#!/usr/bin/env python3
"""Collect per-PR BENCH_fleet.json artifacts and print the jobs/sec
trajectory (ROADMAP open item; conventions in docs/BENCHMARKS.md).

Each CI run uploads a BENCH_fleet artifact (see .github/workflows/ci.yml).
Download the artifacts of the runs you care about (e.g. with
`gh run download -n BENCH_fleet-<sha>` into one directory per run), then:

    python3 tools/bench_trajectory.py artifacts-dir/
    python3 tools/bench_trajectory.py run1/BENCH_fleet.json run2/BENCH_fleet.json

Files given explicitly are plotted in argument order; a directory is
scanned recursively for BENCH_fleet*.json and ordered by mtime, so a
directory of downloaded artifacts reads oldest-to-newest. Only the Python
standard library is used.

CI gate mode (docs/BENCHMARKS.md):

    python3 tools/bench_trajectory.py --check prev-artifact-dir/ BENCH_fleet.json

prints the headline jobs/sec delta of the LAST point vs the one before it
and exits non-zero on a regression worse than -30%. With fewer than two
points (e.g. the first recorded run, or the previous artifact failed to
download) it prints a note and exits zero, so the gate only fires when
there is something to compare.
"""

import json
import os
import sys


def collect(paths):
    """Yield (label, parsed-json) for every BENCH_fleet*.json under paths."""
    files = []
    for p in paths:
        if os.path.isdir(p):
            hits = []
            for root, _dirs, names in os.walk(p):
                for n in sorted(names):
                    if n.startswith("BENCH_fleet") and n.endswith(".json"):
                        hits.append(os.path.join(root, n))
            hits.sort(key=lambda f: os.path.getmtime(f))
            files.extend(hits)
        else:
            files.append(p)
    for f in files:
        try:
            with open(f) as fh:
                yield f, json.load(fh)
        except (OSError, json.JSONDecodeError) as e:
            print(f"skipping {f}: {e}", file=sys.stderr)


def headline(doc):
    """(jobs, jobs_per_sec) of the largest private engine run, or None."""
    best = None
    for run in doc.get("runs", []):
        if "policy" in run:
            continue  # policy-sweep entries measure the shared cluster
        jobs, jps = run.get("jobs"), run.get("jobs_per_sec")
        if jobs is None or jps is None:
            continue
        if best is None or jobs > best[0]:
            best = (jobs, jps)
    return best


def policy_sweep(doc):
    """{policy: jobs_per_sec} for the shared-cluster sweep entries."""
    return {
        run["policy"]: run["jobs_per_sec"]
        for run in doc.get("runs", [])
        if "policy" in run and isinstance(run.get("jobs_per_sec"), (int, float))
    }


def whatif_sweep(doc):
    """(counterfactuals/sec, speedup-vs-cold) of the whatif sweep, or None.

    Informational only — printed, never gated: replay throughput tracks the
    edit mix, which is expected to evolve between PRs.
    """
    ws = doc.get("whatif_sweep")
    if not isinstance(ws, dict):
        return None
    rate = ws.get("counterfactuals_per_sec")
    if not isinstance(rate, (int, float)):
        return None
    speedup = ws.get("speedup_vs_cold")
    return (rate, speedup if isinstance(speedup, (int, float)) else None)


def diagnosis(doc):
    """(overall accuracy, trace overhead %) of the diagnosis section, or None.

    Informational only — printed, never gated: the accuracy bar itself is
    enforced in-tree by the class-labeled test suite; older artifacts
    predate the section and are tolerated silently.
    """
    dx = doc.get("diagnosis")
    if not isinstance(dx, dict):
        return None
    acc = dx.get("overall_accuracy")
    if not isinstance(acc, (int, float)):
        return None
    overhead = (dx.get("trace_overhead") or {}).get("overhead_pct")
    return (acc, overhead if isinstance(overhead, (int, float)) else None)


def audit(doc):
    """(total ms, files/sec, violations, panic sites) of the audit scan, or None.

    Informational only — printed, never gated: the blocking audit gate is
    its own CI step; older artifacts predate the section and are tolerated
    silently.
    """
    au = doc.get("audit")
    if not isinstance(au, dict):
        return None
    ms = au.get("total_ms")
    fps = au.get("files_per_sec")
    if not isinstance(ms, (int, float)) or not isinstance(fps, (int, float)):
        return None
    violations = au.get("violations")
    sites = au.get("panic_sites")
    return (
        ms,
        fps,
        violations if isinstance(violations, (int, float)) else None,
        sites if isinstance(sites, (int, float)) else None,
    )


def replan(doc):
    """(solves/sec, recovered slowdown %) of the S5 replan section, or None.

    Informational only — printed, never gated: the recovery floor is
    enforced in-tree by the saturated-pool tests; older artifacts predate
    the section and are tolerated silently.
    """
    rp = doc.get("replan")
    if not isinstance(rp, dict):
        return None
    solves = rp.get("solves_per_sec")
    if not isinstance(solves, (int, float)):
        return None
    recovered = rp.get("recovered_slowdown_pct")
    return (solves, recovered if isinstance(recovered, (int, float)) else None)


def ledger(doc):
    """(observer overhead %, repeat reduction %) of the ledger section, or None.

    Informational only — printed, never gated: the repeat-incident floor is
    enforced in-tree by the ledger report tests; older artifacts predate
    the section and are tolerated silently.
    """
    lg = doc.get("ledger")
    if not isinstance(lg, dict):
        return None
    overhead = lg.get("overhead_pct")
    if not isinstance(overhead, (int, float)):
        return None
    reduction = lg.get("repeat_reduction_pct")
    return (overhead, reduction if isinstance(reduction, (int, float)) else None)


def sparkline(values):
    ticks = "▁▂▃▄▅▆▇█"
    lo, hi = min(values), max(values)
    span = (hi - lo) or 1.0
    return "".join(ticks[int((v - lo) / span * (len(ticks) - 1))] for v in values)


# Fail --check when jobs/sec drops by more than this fraction.
CHECK_MAX_REGRESSION = 0.30


def check(points):
    """Gate on the last-vs-previous headline delta; see the module docs."""
    if len(points) < 2:
        print("--check: fewer than two recorded runs; nothing to compare (ok)")
        return 0
    pf, prev = points[-2][0], points[-2][2]
    cf, cur = points[-1][0], points[-1][2]
    if prev <= 0.0:
        print(f"--check: previous run {pf} recorded no throughput (ok)")
        return 0
    delta = cur / prev - 1.0
    print(
        f"--check: headline jobs/sec {prev:.1f} ({os.path.relpath(pf)}) -> "
        f"{cur:.1f} ({os.path.relpath(cf)}): {100.0 * delta:+.1f}%"
    )
    if delta < -CHECK_MAX_REGRESSION:
        print(
            f"--check: FAIL — regression exceeds "
            f"{100.0 * CHECK_MAX_REGRESSION:.0f}% budget"
        )
        return 1
    return 0


def main(argv):
    args = argv[1:]
    check_mode = "--check" in args
    paths = [a for a in args if a != "--check"] or ["."]
    points = []
    for f, doc in collect(paths):
        h = headline(doc)
        if h is None:
            print(f"skipping {f}: no private engine runs recorded", file=sys.stderr)
            continue
        points.append(
            (
                f,
                h[0],
                h[1],
                policy_sweep(doc),
                whatif_sweep(doc),
                diagnosis(doc),
                audit(doc),
                replan(doc),
                ledger(doc),
            )
        )

    if check_mode:
        return check(points)

    if not points:
        print("no BENCH_fleet.json artifacts found; see docs/BENCHMARKS.md")
        return 1

    width = max(len(os.path.relpath(f)) for f, *_ in points)
    print(f"fleet engine trajectory ({len(points)} recorded run(s)):\n")
    print(f"  {'artifact':<{width}}  {'jobs':>6}  {'jobs/sec':>9}  policy sweep")
    prev = None
    for f, jobs, jps, sweep, _ws, _dx, _au, _rp, _lg in points:
        delta = "" if prev is None else f" ({100.0 * (jps / prev - 1.0):+.1f}%)"
        sweep_txt = (
            "  ".join(f"{p}={v:.0f}" for p, v in sorted(sweep.items())) or "-"
        )
        print(
            f"  {os.path.relpath(f):<{width}}  {jobs:>6.0f}  {jps:>9.1f}{delta}  "
            f"{sweep_txt}"
        )
        prev = jps
    rates = [p[2] for p in points]
    print(f"\n  trajectory: {sparkline(rates)}  "
          f"(first {rates[0]:.1f} -> last {rates[-1]:.1f} jobs/s, "
          f"{100.0 * (rates[-1] / rates[0] - 1.0):+.1f}%)")
    # Informational (never gated): what-if counterfactual replay rate,
    # diagnosis accuracy / op-trace overhead, audit scan wall-time, the
    # S5 replan planner rate / saturated-pool recovery, and the node-health
    # ledger observer overhead / repeat-incident reduction.
    for f, *_rest, ws, dx, au, rp, lg in points:
        if ws is not None:
            rate, speedup = ws
            extra = "" if speedup is None else f" ({speedup:.1f}x vs cold runs)"
            print(
                f"  whatif sweep [{os.path.relpath(f)}]: "
                f"{rate:.1f} counterfactuals/s{extra}"
            )
        if dx is not None:
            acc, overhead = dx
            extra = (
                "" if overhead is None else f", op-trace overhead {overhead:+.1f}%"
            )
            print(
                f"  diagnosis [{os.path.relpath(f)}]: "
                f"accuracy {acc:.3f}{extra}"
            )
        if au is not None:
            ms, fps, violations, sites = au
            counts = ""
            if violations is not None:
                counts += f", {violations:.0f} violations"
            if sites is not None:
                counts += f", {sites:.0f} budgeted panic sites"
            print(
                f"  audit scan [{os.path.relpath(f)}]: "
                f"{ms:.1f} ms ({fps:.0f} files/sec{counts})"
            )
        if rp is not None:
            solves, recovered = rp
            extra = (
                ""
                if recovered is None
                else f", {recovered:.1f}% slowdown recovered under denial"
            )
            print(
                f"  s5 replan [{os.path.relpath(f)}]: "
                f"{solves:.1f} solves/s{extra}"
            )
        if lg is not None:
            overhead, reduction = lg
            extra = (
                ""
                if reduction is None
                else f", {reduction:.1f}% repeat incidents prevented"
            )
            print(
                f"  ledger [{os.path.relpath(f)}]: "
                f"observer overhead {overhead:+.1f}%{extra}"
            )
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
