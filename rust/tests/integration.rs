//! Cross-module integration tests: detection accuracy end-to-end over the
//! simulator, coordinator invariants under property-based scenario
//! generation, the runtime+trainer composition (when artifacts exist), and
//! report-generator smoke checks.

use falcon::coordinator::{run_with_falcon, ActionKind, FalconConfig};
use falcon::inject::{FailSlowEvent, FailSlowKind, Severity, Target};
use falcon::mitigate::Strategy;
use falcon::pipeline::ParallelConfig;
use falcon::sim::{demo_spec, TrainingSim};
use falcon::simkit::from_secs;
use falcon::util::prop;
use falcon::util::rng::Rng;

fn gpu_event(start_s: f64, dur_iters_s: f64, scale: f64, gpu: usize) -> FailSlowEvent {
    FailSlowEvent {
        kind: FailSlowKind::GpuDegradation,
        target: Target::Gpu(gpu),
        start: from_secs(start_s),
        duration: from_secs(dur_iters_s),
        scale,
    }
}

// ---------------------------------------------------------------------------
// Detection pipeline over the simulator
// ---------------------------------------------------------------------------

#[test]
fn end_to_end_detection_localizes_the_right_gpu() {
    for gpu in [0usize, 3, 5] {
        let mut sim = TrainingSim::new(demo_spec(ParallelConfig::new(1, 8, 1), 77 + gpu as u64));
        let onset = sim.ideal_iter_s * 50.0;
        sim.inject(vec![gpu_event(onset, sim.ideal_iter_s * 400.0, 0.5, gpu)]);
        let falcon = run_with_falcon(&mut sim, FalconConfig::default(), 150);
        let diag = falcon
            .actions
            .iter()
            .find_map(|a| match &a.what {
                ActionKind::Diagnosed(d) => Some(d.clone()),
                _ => None,
            })
            .unwrap_or_else(|| panic!("gpu {gpu}: no diagnosis"));
        assert!(
            diag.slow_gpus.iter().any(|g| g.rank == gpu),
            "gpu {gpu} not localized: {:?}",
            diag.slow_gpus
        );
    }
}

#[test]
fn end_to_end_detection_localizes_congested_path() {
    let mut spec = demo_spec(ParallelConfig::new(8, 2, 2), 91);
    spec.jitter = 0.01;
    let mut sim = TrainingSim::new(spec);
    let onset = sim.ideal_iter_s * 40.0;
    sim.inject(vec![FailSlowEvent {
        kind: FailSlowKind::NetworkCongestion,
        target: Target::Link(0, 1),
        start: from_secs(onset),
        duration: from_secs(sim.ideal_iter_s * 1000.0),
        scale: 0.15,
    }]);
    let falcon = run_with_falcon(&mut sim, FalconConfig::default(), 150);
    let diag = falcon
        .actions
        .iter()
        .find_map(|a| match &a.what {
            ActionKind::Diagnosed(d) => Some(d.clone()),
            _ => None,
        })
        .expect("no diagnosis");
    assert_eq!(diag.kind, FailSlowKind::NetworkCongestion);
    // Flagged edges must touch nodes 0/1 (ranks 0..15).
    assert!(
        diag.slow_edges.iter().all(|e| e.from_rank < 16 && e.to_rank < 16),
        "{:?}",
        diag.slow_edges
    );
}

// ---------------------------------------------------------------------------
// Coordinator invariants (property-based)
// ---------------------------------------------------------------------------

#[test]
fn prop_microbatch_conservation_under_any_scenario() {
    // Whatever FALCON does — S2 reallocations, S3 swaps, S4 restarts — the
    // global batch (sum of micro-batches) is conserved every iteration.
    prop::check(
        "batch-conservation",
        0xBA7C4,
        12,
        |rng: &mut Rng| {
            let dp = [2usize, 4, 8][rng.below(3) as usize];
            let n_events = 1 + rng.below(3) as usize;
            let seed = rng.next_u64();
            (dp, n_events, seed)
        },
        |&(dp, n_events, seed)| {
            let mut sim = TrainingSim::new(demo_spec(ParallelConfig::new(1, dp, 1), seed));
            let total = sim.spec.wl.microbatches * dp;
            let mut rng = Rng::new(seed ^ 1);
            let evs: Vec<FailSlowEvent> = (0..n_events)
                .map(|_| {
                    gpu_event(
                        sim.ideal_iter_s * rng.range_f64(10.0, 60.0),
                        sim.ideal_iter_s * rng.range_f64(30.0, 200.0),
                        rng.range_f64(0.3, 0.8),
                        rng.below(dp as u64) as usize,
                    )
                })
                .collect();
            sim.inject(evs);
            let mut falcon = falcon::coordinator::Falcon::new(FalconConfig::default());
            for _ in 0..120 {
                let obs = sim.step();
                falcon.on_iteration(&mut sim, obs.iter, obs.duration as f64 / 1e6);
                let sum: usize = sim.microbatch_alloc.iter().sum();
                if sum != total {
                    return Err(format!("batch leaked: {sum} != {total}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_node_map_stays_a_permutation() {
    // S3 swaps must always leave node_map a permutation of 0..n.
    prop::check(
        "node-map-permutation",
        0x70B0,
        8,
        |rng: &mut Rng| rng.next_u64(),
        |&seed| {
            let mut spec = demo_spec(ParallelConfig::new(8, 2, 2), seed);
            spec.jitter = 0.01;
            let mut sim = TrainingSim::new(spec);
            let mut rng = Rng::new(seed);
            sim.inject(vec![FailSlowEvent {
                kind: FailSlowKind::NetworkCongestion,
                target: Target::Link(0, 1 + rng.below(3) as usize),
                start: from_secs(sim.ideal_iter_s * 20.0),
                duration: from_secs(sim.ideal_iter_s * 500.0),
                scale: 0.2,
            }]);
            let mut fc = FalconConfig::default();
            fc.overheads.adjust_topology_s = 5.0;
            run_with_falcon(&mut sim, fc, 120);
            let mut map = sim.grid.node_map.clone();
            map.sort_unstable();
            let expect: Vec<usize> = (0..sim.grid.n_nodes()).collect();
            if map == expect {
                Ok(())
            } else {
                Err(format!("node_map corrupted: {:?}", sim.grid.node_map))
            }
        },
    );
}

#[test]
fn prop_clock_monotone_under_falcon_actions() {
    // Pauses, restarts and swaps must never move the clock backwards.
    prop::check(
        "clock-monotone",
        0xC10C,
        8,
        |rng: &mut Rng| rng.next_u64(),
        |&seed| {
            let mut sim = TrainingSim::new(demo_spec(ParallelConfig::new(1, 4, 1), seed));
            sim.inject(vec![gpu_event(
                sim.ideal_iter_s * 15.0,
                sim.ideal_iter_s * 300.0,
                0.25,
                (seed % 4) as usize,
            )]);
            let mut fc = FalconConfig::default();
            fc.overheads.ckpt_restart_s = 30.0;
            fc.restart_cost = from_secs(30.0);
            let mut falcon = falcon::coordinator::Falcon::new(fc);
            let mut last = sim.now;
            for _ in 0..150 {
                let obs = sim.step();
                falcon.on_iteration(&mut sim, obs.iter, obs.duration as f64 / 1e6);
                if sim.now < last {
                    return Err(format!("clock went backwards: {} < {last}", sim.now));
                }
                last = sim.now;
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Mitigation effectiveness invariants
// ---------------------------------------------------------------------------

#[test]
fn mitigated_never_slower_than_unmitigated_for_long_compute_failslow() {
    for seed in [1u64, 2, 3] {
        let mk = |mitigate: bool| {
            let mut sim = TrainingSim::new(demo_spec(ParallelConfig::new(1, 8, 1), 500 + seed));
            let onset = sim.ideal_iter_s * 30.0;
            sim.inject(vec![gpu_event(
                onset,
                sim.ideal_iter_s * 500.0,
                Severity::Severe.scale(),
                (seed % 8) as usize,
            )]);
            run_with_falcon(
                &mut sim,
                FalconConfig { mitigate, ..FalconConfig::default() },
                250,
            );
            250.0 / falcon::simkit::secs(sim.now)
        };
        let with = mk(true);
        let without = mk(false);
        assert!(
            with > without,
            "seed {seed}: mitigated {with} <= unmitigated {without}"
        );
    }
}

// ---------------------------------------------------------------------------
// Runtime + live trainer composition (skipped without artifacts)
// ---------------------------------------------------------------------------

#[cfg(feature = "pjrt")]
fn artifacts_ready() -> bool {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/.stamp")
        .exists()
}

#[cfg(feature = "pjrt")]
#[test]
fn live_trainer_composes_with_detector_and_s2() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    use falcon::detect::{BocdConfig, Detector};
    use falcon::runtime::Runtime;
    use falcon::trainer::{LiveTrainer, TrainerConfig};

    let rt = Runtime::new(
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
    )
    .unwrap();
    let mut t = LiveTrainer::new(
        &rt,
        &TrainerConfig { preset: "tiny".into(), dp: 2, microbatches: 2, seed: 3 },
    )
    .unwrap();
    let mut det = Detector::new(BocdConfig::default());

    let mut verified = false;
    for step in 0..60 {
        if step == 20 {
            t.compute_scale[0] = 0.3;
        }
        let obs = t.step().unwrap();
        if det.push(obs.iter_time_s) == Some(true) {
            verified = true;
            let times = t.microbatch_times(&obs);
            let total: usize = t.alloc.iter().sum();
            t.set_alloc(falcon::mitigate::microbatch::solve(&times, total).m);
        }
        if verified {
            break;
        }
    }
    assert!(verified, "live fail-slow not verified by BOCD+V");
    assert!(
        t.alloc[0] < t.alloc[1],
        "S2 must shed load from the slow worker: {:?}",
        t.alloc
    );
}

// ---------------------------------------------------------------------------
// Fleet engine: sharded many-job campaigns stay deterministic end to end
// ---------------------------------------------------------------------------

#[test]
fn fleet_campaign_deterministic_across_shardings() {
    use falcon::fleet::{run_fleet, FleetConfig};
    let cfg = FleetConfig {
        jobs: 20,
        iters: 50,
        seed: 42,
        workers: 4,
        failslow_boost: 10.0,
        compare: true,
        ..FleetConfig::default()
    };
    let a = run_fleet(&cfg);
    let b = run_fleet(&FleetConfig { workers: 1, ..cfg.clone() });
    assert_eq!(a.results.len(), 20);
    assert_eq!(a.digest(), b.digest(), "fleet result depends on sharding");
    // The aggregate actually aggregates: per-job fields roll up exactly.
    let episodes: usize = a.results.iter().map(|r| r.episodes_detected).sum();
    assert_eq!(episodes, a.episodes_detected);
    assert_eq!(
        a.results.iter().filter(|r| r.injected > 0).count(),
        a.jobs_with_failslow
    );
    // Rendered report is stable modulo wall-clock lines.
    let strip = |s: String| -> String {
        s.lines().filter(|l| !l.starts_with("engine:")).collect::<Vec<_>>().join("\n")
    };
    assert_eq!(strip(a.render()), strip(b.render()));
}

#[test]
fn shared_cluster_fleet_deterministic_and_arbitrated_end_to_end() {
    use falcon::cluster::Policy;
    use falcon::fleet::{run_fleet, FleetConfig};
    let mut cfg = FleetConfig {
        jobs: 14,
        iters: 70,
        seed: 5,
        workers: 4,
        failslow_boost: 18.0,
        compare: false,
        policy: Some(Policy::Packed),
        spare_frac: 0.2,
        epoch_len: 10,
        ..FleetConfig::default()
    };
    cfg.falcon.overheads.adjust_microbatch_s = 0.5;
    cfg.falcon.overheads.adjust_topology_s = 2.0;
    cfg.falcon.overheads.ckpt_restart_s = 10.0;
    let a = run_fleet(&cfg);
    let b = run_fleet(&FleetConfig { workers: 1, ..cfg.clone() });
    assert_eq!(a.digest(), b.digest(), "shared-cluster fleet depends on sharding");
    let c = a.cluster.as_ref().expect("cluster summary");
    assert!(c.mean_contention_scale <= 1.0);
    // Arbitration tallies roll up exactly from the per-job counters.
    let granted: u32 = a.results.iter().map(|r| r.arb.granted).sum();
    assert_eq!(
        granted as usize,
        c.s3_granted + c.s4_granted + c.s4_in_place,
        "grant accounting mismatch"
    );
    let rendered = a.render();
    assert!(rendered.contains("shared cluster: policy packed"), "{rendered}");
    assert!(rendered.contains("arbitration:"), "{rendered}");
}

// ---------------------------------------------------------------------------
// Report generators (fast smoke of the full registry)
// ---------------------------------------------------------------------------

#[test]
fn cheap_reports_render() {
    let args = falcon::util::cli::Args::parse(
        ["--iters".to_string(), "40".into(), "--samples".into(), "500".into()],
    );
    for id in ["fig3", "fig8", "tab2", "tab6", "fig14"] {
        let out = falcon::reports::generate(id, &args);
        assert!(out.len() > 100, "{id}: {out}");
    }
}
