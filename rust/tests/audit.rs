//! Golden tests for the `falcon-audit` scanner: every rule has a fixture
//! under `tests/audit_fixtures/` that fires at an exact `(rule, line)`,
//! allow suppression is pinned, and a self-audit keeps `src/` clean.
//!
//! Fixture files live in a subdirectory so cargo never compiles them —
//! they are scanned as text, under *virtual* paths chosen to put each
//! one in the rule's scope.

use falcon::audit::{audit_dir, audit_source, FileFindings, PANIC_BUDGET, RULES};

fn fired(path: &str, fixture: &str) -> Vec<(&'static str, usize)> {
    let f = audit_source(path, fixture);
    assert!(
        f.panic_sites.is_empty(),
        "unexpected panic sites in {path}: {:?}",
        f.panic_sites
    );
    f.violations.iter().map(|d| (d.rule, d.line)).collect()
}

#[test]
fn generation_discipline_fires_on_direct_field_writes() {
    let fx = include_str!("audit_fixtures/generation_discipline.rs");
    assert_eq!(
        fired("mitigate/planner.rs", fx),
        vec![
            ("generation-discipline", 4), // plain assignment
            ("generation-discipline", 5), // compound assignment
            ("generation-discipline", 6), // pair_scale map mutator
        ]
    );
}

#[test]
fn generation_discipline_blesses_the_setters_themselves() {
    // Same writes inside a blessed setter in fabric/mod.rs are the point.
    let fx = "pub fn set_uplink_scale(&mut self, n: usize, s: f64) {\n    \
              self.uplinks[n].bandwidth_scale = s;\n}\n";
    assert_eq!(fired("fabric/mod.rs", fx), vec![]);
    // ...but only there: any other file is still in scope.
    assert_eq!(fired("sim/mod.rs", fx), vec![("generation-discipline", 2)]);
}

#[test]
fn digest_determinism_fires_on_hash_collections() {
    let fx = include_str!("audit_fixtures/digest_determinism.rs");
    assert_eq!(
        fired("fleet/mod.rs", fx),
        vec![("digest-determinism", 3), ("digest-determinism", 6)]
    );
    // The substrate is exempt: no digest-reachable state there.
    assert_eq!(fired("util/stats.rs", fx), vec![]);
}

#[test]
fn clock_hygiene_fires_on_wall_clock() {
    let fx = include_str!("audit_fixtures/clock_hygiene.rs");
    assert_eq!(
        fired("sim/mod.rs", fx),
        vec![("clock-hygiene", 4), ("clock-hygiene", 5)]
    );
}

#[test]
fn rng_stream_fires_on_adhoc_roots() {
    let fx = include_str!("audit_fixtures/rng_stream.rs");
    assert_eq!(
        fired("sim/mod.rs", fx),
        vec![
            ("rng-stream", 4), // Rng::new root
            ("rng-stream", 5), // rand:: crate
            ("rng-stream", 6), // thread_rng
        ]
    );
    // reports/ may seed its own illustrative streams (exempt from the
    // root-stream rule), but ambient RNG is banned everywhere.
    assert_eq!(
        fired("reports/cases.rs", fx),
        vec![("rng-stream", 5), ("rng-stream", 6)]
    );
}

#[test]
fn panic_budget_meters_sites_separately() {
    let fx = include_str!("audit_fixtures/panic_budget.rs");
    let f: FileFindings = audit_source("fleet/mod.rs", fx);
    assert!(f.violations.is_empty(), "{:?}", f.violations);
    let sites: Vec<(&str, usize)> = f.panic_sites.iter().map(|d| (d.rule, d.line)).collect();
    // `.unwrap(` and `panic!` fire; `unwrap_or` on line 16 must not.
    assert_eq!(sites, vec![("panic-budget", 4), ("panic-budget", 11)]);
}

#[test]
fn allow_grammar_flags_malformed_directives() {
    let fx = include_str!("audit_fixtures/allow_grammar.rs");
    assert_eq!(
        fired("sim/mod.rs", fx),
        vec![
            ("allow-grammar", 4),  // reason-less allow
            ("clock-hygiene", 5),  // ...which therefore does not suppress
            ("allow-grammar", 6),  // unknown rule id
            ("clock-hygiene", 7),  // ...ditto
        ]
    );
}

#[test]
fn wellformed_allow_suppresses_and_tests_are_out_of_scope() {
    let fx = include_str!("audit_fixtures/allow_suppression.rs");
    let f = audit_source("sim/mod.rs", fx);
    assert!(f.violations.is_empty(), "{:?}", f.violations);
    assert!(f.panic_sites.is_empty(), "{:?}", f.panic_sites);
    assert_eq!(f.allowed, 1);
}

#[test]
fn every_rule_has_a_registry_entry_and_vice_versa() {
    let ids: Vec<&str> = RULES.iter().map(|r| r.id).collect();
    for id in [
        "generation-discipline",
        "digest-determinism",
        "clock-hygiene",
        "rng-stream",
        "panic-budget",
        "allow-grammar",
    ] {
        assert!(ids.contains(&id), "missing registry entry for {id}");
    }
    assert_eq!(ids.len(), 6);
}

#[test]
fn shipped_tree_is_audit_clean() {
    let src = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let report = audit_dir(&src).expect("scan src/");
    assert!(
        report.clean(),
        "shipped tree has audit violations:\n{}",
        report.render()
    );
    // Budgets are a ratchet: every metered module must be at or under
    // its allowance (clean() already implies this; pin it explicitly).
    for (prefix, used, allowance) in &report.budget_used {
        assert!(used <= allowance, "{prefix}: {used} > {allowance}");
    }
    assert!(report.files > 40, "suspiciously few files: {}", report.files);
}

#[test]
fn shipped_tree_report_is_machine_readable() {
    let src = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let report = audit_dir(&src).expect("scan src/");
    let json = report.to_json().to_string();
    assert!(json.contains("\"clean\":true"), "{json}");
    assert!(json.contains("\"rules\":"), "{json}");
    // Budget entries survive serialization with their allowances.
    for (prefix, _, _) in PANIC_BUDGET {
        if report.budget_used.iter().any(|(p, _, _)| p == prefix) {
            assert!(json.contains(&format!("\"prefix\":\"{prefix}\"")), "{json}");
        }
    }
}
