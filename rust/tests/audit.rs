//! Golden tests for the `falcon-audit` scanner: every rule has a fixture
//! under `tests/audit_fixtures/` that fires at an exact `(rule, line)`,
//! allow suppression is pinned, and a self-audit keeps `src/` clean.
//!
//! Fixture files live in a subdirectory so cargo never compiles them —
//! they are scanned as text, under *virtual* paths chosen to put each
//! one in the rule's scope. Since v2 the scanner is crate-aware: the
//! digest/clock rules key off call-graph reachability from digest and
//! replay roots, not off hand-maintained path lists, so the same fixture
//! must pin the same findings regardless of the path it is scanned under.

use falcon::audit::{
    audit_dir, audit_dir_graph, audit_source, audit_sources, FileFindings, PANIC_BUDGET, RULES,
};

fn fired(path: &str, fixture: &str) -> Vec<(&'static str, usize)> {
    let f = audit_source(path, fixture);
    assert!(
        f.panic_sites.is_empty(),
        "unexpected panic sites in {path}: {:?}",
        f.panic_sites
    );
    f.violations.iter().map(|d| (d.rule, d.line)).collect()
}

#[test]
fn generation_discipline_fires_on_direct_field_writes() {
    let fx = include_str!("audit_fixtures/generation_discipline.rs");
    assert_eq!(
        fired("mitigate/planner.rs", fx),
        vec![
            ("generation-discipline", 4), // plain assignment
            ("generation-discipline", 5), // compound assignment
            ("generation-discipline", 6), // pair_scale map mutator
        ]
    );
}

#[test]
fn generation_discipline_blesses_the_setters_themselves() {
    // Same writes inside a blessed setter in fabric/mod.rs are the point.
    let fx = "pub fn set_uplink_scale(&mut self, n: usize, s: f64) {\n    \
              self.uplinks[n].bandwidth_scale = s;\n}\n";
    assert_eq!(fired("fabric/mod.rs", fx), vec![]);
    // ...but only there: any other file is still in scope.
    assert_eq!(fired("sim/mod.rs", fx), vec![("generation-discipline", 2)]);
}

#[test]
fn digest_determinism_fires_wherever_a_digest_root_reaches() {
    let fx = include_str!("audit_fixtures/digest_determinism.rs");
    let pins = vec![("digest-determinism", 3), ("digest-determinism", 6)];
    assert_eq!(fired("fleet/mod.rs", fx), pins);
    // v1 exempted util/ by path; reachability replaces that list, so a
    // digest root under util/ is now in scope like anywhere else.
    assert_eq!(fired("util/stats.rs", fx), pins);
}

#[test]
fn digest_rules_scope_by_reachability_not_path() {
    let fx = include_str!("audit_fixtures/digest_reachability.rs");
    // `tally` is reachable from the `digest` root -> its HashMap fires,
    // even under the previously-exempt util/ prefix; `cold_path` is
    // unreachable, so its HashSet on line 17 stays quiet.
    assert_eq!(fired("util/maps.rs", fx), vec![("digest-determinism", 9)]);
}

#[test]
fn clock_hygiene_fires_only_in_digest_reachable_fns() {
    let fx = include_str!("audit_fixtures/clock_hygiene.rs");
    // `step_time` is reachable from the `to_json` root.
    assert_eq!(
        fired("sim/mod.rs", fx),
        vec![("clock-hygiene", 8), ("clock-hygiene", 9)]
    );
    // The same body with no root calling it is out of scope entirely.
    let cold = "fn step_time() -> f64 {\n    \
                let t0 = std::time::Instant::now();\n    \
                t0.elapsed().as_secs_f64()\n}\n";
    assert_eq!(fired("sim/mod.rs", cold), vec![]);
}

#[test]
fn rng_stream_and_taint_fire_on_adhoc_roots() {
    let fx = include_str!("audit_fixtures/rng_stream.rs");
    let pins = vec![
        ("rng-taint", 4),  // Rng::new(0xDEAD): literal seed, no derivation
        ("rng-stream", 5), // rand:: crate
        ("rng-stream", 6), // thread_rng
    ];
    assert_eq!(fired("sim/mod.rs", fx), pins);
    // v1 let reports/ seed its own streams by path exemption; the taint
    // rule replaces that list and holds every module to the same proof.
    assert_eq!(fired("reports/overhead.rs", fx), pins);
}

#[test]
fn rng_taint_traces_literals_through_helper_params() {
    let fx = include_str!("audit_fixtures/rng_taint.rs");
    // `helper(41)` launders a literal into `Rng::new(tag)` -> line 4
    // fires; `Rng::new(seed)` and `helper(seed)` both prove their
    // derivation and stay quiet.
    assert_eq!(fired("sim/taint.rs", fx), vec![("rng-taint", 4)]);
}

#[test]
fn lock_order_flags_inversions_and_guards_across_the_arbiter() {
    let fx = include_str!("audit_fixtures/lock_order.rs");
    assert_eq!(
        fired("fleet/locks.rs", fx),
        vec![
            ("lock-order", 12), // slots -> jobs ...
            ("lock-order", 18), // ... and jobs -> slots: inversion pair
            ("lock-order", 24), // admit() called under a live guard
        ]
    );
}

#[test]
fn module_layering_enforces_the_dependency_dag() {
    let fx = include_str!("audit_fixtures/module_layering.rs");
    // diagnose may not import whatif...
    assert_eq!(fired("diagnose/bad.rs", fx), vec![("module-layering", 3)]);
    // ...but reports may: the same text is clean under an allowed edge.
    assert_eq!(fired("reports/bad.rs", fx), vec![]);
}

#[test]
fn panic_budget_meters_sites_separately() {
    let fx = include_str!("audit_fixtures/panic_budget.rs");
    let f: FileFindings = audit_source("fleet/mod.rs", fx);
    assert!(f.violations.is_empty(), "{:?}", f.violations);
    let sites: Vec<(&str, usize)> = f.panic_sites.iter().map(|d| (d.rule, d.line)).collect();
    // `.unwrap(` and `panic!` fire; `unwrap_or` on line 16 must not, and
    // `self.expect("x")` resolves to Parser's own method — no site.
    assert_eq!(sites, vec![("panic-budget", 4), ("panic-budget", 11)]);
}

#[test]
fn allow_grammar_flags_malformed_and_stale_directives() {
    let fx = include_str!("audit_fixtures/allow_grammar.rs");
    assert_eq!(
        fired("sim/mod.rs", fx),
        vec![
            ("allow-grammar", 4), // reason-less allow
            ("clock-hygiene", 5), // ...which therefore does not suppress
            ("allow-grammar", 6), // unknown rule id
            ("clock-hygiene", 7), // ...ditto
            ("allow-grammar", 8), // well-formed but stale: suppresses nothing
        ]
    );
}

#[test]
fn wellformed_allow_suppresses_and_tests_are_out_of_scope() {
    let fx = include_str!("audit_fixtures/allow_suppression.rs");
    let f = audit_source("sim/mod.rs", fx);
    assert!(f.violations.is_empty(), "{:?}", f.violations);
    assert!(f.panic_sites.is_empty(), "{:?}", f.panic_sites);
    assert_eq!(f.allowed, 1);
}

#[test]
fn every_rule_has_a_registry_entry_and_vice_versa() {
    let ids: Vec<&str> = RULES.iter().map(|r| r.id).collect();
    for id in [
        "generation-discipline",
        "digest-determinism",
        "clock-hygiene",
        "rng-stream",
        "rng-taint",
        "lock-order",
        "module-layering",
        "panic-budget",
        "allow-grammar",
    ] {
        assert!(ids.contains(&id), "missing registry entry for {id}");
    }
    assert_eq!(ids.len(), 9);
}

#[test]
fn path_exemption_lists_stay_deleted() {
    // v2 derives scope from the call graph; the v1 hand-maintained
    // path lists must not come back.
    let rules_src = include_str!("../src/audit/rules.rs");
    assert!(
        !rules_src.contains("DIGEST_EXEMPT") && !rules_src.contains("RNG_EXEMPT"),
        "path-exemption lists have returned to rules.rs; scope comes from reachability"
    );
}

#[test]
fn graph_snapshot_of_a_known_crate_is_exact() {
    let sources = vec![
        ("lib.rs".to_string(), "pub mod fabric;\npub mod sim;\n".to_string()),
        (
            "fabric/mod.rs".to_string(),
            "pub struct Net;\n\nimpl Net {\n    pub fn scale(&self) -> f64 {\n        1.0\n    }\n}\n"
                .to_string(),
        ),
        (
            "sim/mod.rs".to_string(),
            "use crate::fabric::Net;\n\npub fn digest(n: &Net) -> f64 {\n    helper(n)\n}\n\n\
             fn helper(n: &Net) -> f64 {\n    n.scale()\n}\n"
                .to_string(),
        ),
    ];
    let audit = audit_sources(&sources);
    assert!(audit.report.clean(), "{}", audit.report.render());
    assert_eq!(audit.graph.fns.len(), 3, "scale, digest, helper");
    assert_eq!(audit.graph.calls.len(), 2, "helper(n) and n.scale()");
    assert_eq!(audit.graph.call_edges().len(), 2);
    let mods: Vec<&str> = audit.graph.modules.iter().map(|m| m.as_str()).collect();
    assert_eq!(mods, vec!["fabric", "lib", "sim"]);
    let edges: Vec<(&str, &str)> = audit
        .graph
        .mod_edges
        .keys()
        .map(|(a, b)| (a.as_str(), b.as_str()))
        .collect();
    assert_eq!(edges, vec![("sim", "fabric")]);
    // digest is the sole root; helper and Net::scale are reachable from it.
    assert_eq!(audit.flow.roots.len(), 1);
    assert_eq!(audit.flow.reachable.len(), 3);
    // The graph serializes with the sections the CI artifact relies on.
    let json = audit.graph.to_json(&audit.flow).to_string();
    for key in ["\"fns\":", "\"call_sites\":", "\"call_edges\":", "\"roots\":", "\"module_edges\":"] {
        assert!(json.contains(key), "missing {key} in {json}");
    }
}

#[test]
fn graph_snapshot_of_src_stays_in_band() {
    let src = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let audit = audit_dir_graph(&src).expect("scan src/");
    let g = &audit.graph;
    let mods: Vec<&str> = g.modules.iter().map(|m| m.as_str()).collect();
    assert_eq!(
        mods,
        vec![
            "anyhow", "audit", "ckpt", "cluster", "collectives", "coordinator", "detect",
            "diagnose", "fabric", "fleet", "inject", "ledger", "lib", "main", "metrics",
            "mitigate", "monitor", "pipeline", "reports", "runtime", "scenario", "sim", "simkit",
            "trainer", "util", "whatif", "xla",
        ]
    );
    // Size bands around the current snapshot (63 files, ~1015 fns,
    // ~5138 call sites, ~3086 resolved edges, 14 roots, ~298 reachable,
    // ~103 module edges): wide enough to absorb normal growth, tight
    // enough that a broken extractor cannot pass.
    let fns = g.fns.len();
    assert!((800..=1400).contains(&fns), "fn count out of band: {fns}");
    let calls = g.calls.len();
    assert!((4000..=7000).contains(&calls), "call sites out of band: {calls}");
    let edges = g.call_edges().len();
    assert!((2300..=4200).contains(&edges), "call edges out of band: {edges}");
    let roots = audit.flow.roots.len();
    assert!((10..=22).contains(&roots), "roots out of band: {roots}");
    let reach = audit.flow.reachable.len();
    assert!((200..=450).contains(&reach), "reachable out of band: {reach}");
    let med = g.mod_edges.len();
    assert!((70..=150).contains(&med), "module edges out of band: {med}");
    // The fleet admission locks are the crate's only named Mutexes.
    let locks: Vec<String> = g.locks.iter().map(|l| format!("{}::{}", l.module, l.name)).collect();
    assert!(locks.contains(&"fleet::slots".to_string()), "{locks:?}");
    assert!(locks.contains(&"fleet::jobs".to_string()), "{locks:?}");
    // Reachability sanity: the digest surface is in, the report
    // generators (no digest/replay roots) are out.
    assert!(audit.flow.reachable_files.contains("fleet/mod.rs"));
    assert!(audit.flow.reachable_files.contains("whatif/replay.rs"));
    assert!(!audit.flow.reachable_files.contains("reports/overhead.rs"));
}

#[test]
fn shipped_tree_is_audit_clean() {
    let src = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let report = audit_dir(&src).expect("scan src/");
    assert!(
        report.clean(),
        "shipped tree has audit violations:\n{}",
        report.render()
    );
    // Budgets are a ratchet: every metered module must be at or under
    // its allowance (clean() already implies this; pin it explicitly).
    for (prefix, used, allowance) in &report.budget_used {
        assert!(used <= allowance, "{prefix}: {used} > {allowance}");
    }
    assert!(report.files > 40, "suspiciously few files: {}", report.files);
}

#[test]
fn shipped_tree_report_is_machine_readable() {
    let src = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let report = audit_dir(&src).expect("scan src/");
    let json = report.to_json().to_string();
    assert!(json.contains("\"clean\":true"), "{json}");
    assert!(json.contains("\"rules\":"), "{json}");
    // Budget entries survive serialization with their allowances.
    for (prefix, _, _) in PANIC_BUDGET {
        if report.budget_used.iter().any(|(p, _, _)| p == prefix) {
            assert!(json.contains(&format!("\"prefix\":\"{prefix}\"")), "{json}");
        }
    }
}
