//! Fixture: a well-formed allow suppresses exactly its rule on its line,
//! and `#[cfg(test)]` regions are out of scope.

pub fn to_json() -> f64 {
    // audit:allow(clock-hygiene): fixture models a real measurement site
    let t0 = std::time::Instant::now();
    t0.elapsed().as_secs_f64()
}

#[cfg(test)]
mod tests {
    #[test]
    fn tests_may_use_wall_clock_and_unwrap() {
        let t0 = std::time::Instant::now();
        let v = vec![1u32];
        assert_eq!(*v.first().unwrap(), 1);
        assert!(t0.elapsed().as_secs_f64() >= 0.0);
    }
}
