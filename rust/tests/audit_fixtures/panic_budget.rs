//! Fixture: panics in non-test library code.

pub fn head(v: &[u32]) -> u32 {
    *v.first().unwrap()
}

pub fn mode(name: &str) -> u32 {
    match name {
        "fast" => 1,
        "slow" => 2,
        _ => panic!("unknown mode {name}"),
    }
}

pub fn soft(v: &[u32]) -> u32 {
    v.first().copied().unwrap_or(0)
}

pub struct Parser;

impl Parser {
    fn expect(&self, tag: &str) -> u32 {
        tag.len() as u32
    }

    /// `self.expect` resolves to the in-crate method above, not
    /// `Option::expect` — the call graph proves it is no panic site.
    pub fn run(&self) -> u32 {
        self.expect("x")
    }
}
