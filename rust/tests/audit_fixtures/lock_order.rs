//! Fixture: a lock-order inversion pair and a guard held across a call
//! into the arbiter serialization path.

pub struct Pair {
    slots: std::sync::Mutex<u32>,
    jobs: std::sync::Mutex<u32>,
}

impl Pair {
    pub fn ab(&self) -> u32 {
        let ga = self.slots.lock().unwrap_or_else(|e| e.into_inner());
        let gb = self.jobs.lock().unwrap_or_else(|e| e.into_inner());
        *ga + *gb
    }

    pub fn ba(&self) -> u32 {
        let gb = self.jobs.lock().unwrap_or_else(|e| e.into_inner());
        let ga = self.slots.lock().unwrap_or_else(|e| e.into_inner());
        *ga + *gb
    }

    pub fn grant(&self) -> u32 {
        let g = self.slots.lock().unwrap_or_else(|e| e.into_inner());
        self.admit(3);
        *g
    }
}
