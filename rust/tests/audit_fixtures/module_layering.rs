//! Fixture: a module edge outside the allowed dependency DAG.

use crate::whatif::Edit;

pub fn kind(_e: &Edit) -> u32 {
    0
}
