//! Fixture: ad-hoc RNG roots off the blessed derivation path.

pub fn roll() -> u64 {
    let mut r = Rng::new(0xDEAD);
    let ambient = rand::random::<u64>();
    let mut t = thread_rng();
    let forked = r.fork(7).u64();
    forked ^ ambient ^ t.next_u64()
}
