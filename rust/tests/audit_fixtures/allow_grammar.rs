//! Fixture: malformed or stale allow directives are themselves findings.

pub fn to_json() -> f64 {
    // audit:allow(clock-hygiene)
    let t0 = std::time::Instant::now();
    // audit:allow(no-such-rule): a reason does not save an unknown id
    let t1 = std::time::Instant::now();
    // audit:allow(digest-determinism): stale — nothing here touches a map
    let dt = t0.elapsed().as_secs_f64() + t1.elapsed().as_secs_f64();
    dt
}
