//! Fixture: hash collections in digest/replay-reachable code.

use std::collections::HashMap;

pub fn digest(xs: &[u64]) -> u64 {
    let mut seen = std::collections::HashSet::new();
    let mut acc = 0u64;
    for &x in xs {
        if seen.insert(x) {
            acc = acc.wrapping_mul(31).wrapping_add(x);
        }
    }
    let ordered = std::collections::BTreeMap::from([(0u64, acc)]);
    ordered[&0]
}
