//! Fixture: wall-clock reads inside the digest-reachable sim surface.

pub fn to_json() -> f64 {
    step_time()
}

fn step_time() -> f64 {
    let t0 = std::time::Instant::now();
    let wall = std::time::SystemTime::now();
    let _ = wall;
    t0.elapsed().as_secs_f64()
}
