//! Fixture: direct Cluster health-field writes outside the blessed setters.

pub fn tamper(cluster: &mut crate::fabric::Cluster) {
    cluster.gpus[3].compute_scale = 0.25;
    cluster.uplinks[1].bandwidth_scale *= 0.5;
    cluster.pair_scale.insert((0, 1), 0.3);
    let read_is_fine = cluster.gpus[0].compute_scale;
    let _ = read_is_fine;
}
