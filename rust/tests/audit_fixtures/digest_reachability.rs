//! Fixture: reachability puts digest rules in scope even under paths the
//! old exemption lists skipped, and keeps unreachable helpers out.

pub fn digest(xs: &[u64]) -> u64 {
    tally(xs)
}

fn tally(xs: &[u64]) -> u64 {
    let mut m = std::collections::HashMap::new();
    for &x in xs {
        *m.entry(x).or_insert(0u64) += 1;
    }
    m.len() as u64
}

fn cold_path() -> usize {
    let s: std::collections::HashSet<u32> = std::collections::HashSet::new();
    s.len()
}
