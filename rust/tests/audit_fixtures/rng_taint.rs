//! Fixture: a literal RNG root laundered through a helper parameter.

fn helper(tag: u64) -> u64 {
    let r = Rng::new(tag);
    let _ = r;
    tag
}

pub fn seeded(seed: u64) -> u64 {
    let direct = Rng::new(seed);
    let _ = direct;
    helper(41) ^ helper(seed)
}
