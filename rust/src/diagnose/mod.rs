//! Collective-level diagnosis: hang-vs-slow fault taxonomy (CCL-D).
//!
//! FALCON-DETECT tells us *when* iterations go anomalous; this module
//! answers *what kind* and *where*. The simulator records a per-iteration
//! [`TraceEntry`] — per-ring edge evidence plus per-replica compute
//! evidence, every ratio normalized against a pristine healthy twin of the
//! cluster so a healthy component reads exactly 1.0 — into a bounded
//! [`OpTrace`] ring buffer. When the detector opens (or escalates) an
//! episode, [`classify`] folds the most recent [`WINDOW`] entries into one
//! of four classes and pinpoints the culprit component:
//!
//! - **comm-hang** — a collective is *blocked* (hung edges present), with
//!   no independent slow evidence. The CCL-D distinction: a hang does not
//!   stretch, it wedges at the watchdog; S1–S3 mitigations cannot help and
//!   the coordinator routes straight to S4 (checkpoint restart).
//! - **slow-masking-a-hang** — hung edges *plus* genuine slow evidence
//!   (a degraded GPU or congested link underneath). Still routed to S4:
//!   the hang dominates, but the report keeps both signals.
//! - **comm-slow** — no hang, but a ring edge runs ≥ [`COMM_SLOW_RATIO`]
//!   over its healthy-twin time (congestion); normal S1–S4 escalation.
//! - **compute-slow** — rings healthy, a replica's 1F1B makespan runs ≥
//!   [`COMPUTE_SLOW_RATIO`] over its healthy twin (GPU degradation or CPU
//!   contention); normal escalation.
//!
//! Evidence below every threshold classifies as `None` — the episode is a
//! transient/noise verdict the detector will close on its own.
//!
//! Determinism contract: every ratio here derives from *nominal* (noise
//! free) cache products; building or classifying a trace draws no RNG and
//! never perturbs the simulation stream. Collections are BTree-ordered so
//! digests over diagnosis output are stable (`falcon-audit` pins this
//! directory into the digest-determinism scope with a panic budget of 0).

use std::collections::{BTreeMap, BTreeSet, VecDeque};

use crate::simkit::Time;

/// A ring edge runs this factor over its healthy-twin time → comm-slow
/// evidence. The weakest library congestion (scale 0.45) lands near 2.2x;
/// healthy edges read exactly 1.0, so 1.3 splits them with wide margin.
pub const COMM_SLOW_RATIO: f64 = 1.3;

/// A replica 1F1B makespan runs this factor over its healthy twin →
/// compute-slow evidence. Compute faults dilute across the whole pipeline
/// (the weakest library case, a 10% first leak step, lands near 1.10;
/// mild CPU contention near 1.07), so the bar sits much lower than the
/// comm bar — but healthy replicas read exactly 1.0, never near it.
pub const COMPUTE_SLOW_RATIO: f64 = 1.04;

/// How many most-recent trace entries one classification folds over.
pub const WINDOW: usize = 8;

/// Bounded op-trace length: enough for any episode's evidence window with
/// slack for the report's retrospectives, small enough to keep the step
/// loop O(what-changed) in memory too.
pub const TRACE_CAP: usize = 256;

/// The component a diagnosis pins the anomaly on.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Culprit {
    /// A GPU by flat index (node * gpus_per_node + local index).
    Gpu(usize),
    /// A node's host/CPU complex.
    Node(usize),
    /// The inter-node path between two nodes (normalized pair).
    Link(usize, usize),
    /// A node's spine uplink (every path touching the node).
    Uplink(usize),
}

impl Culprit {
    /// Stable textual form pinned by the golden fixtures:
    /// `gpu:2`, `node:0`, `link:1-2`, `uplink:2`.
    pub fn label(&self) -> String {
        match *self {
            Culprit::Gpu(g) => format!("gpu:{g}"),
            Culprit::Node(n) => format!("node:{n}"),
            Culprit::Link(a, b) => format!("link:{}-{}", a.min(b), a.max(b)),
            Culprit::Uplink(u) => format!("uplink:{u}"),
        }
    }
}

/// The four-way hang-vs-slow taxonomy.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum AnomalyClass {
    ComputeSlow,
    CommSlow,
    CommHang,
    SlowMaskingHang,
}

/// Every class, in presentation order (reports iterate this).
pub const CLASSES: [AnomalyClass; 4] = [
    AnomalyClass::ComputeSlow,
    AnomalyClass::CommSlow,
    AnomalyClass::CommHang,
    AnomalyClass::SlowMaskingHang,
];

impl AnomalyClass {
    /// Stable token used in JSON output and the golden fixtures.
    pub fn token(self) -> &'static str {
        match self {
            AnomalyClass::ComputeSlow => "compute-slow",
            AnomalyClass::CommSlow => "comm-slow",
            AnomalyClass::CommHang => "comm-hang",
            AnomalyClass::SlowMaskingHang => "slow-masking-hang",
        }
    }

    /// Human-readable name for rendered reports.
    pub fn name(self) -> &'static str {
        match self {
            AnomalyClass::ComputeSlow => "Compute Slow",
            AnomalyClass::CommSlow => "Communication Slow",
            AnomalyClass::CommHang => "Communication Hang",
            AnomalyClass::SlowMaskingHang => "Slow Masking a Hang",
        }
    }

    /// Hang classes skip S1–S3 and route straight to checkpoint restart.
    pub fn is_hang(self) -> bool {
        matches!(self, AnomalyClass::CommHang | AnomalyClass::SlowMaskingHang)
    }
}

/// One DP gradient ring's evidence at one iteration.
#[derive(Clone, Debug, Default)]
pub struct RingObs {
    /// Pipeline stage whose tp=0 ring this is.
    pub stage: usize,
    /// Worst per-edge nominal-vs-healthy-twin ratio across the ring.
    pub worst_ratio: f64,
    /// Normalized node pairs whose edge ratio ≥ [`COMM_SLOW_RATIO`].
    pub slow: Vec<(usize, usize)>,
    /// Normalized node pairs whose edge is *hung* (blocked, not slow).
    pub blocked: Vec<(usize, usize)>,
}

/// The slowest replica's compute evidence at one iteration.
#[derive(Clone, Debug)]
pub struct ComputeObs {
    /// DP replica index with the worst makespan ratio.
    pub replica: usize,
    /// That replica's 1F1B makespan over its healthy-twin makespan.
    pub ratio: f64,
    /// Telemetry-scan culprit (worst GPU, else worst node CPU) — valid
    /// evidence only when `ratio` clears [`COMPUTE_SLOW_RATIO`].
    pub culprit: Culprit,
}

/// One iteration's collective-level evidence.
#[derive(Clone, Debug)]
pub struct TraceEntry {
    pub iter: usize,
    /// Simulation time the iteration started.
    pub at: Time,
    pub rings: Vec<RingObs>,
    pub compute: ComputeObs,
}

/// Bounded ring buffer of [`TraceEntry`] — the simulator pushes one per
/// iteration (when `enabled`), dropping the oldest past [`TRACE_CAP`].
#[derive(Clone, Debug)]
pub struct OpTrace {
    entries: VecDeque<TraceEntry>,
    /// Tracing switch: the overhead bench flips this off to price the
    /// trace; everything else leaves it on.
    pub enabled: bool,
}

impl Default for OpTrace {
    fn default() -> Self {
        OpTrace { entries: VecDeque::new(), enabled: true }
    }
}

impl OpTrace {
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Append one entry, evicting the oldest once full.
    pub fn push(&mut self, e: TraceEntry) {
        if self.entries.len() >= TRACE_CAP {
            self.entries.pop_front();
        }
        self.entries.push_back(e);
    }

    /// All retained entries, oldest first.
    pub fn entries(&self) -> impl Iterator<Item = &TraceEntry> {
        self.entries.iter()
    }

    /// The most recent `n` entries (newest first).
    pub fn last(&self, n: usize) -> impl Iterator<Item = &TraceEntry> {
        self.entries.iter().rev().take(n)
    }
}

/// One classified episode: class, culprit, and the evidence behind them.
#[derive(Clone, Debug)]
pub struct Classification {
    pub class: AnomalyClass,
    pub culprit: Culprit,
    /// Sim-time span `[first, last]` of the evidence entries folded.
    pub window: (Time, Time),
    /// Worst ring-edge ratio observed in the window.
    pub comm_ratio: f64,
    /// Worst replica makespan ratio observed in the window.
    pub compute_ratio: f64,
}

/// A [`Classification`] stamped with when the coordinator made it.
#[derive(Clone, Debug)]
pub struct EpisodeDiagnosis {
    /// Iteration index the diagnosis was made at.
    pub iter: usize,
    /// Simulation time of the diagnosis.
    pub at: Time,
    pub verdict: Classification,
}

/// Classify the most recent [`WINDOW`] entries of the trace.
///
/// Dominance order mirrors the scenario ground-truth labeling exactly:
/// hang evidence beats slow evidence, comm-slow beats compute-slow. Below
/// every threshold → `None` (transient; the detector will close it).
pub fn classify(trace: &OpTrace) -> Option<Classification> {
    let mut blocked: BTreeSet<(usize, usize)> = BTreeSet::new();
    let mut slow: BTreeSet<(usize, usize)> = BTreeSet::new();
    let mut comm_ratio = 0.0f64;
    let mut compute_ratio = 0.0f64;
    let mut compute_culprit: Option<Culprit> = None;
    let mut t_lo: Option<Time> = None;
    let mut t_hi: Time = 0;
    for e in trace.last(WINDOW) {
        t_lo = Some(t_lo.map_or(e.at, |t| t.min(e.at)));
        t_hi = t_hi.max(e.at);
        for r in &e.rings {
            comm_ratio = comm_ratio.max(r.worst_ratio);
            blocked.extend(r.blocked.iter().copied());
            slow.extend(r.slow.iter().copied());
        }
        if e.compute.ratio > compute_ratio {
            compute_ratio = e.compute.ratio;
            compute_culprit = Some(e.compute.culprit);
        }
    }
    let window = (t_lo?, t_hi);
    let done = |class, culprit| {
        Some(Classification { class, culprit, window, comm_ratio, compute_ratio })
    };
    if !blocked.is_empty() {
        // Hung edges dominate. A hang's own edges still read ratio 1.0
        // (the α–β nominal is computed before the watchdog override), so
        // any slow evidence here is an *independent* fault underneath.
        let masked =
            comm_ratio >= COMM_SLOW_RATIO || compute_ratio >= COMPUTE_SLOW_RATIO;
        let class =
            if masked { AnomalyClass::SlowMaskingHang } else { AnomalyClass::CommHang };
        return done(class, pair_culprit(&blocked)?);
    }
    if comm_ratio >= COMM_SLOW_RATIO {
        return done(AnomalyClass::CommSlow, pair_culprit(&slow)?);
    }
    if compute_ratio >= COMPUTE_SLOW_RATIO {
        return done(AnomalyClass::ComputeSlow, compute_culprit?);
    }
    None
}

/// Pinpoint a component from a set of implicated node pairs: two or more
/// distinct pairs sharing one node indict that node's uplink; a single
/// pair indicts the path itself. (An uplink-wide wedge shows up as both
/// ring edges touching the node; a single bad path shows up alone.)
fn pair_culprit(pairs: &BTreeSet<(usize, usize)>) -> Option<Culprit> {
    let &(a, b) = pairs.iter().next()?;
    if pairs.len() == 1 {
        return Some(if a == b { Culprit::Uplink(a) } else { Culprit::Link(a, b) });
    }
    let mut counts: BTreeMap<usize, usize> = BTreeMap::new();
    for &(x, y) in pairs {
        *counts.entry(x).or_insert(0) += 1;
        if y != x {
            *counts.entry(y).or_insert(0) += 1;
        }
    }
    // Ascending iteration + strict `>` keeps ties on the smallest node.
    let mut best = (usize::MAX, 0usize);
    for (&node, &cnt) in &counts {
        if cnt > best.1 {
            best = (node, cnt);
        }
    }
    if best.1 >= 2 {
        Some(Culprit::Uplink(best.0))
    } else {
        Some(if a == b { Culprit::Uplink(a) } else { Culprit::Link(a, b) })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(iter: usize, rings: Vec<RingObs>, compute: ComputeObs) -> TraceEntry {
        TraceEntry { iter, at: iter as Time * 1_000_000, rings, compute }
    }

    fn healthy_compute() -> ComputeObs {
        ComputeObs { replica: 0, ratio: 1.0, culprit: Culprit::Node(0) }
    }

    fn healthy_ring(stage: usize) -> RingObs {
        RingObs { stage, worst_ratio: 1.0, slow: vec![], blocked: vec![] }
    }

    #[test]
    fn empty_and_healthy_traces_classify_none() {
        let mut t = OpTrace::default();
        assert!(classify(&t).is_none(), "no evidence, no verdict");
        for i in 0..20 {
            t.push(entry(i, vec![healthy_ring(0)], healthy_compute()));
        }
        assert!(classify(&t).is_none(), "all ratios 1.0 stay below every bar");
        assert_eq!(t.len(), 20);
    }

    #[test]
    fn ring_buffer_caps_at_trace_cap() {
        let mut t = OpTrace::default();
        for i in 0..(TRACE_CAP + 50) {
            t.push(entry(i, vec![], healthy_compute()));
        }
        assert_eq!(t.len(), TRACE_CAP);
        let first = t.entries().next().unwrap().iter;
        assert_eq!(first, 50, "oldest entries evicted");
    }

    #[test]
    fn comm_slow_pins_shared_uplink() {
        // Congestion on node 2's uplink slows both ring edges touching it.
        let mut t = OpTrace::default();
        for i in 0..WINDOW {
            let ring = RingObs {
                stage: 0,
                worst_ratio: 2.2,
                slow: vec![(1, 2), (2, 3)],
                blocked: vec![],
            };
            t.push(entry(i, vec![ring], healthy_compute()));
        }
        let c = classify(&t).expect("comm evidence verdicts");
        assert_eq!(c.class, AnomalyClass::CommSlow);
        assert_eq!(c.culprit, Culprit::Uplink(2));
        assert_eq!(c.culprit.label(), "uplink:2");
        assert!(c.comm_ratio >= COMM_SLOW_RATIO);
    }

    #[test]
    fn single_slow_pair_pins_the_link() {
        let mut t = OpTrace::default();
        let ring = RingObs { stage: 0, worst_ratio: 1.9, slow: vec![(0, 1)], blocked: vec![] };
        t.push(entry(0, vec![ring], healthy_compute()));
        let c = classify(&t).unwrap();
        assert_eq!(c.class, AnomalyClass::CommSlow);
        assert_eq!(c.culprit.label(), "link:0-1");
    }

    #[test]
    fn blocked_edge_without_slow_evidence_is_a_pure_hang() {
        let mut t = OpTrace::default();
        let ring = RingObs { stage: 0, worst_ratio: 1.0, slow: vec![], blocked: vec![(1, 2)] };
        t.push(entry(0, vec![ring], healthy_compute()));
        let c = classify(&t).unwrap();
        assert_eq!(c.class, AnomalyClass::CommHang);
        assert!(c.class.is_hang());
        assert_eq!(c.culprit.label(), "link:1-2");
    }

    #[test]
    fn blocked_plus_compute_slow_is_masking() {
        let mut t = OpTrace::default();
        let ring = RingObs { stage: 0, worst_ratio: 1.0, slow: vec![], blocked: vec![(0, 3)] };
        let comp = ComputeObs { replica: 1, ratio: 1.6, culprit: Culprit::Gpu(2) };
        t.push(entry(0, vec![ring], comp));
        let c = classify(&t).unwrap();
        assert_eq!(c.class, AnomalyClass::SlowMaskingHang);
        assert!(c.class.is_hang());
        assert_eq!(c.culprit.label(), "link:0-3", "the hang is the pinned culprit");
        assert!(c.compute_ratio > COMPUTE_SLOW_RATIO, "the masked slow is retained");
    }

    #[test]
    fn uplink_wide_hang_pins_the_common_node() {
        let mut t = OpTrace::default();
        let ring =
            RingObs { stage: 0, worst_ratio: 1.0, slow: vec![], blocked: vec![(1, 2), (2, 3)] };
        t.push(entry(0, vec![ring], healthy_compute()));
        let c = classify(&t).unwrap();
        assert_eq!(c.class, AnomalyClass::CommHang);
        assert_eq!(c.culprit.label(), "uplink:2");
    }

    #[test]
    fn compute_slow_uses_the_telemetry_culprit() {
        let mut t = OpTrace::default();
        for i in 0..4 {
            let comp = ComputeObs { replica: 0, ratio: 1.08, culprit: Culprit::Gpu(3) };
            t.push(entry(i, vec![healthy_ring(0)], comp));
        }
        let c = classify(&t).unwrap();
        assert_eq!(c.class, AnomalyClass::ComputeSlow);
        assert_eq!(c.culprit.label(), "gpu:3");
        assert_eq!(c.window, (0, 3_000_000), "window spans the evidence entries");
    }

    #[test]
    fn window_limits_how_far_back_evidence_reaches() {
        // A hang WINDOW+1 entries ago followed by a healthy tail must not
        // leak into the verdict.
        let mut t = OpTrace::default();
        let ring = RingObs { stage: 0, worst_ratio: 1.0, slow: vec![], blocked: vec![(0, 1)] };
        t.push(entry(0, vec![ring], healthy_compute()));
        for i in 1..=WINDOW {
            t.push(entry(i, vec![healthy_ring(0)], healthy_compute()));
        }
        assert!(classify(&t).is_none(), "stale hang evidence aged out");
    }

    #[test]
    fn class_tokens_and_names_are_stable() {
        let toks: Vec<&str> = CLASSES.iter().map(|c| c.token()).collect();
        assert_eq!(toks, vec!["compute-slow", "comm-slow", "comm-hang", "slow-masking-hang"]);
        assert!(AnomalyClass::SlowMaskingHang.is_hang());
        assert!(!AnomalyClass::CommSlow.is_hang());
        assert_eq!(Culprit::Link(3, 0).label(), "link:0-3", "labels normalize pair order");
        assert_eq!(Culprit::Node(1).label(), "node:1");
    }
}
