//! Monitor shim layer (§4.2's LD_PRELOAD NCCL hook, reproduced in-process).
//!
//! The real FALCON interposes on NCCL calls and logs `(op type, timestamp)`
//! per rank into shared memory. Here, both the simulator and the live
//! trainer call [`Monitor::record`] at exactly the points a hooked NCCL
//! call would fire, producing the same per-rank op timelines — including
//! the recurring per-iteration pattern of Fig 8 — and the per-group
//! transfer timings ("CUDA events") the profiling phase aggregates.
//!
//! Pieces:
//!
//! - [`OpRecord`] / [`RankLog`] — one intercepted call and the bounded
//!   per-rank sliding log of them (capped so an always-on fleet monitor is
//!   O(window), not O(run length)).
//! - [`MonitorMode`] — [`Tracking`](MonitorMode::Tracking) logs op kinds +
//!   timestamps only (the paper's R4 low-overhead requirement, ≤1.1% —
//!   `overhead_frac` models it); [`Profiling`](MonitorMode::Profiling)
//!   additionally times each call, enabled only during the short
//!   diagnosis window.
//! - [`Monitor`] — per-job facade: per-rank logs plus per-group transfer
//!   aggregation ([`Monitor::group_mean_times`]) that the profiling phase turns
//!   into suspicious-group candidates via `detect::profiler`.
//! - [`group_id`] — stable 64-bit id for a rank set, shared with
//!   `detect`'s suspicious-group bookkeeping and the simulator's op log.

use crate::collectives::CollOp;
use crate::simkit::Time;
use std::collections::BTreeMap;

/// One intercepted communication call.
#[derive(Clone, Copy, Debug)]
pub struct OpRecord {
    pub op: CollOp,
    /// Group the call belongs to (opaque id, e.g. hash of member ranks).
    pub group: u64,
    /// Call issue timestamp.
    pub at: Time,
    /// Measured duration (the injected CUDA-event pair of the profiling
    /// phase). Zero when profiling is disabled — tracking only needs `at`.
    pub dur: Time,
}

/// Per-rank sliding log of communication calls.
#[derive(Clone, Debug, Default)]
pub struct RankLog {
    pub ops: Vec<OpRecord>,
    cap: usize,
}

impl RankLog {
    pub fn with_capacity(cap: usize) -> Self {
        RankLog { ops: Vec::new(), cap }
    }

    pub fn push(&mut self, rec: OpRecord) {
        self.ops.push(rec);
        if self.cap > 0 && self.ops.len() > self.cap {
            let excess = self.ops.len() - self.cap;
            self.ops.drain(..excess);
        }
    }

    /// Timestamps only — the tracking phase's input.
    pub fn timestamps(&self) -> Vec<Time> {
        self.ops.iter().map(|o| o.at).collect()
    }

    /// Op-kind sequence as small integers (ACF input signal).
    pub fn op_kinds(&self) -> Vec<f64> {
        self.ops
            .iter()
            .map(|o| match o.op {
                CollOp::AllReduce => 1.0,
                CollOp::ReduceScatter => 2.0,
                CollOp::AllGather => 3.0,
                CollOp::Send => 4.0,
                CollOp::Recv => 5.0,
                CollOp::Broadcast => 6.0,
            })
            .collect()
    }
}

/// Whether the shim is additionally timing each call (profiling phase).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MonitorMode {
    /// Track call types + timestamps only (negligible overhead).
    Tracking,
    /// Also inject CUDA-event timing per call (profiling phase, §4.3).
    Profiling,
}

/// The per-job monitor: one log per rank plus per-group transfer-time
/// aggregation used to find suspicious groups.
#[derive(Clone, Debug)]
pub struct Monitor {
    pub mode: MonitorMode,
    pub logs: Vec<RankLog>,
    /// group id -> accumulated (transfer time, call count) this window.
    /// BTreeMap so aggregation order (and any downstream tie-break) is
    /// deterministic — see the digest-determinism audit rule.
    group_time: BTreeMap<u64, (f64, u64)>,
    /// Fractional per-call overhead the shim itself adds (Fig 18 measures
    /// this end to end; the constant is calibrated to the paper's <=1.1%).
    pub overhead_frac: f64,
}

impl Monitor {
    pub fn new(n_ranks: usize, per_rank_cap: usize) -> Self {
        Monitor {
            mode: MonitorMode::Tracking,
            logs: (0..n_ranks).map(|_| RankLog::with_capacity(per_rank_cap)).collect(),
            group_time: BTreeMap::new(),
            overhead_frac: 0.0039, // 0.39% mean overhead (§7.4)
        }
    }

    pub fn set_mode(&mut self, mode: MonitorMode) {
        self.mode = mode;
        if mode == MonitorMode::Profiling {
            self.group_time.clear();
        }
    }

    /// Record an intercepted call on `rank`. `dur` is honored only in
    /// profiling mode (tracking never measures durations — R4).
    pub fn record(&mut self, rank: usize, op: CollOp, group: u64, at: Time, dur: Time) {
        let dur = if self.mode == MonitorMode::Profiling { dur } else { 0 };
        self.logs[rank].push(OpRecord { op, group, at, dur });
        if self.mode == MonitorMode::Profiling {
            let e = self.group_time.entry(group).or_insert((0.0, 0));
            e.0 += dur as f64 / 1e6;
            e.1 += 1;
        }
    }

    /// Mean transfer time per call for each group observed while profiling.
    pub fn group_mean_times(&self) -> Vec<(u64, f64)> {
        // BTreeMap iteration is already key-sorted, so the output order
        // is stable without an explicit sort.
        self.group_time
            .iter()
            .map(|(&g, &(t, n))| (g, if n > 0 { t / n as f64 } else { 0.0 }))
            .collect()
    }

    pub fn clear_profile(&mut self) {
        self.group_time.clear();
    }
}

/// Stable id for a group from its member ranks (FNV-1a).
pub fn group_id(ranks: &[usize]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &r in ranks {
        h ^= r as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simkit::SEC;

    #[test]
    fn tracking_mode_drops_durations() {
        let mut m = Monitor::new(2, 0);
        m.record(0, CollOp::AllReduce, 1, SEC, 500);
        assert_eq!(m.logs[0].ops[0].dur, 0);
        m.set_mode(MonitorMode::Profiling);
        m.record(0, CollOp::AllReduce, 1, 2 * SEC, 500);
        assert_eq!(m.logs[0].ops[1].dur, 500);
    }

    #[test]
    fn group_means_aggregate() {
        let mut m = Monitor::new(1, 0);
        m.set_mode(MonitorMode::Profiling);
        m.record(0, CollOp::AllReduce, 7, 0, 2_000_000);
        m.record(0, CollOp::AllReduce, 7, SEC, 4_000_000);
        m.record(0, CollOp::Send, 9, 0, 1_000_000);
        let means = m.group_mean_times();
        assert_eq!(means.len(), 2);
        let g7 = means.iter().find(|&&(g, _)| g == 7).unwrap().1;
        assert!((g7 - 3.0).abs() < 1e-9);
    }

    #[test]
    fn log_capacity_bounds_memory() {
        let mut log = RankLog::with_capacity(10);
        for i in 0..100 {
            log.push(OpRecord { op: CollOp::Send, group: 0, at: i, dur: 0 });
        }
        assert_eq!(log.ops.len(), 10);
        assert_eq!(log.ops[0].at, 90);
    }

    #[test]
    fn group_ids_distinct_and_stable() {
        let a = group_id(&[0, 2, 4, 6]);
        let b = group_id(&[0, 2, 4, 8]);
        assert_ne!(a, b);
        assert_eq!(a, group_id(&[0, 2, 4, 6]));
    }

    #[test]
    fn op_kind_signal_periodicity() {
        // 4-op iteration pattern must autocorrelate at lag 4 (Fig 8).
        let mut log = RankLog::with_capacity(0);
        for i in 0..256u64 {
            let op = [CollOp::AllReduce, CollOp::Send, CollOp::Recv, CollOp::AllGather]
                [(i % 4) as usize];
            log.push(OpRecord { op, group: 0, at: i, dur: 0 });
        }
        let sig = log.op_kinds();
        assert!(crate::util::stats::acf(&sig, 4) > 0.95);
        assert!(crate::util::stats::acf(&sig, 3) < 0.8);
    }
}
