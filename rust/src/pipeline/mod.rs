//! Hybrid-parallelism substrate: rank grid, communication groups, the
//! transformer cost model (paper Appendix 9.2), and the 1F1B pipeline
//! iteration-time model.
//!
//! This is the "Megatron-LM" the simulator trains with: given a parallel
//! strategy (T, D, P), a model size, and the current cluster health, it
//! computes per-replica microbatch times, the pipeline makespan, collective
//! times, and the end-to-end iteration time — and emits the per-rank
//! communication-op timeline FALCON-DETECT observes.

use crate::fabric::{Cluster, GpuId};

pub mod schedule;
pub use schedule::{one_f1b_makespan, one_f1b_makespan_scratch, MakespanScratch, StageTimes};

/// Parallel strategy: (TP, DP, PP) sizes. Written xTyDzP in the paper.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ParallelConfig {
    pub tp: usize,
    pub dp: usize,
    pub pp: usize,
}

impl ParallelConfig {
    pub fn new(tp: usize, dp: usize, pp: usize) -> Self {
        ParallelConfig { tp, dp, pp }
    }

    pub fn world(&self) -> usize {
        self.tp * self.dp * self.pp
    }

    pub fn label(&self) -> String {
        format!("{}T{}D{}P", self.tp, self.dp, self.pp)
    }
}

/// Global rank coordinates. Megatron ordering: TP fastest (contiguous, so TP
/// stays intra-node), then DP, then PP (stages span nodes).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct RankCoord {
    pub tp: usize,
    pub dp: usize,
    pub pp: usize,
}

/// Maps ranks onto cluster GPUs, with a mutable node permutation so
/// FALCON-MITIGATE's topology adjustment (S3) can swap nodes.
#[derive(Clone, Debug)]
pub struct RankGrid {
    pub cfg: ParallelConfig,
    pub gpus_per_node: usize,
    /// node_map[i] = physical node hosting "logical node" i. S3 permutes it.
    pub node_map: Vec<usize>,
    /// Placement generation: bumped by [`RankGrid::swap_nodes`] so
    /// placement-derived caches (see `crate::sim`) know to rebuild.
    generation: u64,
}

impl RankGrid {
    pub fn new(cfg: ParallelConfig, gpus_per_node: usize) -> Self {
        let nodes = cfg.world().div_ceil(gpus_per_node);
        RankGrid { cfg, gpus_per_node, node_map: (0..nodes).collect(), generation: 0 }
    }

    /// Monotone counter of node-map permutations applied so far.
    pub fn generation(&self) -> u64 {
        self.generation
    }

    pub fn n_nodes(&self) -> usize {
        self.node_map.len()
    }

    pub fn rank_of(&self, c: RankCoord) -> usize {
        c.pp * (self.cfg.dp * self.cfg.tp) + c.dp * self.cfg.tp + c.tp
    }

    pub fn coord_of(&self, rank: usize) -> RankCoord {
        let tp = rank % self.cfg.tp;
        let dp = (rank / self.cfg.tp) % self.cfg.dp;
        let pp = rank / (self.cfg.tp * self.cfg.dp);
        RankCoord { tp, dp, pp }
    }

    /// Physical GPU hosting a global rank, via the (mutable) node map.
    pub fn gpu_of(&self, rank: usize) -> GpuId {
        let logical_node = rank / self.gpus_per_node;
        let index = rank % self.gpus_per_node;
        GpuId { node: self.node_map[logical_node], index }
    }

    pub fn gpu_of_coord(&self, c: RankCoord) -> GpuId {
        self.gpu_of(self.rank_of(c))
    }

    /// All ranks in the TP group of (dp, pp).
    pub fn tp_group(&self, dp: usize, pp: usize) -> Vec<usize> {
        (0..self.cfg.tp).map(|tp| self.rank_of(RankCoord { tp, dp, pp })).collect()
    }

    /// All ranks in the DP group of (tp, pp) — the gradient all-reduce ring.
    pub fn dp_group(&self, tp: usize, pp: usize) -> Vec<usize> {
        (0..self.cfg.dp).map(|dp| self.rank_of(RankCoord { tp, dp, pp })).collect()
    }

    /// All ranks in the PP group (pipeline) of (tp, dp).
    pub fn pp_group(&self, tp: usize, dp: usize) -> Vec<usize> {
        (0..self.cfg.pp).map(|pp| self.rank_of(RankCoord { tp, dp, pp })).collect()
    }

    /// Swap two logical nodes' physical hosts (S3 topology adjustment).
    pub fn swap_nodes(&mut self, a: usize, b: usize) {
        self.node_map.swap(a, b);
        self.generation = self.generation.wrapping_add(1);
    }
}

/// Transformer size parameters (Appendix 9.2 notation).
#[derive(Clone, Copy, Debug)]
pub struct ModelDims {
    pub layers: usize,   // L
    pub hidden: usize,   // h
    pub heads: usize,    // n_h
    pub vocab: usize,    // v
    pub ctx: usize,      // n_ctx (tokens per sample)
}

impl ModelDims {
    /// N ≈ 12 L h² (Eq. 6).
    pub fn n_params(&self) -> f64 {
        let (l, h) = (self.layers as f64, self.hidden as f64);
        let d = (self.hidden / self.heads) as f64;
        let per_layer = 4.0 * d * self.heads as f64 + 8.0 * h + 5.0;
        h * (self.vocab as f64 + self.ctx as f64 + l * per_layer)
    }

    /// Training FLOPs per token ≈ 6 N (fwd+bwd).
    pub fn flops_per_token(&self) -> f64 {
        6.0 * self.n_params()
    }

    /// GPT-2 presets used by the paper's sampling jobs and evaluation.
    pub fn gpt2(name: &str) -> ModelDims {
        match name {
            "gpt2-7b" => ModelDims { layers: 32, hidden: 4096, heads: 32, vocab: 50257, ctx: 2048 },
            "gpt2-11b" => {
                ModelDims { layers: 40, hidden: 4736, heads: 37, vocab: 50257, ctx: 2048 }
            }
            "gpt2-13b" => {
                ModelDims { layers: 40, hidden: 5120, heads: 40, vocab: 50257, ctx: 2048 }
            }
            // audit:allow(panic-budget): preset names are compile-time
            // literals in reports/presets; an unknown name is a typo to
            // surface immediately, not a runtime condition.
            _ => panic!("unknown model {name}"),
        }
    }
}

/// Per-iteration workload: global batch split into micro-batches.
#[derive(Clone, Copy, Debug)]
pub struct Workload {
    pub model: ModelDims,
    /// Micro-batch size b (samples).
    pub micro_batch: usize,
    /// Micro-batches per DP replica per iteration (m), before S2 rebalance.
    pub microbatches: usize,
}

impl Workload {
    /// Eq. 8: TP volume per microbatch per stage (bytes, bf16 activations).
    pub fn tp_bytes_per_microbatch(&self, cfg: ParallelConfig) -> f64 {
        if cfg.tp == 1 {
            return 0.0;
        }
        let m = &self.model;
        let b = self.micro_batch as f64;
        8.0 * b * m.ctx as f64 * m.hidden as f64 * (m.layers as f64 / cfg.pp as f64)
            * ((cfg.tp - 1) as f64 / cfg.tp as f64)
            * 2.0 // bytes per bf16 element
    }

    /// Eq. 9: DP gradient volume per rank per iteration (bytes, f32 grads).
    pub fn dp_bytes(&self, cfg: ParallelConfig) -> f64 {
        self.model.n_params() / (cfg.pp * cfg.tp) as f64 * 4.0
    }

    /// Eq. 10: PP activation volume per microbatch (bytes).
    pub fn pp_bytes_per_microbatch(&self) -> f64 {
        let m = &self.model;
        self.micro_batch as f64 * m.ctx as f64 * m.hidden as f64 * 2.0
    }

    /// Compute FLOPs per microbatch per pipeline stage per TP shard
    /// (fwd + bwd, bwd counted at 2x fwd).
    pub fn flops_per_microbatch_per_stage(&self, cfg: ParallelConfig) -> f64 {
        let tokens = (self.micro_batch * self.model.ctx) as f64;
        tokens * self.model.flops_per_token() / (cfg.pp * cfg.tp) as f64
    }
}

/// Compute + host time (seconds) for one microbatch (fwd+bwd) on the TP
/// group of (dp, pp), at current cluster health. The TP group advances at
/// the pace of its slowest member (synchronous tensor parallelism), and CPU
/// contention on the hosting node adds per-microbatch host overhead.
pub fn microbatch_time_s(
    cluster: &Cluster,
    grid: &RankGrid,
    wl: &Workload,
    dp: usize,
    pp: usize,
    mfu: f64,
) -> f64 {
    let flops = wl.flops_per_microbatch_per_stage(grid.cfg);
    let mut worst = 0.0f64;
    // Walk the TP group by coordinate (same order as `tp_group`) instead of
    // materializing the rank vector: this sits inside the simulator's
    // per-replica recompute path, where the allocations used to dominate.
    for tp in 0..grid.cfg.tp {
        let gpu = grid.gpu_of(grid.rank_of(RankCoord { tp, dp, pp }));
        let rate = cluster.gpu_rate(gpu) * mfu;
        let compute = flops / rate;
        // Host-side launch/dataloading overhead: ~6% of nominal compute,
        // inflated by CPU contention (Fig 2's mechanism).
        let node = &cluster.nodes[gpu.node];
        let host = 0.06 * flops / (cluster.spec.gpu_class.tflops() * 1e12 * mfu)
            / node.cpu_satisfaction.max(0.05);
        // TP collective per microbatch (intra-node, stable).
        let tp_comm = if grid.cfg.tp > 1 {
            let nbytes = wl.tp_bytes_per_microbatch(grid.cfg) / wl.microbatches.max(1) as f64;
            let next_tp = (tp + 1) % grid.cfg.tp;
            let peer = grid.gpu_of(grid.rank_of(RankCoord { tp: next_tp, dp, pp }));
            cluster.transfer_time_nominal_s(gpu, peer, nbytes)
        } else {
            0.0
        };
        worst = worst.max(compute + host + tp_comm);
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::{ClusterSpec, GpuClass};

    #[test]
    fn rank_round_trip() {
        let grid = RankGrid::new(ParallelConfig::new(2, 4, 2), 8);
        for rank in 0..16 {
            assert_eq!(grid.rank_of(grid.coord_of(rank)), rank);
        }
    }

    #[test]
    fn tp_groups_are_contiguous() {
        let grid = RankGrid::new(ParallelConfig::new(4, 2, 2), 8);
        let g = grid.tp_group(1, 0);
        assert_eq!(g, vec![4, 5, 6, 7]);
        // Contiguous => same node when tp <= gpus_per_node.
        let nodes: Vec<usize> = g.iter().map(|&r| grid.gpu_of(r).node).collect();
        assert!(nodes.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    fn dp_group_strides_tp() {
        let grid = RankGrid::new(ParallelConfig::new(2, 4, 1), 8);
        assert_eq!(grid.dp_group(0, 0), vec![0, 2, 4, 6]);
        assert_eq!(grid.dp_group(1, 0), vec![1, 3, 5, 7]);
    }

    #[test]
    fn pp_group_strides_dp_tp() {
        let grid = RankGrid::new(ParallelConfig::new(2, 2, 4), 4);
        assert_eq!(grid.pp_group(0, 0), vec![0, 4, 8, 12]);
    }

    #[test]
    fn groups_partition_world() {
        // Every rank belongs to exactly one TP group, one DP group, one PP group.
        let grid = RankGrid::new(ParallelConfig::new(2, 4, 2), 8);
        let mut seen = vec![0u32; grid.cfg.world()];
        for dp in 0..4 {
            for pp in 0..2 {
                for r in grid.tp_group(dp, pp) {
                    seen[r] += 1;
                }
            }
        }
        assert!(seen.iter().all(|&c| c == 1));
    }

    #[test]
    fn node_swap_remaps_gpus() {
        let mut grid = RankGrid::new(ParallelConfig::new(2, 4, 2), 4);
        assert_eq!(grid.gpu_of(0).node, 0);
        grid.swap_nodes(0, 3);
        assert_eq!(grid.gpu_of(0).node, 3);
        assert_eq!(grid.gpu_of(15).node, 0);
    }

    #[test]
    fn param_count_matches_12lh2_scale() {
        let m = ModelDims::gpt2("gpt2-13b");
        let approx = 12.0 * m.layers as f64 * (m.hidden as f64).powi(2);
        let exact = m.n_params();
        assert!((exact / approx - 1.0).abs() < 0.15, "{exact} vs {approx}");
        assert!(exact > 12.5e9 && exact < 14.5e9, "13B-class: {exact}");
    }

    #[test]
    fn comm_volume_ordering() {
        // Appendix: Comm_TP ≫ Comm_DP ≫ Comm_PP per iteration at scale.
        let wl = Workload {
            model: ModelDims::gpt2("gpt2-13b"),
            micro_batch: 1,
            microbatches: 8,
        };
        let cfg = ParallelConfig::new(8, 16, 4);
        let tp_iter = wl.tp_bytes_per_microbatch(cfg) * wl.microbatches as f64;
        let dp_iter = wl.dp_bytes(cfg);
        let pp_iter = wl.pp_bytes_per_microbatch() * wl.microbatches as f64;
        assert!(tp_iter > dp_iter, "tp {tp_iter} dp {dp_iter}");
        assert!(dp_iter > pp_iter, "dp {dp_iter} pp {pp_iter}");
    }

    #[test]
    fn slow_gpu_slows_microbatch() {
        let mut cluster = Cluster::new(ClusterSpec::new(2, 4, GpuClass::H800));
        let grid = RankGrid::new(ParallelConfig::new(2, 2, 2), 4);
        let wl = Workload { model: ModelDims::gpt2("gpt2-7b"), micro_batch: 1, microbatches: 4 };
        let healthy = microbatch_time_s(&cluster, &grid, &wl, 0, 0, 0.4);
        cluster.gpus[0].compute_scale = 0.5;
        let degraded = microbatch_time_s(&cluster, &grid, &wl, 0, 0, 0.4);
        assert!(degraded > 1.4 * healthy, "{degraded} vs {healthy}");
        // Other DP replica untouched.
        let other = microbatch_time_s(&cluster, &grid, &wl, 1, 0, 0.4);
        assert!((other - healthy).abs() / healthy < 1e-9);
    }

    #[test]
    fn cpu_contention_slows_microbatch() {
        let mut cluster = Cluster::new(ClusterSpec::new(1, 4, GpuClass::H800));
        let grid = RankGrid::new(ParallelConfig::new(2, 2, 1), 4);
        let wl = Workload { model: ModelDims::gpt2("gpt2-7b"), micro_batch: 1, microbatches: 4 };
        let healthy = microbatch_time_s(&cluster, &grid, &wl, 0, 0, 0.4);
        cluster.nodes[0].cpu_satisfaction = 0.3;
        let contended = microbatch_time_s(&cluster, &grid, &wl, 0, 0, 0.4);
        assert!(contended > 1.05 * healthy, "{contended} vs {healthy}");
    }
}
