//! 1F1B pipeline schedule model.
//!
//! Megatron's 1F1B (one-forward-one-backward) schedule: with P stages and m
//! micro-batches per replica, the steady state interleaves one forward and
//! one backward per stage; warm-up fills the pipeline and cool-down drains
//! it. Iteration compute time for a replica is the pipeline makespan given
//! per-stage microbatch times — which vary per stage when stragglers are
//! present (Fig 11's consolidation analysis relies on exactly this).

/// Per-stage fwd/bwd microbatch times (seconds) for one DP replica.
#[derive(Clone, Debug)]
pub struct StageTimes {
    /// fwd[i] = forward time of one microbatch on stage i.
    pub fwd: Vec<f64>,
    /// bwd[i] = backward time of one microbatch on stage i.
    pub bwd: Vec<f64>,
    /// p2p[i] = activation transfer time from stage i to i+1 (len P-1).
    pub p2p: Vec<f64>,
}

impl StageTimes {
    /// Uniform stages: fwd = t, bwd = 2t (the usual fwd:bwd ratio).
    pub fn uniform(p: usize, fwd: f64, p2p: f64) -> StageTimes {
        StageTimes {
            fwd: vec![fwd; p],
            bwd: vec![2.0 * fwd; p],
            p2p: vec![p2p; p.saturating_sub(1)],
        }
    }
}

/// Reusable buffers for [`one_f1b_makespan_scratch`]: the four p×m
/// completion/readiness matrices. The simulator's cache layer keeps one of
/// these per job so steady-state recomputes allocate nothing.
#[derive(Clone, Debug, Default)]
pub struct MakespanScratch {
    f_done: Vec<Vec<f64>>,
    b_done: Vec<Vec<f64>>,
    ready_f: Vec<Vec<f64>>,
    ready_b: Vec<Vec<f64>>,
}

/// Size `buf` to exactly p rows of m zeros (the makespan recurrence's
/// initial state), reusing row allocations across calls.
fn reset(buf: &mut Vec<Vec<f64>>, p: usize, m: usize) {
    buf.resize_with(p, Vec::new);
    for row in buf.iter_mut() {
        row.clear();
        row.resize(m, 0.0);
    }
}

/// Makespan (seconds) of a 1F1B iteration with `m` micro-batches.
///
/// Exact discrete-event evaluation: simulates the 1F1B order per stage
/// rather than using the closed-form `(m-1 + p) * t` approximation, so
/// heterogeneous (straggling) stages are handled correctly — the paper's
/// Fig 11 iteration times (8s vs 8.5s) come out of exactly this recurrence.
pub fn one_f1b_makespan(st: &StageTimes, m: usize) -> f64 {
    one_f1b_makespan_scratch(st, m, &mut MakespanScratch::default())
}

/// [`one_f1b_makespan`] with caller-owned scratch buffers (bit-identical
/// result; the hot path reuses them instead of reallocating per call).
pub fn one_f1b_makespan_scratch(st: &StageTimes, m: usize, scratch: &mut MakespanScratch) -> f64 {
    let p = st.fwd.len();
    assert!(p >= 1 && m >= 1);
    assert_eq!(st.bwd.len(), p);
    assert_eq!(st.p2p.len(), p - 1);

    // f_done[s][j] = completion time of forward microbatch j on stage s.
    // b_done[s][j] = completion time of backward microbatch j on stage s.
    reset(&mut scratch.f_done, p, m);
    reset(&mut scratch.b_done, p, m);
    let f_done = &mut scratch.f_done;
    let b_done = &mut scratch.b_done;

    // Number of warm-up forwards per stage in 1F1B: min(p - s, m).
    let warmup = |s: usize| (p - s).min(m);

    // Evaluate stage by stage for forward deps, but backward deps flow in
    // reverse; iterate until fixpoint via the natural topological order:
    // process events in the canonical 1F1B per-stage sequence, tracking
    // stage-local time cursors.
    //
    // Each stage executes: warmup(s) forwards, then alternating (bwd, fwd)
    // in steady state, then the remaining backwards.
    reset(&mut scratch.ready_f, p, m); // activation arrival from s-1
    reset(&mut scratch.ready_b, p, m); // grad arrival from s+1
    let ready_f = &mut scratch.ready_f;
    let ready_b = &mut scratch.ready_b;

    // Iterate a few sweeps: dependencies are acyclic in (microbatch, phase)
    // but stage-local ordering couples forward and backward; a fixed small
    // number of sweeps reaches the fixpoint because the schedule's order is
    // deterministic. We instead compute directly with an event-accurate
    // per-stage simulation honoring cross-stage readiness, repeated until
    // stable.
    for _sweep in 0..(2 * p + 2) {
        for s in 0..p {
            let w = warmup(s);
            let mut cursor = 0.0f64;
            let mut next_f = 0usize;
            let mut next_b = 0usize;
            // Phase 1: warm-up forwards.
            while next_f < w {
                let start = cursor.max(ready_f[s][next_f]);
                cursor = start + st.fwd[s];
                f_done[s][next_f] = cursor;
                next_f += 1;
            }
            // Phase 2: steady 1F1B — backward for the oldest unfinished
            // microbatch, then (if any remain) one more forward.
            while next_b < m {
                let start = cursor.max(ready_b[s][next_b]);
                cursor = start + st.bwd[s];
                b_done[s][next_b] = cursor;
                next_b += 1;
                if next_f < m {
                    let start = cursor.max(ready_f[s][next_f]);
                    cursor = start + st.fwd[s];
                    f_done[s][next_f] = cursor;
                    next_f += 1;
                }
            }
        }
        // Propagate readiness for the next sweep.
        for s in 0..p {
            for j in 0..m {
                ready_f[s][j] = if s == 0 { 0.0 } else { f_done[s - 1][j] + st.p2p[s - 1] };
                ready_b[s][j] =
                    if s == p - 1 { f_done[s][j] } else { b_done[s + 1][j] + st.p2p[s] };
            }
        }
    }

    b_done[0].iter().copied().fold(0.0, f64::max)
}

/// Closed-form approximation for uniform stages (used in tests as an oracle
/// and by planners that need a fast estimate):
/// T ≈ (m - 1) * (f + b) + p * (f + b)  [warm-up + drain + steady state]
pub fn uniform_makespan_approx(p: usize, m: usize, fwd: f64) -> f64 {
    let fb = 3.0 * fwd; // fwd + 2*fwd
    (m - 1) as f64 * fb + p as f64 * fb
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_stage_is_sequential() {
        let st = StageTimes::uniform(1, 1.0, 0.0);
        // P=1: m forwards + m backwards, no overlap possible.
        let t = one_f1b_makespan(&st, 4);
        assert!((t - 4.0 * 3.0).abs() < 1e-9, "{t}");
    }

    #[test]
    fn matches_uniform_closed_form() {
        for (p, m) in [(2, 4), (4, 8), (4, 16), (8, 16)] {
            let st = StageTimes::uniform(p, 1.0, 0.0);
            let exact = one_f1b_makespan(&st, m);
            let approx = uniform_makespan_approx(p, m, 1.0);
            let rel = (exact - approx).abs() / approx;
            assert!(rel < 0.15, "p={p} m={m}: exact {exact} approx {approx}");
        }
    }

    #[test]
    fn more_microbatches_amortize_bubble() {
        let st = StageTimes::uniform(4, 1.0, 0.0);
        let t8 = one_f1b_makespan(&st, 8) / 8.0;
        let t32 = one_f1b_makespan(&st, 32) / 32.0;
        assert!(t32 < t8, "per-microbatch cost must drop: {t32} vs {t8}");
    }

    #[test]
    fn slow_stage_dominates() {
        // One straggling stage sets the steady-state rhythm.
        let mut st = StageTimes::uniform(4, 1.0, 0.0);
        st.fwd[2] = 2.0;
        st.bwd[2] = 4.0;
        let slow = one_f1b_makespan(&st, 16);
        let base = one_f1b_makespan(&StageTimes::uniform(4, 1.0, 0.0), 16);
        assert!(slow > 1.5 * base, "{slow} vs {base}");
    }

    #[test]
    fn fig11_consolidation_shape() {
        // Paper Fig 11: two stragglers in ONE stage cost less than the same
        // two spread across TWO stages.
        let m = 8;
        // "straggler" multiplies a stage's time by 1.5 (each straggling GPU
        // slows its whole stage to the straggler pace).
        let mut consolidated = StageTimes::uniform(4, 1.0, 0.0);
        consolidated.fwd[1] *= 1.5;
        consolidated.bwd[1] *= 1.5;

        let mut scattered = StageTimes::uniform(4, 1.0, 0.0);
        for s in [1, 2] {
            scattered.fwd[s] *= 1.5;
            scattered.bwd[s] *= 1.5;
        }
        let t_cons = one_f1b_makespan(&consolidated, m);
        let t_scat = one_f1b_makespan(&scattered, m);
        assert!(
            t_scat > t_cons,
            "scattered {t_scat} must exceed consolidated {t_cons}"
        );
    }

    #[test]
    fn p2p_latency_extends_warmup() {
        let fast = one_f1b_makespan(&StageTimes::uniform(4, 1.0, 0.0), 8);
        let slow = one_f1b_makespan(&StageTimes::uniform(4, 1.0, 0.5), 8);
        assert!(slow > fast);
    }

    #[test]
    fn scratch_reuse_is_bit_identical() {
        let mut scratch = MakespanScratch::default();
        // Reuse one scratch across growing AND shrinking shapes: stale rows
        // must never leak into a later evaluation.
        for (p, m) in [(4usize, 8usize), (2, 4), (8, 16), (1, 3), (4, 8)] {
            let mut st = StageTimes::uniform(p, 1.0, 0.1);
            if p > 2 {
                st.fwd[1] *= 1.7;
                st.bwd[1] *= 1.7;
            }
            let fresh = one_f1b_makespan(&st, m);
            let reused = one_f1b_makespan_scratch(&st, m, &mut scratch);
            assert_eq!(fresh.to_bits(), reused.to_bits(), "p={p} m={m}");
        }
    }

    #[test]
    fn makespan_monotone_in_stage_time() {
        let base = one_f1b_makespan(&StageTimes::uniform(4, 1.0, 0.1), 8);
        for s in 0..4 {
            let mut st = StageTimes::uniform(4, 1.0, 0.1);
            st.fwd[s] *= 1.3;
            st.bwd[s] *= 1.3;
            assert!(one_f1b_makespan(&st, 8) > base, "stage {s}");
        }
    }
}
