//! Fleet engine: thousands of concurrent FALCON-supervised jobs, sharded
//! across worker threads.
//!
//! The paper frames fail-slow handling as a *fleet* problem — hundreds of
//! concurrent jobs on a shared 10,000+-GPU cluster, each continuously
//! watched by an always-on detector (R2). This module scales the
//! single-job reproduction to that setting:
//!
//! - **Jobs.** Each fleet job is an independent `TrainingSim` +
//!   [`crate::coordinator::Falcon`] pair with a heterogeneous spec
//!   (parallel strategy, model size, GPU class, jitter profile) drawn
//!   deterministically from the fleet seed, plus a per-job fail-slow mix
//!   sampled from the §3-calibrated [`InjectionModel`].
//!
//! - **Sharding model.** A fixed pool of `std::thread` workers pulls job
//!   ids from a shared atomic counter (work-stealing by index, no
//!   per-worker queues, no load-balancing heuristics — jobs are coarse
//!   enough that the counter is never contended). Results land in a
//!   slot-per-job vector, so aggregation order is by job id regardless of
//!   which worker ran what. Per-job state is fully owned by the worker
//!   running it; nothing is shared between jobs but the immutable config.
//!
//! - **Determinism.** Job `i` derives every random stream from
//!   `(fleet_seed, i)` — spec, injections, simulator noise — so the fleet
//!   report is bit-identical for a fixed seed across runs *and across
//!   worker counts*. [`FleetReport::digest`] fingerprints the per-job
//!   results to make that property testable.
//!
//! - **Bounded memory.** The per-job detector holds O(VERIFY_WINDOW)
//!   samples (a fixed ring, see `detect::detector`) and a capped BOCD
//!   hypothesis set, so fleet memory is O(jobs), not O(jobs × iterations)
//!   — the prerequisite for an always-on fleet campaign.
//!
//! The cross-job aggregator pools episode counts, detection-latency
//! percentiles (verified onset time minus injected onset time) and the
//! mitigated-vs-ignored throughput delta (each injected job optionally
//! re-run with `mitigate: false` on the identical trace).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::coordinator::{run_with_falcon, FalconConfig};
use crate::fabric::GpuClass;
use crate::inject::InjectionModel;
use crate::metrics::LatencySummary;
use crate::pipeline::{ModelDims, ParallelConfig, Workload};
use crate::sim::{JobSpec, TrainingSim};
use crate::simkit::{from_secs, secs, MINUTE};
use crate::util::plot;
use crate::util::rng::Rng;

/// Fleet campaign configuration.
#[derive(Clone, Debug)]
pub struct FleetConfig {
    /// Number of concurrent jobs.
    pub jobs: usize,
    /// Iterations each job trains for.
    pub iters: usize,
    /// Master seed; everything derives from `(seed, job_id)`.
    pub seed: u64,
    /// Worker threads (0 = one per available core).
    pub workers: usize,
    /// Multiplier on the §3 per-job fail-slow probabilities. 1.0 reproduces
    /// the paper's (sparse) campaign rates; the default oversamples so a
    /// moderate fleet still exercises the whole detect→mitigate path.
    pub failslow_boost: f64,
    /// Re-run each injected job with mitigation disabled on the identical
    /// trace, for the mitigated-vs-ignored throughput delta.
    pub compare: bool,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            jobs: 512,
            iters: 120,
            seed: 2024,
            workers: 0,
            failslow_boost: 8.0,
            compare: true,
        }
    }
}

/// Outcome of one fleet job.
#[derive(Clone, Debug)]
pub struct JobResult {
    pub job_id: usize,
    /// Parallel strategy label, e.g. "2T4D1P".
    pub label: String,
    pub world: usize,
    /// Injected fail-slow events.
    pub injected: usize,
    /// Verified episodes the detector opened.
    pub episodes_detected: usize,
    /// Whether the job was flagged fail-slow (>= 1 verified episode).
    pub flagged: bool,
    /// Seconds from injected onset to verified onset, per matched episode.
    pub detection_latency_s: Vec<f64>,
    /// Healthy-cluster throughput (iters/s) with even allocation.
    pub ideal_thpt: f64,
    /// Mean throughput of the mitigated run.
    pub mean_thpt: f64,
    /// Mean throughput of the ignore-mode re-run (compare mode, injected
    /// jobs only).
    pub ignored_thpt: Option<f64>,
}

/// Aggregated fleet campaign report.
#[derive(Clone, Debug)]
pub struct FleetReport {
    pub jobs: usize,
    pub workers: usize,
    pub iters: usize,
    /// Total simulated GPUs across the fleet.
    pub gpus: usize,
    pub jobs_with_failslow: usize,
    pub jobs_flagged: usize,
    /// Flagged with nothing injected.
    pub false_positives: usize,
    /// Injected but never flagged.
    pub missed: usize,
    pub episodes_injected: usize,
    pub episodes_detected: usize,
    pub latency: LatencySummary,
    /// Mean of (ideal / achieved) throughput across the fleet.
    pub mean_slowdown: f64,
    /// Mean mitigated/ignored throughput ratio over compared jobs (1.0 when
    /// nothing was compared).
    pub mitigated_over_ignored: f64,
    pub compared_jobs: usize,
    pub wall_s: f64,
    pub jobs_per_sec: f64,
    pub results: Vec<JobResult>,
}

/// Heterogeneous job palette: small 1–2-node strategies (the fleet's bread
/// and butter — §3's probe classes) with varied models and noise profiles.
pub fn job_spec(fleet_seed: u64, job_id: usize) -> JobSpec {
    let mut rng = Rng::new(fleet_seed ^ 0xF1EE7).fork(job_id as u64);
    const CFGS: [(usize, usize, usize); 5] =
        [(1, 4, 1), (2, 2, 1), (1, 8, 1), (2, 4, 1), (2, 2, 2)];
    let (tp, dp, pp) = CFGS[rng.below(CFGS.len() as u64) as usize];
    let model = ["gpt2-7b", "gpt2-11b"][rng.below(2) as usize];
    let gpu_class = if rng.bernoulli(0.25) { GpuClass::A100 } else { GpuClass::H800 };
    JobSpec {
        cfg: ParallelConfig::new(tp, dp, pp),
        wl: Workload {
            model: ModelDims::gpt2(model),
            micro_batch: 1,
            microbatches: 4 + 2 * rng.below(3) as usize,
        },
        gpus_per_node: 4,
        gpu_class,
        mfu: rng.range_f64(0.38, 0.45),
        jitter: rng.range_f64(0.010, 0.020),
        spike_p: rng.range_f64(0.005, 0.02),
        seed: rng.next_u64(),
    }
}

/// §3 injection model scaled for fleet campaigns: boosted occurrence
/// probabilities and shorter mean durations so a ~100-iteration job sees
/// onsets *and* reliefs.
fn fleet_injection_model(boost: f64) -> InjectionModel {
    let base = InjectionModel::default();
    InjectionModel {
        p_cpu_1node: (base.p_cpu_1node * boost).min(0.5),
        p_gpu_1node: (base.p_gpu_1node * boost).min(0.5),
        p_congestion_per_link: (base.p_congestion_per_link * boost).min(0.5),
        mean_comp_duration: 2 * MINUTE,
        mean_comm_duration: 4 * MINUTE,
    }
}

/// Run one fleet job end to end (deterministic in `(cfg.seed, job_id)`).
pub fn run_job(cfg: &FleetConfig, job_id: usize) -> JobResult {
    let spec = job_spec(cfg.seed, job_id);
    let world = spec.cfg.world();
    let label = spec.cfg.label();

    let mut sim = TrainingSim::new(spec.clone());
    let horizon = from_secs((sim.ideal_iter_s * cfg.iters as f64).max(60.0));
    let mut ev_rng = Rng::new(cfg.seed ^ 0xE7E47).fork(job_id as u64);
    let events = fleet_injection_model(cfg.failslow_boost).sample_job(
        spec.n_nodes(),
        spec.gpus_per_node,
        horizon,
        &mut ev_rng,
    );
    sim.inject(events.clone());
    let falcon = run_with_falcon(
        &mut sim,
        FalconConfig { mitigate: true, ..FalconConfig::default() },
        cfg.iters,
    );

    // Match verified onsets to injected onsets chronologically: latency =
    // first unclaimed verified open at/after the event's start.
    // (sample_job already returns events sorted by start; sort locally so
    // the greedy matching never depends on that nonlocal invariant.)
    let mut events_by_start = events.clone();
    events_by_start.sort_by_key(|e| e.start);
    let opens = falcon.episode_opens();
    let mut used = vec![false; opens.len()];
    let mut latencies = Vec::new();
    for ev in &events_by_start {
        for (i, &at) in opens.iter().enumerate() {
            if !used[i] && at >= ev.start {
                used[i] = true;
                latencies.push(secs(at - ev.start));
                break;
            }
        }
    }

    let ignored_thpt = if cfg.compare && !events.is_empty() {
        let mut ignored = TrainingSim::new(spec.clone());
        ignored.inject(events.clone());
        run_with_falcon(
            &mut ignored,
            FalconConfig { mitigate: false, ..FalconConfig::default() },
            cfg.iters,
        );
        Some(ignored.timeline.mean_throughput())
    } else {
        None
    };

    JobResult {
        job_id,
        label,
        world,
        injected: events.len(),
        episodes_detected: falcon.detector.episodes.len(),
        flagged: falcon.detector.job_flagged(),
        detection_latency_s: latencies,
        ideal_thpt: 1.0 / sim.ideal_iter_s,
        mean_thpt: sim.timeline.mean_throughput(),
        ignored_thpt,
    }
}

/// Run the whole fleet, sharded across worker threads.
pub fn run_fleet(cfg: &FleetConfig) -> FleetReport {
    let t0 = std::time::Instant::now();
    let jobs = cfg.jobs;
    let workers = if cfg.workers > 0 {
        cfg.workers
    } else {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
    }
    .min(jobs.max(1));

    let next = AtomicUsize::new(0);
    let slots: Mutex<Vec<Option<JobResult>>> = Mutex::new(vec![None; jobs]);
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let id = next.fetch_add(1, Ordering::Relaxed);
                if id >= jobs {
                    break;
                }
                let r = run_job(cfg, id);
                slots.lock().unwrap()[id] = Some(r);
            });
        }
    });
    let wall_s = t0.elapsed().as_secs_f64();
    let results: Vec<JobResult> = slots
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|r| r.expect("every job completes"))
        .collect();
    aggregate(cfg, workers, results, wall_s)
}

fn aggregate(
    cfg: &FleetConfig,
    workers: usize,
    results: Vec<JobResult>,
    wall_s: f64,
) -> FleetReport {
    let jobs = results.len();
    let gpus: usize = results.iter().map(|r| r.world).sum();
    let jobs_with_failslow = results.iter().filter(|r| r.injected > 0).count();
    let jobs_flagged = results.iter().filter(|r| r.flagged).count();
    let false_positives = results.iter().filter(|r| r.flagged && r.injected == 0).count();
    let missed = results.iter().filter(|r| !r.flagged && r.injected > 0).count();
    let episodes_injected: usize = results.iter().map(|r| r.injected).sum();
    let episodes_detected: usize = results.iter().map(|r| r.episodes_detected).sum();

    let pooled: Vec<f64> = results
        .iter()
        .flat_map(|r| r.detection_latency_s.iter().copied())
        .collect();
    let latency = LatencySummary::from_samples(&pooled);

    let slowdowns: Vec<f64> = results
        .iter()
        .filter(|r| r.mean_thpt > 0.0)
        .map(|r| r.ideal_thpt / r.mean_thpt)
        .collect();
    let mean_slowdown = crate::util::stats::mean(&slowdowns);

    let ratios: Vec<f64> = results
        .iter()
        .filter_map(|r| r.ignored_thpt.filter(|&t| t > 0.0).map(|t| r.mean_thpt / t))
        .collect();
    let compared_jobs = ratios.len();
    let mitigated_over_ignored =
        if ratios.is_empty() { 1.0 } else { crate::util::stats::mean(&ratios) };

    FleetReport {
        jobs,
        workers,
        iters: cfg.iters,
        gpus,
        jobs_with_failslow,
        jobs_flagged,
        false_positives,
        missed,
        episodes_injected,
        episodes_detected,
        latency,
        mean_slowdown,
        mitigated_over_ignored,
        compared_jobs,
        wall_s,
        jobs_per_sec: jobs as f64 / wall_s.max(1e-9),
        results,
    }
}

impl FleetReport {
    /// Fingerprint of the per-job results in job-id order (FNV-1a over
    /// exact bit patterns). Results land in per-job slots, so the order —
    /// and therefore the digest — does not depend on thread scheduling:
    /// equal digests across runs and worker counts is the fleet's
    /// determinism contract.
    pub fn digest(&self) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        let mut mix = |v: u64| {
            for b in v.to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x1000_0000_01b3);
            }
        };
        for r in &self.results {
            mix(r.job_id as u64);
            mix(r.injected as u64);
            mix(r.episodes_detected as u64);
            mix(r.mean_thpt.to_bits());
            mix(r.ignored_thpt.map_or(0, f64::to_bits));
            for &l in &r.detection_latency_s {
                mix(l.to_bits());
            }
        }
        h
    }

    /// Human-readable fleet report.
    pub fn render(&self) -> String {
        let mut out = format!(
            "FLEET — {} jobs ({} simulated GPUs) x {} iters, {} workers\n",
            self.jobs, self.gpus, self.iters, self.workers
        );
        out.push_str(&plot::table(
            &["jobs", "w/ fail-slow", "flagged", "missed", "false+", "episodes inj", "episodes det"],
            &[vec![
                self.jobs.to_string(),
                self.jobs_with_failslow.to_string(),
                self.jobs_flagged.to_string(),
                self.missed.to_string(),
                self.false_positives.to_string(),
                self.episodes_injected.to_string(),
                self.episodes_detected.to_string(),
            ]],
        ));
        out.push_str(&format!(
            "detection latency (s): p50 {:.1}  p90 {:.1}  p99 {:.1}  (n={})\n",
            self.latency.p50, self.latency.p90, self.latency.p99, self.latency.n
        ));
        out.push_str(&format!(
            "fleet slowdown vs ideal: {:.3}x mean\n",
            self.mean_slowdown
        ));
        if self.compared_jobs > 0 {
            out.push_str(&format!(
                "mitigated vs ignored throughput: {:+.1}% mean over {} injected jobs\n",
                100.0 * (self.mitigated_over_ignored - 1.0),
                self.compared_jobs
            ));
        }
        out.push_str(&format!(
            "engine: {:.1} jobs/s ({:.2} s wall), digest {:016x}\n",
            self.jobs_per_sec,
            self.wall_s,
            self.digest()
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> FleetConfig {
        FleetConfig { jobs: 10, iters: 40, seed: 7, workers: 3, failslow_boost: 12.0, compare: true }
    }

    #[test]
    fn job_specs_deterministic_and_heterogeneous() {
        let a = job_spec(1, 5);
        let b = job_spec(1, 5);
        assert_eq!(a.cfg, b.cfg);
        assert_eq!(a.seed, b.seed);
        assert_eq!(a.mfu, b.mfu);
        // Across ids the palette actually varies.
        let labels: std::collections::HashSet<String> =
            (0..32).map(|i| job_spec(1, i).cfg.label()).collect();
        assert!(labels.len() >= 3, "palette collapsed: {labels:?}");
    }

    #[test]
    fn single_job_is_deterministic() {
        let cfg = small_cfg();
        let a = run_job(&cfg, 3);
        let b = run_job(&cfg, 3);
        assert_eq!(a.mean_thpt.to_bits(), b.mean_thpt.to_bits());
        assert_eq!(a.injected, b.injected);
        assert_eq!(a.episodes_detected, b.episodes_detected);
    }

    #[test]
    fn fleet_digest_stable_across_worker_counts() {
        let mut cfg = small_cfg();
        let a = run_fleet(&cfg);
        cfg.workers = 1;
        let b = run_fleet(&cfg);
        assert_eq!(a.results.len(), cfg.jobs);
        assert_eq!(a.digest(), b.digest(), "sharding changed the results");
        assert!(a.jobs_per_sec > 0.0);
    }

    #[test]
    fn boosted_fleet_sees_and_detects_failslows() {
        let cfg = FleetConfig { jobs: 24, iters: 60, ..small_cfg() };
        let r = run_fleet(&cfg);
        assert!(r.jobs_with_failslow > 0, "boosted fleet saw no fail-slows");
        assert!(r.jobs_flagged > 0, "no job flagged");
        assert!(r.episodes_detected > 0);
        assert!(r.latency.n > 0, "no detection latencies matched");
        assert!(r.gpus >= 24 * 4);
        let rendered = r.render();
        assert!(rendered.contains("detection latency"));
        assert!(rendered.contains("digest"));
    }

    #[test]
    fn compare_mode_measures_mitigation_delta() {
        let cfg = FleetConfig { jobs: 16, iters: 80, ..small_cfg() };
        let r = run_fleet(&cfg);
        assert!(r.compared_jobs > 0, "no injected job was compared");
        // Mitigation must not make the fleet slower on average.
        assert!(
            r.mitigated_over_ignored > 0.9,
            "mitigated/ignored ratio {}",
            r.mitigated_over_ignored
        );
    }
}
