//! Fleet engine: thousands of concurrent FALCON-supervised jobs, sharded
//! across worker threads — on private clusters or one *shared* cluster.
//!
//! The paper frames fail-slow handling as a *fleet* problem — hundreds of
//! concurrent jobs on a shared 10,000+-GPU cluster, each continuously
//! watched by an always-on detector (R2). This module scales the
//! single-job reproduction to that setting:
//!
//! - **Jobs.** Each fleet job is an independent `TrainingSim` +
//!   [`crate::coordinator::Falcon`] pair with a heterogeneous spec
//!   (parallel strategy, model size, GPU class, jitter profile) drawn
//!   deterministically from the fleet seed, plus a per-job fail-slow mix
//!   sampled from the §3-calibrated [`InjectionModel`].
//!
//! - **Sharding model.** A fixed pool of `std::thread` workers pulls job
//!   ids from a shared atomic counter (work-stealing by index — jobs are
//!   coarse enough that the counter is never contended). Results land in a
//!   slot-per-job vector, so aggregation order is by job id regardless of
//!   which worker ran what.
//!
//! - **Shared-cluster mode** ([`FleetConfig::policy`]` = Some(_)`): all
//!   jobs draw nodes from one [`crate::cluster::ClusterState`] and share
//!   its spine-leaf uplinks — a leaf's bandwidth splits between its
//!   co-resident jobs **weighted by their actual inter-node communication
//!   volume** (a chatty 2-node job squeezes its neighbors, a single-node
//!   job not at all; see `ClusterState::contention_scale_for`), so one
//!   job's traffic is another's congestion
//!   (`LinkState::external_scale`). With [`FleetConfig::stagger`]` > 0`
//!   jobs start and finish at staggered epochs: the pool is sized by peak
//!   (not aggregate) demand, finished jobs release their nodes, and late
//!   arrivals admit into the freed capacity — the pool breathes. S3/S4
//!   mitigation no longer executes
//!   unconditionally: requests go through the [`crate::cluster::Arbiter`],
//!   compete for the finite healthy-node pool, and can be granted, denied,
//!   queued, or preempted. Execution proceeds in *epochs* of
//!   [`FleetConfig::epoch_len`] iterations: within an epoch every job
//!   steps independently behind its own lock (one lock acquisition per job
//!   per epoch — the "epoch-sharded" locking discipline), and at each
//!   epoch boundary a single serial pass syncs fail-slow flags into the
//!   shared inventory, re-derives contention, and arbitrates requests in
//!   job-id order.
//!
//! - **Determinism.** Job `i` derives every random stream from
//!   `(fleet_seed, i)`. In shared mode, cross-job coupling (contention and
//!   grants) is only ever computed in the serial boundary pass from state
//!   that is itself deterministic, so the fleet report remains
//!   bit-identical for a fixed seed across runs *and across worker
//!   counts*. [`FleetReport::digest`] fingerprints the per-job results —
//!   including arbitration outcomes — to make that property testable.
//!
//! - **Bounded memory.** The per-job detector holds O(VERIFY_WINDOW)
//!   samples (a fixed ring, see `detect::detector`) and a capped BOCD
//!   hypothesis set, so fleet memory is O(jobs), not O(jobs × iterations)
//!   — the prerequisite for an always-on fleet campaign.
//!
//! The cross-job aggregator pools episode counts, detection-latency
//! percentiles (verified onset time minus injected onset time) and the
//! mitigated-vs-ignored throughput delta (each injected job optionally
//! re-run with `mitigate: false` on the identical trace; private mode
//! only — in shared mode the counterfactual is the private-cluster
//! baseline itself, see the `fleet_cluster` report).

use std::collections::{BTreeMap, BTreeSet};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::cluster::{Arbiter, ClusterState, Decision, GrantRequest, Policy};
use crate::coordinator::{run_with_falcon, Falcon, FalconConfig};
use crate::diagnose::AnomalyClass;
use crate::fabric::GpuClass;
use crate::inject::{FailSlowEvent, FailSlowKind, InjectionModel, Target};
use crate::ledger::NodeLedger;
use crate::metrics::LatencySummary;
use crate::mitigate::{topology, Strategy};
use crate::pipeline::{ModelDims, ParallelConfig, Workload};
use crate::sim::{JobSpec, TrainingSim};
use crate::simkit::{from_secs, secs, Time, MINUTE};
use crate::util::plot;
use crate::util::rng::Rng;

/// Fleet campaign configuration.
#[derive(Clone, Debug)]
pub struct FleetConfig {
    /// Number of concurrent jobs.
    pub jobs: usize,
    /// Iterations each job trains for.
    pub iters: usize,
    /// Master seed; everything derives from `(seed, job_id)`.
    pub seed: u64,
    /// Worker threads (0 = one per available core).
    pub workers: usize,
    /// Multiplier on the §3 per-job fail-slow probabilities. 1.0 reproduces
    /// the paper's (sparse) campaign rates; the default oversamples so a
    /// moderate fleet still exercises the whole detect→mitigate path.
    pub failslow_boost: f64,
    /// Re-run each injected job with mitigation disabled on the identical
    /// trace, for the mitigated-vs-ignored throughput delta (private mode
    /// only).
    pub compare: bool,
    /// `Some(policy)` = shared-cluster mode: one node pool, contended
    /// uplinks, arbitrated mitigation. `None` = every job owns a private
    /// simulated cluster.
    pub policy: Option<Policy>,
    /// Healthy-node headroom above the fleet's PEAK concurrent demand
    /// (shared mode): 0.15 provisions 15% spares; 0.0 saturates the pool
    /// so every S3 swap is denied.
    pub spare_frac: f64,
    /// Iterations per arbitration epoch (shared mode).
    pub epoch_len: usize,
    /// Staggered job starts (shared mode): job start epochs spread
    /// deterministically over `stagger * ceil(iters / epoch_len)` epochs,
    /// so jobs start and finish at different times and the node pool
    /// breathes — finished jobs release nodes that late arrivals and
    /// mitigation grants can claim. 0.0 (the default) starts every job at
    /// epoch 0, the previous behavior. Ignored in private mode, where jobs
    /// share nothing.
    pub stagger: f64,
    /// Scripted per-job fault events (absolute sim time), injected ON TOP
    /// of whatever the calibrated injection model samples for that job.
    /// This is how scenario `[[fault]]` entries with `job = N` reach the
    /// engine (see `crate::scenario::ScenarioSpec::fleet_config`).
    pub scripted: Vec<(usize, Vec<FailSlowEvent>)>,
    /// Per-job coordinator configuration (overheads, pauses, BOCD knobs).
    /// `mitigate`/`defer_heavy` are forced per engine mode.
    pub falcon: FalconConfig,
    /// Attach a persistent node-health ledger to the shared cluster
    /// (see [`crate::ledger`]): incidents, recurrence gaps, and decaying
    /// scores are recorded at every epoch boundary, quarantine durations
    /// become ledger-driven under [`Policy::PredictiveQuarantine`], and
    /// the final ledger lands in [`FleetReport::ledger`]. `false` (the
    /// default) keeps the memoryless engine bit-identical. Ignored in
    /// private mode.
    pub ledger: bool,
    /// Seed the ledger from a prior campaign's snapshot (implies
    /// `ledger`; the `predictive` flag is re-derived from this campaign's
    /// policy).
    pub ledger_init: Option<NodeLedger>,
    /// Fraction of shared nodes that are chronically flaky (the
    /// heavy-tailed recurrence generator, arxiv 2512.09685): each flaky
    /// node flares repeatedly with Pareto-distributed inter-arrival gaps,
    /// striking whichever job is placed on it. 0.0 (default) disables the
    /// generator entirely — no RNG stream is even created.
    pub flaky_frac: f64,
    /// Pareto tail index of the flare inter-arrival gaps; smaller =
    /// heavier tail (a minority of nodes relapse rapidly).
    pub flaky_alpha: f64,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            jobs: 512,
            iters: 120,
            seed: 2024,
            workers: 0,
            failslow_boost: 8.0,
            compare: true,
            policy: None,
            spare_frac: 0.15,
            epoch_len: 20,
            stagger: 0.0,
            scripted: Vec::new(),
            falcon: FalconConfig::default(),
            ledger: false,
            ledger_init: None,
            flaky_frac: 0.0,
            flaky_alpha: 1.2,
        }
    }
}

/// Per-job arbitration tallies (all zero in private mode). Folded into
/// [`FleetReport::digest`] so the determinism contract covers arbitration
/// outcomes, not just training results.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ArbCounts {
    /// Requests this job filed with the arbiter.
    pub requested: u32,
    /// Grants that handed out fresh healthy nodes.
    pub granted: u32,
    /// Requests denied outright (empty pool).
    pub denied: u32,
    /// Epoch-boundaries spent queued waiting for nodes.
    pub queued: u32,
    /// S4 grants executed in place after queueing past the wait cap.
    pub in_place: u32,
    /// Requests dropped because the episode healed before a grant.
    pub cancelled: u32,
}

/// Outcome of one fleet job.
#[derive(Clone, Debug)]
pub struct JobResult {
    pub job_id: usize,
    /// Parallel strategy label, e.g. "2T4D1P".
    pub label: String,
    pub world: usize,
    /// Fleet iteration at which the job was admitted (staggered shared
    /// mode; 0 when every job starts together).
    pub start_iter: usize,
    /// Injected fail-slow events.
    pub injected: usize,
    /// Verified episodes the detector opened.
    pub episodes_detected: usize,
    /// Whether the job was flagged fail-slow (>= 1 verified episode).
    pub flagged: bool,
    /// Seconds from injected onset to verified onset, per matched episode.
    pub detection_latency_s: Vec<f64>,
    /// Healthy-cluster throughput (iters/s) with even allocation.
    pub ideal_thpt: f64,
    /// Mean throughput of the mitigated run.
    pub mean_thpt: f64,
    /// Mean throughput of the ignore-mode re-run (compare mode, injected
    /// jobs only).
    pub ignored_thpt: Option<f64>,
    /// Arbitration tallies (shared-cluster mode).
    pub arb: ArbCounts,
    /// Per-grant wait times in approximate wall seconds (shared mode).
    pub grant_wait_s: Vec<f64>,
}

/// Fleet-level shared-cluster accounting (None in private mode).
#[derive(Clone, Debug)]
pub struct ClusterSummary {
    pub policy: Policy,
    pub nodes: usize,
    pub leaves: usize,
    /// Healthy spares at campaign start.
    pub spares_initial: usize,
    pub s3_requests: usize,
    pub s3_granted: usize,
    pub s3_denied: usize,
    pub s4_requests: usize,
    /// S4 grants with fresh nodes.
    pub s4_granted: usize,
    /// S4 grants executed in place after queue starvation.
    pub s4_in_place: usize,
    /// Queued decisions across all epochs (one per waiting request-epoch).
    pub queued_decisions: usize,
    /// Arbitration rounds where a higher-priority grant starved someone.
    pub preempted: usize,
    /// Requests dropped because the episode healed first.
    pub cancelled: usize,
    /// Wait from filing to grant, in approximate wall seconds
    /// (epochs waited × epoch length × the job's healthy iteration time).
    pub grant_wait: LatencySummary,
    /// Mean cross-job bandwidth share over all jobs' uplinks and epochs
    /// (1.0 = never contended).
    pub mean_contention_scale: f64,
}

impl ClusterSummary {
    /// Fraction of filed requests that were denied outright.
    pub fn denial_rate(&self) -> f64 {
        let total = self.s3_requests + self.s4_requests;
        if total == 0 {
            return 0.0;
        }
        self.s3_denied as f64 / total as f64
    }
}

/// Aggregated fleet campaign report.
#[derive(Clone, Debug)]
pub struct FleetReport {
    pub jobs: usize,
    pub workers: usize,
    pub iters: usize,
    /// Total simulated GPUs across the fleet.
    pub gpus: usize,
    pub jobs_with_failslow: usize,
    pub jobs_flagged: usize,
    /// Flagged with nothing injected.
    pub false_positives: usize,
    /// Injected but never flagged.
    pub missed: usize,
    pub episodes_injected: usize,
    pub episodes_detected: usize,
    pub latency: LatencySummary,
    /// Mean of (ideal / achieved) throughput across the fleet.
    pub mean_slowdown: f64,
    /// Mean mitigated/ignored throughput ratio over compared jobs (1.0 when
    /// nothing was compared).
    pub mitigated_over_ignored: f64,
    pub compared_jobs: usize,
    pub wall_s: f64,
    pub jobs_per_sec: f64,
    /// Shared-cluster accounting (None in private mode).
    pub cluster: Option<ClusterSummary>,
    /// Final node-health ledger ([`FleetConfig::ledger`]; None when the
    /// ledger is disabled — the default — so the digest of a memoryless
    /// run is untouched).
    pub ledger: Option<NodeLedger>,
    pub results: Vec<JobResult>,
}

/// One per-(job, leaf) contention sample at an epoch boundary (shared
/// mode): which job sat on which leaf, at what bandwidth share, carrying
/// what communication volume. The what-if engine's fleet blame attribution
/// ("which job slowed which") is computed purely from these records.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ContentionSample {
    pub epoch: usize,
    pub leaf: usize,
    pub job: usize,
    /// Bandwidth share the job got on this leaf's uplink (1.0 = alone).
    pub scale: f64,
    /// The job's inter-node communication volume rate (bytes/s of healthy
    /// training) — the culprit weighting.
    pub volume: f64,
}

/// Recording of one shared-cluster fleet run for counterfactual analysis.
/// Private-mode fleets produce an empty trace: nothing is shared, so there
/// is nobody to blame.
#[derive(Clone, Debug, Default)]
pub struct FleetTrace {
    /// Iterations per arbitration epoch (0 = no shared run recorded).
    pub epoch_len: usize,
    /// Epoch-boundary passes executed.
    pub epochs: usize,
    pub contention: Vec<ContentionSample>,
    /// Healthy iteration seconds per job (exposure weighting for blame).
    pub job_ideal_iter_s: Vec<f64>,
    /// Final shared-node placement per job (job id → shared node ids),
    /// so contention blame can be charged back to the *nodes* a culprit
    /// job sat on (`whatif::attribution::ledger_blame`).
    pub placements: BTreeMap<usize, Vec<usize>>,
}

/// Heterogeneous job palette: small 1–2-node strategies (the fleet's bread
/// and butter — §3's probe classes) with varied models and noise profiles.
pub fn job_spec(fleet_seed: u64, job_id: usize) -> JobSpec {
    // The fleet seed is the root, tagged and forked per job so job
    // streams never alias (rng-taint proves the derivation).
    let mut rng = Rng::new(fleet_seed ^ 0xF1EE7).fork(job_id as u64);
    const CFGS: [(usize, usize, usize); 5] =
        [(1, 4, 1), (2, 2, 1), (1, 8, 1), (2, 4, 1), (2, 2, 2)];
    let (tp, dp, pp) = CFGS[rng.below(CFGS.len() as u64) as usize];
    let model = ["gpt2-7b", "gpt2-11b"][rng.below(2) as usize];
    let gpu_class = if rng.bernoulli(0.25) { GpuClass::A100 } else { GpuClass::H800 };
    JobSpec {
        cfg: ParallelConfig::new(tp, dp, pp),
        wl: Workload {
            model: ModelDims::gpt2(model),
            micro_batch: 1,
            microbatches: 4 + 2 * rng.below(3) as usize,
        },
        gpus_per_node: 4,
        gpu_class,
        mfu: rng.range_f64(0.38, 0.45),
        jitter: rng.range_f64(0.010, 0.020),
        spike_p: rng.range_f64(0.005, 0.02),
        seed: rng.next_u64(),
    }
}

/// §3 injection model scaled for fleet campaigns: boosted occurrence
/// probabilities and shorter mean durations so a ~100-iteration job sees
/// onsets *and* reliefs.
fn fleet_injection_model(boost: f64) -> InjectionModel {
    let base = InjectionModel::default();
    InjectionModel {
        p_cpu_1node: (base.p_cpu_1node * boost).min(0.5),
        p_gpu_1node: (base.p_gpu_1node * boost).min(0.5),
        p_congestion_per_link: (base.p_congestion_per_link * boost).min(0.5),
        mean_comp_duration: 2 * MINUTE,
        mean_comm_duration: 4 * MINUTE,
    }
}

/// Sample job `job_id`'s fail-slow trace (deterministic in `(seed, id)`),
/// then append any scripted events targeted at this job.
fn sample_events(
    cfg: &FleetConfig,
    job_id: usize,
    spec: &JobSpec,
    horizon: Time,
) -> Vec<FailSlowEvent> {
    // Fault traces get their own tagged stream off the fleet seed,
    // independent of sim streams.
    let mut ev_rng = Rng::new(cfg.seed ^ 0xE7E47).fork(job_id as u64);
    let mut events = fleet_injection_model(cfg.failslow_boost).sample_job(
        spec.n_nodes(),
        spec.gpus_per_node,
        horizon,
        &mut ev_rng,
    );
    for (job, evs) in &cfg.scripted {
        if *job == job_id {
            events.extend(evs.iter().copied());
        }
    }
    events
}

/// Match verified onsets to injected onsets chronologically: latency =
/// first unclaimed verified open at/after the event's start. Shared with
/// `crate::scenario` for single-job outcome accounting.
pub fn match_detection_latencies(events: &[FailSlowEvent], opens: &[Time]) -> Vec<f64> {
    let mut events_by_start = events.to_vec();
    events_by_start.sort_by_key(|e| e.start);
    let mut used = vec![false; opens.len()];
    let mut latencies = Vec::new();
    for ev in &events_by_start {
        for (i, &at) in opens.iter().enumerate() {
            if !used[i] && at >= ev.start {
                used[i] = true;
                latencies.push(secs(at - ev.start));
                break;
            }
        }
    }
    latencies
}

/// Run one private-cluster fleet job end to end (deterministic in
/// `(cfg.seed, job_id)`).
pub fn run_job(cfg: &FleetConfig, job_id: usize) -> JobResult {
    let spec = job_spec(cfg.seed, job_id);
    let world = spec.cfg.world();
    let label = spec.cfg.label();

    // `JobSpec` is `Copy` and the sampled fault script is injected by
    // borrowed iteration, so neither is cloned per run (the ignore-mode
    // re-run replays the identical trace from the same buffer).
    let mut sim = TrainingSim::new(spec);
    // Horizon formula mirrored by scenario::ScenarioSpec::fleet_config
    // (scripted-fault lowering) — change both together.
    let horizon = from_secs((sim.ideal_iter_s * cfg.iters as f64).max(60.0));
    let events = sample_events(cfg, job_id, &spec, horizon);
    sim.inject(events.iter().copied());
    let falcon = run_with_falcon(
        &mut sim,
        FalconConfig { mitigate: true, defer_heavy: false, ..cfg.falcon.clone() },
        cfg.iters,
    );

    let latencies = match_detection_latencies(&events, &falcon.episode_opens());

    let ignored_thpt = if cfg.compare && !events.is_empty() {
        let mut ignored = TrainingSim::new(spec);
        ignored.inject(events.iter().copied());
        run_with_falcon(
            &mut ignored,
            FalconConfig { mitigate: false, defer_heavy: false, ..cfg.falcon.clone() },
            cfg.iters,
        );
        Some(ignored.timeline.mean_throughput())
    } else {
        None
    };

    JobResult {
        job_id,
        label,
        world,
        start_iter: 0,
        injected: events.len(),
        episodes_detected: falcon.detector.episodes.len(),
        flagged: falcon.detector.job_flagged(),
        detection_latency_s: latencies,
        ideal_thpt: 1.0 / sim.ideal_iter_s,
        mean_thpt: sim.timeline.mean_throughput(),
        ignored_thpt,
        arb: ArbCounts::default(),
        grant_wait_s: Vec::new(),
    }
}

/// Run the whole fleet: private clusters, or the shared cluster when
/// [`FleetConfig::policy`] is set.
pub fn run_fleet(cfg: &FleetConfig) -> FleetReport {
    match cfg.policy {
        Some(policy) => run_fleet_shared(cfg, policy, None),
        None => run_fleet_private(cfg),
    }
}

/// Run the fleet AND record the [`FleetTrace`] the what-if engine attributes
/// contention blame from. Recording is read-only instrumentation: the
/// report is bit-identical to [`run_fleet`]'s for the same config.
pub fn run_fleet_traced(cfg: &FleetConfig) -> (FleetReport, FleetTrace) {
    let mut trace = FleetTrace::default();
    let report = match cfg.policy {
        Some(policy) => run_fleet_shared(cfg, policy, Some(&mut trace)),
        None => run_fleet_private(cfg),
    };
    (report, trace)
}

fn worker_count(cfg: &FleetConfig) -> usize {
    if cfg.workers > 0 {
        cfg.workers
    } else {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
    }
    .min(cfg.jobs.max(1))
}

fn run_fleet_private(cfg: &FleetConfig) -> FleetReport {
    // audit:allow(clock-hygiene): wall_s/jobs_per_sec are harness telemetry,
    // excluded from the deterministic digest.
    let t0 = std::time::Instant::now();
    let jobs = cfg.jobs;
    let workers = worker_count(cfg);

    let next = AtomicUsize::new(0);
    let slots: Mutex<Vec<Option<JobResult>>> = Mutex::new(vec![None; jobs]);
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let id = next.fetch_add(1, Ordering::Relaxed);
                if id >= jobs {
                    break;
                }
                let r = run_job(cfg, id);
                slots.lock().unwrap_or_else(|e| e.into_inner())[id] = Some(r);
            });
        }
    });
    let wall_s = t0.elapsed().as_secs_f64();
    let results: Vec<JobResult> = slots
        .into_inner()
        .unwrap_or_else(|e| e.into_inner())
        .into_iter()
        // audit:allow(panic-budget): the worker loop claims every id below
        // `jobs` exactly once and scope() joins all workers, so each slot
        // is filled; a hole is a scheduler bug worth crashing on.
        .map(|r| r.expect("every job completes"))
        .collect();
    aggregate(cfg, workers, results, wall_s, None, None)
}

// ---------------------------------------------------------------------------
// Shared-cluster mode
// ---------------------------------------------------------------------------

/// Per-job runtime state in shared mode. Each instance sits behind its own
/// `Mutex`; a worker takes the lock exactly once per epoch (the
/// epoch-sharded locking discipline), and the serial boundary pass uses
/// `get_mut`, so lock contention is structurally impossible.
struct SharedJob {
    sim: TrainingSim,
    falcon: Falcon,
    events: Vec<FailSlowEvent>,
    /// Shared-cluster node backing each logical job node (empty until the
    /// job is admitted).
    placement: Vec<usize>,
    /// Inter-node communication volume rate, for contention weighting.
    volume: f64,
    /// Epoch the job WANTS to start at (staggered starts).
    start_epoch: usize,
    /// Epoch the job was actually admitted at (None = still waiting).
    admitted_epoch: Option<usize>,
    /// Nodes handed back after the job finished.
    released: bool,
    arb: ArbCounts,
    grant_wait_s: Vec<f64>,
    done_iters: usize,
}

/// Inter-node communication volume rate of one job (bytes per second of
/// healthy training): DP gradient plus PP activation traffic per
/// iteration, over the healthy iteration time. Single-node jobs send
/// nothing over the leaf uplinks, so they neither suffer nor cause
/// contention (see `ClusterState::contention_scale_for`).
fn comm_volume_rate(spec: &JobSpec, ideal_iter_s: f64) -> f64 {
    if spec.n_nodes() <= 1 {
        return 0.0;
    }
    let cfg = spec.cfg;
    let mut bytes = 0.0;
    if cfg.dp > 1 {
        bytes += spec.wl.dp_bytes(cfg);
    }
    if cfg.pp > 1 {
        bytes += spec.wl.pp_bytes_per_microbatch() * spec.wl.microbatches as f64;
    }
    bytes / ideal_iter_s.max(1e-9)
}

/// Is the job's logical node `k` currently degraded (an injected episode
/// is active on its GPUs, CPU, or uplink)? Read from the sim's own health
/// state so flag sync needs no event bookkeeping of its own.
fn node_degraded(sim: &TrainingSim, k: usize) -> bool {
    let c = &sim.cluster;
    if c.nodes[k].cpu_satisfaction < 1.0 || c.uplinks[k].bandwidth_scale < 1.0 {
        return true;
    }
    let gpn = c.spec.gpus_per_node;
    (0..gpn).any(|g| c.gpus[k * gpn + g].compute_scale < 1.0)
}

/// Diagnosis-taxonomy fault kind for a degraded logical node, for the
/// ledger's incident records: uplink trouble reads as comm-slow,
/// everything else (GPU/CPU) as compute-slow.
fn degraded_kind(sim: &TrainingSim, k: usize) -> AnomalyClass {
    if sim.cluster.uplinks[k].bandwidth_scale < 1.0 {
        AnomalyClass::CommSlow
    } else {
        AnomalyClass::ComputeSlow
    }
}

/// One chronic-hardware flare: the shared node runs degraded for
/// `[start_epoch, end_epoch)` fleet epochs at the given compute scale.
#[derive(Clone, Copy, Debug)]
struct Flare {
    start_epoch: usize,
    end_epoch: usize,
    /// Residual GPU compute scale in (0, 1) while flaring.
    scale: f64,
}

/// Heavy-tailed per-node recurrence generator (arxiv 2512.09685): each
/// shared node is chronically flaky with probability
/// [`FleetConfig::flaky_frac`]; a flaky node's flare inter-arrival gaps
/// are Pareto([`FleetConfig::flaky_alpha`]) distributed, so a minority of
/// nodes relapse rapidly while most stay quiet for long stretches —
/// exactly the regime where a persistent ledger beats memoryless
/// policies. Deterministic in `(cfg.seed, node)`.
fn flare_schedules(cfg: &FleetConfig, n_nodes: usize, horizon_epochs: usize) -> Vec<Vec<Flare>> {
    let mut schedules = vec![Vec::new(); n_nodes];
    if cfg.flaky_frac <= 0.0 {
        return schedules;
    }
    let alpha = cfg.flaky_alpha.max(0.1);
    for (node, sched) in schedules.iter_mut().enumerate() {
        // Flare streams fork per node off the tagged fleet seed.
        let mut rng = Rng::new(cfg.seed ^ 0x1ED6E4).fork(node as u64);
        if !rng.bernoulli(cfg.flaky_frac) {
            continue;
        }
        let mut at = 1 + rng.below(6) as usize;
        while at < horizon_epochs {
            let dur = 1 + rng.below(3) as usize;
            let end = (at + dur).min(horizon_epochs);
            sched.push(Flare {
                start_epoch: at,
                end_epoch: end,
                scale: rng.range_f64(0.35, 0.6),
            });
            // Pareto(alpha) with x_m = 1: gap = ceil(U^(-1/alpha)).
            let u = rng.f64().max(1e-12);
            let gap = (1.0 / u.powf(1.0 / alpha)).ceil() as usize;
            at = end + gap.max(1);
        }
    }
    schedules
}

fn run_fleet_shared(
    cfg: &FleetConfig,
    policy: Policy,
    mut trace: Option<&mut FleetTrace>,
) -> FleetReport {
    // audit:allow(clock-hygiene): wall_s/jobs_per_sec are harness telemetry,
    // excluded from the deterministic digest.
    let t0 = std::time::Instant::now();
    let workers = worker_count(cfg);
    let epoch_len = cfg.epoch_len.max(1);
    let base_epochs = cfg.iters.div_ceil(epoch_len);

    // --- staggered start epochs (deterministic in (seed, job)) ------------
    let span_epochs = (cfg.stagger.max(0.0) * base_epochs as f64).round() as usize;
    let start_epochs: Vec<usize> = (0..cfg.jobs)
        .map(|i| {
            if span_epochs == 0 {
                0
            } else {
                // Stagger offsets fork per job off the tagged fleet seed.
                let mut rng = Rng::new(cfg.seed ^ 0x57A6_6E7).fork(i as u64);
                rng.below(span_epochs as u64 + 1) as usize
            }
        })
        .collect();

    // --- size the pool by PEAK concurrent demand (so the pool breathes:
    // staggered fleets need fewer nodes than their aggregate footprint) ----
    let specs: Vec<JobSpec> = (0..cfg.jobs).map(|i| job_spec(cfg.seed, i)).collect();
    let horizon_epochs =
        start_epochs.iter().map(|s| s + base_epochs).max().unwrap_or(0);
    let mut demand_at = vec![0usize; horizon_epochs.max(1)];
    for (i, spec) in specs.iter().enumerate() {
        for e in start_epochs[i]..start_epochs[i] + base_epochs {
            demand_at[e] += spec.n_nodes();
        }
    }
    let peak = demand_at.iter().copied().max().unwrap_or(0);
    let n_nodes = peak + (peak as f64 * cfg.spare_frac.max(0.0)).ceil() as usize;
    let mut cluster = ClusterState::new(n_nodes);
    let mut arbiter = Arbiter::new(policy);
    let spares_initial = n_nodes - peak;

    // --- persistent node-health ledger (opt-in; None keeps the
    // memoryless engine bit-identical) --------------------------------------
    if cfg.ledger || cfg.ledger_init.is_some() {
        let mut ledger = cfg.ledger_init.clone().unwrap_or_default();
        // Predictive behavior follows THIS campaign's policy, whatever
        // mode the seeding snapshot ran under.
        ledger.predictive = policy == Policy::PredictiveQuarantine;
        cluster.ledger = Some(ledger);
    }

    // --- heavy-tailed chronic-node flare schedules (ledger scenario knob) --
    let flares = flare_schedules(cfg, n_nodes, horizon_epochs + 16);
    let has_flares = flares.iter().any(|s| !s.is_empty());
    // (job, node, flare index) triples already injected into a job's sim.
    let mut flares_injected: BTreeSet<(usize, usize, usize)> = BTreeSet::new();

    let mut jobs: Vec<Mutex<SharedJob>> = Vec::with_capacity(cfg.jobs);
    let mut ideal_iters: Vec<f64> = Vec::new(); // filled only when tracing
    for (id, spec) in specs.iter().enumerate() {
        let mut sim = TrainingSim::new(*spec);
        if trace.is_some() {
            ideal_iters.push(sim.ideal_iter_s);
        }
        // Horizon formula mirrored by scenario::ScenarioSpec::fleet_config
        // (scripted-fault lowering) — change both together.
        let horizon = from_secs((sim.ideal_iter_s * cfg.iters as f64).max(60.0));
        let events = sample_events(cfg, id, spec, horizon);
        sim.inject(events.iter().copied());
        let falcon = Falcon::new(FalconConfig {
            mitigate: true,
            defer_heavy: true,
            ..cfg.falcon.clone()
        });
        let volume = comm_volume_rate(spec, sim.ideal_iter_s);
        jobs.push(Mutex::new(SharedJob {
            sim,
            falcon,
            events,
            placement: Vec::new(),
            volume,
            start_epoch: start_epochs[id],
            admitted_epoch: None,
            released: false,
            arb: ArbCounts::default(),
            grant_wait_s: Vec::new(),
            done_iters: 0,
        }));
    }

    let mut summary = ClusterSummary {
        policy,
        nodes: n_nodes,
        leaves: cluster.n_leaves(),
        spares_initial,
        s3_requests: 0,
        s3_granted: 0,
        s3_denied: 0,
        s4_requests: 0,
        s4_granted: 0,
        s4_in_place: 0,
        queued_decisions: 0,
        preempted: 0,
        cancelled: 0,
        grant_wait: LatencySummary::default(),
        mean_contention_scale: 1.0,
    };
    let mut grant_waits: Vec<f64> = Vec::new();
    let mut contention_sum = 0.0f64;
    let mut contention_n = 0usize;

    // Generous runaway bound: deferred admissions always clear once
    // quarantines expire and finished jobs release nodes, so this cap is
    // defensive only.
    let epoch_cap = horizon_epochs + 8 * (cfg.jobs + 8);
    let mut epoch = 0usize;
    loop {
        let all_done = jobs.iter_mut().all(|j| {
            let job = j.get_mut().unwrap_or_else(|e| e.into_inner());
            job.admitted_epoch.is_some() && job.done_iters >= cfg.iters
        });
        if all_done || epoch >= epoch_cap {
            break;
        }

        // --- serial boundary pass 1: release, admit, flags, contention ----
        // Ledger bookkeeping brackets the pass: the pre-release flag state
        // is what incident transitions diff against, and every clean node
        // recovers once per boundary.
        let ledger_on = cluster.ledger.is_some();
        let prev_flagged: Vec<bool> = if ledger_on {
            cluster.nodes.iter().map(|n| n.flagged).collect()
        } else {
            Vec::new()
        };
        if let Some(l) = cluster.ledger.as_mut() {
            l.advance_epoch(epoch);
        }
        // Finished jobs hand their nodes back (degraded ones quarantine),
        // making room for late arrivals and mitigation grants: the pool
        // breathes.
        for (id, j) in jobs.iter_mut().enumerate() {
            let job = j.get_mut().unwrap_or_else(|e| e.into_inner());
            if job.admitted_epoch.is_some() && job.done_iters >= cfg.iters && !job.released {
                for &n in &job.placement {
                    cluster.release(n, epoch);
                }
                cluster.clear_job_volume(id);
                cluster.clear_job_horizon(id);
                arbiter.cancel(id);
                job.released = true;
            }
        }
        for (id, j) in jobs.iter_mut().enumerate() {
            let job = j.get_mut().unwrap_or_else(|e| e.into_inner());
            if job.admitted_epoch.is_none() && epoch >= job.start_epoch {
                let wanted = job.sim.spec.n_nodes();
                // Horizon first: predictive-quarantine admission tests
                // predicted incidents against the job's remaining span.
                cluster.set_job_horizon(id, epoch + base_epochs);
                if let Some(placement) = arbiter.admit(&mut cluster, id, wanted, epoch) {
                    job.placement = placement;
                    job.admitted_epoch = Some(epoch);
                    cluster.set_job_volume(id, job.volume);
                }
                // else: the pool is momentarily short (quarantined
                // releases); retry next epoch — the job starts late.
            }
        }
        // Chronic-node flares strike whichever job currently sits on the
        // flaky node: the degradation event enters that job's own sim (in
        // job-id order, so the injection set is deterministic) and flags
        // re-derive from it at the next boundary like any other fault.
        if has_flares {
            for (id, j) in jobs.iter_mut().enumerate() {
                let job = j.get_mut().unwrap_or_else(|e| e.into_inner());
                if job.admitted_epoch.is_none() || job.done_iters >= cfg.iters {
                    continue;
                }
                let gpn = job.sim.cluster.spec.gpus_per_node;
                for (k, &shared) in job.placement.iter().enumerate() {
                    for (fi, flare) in flares[shared].iter().enumerate() {
                        if flare.start_epoch <= epoch
                            && epoch < flare.end_epoch
                            && flares_injected.insert((id, shared, fi))
                        {
                            // Remaining flare span, in this job's sim time.
                            let dur_s = (flare.end_epoch - epoch) as f64
                                * epoch_len as f64
                                * job.sim.ideal_iter_s;
                            let ev = FailSlowEvent {
                                kind: FailSlowKind::GpuDegradation,
                                target: Target::Gpu(k * gpn),
                                start: job.sim.now,
                                duration: from_secs(dur_s),
                                scale: flare.scale,
                            };
                            job.sim.inject(std::iter::once(ev));
                            job.events.push(ev);
                        }
                    }
                }
            }
        }
        for node in &mut cluster.nodes {
            node.flagged = false;
        }
        let mut flag_kinds: Vec<Option<AnomalyClass>> =
            if ledger_on { vec![None; cluster.nodes.len()] } else { Vec::new() };
        for j in jobs.iter_mut() {
            let job = j.get_mut().unwrap_or_else(|e| e.into_inner());
            if job.admitted_epoch.is_none() || job.done_iters >= cfg.iters {
                continue;
            }
            for (k, &shared) in job.placement.iter().enumerate() {
                if node_degraded(&job.sim, k) {
                    cluster.nodes[shared].flagged = true;
                    if ledger_on && flag_kinds[shared].is_none() {
                        flag_kinds[shared] = Some(degraded_kind(&job.sim, k));
                    }
                }
            }
        }
        if ledger_on {
            // Incident transitions, in node order (deterministic): a node
            // newly flagged opens an incident with the kind observed by
            // the lowest-id job on it; a node whose flag dropped without
            // going through `release` (the flare ended in place) closes
            // its open incident here.
            for node in 0..cluster.nodes.len() {
                let now_flagged = cluster.nodes[node].flagged;
                if now_flagged == prev_flagged[node] {
                    continue;
                }
                if let Some(l) = cluster.ledger.as_mut() {
                    if now_flagged {
                        let kind = flag_kinds[node].unwrap_or(AnomalyClass::ComputeSlow);
                        l.record_flag(node, epoch, kind);
                    } else {
                        l.record_release(node, epoch);
                    }
                }
            }
        }
        let leaf_volumes: Vec<f64> =
            (0..cluster.n_leaves()).map(|l| cluster.leaf_volume(l)).collect();
        for (id, j) in jobs.iter_mut().enumerate() {
            let job = j.get_mut().unwrap_or_else(|e| e.into_inner());
            if job.admitted_epoch.is_none() || job.done_iters >= cfg.iters {
                continue;
            }
            for (k, &shared) in job.placement.iter().enumerate() {
                let leaf = cluster.leaf_of(shared);
                let scale = cluster.contention_share(leaf_volumes[leaf], id);
                job.sim.cluster.set_external_scale(k, scale);
                contention_sum += scale;
                contention_n += 1;
                if let Some(tr) = trace.as_deref_mut() {
                    // One sample per (epoch, job, leaf): this job's samples
                    // are the most recent pushes, so a bounded tail scan
                    // dedupes multi-node-per-leaf placements.
                    let dup = tr
                        .contention
                        .iter()
                        .rev()
                        .take(job.placement.len())
                        .any(|s| s.epoch == epoch && s.job == id && s.leaf == leaf);
                    if !dup {
                        tr.contention.push(ContentionSample {
                            epoch,
                            leaf,
                            job: id,
                            scale,
                            volume: job.volume,
                        });
                    }
                }
            }
        }

        // --- parallel epoch: every active job steps behind its own lock ---
        let next = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..workers {
                s.spawn(|| loop {
                    let id = next.fetch_add(1, Ordering::Relaxed);
                    if id >= jobs.len() {
                        break;
                    }
                    let mut guard = jobs[id].lock().unwrap_or_else(|e| e.into_inner());
                    let SharedJob { sim, falcon, done_iters, admitted_epoch, .. } = &mut *guard;
                    if admitted_epoch.is_none() {
                        continue;
                    }
                    let target = (*done_iters + epoch_len).min(cfg.iters);
                    while *done_iters < target {
                        let obs = sim.step();
                        falcon.on_iteration(sim, obs.iter, obs.duration_s());
                        *done_iters += 1;
                    }
                });
            }
        });

        // --- serial boundary pass 2: file + arbitrate (id order) ----------
        for (id, j) in jobs.iter_mut().enumerate() {
            let job = j.get_mut().unwrap_or_else(|e| e.into_inner());
            if job.admitted_epoch.is_none() {
                continue;
            }
            if job.done_iters >= cfg.iters {
                // Finished this epoch: drop any in-flight request; the
                // nodes release at the next boundary pass.
                job.falcon.take_request();
                if arbiter.cancel(id) {
                    job.arb.cancelled += 1;
                    summary.cancelled += 1;
                }
                continue;
            }
            if let Some(strategy) = job.falcon.take_request() {
                let fresh = !arbiter.has_queued(id);
                let nodes_wanted = if strategy == Strategy::CkptRestart {
                    job.placement.len()
                } else {
                    1
                };
                arbiter.file(GrantRequest {
                    job: id,
                    strategy,
                    nodes_wanted,
                    filed_epoch: epoch,
                });
                if fresh {
                    job.arb.requested += 1;
                    match strategy {
                        Strategy::CkptRestart => summary.s4_requests += 1,
                        _ => summary.s3_requests += 1,
                    }
                }
            } else if arbiter.has_queued(id) && !job.falcon.detector.slow_now() {
                arbiter.cancel(id);
                job.arb.cancelled += 1;
                summary.cancelled += 1;
            }
        }
        for outcome in arbiter.arbitrate(&mut cluster, epoch) {
            let job = jobs[outcome.job].get_mut().unwrap_or_else(|e| e.into_inner());
            if job.done_iters >= cfg.iters {
                // Defensive: the requester finished between filing and the
                // grant; hand any fresh nodes straight back.
                for &n in &outcome.granted_nodes {
                    cluster.release(n, epoch);
                }
                continue;
            }
            let SharedJob { sim, falcon, placement, arb, grant_wait_s, .. } = job;
            let wait_s =
                outcome.waited_epochs as f64 * epoch_len as f64 * sim.ideal_iter_s;
            match outcome.decision {
                Decision::Granted if outcome.strategy == Strategy::CkptRestart => {
                    for &old in placement.iter() {
                        cluster.release(old, epoch);
                    }
                    *placement = outcome.granted_nodes.clone();
                    falcon.execute_granted(sim, Strategy::CkptRestart);
                    arb.granted += 1;
                    summary.s4_granted += 1;
                    grant_waits.push(wait_s);
                    grant_wait_s.push(wait_s);
                }
                Decision::Granted => match topology::worst_node(sim) {
                    Some(k) => {
                        sim.replace_node_hardware(k);
                        sim.now += cfg.falcon.topology_pause;
                        cluster.release(placement[k], epoch);
                        placement[k] = outcome.granted_nodes[0];
                        falcon.note_grant(sim, outcome.strategy, true);
                        arb.granted += 1;
                        summary.s3_granted += 1;
                        grant_waits.push(wait_s);
                        grant_wait_s.push(wait_s);
                    }
                    None => {
                        // Healed before the grant landed: hand the nodes back.
                        for &n in &outcome.granted_nodes {
                            cluster.release(n, epoch);
                        }
                        arb.cancelled += 1;
                        summary.cancelled += 1;
                    }
                },
                Decision::GrantedInPlace => {
                    falcon.execute_granted_in_place(sim);
                    arb.granted += 1;
                    arb.in_place += 1;
                    summary.s4_in_place += 1;
                    grant_waits.push(wait_s);
                    grant_wait_s.push(wait_s);
                }
                Decision::Denied => {
                    falcon.note_grant(sim, outcome.strategy, false);
                    arb.denied += 1;
                    summary.s3_denied += 1;
                }
                Decision::Queued => {
                    arb.queued += 1;
                    summary.queued_decisions += 1;
                }
            }
        }
        epoch += 1;
    }

    // --- finalize ----------------------------------------------------------
    if let Some(tr) = trace.as_deref_mut() {
        tr.epoch_len = epoch_len;
        tr.epochs = epoch;
        tr.job_ideal_iter_s = ideal_iters;
        for (id, j) in jobs.iter_mut().enumerate() {
            let job = j.get_mut().unwrap_or_else(|e| e.into_inner());
            tr.placements.insert(id, job.placement.clone());
        }
    }
    let ledger = cluster.ledger.take();
    summary.preempted = arbiter.preempted;
    summary.grant_wait = LatencySummary::from_samples(&grant_waits);
    summary.mean_contention_scale =
        if contention_n == 0 { 1.0 } else { contention_sum / contention_n as f64 };

    let results: Vec<JobResult> = jobs
        .into_iter()
        .enumerate()
        .map(|(id, j)| {
            let job = j.into_inner().unwrap_or_else(|e| e.into_inner());
            let latencies =
                match_detection_latencies(&job.events, &job.falcon.episode_opens());
            JobResult {
                job_id: id,
                label: job.sim.spec.cfg.label(),
                world: job.sim.spec.cfg.world(),
                start_iter: job.admitted_epoch.unwrap_or(0) * epoch_len,
                injected: job.events.len(),
                episodes_detected: job.falcon.detector.episodes.len(),
                flagged: job.falcon.detector.job_flagged(),
                detection_latency_s: latencies,
                ideal_thpt: 1.0 / job.sim.ideal_iter_s,
                mean_thpt: job.sim.timeline.mean_throughput(),
                ignored_thpt: None,
                arb: job.arb,
                grant_wait_s: job.grant_wait_s,
            }
        })
        .collect();
    let wall_s = t0.elapsed().as_secs_f64();
    aggregate(cfg, workers, results, wall_s, Some(summary), ledger)
}

fn aggregate(
    cfg: &FleetConfig,
    workers: usize,
    results: Vec<JobResult>,
    wall_s: f64,
    cluster: Option<ClusterSummary>,
    ledger: Option<NodeLedger>,
) -> FleetReport {
    let jobs = results.len();
    let gpus: usize = results.iter().map(|r| r.world).sum();
    let jobs_with_failslow = results.iter().filter(|r| r.injected > 0).count();
    let jobs_flagged = results.iter().filter(|r| r.flagged).count();
    let false_positives = results.iter().filter(|r| r.flagged && r.injected == 0).count();
    let missed = results.iter().filter(|r| !r.flagged && r.injected > 0).count();
    let episodes_injected: usize = results.iter().map(|r| r.injected).sum();
    let episodes_detected: usize = results.iter().map(|r| r.episodes_detected).sum();

    let pooled: Vec<f64> = results
        .iter()
        .flat_map(|r| r.detection_latency_s.iter().copied())
        .collect();
    let latency = LatencySummary::from_samples(&pooled);

    let slowdowns: Vec<f64> = results
        .iter()
        .filter(|r| r.mean_thpt > 0.0)
        .map(|r| r.ideal_thpt / r.mean_thpt)
        .collect();
    let mean_slowdown = crate::util::stats::mean(&slowdowns);

    let ratios: Vec<f64> = results
        .iter()
        .filter_map(|r| r.ignored_thpt.filter(|&t| t > 0.0).map(|t| r.mean_thpt / t))
        .collect();
    let compared_jobs = ratios.len();
    let mitigated_over_ignored =
        if ratios.is_empty() { 1.0 } else { crate::util::stats::mean(&ratios) };

    FleetReport {
        jobs,
        workers,
        iters: cfg.iters,
        gpus,
        jobs_with_failslow,
        jobs_flagged,
        false_positives,
        missed,
        episodes_injected,
        episodes_detected,
        latency,
        mean_slowdown,
        mitigated_over_ignored,
        compared_jobs,
        wall_s,
        jobs_per_sec: jobs as f64 / wall_s.max(1e-9),
        cluster,
        ledger,
        results,
    }
}

impl FleetReport {
    /// Fingerprint of the per-job results in job-id order (FNV-1a over
    /// exact bit patterns), covering training outcomes *and* arbitration
    /// tallies. Results land in per-job slots, so the order — and
    /// therefore the digest — does not depend on thread scheduling: equal
    /// digests across runs and worker counts is the fleet's determinism
    /// contract, in shared-cluster mode included.
    pub fn digest(&self) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        let mut mix = |v: u64| {
            for b in v.to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x1000_0000_01b3);
            }
        };
        for r in &self.results {
            mix(r.job_id as u64);
            mix(r.start_iter as u64);
            mix(r.injected as u64);
            mix(r.episodes_detected as u64);
            mix(r.mean_thpt.to_bits());
            mix(r.ignored_thpt.map_or(0, f64::to_bits));
            for &l in &r.detection_latency_s {
                mix(l.to_bits());
            }
            mix(r.arb.requested as u64);
            mix(r.arb.granted as u64);
            mix(r.arb.denied as u64);
            mix(r.arb.queued as u64);
            mix(r.arb.in_place as u64);
            mix(r.arb.cancelled as u64);
            for &w in &r.grant_wait_s {
                mix(w.to_bits());
            }
        }
        // Ledger state folds in only when the ledger ran: a memoryless
        // campaign's digest is byte-for-byte what it was before the
        // ledger existed.
        if let Some(ledger) = &self.ledger {
            mix(ledger.epoch as u64);
            mix(ledger.predictive as u64);
            for (&node, health) in &ledger.nodes {
                mix(node as u64);
                mix(health.score.to_bits());
                mix(health.repeats as u64);
                mix(health.incidents.len() as u64);
                for inc in &health.incidents {
                    mix(inc.epoch as u64);
                    mix(inc.duration_epochs as u64);
                    mix(inc.gap_epochs.map_or(u64::MAX, |g| g as u64));
                }
            }
        }
        h
    }

    /// Human-readable fleet report.
    pub fn render(&self) -> String {
        let mut out = format!(
            "FLEET — {} jobs ({} simulated GPUs) x {} iters, {} workers\n",
            self.jobs, self.gpus, self.iters, self.workers
        );
        out.push_str(&plot::table(
            &[
                "jobs",
                "w/ fail-slow",
                "flagged",
                "missed",
                "false+",
                "episodes inj",
                "episodes det",
            ],
            &[vec![
                self.jobs.to_string(),
                self.jobs_with_failslow.to_string(),
                self.jobs_flagged.to_string(),
                self.missed.to_string(),
                self.false_positives.to_string(),
                self.episodes_injected.to_string(),
                self.episodes_detected.to_string(),
            ]],
        ));
        out.push_str(&format!(
            "detection latency (s): p50 {:.1}  p90 {:.1}  p99 {:.1}  (n={})\n",
            self.latency.p50, self.latency.p90, self.latency.p99, self.latency.n
        ));
        out.push_str(&format!(
            "fleet slowdown vs ideal: {:.3}x mean\n",
            self.mean_slowdown
        ));
        if self.compared_jobs > 0 {
            out.push_str(&format!(
                "mitigated vs ignored throughput: {:+.1}% mean over {} injected jobs\n",
                100.0 * (self.mitigated_over_ignored - 1.0),
                self.compared_jobs
            ));
        }
        if let Some(c) = &self.cluster {
            out.push_str(&format!(
                "shared cluster: policy {}, {} nodes / {} leaves ({} spares), \
                 mean contention scale {:.3}\n",
                c.policy.name(),
                c.nodes,
                c.leaves,
                c.spares_initial,
                c.mean_contention_scale
            ));
            out.push_str(&format!(
                "arbitration: S3 {} req / {} granted / {} denied; \
                 S4 {} req / {} granted / {} in-place; \
                 queued {}, preempted {}, cancelled {}\n",
                c.s3_requests,
                c.s3_granted,
                c.s3_denied,
                c.s4_requests,
                c.s4_granted,
                c.s4_in_place,
                c.queued_decisions,
                c.preempted,
                c.cancelled
            ));
            out.push_str(&format!(
                "grant wait (s): p50 {:.1}  p90 {:.1}  p99 {:.1}  (n={}); denial rate {:.1}%\n",
                c.grant_wait.p50,
                c.grant_wait.p90,
                c.grant_wait.p99,
                c.grant_wait.n,
                100.0 * c.denial_rate()
            ));
        }
        if let Some(l) = &self.ledger {
            out.push_str(&format!(
                "node-health ledger: {} tracked nodes, {} incidents ({} repeat), \
                 predictive quarantine {}\n",
                l.len(),
                l.total_incidents(),
                l.repeat_incidents(),
                if l.predictive { "on" } else { "off" }
            ));
        }
        out.push_str(&format!(
            "engine: {:.1} jobs/s ({:.2} s wall), digest {:016x}\n",
            self.jobs_per_sec,
            self.wall_s,
            self.digest()
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mitigate::planner::Overheads;

    fn small_cfg() -> FleetConfig {
        FleetConfig {
            jobs: 10,
            iters: 40,
            seed: 7,
            workers: 3,
            failslow_boost: 12.0,
            compare: true,
            ..FleetConfig::default()
        }
    }

    /// Shared-cluster config tuned so escalation reliably reaches S3/S4
    /// within a short horizon (tiny ski-rental overheads, heavy injection).
    fn shared_cfg() -> FleetConfig {
        let mut cfg = FleetConfig {
            jobs: 12,
            iters: 80,
            seed: 11,
            workers: 3,
            failslow_boost: 20.0,
            compare: false,
            policy: Some(Policy::StragglerAware),
            spare_frac: 0.25,
            epoch_len: 10,
            ..FleetConfig::default()
        };
        cfg.falcon.overheads = Overheads {
            adjust_microbatch_s: 0.5,
            adjust_topology_s: 2.0,
            replan_s: 4.0,
            ckpt_restart_s: 10.0,
        };
        cfg.falcon.topology_pause = from_secs(5.0);
        cfg.falcon.restart_cost = from_secs(30.0);
        cfg
    }

    #[test]
    fn job_specs_deterministic_and_heterogeneous() {
        let a = job_spec(1, 5);
        let b = job_spec(1, 5);
        assert_eq!(a.cfg, b.cfg);
        assert_eq!(a.seed, b.seed);
        assert_eq!(a.mfu, b.mfu);
        // Across ids the palette actually varies.
        let labels: std::collections::HashSet<String> =
            (0..32).map(|i| job_spec(1, i).cfg.label()).collect();
        assert!(labels.len() >= 3, "palette collapsed: {labels:?}");
    }

    #[test]
    fn single_job_is_deterministic() {
        let cfg = small_cfg();
        let a = run_job(&cfg, 3);
        let b = run_job(&cfg, 3);
        assert_eq!(a.mean_thpt.to_bits(), b.mean_thpt.to_bits());
        assert_eq!(a.injected, b.injected);
        assert_eq!(a.episodes_detected, b.episodes_detected);
    }

    #[test]
    fn fleet_digest_stable_across_worker_counts() {
        let mut cfg = small_cfg();
        let a = run_fleet(&cfg);
        cfg.workers = 1;
        let b = run_fleet(&cfg);
        assert_eq!(a.results.len(), cfg.jobs);
        assert_eq!(a.digest(), b.digest(), "sharding changed the results");
        assert!(a.jobs_per_sec > 0.0);
        assert!(a.cluster.is_none(), "private mode has no cluster summary");
    }

    #[test]
    fn boosted_fleet_sees_and_detects_failslows() {
        let cfg = FleetConfig { jobs: 24, iters: 60, ..small_cfg() };
        let r = run_fleet(&cfg);
        assert!(r.jobs_with_failslow > 0, "boosted fleet saw no fail-slows");
        assert!(r.jobs_flagged > 0, "no job flagged");
        assert!(r.episodes_detected > 0);
        assert!(r.latency.n > 0, "no detection latencies matched");
        assert!(r.gpus >= 24 * 4);
        let rendered = r.render();
        assert!(rendered.contains("detection latency"));
        assert!(rendered.contains("digest"));
    }

    #[test]
    fn compare_mode_measures_mitigation_delta() {
        let cfg = FleetConfig { jobs: 16, iters: 80, ..small_cfg() };
        let r = run_fleet(&cfg);
        assert!(r.compared_jobs > 0, "no injected job was compared");
        // Mitigation must not make the fleet slower on average.
        assert!(
            r.mitigated_over_ignored > 0.9,
            "mitigated/ignored ratio {}",
            r.mitigated_over_ignored
        );
    }

    #[test]
    fn shared_digest_identical_across_1_4_8_workers() {
        // The satellite determinism contract: contention + arbitration
        // enabled, digest bit-identical across worker counts.
        let cfg = shared_cfg();
        let mut digests = Vec::new();
        let mut requests = 0;
        for w in [1usize, 4, 8] {
            let mut c = cfg.clone();
            c.workers = w;
            let r = run_fleet(&c);
            let summary = r.cluster.as_ref().expect("shared mode emits a cluster summary");
            requests = summary.s3_requests + summary.s4_requests;
            digests.push(r.digest());
        }
        assert_eq!(digests[0], digests[1], "1 vs 4 workers");
        assert_eq!(digests[1], digests[2], "4 vs 8 workers");
        assert!(requests > 0, "scenario never exercised the arbiter");
    }

    #[test]
    fn shared_digest_identical_across_workers_with_replan() {
        // S5 enabled on an exhausted pool: every denial triggers the
        // in-allocation replan fallback, and the whole campaign must stay
        // bit-identical across worker counts (plans, merges, and reverts
        // are all RNG-free and sharding-independent).
        let mut cfg = shared_cfg();
        cfg.falcon.replan = true;
        cfg.falcon.replan_pause = from_secs(5.0);
        cfg.spare_frac = 0.0;
        cfg.failslow_boost = 25.0;
        let mut digests = Vec::new();
        let mut denied = 0;
        for w in [1usize, 4, 8] {
            let mut c = cfg.clone();
            c.workers = w;
            let r = run_fleet(&c);
            denied = r.cluster.as_ref().map_or(0, |c| c.s3_denied);
            digests.push(r.digest());
        }
        assert_eq!(digests[0], digests[1], "1 vs 4 workers");
        assert_eq!(digests[1], digests[2], "4 vs 8 workers");
        assert!(denied > 0, "exhausted pool produced no denials to fall back from");
    }

    #[test]
    fn ledger_digest_identical_across_1_4_8_workers() {
        // Satellite: ledger + heavy-tailed flares + both ledger-consuming
        // policies stay bit-identical across worker counts, and the
        // campaign actually records incidents for the ledger to learn from.
        for policy in [Policy::HealthWeighted, Policy::PredictiveQuarantine] {
            let mut cfg = shared_cfg();
            cfg.jobs = 10;
            cfg.iters = 60;
            cfg.policy = Some(policy);
            cfg.ledger = true;
            cfg.flaky_frac = 0.4;
            cfg.flaky_alpha = 1.1;
            let mut digests = Vec::new();
            let mut incidents = 0;
            for w in [1usize, 4, 8] {
                let mut c = cfg.clone();
                c.workers = w;
                let r = run_fleet(&c);
                let ledger =
                    r.ledger.as_ref().expect("ledger campaign returns a ledger");
                incidents = ledger.total_incidents();
                digests.push(r.digest());
            }
            assert_eq!(digests[0], digests[1], "{policy:?}: 1 vs 4 workers");
            assert_eq!(digests[1], digests[2], "{policy:?}: 4 vs 8 workers");
            assert!(incidents > 0, "{policy:?}: campaign recorded no incidents");
        }
    }

    #[test]
    fn ledger_disabled_fleet_is_memoryless_and_unchanged() {
        // Acceptance gate: the default campaign carries no ledger, and a
        // non-predictive ledger under the same policy is a pure observer —
        // every training outcome bit-identical to the memoryless run.
        let cfg = shared_cfg();
        let r = run_fleet(&cfg);
        assert!(r.ledger.is_none(), "default campaign must stay memoryless");
        let mut with = cfg.clone();
        with.ledger = true;
        let rl = run_fleet(&with);
        assert!(rl.ledger.is_some(), "opt-in campaign must return its ledger");
        for (a, b) in r.results.iter().zip(rl.results.iter()) {
            assert_eq!(
                a.mean_thpt.to_bits(),
                b.mean_thpt.to_bits(),
                "shadow ledger perturbed job {}",
                a.job_id
            );
        }
    }

    #[test]
    fn shared_mode_contends_uplinks() {
        // Co-residency on leaf uplinks must actually slow multi-node jobs:
        // the shared fleet can be no faster than the same fleet on private
        // clusters, and its contention scale must show sharing.
        let mut cfg = shared_cfg();
        cfg.failslow_boost = 0.0; // isolate contention from fail-slows
        cfg.iters = 30;
        let shared = run_fleet(&cfg);
        let summary = shared.cluster.unwrap();
        assert!(
            summary.mean_contention_scale < 1.0,
            "no uplink sharing at scale {}",
            summary.mean_contention_scale
        );
        let mut base = cfg.clone();
        base.policy = None;
        let private = run_fleet(&base);
        assert!(
            shared.mean_slowdown > private.mean_slowdown,
            "contention must cost throughput: shared {} vs private {}",
            shared.mean_slowdown,
            private.mean_slowdown
        );
    }

    #[test]
    fn saturated_pool_denies_s3_and_escalates_to_s4() {
        // Satellite: a spare-free pool must deny every S3 swap; the
        // ski-rental planner then reaches S4 on accumulated impact alone,
        // and nothing panics even though no fresh nodes ever exist.
        let mut cfg = shared_cfg();
        cfg.jobs = 16;
        cfg.iters = 100;
        cfg.spare_frac = 0.0;
        cfg.failslow_boost = 25.0;
        let r = run_fleet(&cfg);
        let c = r.cluster.unwrap();
        assert!(c.s3_requests > 0, "scenario produced no S3 requests");
        assert_eq!(c.s3_granted, 0, "spare-free pool granted a swap");
        assert!(c.s3_denied > 0, "S3 must be denied when the pool is empty");
        assert!(c.denial_rate() > 0.0);
        assert!(
            c.s4_requests > 0,
            "denied S3 must escalate to S4 (requests: S3 {} S4 {})",
            c.s3_requests,
            c.s4_requests
        );
        assert_eq!(c.s4_granted, 0, "no fresh nodes exist to grant");
        // Every S4 either queued or eventually ran in place.
        assert!(c.queued_decisions + c.s4_in_place + c.cancelled > 0);
        let denied_jobs = r.results.iter().filter(|j| j.arb.denied > 0).count();
        assert!(denied_jobs > 0);
    }

    #[test]
    fn staggered_fleet_breathes_and_stays_deterministic() {
        // ROADMAP "pool breathes": staggered starts must (a) keep the
        // digest bit-identical across worker counts, (b) actually spread
        // job admissions over time, and (c) need fewer nodes than the
        // everyone-at-once fleet, because the pool is sized by peak rather
        // than aggregate demand.
        let mut cfg = shared_cfg();
        cfg.jobs = 10;
        cfg.stagger = 3.0;
        let a = run_fleet(&cfg);
        let mut one = cfg.clone();
        one.workers = 1;
        let b = run_fleet(&one);
        assert_eq!(a.digest(), b.digest(), "staggering broke determinism");
        let starts: std::collections::HashSet<usize> =
            a.results.iter().map(|r| r.start_iter).collect();
        assert!(starts.len() >= 2, "admissions never staggered: {starts:?}");
        for r in &a.results {
            assert!(r.mean_thpt > 0.0, "job {} never ran its iterations", r.job_id);
        }
        let mut flat = cfg.clone();
        flat.stagger = 0.0;
        let c = run_fleet(&flat);
        let a_nodes = a.cluster.as_ref().unwrap().nodes;
        let c_nodes = c.cluster.as_ref().unwrap().nodes;
        assert!(
            a_nodes < c_nodes,
            "staggered pool must be smaller than the burst pool: {a_nodes} vs {c_nodes}"
        );
    }

    #[test]
    fn scripted_events_strike_only_their_job() {
        use crate::inject::{FailSlowKind, Target};
        let mut cfg = small_cfg();
        cfg.failslow_boost = 0.0; // isolate the scripted fault
        cfg.compare = false;
        cfg.iters = 60;
        cfg.scripted.push((
            2,
            vec![FailSlowEvent {
                kind: FailSlowKind::GpuDegradation,
                target: Target::Gpu(0),
                start: 0,
                duration: 600 * MINUTE,
                scale: 0.4,
            }],
        ));
        let r = run_fleet(&cfg);
        for (i, jr) in r.results.iter().enumerate() {
            assert_eq!(jr.injected, usize::from(i == 2), "job {i}");
        }
        let victim = &r.results[2];
        assert!(
            victim.mean_thpt < 0.95 * victim.ideal_thpt,
            "scripted fault must slow its job: {} vs ideal {}",
            victim.mean_thpt,
            victim.ideal_thpt
        );
    }

    #[test]
    fn traced_shared_fleet_matches_untraced_and_records_contention() {
        let mut cfg = shared_cfg();
        cfg.jobs = 8;
        cfg.iters = 30;
        let (r, tr) = run_fleet_traced(&cfg);
        assert_eq!(r.digest(), run_fleet(&cfg).digest(), "tracing perturbed the run");
        assert_eq!(tr.epoch_len, cfg.epoch_len);
        assert!(tr.epochs > 0);
        assert_eq!(tr.job_ideal_iter_s.len(), cfg.jobs);
        assert!(!tr.contention.is_empty(), "shared fleet recorded no contention");
        assert!(tr
            .contention
            .iter()
            .all(|s| s.job < cfg.jobs && s.scale > 0.0 && s.scale <= 1.0));
        // Private mode records nothing: there is nobody to blame.
        let mut private = cfg.clone();
        private.policy = None;
        let (_, tr) = run_fleet_traced(&private);
        assert!(tr.contention.is_empty());
        assert_eq!(tr.epoch_len, 0);
    }

    #[test]
    fn all_policies_run_and_differ_only_by_placement() {
        for policy in Policy::ALL {
            let mut cfg = shared_cfg();
            cfg.jobs = 8;
            cfg.iters = 30;
            cfg.policy = Some(policy);
            let r = run_fleet(&cfg);
            assert_eq!(r.results.len(), 8, "{} dropped jobs", policy.name());
            let rendered = r.render();
            assert!(rendered.contains(policy.name()), "{rendered}");
        }
    }
}
