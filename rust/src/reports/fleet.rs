//! Fleet-scale campaign report (beyond the paper's single-job evaluation:
//! the ROADMAP's production-scale direction). Thin report-registry wrapper
//! over [`crate::fleet::run_fleet`]; the `falcon fleet` CLI subcommand is
//! the primary entry point with the same knobs.

use crate::fleet::{run_fleet, FleetConfig};
use crate::util::cli::Args;

pub fn config_from_args(args: &Args) -> FleetConfig {
    let d = FleetConfig::default();
    FleetConfig {
        jobs: args.usize_or("jobs", d.jobs),
        iters: args.usize_or("iters", d.iters),
        seed: args.u64_or("seed", d.seed),
        workers: args.usize_or("workers", d.workers),
        failslow_boost: args.f64_or("boost", d.failslow_boost),
        compare: args.bool_or("compare", d.compare),
    }
}

pub fn fleet(args: &Args) -> String {
    let cfg = config_from_args(args);
    run_fleet(&cfg).render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fleet_report_renders() {
        let args = Args::parse(
            ["--jobs", "6", "--iters", "30", "--workers", "2", "--seed", "3"]
                .iter()
                .map(|s| s.to_string()),
        );
        let out = fleet(&args);
        assert!(out.contains("FLEET — 6 jobs"), "{out}");
        assert!(out.contains("digest"));
    }
}
