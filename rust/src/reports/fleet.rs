//! Fleet-scale campaign reports (beyond the paper's single-job evaluation:
//! the ROADMAP's production-scale direction).
//!
//! Two report ids dispatch here:
//!
//! - `fleet` — thin wrapper over [`crate::fleet::run_fleet`] (private
//!   clusters unless `--policy` selects shared mode); the `falcon fleet`
//!   CLI subcommand is the primary entry point with the same knobs.
//! - `fleet_cluster` — the shared-cluster evaluation: runs the fleet on
//!   one shared cluster under the chosen `--policy`, then re-runs the
//!   identical fleet on private clusters, and reports grant-latency
//!   percentiles, the arbitration denial rate, and the contention slowdown
//!   (shared mean slowdown over private mean slowdown — what co-residency
//!   alone costs the fleet).

use crate::cluster::Policy;
use crate::fleet::{run_fleet, FleetConfig};
use crate::scenario::FleetSpec;
use crate::util::cli::Args;

/// Lower the CLI flags onto a declarative [`FleetSpec`] and from there to
/// the engine configuration — the same path `falcon run` takes for fleet
/// scenarios, so flags and spec files cannot drift apart.
pub fn config_from_args(args: &Args) -> FleetConfig {
    let d = FleetConfig::default();
    let policy = match args.get("policy") {
        None => None,
        Some("private") | Some("none") => None,
        Some(p) => match Policy::parse(p) {
            Some(p) => Some(p),
            None => {
                eprintln!(
                    "[fleet] unknown --policy '{p}' (want first-fit|packed|spread|\
                     straggler-aware|health-weighted|predictive-quarantine|private); \
                     falling back to private clusters"
                );
                None
            }
        },
    };
    let spec = FleetSpec {
        jobs: args.usize_or("jobs", d.jobs),
        workers: args.usize_or("workers", d.workers),
        boost: args.f64_or("boost", d.failslow_boost),
        compare: args.bool_or("compare", d.compare),
        policy,
        spare: args.f64_or("spare", d.spare_frac),
        epoch_len: args.usize_or("epoch-len", d.epoch_len),
        stagger: args.f64_or("stagger", 0.0),
    };
    let mut cfg = spec.to_config(args.usize_or("iters", d.iters), args.u64_or("seed", d.seed));
    // Ledger knobs ride along (`--ledger true`, `--flaky 0.3`, `--alpha
    // 1.1`); `falcon fleet --ledger-file` seeding is layered on in main.
    cfg.ledger = args.bool_or("ledger", d.ledger);
    cfg.flaky_frac = args.f64_or("flaky", d.flaky_frac);
    cfg.flaky_alpha = args.f64_or("alpha", d.flaky_alpha);
    cfg
}

pub fn fleet(args: &Args) -> String {
    let cfg = config_from_args(args);
    run_fleet(&cfg).render()
}

/// Shared-vs-private fleet comparison (`fleet_cluster` report id).
pub fn fleet_cluster(args: &Args) -> String {
    let mut cfg = config_from_args(args);
    cfg.jobs = args.usize_or("jobs", 96);
    cfg.iters = args.usize_or("iters", 80);
    cfg.compare = false; // the counterfactual here is the private baseline
    let policy = cfg.policy.unwrap_or(Policy::StragglerAware);
    cfg.policy = Some(policy);

    let shared = run_fleet(&cfg);
    let mut base = cfg.clone();
    base.policy = None;
    let private = run_fleet(&base);

    let Some(c) = shared.cluster.as_ref() else {
        return "FLEET_CLUSTER unavailable: shared mode emitted no cluster summary\n".to_string();
    };
    let contention_slowdown = if private.mean_slowdown > 0.0 {
        shared.mean_slowdown / private.mean_slowdown
    } else {
        1.0
    };
    let mut out = format!(
        "FLEET_CLUSTER — {} jobs x {} iters on one shared cluster (policy {})\n\n",
        cfg.jobs,
        cfg.iters,
        policy.name()
    );
    out.push_str(&shared.render());
    out.push_str(&format!(
        "\nprivate-cluster baseline: slowdown {:.3}x mean, {:.1} jobs/s\n",
        private.mean_slowdown, private.jobs_per_sec
    ));
    out.push_str(&format!(
        "contention slowdown (shared/private): {:.3}x\n",
        contention_slowdown
    ));
    out.push_str(&format!(
        "arbitration: denial rate {:.1}%, grant wait p50 {:.1}s p99 {:.1}s over {} grants\n",
        100.0 * c.denial_rate(),
        c.grant_wait.p50,
        c.grant_wait.p99,
        c.grant_wait.n
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string()))
    }

    #[test]
    fn fleet_report_renders() {
        let args = parse(&["--jobs", "6", "--iters", "30", "--workers", "2", "--seed", "3"]);
        let out = fleet(&args);
        assert!(out.contains("FLEET — 6 jobs"), "{out}");
        assert!(out.contains("digest"));
    }

    #[test]
    fn policy_flag_selects_shared_mode() {
        let args = parse(&[
            "--jobs", "6", "--iters", "20", "--workers", "2", "--policy", "packed",
        ]);
        let cfg = config_from_args(&args);
        assert_eq!(cfg.policy, Some(Policy::Packed));
        let out = fleet(&args);
        assert!(out.contains("shared cluster: policy packed"), "{out}");
        // And every other spelling parses.
        for p in ["first-fit", "spread", "straggler-aware"] {
            let cfg = config_from_args(&parse(&["--policy", p]));
            assert_eq!(cfg.policy.map(|p| p.name()), Some(p));
        }
        assert_eq!(config_from_args(&parse(&["--policy", "private"])).policy, None);
        assert_eq!(config_from_args(&parse(&["--policy", "bogus"])).policy, None);
    }

    #[test]
    fn ledger_flags_lower_onto_the_config() {
        let cfg = config_from_args(&parse(&[
            "--policy", "health-weighted", "--ledger", "true", "--flaky", "0.3",
            "--alpha", "1.1",
        ]));
        assert_eq!(cfg.policy, Some(Policy::HealthWeighted));
        assert!(cfg.ledger);
        assert_eq!(cfg.flaky_frac, 0.3);
        assert_eq!(cfg.flaky_alpha, 1.1);
        let cfg = config_from_args(&parse(&["--policy", "predictive-quarantine"]));
        assert_eq!(cfg.policy, Some(Policy::PredictiveQuarantine));
        assert!(!cfg.ledger, "ledger stays off unless asked");
    }

    #[test]
    fn fleet_cluster_report_compares_to_private_baseline() {
        // Saturated pool so the report demonstrably shows denials.
        let args = parse(&[
            "--jobs", "10", "--iters", "60", "--workers", "2", "--seed", "11", "--boost",
            "20", "--spare", "0.0", "--epoch-len", "10",
        ]);
        let out = fleet_cluster(&args);
        assert!(out.contains("FLEET_CLUSTER"), "{out}");
        assert!(out.contains("contention slowdown"), "{out}");
        assert!(out.contains("denial rate"), "{out}");
        assert!(out.contains("private-cluster baseline"), "{out}");
    }
}
