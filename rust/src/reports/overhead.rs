//! Overhead experiments: Fig 18 (detector), Table 6 (micro-batch solve
//! time) and Fig 19 (topology-adjustment pause, memory vs disk — measured
//! on real buffers).

use crate::mitigate::microbatch;
use crate::pipeline::{ModelDims, ParallelConfig, Workload};
use crate::sim::{JobSpec, TrainingSim};
use crate::util::cli::Args;
use crate::util::plot;
use crate::util::rng::Rng;
// Wall-clock is fine here: this report *is* the overhead measurement
// (Fig 18/20) and nothing in it is reachable from a digest or replay
// root, so clock-hygiene's reachability scope excludes it.
use std::time::Instant;

/// Fig 18 — detector overhead across parallel strategies: iteration time
/// with the monitor shim attached vs detached.
pub fn fig18(args: &Args) -> String {
    let iters = args.usize_or("iters", 150);
    let configs: Vec<(&str, ParallelConfig, usize)> = vec![
        ("4T1D1P", ParallelConfig::new(4, 1, 1), 1),
        ("2T2D1P", ParallelConfig::new(2, 2, 1), 1),
        ("2T1D2P", ParallelConfig::new(2, 1, 2), 1),
        ("1T4D1P", ParallelConfig::new(1, 4, 1), 1),
        ("2T2D2P", ParallelConfig::new(2, 2, 2), 2),
        ("2T4D1P", ParallelConfig::new(2, 4, 1), 4),
    ];
    let mut labels = Vec::new();
    let mut overheads = Vec::new();
    for (label, cfg, nodes) in configs {
        let mk = |attached: bool, seed: u64| {
            let mut sim = TrainingSim::new(JobSpec {
                cfg,
                wl: Workload { model: ModelDims::gpt2("gpt2-7b"), micro_batch: 1, microbatches: 8 },
                gpus_per_node: cfg.world().div_ceil(nodes),
                gpu_class: crate::fabric::GpuClass::H800,
                mfu: 0.42,
                jitter: 0.012, // real runs jitter — hence the paper's "0.0%" cells
            spike_p: 0.01,
                seed,
            });
            sim.monitor_attached = attached;
            let outcome = sim.run(iters);
            outcome.actual as f64 / iters as f64
        };
        let with = mk(true, 18);
        let without = mk(false, 19); // different seed = run-to-run variability
        labels.push(label.to_string());
        overheads.push((100.0 * (with - without) / without).max(0.0));
    }
    let mut out = String::from("Figure 18 — FALCON-DETECT overhead per parallel strategy (%)\n");
    out.push_str(&plot::bar_chart("overhead (%)", &labels, &overheads, 40));
    let mean: f64 = overheads.iter().sum::<f64>() / overheads.len() as f64;
    let max = overheads.iter().cloned().fold(0.0, f64::max);
    out.push_str(&format!(
        "mean {mean:.2}%, max {max:.2}% \
         (paper: mean 0.39%, max 1.1%; some cells 0.0% from run variability)\n"
    ));
    out
}

/// Table 6 — time to find the optimal micro-batch distribution vs DP count.
/// Our exact greedy replaces the paper's cvxpy QP; the table shows both.
pub fn tab6(args: &Args) -> String {
    let seed = args.u64_or("seed", 6);
    let mut rng = Rng::new(seed);
    let mut rows = Vec::new();
    for d in [16usize, 32, 64, 128, 256, 512] {
        let times: Vec<f64> = (0..d).map(|_| 0.5 + rng.f64()).collect();
        let total = d * 8;
        // Warm up + time repeated solves for a stable measurement.
        let reps = 50;
        let t0 = Instant::now();
        let mut sink = 0usize;
        for _ in 0..reps {
            sink += microbatch::solve(&times, total).m[0];
        }
        let secs = t0.elapsed().as_secs_f64() / reps as f64;
        std::hint::black_box(sink);
        let paper = match d {
            16 | 32 | 64 => 0.01,
            128 => 0.11,
            256 => 6.78,
            _ => 35.93,
        };
        rows.push(vec![
            d.to_string(),
            format!("{:.6}", secs),
            format!("{paper:.2}"),
        ]);
    }
    let mut out = String::from("Table 6 — micro-batch distribution solve time vs #DP groups\n");
    out.push_str(&plot::table(&["# DPs", "ours (s, exact greedy)", "paper cvxpy QP (s)"], &rows));
    out.push_str(
        "the greedy is provably optimal for Eq. 1 (see mitigate::microbatch tests), \
         replacing the QP\n",
    );
    out
}

/// Fig 19 — topology-adjustment overhead: memory (M) vs disk (D) parameter
/// dump+load, measured on real buffers at several sizes ("GPU memory
/// utilization" levels scaled to this host).
pub fn fig19(args: &Args) -> String {
    let mbs: Vec<usize> = if args.bool_or("fast", true) {
        vec![16, 64, 192]
    } else {
        vec![16, 64, 256, 512, 1024]
    };
    let dir = std::env::temp_dir().join("falcon_fig19");
    let disk = crate::ckpt::DiskStore::new(&dir).expect("tmp dir");
    let mut mem = crate::ckpt::MemoryStore::new();

    let mut rows = Vec::new();
    for &mb in &mbs {
        let data: Vec<u8> = (0..mb * 1024 * 1024).map(|i| (i * 31 + 7) as u8).collect();
        let mut out_buf = Vec::new();
        let t_mem = mem.dump("k", &data) + mem.load("k", &mut out_buf).unwrap();
        let t_disk = disk.dump("k", &data).unwrap() + disk.load("k", &mut out_buf).unwrap();
        rows.push(vec![mb as f64, t_mem, t_disk, t_disk / t_mem.max(1e-9)]);
    }
    let _ = std::fs::remove_dir_all(&dir);

    let mut out = String::from(
        "Figure 19 — topology-adjustment pause: memory (M) vs disk (D) dump+load, real buffers\n",
    );
    out.push_str(&plot::csv(&["size_mb", "mem_s", "disk_s", "speedup_x"], &rows));
    for r in &rows {
        out.push_str(&format!(
            "  {:>5} MB: M {:.4}s  D {:.4}s  ({:.1}x)\n",
            r[0] as usize, r[1], r[2], r[3]
        ));
    }
    let max_speedup = rows.iter().map(|r| r[3]).fold(0.0, f64::max);
    out.push_str(&format!(
        "max speedup {max_speedup:.1}x (paper: up to 6.72x, growing with memory utilization)\n"
    ));
    // Model extrapolation to paper-scale checkpoints.
    let model = crate::ckpt::CkptCostModel::default();
    out.push_str(&format!(
        "cost-model extrapolation @80GB/GPU x8: M {:.0}s vs D {:.0}s\n",
        model.mem_roundtrip_s(640e9),
        model.disk_roundtrip_s(640e9)
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tab6_solver_fast_at_512() {
        let out = tab6(&Args::parse([]));
        assert!(out.contains("512"));
        // Extract our 512-DP solve time; must be far below the paper's 36 s.
        let line = out.lines().find(|l| l.starts_with("| 512")).unwrap();
        let ours: f64 = line.split('|').nth(2).unwrap().trim().parse().unwrap();
        assert!(ours < 0.1, "greedy too slow: {ours}s");
    }

    #[test]
    fn fig19_memory_wins() {
        let out = fig19(&Args::parse(["--fast".to_string()]));
        let speedup_line = out.lines().find(|l| l.starts_with("max speedup")).unwrap();
        let x: f64 = speedup_line
            .split_whitespace()
            .nth(2)
            .unwrap()
            .trim_end_matches('x')
            .parse()
            .unwrap();
        assert!(x > 1.0, "memory must beat disk: {x}");
    }

    #[test]
    fn fig18_overhead_small() {
        let out = fig18(&Args::parse(["--iters".to_string(), "60".into()]));
        let mean_line = out.lines().find(|l| l.starts_with("mean")).unwrap();
        let mean: f64 = mean_line
            .split_whitespace()
            .nth(1)
            .unwrap()
            .trim_end_matches("%,")
            .parse()
            .unwrap();
        assert!(mean < 5.0, "detector overhead too large: {mean}%");
    }
}
