//! What-if counterfactual report (`whatif` id): replay-based attribution
//! for a single-job scenario plus contention blame for a shared fleet —
//! the report-registry face of [`crate::whatif`] (the `falcon whatif`
//! subcommand is the interactive entry with per-edit knobs).

use crate::cluster::Policy;
use crate::scenario::{find, FleetSpec, ScenarioSpec};
use crate::util::cli::Args;
use crate::whatif::{
    attribute, contention_blame, record, record_fleet, render_blame, TraceConfig,
};

pub fn whatif(args: &Args) -> String {
    let name = args.str_or("scenario", "slow-leak-gpu");
    let workers = args.usize_or("workers", 0);
    let mut out = String::new();

    // --- single-job attribution -------------------------------------------
    // A fleet --scenario drives the blame section below instead; anything
    // unknown is reported, never silently substituted.
    let requested_fleet = find(&name).filter(|s| s.fleet.is_some());
    let spec = match find(&name) {
        Some(s) if s.fleet.is_none() => s,
        other => {
            let why = if other.is_some() {
                "is a fleet scenario — its contention blame is attributed below"
            } else {
                "is not a library scenario"
            };
            out.push_str(&format!(
                "note: --scenario '{name}' {why}; the single-job attribution \
                 uses the default 'slow-leak-gpu'\n",
            ));
            match find("slow-leak-gpu") {
                Some(s) => s,
                None => {
                    out.push_str("library scenario `slow-leak-gpu` missing\n");
                    return out;
                }
            }
        }
    };
    let iters = args.usize_or("iters", spec.run.iters.min(300));
    let spec = spec.iters(iters);
    out.push_str(&format!(
        "WHATIF — counterfactual attribution of '{}' ({} iters)\n\n",
        spec.name, iters
    ));
    match record(&spec, &TraceConfig::default()) {
        Err(e) => out.push_str(&format!("recording failed: {e}\n")),
        Ok(trace) => match attribute(&trace, workers) {
            Err(e) => out.push_str(&format!("attribution failed: {e}\n")),
            Ok(attr) => out.push_str(&attr.render()),
        },
    }

    // --- fleet contention blame -------------------------------------------
    // The requested fleet scenario when one was named; otherwise a small
    // synthetic packed fleet.
    let fleet_spec = match requested_fleet {
        Some(s) => {
            let iters = args.usize_or("fleet-iters", s.run.iters.min(40));
            s.iters(iters)
        }
        None => ScenarioSpec::new("whatif-fleet", 2, 4, 1)
            .iters(args.usize_or("fleet-iters", 40))
            .seed(args.u64_or("seed", 11))
            .with_fleet(FleetSpec {
                jobs: args.usize_or("jobs", 12),
                workers,
                boost: args.f64_or("boost", 4.0),
                compare: false,
                policy: Some(Policy::Packed),
                spare: 0.1,
                epoch_len: 10,
                stagger: 0.0,
            }),
    };
    let fleet_jobs = fleet_spec.fleet.as_ref().map_or(0, |f| f.jobs);
    out.push_str(&format!(
        "\ncontention blame — '{}': {} jobs x {} iters on a shared cluster\n",
        fleet_spec.name, fleet_jobs, fleet_spec.run.iters
    ));
    match record_fleet(&fleet_spec) {
        Err(e) => out.push_str(&format!("fleet recording failed: {e}\n")),
        Ok(rec) => out.push_str(&render_blame(&contention_blame(&rec.trace), 10)),
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn whatif_report_renders_attribution_and_blame() {
        let args = Args::parse(
            ["--iters", "120", "--jobs", "6", "--fleet-iters", "20", "--workers", "2"]
                .iter()
                .map(|s| s.to_string()),
        );
        let out = whatif(&args);
        assert!(out.contains("WHATIF"), "{out}");
        assert!(out.contains("what-if attribution"), "{out}");
        assert!(out.contains("contention blame"), "{out}");
    }
}
