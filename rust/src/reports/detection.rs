//! Detection accuracy experiments: Fig 12 (iteration-time estimation) and
//! Tables 4–5 (BOCD+V vs raw BOCD vs SlideWindow on labelled traces).

use crate::detect::acf;
use crate::detect::bocd::{detect_changepoints, BocdConfig};
use crate::detect::detector::detect_episodes;
use crate::detect::window;
use crate::inject::{FailSlowEvent, FailSlowKind, Target};
use crate::pipeline::{ModelDims, ParallelConfig, Workload};
use crate::sim::{JobSpec, TrainingSim};
use crate::util::cli::Args;
use crate::util::plot;
use crate::util::rng::Rng;

/// Fig 12 — relative error of ACF-based iteration-time estimation across
/// hybrid-parallel strategies on 1/2/4 nodes.
pub fn fig12(args: &Args) -> String {
    let iters = args.usize_or("iters", 120);
    // (label, cfg, nodes) — §7.2's configurations.
    let configs: Vec<(&str, ParallelConfig, usize)> = vec![
        ("S-4T1D1P", ParallelConfig::new(4, 1, 1), 1),
        ("S-2T2D1P", ParallelConfig::new(2, 2, 1), 1),
        ("S-2T1D2P", ParallelConfig::new(2, 1, 2), 1),
        ("S-1T4D1P", ParallelConfig::new(1, 4, 1), 1),
        ("S-1T2D2P", ParallelConfig::new(1, 2, 2), 1),
        ("M-2T2D2P", ParallelConfig::new(2, 2, 2), 2),
        ("M-2T4D1P", ParallelConfig::new(2, 4, 1), 4),
    ];

    let mut labels = Vec::new();
    let mut errors = Vec::new();
    for (label, cfg, nodes) in configs {
        let gpus_per_node = cfg.world().div_ceil(nodes);
        let mut sim = TrainingSim::new(JobSpec {
            cfg,
            wl: Workload { model: ModelDims::gpt2("gpt2-7b"), micro_batch: 1, microbatches: 8 },
            gpus_per_node,
            gpu_class: crate::fabric::GpuClass::H800,
            mfu: 0.42,
            jitter: 0.01,
            spike_p: 0.01,
            seed: 1000 + cfg.world() as u64,
        });
        let mut truth = Vec::new();
        for _ in 0..iters {
            let obs = sim.step();
            truth.push(obs.duration as f64 / 1e6);
        }
        let log = &sim.monitor.logs[0];
        let est = acf::iteration_times(&log.op_kinds(), &log.timestamps(), 64);
        let err = match est {
            Some((_, times)) => acf::relative_error(&times, &truth),
            None => 1.0,
        };
        labels.push(label.to_string());
        errors.push(err * 100.0);
    }

    let mut out = String::from(
        "Figure 12 — iteration-time estimation accuracy \
         (relative error %, S=single-node M=multi-node)\n",
    );
    out.push_str(&plot::bar_chart("relative error (%)", &labels, &errors, 40));
    out.push_str(&plot::csv(
        &["config_idx", "rel_err_pct"],
        &errors.iter().enumerate().map(|(i, &e)| vec![i as f64, e]).collect::<Vec<_>>(),
    ));
    let max = errors.iter().cloned().fold(0.0, f64::max);
    out.push_str(&format!(
        "max error {max:.2}% (paper: <=1.2% single-node, 0.1–0.7% multi-node)\n"
    ));
    out
}

/// A labelled trace for the detection shoot-out: iteration times + whether
/// a real fail-slow is present.
pub struct LabelledTrace {
    pub series: Vec<f64>,
    pub has_failslow: bool,
}

/// Generate the labelled traces for one campaign class (computation or
/// communication fail-slows), mirroring §3's sampling-job populations.
pub fn labelled_traces(comm: bool, n_jobs: usize, iters: usize, seed: u64) -> Vec<LabelledTrace> {
    let mut out = Vec::new();
    for j in 0..n_jobs {
        let seed_j = seed.wrapping_add(j as u64 * 6151);
        let mut rng = Rng::new(seed_j);
        let (cfg, nodes, model) = if comm {
            (ParallelConfig::new(2, 4, 1), 4, "gpt2-7b")
        } else {
            (ParallelConfig::new(2, 1, 2), 1, "gpt2-11b")
        };
        let gpus_per_node = cfg.world().div_ceil(nodes);
        let mut sim = TrainingSim::new(JobSpec {
            cfg,
            wl: Workload { model: ModelDims::gpt2(model), micro_batch: 1, microbatches: 8 },
            gpus_per_node,
            gpu_class: crate::fabric::GpuClass::H800,
            mfu: 0.42,
            jitter: 0.015,
            // ~1 stall spike per 250 iterations: enough to give raw BOCD its
            // characteristic false positives without drowning SlideWindow.
            spike_p: 0.004,
            seed: seed_j,
        });

        // Match the paper's base rates: computation fail-slows are rare
        // (6/392), communication ones common (43/107 ~ 40%).
        let inject_p = if comm { 0.4 } else { 6.0 / 392.0 };
        let has = rng.bernoulli(inject_p);
        if has {
            let span = sim.ideal_iter_s * iters as f64;
            let start = span * rng.range_f64(0.2, 0.5);
            let dur = span * rng.range_f64(0.15, 0.4);
            let ev = if comm {
                FailSlowEvent {
                    kind: FailSlowKind::NetworkCongestion,
                    target: Target::Uplink(rng.below(nodes as u64) as usize),
                    start: crate::simkit::from_secs(start),
                    duration: (dur * 1e6) as u64,
                    scale: rng.range_f64(0.2, 0.55),
                }
            } else {
                let comp_kind = if rng.bernoulli(4.0 / 6.0) {
                    (FailSlowKind::CpuContention, Target::Node(0), rng.range_f64(0.3, 0.6))
                } else {
                    let gpu = rng.below(4) as usize;
                    (FailSlowKind::GpuDegradation, Target::Gpu(gpu), rng.range_f64(0.5, 0.8))
                };
                FailSlowEvent {
                    kind: comp_kind.0,
                    target: comp_kind.1,
                    start: crate::simkit::from_secs(start),
                    duration: (dur * 1e6) as u64,
                    scale: comp_kind.2,
                }
            };
            sim.inject(vec![ev]);
        }
        let mut series = Vec::with_capacity(iters);
        for _ in 0..iters {
            series.push(sim.step().duration as f64 / 1e6);
        }
        out.push(LabelledTrace { series, has_failslow: has });
    }
    out
}

/// Job-level confusion counts for one algorithm.
#[derive(Clone, Copy, Debug, Default)]
pub struct Confusion {
    pub tp: usize,
    pub fp: usize,
    pub tn: usize,
    pub fn_: usize,
}

impl Confusion {
    pub fn accuracy(&self) -> f64 {
        let total = self.tp + self.fp + self.tn + self.fn_;
        (self.tp + self.tn) as f64 / total.max(1) as f64
    }

    pub fn fpr(&self) -> f64 {
        self.fp as f64 / (self.fp + self.tn).max(1) as f64
    }

    pub fn fnr(&self) -> f64 {
        self.fn_ as f64 / (self.fn_ + self.tp).max(1) as f64
    }
}

fn score(traces: &[LabelledTrace], mut flag: impl FnMut(&[f64]) -> bool) -> Confusion {
    let mut c = Confusion::default();
    for t in traces {
        match (flag(&t.series), t.has_failslow) {
            (true, true) => c.tp += 1,
            (true, false) => c.fp += 1,
            (false, false) => c.tn += 1,
            (false, true) => c.fn_ += 1,
        }
    }
    c
}

/// Run the three detectors over labelled traces and render a table.
pub fn detection_table(title: &str, paper_note: &str, traces: &[LabelledTrace]) -> String {
    // SlideWindow flags a job when >=3 points deviate (debounce single
    // jitters, as any practical deployment must).
    let sw = score(traces, |xs| window::detect_slow_points(xs, 20, 0.10).len() >= 3);
    // Raw BOCD: any change-point flags the job (the paper's FPR source).
    let bocd = score(traces, |xs| {
        !detect_changepoints(xs, BocdConfig::default()).is_empty()
    });
    // BOCD+V: verified episodes only.
    let bocdv = score(traces, |xs| {
        !detect_episodes(xs, BocdConfig::default()).is_empty()
    });

    let row = |name: &str, c: Confusion| {
        vec![
            name.to_string(),
            format!("{:.1} ({}/{})", 100.0 * c.accuracy(), c.tp + c.tn, c.tp + c.tn + c.fp + c.fn_),
            format!("{:.1} ({}/{})", 100.0 * c.fpr(), c.fp, c.fp + c.tn),
            format!("{:.1} ({}/{})", 100.0 * c.fnr(), c.fn_, c.fn_ + c.tp),
        ]
    };
    let mut out = format!("{title}\n");
    out.push_str(&plot::table(
        &["Algorithm", "Accuracy^ (%)", "FPR_ (%)", "FNR_ (%)"],
        &[row("SlideWindow", sw), row("BOCD", bocd), row("BOCD+V", bocdv)],
    ));
    out.push_str(paper_note);
    out.push('\n');
    out
}

/// Table 4 — computation fail-slows.
pub fn tab4(args: &Args) -> String {
    let fast = args.bool_or("fast", true);
    let n = if fast { 60 } else { 392 };
    let iters = args.usize_or("iters", 300);
    let traces = labelled_traces(false, n, iters, args.u64_or("seed", 44));
    detection_table(
        &format!("Table 4 — detection algorithms on computation fail-slows ({n} jobs)"),
        "paper: SlideWindow 99.5/0.0/25.0 | BOCD 77.8/18.4/0.0 | BOCD+V 100.0/0.0/0.0",
        &traces,
    )
}

/// Table 5 — communication fail-slows.
pub fn tab5(args: &Args) -> String {
    let fast = args.bool_or("fast", true);
    let n = if fast { 60 } else { 107 };
    let iters = args.usize_or("iters", 300);
    let traces = labelled_traces(true, n, iters, args.u64_or("seed", 55));
    detection_table(
        &format!("Table 5 — detection algorithms on communication fail-slows ({n} jobs)"),
        "paper: SlideWindow 93.5/1.5/12.2 | BOCD 69.2/34.0/0.0 | BOCD+V 99.1/0.0/2.3",
        &traces,
    )
}

/// Detection-quality assertion used by integration tests and EXPERIMENTS.md:
/// BOCD+V must dominate both baselines in accuracy and hold ~zero FPR.
pub fn bocdv_dominates(traces: &[LabelledTrace]) -> (Confusion, Confusion, Confusion) {
    let sw = score(traces, |xs| window::detect_slow_points(xs, 20, 0.10).len() >= 3);
    let bocd = score(traces, |xs| !detect_changepoints(xs, BocdConfig::default()).is_empty());
    let bocdv = score(traces, |xs| !detect_episodes(xs, BocdConfig::default()).is_empty());
    (sw, bocd, bocdv)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig12_errors_small() {
        let out = fig12(&Args::parse(["--iters".to_string(), "80".into()]));
        assert!(out.contains("max error"));
        // Parse the max error and require the paper's ballpark (<2%).
        let max_line = out.lines().find(|l| l.starts_with("max error")).unwrap();
        let pct: f64 = max_line
            .split_whitespace()
            .nth(2)
            .unwrap()
            .trim_end_matches('%')
            .parse()
            .unwrap();
        assert!(pct < 2.0, "estimation error too high: {pct}%");
    }

    #[test]
    fn bocdv_beats_baselines_on_comm_traces() {
        let traces = labelled_traces(true, 40, 250, 99);
        let (sw, bocd, bocdv) = bocdv_dominates(&traces);
        assert!(bocdv.accuracy() >= sw.accuracy(), "sw {sw:?} vs bocdv {bocdv:?}");
        assert!(bocdv.accuracy() > bocd.accuracy(), "bocd {bocd:?} vs bocdv {bocdv:?}");
        assert!(bocdv.accuracy() >= 0.9, "{bocdv:?}");
        assert!(bocdv.fpr() <= 0.05, "{bocdv:?}");
        // Raw BOCD shows its characteristic high FPR (the paper's point).
        assert!(bocd.fpr() > bocdv.fpr(), "bocd {bocd:?} bocdv {bocdv:?}");
    }

    #[test]
    fn comp_traces_mostly_clean() {
        let traces = labelled_traces(false, 40, 200, 7);
        let slow = traces.iter().filter(|t| t.has_failslow).count();
        assert!(slow <= 6, "computation fail-slows should be rare: {slow}/40");
    }
}
