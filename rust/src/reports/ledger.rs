//! Node-health ledger campaign report (`ledger` id, beyond the paper):
//! one chronically flaky fleet — a slice of the shared pool flares on
//! heavy-tailed Pareto gaps — run three ways:
//!
//! 1. **memoryless** — the straggler-aware Arbiter with a *shadow* ledger
//!    that only observes (quarantines stay at the fixed 4-epoch floor, so
//!    training outcomes are bit-identical to the pre-ledger engine);
//! 2. **health-weighted** — placement prefers the nodes with the highest
//!    decayed health scores ([`Policy::HealthWeighted`]);
//! 3. **predictive-quarantine** — repeat offenders quarantine longer and
//!    admissions avoid nodes whose predicted next incident lands inside
//!    the job's horizon ([`Policy::PredictiveQuarantine`]).
//!
//! The scorecard compares mean JCT slowdown, repeat-incident counts, and
//! the arbitration denial rate across the arms, and charges what-if
//! contention blame back to the nodes of the predictive run
//! ([`crate::whatif::attribution::ledger_blame`]).

use crate::cluster::Policy;
use crate::fleet::{run_fleet, run_fleet_traced, FleetConfig, FleetReport};
use crate::mitigate::planner::Overheads;
use crate::simkit::from_secs;
use crate::util::cli::Args;
use crate::whatif::attribution::ledger_blame;

/// The flaky-fleet campaign configuration all three arms share: only the
/// policy differs. Ski-rental overheads are dialed down so flare-struck
/// jobs reliably escalate to S3 swaps within the short horizon — the node
/// churn the quarantine comparison needs.
pub(crate) fn campaign_config(args: &Args, policy: Policy) -> FleetConfig {
    let mut cfg = super::fleet::config_from_args(args);
    cfg.jobs = args.usize_or("jobs", 24);
    cfg.iters = args.usize_or("iters", 60);
    cfg.compare = false;
    cfg.policy = Some(policy);
    cfg.failslow_boost = args.f64_or("boost", 6.0);
    cfg.spare_frac = args.f64_or("spare", 0.6);
    cfg.epoch_len = args.usize_or("epoch-len", 5);
    cfg.stagger = args.f64_or("stagger", 2.0);
    cfg.ledger = true;
    cfg.flaky_frac = args.f64_or("flaky", 0.5);
    cfg.flaky_alpha = args.f64_or("alpha", 1.0);
    cfg.falcon.overheads = Overheads {
        adjust_microbatch_s: 0.5,
        adjust_topology_s: 2.0,
        replan_s: 4.0,
        ckpt_restart_s: 10.0,
    };
    cfg.falcon.topology_pause = from_secs(5.0);
    cfg.falcon.restart_cost = from_secs(30.0);
    cfg
}

fn arm_row(name: &str, r: &FleetReport) -> String {
    let ledger = r.ledger.as_ref();
    let (repeats, total) =
        ledger.map_or((0, 0), |l| (l.repeat_incidents(), l.total_incidents()));
    let denial = r.cluster.as_ref().map_or(0.0, |c| 100.0 * c.denial_rate());
    format!(
        "  {name:>21}: slowdown {:.3}x | incidents {total:>3} ({repeats:>3} repeat) | \
         denial {denial:>5.1}% | {:.1} jobs/s\n",
        r.mean_slowdown, r.jobs_per_sec
    )
}

pub fn ledger(args: &Args) -> String {
    let memoryless = run_fleet(&campaign_config(args, Policy::StragglerAware));
    let hw = run_fleet(&campaign_config(args, Policy::HealthWeighted));
    let pq_cfg = campaign_config(args, Policy::PredictiveQuarantine);
    let (pq, trace) = run_fleet_traced(&pq_cfg);

    let mut out = format!(
        "LEDGER — flaky fleet ({} jobs x {} iters, flaky {:.0}%, Pareto alpha {}) \
         under three Arbiter policies\n\n",
        pq_cfg.jobs,
        pq_cfg.iters,
        100.0 * pq_cfg.flaky_frac,
        pq_cfg.flaky_alpha
    );
    out.push_str(&arm_row("memoryless", &memoryless));
    out.push_str(&arm_row("health-weighted", &hw));
    out.push_str(&arm_row("predictive-quarantine", &pq));

    let base = memoryless.ledger.as_ref().map_or(0, |l| l.repeat_incidents());
    let pq_repeats = pq.ledger.as_ref().map_or(0, |l| l.repeat_incidents());
    if base > 0 {
        out.push_str(&format!(
            "\nrepeat incidents: {base} memoryless -> {pq_repeats} predictive \
             ({:+.0}%)\n",
            100.0 * (pq_repeats as f64 - base as f64) / base as f64
        ));
    }
    out.push_str(&format!(
        "JCT delta (predictive vs memoryless): {:+.1}%\n",
        100.0 * (pq.mean_slowdown / memoryless.mean_slowdown.max(1e-9) - 1.0)
    ));

    // Charge contention blame back to the predictive run's nodes.
    if let Some(mut l) = pq.ledger.clone() {
        ledger_blame(&trace, &mut l);
        let mut blamed: Vec<(usize, f64)> = l
            .nodes
            .iter()
            .filter(|(_, h)| h.blame_s > 0.0)
            .map(|(&n, h)| (n, h.blame_s))
            .collect();
        blamed.sort_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
        if blamed.is_empty() {
            out.push_str("contention blame: no cross-job contention recorded\n");
        } else {
            out.push_str("top contention-blamed nodes (what-if attribution):\n");
            for (n, s) in blamed.iter().take(5) {
                out.push_str(&format!("  node {n:>3}: ~{s:.1} s of victim time\n"));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string()))
    }

    #[test]
    fn ledger_report_renders_all_three_arms() {
        let args = parse(&["--jobs", "8", "--iters", "30", "--workers", "2", "--seed", "5"]);
        let out = ledger(&args);
        assert!(out.contains("LEDGER"), "{out}");
        assert!(out.contains("memoryless"), "{out}");
        assert!(out.contains("health-weighted"), "{out}");
        assert!(out.contains("predictive-quarantine"), "{out}");
        assert!(out.contains("JCT delta"), "{out}");
    }

    #[test]
    fn predictive_quarantine_cuts_repeat_incidents() {
        // The satellite acceptance gate: on the chronically flaky fleet,
        // predictive quarantine must cut repeat incidents by >= 30%
        // relative to the memoryless baseline (summed over two seeds to
        // dampen single-seed luck; each run is individually deterministic).
        let mut base_total = 0u32;
        let mut pq_total = 0u32;
        for seed in ["11", "12"] {
            let args = parse(&[
                "--jobs", "16", "--iters", "60", "--workers", "2", "--seed", seed,
            ]);
            let memoryless = run_fleet(&campaign_config(&args, Policy::StragglerAware));
            let pq = run_fleet(&campaign_config(&args, Policy::PredictiveQuarantine));
            base_total +=
                memoryless.ledger.as_ref().map_or(0, |l| l.repeat_incidents());
            pq_total += pq.ledger.as_ref().map_or(0, |l| l.repeat_incidents());
        }
        assert!(base_total > 0, "flaky fleet produced no repeat incidents");
        assert!(
            (pq_total as f64) <= 0.7 * base_total as f64,
            "predictive quarantine did not cut repeats >= 30%: {pq_total} vs {base_total}"
        );
    }
}
