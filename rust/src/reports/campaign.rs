//! Characterization campaign (Fig 1 + Table 1): reproduce §3's probing
//! methodology — many small sampling jobs plus a batch of at-scale jobs,
//! fail-slows drawn from the paper-calibrated `InjectionModel`, detection
//! via FALCON-DETECT's BOCD+V on the per-job iteration-time series.

use crate::detect::detector::detect_episodes;
use crate::detect::BocdConfig;
use crate::inject::{FailSlowKind, InjectionModel};
use crate::pipeline::{ModelDims, ParallelConfig, Workload};
use crate::sim::{JobSpec, TrainingSim};
use crate::simkit::{mins, HOUR};
use crate::util::cli::Args;
use crate::util::plot;
use crate::util::rng::Rng;
use crate::util::stats;

/// Outcome of one probe job.
#[derive(Clone, Debug)]
pub struct ProbeResult {
    pub root_causes: Vec<FailSlowKind>,
    pub slowdown_pct: f64,
    pub episode_mins: Vec<f64>,
    pub detected_episodes: usize,
}

/// One campaign class: (label, jobs, spec-builder, iters).
pub struct CampaignClass {
    pub label: &'static str,
    pub jobs: usize,
    pub nodes: usize,
    pub cfg: ParallelConfig,
    pub model: &'static str,
    pub iters: usize,
}

pub fn classes(fast: bool) -> Vec<CampaignClass> {
    let scale = if fast { 8 } else { 1 };
    vec![
        // §3.2: 392 single-node GPT2-11B jobs, (2T,1D,2P) on 4 H800s.
        CampaignClass {
            label: "1-Node",
            jobs: 392 / scale,
            nodes: 1,
            cfg: ParallelConfig::new(2, 1, 2),
            model: "gpt2-11b",
            iters: if fast { 400 } else { 1500 },
        },
        // §3.3: 107 four-node GPT2-7B jobs, (2T,4D,1P).
        CampaignClass {
            label: "4-Node",
            jobs: 107 / scale.min(4),
            nodes: 4,
            cfg: ParallelConfig::new(2, 4, 1),
            model: "gpt2-7b",
            iters: if fast { 400 } else { 1500 },
        },
        // §3.4: 27 at-scale jobs (>=512 GPUs), (8T,16D,4P) = 512 GPUs.
        CampaignClass {
            label: "At Scale (>=512 GPUs)",
            jobs: 27 / scale.min(3),
            nodes: 64,
            cfg: ParallelConfig::new(8, 16, 4),
            model: "gpt2-13b",
            iters: if fast { 250 } else { 800 },
        },
    ]
}

/// Run one probe job and classify it.
pub fn run_probe(class: &CampaignClass, seed: u64) -> ProbeResult {
    // GPUs per node follows the class's node count (probes used 4-GPU and
    // 2-GPU slices; at-scale jobs full 8-GPU nodes).
    let gpus_per_node = class.cfg.world().div_ceil(class.nodes);
    let spec = JobSpec {
        cfg: class.cfg,
        wl: Workload { model: ModelDims::gpt2(class.model), micro_batch: 1, microbatches: 8 },
        gpus_per_node,
        gpu_class: crate::fabric::GpuClass::H800,
        mfu: 0.42,
        jitter: 0.015,
        spike_p: 0.01,
        seed,
    };
    let mut sim = TrainingSim::new(spec);

    // Sample this job's fail-slows from the §3-calibrated model. At-scale
    // jobs are exclusive (no CPU contention — Table 1).
    let model = if class.nodes >= 64 {
        InjectionModel { p_cpu_1node: 0.0, p_gpu_1node: 0.02, p_congestion_per_link: 0.013,
                         mean_comm_duration: 72 * crate::simkit::MINUTE,
                         ..InjectionModel::default() }
    } else {
        InjectionModel::default()
    };
    let mut rng = Rng::new(seed ^ 0xCA);
    let horizon = (sim.ideal_iter_s * class.iters as f64 * 1e6) as u64;
    let events =
        model.sample_job(class.nodes, sim.spec.gpus_per_node, horizon.max(HOUR / 4), &mut rng);
    let root_causes: Vec<FailSlowKind> = {
        let mut k: Vec<FailSlowKind> = events.iter().map(|e| e.kind).collect();
        k.sort_by_key(|k| k.name());
        k.dedup();
        k
    };
    let episode_mins = events.iter().map(|e| mins(e.duration)).collect();
    sim.inject(events);

    let outcome = sim.run(class.iters);
    let series: Vec<f64> = outcome
        .timeline
        .points
        .iter()
        .map(|&(_, thpt)| 1.0 / thpt.max(1e-9))
        .collect();
    let detected = detect_episodes(&series, BocdConfig::default());

    ProbeResult {
        root_causes,
        slowdown_pct: outcome.slowdown_pct(),
        episode_mins,
        detected_episodes: detected.len(),
    }
}

pub struct CampaignSummary {
    pub label: &'static str,
    pub no_failslow: usize,
    pub cpu: usize,
    pub gpu: usize,
    pub net: usize,
    pub multi: usize,
    pub total: usize,
    pub avg_slowdown_pct: f64,
    pub durations_mins: Vec<f64>,
    pub slowdowns: Vec<f64>,
}

pub fn run_campaign(fast: bool, seed: u64) -> Vec<CampaignSummary> {
    classes(fast)
        .iter()
        .map(|class| {
            let mut s = CampaignSummary {
                label: class.label,
                no_failslow: 0,
                cpu: 0,
                gpu: 0,
                net: 0,
                multi: 0,
                total: class.jobs,
                avg_slowdown_pct: 0.0,
                durations_mins: Vec::new(),
                slowdowns: Vec::new(),
            };
            let mut slow_sum = 0.0;
            let mut slow_n = 0usize;
            for j in 0..class.jobs {
                let r = run_probe(class, seed.wrapping_add(j as u64 * 7919));
                match r.root_causes.len() {
                    0 => s.no_failslow += 1,
                    1 => match r.root_causes[0] {
                        FailSlowKind::CpuContention => s.cpu += 1,
                        FailSlowKind::GpuDegradation => s.gpu += 1,
                        // The §3 campaign characterizes slowdowns; hangs
                        // are injected only by scripted scenarios.
                        FailSlowKind::NetworkCongestion | FailSlowKind::CommHang => s.net += 1,
                    },
                    _ => s.multi += 1,
                }
                if !r.root_causes.is_empty() {
                    slow_sum += r.slowdown_pct;
                    slow_n += 1;
                    s.durations_mins.extend(r.episode_mins);
                    s.slowdowns.push(r.slowdown_pct);
                }
            }
            s.avg_slowdown_pct = if slow_n > 0 { slow_sum / slow_n as f64 } else { 0.0 };
            s
        })
        .collect()
}

pub fn tab1(args: &Args) -> String {
    let fast = args.bool_or("fast", true);
    let seed = args.u64_or("seed", 2024);
    let summaries = run_campaign(fast, seed);
    let rows: Vec<Vec<String>> = [
        ("No fail-slow", 0usize),
        ("CPU Contention", 1),
        ("GPU Degradation", 2),
        ("Network Congestion", 3),
        ("Multiple Issues", 4),
        ("Total # Jobs", 5),
        ("Avg. JCT Slowdown", 6),
    ]
    .iter()
    .map(|&(name, row)| {
        let mut cells = vec![name.to_string()];
        for s in &summaries {
            let v = match row {
                0 => s.no_failslow.to_string(),
                1 => s.cpu.to_string(),
                2 => s.gpu.to_string(),
                3 => s.net.to_string(),
                4 => s.multi.to_string(),
                5 => s.total.to_string(),
                _ => format!("{:.2}%", s.avg_slowdown_pct),
            };
            cells.push(v);
        }
        cells
    })
    .collect();
    let mut out = String::from(
        "Table 1 — Root causes and JCT slowdown of fail-slow issues (campaign reproduction)\n",
    );
    out.push_str(&plot::table(
        &["Category", "1-Node", "4-Node", "At Scale (>=512 GPUs)"],
        &rows,
    ));
    out.push_str(
        "\npaper: 386/4/2/0/0 of 392 | 64/1/0/42/0 of 107 | 11/0/0/13/3 of 27; \
         slowdowns 11.79% / 15.45% / 34.59%\n",
    );
    out
}

pub fn fig1(args: &Args) -> String {
    let fast = args.bool_or("fast", true);
    let seed = args.u64_or("seed", 2024);
    let summaries = run_campaign(fast, seed);

    let mut out = String::from("Figure 1 — fail-slow occurrence, JCT impact, duration CDF\n\n");

    // Left: occurrence rates.
    let labels: Vec<String> = summaries.iter().map(|s| s.label.to_string()).collect();
    let rates: Vec<f64> = summaries
        .iter()
        .map(|s| 100.0 * (s.total - s.no_failslow) as f64 / s.total.max(1) as f64)
        .collect();
    out.push_str(&plot::bar_chart("occurrence rate (% of jobs)", &labels, &rates, 40));

    // Center: JCT slowdown distribution of slow jobs at scale.
    let at_scale = &summaries[2];
    if !at_scale.slowdowns.is_empty() {
        let over50 = at_scale.slowdowns.iter().filter(|&&s| s > 50.0).count();
        out.push_str(&format!(
            "\nJCT impact at scale: mean {:.1}%, {:.0}% of slow jobs delayed >50%\n",
            at_scale.avg_slowdown_pct,
            100.0 * over50 as f64 / at_scale.slowdowns.len() as f64
        ));
    }

    // Right: duration CDF across all classes.
    let mut durs: Vec<f64> = summaries.iter().flat_map(|s| s.durations_mins.clone()).collect();
    if durs.is_empty() {
        durs.push(0.0);
    }
    let cdf = stats::ecdf(&durs, 20);
    let xs: Vec<f64> = cdf.iter().map(|&(v, _)| v).collect();
    let ys: Vec<f64> = cdf.iter().map(|&(_, f)| f).collect();
    out.push_str(&plot::line_chart("\nfail-slow duration CDF (minutes)", &xs, &ys, 50, 10));
    out.push_str(&plot::csv(
        &["duration_min", "cdf"],
        &cdf.iter().map(|&(v, f)| vec![v, f]).collect::<Vec<_>>(),
    ));
    out.push_str(&format!(
        "median {:.1} min, p90 {:.1} min (paper: tens of seconds to ~10 h, \
         small-job mean 10–24 min, at-scale 72 min)\n",
        stats::median(&durs),
        stats::quantile(&durs, 0.9)
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn probe_classifies_single_node() {
        let class = &classes(true)[0];
        // Over a handful of seeds, most jobs are clean (paper: 386/392).
        let mut clean = 0;
        for seed in 0..12 {
            let r = run_probe(class, seed * 131);
            if r.root_causes.is_empty() {
                clean += 1;
                assert!(r.slowdown_pct < 8.0, "clean job slowed {:.2}%", r.slowdown_pct);
            }
        }
        assert!(clean >= 9, "only {clean}/12 clean");
    }

    #[test]
    fn injected_jobs_slow_and_detected() {
        let class = &classes(true)[1]; // 4-node, congestion-prone
        let mut hit = false;
        for seed in 0..24 {
            let r = run_probe(class, seed * 977 + 5);
            if r.root_causes.contains(&FailSlowKind::NetworkCongestion)
                && r.slowdown_pct > 5.0
            {
                hit = true;
                assert!(r.detected_episodes > 0, "fail-slow not detected");
                break;
            }
        }
        assert!(hit, "no congested 4-node probe in 24 seeds");
    }

    #[test]
    fn tab1_renders() {
        let out = tab1(&Args::parse(["--fast".to_string(), "--seed".into(), "3".into()]));
        assert!(out.contains("Network Congestion"));
        assert!(out.contains("Avg. JCT Slowdown"));
    }
}
