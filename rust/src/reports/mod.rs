//! Experiment report generators: one function per paper table and figure.
//!
//! Each generator runs the corresponding experiment on the simulator (or
//! the live trainer / real buffers where the artifact is measurable on this
//! host), then renders an ASCII figure + CSV block mirroring the paper's
//! plot. `bench_tables`/`bench_figures` and the `falcon report` CLI all
//! dispatch through [`generate`].
//!
//! Layout: [`ALL`] lists the paper reports in paper order; ids map to
//! generators in [`generate`]'s match. Submodules group generators by
//! paper section:
//!
//! - [`campaign`] — the §3 characterization campaign (Fig 1, Table 1).
//! - [`cases`] — §3.2 case studies and monitor signatures (Fig 2–8, Tab 2).
//! - [`detection`] — FALCON-DETECT accuracy (Fig 12, Tables 4–5).
//! - [`mitigation`] — S2/S3 effectiveness and compound cases (Fig 13–17),
//!   plus the beyond-paper S5 malleable-parallelism demo (`replan` id):
//!   every S3/S4 grant denied, relief from in-place swaps + an asymmetric
//!   micro-batch re-split (see [`crate::mitigate::replan`]).
//! - [`overhead`] — monitor/validation overhead (Fig 18–19, Table 6).
//! - [`scale`] — scale sensitivity (Fig 20, Table 7).
//! - [`fleet`] — beyond-paper fleet campaigns (`fleet`, `fleet_cluster`
//!   ids): many concurrent jobs, optionally on one shared cluster with
//!   contended uplinks and arbitrated mitigation (see [`crate::cluster`]).
//! - [`whatif`] — beyond-paper counterfactual attribution (`whatif` id):
//!   record a run, replay fault-removed/mitigation-changed variants, and
//!   attribute the JCT delay (see [`crate::whatif`]).
//! - [`diagnosis`] — beyond-paper hang-vs-slow taxonomy scorecard
//!   (`diagnosis` id): per-class precision/recall/latency and a confusion
//!   matrix against scripted ground truth (see [`crate::diagnose`]).
//! - [`ledger`] — beyond-paper node-health ledger campaign (`ledger` id):
//!   a chronically flaky fleet under memoryless, health-weighted, and
//!   predictive-quarantine policies (see [`crate::ledger`]).
//!
//! Conventions: every generator takes [`Args`] (knobs like `--iters`,
//! `--seed`, `--fast`) and returns a self-contained string — no generator
//! writes files or mutates global state, so reports compose in any order.

pub mod campaign;
pub mod cases;
pub mod detection;
pub mod diagnosis;
pub mod fleet;
pub mod ledger;
pub mod mitigation;
pub mod overhead;
pub mod scale;
pub mod whatif;

use crate::util::cli::Args;

/// All report ids, in paper order.
pub const ALL: &[&str] = &[
    "fig1", "tab1", "fig2", "fig3", "fig4", "tab2", "fig5", "fig6", "fig8",
    "fig12", "tab4", "tab5", "fig13", "fig14", "fig15", "fig16", "fig17",
    "fig18", "tab6", "fig19", "fig20", "tab7",
];

/// Beyond-paper report ids (kept out of [`ALL`] so `report all` stays the
/// paper set; `falcon list` prints them under their own section).
pub const BEYOND_PAPER: &[&str] =
    &["fleet", "fleet_cluster", "whatif", "diagnosis", "replan", "ledger"];

/// Generate one report by id. `args` supplies knobs like `--iters`,
/// `--seed`, `--fast`.
pub fn generate(id: &str, args: &Args) -> String {
    match id {
        "fig1" => campaign::fig1(args),
        "tab1" => campaign::tab1(args),
        "fig2" => cases::fig2(args),
        "fig3" => cases::fig3(args),
        "fig4" => cases::fig4(args),
        "tab2" => cases::tab2(args),
        "fig5" => cases::fig5(args),
        "fig6" => cases::fig6(args),
        "fig8" => cases::fig8(args),
        "fig12" => detection::fig12(args),
        "tab4" => detection::tab4(args),
        "tab5" => detection::tab5(args),
        "fig13" => mitigation::fig13(args),
        "fig14" => mitigation::fig14(args),
        "fig15" => mitigation::fig15(args),
        "fig16" => mitigation::fig16(args),
        "fig17" => mitigation::fig17(args),
        "fig18" => overhead::fig18(args),
        "tab6" => overhead::tab6(args),
        "fig19" => overhead::fig19(args),
        "fig20" => scale::fig20(args),
        "tab7" => scale::tab7(args),
        // Beyond-paper reports (not in ALL so `report all` stays the paper
        // set; the `falcon fleet` subcommand is the primary entry).
        "fleet" => fleet::fleet(args),
        "fleet_cluster" => fleet::fleet_cluster(args),
        "whatif" => whatif::whatif(args),
        "diagnosis" => diagnosis::diagnosis(args),
        "replan" => mitigation::replan(args),
        "ledger" => ledger::ledger(args),
        other => format!(
            "unknown report '{other}'; available: {ALL:?} \
             plus beyond-paper: {BEYOND_PAPER:?}\n"
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_smokes_every_id_under_fast() {
        // Every id in ALL plus the beyond-paper reports must render
        // non-empty output without panicking. Knobs are dialed down so the
        // whole sweep stays debug-test-friendly.
        let args = Args::parse(
            [
                "--fast", "true", "--iters", "30", "--samples", "600", "--jobs", "6",
                "--workers", "2",
            ]
            .iter()
            .map(|s| s.to_string()),
        );
        for id in ALL.iter().chain(BEYOND_PAPER) {
            let out = generate(id, &args);
            assert!(out.len() > 50, "{id} produced: {out}");
            assert!(!out.contains("unknown report"), "{id} fell through the registry");
        }
    }

    #[test]
    fn unknown_id_reports_availability() {
        let out = generate("fig99", &Args::parse([]));
        assert!(out.contains("unknown report"));
        assert!(out.contains("fleet_cluster"), "beyond-paper ids must be mentioned: {out}");
        assert!(out.contains("diagnosis"), "beyond-paper ids must be mentioned: {out}");
        assert!(out.contains("ledger"), "beyond-paper ids must be mentioned: {out}");
    }
}
