//! Diagnosis accuracy report (`diagnosis` id, beyond-paper): the
//! hang-vs-slow taxonomy of [`crate::diagnose`] scored against scripted
//! ground truth.
//!
//! Every labeled single-job fault scenario in the library runs end to end
//! under FALCON; each recorded episode diagnosis (class + culprit) is
//! scored against the scenario's fault script restricted to the
//! diagnosis's own evidence window. The report emits a per-scenario tally,
//! a truth x predicted confusion matrix, and per-class precision /
//! recall / detection-latency — the numbers the class-labeled accuracy
//! suite (and BENCH_fleet.json) pin.
//!
//! Ground-truth labeling follows the classifier's dominance order
//! (hang > comm > compute) with two deliberate allowances, both
//! documented in docs/DIAGNOSIS.md:
//!
//! - **comm/compute ambiguity**: when a congestion fault and a compute
//!   fault are scripted concurrently (no hang), either family is a true
//!   pin — S3 may already have rerouted around the congested path, so
//!   which symptom dominates the op-trace legitimately depends on the
//!   mitigation history. The hang dominance is never relaxed.
//! - **uplink/link equivalence**: on a two-node job an uplink fault and a
//!   pinned inter-node path produce identical ring evidence; a `link:a-b`
//!   pin is accepted for an `uplink:u` truth when `u` is an endpoint
//!   (and vice versa).

use std::collections::BTreeMap;

use crate::diagnose::{AnomalyClass, CLASSES};
use crate::inject::{FailSlowEvent, FailSlowKind, Target};
use crate::scenario::{find, OutcomeDiagnosis, ScenarioError};
use crate::simkit::secs;
use crate::util::cli::Args;
use crate::util::plot;

/// Library scenarios with a scripted single-job fault ground truth (the
/// fleet entries aggregate many jobs and carry no per-episode diagnosis;
/// fault-free scenarios have nothing to label). Library order.
pub const LABELED: &[&str] = &[
    "cpu-contention",
    "gpu-thermal",
    "net-congestion",
    "compound-cascade",
    "slow-leak-gpu",
    "flapping-link",
    "transient-spikes",
    "cascading-leaf-congestion",
    "correlated-storm",
    "hang",
    "hang-then-recover",
    "slow-masking-a-hang",
];

/// One episode diagnosis scored against the fault script.
#[derive(Clone, Debug)]
pub struct Scored {
    pub scenario: String,
    /// Predicted class token (`AnomalyClass::token`).
    pub predicted: String,
    /// Predicted culprit label (`Culprit::label`).
    pub culprit: String,
    /// Ground-truth class token for the diagnosis window ("none" when no
    /// scripted fault was active — a false positive).
    pub truth: String,
    /// Class AND culprit both match the script.
    pub correct: bool,
    /// Diagnosis time minus the onset of the earliest truth-family event
    /// active in the window (only meaningful when `correct`).
    pub latency_s: f64,
}

/// Per-class tallies over every scored diagnosis.
#[derive(Clone, Debug)]
pub struct ClassStats {
    pub class: &'static str,
    /// Diagnoses whose ground-truth label is this class.
    pub truth_n: usize,
    /// Diagnoses whose predicted label is this class.
    pub predicted_n: usize,
    /// Predicted == truth == this class AND the culprit pin matched.
    pub correct: usize,
    /// Mean detection latency over the correct diagnoses (seconds).
    pub mean_latency_s: f64,
}

impl ClassStats {
    pub fn precision(&self) -> f64 {
        ratio(self.correct, self.predicted_n)
    }

    pub fn recall(&self) -> f64 {
        ratio(self.correct, self.truth_n)
    }
}

fn ratio(num: usize, den: usize) -> f64 {
    if den == 0 {
        1.0 // vacuous: nothing to get wrong
    } else {
        num as f64 / den as f64
    }
}

/// Full evaluation over the labeled library set.
#[derive(Clone, Debug)]
pub struct Evaluation {
    /// (scenario, diagnoses, correct) per labeled scenario, library order.
    pub scenarios: Vec<(String, usize, usize)>,
    pub scored: Vec<Scored>,
    /// One row per [`CLASSES`] entry, in taxonomy order.
    pub stats: Vec<ClassStats>,
    /// (truth, predicted) -> count, including any "none" truth row.
    pub confusion: BTreeMap<(String, String), usize>,
}

impl Evaluation {
    /// Correct pins / truth-labeled diagnoses for one class token (1.0
    /// when the class never occurs — vacuously accurate).
    pub fn accuracy(&self, class: &str) -> f64 {
        self.stats.iter().find(|s| s.class == class).map_or(1.0, ClassStats::recall)
    }

    /// Correct pins / all scored diagnoses.
    pub fn overall_accuracy(&self) -> f64 {
        let correct = self.scored.iter().filter(|s| s.correct).count();
        ratio(correct, self.scored.len())
    }
}

/// Run every labeled scenario (at its native horizon, or `iters_override`
/// iterations when non-zero) and score each recorded episode diagnosis.
pub fn evaluate(iters_override: usize) -> Result<Evaluation, ScenarioError> {
    let mut scored: Vec<Scored> = Vec::new();
    let mut scenarios = Vec::new();
    for name in LABELED {
        let spec = find(name).ok_or_else(|| {
            ScenarioError::field("scenario", format!("'{name}' is not a library scenario"))
        })?;
        let spec = if iters_override > 0 { spec.iters(iters_override) } else { spec };
        // The exact event list the run injects (ramps/recurrences expanded
        // against the same horizon build_sim uses).
        let events = spec.build_sim()?.events.clone();
        let out = spec.run()?;
        let before = scored.len();
        for d in &out.diagnosis {
            scored.push(score(name, d, &events));
        }
        let n = scored.len() - before;
        let ok = scored[before..].iter().filter(|s| s.correct).count();
        scenarios.push((name.to_string(), n, ok));
    }

    let mut confusion: BTreeMap<(String, String), usize> = BTreeMap::new();
    for s in &scored {
        *confusion.entry((s.truth.clone(), s.predicted.clone())).or_insert(0) += 1;
    }
    let stats = CLASSES
        .iter()
        .map(|c| {
            let tok = c.token();
            let truth_n = scored.iter().filter(|s| s.truth == tok).count();
            let predicted_n = scored.iter().filter(|s| s.predicted == tok).count();
            let hits: Vec<&Scored> =
                scored.iter().filter(|s| s.truth == tok && s.correct).collect();
            let mean_latency_s = if hits.is_empty() {
                0.0
            } else {
                hits.iter().map(|s| s.latency_s).sum::<f64>() / hits.len() as f64
            };
            ClassStats { class: tok, truth_n, predicted_n, correct: hits.len(), mean_latency_s }
        })
        .collect();
    Ok(Evaluation { scenarios, scored, stats, confusion })
}

/// Score one diagnosis against the events active in its evidence window.
fn score(scenario: &str, d: &OutcomeDiagnosis, events: &[FailSlowEvent]) -> Scored {
    let (w_lo, w_hi) = d.window_s;
    let mut hang: Vec<&FailSlowEvent> = Vec::new();
    let mut comm: Vec<&FailSlowEvent> = Vec::new();
    let mut compute: Vec<&FailSlowEvent> = Vec::new();
    for e in events.iter().filter(|e| secs(e.start) < w_hi && secs(e.end()) > w_lo) {
        match e.kind {
            FailSlowKind::CommHang => hang.push(e),
            FailSlowKind::NetworkCongestion => comm.push(e),
            FailSlowKind::CpuContention | FailSlowKind::GpuDegradation => compute.push(e),
        }
    }

    let truth = if !hang.is_empty() {
        if comm.is_empty() && compute.is_empty() {
            AnomalyClass::CommHang.token()
        } else {
            AnomalyClass::SlowMaskingHang.token()
        }
    } else if !comm.is_empty() && !compute.is_empty() {
        // Concurrent congestion + compute faults: either family is a true
        // pin (see the module docs) — take the prediction's side when its
        // culprit matches that family, otherwise comm dominates.
        if d.class == AnomalyClass::ComputeSlow.token() && culprit_matches(&d.culprit, &compute) {
            AnomalyClass::ComputeSlow.token()
        } else {
            AnomalyClass::CommSlow.token()
        }
    } else if !comm.is_empty() {
        AnomalyClass::CommSlow.token()
    } else if !compute.is_empty() {
        AnomalyClass::ComputeSlow.token()
    } else {
        "none"
    };

    // Both hang classes pin the wedged path, so both score against the
    // hang family's targets.
    let family: &[&FailSlowEvent] = match truth {
        "comm-hang" | "slow-masking-hang" => &hang,
        "comm-slow" => &comm,
        "compute-slow" => &compute,
        _ => &[],
    };
    let correct = truth == d.class && culprit_matches(&d.culprit, family);
    let onset = family.iter().map(|e| secs(e.start)).fold(f64::INFINITY, f64::min);
    let latency_s = if onset.is_finite() { (d.t_min * 60.0 - onset).max(0.0) } else { 0.0 };
    Scored {
        scenario: scenario.to_string(),
        predicted: d.class.clone(),
        culprit: d.culprit.clone(),
        truth: truth.to_string(),
        correct,
        latency_s,
    }
}

fn target_label(t: Target) -> String {
    match t {
        Target::Gpu(g) => format!("gpu:{g}"),
        Target::Node(n) => format!("node:{n}"),
        Target::Uplink(u) => format!("uplink:{u}"),
        Target::Link(a, b) => {
            let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
            format!("link:{lo}-{hi}")
        }
    }
}

/// Node endpoints named by a comm culprit label (`None` for gpu:/node:).
fn endpoints(label: &str) -> Option<Vec<usize>> {
    if let Some(rest) = label.strip_prefix("uplink:") {
        return rest.parse().ok().map(|u| vec![u]);
    }
    if let Some(rest) = label.strip_prefix("link:") {
        let (a, b) = rest.split_once('-')?;
        return Some(vec![a.parse().ok()?, b.parse().ok()?]);
    }
    None
}

/// Does the predicted culprit pin any of the family's scripted targets?
/// Exact label match, or the uplink/link equivalence from the module docs
/// (one side names an uplink whose node is an endpoint of the other's
/// path — never link-to-link overlap).
fn culprit_matches(pred: &str, family: &[&FailSlowEvent]) -> bool {
    family.iter().any(|e| {
        let truth = target_label(e.target);
        if pred == truth {
            return true;
        }
        match (endpoints(pred), endpoints(&truth)) {
            (Some(p), Some(t)) => {
                (p.len() == 1 || t.len() == 1) && p.iter().any(|n| t.contains(n))
            }
            _ => false,
        }
    })
}

/// The `diagnosis` report: run the labeled set and render the scorecard.
pub fn diagnosis(args: &Args) -> String {
    let iters = args.usize_or("iters", 0); // 0 = native horizons
    let mut out = String::new();
    out.push_str(&format!(
        "DIAGNOSIS — hang-vs-slow taxonomy scored against scripted ground truth\n\
         labeled scenarios: {} (single-job fault entries of the library{})\n\n",
        LABELED.len(),
        if iters > 0 { format!(", clipped to {iters} iters") } else { String::new() }
    ));
    let eval = match evaluate(iters) {
        Ok(e) => e,
        Err(e) => {
            out.push_str(&format!("evaluation failed: {e}\n"));
            return out;
        }
    };

    let rows: Vec<Vec<String>> = eval
        .scenarios
        .iter()
        .map(|(name, n, ok)| vec![name.clone(), n.to_string(), ok.to_string()])
        .collect();
    out.push_str(&plot::table(&["scenario", "diagnoses", "correct"], &rows));

    out.push_str("\nconfusion (rows = scripted truth, cols = predicted):\n");
    let mut header: Vec<&str> = vec!["truth \\ predicted"];
    header.extend(CLASSES.iter().map(|c| c.token()));
    let mut truths: Vec<String> = CLASSES.iter().map(|c| c.token().to_string()).collect();
    if eval.confusion.keys().any(|(t, _)| t == "none") {
        truths.push("none".to_string());
    }
    let rows: Vec<Vec<String>> = truths
        .iter()
        .map(|t| {
            let mut row = vec![t.clone()];
            for c in CLASSES {
                let n = eval.confusion.get(&(t.clone(), c.token().to_string()));
                row.push(n.copied().unwrap_or(0).to_string());
            }
            row
        })
        .collect();
    out.push_str(&plot::table(&header, &rows));

    out.push_str("\nper-class scorecard (recall = correct pins / truth-labeled):\n");
    let rows: Vec<Vec<String>> = eval
        .stats
        .iter()
        .map(|s| {
            vec![
                s.class.to_string(),
                s.truth_n.to_string(),
                s.predicted_n.to_string(),
                s.correct.to_string(),
                format!("{:.3}", s.precision()),
                format!("{:.3}", s.recall()),
                format!("{:.1}", s.mean_latency_s),
            ]
        })
        .collect();
    out.push_str(&plot::table(
        &["class", "truth", "predicted", "correct", "precision", "recall", "latency_s"],
        &rows,
    ));
    out.push_str(&format!(
        "\noverall accuracy: {:.3} over {} diagnoses\n",
        eval.overall_accuracy(),
        eval.scored.len()
    ));

    let misses: Vec<&Scored> = eval.scored.iter().filter(|s| !s.correct).collect();
    if !misses.is_empty() {
        out.push_str("\nmisclassified:\n");
        for m in misses {
            out.push_str(&format!(
                "  {}: predicted {} culprit={} (truth {})\n",
                m.scenario, m.predicted, m.culprit, m.truth
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::library;

    #[test]
    fn labeled_set_is_exactly_the_single_job_fault_scenarios() {
        // New library entries with faults must join the labeled set (and
        // with it the accuracy gate below) automatically-by-failure here.
        let expect: Vec<String> = library::all()
            .into_iter()
            .filter(|s| s.fleet.is_none() && !s.faults.is_empty())
            .map(|s| s.name)
            .collect();
        let got: Vec<String> = LABELED.iter().map(|s| s.to_string()).collect();
        assert_eq!(got, expect);
    }

    fn diags(name: &str) -> Vec<OutcomeDiagnosis> {
        find(name).expect("library scenario").run().expect("scenario runs").diagnosis
    }

    #[test]
    fn hang_scenarios_pin_class_and_culprit() {
        let d = diags("hang");
        let first = d.first().expect("hang episode diagnosed");
        assert_eq!((first.class.as_str(), first.culprit.as_str()), ("comm-hang", "link:1-2"));

        let d = diags("hang-then-recover");
        let first = d.first().expect("transient hang diagnosed");
        assert_eq!((first.class.as_str(), first.culprit.as_str()), ("comm-hang", "uplink:2"));
    }

    #[test]
    fn masked_hang_is_first_compute_slow_then_unmasked() {
        let d = diags("slow-masking-a-hang");
        assert!(d.len() >= 2, "open + escalation re-diagnosis expected: {d:?}");
        assert_eq!((d[0].class.as_str(), d[0].culprit.as_str()), ("compute-slow", "gpu:2"));
        let unmasked = d
            .iter()
            .find(|x| x.class == "slow-masking-hang")
            .expect("escalation re-diagnosis sees the hang under the slow");
        assert_eq!(unmasked.culprit, "link:0-3");
    }

    #[test]
    fn slow_scenarios_pin_class_and_culprit() {
        let d = diags("net-congestion");
        let first = d.first().expect("congestion diagnosed");
        assert_eq!((first.class.as_str(), first.culprit.as_str()), ("comm-slow", "uplink:2"));

        let d = diags("cpu-contention");
        let first = d.first().expect("contention diagnosed");
        assert_eq!((first.class.as_str(), first.culprit.as_str()), ("compute-slow", "node:0"));
    }

    #[test]
    fn diagnosis_window_sits_inside_the_scripted_hang() {
        let spec = find("hang").expect("library scenario");
        let events = spec.build_sim().expect("builds").events.clone();
        let ev = events.first().expect("one hang event");
        let d = diags("hang");
        let first = d.first().expect("diagnosed");
        let (w_lo, w_hi) = first.window_s;
        assert!(w_hi > w_lo, "{:?}", first.window_s);
        // The evidence window overlaps the scripted hang and starts no
        // earlier than one healthy iteration before its onset.
        assert!(w_hi > secs(ev.start) && w_lo < secs(ev.end()), "{:?}", first.window_s);
        assert!(w_lo >= secs(ev.start) - 10.0, "window {w_lo} vs onset {}", secs(ev.start));
    }

    #[test]
    fn per_class_accuracy_meets_the_bar_on_the_labeled_library() {
        let eval = evaluate(0).expect("labeled set runs");
        let misses: Vec<&Scored> = eval.scored.iter().filter(|s| !s.correct).collect();
        for c in CLASSES {
            let s = eval
                .stats
                .iter()
                .find(|s| s.class == c.token())
                .expect("stats row per class");
            assert!(s.truth_n >= 1, "{} never labeled — the library lost coverage", c.token());
            assert!(
                s.recall() >= 0.99,
                "{} recall {:.3} ({}/{}) — misses: {misses:?}",
                c.token(),
                s.recall(),
                s.correct,
                s.truth_n
            );
            assert!(
                s.precision() >= 0.99,
                "{} precision {:.3} ({}/{}) — misses: {misses:?}",
                c.token(),
                s.precision(),
                s.correct,
                s.predicted_n
            );
        }
        assert!(eval.overall_accuracy() >= 0.99, "misses: {misses:?}");
        // Health-derived evidence cannot fire without a scripted fault.
        assert!(
            eval.scored.iter().all(|s| s.truth != "none"),
            "false positives: {misses:?}"
        );
        assert_eq!(eval.accuracy("comm-hang"), 1.0, "misses: {misses:?}");
    }
}
