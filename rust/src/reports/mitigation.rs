//! Mitigation effectiveness experiments: Fig 13–17, plus the beyond-paper
//! S5 malleable-parallelism demo (`replan`).

use crate::coordinator::{run_with_falcon, ActionKind, Falcon, FalconConfig};
use crate::inject::{FailSlowEvent, FailSlowKind, Severity, Target};
use crate::mitigate::{microbatch, Strategy};
use crate::pipeline::{ModelDims, ParallelConfig, Workload};
use crate::sim::{demo_spec, JobSpec, TrainingSim};
use crate::simkit::{from_secs, MINUTE};
use crate::util::cli::Args;
use crate::util::plot;

fn spec(cfg: ParallelConfig, nodes: usize, model: &str, seed: u64) -> JobSpec {
    JobSpec {
        cfg,
        wl: Workload { model: ModelDims::gpt2(model), micro_batch: 1, microbatches: 8 },
        gpus_per_node: cfg.world().div_ceil(nodes),
        gpu_class: crate::fabric::GpuClass::H800,
        mfu: 0.42,
        jitter: 0.0,
        spike_p: 0.0,
        seed,
    }
}

/// Slowdown factor of a sim against its own ideal, averaged over `iters`.
fn slowdown(sim: &mut TrainingSim, iters: usize) -> f64 {
    let outcome = sim.run(iters);
    outcome.slowdown()
}

/// Mitigated-vs-unmitigated slowdown reduction (%) for one scenario built
/// by `build`. S2-only evaluation applies the micro-batch solve directly
/// (isolating the strategy, as §7.3 does).
fn s2_reduction(build: impl Fn() -> TrainingSim, iters: usize) -> (f64, f64, f64) {
    // Unmitigated.
    let mut sim = build();
    let slow = slowdown(&mut sim, iters);
    // Mitigated: profile replica speeds, re-solve allocation.
    let mut sim = build();
    sim.step();
    let times = sim.replica_microbatch_times();
    let total = sim.spec.wl.microbatches * sim.spec.cfg.dp;
    sim.set_microbatch_alloc(microbatch::solve(&times, total).m);
    let mitigated = slowdown(&mut sim, iters);
    let reduction = if slow > 1.0 {
        100.0 * (slow - mitigated) / (slow - 1.0)
    } else {
        0.0
    };
    (slow, mitigated, reduction)
}

/// Fig 13 — S2 vs severity (W/M/S) across DP in {2,4,8} on one 8-GPU node.
pub fn fig13(args: &Args) -> String {
    let iters = args.usize_or("iters", 60);
    let mut labels = Vec::new();
    let mut slows = Vec::new();
    let mut mitigs = Vec::new();
    let mut rows = Vec::new();
    for (dp, tp) in [(2usize, 4usize), (4, 2), (8, 1)] {
        for sev in Severity::ALL {
            let build = || {
                let mut sim =
                    TrainingSim::new(spec(ParallelConfig::new(tp, dp, 1), 1, "gpt2-7b", 13));
                sim.inject(vec![FailSlowEvent {
                    kind: FailSlowKind::GpuDegradation,
                    target: Target::Gpu(0),
                    start: 0,
                    duration: 10_000 * MINUTE,
                    scale: sev.scale(),
                }]);
                sim
            };
            let (slow, mitig, red) = s2_reduction(build, iters);
            labels.push(format!("DP{dp}-{}", sev.name()));
            slows.push(slow);
            mitigs.push(mitig);
            rows.push(vec![dp as f64, sev.scale(), slow, mitig, red]);
        }
    }

    let mut out = String::from(
        "Figure 13 — micro-batch adjustment (S2) vs fail-slow severity and DP width\n",
    );
    out.push_str("  (bars: iteration slowdown factor; left=unmitigated, right=with S2)\n");
    let mut merged_labels = Vec::new();
    let mut merged = Vec::new();
    for (i, l) in labels.iter().enumerate() {
        merged_labels.push(format!("{l} raw"));
        merged.push(slows[i]);
        merged_labels.push(format!("{l} +S2"));
        merged.push(mitigs[i]);
    }
    out.push_str(&plot::bar_chart("slowdown (x)", &merged_labels, &merged, 40));
    out.push_str(&plot::csv(&["dp", "sev_scale", "slow_x", "mitigated_x", "reduction_pct"], &rows));
    let avg: f64 = rows.iter().map(|r| r[4]).sum::<f64>() / rows.len() as f64;
    let max = rows.iter().map(|r| r[4]).fold(0.0, f64::max);
    out.push_str(&format!(
        "mean reduction {avg:.1}%, max {max:.1}% (paper: 55.3–77.8% means, up to 82.9%)\n"
    ));
    out
}

/// Fig 14 — S2 vs number of degraded DP groups (0–4 of 4).
pub fn fig14(args: &Args) -> String {
    let iters = args.usize_or("iters", 60);
    let mut rows = Vec::new();
    for n_slow in 0..=4usize {
        let build = || {
            let mut sim = TrainingSim::new(spec(ParallelConfig::new(2, 4, 1), 1, "gpt2-7b", 14));
            let evs: Vec<FailSlowEvent> = (0..n_slow)
                .map(|d| FailSlowEvent {
                    kind: FailSlowKind::GpuDegradation,
                    // Degrade one GPU of replica d's TP pair: GPUs 2d.
                    target: Target::Gpu(2 * d),
                    start: 0,
                    duration: 10_000 * MINUTE,
                    scale: 0.52, // ~1.9x replica slowdown, the paper's case
                })
                .collect();
            sim.inject(evs);
            sim
        };
        let (slow, mitig, red) = s2_reduction(build, iters);
        rows.push(vec![n_slow as f64, slow, mitig, red]);
    }
    let mut out = String::from("Figure 14 — S2 vs number of fail-slow DP groups (of 4)\n");
    out.push_str(&plot::csv(&["n_slow_groups", "slow_x", "mitigated_x", "reduction_pct"], &rows));
    out.push_str(&plot::bar_chart(
        "reduction (%)",
        &rows.iter().map(|r| format!("{} slow", r[0] as usize)).collect::<Vec<_>>(),
        &rows.iter().map(|r| r[3].max(0.0)).collect::<Vec<_>>(),
        40,
    ));
    out.push_str(
        "paper: best 79.7% with 1 slow group (1.9x -> 1.2x); no room when all 4 degraded\n",
    );
    out
}

/// Fig 15 — topology adjustment (S3) vs congestion severity, PP in {4, 8}
/// on 2 nodes x 8 GPUs.
pub fn fig15(args: &Args) -> String {
    let iters = args.usize_or("iters", 400);
    let mut rows = Vec::new();
    for (pp, dp) in [(4usize, 4usize), (8, 2)] {
        for sev in Severity::ALL {
            // 16 ranks, one per node: stage-0's DP ring crosses the
            // congested pair in both PP depths. Deeper pipelines shard the
            // gradient volume (Eq. 9: N/(P*T)), so congestion hurts less
            // and S3 has less to recover — the paper's PP=4 > PP=8 shape.
            let nodes = 16;
            let build = |mitigate: bool| {
                let cfg = ParallelConfig::new(1, dp, pp);
                let mut sim = TrainingSim::new(spec(cfg, nodes, "gpt2-7b", 15 + pp as u64));
                sim.spec.jitter = 0.01;
                let onset = sim.ideal_iter_s * 20.0;
                // Congest the path between the first two nodes (carries DP
                // when dp>1, PP when dp=1 — both the paper's cases).
                sim.inject(vec![FailSlowEvent {
                    kind: FailSlowKind::NetworkCongestion,
                    target: Target::Link(0, 1),
                    start: from_secs(onset),
                    duration: 10_000 * MINUTE,
                    scale: sev.scale() * 0.5,
                }]);
                let mut fc = FalconConfig::default();
                fc.mitigate = mitigate;
                fc.overheads.adjust_topology_s = 20.0;
                fc.topology_pause = from_secs(20.0);
                let _ = run_with_falcon(&mut sim, fc, iters);
                // Slowdown over the post-onset window.
                let outcome_thpt = sim.timeline.mean_throughput();
                1.0 / outcome_thpt / sim.ideal_iter_s
            };
            let slow = build(false);
            let mitig = build(true);
            let red = if slow > 1.0 { 100.0 * (slow - mitig) / (slow - 1.0) } else { 0.0 };
            rows.push(vec![pp as f64, sev.scale(), slow, mitig, red]);
        }
    }
    let mut out =
        String::from("Figure 15 — topology adjustment (S3) vs congestion severity and PP depth\n");
    out.push_str(&plot::csv(
        &["pp", "sev_scale", "slow_x", "mitigated_x", "reduction_pct"],
        &rows,
    ));
    let mean4: f64 = rows.iter().filter(|r| r[0] == 4.0).map(|r| r[4]).sum::<f64>() / 3.0;
    let mean8: f64 = rows.iter().filter(|r| r[0] == 8.0).map(|r| r[4]).sum::<f64>() / 3.0;
    out.push_str(&format!(
        "mean reduction: PP=4 {mean4:.1}%, PP=8 {mean8:.1}% \
         (paper: 53.7% and 24.8%, max 61.5%; PP=4 benefits more)\n"
    ));
    out
}

/// Fig 16 — straggler consolidation with 1–4 congested links on (4D,4P).
///
/// The paper congests links that slow pairs of GPUs in PP stages and shows
/// consolidation bounds the damage: 16 GPUs (4D,4P) on 8 nodes, stage s on
/// the node pair (2s, 2s+1) whose interconnect carries that stage's DP
/// ring. Congesting k of those pairs slows k stages; the S3 planner swaps
/// nodes so the slow paths collapse onto the fewest stages (and dodges
/// them entirely when clean pairs remain).
pub fn fig16(args: &Args) -> String {
    let iters = args.usize_or("iters", 40);
    let cfg = ParallelConfig::new(1, 4, 4);
    let mut rows = Vec::new();
    for n_links in 1..=4usize {
        let build = || {
            let mut sim = TrainingSim::new(spec(cfg, 8, "gpt2-7b", 16));
            let evs: Vec<FailSlowEvent> = (0..n_links)
                .map(|s| FailSlowEvent {
                    // Each injected straggler slows one GPU pair's stage —
                    // the per-stage slowdown Fig 11's makespan analysis is
                    // about. (Congestion-on-links in our volume-accurate
                    // model hits the all-reduce MAX instead, where
                    // consolidation is a no-op by construction; see
                    // EXPERIMENTS.md for the substitution note.)
                    kind: FailSlowKind::GpuDegradation,
                    target: Target::Gpu(s * 4),
                    start: 0,
                    duration: 10_000 * MINUTE,
                    scale: 0.6,
                })
                .collect();
            sim.inject(evs);
            sim
        };
        // Unmitigated: k congested stage interconnects.
        let mut sim = build();
        let congested = slowdown(&mut sim, iters);
        // Mitigated: S3 swap search (up to k+1 swaps) — an uplink travels
        // with its node, so the only lever is CONSOLIDATING congested
        // nodes into the fewest PP stages (Fig 11's argument).
        let mut sim = build();
        sim.step();
        let plan = crate::mitigate::topology::plan(&mut sim, n_links + 1);
        crate::mitigate::topology::apply(&mut sim, &plan, 0);
        let mitigated = slowdown(&mut sim, iters);
        rows.push(vec![n_links as f64, congested, mitigated]);
    }
    let mut out = String::from("Figure 16 — straggler consolidation across PP stages (4D,4P)\n");
    out.push_str(&plot::csv(&["n_slow_links", "congested_x", "mitigated_x"], &rows));
    for r in &rows {
        out.push_str(&format!(
            "  {} slow link(s): {:.2}x -> {:.2}x\n",
            r[0] as usize, r[1], r[2]
        ));
    }
    out.push_str(
        "paper: 1.6x->1.3x (1 link), 1.7x->1.3x (2 links), 1.9x->1.7x (3), no room at 4\n",
    );
    out
}

/// Fig 17 — compound computation + communication fail-slow handled by the
/// multi-level planner (S3 at the congestion, S2 at the GPU degradation,
/// restart once the impact passes the threshold). The fault script is a
/// declarative [`crate::scenario::ScenarioSpec`]; only the ski-rental
/// overheads are figure-specific.
pub fn fig17(args: &Args) -> String {
    use crate::scenario::{FaultSpec, ScenarioSpec};
    let iters = args.usize_or("iters", 900);
    let scenario = ScenarioSpec::new("fig17-compound", 2, 4, 2)
        .nodes(8)
        .seed(17)
        .iters(iters)
        .jitter(0.01)
        .spike_p(0.0)
        .fault(FaultSpec::new(
            FailSlowKind::NetworkCongestion,
            Target::Link(0, 1),
            0.08,
            1.2,
            0.25,
        ))
        .fault(FaultSpec::new(
            FailSlowKind::GpuDegradation,
            Target::Gpu(2),
            0.4,
            1.2,
            0.45,
        ));
    let run = |mitigate: bool| {
        let mut sim = scenario.build_sim().expect("fig17 scenario is valid");
        let span = sim.ideal_iter_s * iters as f64;
        let mut fc = FalconConfig::default();
        fc.mitigate = mitigate;
        fc.overheads.adjust_topology_s = 25.0;
        fc.topology_pause = from_secs(25.0);
        fc.overheads.ckpt_restart_s = span * 0.35;
        fc.restart_cost = from_secs(span * 0.12);
        let falcon = run_with_falcon(&mut sim, fc, iters);
        (sim, falcon)
    };

    let (sim_m, falcon) = run(true);
    let (sim_u, _) = run(false);

    let t: Vec<f64> = sim_m.timeline.xs_mins();
    let y: Vec<f64> = sim_m.timeline.ys();
    let mut out =
        String::from("Figure 17 — compound comp+comm fail-slow under multi-level mitigation\n");
    out.push_str(&plot::line_chart("throughput WITH FALCON (iters/s)", &t, &y, 64, 9));
    let tu: Vec<f64> = sim_u.timeline.xs_mins();
    let yu: Vec<f64> = sim_u.timeline.ys();
    out.push_str(&plot::line_chart("throughput WITHOUT (iters/s)", &tu, &yu, 64, 9));
    out.push_str("actions:\n");
    for a in &falcon.actions {
        out.push_str(&format!(
            "  t={:.1}min iter={} {:?}\n",
            crate::simkit::mins(a.at),
            a.iter,
            match &a.what {
                crate::coordinator::ActionKind::Diagnosed(d) => format!("Diagnosed({:?})", d.kind),
                other => format!("{other:?}"),
            }
        ));
    }
    let mean_m = sim_m.timeline.mean_throughput();
    let mean_u = sim_u.timeline.mean_throughput();
    out.push_str(&format!(
        "mean throughput: {mean_m:.3} with FALCON vs {mean_u:.3} without ({:.1}% recovered)\n",
        100.0 * (mean_m - mean_u) / mean_u.max(1e-12)
    ));
    out
}

/// Beyond-paper — S5 malleable-parallelism replan under a saturated
/// healthy-node pool. Every S3/S4 request the coordinator files is denied
/// (no spares, no healthy restart target), so the only relief left is
/// re-planning within the job's own allocation: in-place node swaps plus a
/// non-uniform micro-batch re-split across the now-asymmetric replicas.
/// Three arms share one fault script: mitigation off, the grant-denied
/// ladder without S5, and the same dead end with S5 enabled.
pub fn replan(args: &Args) -> String {
    let iters = args.usize_or("iters", 400);
    let run = |mitigate: bool, replan: bool| {
        let mut spec = demo_spec(ParallelConfig::new(8, 2, 2), 51);
        spec.jitter = 0.0;
        spec.spike_p = 0.0;
        let mut sim = TrainingSim::new(spec);
        let ideal = sim.ideal_iter_s;
        sim.inject(vec![FailSlowEvent {
            kind: FailSlowKind::NetworkCongestion,
            target: Target::Link(0, 1),
            start: from_secs(ideal * 20.0),
            duration: 600 * MINUTE,
            scale: 0.15,
        }]);
        let mut fc = FalconConfig::default();
        fc.mitigate = mitigate;
        fc.defer_heavy = true;
        fc.replan = replan;
        fc.overheads.adjust_topology_s = 10.0;
        fc.overheads.replan_s = 30.0;
        fc.overheads.ckpt_restart_s = 50_000.0;
        fc.replan_pause = from_secs(30.0);
        let mut falcon = Falcon::new(fc);
        for _ in 0..iters {
            let obs = sim.step();
            falcon.on_iteration(&mut sim, obs.iter, obs.duration_s());
            if let Some(req) = falcon.take_request() {
                falcon.note_grant(&mut sim, req, false); // pool exhausted
            }
        }
        (sim, falcon)
    };
    let (sim_off, _) = run(false, false);
    let (sim_s2, falcon_s2) = run(true, false);
    let (sim_s5, falcon_s5) = run(true, true);

    let mut out = String::from(
        "S5 replan — graceful degradation with the healthy-node pool exhausted\n",
    );
    out.push_str(&plot::line_chart(
        "throughput WITH S5 (iters/s)",
        &sim_s5.timeline.xs_mins(),
        &sim_s5.timeline.ys(),
        64,
        9,
    ));
    out.push_str(&plot::line_chart(
        "throughput WITHOUT S5, grants denied (iters/s)",
        &sim_s2.timeline.xs_mins(),
        &sim_s2.timeline.ys(),
        64,
        9,
    ));
    let denials = |f: &Falcon| {
        f.actions.iter().filter(|a| matches!(a.what, ActionKind::Denied(_, _))).count()
    };
    let replans = |f: &Falcon| {
        f.applied_strategies()
            .iter()
            .filter(|&&s| s == Strategy::ReplanParallelism)
            .count()
    };
    out.push_str(&format!(
        "denials: {} without S5, {} with S5; S5 applications: {}\n",
        denials(&falcon_s2),
        denials(&falcon_s5),
        replans(&falcon_s5),
    ));
    let healthy = 1.0 / sim_off.ideal_iter_s;
    let t_off = sim_off.timeline.mean_throughput();
    let t_s2 = sim_s2.timeline.mean_throughput();
    let t_s5 = sim_s5.timeline.mean_throughput();
    let recovery = |t: f64| 100.0 * (t - t_off) / (healthy - t_off).max(1e-12);
    out.push_str(&format!(
        "mean throughput: {t_off:.3} off, {t_s2:.3} denied ladder, {t_s5:.3} with S5 \
         (healthy {healthy:.3})\n",
    ));
    out.push_str(&format!(
        "slowdown recovered vs off: {:.1}% without S5, {:.1}% with S5 \
         (target: >=40% with every grant denied)\n",
        recovery(t_s2),
        recovery(t_s5),
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick() -> Args {
        Args::parse(["--iters".to_string(), "40".into()])
    }

    #[test]
    fn replan_report_recovers_under_denial() {
        let out = replan(&Args::parse(["--iters".to_string(), "400".into()]));
        let line = out.lines().find(|l| l.starts_with("slowdown recovered")).unwrap();
        let with_s5: f64 = line
            .split("without S5,")
            .nth(1)
            .unwrap()
            .split('%')
            .next()
            .unwrap()
            .trim()
            .parse()
            .unwrap();
        assert!(with_s5 >= 40.0, "S5 recovery too low: {with_s5}%\n{out}");
    }

    #[test]
    fn fig13_s2_reduces_slowdown() {
        let out = fig13(&quick());
        let mean_line = out.lines().find(|l| l.starts_with("mean reduction")).unwrap();
        let mean: f64 =
            mean_line.split_whitespace().nth(2).unwrap().trim_end_matches("%,").parse().unwrap();
        assert!(mean > 30.0, "S2 mean reduction too low: {mean}% \n{out}");
    }

    #[test]
    fn fig14_monotone_room() {
        let out = fig14(&quick());
        // Extract reductions for 1..4 slow groups from the CSV.
        let reds: Vec<f64> = out
            .lines()
            .filter(|l| l.chars().next().map(|c| c.is_ascii_digit()).unwrap_or(false))
            .map(|l| l.split(',').last().unwrap().parse::<f64>().unwrap())
            .collect();
        assert_eq!(reds.len(), 5);
        assert!(reds[1] > reds[3], "room must shrink: {reds:?}");
        assert!(reds[4].abs() < 15.0, "no room with all slow: {reds:?}");
    }

    #[test]
    fn fig16_consolidation_helps_when_possible() {
        let out = fig16(&Args::parse(["--iters".to_string(), "25".into()]));
        let rows: Vec<Vec<f64>> = out
            .lines()
            .filter(|l| l.chars().next().map(|c| c.is_ascii_digit()).unwrap_or(false))
            .map(|l| l.split(',').map(|x| x.parse::<f64>().unwrap()).collect())
            .collect();
        // With 2 stragglers, consolidation must improve on scattered.
        assert!(rows[1][2] <= rows[1][1] + 1e-9, "{rows:?}");
    }
}
