//! Case studies (Fig 2–6), the communication-stability table (Table 2) and
//! the recurring-period illustration (Fig 8).

use crate::fabric::{Cluster, ClusterSpec, GpuClass, GpuId, LinkClass};
use crate::inject::{FailSlowEvent, FailSlowKind, Target};
use crate::pipeline::{ModelDims, ParallelConfig, Workload};
use crate::sim::{JobSpec, TrainingSim};
use crate::simkit::from_secs;
use crate::util::cli::Args;
use crate::util::plot;
use crate::util::rng::Rng;
use crate::util::stats;

fn case_sim(cfg: ParallelConfig, model: &str, nodes_hint: usize, seed: u64) -> TrainingSim {
    let gpus_per_node = cfg.world().div_ceil(nodes_hint).max(1);
    TrainingSim::new(JobSpec {
        cfg,
        wl: Workload { model: ModelDims::gpt2(model), micro_batch: 1, microbatches: 8 },
        gpus_per_node,
        gpu_class: GpuClass::H800,
        mfu: 0.42,
        jitter: 0.015,
        spike_p: 0.01,
        seed,
    })
}

/// Run `iters`, sampling throughput + an auxiliary signal every iteration.
fn run_case(
    sim: &mut TrainingSim,
    iters: usize,
    mut aux: impl FnMut(&TrainingSim) -> f64,
) -> (Vec<f64>, Vec<f64>, Vec<f64>, Vec<f64>) {
    let mut t_mins = Vec::new();
    let mut thpt = Vec::new();
    let mut sm = Vec::new();
    let mut extra = Vec::new();
    for _ in 0..iters {
        let obs = sim.step();
        t_mins.push(crate::simkit::mins(obs.start));
        thpt.push(1e6 / obs.duration as f64);
        sm.push(obs.sm_util * 100.0);
        extra.push(aux(sim));
    }
    (t_mins, thpt, sm, extra)
}

/// Fig 2 — CPU-contention case: two contention bursts, SM util dips,
/// high-CPU job count and CPU satisfaction trace the root cause.
///
/// The experiment is constructed through the declarative scenario API: the
/// library's `cpu-contention` entry IS this figure's fault script.
pub fn fig2(args: &Args) -> String {
    let iters = args.usize_or("iters", 600);
    let Some(spec) = crate::scenario::find("cpu-contention") else {
        return "figure 2 unavailable: library scenario `cpu-contention` missing\n".to_string();
    };
    let mut sim = match spec.iters(iters).build_sim() {
        Ok(sim) => sim,
        Err(e) => return format!("figure 2 unavailable: {e}\n"),
    };
    let (t, thpt, sm, cpu) = run_case(&mut sim, iters, |s| s.cluster.nodes[0].cpu_satisfaction);
    let jobs: Vec<f64> =
        cpu.iter().map(|&c| if c < 0.99 { (1.0 - c) * 20.0 } else { 1.0 }).collect();

    let mut out =
        String::from("Figure 2 — fail-slow from CPU contention (1-node GPT2-11B, 2T1D2P)\n");
    out.push_str(&plot::line_chart("throughput (iters/s)", &t, &thpt, 60, 8));
    out.push_str(&plot::line_chart("GPU SM utilization (%)", &t, &sm, 60, 6));
    out.push_str(&plot::line_chart("# high-CPU colocated jobs", &t, &jobs, 60, 5));
    out.push_str(&plot::line_chart("CPU satisfaction rate", &t, &cpu, 60, 5));
    let drop = 100.0 * (1.0 - thpt.iter().cloned().fold(f64::MAX, f64::min)
        / stats::quantile(&thpt, 0.9));
    out.push_str(&format!("max throughput drop: {drop:.1}% (paper case: 21.6%)\n"));
    out
}

/// Fig 3 — GPU performance degradation (thermal): GPU0 20% slower, 70 C.
pub fn fig3(args: &Args) -> String {
    let iters = args.usize_or("iters", 500);
    let mut sim = case_sim(ParallelConfig::new(2, 1, 2), "gpt2-11b", 1, 3);
    let it = sim.ideal_iter_s;
    sim.inject(vec![FailSlowEvent {
        kind: FailSlowKind::GpuDegradation,
        target: Target::Gpu(0),
        start: 0,
        duration: (it * iters as f64 * 0.3 * 1e6) as u64,
        scale: 0.8,
    }]);
    let (t, thpt, sm, temp) = run_case(&mut sim, iters, |s| s.cluster.gpus[0].temp_c);
    let perf: Vec<f64> = (0..4)
        .map(|g| if g == 0 { 0.8 } else { 1.0 })
        .collect();

    let mut out =
        String::from("Figure 3 — fail-slow from GPU degradation (thermal throttling)\n");
    out.push_str(&plot::line_chart("throughput (iters/s)", &t, &thpt, 60, 8));
    out.push_str(&plot::line_chart("GPU SM utilization (%)", &t, &sm, 60, 6));
    out.push_str(&plot::bar_chart(
        "normalized GPU performance during fail-slow",
        &(0..4).map(|g| format!("GPU{g}")).collect::<Vec<_>>(),
        &perf,
        30,
    ));
    out.push_str(&plot::line_chart("GPU0 temperature (C)", &t, &temp, 60, 5));
    out.push_str("paper case: GPU0 20% slower at ~70C for the first 10 minutes\n");
    out
}

/// Fig 4 — network congestion on a 4-node GPT2-7B job: two events, CNP
/// surges correlate with throughput dips. Built from the library's
/// `net-congestion` scenario.
pub fn fig4(args: &Args) -> String {
    let iters = args.usize_or("iters", 700);
    let Some(spec) = crate::scenario::find("net-congestion") else {
        return "figure 4 unavailable: library scenario `net-congestion` missing\n".to_string();
    };
    let mut sim = match spec.iters(iters).build_sim() {
        Ok(sim) => sim,
        Err(e) => return format!("figure 4 unavailable: {e}\n"),
    };
    let mut last_cnp = 0u64;
    let (t, thpt, sm, cnp_rate) = run_case(&mut sim, iters, |s| {
        let total: u64 = s.cluster.uplinks.iter().map(|u| u.cnp_count).sum();
        let rate = (total - last_cnp) as f64 / 1000.0;
        last_cnp = total;
        rate
    });

    let mut out =
        String::from("Figure 4 — fail-slow from network congestion (4-node GPT2-7B, 2T4D1P)\n");
    out.push_str(&plot::line_chart("throughput (iters/s)", &t, &thpt, 60, 8));
    out.push_str(&plot::line_chart("CNPs sent by NICs (x1000/iter)", &t, &cnp_rate, 60, 6));
    out.push_str(&plot::line_chart("avg GPU SM utilization (%)", &t, &sm, 60, 6));
    let lo = thpt.iter().cloned().fold(f64::MAX, f64::min);
    let hi = stats::quantile(&thpt, 0.9);
    out.push_str(&format!(
        "throughput {hi:.2} -> {lo:.2} iters/s across the two events \
         (paper: 0.57 -> 0.41 -> 0.31)\n"
    ));
    out
}

/// Table 2 — CoV of communication components. RDMA samples include the
/// campaign's congestion episodes (that's what makes its CoV 0.29-class).
pub fn tab2(args: &Args) -> String {
    let n = args.usize_or("samples", 4000);
    let seed = args.u64_or("seed", 7);
    let mut rng = Rng::new(seed);
    let mut cluster = Cluster::new(ClusterSpec::new(4, 8, GpuClass::A100));
    let bytes = 64.0 * 1024.0 * 1024.0;

    let mut rows: Vec<Vec<String>> = Vec::new();
    let mut record = |name: &str, cov: f64, paper: f64| {
        rows.push(vec![name.to_string(), format!("{cov:.2}"), format!("{paper:.2}")]);
    };

    // Intra-GPU / NVL: direct class sampling.
    let a = GpuId { node: 0, index: 0 };
    let same = GpuId { node: 0, index: 1 };
    let covs = |cluster: &mut Cluster, rng: &mut Rng, from: GpuId, to: GpuId, n: usize| {
        let xs: Vec<f64> = (0..n).map(|_| cluster.transfer_time_s(from, to, bytes, rng)).collect();
        stats::cov(&xs)
    };
    record("Intra-GPU (A100)", covs(&mut cluster, &mut rng, a, a, n), 0.01);
    record("Intra-GPU (H800)", {
        let mut c2 = Cluster::new(ClusterSpec::new(1, 8, GpuClass::H800));
        covs(&mut c2, &mut rng, a, a, n)
    }, 0.01);
    record("NVL", covs(&mut cluster, &mut rng, a, same, n), 0.02);
    // PIX: pcie-switch path modeled via its class noise directly.
    let pix: Vec<f64> = (0..n)
        .map(|_| {
            let base = LinkClass::PcieSwitch.latency_s()
                + bytes / (LinkClass::PcieSwitch.gbytes_per_sec(GpuClass::A100) * 1e9);
            base * (1.0 + LinkClass::PcieSwitch.base_cov() * rng.normal()).max(0.05)
        })
        .collect();
    record("PIX", stats::cov(&pix), 0.09);
    // RDMA with intermittent congestion (as the sampling jobs experienced).
    let b = GpuId { node: 1, index: 0 };
    let xs: Vec<f64> = (0..n)
        .map(|i| {
            // ~8% of samples fall inside a congestion episode.
            let congested = (i % 100) < 8;
            cluster.set_uplink_scale(1, if congested { 0.3 } else { 1.0 });
            cluster.transfer_time_s(a, b, bytes, &mut rng)
        })
        .collect();
    cluster.set_uplink_scale(1, 1.0);
    record("RDMA (incl. congestion episodes)", stats::cov(&xs), 0.29);

    let mut out =
        String::from("Table 2 — performance variation (CoV) of communication components\n");
    out.push_str(&plot::table(&["Comm. Type", "CoV (measured)", "CoV (paper)"], &rows));
    out
}

/// Fig 5 — two 1024-GPU jobs failing slow from congestion (LLM steady-ish
/// with early turbulence; MoE with ladder-shaped degradations).
pub fn fig5(args: &Args) -> String {
    let iters = args.usize_or("iters", 400);
    let mut out = String::from("Figure 5 — 1024-GPU jobs under network congestion\n");

    // LLM job: heavy congestion in the initial phase.
    let mut sim = case_sim(ParallelConfig::new(8, 32, 4), "gpt2-13b", 128, 5);
    let span = sim.ideal_iter_s * iters as f64;
    sim.inject(vec![
        FailSlowEvent {
            kind: FailSlowKind::NetworkCongestion,
            target: Target::Uplink(3),
            start: 0,
            duration: (span * 0.3 * 1e6) as u64,
            scale: 0.3,
        },
        FailSlowEvent {
            kind: FailSlowKind::NetworkCongestion,
            target: Target::Uplink(40),
            start: from_secs(span * 0.1),
            duration: (span * 0.1 * 1e6) as u64,
            scale: 0.5,
        },
    ]);
    let (t, thpt, _, _) = run_case(&mut sim, iters, |_| 0.0);
    out.push_str(&plot::line_chart("LLM job throughput (iters/s)", &t, &thpt, 60, 8));

    // MoE job: ladder of persistent congestions through the run.
    let mut sim2 = case_sim(ParallelConfig::new(8, 32, 4), "gpt2-13b", 128, 6);
    let span2 = sim2.ideal_iter_s * iters as f64;
    let mut evs = Vec::new();
    for (i, frac) in [0.1, 0.35, 0.6, 0.8].iter().enumerate() {
        evs.push(FailSlowEvent {
            kind: FailSlowKind::NetworkCongestion,
            target: Target::Uplink(10 + i * 7),
            start: from_secs(span2 * frac),
            duration: (span2 * 0.18 * 1e6) as u64,
            scale: 0.5 - 0.08 * i as f64,
        });
    }
    sim2.inject(evs);
    let (t2, thpt2, _, _) = run_case(&mut sim2, iters, |_| 0.0);
    out.push_str(&plot::line_chart(
        "MoE job throughput (ladder-shaped, iters/s)",
        &t2,
        &thpt2,
        60,
        8,
    ));
    let cov = stats::cov(&thpt2);
    out.push_str(&format!("MoE throughput CoV {cov:.2} (paper: high variance + ladder shape)\n"));
    out
}

/// Fig 6 — compound congestion + thermal throttling on a 1024-GPU job,
/// scripted through the scenario builder (the 1024-GPU footprint is too
/// heavy for the interactive library, so the spec is assembled inline).
pub fn fig6(args: &Args) -> String {
    use crate::scenario::{FaultSpec, ScenarioSpec};
    let iters = args.usize_or("iters", 500);
    let spec = ScenarioSpec::new("fig6-compound", 8, 32, 4)
        .model("gpt2-13b")
        .nodes(128)
        .seed(7)
        .iters(iters)
        // t=62 min analogue: severe congestion, -80% throughput.
        .fault(FaultSpec::new(
            FailSlowKind::NetworkCongestion,
            Target::Uplink(9),
            0.2,
            0.25,
            0.06,
        ))
        // t=80: thermal throttling while congestion unabated.
        .fault(FaultSpec::new(
            FailSlowKind::GpuDegradation,
            Target::Gpu(9 * 8 + 3),
            0.28,
            0.17,
            0.5,
        ))
        // t=120 onward: another two-hour congestion, -85%.
        .fault(FaultSpec::new(
            FailSlowKind::NetworkCongestion,
            Target::Uplink(33),
            0.55,
            0.35,
            0.05,
        ));
    let mut sim = spec.build_sim().expect("fig6 scenario is valid");
    let (t, thpt, sm, _) = run_case(&mut sim, iters, |_| 0.0);

    let mut out =
        String::from("Figure 6 — compound fail-slow (congestion + GPU thermal) at 1024 GPUs\n");
    out.push_str(&plot::line_chart("throughput (iters/s)", &t, &thpt, 60, 8));
    out.push_str(&plot::line_chart("GPU SM utilization (%)", &t, &sm, 60, 6));
    let hi = stats::quantile(&thpt, 0.95);
    let lo = thpt.iter().cloned().fold(f64::MAX, f64::min);
    out.push_str(&format!(
        "worst-case throughput {:.0}% of normal (paper: compound issues cut to ~10%)\n",
        100.0 * lo / hi
    ));
    out
}

/// Fig 8 — recurring communication pattern in the monitor's op log.
pub fn fig8(args: &Args) -> String {
    let iters = args.usize_or("iters", 24);
    let mut sim = case_sim(ParallelConfig::new(2, 2, 2), "gpt2-7b", 1, 8);
    for _ in 0..iters {
        sim.step();
    }
    let log = &sim.monitor.logs[0];
    let kinds = log.op_kinds();
    let period = crate::detect::acf::find_period(&kinds, 16, 0.9).unwrap_or(0);

    let mut out = String::from("Figure 8 — periodic communication pattern (rank 0 op log)\n  ");
    for op in log.ops.iter().take(4 * period.max(3)) {
        out.push_str(&format!("[{} @{:.2}s] ", op.op.name(), crate::simkit::secs(op.at)));
    }
    out.push_str(&format!(
        "\n  ACF-detected recurring period: {period} ops/iteration\n"
    ));
    let acf_vals: Vec<f64> = (1..=8).map(|k| stats::acf(&kinds, k)).collect();
    out.push_str(&plot::csv(
        &["lag", "acf"],
        &acf_vals
            .iter()
            .enumerate()
            .map(|(i, &a)| vec![(i + 1) as f64, a])
            .collect::<Vec<_>>(),
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args() -> Args {
        Args::parse(["--iters".to_string(), "120".into()])
    }

    #[test]
    fn fig2_shows_throughput_drop() {
        let out = fig2(&args());
        assert!(out.contains("CPU contention"));
        assert!(out.contains("max throughput drop"));
    }

    #[test]
    fn fig4_emits_cnps() {
        let out = fig4(&args());
        assert!(out.contains("CNPs"));
    }

    #[test]
    fn tab2_rdma_least_stable() {
        let out = tab2(&Args::parse(["--samples".to_string(), "1500".into()]));
        // RDMA row must carry the largest measured CoV.
        let covs: Vec<f64> = out
            .lines()
            .filter(|l| l.starts_with('|') && !l.contains("Comm. Type") && !l.contains("---"))
            .filter_map(|l| {
                let cells: Vec<&str> = l.split('|').map(str::trim).collect();
                cells.get(2).and_then(|c| c.parse::<f64>().ok())
            })
            .collect();
        assert!(covs.len() >= 4, "{out}");
        let rdma = covs.last().unwrap();
        assert!(covs[..covs.len() - 1].iter().all(|c| c < rdma), "{covs:?}");
    }

    #[test]
    fn fig8_finds_period() {
        let out = fig8(&Args::parse(["--iters".to_string(), "30".into()]));
        assert!(out.contains("recurring period"));
        assert!(!out.contains("period: 0"), "{out}");
    }
}
