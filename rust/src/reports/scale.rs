//! At-scale end-to-end evaluation: Fig 20 and Table 7 — the 64-GPU
//! GPT2-13B (16D,4P) job with two communication and eight computation
//! fail-slows, run twice (with and without FALCON) on the same trace.

use crate::coordinator::{run_with_falcon, Falcon, FalconConfig};
use crate::inject::{FailSlowEvent, FailSlowKind, Target};
use crate::metrics::slowdown_reduction;
use crate::pipeline::{ModelDims, ParallelConfig, Workload};
use crate::sim::{JobSpec, TrainingSim};
use crate::simkit::from_secs;
use crate::util::cli::Args;
use crate::util::plot;
use crate::util::rng::Rng;

/// The Fig 20 injection trace: 8 computation + 2 communication fail-slows
/// of varying severity across the run.
pub fn fig20_trace(span_s: f64, seed: u64) -> Vec<FailSlowEvent> {
    let mut rng = Rng::new(seed);
    let mut evs = Vec::new();
    // 8 computation fail-slows: staggered GPU degradations. Durations are
    // proportionally faithful to Fig 20 (each event spans many tens of
    // iterations, so detection latency is a small fraction of the episode).
    for i in 0..8 {
        let start = span_s * (0.04 + 0.115 * i as f64);
        evs.push(FailSlowEvent {
            kind: FailSlowKind::GpuDegradation,
            target: Target::Gpu((i * 7) % 64),
            start: from_secs(start),
            duration: (span_s * rng.range_f64(0.10, 0.15) * 1e6) as u64,
            scale: rng.range_f64(0.35, 0.7),
        });
    }
    // 2 communication fail-slows (the paper pauses for topology adjustment
    // at t=600 and t=2100 — place them to produce that rhythm).
    for (i, frac) in [0.18, 0.62].iter().enumerate() {
        evs.push(FailSlowEvent {
            kind: FailSlowKind::NetworkCongestion,
            target: Target::Link(2 * i, 2 * i + 1),
            start: from_secs(span_s * frac),
            duration: (span_s * 0.22 * 1e6) as u64,
            scale: 0.3,
        });
    }
    evs.sort_by_key(|e| e.start);
    evs
}

pub struct ScaleRun {
    pub sim: TrainingSim,
    pub falcon: Option<Falcon>,
    pub iters: usize,
}

impl ScaleRun {
    /// Wall-clock throughput in iterations/min — the paper's Table 7
    /// metric. (Mean of per-iteration rates would bias the comparison:
    /// the two runs traverse the same wall-clock fail-slow trace at
    /// different speeds, so indices don't align.)
    pub fn iters_per_min(&self) -> f64 {
        self.iters as f64 / crate::simkit::mins(self.sim.now).max(1e-9)
    }
}

/// Run the 64-GPU job once. `mode`: 0 = healthy (no injections),
/// 1 = fail-slow without FALCON, 2 = fail-slow with FALCON.
pub fn run_scale(iters: usize, mode: u8, seed: u64) -> ScaleRun {
    // 64 GPUs, 8 nodes: (1T,16D,4P) ~ the paper's (16DP,4PP).
    let cfg = ParallelConfig::new(1, 16, 4);
    let mut sim = TrainingSim::new(JobSpec {
        cfg,
        wl: Workload { model: ModelDims::gpt2("gpt2-13b"), micro_batch: 1, microbatches: 16 },
        gpus_per_node: 8,
        gpu_class: crate::fabric::GpuClass::H800,
        mfu: 0.42,
        jitter: 0.01,
        spike_p: 0.01,
        seed,
    });
    let span = sim.ideal_iter_s * iters as f64;
    if mode > 0 {
        sim.inject(fig20_trace(span, 2020));
    }
    let falcon = if mode == 2 {
        let mut fc = FalconConfig::default();
        fc.overheads.adjust_topology_s = 20.0;
        fc.topology_pause = from_secs(20.0);
        fc.overheads.ckpt_restart_s = span; // restart not worth it here
        Some(run_with_falcon(&mut sim, fc, iters))
    } else {
        sim.run(iters);
        None
    };
    ScaleRun { sim, falcon, iters }
}

/// Fig 20 — throughput timelines with/without FALCON + the injection trace.
pub fn fig20(args: &Args) -> String {
    let iters = args.usize_or("iters", 700);
    let seed = args.u64_or("seed", 64);
    let with = run_scale(iters, 2, seed);
    let without = run_scale(iters, 1, seed);

    let mut out = String::from(
        "Figure 20 — 64-GPU GPT2-13B (16D,4P), 8 computation + 2 communication fail-slows\n",
    );
    out.push_str(&plot::line_chart(
        "throughput WITH FALCON (iters/s)",
        &with.sim.timeline.xs_mins(),
        &with.sim.timeline.ys(),
        64,
        9,
    ));
    out.push_str(&plot::line_chart(
        "throughput WITHOUT FALCON (iters/s)",
        &without.sim.timeline.xs_mins(),
        &without.sim.timeline.ys(),
        64,
        9,
    ));
    out.push_str("injected trace:\n");
    for ev in fig20_trace(with.sim.ideal_iter_s * iters as f64, 2020) {
        out.push_str(&format!(
            "  t={:.1}min {:?} {:?} scale {:.2} dur {:.1}min\n",
            crate::simkit::mins(ev.start),
            ev.kind,
            ev.target,
            ev.scale,
            crate::simkit::mins(ev.duration)
        ));
    }
    if let Some(f) = &with.falcon {
        out.push_str(&format!(
            "FALCON actions: {} (strategies: {:?})\n",
            f.actions.len(),
            f.applied_strategies()
        ));
    }
    out
}

/// Table 7 — healthy / fail-slow / mitigated throughput and the slowdown
/// reduction headline.
pub fn tab7(args: &Args) -> String {
    let iters = args.usize_or("iters", 700);
    let seed = args.u64_or("seed", 64);
    let healthy = run_scale(iters, 0, seed).iters_per_min();
    let slow = run_scale(iters, 1, seed).iters_per_min();
    let mitigated = run_scale(iters, 2, seed).iters_per_min();
    let reduction = 100.0 * slowdown_reduction(healthy, slow, mitigated);

    let mut out = String::from("Table 7 — FALCON end-to-end effectiveness (64 GPUs)\n");
    out.push_str(&plot::table(
        &["Healthy Thpt.", "Fail-slow Thpt.", "Mitigated Thpt.", "Slowdown reduced"],
        &[vec![
            format!("{healthy:.1} iters/min"),
            format!("{slow:.1} iters/min"),
            format!("{mitigated:.1} iters/min"),
            format!("{reduction:.1}%"),
        ]],
    ));
    out.push_str("paper: 17.1 / 14.8 / 16.2 iters/min, -60.1% slowdown (1.15x -> 1.05x optimal)\n");
    out.push_str(&format!(
        "JCT vs optimal: fail-slow {:.2}x, mitigated {:.2}x\n",
        healthy / slow,
        healthy / mitigated
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_has_paper_composition() {
        let evs = fig20_trace(3600.0, 1);
        let comp = evs.iter().filter(|e| e.kind.is_compute()).count();
        let comm = evs.iter().filter(|e| !e.kind.is_compute()).count();
        assert_eq!(comp, 8);
        assert_eq!(comm, 2);
    }

    #[test]
    fn falcon_recovers_most_of_the_slowdown() {
        // Short horizon keeps the debug-mode test affordable; recovery grows
        // with episode length relative to detection latency (the 700-iter
        // release run in EXPERIMENTS.md reaches the paper-shape ~50-60%).
        let iters = 400;
        let healthy = run_scale(iters, 0, 9).iters_per_min();
        let slow = run_scale(iters, 1, 9).iters_per_min();
        let mitigated = run_scale(iters, 2, 9).iters_per_min();
        assert!(slow < 0.97 * healthy, "injection must hurt: {slow} vs {healthy}");
        assert!(mitigated > slow, "FALCON must help: {mitigated} vs {slow}");
        let red = slowdown_reduction(healthy, slow, mitigated);
        assert!(red > 0.2, "reduction {red} (paper: 0.601 at full scale)");
    }
}
