//! Discrete-event simulation engine.
//!
//! Drives every at-scale experiment: jobs, fail-slow event onsets/reliefs,
//! detection phases and mitigation actions are all events on one
//! deterministic timeline. Time is `u64` microseconds so event ordering is
//! exact; ties break by insertion sequence for full determinism.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Simulation time in microseconds.
pub type Time = u64;

pub const USEC: Time = 1;
pub const MSEC: Time = 1_000;
pub const SEC: Time = 1_000_000;
pub const MINUTE: Time = 60 * SEC;
pub const HOUR: Time = 60 * MINUTE;

pub fn secs(t: Time) -> f64 {
    t as f64 / SEC as f64
}

pub fn mins(t: Time) -> f64 {
    t as f64 / MINUTE as f64
}

pub fn from_secs(s: f64) -> Time {
    (s * SEC as f64).round().max(0.0) as Time
}

struct Scheduled<E> {
    at: Time,
    seq: u64,
    event: E,
}

impl<E> PartialEq for Scheduled<E> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<E> Eq for Scheduled<E> {}
impl<E> PartialOrd for Scheduled<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Scheduled<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert for earliest-first.
        other
            .at
            .cmp(&self.at)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Deterministic event queue with a monotonically advancing clock.
pub struct Sim<E> {
    now: Time,
    seq: u64,
    heap: BinaryHeap<Scheduled<E>>,
}

impl<E> Default for Sim<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> Sim<E> {
    pub fn new() -> Self {
        Sim { now: 0, seq: 0, heap: BinaryHeap::new() }
    }

    pub fn now(&self) -> Time {
        self.now
    }

    /// Schedule `event` at absolute time `at` (clamped to now).
    pub fn schedule_at(&mut self, at: Time, event: E) {
        let at = at.max(self.now);
        self.heap.push(Scheduled { at, seq: self.seq, event });
        self.seq += 1;
    }

    /// Schedule `event` after a delay.
    pub fn schedule_in(&mut self, delay: Time, event: E) {
        self.schedule_at(self.now.saturating_add(delay), event);
    }

    /// Pop the next event, advancing the clock.
    pub fn next(&mut self) -> Option<(Time, E)> {
        self.heap.pop().map(|s| {
            debug_assert!(s.at >= self.now, "time went backwards");
            self.now = s.at;
            (s.at, s.event)
        })
    }

    /// Peek at the next event time without consuming it.
    pub fn peek_time(&self) -> Option<Time> {
        self.heap.peek().map(|s| s.at)
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Run until the queue drains or `until` is reached, applying `handler`
    /// to each event (the handler may schedule more events).
    pub fn run_until(&mut self, until: Time, mut handler: impl FnMut(&mut Self, Time, E)) {
        while let Some(&Scheduled { at, .. }) = self.heap.peek().map(|s| s as _) {
            if at > until {
                break;
            }
            let Some((t, e)) = self.next() else { break };
            handler(self, t, e);
        }
        // Advance to the bound only if work remains beyond it; an exhausted
        // queue leaves the clock at the last processed event.
        self.now = self.now.max(until.min(self.peek_time().unwrap_or(self.now)));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_fire_in_time_order() {
        let mut sim: Sim<u32> = Sim::new();
        sim.schedule_at(30, 3);
        sim.schedule_at(10, 1);
        sim.schedule_at(20, 2);
        let mut order = Vec::new();
        while let Some((t, e)) = sim.next() {
            order.push((t, e));
        }
        assert_eq!(order, vec![(10, 1), (20, 2), (30, 3)]);
    }

    #[test]
    fn ties_break_by_insertion() {
        let mut sim: Sim<u32> = Sim::new();
        for i in 0..5 {
            sim.schedule_at(100, i);
        }
        let got: Vec<u32> = std::iter::from_fn(|| sim.next().map(|(_, e)| e)).collect();
        assert_eq!(got, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn clock_monotone() {
        let mut sim: Sim<()> = Sim::new();
        sim.schedule_at(50, ());
        sim.next();
        assert_eq!(sim.now(), 50);
        // Scheduling "in the past" clamps to now.
        sim.schedule_at(10, ());
        let (t, _) = sim.next().unwrap();
        assert_eq!(t, 50);
    }

    #[test]
    fn handler_can_reschedule() {
        let mut sim: Sim<u32> = Sim::new();
        sim.schedule_at(0, 0);
        let mut count = 0;
        sim.run_until(10 * SEC, |sim, _t, e| {
            count += 1;
            if e < 5 {
                sim.schedule_in(SEC, e + 1);
            }
        });
        assert_eq!(count, 6);
        assert_eq!(sim.now(), 5 * SEC);
    }

    #[test]
    fn run_until_stops_at_bound() {
        let mut sim: Sim<u32> = Sim::new();
        sim.schedule_at(5, 1);
        sim.schedule_at(15, 2);
        let mut seen = Vec::new();
        sim.run_until(10, |_, _, e| seen.push(e));
        assert_eq!(seen, vec![1]);
        assert_eq!(sim.peek_time(), Some(15));
    }

    #[test]
    fn time_conversions() {
        assert_eq!(from_secs(1.5), 1_500_000);
        assert_eq!(secs(2 * SEC), 2.0);
        assert_eq!(mins(90 * SEC), 1.5);
    }
}
