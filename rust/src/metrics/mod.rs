//! Metrics accounting: throughput timelines, JCT slowdown, fail-slow impact.

use crate::simkit::{secs, Time};

/// Throughput timeline: (time, iterations/sec) samples plus iteration marks.
#[derive(Clone, Debug, Default)]
pub struct Timeline {
    pub points: Vec<(Time, f64)>,
}

impl Timeline {
    pub fn push(&mut self, at: Time, iters_per_sec: f64) {
        self.points.push((at, iters_per_sec));
    }

    pub fn xs_mins(&self) -> Vec<f64> {
        self.points.iter().map(|&(t, _)| secs(t) / 60.0).collect()
    }

    pub fn ys(&self) -> Vec<f64> {
        self.points.iter().map(|&(_, y)| y).collect()
    }

    pub fn mean_throughput(&self) -> f64 {
        crate::util::stats::mean(&self.ys())
    }
}

/// Job-completion accounting for the characterization campaign.
#[derive(Clone, Debug)]
pub struct JobOutcome {
    pub iters: usize,
    /// Ideal completion time with no fail-slow.
    pub ideal: Time,
    /// Actual completion time.
    pub actual: Time,
    pub timeline: Timeline,
}

impl JobOutcome {
    /// JCT slowdown factor (1.0 = no slowdown). Fig 1 center.
    pub fn slowdown(&self) -> f64 {
        self.actual as f64 / self.ideal.max(1) as f64
    }

    pub fn slowdown_pct(&self) -> f64 {
        (self.slowdown() - 1.0) * 100.0
    }
}

/// Percentile summary of latency-like samples (detection latencies across a
/// fleet, episode durations, ...). Built once from the pooled samples so
/// fleet-wide aggregation is a single pass.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LatencySummary {
    pub n: usize,
    pub mean: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
}

impl LatencySummary {
    pub fn from_samples(xs: &[f64]) -> Self {
        if xs.is_empty() {
            return Self::default();
        }
        let mut sorted = xs.to_vec();
        sorted.sort_by(f64::total_cmp);
        LatencySummary {
            n: xs.len(),
            mean: crate::util::stats::mean(xs),
            p50: crate::util::stats::quantile_sorted(&sorted, 0.5),
            p90: crate::util::stats::quantile_sorted(&sorted, 0.9),
            p99: crate::util::stats::quantile_sorted(&sorted, 0.99),
        }
    }
}

/// Fraction of a slowdown removed by mitigation (the paper's headline
/// "reduces the slowdown by 60.1%" in Table 7), computed in *throughput*
/// space as the paper does: reduction = (mitigated - slow) / (healthy - slow).
pub fn slowdown_reduction(healthy: f64, slow: f64, mitigated: f64) -> f64 {
    if (healthy - slow).abs() < 1e-12 {
        return 0.0;
    }
    (mitigated - slow) / (healthy - slow)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simkit::SEC;

    #[test]
    fn slowdown_factor() {
        let j = JobOutcome {
            iters: 100,
            ideal: 100 * SEC,
            actual: 134 * SEC,
            timeline: Timeline::default(),
        };
        assert!((j.slowdown() - 1.34).abs() < 1e-9);
        assert!((j.slowdown_pct() - 34.0).abs() < 1e-9);
    }

    #[test]
    fn reduction_formula_matches_paper_semantics() {
        // Table 7: healthy 17.1, fail-slow 14.8, mitigated 16.2 iters/min.
        let red = slowdown_reduction(17.1, 14.8, 16.2);
        assert!((red - 0.601).abs() < 0.02, "reduction {red}");
    }

    #[test]
    fn latency_summary_percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = LatencySummary::from_samples(&xs);
        assert_eq!(s.n, 100);
        assert!((s.mean - 50.5).abs() < 1e-9);
        assert!((s.p50 - 50.5).abs() < 1e-9);
        assert!((s.p90 - 90.1).abs() < 1e-9);
        assert!(s.p99 > s.p90 && s.p99 <= 100.0);
        assert_eq!(LatencySummary::from_samples(&[]), LatencySummary::default());
    }

    #[test]
    fn timeline_mean() {
        let mut t = Timeline::default();
        t.push(0, 1.0);
        t.push(SEC, 3.0);
        assert_eq!(t.mean_throughput(), 2.0);
    }
}
