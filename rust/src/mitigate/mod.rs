//! FALCON-MITIGATE (§5): the adaptive multi-level mitigation mechanism.
//!
//! `planner` implements Algorithm 1 (ski-rental escalation across S1–S4);
//! `microbatch` solves Eq. 1 exactly (S2); `topology` plans node swaps for
//! congestion reassignment and straggler consolidation (S3); S4 uses
//! `crate::ckpt` for its cost and `TrainingSim::restart` / the live
//! trainer's reload path for its effect. `replan` adds the beyond-paper S5
//! malleable-parallelism tier — graceful, reversible degradation within
//! the existing allocation when the healthy-node pool is exhausted.

pub mod microbatch;
pub mod planner;
pub mod replan;
pub mod topology;

pub use microbatch::{solve as solve_microbatch, Allocation};
pub use planner::{find_strategies, find_strategies_with_replan, MitigationPlanner, Overheads, Strategy};
pub use replan::{plan as plan_replan, resplit, ReplanPlan};
pub use topology::{plan as plan_topology, TopologyPlan};
