//! S5 — malleable-parallelism re-planning (beyond the paper; the
//! Malleus-style fallback for an exhausted healthy-node pool).
//!
//! The S1–S4 ladder dead-ends when the shared cluster has no spares: the
//! arbiter denies every S3/S4 grant and the job just eats the slowdown
//! until a node frees up. S5 converts that dead end into bounded,
//! *reversible* degradation using only resources the job already owns:
//!
//! 1. **Stage migration within the existing allocation** — logical-node
//!    swaps move pipeline stages off degraded nodes (and heavy DP rings off
//!    congested links) without asking the arbiter for replacement hardware.
//! 2. **Asymmetric micro-batch re-split** — [`resplit`] generalizes
//!    `mitigate/microbatch::solve` (Eq. 1) to replicas with unequal fixed
//!    offsets (the pipeline fill/drain each replica pays under the migrated
//!    layout): minimize max_i (fixed_i + m_i·t_i) subject to Σ m_i = M.
//!
//! The two are solved *jointly*: every candidate swap is scored with its
//! own re-solved split through the simulator's noise-free iteration-time
//! estimate, so any improvement the plan claims is real under the current
//! health picture. The plan is fully reversible — [`revert`] restores the
//! nominal node map (swaps are involutions, undone LIFO) and the
//! construction-time even split bit-for-bit — because S5 is a degradation
//! *mode* the job enters while the pool is exhausted and exits on heal.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::mitigate::microbatch::{self, Allocation};
use crate::sim::TrainingSim;
use crate::simkit::Time;

/// Minimum relative gain before a candidate swap is worth keeping and
/// before the executor considers the plan worth its pause at all.
const MIN_GAIN: f64 = 1e-3;

/// A malleable re-plan: node swaps (stage migration within the existing
/// allocation) plus the asymmetric micro-batch split solved for the
/// migrated layout.
#[derive(Clone, Debug)]
pub struct ReplanPlan {
    /// Logical-node swaps, in application order.
    pub swaps: Vec<(usize, usize)>,
    /// Per-replica micro-batch shares under the re-planned layout.
    pub alloc: Vec<usize>,
    pub predicted_iter_s: f64,
    pub baseline_iter_s: f64,
}

impl ReplanPlan {
    /// Predicted relative improvement vs leaving the layout alone.
    pub fn improvement(&self) -> f64 {
        if self.baseline_iter_s <= 0.0 {
            return 0.0;
        }
        1.0 - self.predicted_iter_s / self.baseline_iter_s
    }

    /// Whether applying the plan would recover enough to justify its pause.
    pub fn is_worthwhile(&self) -> bool {
        self.improvement() > MIN_GAIN
    }

    /// Fold a later re-plan (computed from the already-replanned state) into
    /// this one so a single [`revert`] unwinds both: swaps concatenate (the
    /// LIFO undo stays exact), the split and prediction come from the later
    /// plan, the baseline from the first.
    pub fn merge(self, later: ReplanPlan) -> ReplanPlan {
        let mut swaps = self.swaps;
        swaps.extend(later.swaps);
        ReplanPlan {
            swaps,
            alloc: later.alloc,
            predicted_iter_s: later.predicted_iter_s,
            baseline_iter_s: self.baseline_iter_s,
        }
    }
}

/// Generalized Eq. 1 for asymmetric replicas: minimize
/// max_i (fixed_i + m_i·t_i) subject to Σ m_i = M and m_i >= 1, where
/// `fixed[i]` is replica i's per-iteration offset (pipeline fill/drain
/// under a migrated stage layout) and `times[i]` its per-micro-batch time.
/// With all offsets zero this reduces exactly — same greedy, same
/// tie-breaking — to `microbatch::solve`.
///
/// The greedy that hands the next micro-batch to the replica whose
/// completion time stays smallest is optimal here too: each replica's
/// completion is a separable increasing linear function of its share, so
/// the classic exchange argument carries over unchanged (pinned against a
/// brute-force oracle below).
///
/// Degenerate profiles are clamped, never crashed on: non-finite times or
/// offsets read as a large suspect sentinel (load sheds away), non-positive
/// times as a small epsilon, negative offsets as zero. When `total` is
/// smaller than the replica count the m_i >= 1 constraint is unsatisfiable
/// and the scarce micro-batches go to the earliest-finishing replicas.
pub fn resplit(times: &[f64], fixed: &[f64], total: usize) -> Allocation {
    let d = times.len();
    if d == 0 || fixed.len() != d {
        return Allocation { m: Vec::new(), makespan: 0.0 };
    }
    const T_EPS: f64 = 1e-9;
    const T_SUSPECT: f64 = 1e6;
    let times: Vec<f64> = times
        .iter()
        .map(|&t| {
            if !t.is_finite() {
                T_SUSPECT
            } else if t <= 0.0 {
                T_EPS
            } else {
                t
            }
        })
        .collect();
    let fixed: Vec<f64> = fixed
        .iter()
        .map(|&f| {
            if !f.is_finite() {
                T_SUSPECT
            } else if f < 0.0 {
                0.0
            } else {
                f
            }
        })
        .collect();

    let completion = |m: &[usize]| -> f64 {
        m.iter()
            .enumerate()
            .map(|(i, &mi)| if mi == 0 { 0.0 } else { fixed[i] + mi as f64 * times[i] })
            .fold(0.0, f64::max)
    };

    if total < d {
        // One micro-batch each to the replicas that finish a single
        // micro-batch soonest (offset included).
        let mut order: Vec<usize> = (0..d).collect();
        order.sort_by(|&a, &b| {
            (fixed[a] + times[a]).total_cmp(&(fixed[b] + times[b])).then(a.cmp(&b))
        });
        let mut m = vec![0usize; d];
        for &i in order.iter().take(total) {
            m[i] = 1;
        }
        let makespan = completion(&m);
        return Allocation { m, makespan };
    }

    // Min-heap on (completion time if given one more, index).
    #[derive(PartialEq)]
    struct Slot(f64, usize);
    impl Eq for Slot {}
    impl PartialOrd for Slot {
        fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
            Some(self.cmp(o))
        }
    }
    impl Ord for Slot {
        fn cmp(&self, o: &Self) -> std::cmp::Ordering {
            self.0.total_cmp(&o.0).then(self.1.cmp(&o.1))
        }
    }

    let mut m = vec![1usize; d];
    let mut heap: BinaryHeap<Reverse<Slot>> = (0..d)
        .map(|i| Reverse(Slot(fixed[i] + 2.0 * times[i], i))) // completion if given a 2nd
        .collect();
    let mut left = total - d;
    while left > 0 {
        // The heap always holds exactly d slots (every pop is followed by a
        // push), so the else arm is unreachable — kept as a graceful exit
        // rather than an unwrap.
        let Some(Reverse(Slot(_, i))) = heap.pop() else { break };
        m[i] += 1;
        left -= 1;
        heap.push(Reverse(Slot(fixed[i] + (m[i] + 1) as f64 * times[i], i)));
    }
    let makespan = completion(&m);
    Allocation { m, makespan }
}

/// Best micro-batch split for the *current* grid layout, by the simulator's
/// own noise-free estimate: the asymmetric re-split (pipeline fill modeled
/// as (pp-1)·t_i per replica), the flat Eq. 1 solve, and the incumbent
/// split compete; ties keep the incumbent, so a no-change layout scores
/// exactly its current estimate and the plan's predicted improvement can
/// never be negative.
fn best_split(sim: &mut TrainingSim, total: usize) -> (f64, Vec<usize>) {
    let dp = sim.spec.cfg.dp;
    let pp = sim.spec.cfg.pp;
    let times = sim.replica_microbatch_times();
    let fill: Vec<f64> = times.iter().map(|&t| (pp as f64 - 1.0) * t).collect();
    let incumbent = sim.microbatch_alloc.clone();
    let candidates = [
        incumbent.clone(),
        resplit(&times, &fill, total).m,
        microbatch::solve(&times, total).m,
    ];
    let mut best_t = f64::INFINITY;
    let mut best_m = incumbent.clone();
    for cand in candidates {
        if cand.len() != dp || cand.iter().sum::<usize>() != total {
            continue;
        }
        sim.set_microbatch_alloc(cand.clone());
        let t = sim.estimate_iter_time_s();
        if t < best_t {
            best_t = t;
            best_m = cand;
        }
    }
    sim.set_microbatch_alloc(incumbent);
    (best_t, best_m)
}

/// Joint greedy search: each round tries every logical-node pair, scoring
/// the swapped layout *with its own re-solved micro-batch split*, keeps the
/// best pair that improves the running best by more than [`MIN_GAIN`], and
/// repeats up to `max_swaps` rounds. The sim is restored exactly before
/// returning — planning only; [`apply`] charges the pause.
pub fn plan(sim: &mut TrainingSim, max_swaps: usize) -> ReplanPlan {
    let total = sim.spec.wl.microbatches * sim.spec.cfg.dp;
    let baseline = sim.estimate_iter_time_s();
    let (mut best_t, mut best_alloc) = best_split(sim, total);
    let n = sim.grid.n_nodes();
    let mut swaps: Vec<(usize, usize)> = Vec::new();

    for _round in 0..max_swaps {
        let mut round_best: Option<(usize, usize, f64, Vec<usize>)> = None;
        for a in 0..n {
            for b in a + 1..n {
                sim.grid.swap_nodes(a, b);
                let (t, alloc) = best_split(sim, total);
                sim.grid.swap_nodes(a, b); // revert trial
                if t < best_t * (1.0 - MIN_GAIN)
                    && round_best.as_ref().map(|r| t < r.2).unwrap_or(true)
                {
                    round_best = Some((a, b, t, alloc));
                }
            }
        }
        match round_best {
            Some((a, b, t, alloc)) => {
                sim.grid.swap_nodes(a, b);
                swaps.push((a, b));
                best_t = t;
                best_alloc = alloc;
            }
            None => break,
        }
    }
    // Leave the grid as found (planning only).
    for &(a, b) in swaps.iter().rev() {
        sim.grid.swap_nodes(a, b);
    }
    ReplanPlan { swaps, alloc: best_alloc, predicted_iter_s: best_t, baseline_iter_s: baseline }
}

/// Enter the degradation mode: replay the swaps (each bumps the grid's
/// placement generation, so the sim's memo layer invalidates exactly),
/// install the asymmetric split, and charge the pause. A malformed split
/// (wrong length or sum) is skipped rather than asserted on — the swaps
/// alone still stand.
pub fn apply(sim: &mut TrainingSim, plan: &ReplanPlan, pause: Time) {
    for &(a, b) in &plan.swaps {
        sim.grid.swap_nodes(a, b);
    }
    let total = sim.spec.wl.microbatches * sim.spec.cfg.dp;
    if plan.alloc.len() == sim.spec.cfg.dp && plan.alloc.iter().sum::<usize>() == total {
        sim.set_microbatch_alloc(plan.alloc.clone());
    }
    sim.now += pause;
}

/// Exit the degradation mode: undo the swaps in reverse order and restore
/// the nominal even split. Bit-for-bit: swaps are involutions applied LIFO,
/// and the split equals the construction-time `even_alloc`.
pub fn revert(sim: &mut TrainingSim, plan: &ReplanPlan) {
    for &(a, b) in plan.swaps.iter().rev() {
        sim.grid.swap_nodes(a, b);
    }
    let total = sim.spec.wl.microbatches * sim.spec.cfg.dp;
    sim.set_microbatch_alloc(crate::sim::even_alloc(total, sim.spec.cfg.dp));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::inject::{FailSlowEvent, FailSlowKind, Target};
    use crate::pipeline::ParallelConfig;
    use crate::sim::{demo_spec, even_alloc, TrainingSim};
    use crate::simkit::{MINUTE, SEC};
    use crate::util::prop;
    use crate::util::rng::Rng;

    /// Brute-force oracle: best makespan over all compositions m_i >= 1.
    fn resplit_brute(times: &[f64], fixed: &[f64], total: usize) -> f64 {
        fn rec(
            i: usize,
            remaining: usize,
            m: &mut Vec<usize>,
            times: &[f64],
            fixed: &[f64],
            best: &mut f64,
        ) {
            let d = times.len();
            if i == d - 1 {
                m[i] = 1 + remaining;
                let makespan = m
                    .iter()
                    .enumerate()
                    .map(|(j, &mj)| fixed[j] + mj as f64 * times[j])
                    .fold(0.0, f64::max);
                if makespan < *best {
                    *best = makespan;
                }
                return;
            }
            for extra in 0..=remaining {
                m[i] = 1 + extra;
                rec(i + 1, remaining - extra, m, times, fixed, best);
            }
        }
        let mut m = vec![1usize; times.len()];
        let mut best = f64::INFINITY;
        rec(0, total - times.len(), &mut m, times, fixed, &mut best);
        best
    }

    fn congested_sim(seed: u64) -> TrainingSim {
        // Fig 10's layout: 4 nodes, stage-0 DP traffic crosses the 0-1
        // path; congesting it is exactly the case a denied S3 leaves behind.
        let mut spec = demo_spec(ParallelConfig::new(8, 2, 2), seed);
        spec.jitter = 0.0;
        spec.spike_p = 0.0;
        let mut sim = TrainingSim::new(spec);
        sim.inject(vec![FailSlowEvent {
            kind: FailSlowKind::NetworkCongestion,
            target: Target::Link(0, 1),
            start: 0,
            duration: 600 * MINUTE,
            scale: 0.15,
        }]);
        sim.step();
        sim
    }

    #[test]
    fn reduces_to_eq1_without_offsets() {
        // fixed = 0 must reproduce microbatch::solve exactly — same greedy,
        // same tie-breaking, bitwise makespan.
        let times = [2.0, 1.0, 1.0, 0.7];
        let a = resplit(&times, &[0.0; 4], 32);
        let b = microbatch::solve(&times, 32);
        assert_eq!(a.m, b.m);
        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
    }

    #[test]
    fn matches_brute_force_with_offsets() {
        prop::check(
            "resplit-optimal",
            0x5A1C0,
            300,
            |rng: &mut Rng| {
                let d = 2 + rng.below(3) as usize;
                let total = d + rng.below(11) as usize;
                let times: Vec<f64> = (0..d).map(|_| 0.2 + rng.f64() * 3.0).collect();
                let fixed: Vec<f64> = (0..d).map(|_| rng.f64() * 5.0).collect();
                (times, fixed, total)
            },
            |(times, fixed, total)| {
                let g = resplit(times, fixed, *total);
                let b = resplit_brute(times, fixed, *total);
                if (g.makespan - b).abs() < 1e-9 {
                    Ok(())
                } else {
                    Err(format!("greedy {} vs brute {b}", g.makespan))
                }
            },
        );
    }

    #[test]
    fn conserves_batch_with_min_one() {
        prop::check(
            "resplit-sum",
            11,
            200,
            |rng: &mut Rng| {
                let d = 1 + rng.below(32) as usize;
                let total = d + rng.below(128) as usize;
                let times: Vec<f64> = (0..d).map(|_| 0.1 + rng.f64() * 4.0).collect();
                let fixed: Vec<f64> = (0..d).map(|_| rng.f64() * 8.0).collect();
                (times, fixed, total)
            },
            |(times, fixed, total)| {
                let a = resplit(times, fixed, *total);
                if a.m.iter().sum::<usize>() == *total && a.m.iter().all(|&m| m >= 1) {
                    Ok(())
                } else {
                    Err(format!("bad allocation {:?}", a.m))
                }
            },
        );
    }

    #[test]
    fn high_offset_replica_sheds_load() {
        // Replica 0 pays a heavy fixed fill (deep migrated stage): it must
        // receive fewer micro-batches than an offset-free equal-speed peer.
        let a = resplit(&[1.0, 1.0], &[6.0, 0.0], 16);
        assert!(a.m[0] < a.m[1], "{:?}", a.m);
        assert_eq!(a.m.iter().sum::<usize>(), 16);
    }

    #[test]
    fn degenerate_inputs_clamped_not_crashed() {
        let a = resplit(&[0.0, f64::NAN, 1.0], &[f64::INFINITY, -1.0, 0.5], 10);
        assert_eq!(a.m.iter().sum::<usize>(), 10);
        assert!(a.makespan.is_finite());
        // Suspect entries (NaN time, inf offset) keep the mandatory minimum.
        assert_eq!(a.m[1], 1, "{:?}", a.m);
        let mismatched = resplit(&[1.0, 1.0], &[0.0], 8);
        assert!(mismatched.m.is_empty());
    }

    #[test]
    fn scarce_microbatches_go_to_earliest_finishers() {
        // total < d: offsets count — replica 1 finishes one micro-batch at
        // 2.0, replica 0 not before 6.0.
        let a = resplit(&[1.0, 2.0], &[5.0, 0.0], 1);
        assert_eq!(a.m, vec![0, 1]);
    }

    #[test]
    fn congestion_replan_recovers_without_a_grant() {
        let mut sim = congested_sim(7);
        let p = plan(&mut sim, 2);
        assert!(
            p.improvement() > 0.05,
            "replan must relieve congestion locally: {:?} improvement {}",
            p.swaps,
            p.improvement()
        );
        assert!(!p.swaps.is_empty(), "congestion relief needs stage migration");
    }

    #[test]
    fn degraded_gpu_replan_shifts_load_on_single_node() {
        // One node: no swaps possible, so the whole recovery must come from
        // the asymmetric re-split.
        let mut sim = TrainingSim::new(demo_spec(ParallelConfig::new(1, 4, 1), 19));
        sim.inject(vec![FailSlowEvent {
            kind: FailSlowKind::GpuDegradation,
            target: Target::Gpu(0),
            start: 0,
            duration: 600 * MINUTE,
            scale: 0.5,
        }]);
        sim.step();
        let p = plan(&mut sim, 2);
        assert!(p.swaps.is_empty(), "{:?}", p.swaps);
        assert!(p.improvement() > 0.05, "improvement {}", p.improvement());
        assert!(p.alloc[0] < p.alloc[1], "{:?}", p.alloc);
    }

    #[test]
    fn healthy_sim_plans_nothing() {
        let mut spec = demo_spec(ParallelConfig::new(8, 2, 2), 9);
        spec.jitter = 0.0;
        let mut sim = TrainingSim::new(spec);
        sim.step();
        let p = plan(&mut sim, 2);
        assert!(p.swaps.is_empty(), "{:?}", p.swaps);
        // Ties keep the incumbent split, so the prediction IS the baseline.
        assert_eq!(p.predicted_iter_s.to_bits(), p.baseline_iter_s.to_bits());
        assert!(!p.is_worthwhile());
    }

    #[test]
    fn plan_does_not_mutate_sim() {
        let mut sim = congested_sim(11);
        let map_before = sim.grid.node_map.clone();
        let alloc_before = sim.microbatch_alloc.clone();
        let est_before = sim.estimate_iter_time_s();
        let _ = plan(&mut sim, 2);
        assert_eq!(sim.grid.node_map, map_before);
        assert_eq!(sim.microbatch_alloc, alloc_before);
        assert_eq!(sim.estimate_iter_time_s().to_bits(), est_before.to_bits());
    }

    #[test]
    fn apply_then_revert_restores_nominal_layout_bitwise() {
        let mut sim = congested_sim(13);
        let nominal_map = sim.grid.node_map.clone();
        let nominal_alloc =
            even_alloc(sim.spec.wl.microbatches * sim.spec.cfg.dp, sim.spec.cfg.dp);
        assert_eq!(sim.microbatch_alloc, nominal_alloc);
        let degraded = sim.estimate_iter_time_s();

        let p = plan(&mut sim, 2);
        assert!(p.is_worthwhile());
        let t0 = sim.now;
        apply(&mut sim, &p, 30 * SEC);
        assert_eq!(sim.now - t0, 30 * SEC, "apply charges exactly the pause");
        assert_ne!(sim.grid.node_map, nominal_map, "stage migration happened");
        let replanned = sim.estimate_iter_time_s();
        assert!(replanned < degraded, "{replanned} vs {degraded}");

        revert(&mut sim, &p);
        assert_eq!(sim.grid.node_map, nominal_map, "node map restored bitwise");
        assert_eq!(sim.microbatch_alloc, nominal_alloc, "even split restored");
        assert_eq!(sim.estimate_iter_time_s().to_bits(), degraded.to_bits());
    }

    #[test]
    fn merged_plans_revert_in_one_step() {
        let mut sim = congested_sim(17);
        let nominal_map = sim.grid.node_map.clone();
        let first = plan(&mut sim, 1);
        apply(&mut sim, &first, SEC);
        let second = plan(&mut sim, 1);
        apply(&mut sim, &second, SEC);
        let merged = first.merge(second);
        revert(&mut sim, &merged);
        assert_eq!(sim.grid.node_map, nominal_map);
    }
}
