//! Adaptive multi-level mitigation planner — Algorithm 1 (§5.2).
//!
//! The ski-rental insight: the fail-slow duration is unknown, so start with
//! the cheapest strategy and escalate to the next (costlier, more
//! effective) one only when the *accumulated* slowdown impact of the
//! ongoing episode equals that strategy's action overhead. Checkpoint-and-
//! restart is the last resort.

use crate::inject::FailSlowKind;

/// Mitigation strategies in escalation order (Table 3).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Strategy {
    /// S1 — do nothing, hope for self-recovery.
    Ignore,
    /// S2 — redistribute micro-batches across DP groups.
    AdjustMicrobatch,
    /// S3 — adjust parallelism topology (node swaps).
    AdjustTopology,
    /// S4 — checkpoint and restart on healthy nodes.
    CkptRestart,
    /// S5 — re-plan the parallelization itself (beyond the paper;
    /// Malleus-style): stage migration within the existing allocation plus
    /// an asymmetric micro-batch re-split. Needs no cluster grant, so it is
    /// the graceful-degradation fallback when the healthy-node pool is
    /// exhausted and S3/S4 grants are denied.
    ReplanParallelism,
}

impl Strategy {
    pub fn name(self) -> &'static str {
        match self {
            Strategy::Ignore => "S1:Ignore",
            Strategy::AdjustMicrobatch => "S2:AdjustMicrobatch",
            Strategy::AdjustTopology => "S3:AdjustTopology",
            Strategy::CkptRestart => "S4:CkptRestart",
            Strategy::ReplanParallelism => "S5:ReplanParallelism",
        }
    }

    /// Whether the strategy can help the given root cause (Table 3):
    /// micro-batch adjustment cannot fix a congested link. S5 re-plans
    /// around both slow compute (re-split) and slow links (migration).
    pub fn effective_against(self, kind: FailSlowKind) -> bool {
        match self {
            Strategy::Ignore => true,
            Strategy::AdjustMicrobatch => kind.is_compute(),
            Strategy::AdjustTopology
            | Strategy::CkptRestart
            | Strategy::ReplanParallelism => true,
        }
    }
}

/// Action overheads in seconds (configurable; defaults follow §5.3/§7.4:
/// S2 solver is sub-second to seconds, S3 pause is under a minute, S4 costs
/// checkpoint dump + scheduling + restore, i.e. many minutes).
#[derive(Clone, Copy, Debug)]
pub struct Overheads {
    pub adjust_microbatch_s: f64,
    pub adjust_topology_s: f64,
    pub ckpt_restart_s: f64,
    /// S5 pause: dump to memory, migrate the affected stages within the
    /// existing allocation, re-split, restore — a few minutes, between S3's
    /// sub-minute pause and S4's full checkpoint-restart.
    pub replan_s: f64,
}

impl Default for Overheads {
    fn default() -> Self {
        Overheads {
            adjust_microbatch_s: 2.0,
            adjust_topology_s: 45.0,
            ckpt_restart_s: 20.0 * 60.0,
            replan_s: 3.0 * 60.0,
        }
    }
}

impl Overheads {
    pub fn of(&self, s: Strategy) -> f64 {
        match s {
            Strategy::Ignore => 0.0,
            Strategy::AdjustMicrobatch => self.adjust_microbatch_s,
            Strategy::AdjustTopology => self.adjust_topology_s,
            Strategy::CkptRestart => self.ckpt_restart_s,
            Strategy::ReplanParallelism => self.replan_s,
        }
    }
}

/// FindStrategies(root_cause): applicable strategies sorted by overhead
/// (Algorithm 1, lines 3–4).
pub fn find_strategies(kind: FailSlowKind, ov: &Overheads) -> Vec<Strategy> {
    let mut cands: Vec<Strategy> = [
        Strategy::Ignore,
        Strategy::AdjustMicrobatch,
        Strategy::AdjustTopology,
        Strategy::CkptRestart,
    ]
    .into_iter()
    .filter(|s| s.effective_against(kind))
    .collect();
    cands.sort_by(|a, b| ov.of(*a).total_cmp(&ov.of(*b)));
    cands
}

/// FindStrategies over the five-tier ladder including the S5 malleable
/// tier (enabled by `FalconConfig::replan`): same applicability filter,
/// same overhead sort. With default overheads S5 slots between S3's
/// sub-minute pause and S4's full restart.
pub fn find_strategies_with_replan(kind: FailSlowKind, ov: &Overheads) -> Vec<Strategy> {
    let mut cands: Vec<Strategy> = [
        Strategy::Ignore,
        Strategy::AdjustMicrobatch,
        Strategy::AdjustTopology,
        Strategy::CkptRestart,
        Strategy::ReplanParallelism,
    ]
    .into_iter()
    .filter(|s| s.effective_against(kind))
    .collect();
    cands.sort_by(|a, b| ov.of(*a).total_cmp(&ov.of(*b)));
    cands
}

/// Escalation decision for one ongoing fail-slow event.
#[derive(Clone, Debug)]
pub struct MitigationPlanner {
    pub candidates: Vec<Strategy>,
    pub overheads: Overheads,
    /// Next strategy index to apply (Algorithm 1's `id`).
    id: usize,
    /// Accumulated impact: Σ over slow iterations of (t_slow - t_healthy).
    impact_s: f64,
    /// Log of applied strategies with the impact level that triggered them.
    pub applied: Vec<(Strategy, f64)>,
    /// Strategies whose resource grant a shared cluster denied (the
    /// healthy-node pool was exhausted). Escalation never assumes a denied
    /// strategy helped: the accumulated impact keeps growing untouched, so
    /// the next level still fires once its own overhead is matched.
    pub denied: Vec<Strategy>,
    /// Consecutive denials in this episode with no grant in between — the
    /// dead-end hysteresis S5 entry keys off (a streak means the pool is
    /// *exhausted*, not merely momentarily busy). A grant or a reset
    /// clears it.
    denied_streak: usize,
}

impl MitigationPlanner {
    pub fn new(kind: FailSlowKind, overheads: Overheads) -> Self {
        MitigationPlanner {
            candidates: find_strategies(kind, &overheads),
            overheads,
            id: 0,
            impact_s: 0.0,
            applied: Vec::new(),
            denied: Vec::new(),
            denied_streak: 0,
        }
    }

    /// Like [`MitigationPlanner::new`] but escalating over the five-tier
    /// ladder: the S5 malleable-parallelism tier joins at its own overhead
    /// slot, so a persistent episode reaches it even when no grant is ever
    /// denied (e.g. the arbiter simply queues forever).
    pub fn with_replan(kind: FailSlowKind, overheads: Overheads) -> Self {
        MitigationPlanner {
            candidates: find_strategies_with_replan(kind, &overheads),
            overheads,
            id: 0,
            impact_s: 0.0,
            applied: Vec::new(),
            denied: Vec::new(),
            denied_streak: 0,
        }
    }

    /// Record that a shared cluster denied `strategy`'s resource grant.
    /// The planner's escalation cursor already moved past it when the
    /// request fired, so escalation-wise this is bookkeeping — but the
    /// denial list lets callers assert that a saturated pool forces S3 to
    /// be skipped, and the consecutive-denial streak is the deterministic
    /// signal the S5 dead-end fallback keys off.
    pub fn on_denied(&mut self, strategy: Strategy) {
        self.denied.push(strategy);
        self.denied_streak += 1;
    }

    /// A grant came through after all: the pool is not exhausted, so the
    /// dead-end streak resets (the denial *history* is kept).
    pub fn on_granted(&mut self) {
        self.denied_streak = 0;
    }

    /// Consecutive denials with no grant in between (this episode).
    pub fn denied_streak(&self) -> usize {
        self.denied_streak
    }

    /// Account one slow iteration (Algorithm 1, lines 9–11) and decide
    /// whether to escalate now (lines 13–15). Returns the strategy to
    /// apply, if any. S1 (Ignore, overhead 0) is "applied" immediately,
    /// which matches the paper: the system starts by doing nothing.
    pub fn on_slow_iter(&mut self, t_slow_s: f64, t_healthy_s: f64) -> Option<Strategy> {
        self.impact_s += (t_slow_s - t_healthy_s).max(0.0);
        if self.id >= self.candidates.len() {
            return None;
        }
        let next = self.candidates[self.id];
        if self.impact_s > self.overheads.of(next) {
            self.applied.push((next, self.impact_s));
            self.id += 1;
            Some(next)
        } else {
            None
        }
    }

    /// Impact accumulated so far (diagnostics / Fig 17 annotations).
    pub fn impact_s(&self) -> f64 {
        self.impact_s
    }

    /// Reset for a new episode (event resolved).
    pub fn reset(&mut self) {
        self.id = 0;
        self.impact_s = 0.0;
        self.applied.clear();
        self.denied.clear();
        self.denied_streak = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strategies_ordered_by_overhead() {
        let ov = Overheads::default();
        let s = find_strategies(FailSlowKind::GpuDegradation, &ov);
        assert_eq!(
            s,
            vec![
                Strategy::Ignore,
                Strategy::AdjustMicrobatch,
                Strategy::AdjustTopology,
                Strategy::CkptRestart
            ]
        );
    }

    #[test]
    fn microbatch_skipped_for_congestion() {
        // Table 3: S2 has no effect on slow communication.
        let ov = Overheads::default();
        let s = find_strategies(FailSlowKind::NetworkCongestion, &ov);
        assert!(!s.contains(&Strategy::AdjustMicrobatch));
        assert_eq!(s[0], Strategy::Ignore);
        assert_eq!(*s.last().unwrap(), Strategy::CkptRestart);
    }

    #[test]
    fn short_episode_stays_at_ignore() {
        let mut p = MitigationPlanner::new(FailSlowKind::GpuDegradation, Overheads::default());
        // S1 fires immediately (zero overhead), nothing else for a brief blip.
        let first = p.on_slow_iter(1.5, 1.0);
        assert_eq!(first, Some(Strategy::Ignore));
        for _ in 0..3 {
            assert_eq!(p.on_slow_iter(1.5, 1.0), None);
        }
        assert_eq!(p.applied.len(), 1);
    }

    #[test]
    fn escalates_as_impact_accumulates() {
        let ov = Overheads {
            adjust_microbatch_s: 2.0,
            adjust_topology_s: 40.0,
            ckpt_restart_s: 300.0,
            replan_s: 150.0,
        };
        let mut p = MitigationPlanner::new(FailSlowKind::GpuDegradation, ov);
        let mut seen = Vec::new();
        // 1 s of excess per slow iteration.
        for _ in 0..400 {
            if let Some(s) = p.on_slow_iter(2.0, 1.0) {
                seen.push((s, p.impact_s()));
            }
        }
        assert_eq!(
            seen.iter().map(|&(s, _)| s).collect::<Vec<_>>(),
            vec![
                Strategy::Ignore,
                Strategy::AdjustMicrobatch,
                Strategy::AdjustTopology,
                Strategy::CkptRestart
            ]
        );
        // Ski-rental property: each strategy fires only once its overhead is
        // matched by accumulated impact.
        for &(s, at) in &seen {
            assert!(at >= ov.of(s), "{s:?} fired early at {at}");
            assert!(at <= ov.of(s) + 2.0, "{s:?} fired late at {at}");
        }
    }

    #[test]
    fn ski_rental_never_pays_more_than_damage() {
        // The ski-rental guarantee as the planner realizes it: an action's
        // overhead is paid only once the accumulated impact has matched it,
        // so at every instant the total overhead paid is bounded by
        // (levels x impact) and, with geometrically-spaced overheads as
        // here, by 2x the impact suffered.
        let ov = Overheads {
            adjust_microbatch_s: 10.0,
            adjust_topology_s: 100.0,
            ckpt_restart_s: 1000.0,
            replan_s: 300.0,
        };
        for dur in [5usize, 50, 500, 5000] {
            let mut p = MitigationPlanner::new(FailSlowKind::GpuDegradation, ov);
            let mut paid = 0.0;
            for _ in 0..dur {
                if let Some(s) = p.on_slow_iter(2.0, 1.0) {
                    paid += ov.of(s);
                }
                assert!(
                    paid <= 2.0 * p.impact_s() + 1e-9,
                    "dur {dur}: paid {paid} > 2x impact {}",
                    p.impact_s()
                );
            }
        }
    }

    #[test]
    fn denied_s3_still_escalates_to_s4_on_impact() {
        // Shared cluster with an exhausted pool: S3's grant is denied, yet
        // the ski-rental escalation reaches S4 exactly when the accumulated
        // impact matches S4's overhead — no assumption that S3 ran.
        let ov = Overheads {
            adjust_microbatch_s: 2.0,
            adjust_topology_s: 40.0,
            ckpt_restart_s: 300.0,
            replan_s: 150.0,
        };
        let mut p = MitigationPlanner::new(FailSlowKind::GpuDegradation, ov);
        let mut seen = Vec::new();
        for _ in 0..400 {
            if let Some(s) = p.on_slow_iter(2.0, 1.0) {
                if s == Strategy::AdjustTopology {
                    p.on_denied(s); // pool exhausted
                }
                seen.push((s, p.impact_s()));
            }
        }
        assert_eq!(p.denied, vec![Strategy::AdjustTopology]);
        let s4 = seen
            .iter()
            .find(|&&(s, _)| s == Strategy::CkptRestart)
            .expect("S4 must still fire");
        assert!(s4.1 >= ov.ckpt_restart_s, "S4 fired early at {}", s4.1);
        p.reset();
        assert!(p.denied.is_empty());
    }

    #[test]
    fn reset_clears_state() {
        let mut p = MitigationPlanner::new(FailSlowKind::GpuDegradation, Overheads::default());
        for _ in 0..100 {
            p.on_slow_iter(3.0, 1.0);
        }
        assert!(p.impact_s() > 0.0);
        p.reset();
        assert_eq!(p.impact_s(), 0.0);
        assert!(p.applied.is_empty());
        assert_eq!(p.on_slow_iter(3.0, 1.0), Some(Strategy::Ignore));
    }

    #[test]
    fn replan_ladder_slots_s5_between_s3_and_s4() {
        let ov = Overheads::default();
        let s = find_strategies_with_replan(FailSlowKind::GpuDegradation, &ov);
        assert_eq!(
            s,
            vec![
                Strategy::Ignore,
                Strategy::AdjustMicrobatch,
                Strategy::AdjustTopology,
                Strategy::ReplanParallelism,
                Strategy::CkptRestart
            ]
        );
        // S5 re-plans around slow links too, unlike S2 (Table 3).
        let c = find_strategies_with_replan(FailSlowKind::NetworkCongestion, &ov);
        assert!(!c.contains(&Strategy::AdjustMicrobatch));
        assert!(c.contains(&Strategy::ReplanParallelism));
        // The four-tier ladder is untouched by the new tier.
        assert_eq!(find_strategies(FailSlowKind::GpuDegradation, &ov).len(), 4);
    }

    #[test]
    fn with_replan_escalation_reaches_s5_before_s4() {
        let ov = Overheads {
            adjust_microbatch_s: 2.0,
            adjust_topology_s: 40.0,
            ckpt_restart_s: 300.0,
            replan_s: 150.0,
        };
        let mut p = MitigationPlanner::with_replan(FailSlowKind::GpuDegradation, ov);
        let mut seen = Vec::new();
        for _ in 0..400 {
            if let Some(s) = p.on_slow_iter(2.0, 1.0) {
                seen.push((s, p.impact_s()));
            }
        }
        let order: Vec<Strategy> = seen.iter().map(|&(s, _)| s).collect();
        assert_eq!(
            order,
            vec![
                Strategy::Ignore,
                Strategy::AdjustMicrobatch,
                Strategy::AdjustTopology,
                Strategy::ReplanParallelism,
                Strategy::CkptRestart
            ]
        );
        // Ski-rental holds for the inserted tier as well.
        for &(s, at) in &seen {
            assert!(at >= ov.of(s), "{s:?} fired early at {at}");
            assert!(at <= ov.of(s) + 2.0, "{s:?} fired late at {at}");
        }
    }

    #[test]
    fn denied_streak_counts_consecutive_denials_only() {
        let mut p = MitigationPlanner::with_replan(FailSlowKind::GpuDegradation, Overheads::default());
        assert_eq!(p.denied_streak(), 0);
        p.on_denied(Strategy::AdjustTopology);
        p.on_denied(Strategy::CkptRestart);
        assert_eq!(p.denied_streak(), 2);
        p.on_granted(); // pool freed up after all
        assert_eq!(p.denied_streak(), 0, "a grant breaks the streak");
        assert_eq!(p.denied.len(), 2, "the denial history is kept");
        p.on_denied(Strategy::AdjustTopology);
        assert_eq!(p.denied_streak(), 1);
        p.reset();
        assert_eq!(p.denied_streak(), 0);
        assert!(p.denied.is_empty());
    }
}
