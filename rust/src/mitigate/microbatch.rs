//! S2 — micro-batch distribution adjustment (§5.3, Eq. 1).
//!
//! Given per-replica micro-batch processing times t_i (profiled by
//! FALCON-DETECT) and M total micro-batches, find integer allocations m_i
//! minimizing the slowest replica's total time max_i m_i·t_i, subject to
//! Σ m_i = M and m_i >= 1.
//!
//! The paper solves this as a QP via cvxpy (Table 6: up to ~36 s at
//! D = 512). Because the micro-batches are *identical unit jobs on uniform
//! machines*, the greedy that repeatedly gives the next micro-batch to the
//! replica whose completion time would stay smallest is *exactly optimal* —
//! a classic exchange argument, verified here against brute force — and
//! runs in O(M log D), replacing the QP solver entirely.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Result of the solver.
#[derive(Clone, Debug, PartialEq)]
pub struct Allocation {
    pub m: Vec<usize>,
    /// Predicted slowest-replica time max_i m_i t_i.
    pub makespan: f64,
}

/// Exact greedy solver. `times[i]` = per-micro-batch time of replica i,
/// `total` = M.
///
/// Degenerate profiles are handled gracefully rather than crashing a live
/// mitigation step: a non-finite replica time (NaN, a hung probe reported
/// as +inf) clamps to a large sentinel — the replica is *suspect*, so the
/// solver sheds load away from it rather than piling the batch onto a
/// replica that may not make progress — while a non-positive finite time
/// (measurement underflow) clamps to a small epsilon. When
/// `total < replicas` the constraint m_i >= 1 is unsatisfiable, so the
/// solver gives one micro-batch each to the fastest replicas.
pub fn solve(times: &[f64], total: usize) -> Allocation {
    let d = times.len();
    if d == 0 {
        return Allocation { m: Vec::new(), makespan: 0.0 };
    }
    const T_EPS: f64 = 1e-9;
    const T_SUSPECT: f64 = 1e6;
    let times: Vec<f64> = times
        .iter()
        .map(|&t| {
            if !t.is_finite() {
                T_SUSPECT
            } else if t <= 0.0 {
                T_EPS
            } else {
                t
            }
        })
        .collect();
    let times = &times[..];
    if total < d {
        // One micro-batch each to the `total` *fastest* replicas.
        let mut order: Vec<usize> = (0..d).collect();
        order.sort_by(|&a, &b| times[a].total_cmp(&times[b]).then(a.cmp(&b)));
        let mut m = vec![0usize; d];
        for &i in order.iter().take(total) {
            m[i] = 1;
        }
        let makespan = m
            .iter()
            .zip(times)
            .map(|(&mi, &t)| mi as f64 * t)
            .fold(0.0, f64::max);
        return Allocation { m, makespan };
    }

    // Min-heap on (completion time if given one more, index).
    #[derive(PartialEq)]
    struct Slot(f64, usize);
    impl Eq for Slot {}
    impl PartialOrd for Slot {
        fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
            Some(self.cmp(o))
        }
    }
    impl Ord for Slot {
        fn cmp(&self, o: &Self) -> std::cmp::Ordering {
            self.0.total_cmp(&o.0).then(self.1.cmp(&o.1))
        }
    }

    let mut m = vec![1usize; d]; // m_i in N+ (paper constraint)
    let mut heap: BinaryHeap<Reverse<Slot>> = (0..d)
        .map(|i| Reverse(Slot(2.0 * times[i], i))) // completion if given a 2nd
        .collect();
    for _ in 0..total - d {
        // audit:allow(panic-budget): the heap holds exactly d slots (one
        // per replica) and every pop is followed by a push.
        let Reverse(Slot(_, i)) = heap.pop().unwrap();
        m[i] += 1;
        heap.push(Reverse(Slot((m[i] + 1) as f64 * times[i], i)));
    }
    let makespan = m
        .iter()
        .zip(times)
        .map(|(&mi, &t)| mi as f64 * t)
        .fold(0.0, f64::max);
    Allocation { m, makespan }
}

/// Brute-force oracle for small instances (tests): enumerate compositions.
pub fn solve_brute(times: &[f64], total: usize) -> Allocation {
    let d = times.len();
    let mut best: Option<Allocation> = None;
    let mut m = vec![1usize; d];

    fn rec(
        i: usize,
        remaining: usize,
        m: &mut Vec<usize>,
        times: &[f64],
        best: &mut Option<Allocation>,
    ) {
        let d = times.len();
        if i == d - 1 {
            m[i] = 1 + remaining;
            let makespan = m
                .iter()
                .zip(times)
                .map(|(&mi, &t)| mi as f64 * t)
                .fold(0.0, f64::max);
            if best.as_ref().map(|b| makespan < b.makespan).unwrap_or(true) {
                *best = Some(Allocation { m: m.clone(), makespan });
            }
            return;
        }
        for extra in 0..=remaining {
            m[i] = 1 + extra;
            rec(i + 1, remaining - extra, m, times, best);
        }
    }
    rec(0, total - d, &mut m, times, &mut best);
    // audit:allow(panic-budget): rec's base case always records a
    // candidate (extra=0 is in every range), so best is Some.
    best.unwrap()
}

/// Predicted slowdown factor of an allocation vs the all-healthy ideal.
pub fn predicted_slowdown(times: &[f64], alloc: &[usize], healthy_time: f64, even_m: usize) -> f64 {
    let makespan = alloc
        .iter()
        .zip(times)
        .map(|(&m, &t)| m as f64 * t)
        .fold(0.0, f64::max);
    makespan / (even_m as f64 * healthy_time)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn even_when_healthy() {
        let a = solve(&[1.0, 1.0, 1.0, 1.0], 32);
        assert_eq!(a.m, vec![8, 8, 8, 8]);
        assert!((a.makespan - 8.0).abs() < 1e-12);
    }

    #[test]
    fn sheds_load_from_slow_replica() {
        // Replica 0 is 2x slower: it should get roughly half the work.
        let a = solve(&[2.0, 1.0, 1.0, 1.0], 32);
        assert!(a.m[0] < 8, "{:?}", a.m);
        assert_eq!(a.m.iter().sum::<usize>(), 32);
        // Near-balanced completion times.
        assert!(a.makespan < 2.0 * 8.0 * 0.7, "makespan {}", a.makespan);
    }

    #[test]
    fn respects_min_one() {
        // Pathologically slow replica still gets exactly 1.
        let a = solve(&[100.0, 1.0, 1.0, 1.0], 16);
        assert_eq!(a.m[0], 1);
        assert_eq!(a.m.iter().sum::<usize>(), 16);
    }

    #[test]
    fn degenerate_times_clamped_not_crashed() {
        // A live mitigation step must survive a broken profile: zero,
        // negative, NaN and infinite per-replica times are all sanitized
        // and the allocation still conserves the global batch.
        let a = solve(&[0.0, -1.0, f64::NAN, f64::INFINITY, 1.0], 20);
        assert_eq!(a.m.iter().sum::<usize>(), 20);
        assert!(a.m.iter().all(|&m| m >= 1), "{:?}", a.m);
        assert!(a.makespan.is_finite());
        // Suspect replicas (NaN / hung-probe inf) get only the mandatory
        // minimum — load sheds AWAY from a replica that may not progress.
        assert_eq!(a.m[2], 1, "{:?}", a.m);
        assert_eq!(a.m[3], 1, "{:?}", a.m);
        // Underflowed-measurement replicas absorb the remainder.
        assert!(a.m[0] > a.m[4] || a.m[1] > a.m[4], "{:?}", a.m);
    }

    #[test]
    fn fewer_microbatches_than_replicas_falls_back_to_even() {
        let a = solve(&[1.0, 2.0, 3.0, 4.0], 2);
        assert_eq!(a.m.iter().sum::<usize>(), 2);
        assert_eq!(a.m.len(), 4);
        assert!(a.makespan.is_finite() && a.makespan > 0.0);
        // The scarce micro-batches go to the fastest replicas.
        assert_eq!(a.m, vec![1, 1, 0, 0]);
        let b = solve(&[9.0, 1.0, 1.0], 2);
        assert_eq!(b.m, vec![0, 1, 1]);
        assert!((b.makespan - 1.0).abs() < 1e-12, "{}", b.makespan);
        let empty = solve(&[], 5);
        assert!(empty.m.is_empty());
        assert_eq!(empty.makespan, 0.0);
    }

    #[test]
    fn matches_brute_force() {
        prop::check(
            "greedy-optimal",
            0xFA1C0,
            300,
            |rng: &mut Rng| {
                let d = 2 + rng.below(4) as usize;
                let total = d + rng.below(14) as usize;
                let times: Vec<f64> =
                    (0..d).map(|_| 0.2 + rng.f64() * 3.0).collect();
                (times, total)
            },
            |(times, total)| {
                let g = solve(times, *total);
                let b = solve_brute(times, *total);
                if (g.makespan - b.makespan).abs() < 1e-9 {
                    Ok(())
                } else {
                    Err(format!("greedy {} vs brute {}", g.makespan, b.makespan))
                }
            },
        );
    }

    #[test]
    fn allocation_conserves_global_batch() {
        prop::check(
            "sum-preserved",
            7,
            200,
            |rng: &mut Rng| {
                let d = 1 + rng.below(64) as usize;
                let total = d + rng.below(256) as usize;
                let times: Vec<f64> = (0..d).map(|_| 0.1 + rng.f64() * 5.0).collect();
                (times, total)
            },
            |(times, total)| {
                let a = solve(times, *total);
                if a.m.iter().sum::<usize>() == *total && a.m.iter().all(|&m| m >= 1) {
                    Ok(())
                } else {
                    Err(format!("bad allocation {:?}", a.m))
                }
            },
        );
    }

    #[test]
    fn fig14_no_room_when_all_slow() {
        // All replicas equally degraded -> allocation stays even, no gain.
        let healthy = solve(&[1.0; 4], 32);
        let all_slow = solve(&[1.5; 4], 32);
        assert_eq!(healthy.m, all_slow.m);
        assert!((all_slow.makespan / healthy.makespan - 1.5).abs() < 1e-9);
    }

    #[test]
    fn fig14_gain_shrinks_with_more_slow_groups() {
        // 4 DP groups; degrading more of them leaves less headroom (Fig 14).
        let m_total = 32;
        let mk = |n_slow: usize| {
            let times: Vec<f64> =
                (0..4).map(|i| if i < n_slow { 1.9 } else { 1.0 }).collect();
            solve(&times, m_total).makespan
        };
        let even = |n_slow: usize| {
            let worst = if n_slow > 0 { 1.9 } else { 1.0 };
            8.0 * worst
        };
        let gain = |n: usize| (even(n) - mk(n)) / even(n);
        assert!(gain(1) > gain(2) && gain(2) > gain(3) && gain(3) > gain(4) - 1e-12);
        assert!(gain(4) < 1e-9, "no room with all slow");
    }

    #[test]
    fn large_instance_fast() {
        // Table 6 scale: D = 512 solves in well under a millisecond-scale
        // budget (exact timing in bench_tables tab6).
        let mut rng = Rng::new(1);
        let times: Vec<f64> = (0..512).map(|_| 0.5 + rng.f64()).collect();
        let a = solve(&times, 512 * 8);
        assert_eq!(a.m.iter().sum::<usize>(), 512 * 8);
    }
}
