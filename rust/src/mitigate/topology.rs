//! S3 — parallelism-topology adjustment (§5.3, Fig 10–11).
//!
//! Two mechanisms, both realized as *node swaps* in the rank grid:
//!
//! 1. **Congested-link reassignment**: move the traffic crossing a congested
//!    uplink from heavy DP rings onto light PP chains by exchanging node
//!    positions (Fig 10).
//! 2. **Straggler consolidation**: gather slow GPUs into the minimal number
//!    of PP stages — workers in a stage run at the slowest member's pace,
//!    so co-locating stragglers bounds the damage to one stage (Fig 11) —
//!    preferring interior stages (first/last carry embedding/LM-head).
//!
//! The planner searches single swaps (and greedy sequences of them) scoring
//! each candidate with the simulator's own iteration-time estimate, so any
//! improvement it claims is real under the current health picture.

use crate::sim::TrainingSim;

/// A planned adjustment: sequence of logical-node swaps plus the predicted
/// iteration time after applying them.
#[derive(Clone, Debug)]
pub struct TopologyPlan {
    pub swaps: Vec<(usize, usize)>,
    pub predicted_iter_s: f64,
    pub baseline_iter_s: f64,
}

impl TopologyPlan {
    pub fn improvement(&self) -> f64 {
        if self.baseline_iter_s <= 0.0 {
            return 0.0;
        }
        1.0 - self.predicted_iter_s / self.baseline_iter_s
    }
}

/// Estimate current iteration time without mutating sim state.
fn estimate_iter_s(sim: &mut TrainingSim) -> f64 {
    // The nominal estimator: no clock advance, no op log, and no RNG
    // traffic at all (the incremental engine's ring plans expose a
    // noise-free value), so an O(n^2)-candidate swap search perturbs
    // nothing it does not intend to.
    sim.estimate_iter_time_s()
}

/// Greedy swap search: try all node pairs, keep the best improving swap,
/// repeat up to `max_swaps` times.
pub fn plan(sim: &mut TrainingSim, max_swaps: usize) -> TopologyPlan {
    let baseline = estimate_iter_s(sim);
    let n = sim.grid.n_nodes();
    let mut swaps = Vec::new();
    let mut best_overall = baseline;

    for _round in 0..max_swaps {
        let mut round_best: Option<(usize, usize, f64)> = None;
        for a in 0..n {
            for b in a + 1..n {
                sim.grid.swap_nodes(a, b);
                let t = estimate_iter_s(sim);
                sim.grid.swap_nodes(a, b); // revert
                if t < best_overall * 0.999
                    && round_best.map(|(_, _, bt)| t < bt).unwrap_or(true)
                {
                    round_best = Some((a, b, t));
                }
            }
        }
        match round_best {
            Some((a, b, t)) => {
                sim.grid.swap_nodes(a, b);
                swaps.push((a, b));
                best_overall = t;
            }
            None => break,
        }
    }
    // Leave the grid as found: revert applied swaps (the planner only
    // *plans*; applying is the strategy executor's job, which also charges
    // the pause overhead).
    for &(a, b) in swaps.iter().rev() {
        sim.grid.swap_nodes(a, b);
    }
    TopologyPlan { swaps, predicted_iter_s: best_overall, baseline_iter_s: baseline }
}

/// Apply a plan to the sim, charging the pause overhead per §5.3 (dump to
/// memory, swap parameters via RDMA, restore — "typically within one
/// minute"; cost supplied by the caller from the ckpt model).
pub fn apply(sim: &mut TrainingSim, plan: &TopologyPlan, pause: crate::simkit::Time) {
    for &(a, b) in &plan.swaps {
        sim.grid.swap_nodes(a, b);
    }
    sim.now += pause;
}

/// Most-degraded physical node under the current health picture, or `None`
/// when every node is nominal. Shared-cluster S3 (see `crate::cluster`)
/// trades exactly this node for a healthy spare when the arbiter grants
/// one; a denied or queued grant leaves it in place and the ski-rental
/// planner escalates on accumulated impact instead.
pub fn worst_node(sim: &TrainingSim) -> Option<usize> {
    let c = &sim.cluster;
    let mut worst: Option<(usize, f64)> = None;
    for n in 0..c.spec.nodes {
        let mut badness = (1.0 - c.nodes[n].cpu_satisfaction).max(0.0)
            + (1.0 - c.uplinks[n].bandwidth_scale).max(0.0);
        for g in 0..c.spec.gpus_per_node {
            badness += (1.0 - c.gpus[n * c.spec.gpus_per_node + g].compute_scale).max(0.0);
        }
        if badness > 1e-9 && worst.map(|(_, b)| badness > b).unwrap_or(true) {
            worst = Some((n, badness));
        }
    }
    worst.map(|(n, _)| n)
}

/// Minimal number of PP stages that can contain `n_stragglers` stragglers
/// (paper formula: ceil(#stragglers / GPUs-per-stage)).
pub fn min_straggler_stages(n_stragglers: usize, gpus_per_stage: usize) -> usize {
    n_stragglers.div_ceil(gpus_per_stage.max(1))
}

/// Preferred consolidation stages: interior first (§5.3).
pub fn preferred_stages(pp: usize) -> Vec<usize> {
    let mut order: Vec<usize> = (0..pp).collect();
    // Sort by distance from the boundary, descending (interior first).
    order.sort_by_key(|&s| {
        let d = s.min(pp - 1 - s);
        std::cmp::Reverse(d)
    });
    order
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::inject::{FailSlowEvent, FailSlowKind, Target};
    use crate::pipeline::ParallelConfig;
    use crate::sim::demo_spec;
    use crate::simkit::{MINUTE, SEC};

    #[test]
    fn min_stages_formula() {
        assert_eq!(min_straggler_stages(2, 4), 1);
        assert_eq!(min_straggler_stages(6, 4), 2);
        assert_eq!(min_straggler_stages(4, 4), 1);
        assert_eq!(min_straggler_stages(0, 4), 0);
    }

    #[test]
    fn interior_stages_preferred() {
        let order = preferred_stages(4);
        assert!(order[0] == 1 || order[0] == 2);
        assert!(order[3] == 0 || order[3] == 3);
        let order8 = preferred_stages(8);
        assert!(order8.ends_with(&[0]) || order8.ends_with(&[7]) || {
            let last2: Vec<usize> = order8[6..].to_vec();
            last2.contains(&0) && last2.contains(&7)
        });
    }

    #[test]
    fn congestion_swap_improves_iteration() {
        // Fig 10's scenario: 4 nodes (one per TP group), DP rings between
        // same-stage nodes. Congest the path between the two stage-0 nodes
        // (physical 0 and 1) — a heavy DP link. The planner must find a
        // swap that turns that path into a light PP link.
        let mut spec = demo_spec(ParallelConfig::new(8, 2, 2), 7);
        spec.jitter = 0.0;
        let mut sim = TrainingSim::new(spec);
        assert_eq!(sim.grid.n_nodes(), 4);
        sim.inject(vec![FailSlowEvent {
            kind: FailSlowKind::NetworkCongestion,
            target: Target::Link(0, 1),
            start: 0,
            duration: 600 * MINUTE,
            scale: 0.15,
        }]);
        sim.step();
        let p = plan(&mut sim, 2);
        assert!(
            p.improvement() > 0.05,
            "planner should relieve congestion: {:?} improvement {}",
            p.swaps,
            p.improvement()
        );
    }

    #[test]
    fn healthy_cluster_needs_no_swap() {
        let mut spec = demo_spec(ParallelConfig::new(8, 2, 2), 9);
        spec.jitter = 0.0;
        let mut sim = TrainingSim::new(spec);
        sim.step();
        let p = plan(&mut sim, 2);
        assert!(p.swaps.is_empty(), "{:?}", p.swaps);
    }

    #[test]
    fn plan_does_not_mutate_grid() {
        let mut spec = demo_spec(ParallelConfig::new(8, 2, 2), 11);
        spec.jitter = 0.0;
        let mut sim = TrainingSim::new(spec);
        sim.inject(vec![FailSlowEvent {
            kind: FailSlowKind::NetworkCongestion,
            target: Target::Uplink(0),
            start: 0,
            duration: 600 * MINUTE,
            scale: 0.2,
        }]);
        sim.step();
        let before = sim.grid.node_map.clone();
        let _ = plan(&mut sim, 2);
        assert_eq!(sim.grid.node_map, before);
    }

    #[test]
    fn worst_node_pinpoints_degradation() {
        let mut spec = demo_spec(ParallelConfig::new(8, 2, 2), 15);
        spec.jitter = 0.0;
        let mut sim = TrainingSim::new(spec);
        assert_eq!(worst_node(&sim), None, "healthy cluster has no worst node");
        sim.inject(vec![
            FailSlowEvent {
                kind: FailSlowKind::GpuDegradation,
                target: Target::Gpu(2 * 8 + 3), // node 2
                start: 0,
                duration: 600 * MINUTE,
                scale: 0.7,
            },
            FailSlowEvent {
                kind: FailSlowKind::NetworkCongestion,
                target: Target::Uplink(1),
                start: 0,
                duration: 600 * MINUTE,
                scale: 0.2,
            },
        ]);
        sim.step();
        // Uplink 1 lost 0.8 of its bandwidth vs node 2's GPU losing 0.3.
        assert_eq!(worst_node(&sim), Some(1));
    }

    #[test]
    fn apply_charges_pause() {
        let mut spec = demo_spec(ParallelConfig::new(8, 2, 2), 13);
        spec.jitter = 0.0;
        let mut sim = TrainingSim::new(spec);
        let t0 = sim.now;
        let p = TopologyPlan { swaps: vec![(0, 1)], predicted_iter_s: 1.0, baseline_iter_s: 1.0 };
        apply(&mut sim, &p, 30 * SEC);
        assert_eq!(sim.now - t0, 30 * SEC);
        assert_eq!(sim.grid.node_map[0], 1);
    }
}
