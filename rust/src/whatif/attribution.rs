//! Blame attribution: turn counterfactual replays into per-fault delay,
//! per-mitigation benefit, paper-style aggregate JCT-delay %, and — for
//! shared-cluster fleets — per-job contention blame.

use std::collections::BTreeMap;

use crate::fleet::FleetTrace;
use crate::ledger::NodeLedger;
// The `Attribution`/`FaultAttribution` result types live in
// `crate::scenario::outcome` (they are part of the Outcome shape);
// this module computes them.
use crate::scenario::{Attribution, FaultAttribution};

use super::trace::RunTrace;
use super::{sweep, Edit, WhatifError};

/// Full attribution of a recorded run: one fault-removed replay per
/// `[[fault]]` entry plus (when the run mitigates) a `NoMitigation`
/// replay, fanned across `workers` threads.
pub fn attribute(trace: &RunTrace, workers: usize) -> Result<Attribution, WhatifError> {
    let spec = &trace.spec;
    let mut edit_sets: Vec<Vec<Edit>> =
        (0..spec.faults.len()).map(|i| vec![Edit::DropFault(i)]).collect();
    let mitigation_idx = if spec.run.mitigate {
        edit_sets.push(vec![Edit::NoMitigation]);
        Some(edit_sets.len() - 1)
    } else {
        None
    };

    let outs = sweep(trace, &edit_sets, workers);
    let baseline = trace.outcome.jct_s;
    let ideal = trace.outcome.iters as f64 / trace.outcome.ideal_thpt;

    let mut faults = Vec::with_capacity(spec.faults.len());
    for (i, f) in spec.faults.iter().enumerate() {
        let out = outs[i].as_ref().map_err(|e| e.clone())?;
        let delay_s = baseline - out.jct_s;
        faults.push(FaultAttribution {
            fault: i,
            label: format!(
                "{} {} @{:.2}",
                crate::scenario::kind_token(f.kind),
                crate::scenario::target_token(f.target),
                f.start
            ),
            events: trace.event_fault.iter().filter(|&&fi| fi == i).count(),
            delay_s,
            delay_pct: 100.0 * delay_s / ideal.max(1e-9),
        });
    }
    let mitigation_benefit_s = match mitigation_idx {
        Some(k) => outs[k].as_ref().map_err(|e| e.clone())?.jct_s - baseline,
        None => 0.0,
    };
    let attributed: f64 = faults.iter().map(|f| f.delay_s).sum();
    Ok(Attribution {
        baseline_jct_s: baseline,
        ideal_jct_s: ideal,
        jct_delay_pct: 100.0 * (baseline - ideal) / ideal.max(1e-9),
        faults,
        mitigation_benefit_s,
        mitigation_benefit_pct: 100.0 * mitigation_benefit_s / ideal.max(1e-9),
        unattributed_s: (baseline - ideal) - attributed,
        replays: edit_sets.len(),
    })
}

/// Fleet-level contention blame: `victim` lost ~`lost_s` seconds to
/// `culprit`'s traffic on shared leaf uplinks.
#[derive(Clone, Debug, PartialEq)]
pub struct BlameEntry {
    pub victim: usize,
    pub culprit: usize,
    /// Exposure-weighted upper bound on the time `culprit` cost `victim`:
    /// `(1/scale - 1) * epoch_len * ideal_iter_s(victim)`, split across the
    /// leaf's other residents by communication-volume share. An upper
    /// bound because it assumes the victim's iterations are fully
    /// communication-bound while contended.
    pub lost_s: f64,
}

/// Attribute each job's uplink slowdown to the co-resident jobs whose
/// traffic caused it, from the recorded per-epoch contention rosters.
/// Deterministic: aggregation runs over ordered maps, and the result is
/// sorted by `lost_s` descending (ties by victim, then culprit id).
pub fn contention_blame(trace: &FleetTrace) -> Vec<BlameEntry> {
    // Group samples by (epoch, leaf); samples are per (job, leaf) already.
    let mut groups: BTreeMap<(usize, usize), Vec<usize>> = BTreeMap::new();
    for (i, s) in trace.contention.iter().enumerate() {
        groups.entry((s.epoch, s.leaf)).or_default().push(i);
    }
    let mut blame: BTreeMap<(usize, usize), f64> = BTreeMap::new();
    for members in groups.values() {
        for &vi in members {
            let v = trace.contention[vi];
            if v.scale >= 1.0 {
                continue; // uncontended this epoch
            }
            let culprit_vol: f64 = members
                .iter()
                .filter(|&&ci| trace.contention[ci].job != v.job)
                .map(|&ci| trace.contention[ci].volume)
                .sum();
            if culprit_vol <= 0.0 {
                continue;
            }
            let ideal = trace.job_ideal_iter_s.get(v.job).copied().unwrap_or(0.0);
            let lost = (1.0 / v.scale - 1.0) * trace.epoch_len as f64 * ideal;
            for &ci in members {
                let c = trace.contention[ci];
                if c.job == v.job {
                    continue;
                }
                *blame.entry((v.job, c.job)).or_insert(0.0) +=
                    lost * c.volume / culprit_vol;
            }
        }
    }
    let mut out: Vec<BlameEntry> = blame
        .into_iter()
        .filter(|&(_, lost)| lost > 0.0)
        .map(|((victim, culprit), lost_s)| BlameEntry { victim, culprit, lost_s })
        .collect();
    out.sort_by(|a, b| {
        b.lost_s
            .total_cmp(&a.lost_s)
            .then(a.victim.cmp(&b.victim))
            .then(a.culprit.cmp(&b.culprit))
    });
    out
}

/// Fold contention blame into a node-health ledger: each culprit job's
/// blamed seconds spread evenly over the shared nodes it sat on
/// ([`FleetTrace::placements`]), accruing in the per-node `blame_s`
/// counters the `falcon report ledger` campaign surfaces. Jobs with no
/// recorded placement (never admitted) contribute nothing.
pub fn ledger_blame(trace: &FleetTrace, ledger: &mut NodeLedger) {
    for b in contention_blame(trace) {
        let nodes = match trace.placements.get(&b.culprit) {
            Some(p) if !p.is_empty() => p,
            _ => continue,
        };
        let share = b.lost_s / nodes.len() as f64;
        for &n in nodes {
            ledger.add_blame(n, share);
        }
    }
}

/// Render the top `limit` blame pairs as text lines — the one formatter
/// shared by the `falcon whatif` CLI and the `whatif` report.
pub fn render_blame(blame: &[BlameEntry], limit: usize) -> String {
    if blame.is_empty() {
        return "  no cross-job contention recorded\n".to_string();
    }
    let mut out = String::new();
    for b in blame.iter().take(limit) {
        out.push_str(&format!(
            "  job {:>3} slowed by job {:>3}: ~{:.1} s\n",
            b.victim, b.culprit, b.lost_s
        ));
    }
    if blame.len() > limit {
        out.push_str(&format!("  ... and {} more pairs\n", blame.len() - limit));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::super::trace::{record, record_fleet, TraceConfig};
    use super::*;
    use crate::fleet::ContentionSample;
    use crate::scenario::{find, FleetSpec, ScenarioSpec};
    use crate::util::json::Json;

    #[test]
    fn attribution_blames_the_slow_leak() {
        // The acceptance scenario: `whatif slow-leak-gpu --drop-fault 0`
        // must report a positive attributed delay for the fault.
        let spec = find("slow-leak-gpu").unwrap().iters(160);
        let trace = record(&spec, &TraceConfig::default()).unwrap();
        let attr = attribute(&trace, 2).unwrap();
        assert_eq!(attr.faults.len(), 1);
        assert_eq!(attr.faults[0].events, 10, "ramp expands to ten events");
        assert!(
            attr.faults[0].delay_s > 0.0,
            "the leak must have a positive attributed delay: {:?}",
            attr.faults[0]
        );
        assert!(attr.jct_delay_pct > 0.0);
        assert_eq!(attr.replays, 2, "one drop-fault replay + one no-mitigation replay");
        // Attribution is reproducible (deterministic replays).
        let again = attribute(&trace, 1).unwrap();
        assert_eq!(attr, again);
    }

    #[test]
    fn golden_attribution_json_schema() {
        // Pins the whatif JSON schema (field names, nesting, encoding),
        // compared as parsed JSON like the Outcome golden test.
        let attr = Attribution {
            baseline_jct_s: 120.5,
            ideal_jct_s: 100.0,
            jct_delay_pct: 20.5,
            faults: vec![FaultAttribution {
                fault: 0,
                label: "gpu gpu:3 @0.10".to_string(),
                events: 10,
                delay_s: 15.25,
                delay_pct: 15.25,
            }],
            mitigation_benefit_s: 4.5,
            mitigation_benefit_pct: 4.5,
            unattributed_s: 5.25,
            replays: 2,
        };
        let expected = r#"{
            "baseline_jct_s": 120.5, "ideal_jct_s": 100,
            "jct_delay_pct": 20.5,
            "faults": [{"fault": 0, "label": "gpu gpu:3 @0.10", "events": 10,
                        "delay_s": 15.25, "delay_pct": 15.25}],
            "mitigation_benefit_s": 4.5, "mitigation_benefit_pct": 4.5,
            "unattributed_s": 5.25, "replays": 2
        }"#;
        assert_eq!(Json::parse(expected).unwrap(), attr.to_json());
        let rendered = attr.render();
        assert!(rendered.contains("what-if attribution (2 replays)"));
        assert!(rendered.contains("fault[0] gpu gpu:3 @0.10"));
    }

    #[test]
    fn blame_splits_by_volume_share() {
        // Hand-built roster: jobs 1 and 2 squeeze job 0 on leaf 0, with
        // job 2 sending three times the volume — it takes 3/4 of the blame.
        let trace = FleetTrace {
            epoch_len: 10,
            epochs: 1,
            contention: vec![
                ContentionSample { epoch: 0, leaf: 0, job: 0, scale: 0.5, volume: 1e6 },
                ContentionSample { epoch: 0, leaf: 0, job: 1, scale: 0.8, volume: 1e6 },
                ContentionSample { epoch: 0, leaf: 0, job: 2, scale: 0.8, volume: 3e6 },
            ],
            job_ideal_iter_s: vec![2.0, 1.0, 1.0],
            placements: BTreeMap::new(),
        };
        let blame = contention_blame(&trace);
        let get = |v: usize, c: usize| {
            blame
                .iter()
                .find(|b| b.victim == v && b.culprit == c)
                .map(|b| b.lost_s)
                .unwrap_or(0.0)
        };
        // Job 0 lost (1/0.5 - 1) * 10 * 2.0 = 20 s, split 1:3.
        assert!((get(0, 1) - 5.0).abs() < 1e-9, "{blame:?}");
        assert!((get(0, 2) - 15.0).abs() < 1e-9, "{blame:?}");
        // Victims with scale 1.0 or no culprit volume accrue nothing.
        assert!(blame.iter().all(|b| b.lost_s > 0.0));
        // Sorted by lost_s descending.
        assert!(blame.windows(2).all(|w| w[0].lost_s >= w[1].lost_s));
    }

    #[test]
    fn ledger_blame_spreads_over_culprit_placements() {
        // Same roster as above, now with recorded placements: job 1 sat on
        // nodes {4, 5} (its 5 s of blame splits evenly), job 2 on node 6
        // (all 15 s land there). Job 0 is a victim only.
        let mut placements = BTreeMap::new();
        placements.insert(1usize, vec![4usize, 5]);
        placements.insert(2, vec![6]);
        let trace = FleetTrace {
            epoch_len: 10,
            epochs: 1,
            contention: vec![
                ContentionSample { epoch: 0, leaf: 0, job: 0, scale: 0.5, volume: 1e6 },
                ContentionSample { epoch: 0, leaf: 0, job: 1, scale: 0.8, volume: 1e6 },
                ContentionSample { epoch: 0, leaf: 0, job: 2, scale: 0.8, volume: 3e6 },
            ],
            job_ideal_iter_s: vec![2.0, 1.0, 1.0],
            placements,
        };
        let mut ledger = NodeLedger::default();
        ledger_blame(&trace, &mut ledger);
        let blame_on = |n: usize| ledger.nodes.get(&n).map_or(0.0, |h| h.blame_s);
        assert!((blame_on(4) - 2.5).abs() < 1e-9, "{}", blame_on(4));
        assert!((blame_on(5) - 2.5).abs() < 1e-9, "{}", blame_on(5));
        assert!((blame_on(6) - 15.0).abs() < 1e-9, "{}", blame_on(6));
        assert_eq!(blame_on(0), 0.0, "victims accrue no blame");
    }

    #[test]
    fn shared_fleet_blame_is_nonempty_and_deterministic() {
        let spec = ScenarioSpec::new("blame-fleet", 2, 4, 1).iters(30).seed(11).with_fleet(
            FleetSpec {
                jobs: 8,
                workers: 2,
                boost: 0.0,
                compare: false,
                policy: Some(crate::cluster::Policy::Packed),
                spare: 0.1,
                epoch_len: 5,
                stagger: 0.0,
            },
        );
        let rec = record_fleet(&spec).unwrap();
        let blame = contention_blame(&rec.trace);
        assert!(
            !blame.is_empty(),
            "packed multi-node jobs must contend somewhere: {:?}",
            rec.trace.contention.len()
        );
        for b in &blame {
            assert!(b.victim != b.culprit);
            assert!(b.victim < 8 && b.culprit < 8);
            assert!(b.lost_s > 0.0);
        }
        let again = contention_blame(&record_fleet(&spec).unwrap().trace);
        assert_eq!(blame, again, "blame must be deterministic");
    }
}
