//! Run recording: per-iteration records plus periodic full-state
//! snapshots, the substrate counterfactual replay restores from.

use crate::coordinator::{Falcon, FalconConfig};
use crate::fleet::{run_fleet_traced, FleetTrace};
use crate::inject::FailSlowEvent;
use crate::scenario::{Outcome, ScenarioError, ScenarioSpec};
use crate::sim::TrainingSim;
use crate::simkit::Time;

/// Upper bound on interior snapshots per recording: on long horizons the
/// effective cadence is raised to `iters / MAX_SNAPSHOTS` so snapshot
/// memory stays O(MAX_SNAPSHOTS × state) — each snapshot clones the sim
/// whole, timeline included, which would otherwise grow quadratically
/// with the horizon.
pub const MAX_SNAPSHOTS: usize = 64;

/// Recording knobs.
#[derive(Clone, Copy, Debug)]
pub struct TraceConfig {
    /// Take a full-state snapshot every this many iterations (plus one at
    /// iteration 0 and one at the end). Smaller = cheaper replays, more
    /// memory: each snapshot clones the sim and coordinator. Cadences
    /// finer than `iters / MAX_SNAPSHOTS` are coarsened to that bound.
    pub snapshot_every: usize,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig { snapshot_every: 64 }
    }
}

/// One iteration of the recorded baseline, compact.
#[derive(Clone, Debug, PartialEq)]
pub struct IterRecord {
    /// Observed iteration duration (the sample FALCON-DETECT consumed).
    pub duration_s: f64,
    /// Sim clock at the start of the iteration.
    pub start: Time,
    /// Indices (into [`RunTrace::injected`]) of the fail-slow events
    /// applied to the cluster during this iteration's step (captured
    /// before the coordinator reacts, so a restart cannot hide a fault
    /// that fired on the same iteration it was cleared).
    pub active_faults: Vec<u32>,
    /// Cluster health epoch after the iteration — consecutive records with
    /// equal epochs saw identical cluster health (the generation-delta
    /// view of the run).
    pub health_epoch: u64,
}

/// Full engine state at one iteration boundary: everything a replay needs
/// to continue the run bit-exactly (cluster health, RNG stream position,
/// detector posterior, planner cursor, warm sim caches).
pub(super) struct Snapshot {
    pub(super) iter: usize,
    pub(super) sim: TrainingSim,
    pub(super) falcon: Falcon,
}

/// A recorded single-job run: the spec, the injected events, the
/// per-iteration trace, the baseline [`Outcome`] (whose `actions` carry
/// every coordinator decision, arbiter grants/denials included), and the
/// snapshots replay restores from.
pub struct RunTrace {
    pub spec: ScenarioSpec,
    /// The fault script as injected at t=0 (absolute times).
    pub injected: Vec<FailSlowEvent>,
    /// `injected[k]` expanded from `spec.faults[event_fault[k]]`.
    pub event_fault: Vec<usize>,
    pub iters: Vec<IterRecord>,
    /// The baseline outcome (bit-identical to `spec.run()`'s).
    pub outcome: Outcome,
    pub(super) snapshots: Vec<Snapshot>,
}

impl RunTrace {
    /// Number of snapshots held (diagnostics; memory is proportional).
    pub fn snapshot_count(&self) -> usize {
        self.snapshots.len()
    }
}

/// Map the sim's currently applied events back to indices into the
/// original injected list. `sim.events` is an order-preserving subsequence
/// of `injected` (restart clears, `remove_events` filters — neither
/// reorders), so a greedy forward match recovers exact original indices.
pub(super) fn map_active(injected: &[FailSlowEvent], sim: &TrainingSim) -> Vec<u32> {
    let active = sim.active_event_indices();
    let mut out = Vec::with_capacity(active.len());
    let mut oi = 0usize;
    let mut ai = 0usize;
    for (ci, ev) in sim.events.iter().enumerate() {
        while oi < injected.len() && injected[oi] != *ev {
            oi += 1;
        }
        if oi >= injected.len() {
            break; // defensive: unmatched event (never expected)
        }
        if ai < active.len() && active[ai] == ci {
            out.push(oi as u32);
            ai += 1;
        }
        oi += 1;
    }
    out
}

/// Record a single-job scenario: execute it exactly like
/// [`ScenarioSpec::run`] while capturing the per-iteration trace and
/// snapshots. The recorded `outcome` is bit-identical to a plain run.
pub fn record(spec: &ScenarioSpec, cfg: &TraceConfig) -> Result<RunTrace, ScenarioError> {
    if spec.fleet.is_some() {
        return Err(ScenarioError::field(
            "fleet",
            "fleet scenarios record through whatif::record_fleet",
        ));
    }
    let mut sim = spec.build_sim()?;
    let injected = sim.events.clone();
    let horizon_s = sim.ideal_iter_s * spec.run.iters as f64;
    let event_fault = spec.event_fault_indices(horizon_s);
    debug_assert_eq!(event_fault.len(), injected.len());

    let mut falcon = Falcon::new(FalconConfig {
        mitigate: spec.run.mitigate,
        replan: spec.run.replan,
        ..FalconConfig::default()
    });
    let total = spec.run.iters;
    let every = cfg.snapshot_every.max(total / MAX_SNAPSHOTS).max(1);
    let mut snapshots =
        vec![Snapshot { iter: 0, sim: sim.clone(), falcon: falcon.clone() }];
    let mut iters = Vec::with_capacity(total);
    for i in 0..total {
        let obs = sim.step();
        // Capture the active set BEFORE the coordinator reacts: an S4
        // restart inside on_iteration clears sim.events, which would hide
        // a fault that first applied during this very step (and push
        // DropFault's divergence iteration past its real first effect).
        let active_faults = map_active(&injected, &sim);
        falcon.on_iteration(&mut sim, obs.iter, obs.duration_s());
        iters.push(IterRecord {
            duration_s: obs.duration_s(),
            start: obs.start,
            active_faults,
            health_epoch: sim.cluster.health_epoch(),
        });
        if (i + 1) % every == 0 && i + 1 < total {
            snapshots.push(Snapshot { iter: i + 1, sim: sim.clone(), falcon: falcon.clone() });
        }
    }
    snapshots.push(Snapshot { iter: total, sim: sim.clone(), falcon: falcon.clone() });
    let outcome = Outcome::from_single(spec, &sim, &falcon, &injected);
    Ok(RunTrace { spec: spec.clone(), injected, event_fault, iters, outcome, snapshots })
}

/// A recorded fleet run: the baseline outcome plus the shared-cluster
/// contention rosters ([`FleetTrace`]) blame attribution reads. Fleet
/// counterfactuals re-run cold — the engine is already sharded across
/// workers, and cross-job coupling defeats per-job snapshot reuse.
pub struct FleetRecord {
    pub spec: ScenarioSpec,
    pub outcome: Outcome,
    pub trace: FleetTrace,
}

/// Record a fleet scenario (shared-cluster runs also capture the
/// contention rosters; private fleets record an empty roster).
pub fn record_fleet(spec: &ScenarioSpec) -> Result<FleetRecord, ScenarioError> {
    spec.validate()?;
    let Some(cfg) = spec.fleet_config() else {
        return Err(ScenarioError::field(
            "fleet",
            "single-job scenarios record through whatif::record",
        ));
    };
    let (report, trace) = run_fleet_traced(&cfg);
    let outcome = Outcome::from_fleet(spec, &report);
    Ok(FleetRecord { spec: spec.clone(), outcome, trace })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::find;

    #[test]
    fn recording_matches_plain_run_bitwise() {
        let spec = find("gpu-thermal").unwrap().iters(120);
        let trace = record(&spec, &TraceConfig::default()).unwrap();
        let plain = spec.run().unwrap();
        assert_eq!(
            trace.outcome.to_json().to_string(),
            plain.to_json().to_string(),
            "recording must not perturb the run"
        );
        assert_eq!(trace.iters.len(), 120);
        // Snapshots: t=0, every 64th, and the end.
        assert_eq!(trace.snapshot_count(), 1 + 1 + 1);
        assert!(trace.iters.iter().all(|r| r.duration_s > 0.0));
        // The thermal fault is active from the start of the run.
        assert_eq!(trace.iters[0].active_faults, vec![0]);
        // Health epoch moves when the fault expires.
        let first = trace.iters.first().unwrap().health_epoch;
        let last = trace.iters.last().unwrap().health_epoch;
        assert!(last > first, "fault relief must bump the health epoch");
    }

    #[test]
    fn active_fault_indices_follow_the_script() {
        // Two disjoint CPU bursts: the active set names each event while
        // (and only while) it is applied. Probe mode keeps the script
        // untouched (no S4 restart can clear events mid-run).
        let spec = find("cpu-contention").unwrap().iters(150).mitigate(false);
        let trace = record(&spec, &TraceConfig::default()).unwrap();
        let mut seen: Vec<u32> = trace
            .iters
            .iter()
            .flat_map(|r| r.active_faults.iter().copied())
            .collect();
        seen.dedup();
        assert_eq!(seen, vec![0, 1], "bursts activate in order, one at a time");
        assert_eq!(trace.event_fault, vec![0, 1]);
    }

    #[test]
    fn fleet_recording_matches_plain_run() {
        let mut spec = find("noisy-neighbor").unwrap();
        spec.run.iters = 30;
        let rec = record_fleet(&spec).unwrap();
        let plain = spec.run().unwrap();
        assert_eq!(rec.outcome.to_json().to_string(), plain.to_json().to_string());
        assert!(rec.trace.epochs > 0);
    }
}
