//! Counterfactual replay: apply typed edits to a recorded run and
//! deterministically re-execute from the latest snapshot the edits cannot
//! have affected.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::coordinator::Falcon;
use crate::inject::FailSlowEvent;
use crate::mitigate::Strategy;
use crate::scenario::{Outcome, ScenarioSpec};
use crate::sim::TrainingSim;

use super::trace::{FleetRecord, RunTrace};
use super::{Edit, WhatifError};

/// First iteration an edit can possibly change, against this trace. A
/// replay restores the latest snapshot at or before the minimum over its
/// edits — everything earlier is bit-identical to the baseline by
/// construction, so re-simulating it would only burn time.
fn divergence_iter(trace: &RunTrace, edit: &Edit) -> usize {
    match *edit {
        Edit::DropFault(i) => trace
            .iters
            .iter()
            .position(|r| {
                r.active_faults.iter().any(|&e| trace.event_fault[e as usize] == i)
            })
            .unwrap_or(usize::MAX),
        // NoMitigation can matter as soon as any fault is applied: the
        // coordinator's healthy-housekeeping re-solve (mitigate-gated,
        // every 20th iteration) acts on skewed replica times even before
        // an episode verifies. On a fault-free, episode-free prefix the
        // re-solve is a no-op, so the earlier of (first active fault,
        // first verified episode) bounds the divergence.
        Edit::NoMitigation => {
            let first_active = trace
                .iters
                .iter()
                .position(|r| !r.active_faults.is_empty())
                .unwrap_or(usize::MAX);
            first_active.min(first_episode_open(trace))
        }
        // A delayed planner behaves identically until an episode opens:
        // the delay gates only the post-open escalation branch.
        Edit::DelayMitigation(_) => first_episode_open(trace),
        Edit::ForceLevel { at_frac, .. } => force_iter(at_frac, trace.spec.run.iters),
        Edit::SwapPolicy(_) => 0,
    }
}

/// Iteration a forced strategy fires at: `at_frac` of the horizon, capped
/// to the last executed iteration so `@1.0` means "at the very end"
/// rather than silently never firing.
fn force_iter(at_frac: f64, total_iters: usize) -> usize {
    let at = (at_frac.clamp(0.0, 1.0) * total_iters as f64) as usize;
    at.min(total_iters.saturating_sub(1))
}

/// Iteration of the first verified episode open (`usize::MAX` if none).
fn first_episode_open(trace: &RunTrace) -> usize {
    trace
        .outcome
        .actions
        .iter()
        .find(|a| a.kind == "episode_opened")
        .map(|a| a.iter)
        .unwrap_or(usize::MAX)
}

fn check_edits(spec: &ScenarioSpec, edits: &[Edit]) -> Result<(), WhatifError> {
    for e in edits {
        match *e {
            Edit::DropFault(i) if i >= spec.faults.len() => {
                return Err(WhatifError::Unsupported(format!(
                    "drop-fault {i}: scenario '{}' has {} faults",
                    spec.name,
                    spec.faults.len()
                )))
            }
            Edit::SwapPolicy(_) if spec.fleet.is_none() => {
                return Err(WhatifError::Unsupported(
                    "swap-policy applies to fleet scenarios only".to_string(),
                ))
            }
            _ => {}
        }
    }
    Ok(())
}

/// Apply the state-level edits to a restored (or fresh) sim + coordinator.
/// Returns the post-edit injected-event list (for detection-latency
/// accounting) and the forced-strategy schedule for the step loop.
fn apply_edits(
    injected: &[FailSlowEvent],
    event_fault: &[usize],
    total_iters: usize,
    edits: &[Edit],
    sim: &mut TrainingSim,
    falcon: &mut Falcon,
) -> (Vec<FailSlowEvent>, Vec<(usize, Strategy)>) {
    let mut keep = vec![true; injected.len()];
    let mut forced: Vec<(usize, Strategy)> = Vec::new();
    for e in edits {
        match *e {
            Edit::DropFault(i) => {
                for (k, &fi) in event_fault.iter().enumerate() {
                    if fi == i {
                        keep[k] = false;
                    }
                }
            }
            Edit::NoMitigation => falcon.cfg.mitigate = false,
            Edit::DelayMitigation(n) => falcon.cfg.mitigation_delay_iters += n,
            Edit::ForceLevel { strategy, at_frac } => {
                forced.push((force_iter(at_frac, total_iters), strategy));
            }
            // audit:allow(panic-budget): check_edits rejects SwapPolicy for
            // single-job traces before apply_edits can see one.
            Edit::SwapPolicy(_) => unreachable!("checked: fleet-only edit"),
        }
    }
    let dropped: Vec<FailSlowEvent> = injected
        .iter()
        .zip(&keep)
        .filter(|(_, &k)| !k)
        .map(|(ev, _)| *ev)
        .collect();
    if !dropped.is_empty() {
        // Excise one sim event per dropped original (value-matched: the
        // sim's list is a subsequence of the original).
        let mut remaining = dropped;
        sim.remove_events(|ev| {
            if let Some(p) = remaining.iter().position(|d| d == ev) {
                remaining.swap_remove(p);
                true
            } else {
                false
            }
        });
    }
    let new_injected =
        injected.iter().zip(&keep).filter(|(_, &k)| k).map(|(ev, _)| *ev).collect();
    forced.sort_by_key(|&(at, _)| at);
    (new_injected, forced)
}

/// Step the tail of a (restored or fresh) run to the horizon, firing any
/// forced strategies, and assemble the outcome.
fn run_tail(
    spec: &ScenarioSpec,
    mut sim: TrainingSim,
    mut falcon: Falcon,
    injected: Vec<FailSlowEvent>,
    forced: &[(usize, Strategy)],
    from_iter: usize,
) -> Outcome {
    for i in from_iter..spec.run.iters {
        for &(at, strategy) in forced {
            if at == i {
                falcon.force(&mut sim, strategy);
            }
        }
        let obs = sim.step();
        falcon.on_iteration(&mut sim, obs.iter, obs.duration_s());
    }
    Outcome::from_single(spec, &sim, &falcon, &injected)
}

impl RunTrace {
    /// Replay this recording with `edits` applied.
    ///
    /// The run restarts from the latest snapshot at or before the edits'
    /// earliest divergence iteration, so the cost is proportional to the
    /// re-simulated tail — an empty edit list restores the final snapshot
    /// and returns the baseline outcome bit for bit, and dropping a
    /// late-run fault re-simulates only the iterations it could touch.
    pub fn replay(&self, edits: &[Edit]) -> Result<Outcome, WhatifError> {
        check_edits(&self.spec, edits)?;
        let total = self.spec.run.iters;
        let d = edits
            .iter()
            .map(|e| divergence_iter(self, e))
            .min()
            .unwrap_or(usize::MAX)
            .min(total);
        let Some(snap) = self.snapshots.iter().rev().find(|s| s.iter <= d) else {
            // Recordings always snapshot iteration 0, so this is a
            // corrupted/hand-built trace — refuse rather than crash.
            return Err(WhatifError::Unsupported(
                "trace has no snapshot at or before the divergence iteration".to_string(),
            ));
        };
        let mut sim = snap.sim.clone();
        let mut falcon = snap.falcon.clone();
        let (injected, forced) = apply_edits(
            &self.injected,
            &self.event_fault,
            total,
            edits,
            &mut sim,
            &mut falcon,
        );
        Ok(run_tail(&self.spec, sim, falcon, injected, &forced, snap.iter))
    }
}

/// Replay a scenario from scratch with the edits applied — no trace, no
/// snapshots: the full-cost baseline the snapshot path is measured (and
/// bit-compared) against.
pub fn replay_cold(spec: &ScenarioSpec, edits: &[Edit]) -> Result<Outcome, WhatifError> {
    if spec.fleet.is_some() {
        return replay_fleet(spec, edits);
    }
    check_edits(spec, edits)?;
    let mut sim = spec.build_sim().map_err(WhatifError::Scenario)?;
    let injected = sim.events.clone();
    let horizon_s = sim.ideal_iter_s * spec.run.iters as f64;
    let event_fault = spec.event_fault_indices(horizon_s);
    let mut falcon = Falcon::new(crate::coordinator::FalconConfig {
        mitigate: spec.run.mitigate,
        replan: spec.run.replan,
        ..Default::default()
    });
    let (injected, forced) = apply_edits(
        &injected,
        &event_fault,
        spec.run.iters,
        edits,
        &mut sim,
        &mut falcon,
    );
    Ok(run_tail(spec, sim, falcon, injected, &forced, 0))
}

/// Fleet counterfactual: lower the edits onto a modified spec and re-run
/// the campaign cold (deterministic, so "cold" is still exact).
fn replay_fleet(spec: &ScenarioSpec, edits: &[Edit]) -> Result<Outcome, WhatifError> {
    let mut spec = spec.clone();
    let mut drops: Vec<usize> = Vec::new();
    for e in edits {
        match *e {
            Edit::SwapPolicy(p) => {
                let Some(f) = spec.fleet.as_mut() else {
                    return Err(WhatifError::Unsupported(
                        "swap-policy needs a fleet scenario".to_string(),
                    ));
                };
                f.policy = Some(p);
            }
            Edit::DropFault(i) => drops.push(i),
            other => {
                return Err(WhatifError::Unsupported(format!(
                    "{other} does not apply to fleet scenarios (the engine forces \
                     per-mode mitigation)"
                )))
            }
        }
    }
    drops.sort_unstable();
    drops.dedup();
    for &i in drops.iter().rev() {
        if i >= spec.faults.len() {
            return Err(WhatifError::Unsupported(format!(
                "drop-fault {i}: scenario '{}' has {} faults",
                spec.name,
                spec.faults.len()
            )));
        }
        spec.faults.remove(i);
    }
    spec.run().map_err(WhatifError::Scenario)
}

impl FleetRecord {
    /// Replay the fleet with edits applied ([`Edit::SwapPolicy`] /
    /// [`Edit::DropFault`]; per-job mitigation shaping is not meaningful —
    /// the engine forces per-mode behavior).
    pub fn replay(&self, edits: &[Edit]) -> Result<Outcome, WhatifError> {
        replay_fleet(&self.spec, edits)
    }
}

/// Fan a sweep of edit sets across `workers` std::thread workers (0 = one
/// per core), exactly like the fleet engine shards jobs: an atomic cursor
/// hands out indices, results land in per-index slots, so the output
/// order matches the input regardless of scheduling.
pub fn sweep(
    trace: &RunTrace,
    edit_sets: &[Vec<Edit>],
    workers: usize,
) -> Vec<Result<Outcome, WhatifError>> {
    let n = edit_sets.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = if workers > 0 {
        workers
    } else {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
    }
    .min(n);
    let next = AtomicUsize::new(0);
    let slots: Mutex<Vec<Option<Result<Outcome, WhatifError>>>> = Mutex::new(vec![None; n]);
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = trace.replay(&edit_sets[i]);
                slots.lock().unwrap_or_else(|e| e.into_inner())[i] = Some(r);
            });
        }
    });
    slots
        .into_inner()
        .unwrap_or_else(|e| e.into_inner())
        .into_iter()
        // audit:allow(panic-budget): the worker loop claims every index
        // below n exactly once and scope() joins all workers first.
        .map(|r| r.expect("every sweep slot completes"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::super::trace::{record, TraceConfig};
    use super::*;
    use crate::scenario::{find, library};

    fn cap(mut spec: ScenarioSpec) -> ScenarioSpec {
        let cap = if spec.fleet.is_some() { 30 } else { 120 };
        spec.run.iters = spec.run.iters.min(cap);
        spec
    }

    #[test]
    fn empty_edit_replay_is_bit_identical_across_library() {
        // The acceptance property: for EVERY library entry, recording a
        // run and replaying it with no edits reproduces the baseline
        // Outcome::to_json bit for bit (single-job entries exercise the
        // final-snapshot restore; fleet entries the deterministic cold
        // path).
        for spec in library::all() {
            let spec = cap(spec);
            let rec = super::super::record_scenario(&spec, &TraceConfig { snapshot_every: 40 })
                .unwrap_or_else(|e| panic!("{}: {e}", spec.name));
            let baseline = rec.outcome().to_json().to_string();
            let replayed = rec
                .replay(&[])
                .unwrap_or_else(|e| panic!("{}: {e}", spec.name))
                .to_json()
                .to_string();
            assert_eq!(baseline, replayed, "scenario '{}' empty-edit replay diverged", spec.name);
        }
    }

    #[test]
    fn snapshot_replay_matches_cold_replay_bitwise() {
        // The replay engine's correctness bar: restoring a mid-run
        // snapshot and re-simulating the tail must equal a from-scratch
        // run with the same edit — for a fault edit and a mitigation edit.
        let spec = find("slow-leak-gpu").unwrap().iters(160);
        let trace = record(&spec, &TraceConfig { snapshot_every: 25 }).unwrap();
        for edits in [
            vec![Edit::DropFault(0)],
            vec![Edit::NoMitigation],
            vec![Edit::DelayMitigation(30)],
            vec![Edit::ForceLevel { strategy: Strategy::AdjustMicrobatch, at_frac: 0.5 }],
            vec![Edit::ForceLevel { strategy: Strategy::ReplanParallelism, at_frac: 0.5 }],
        ] {
            let warm = trace.replay(&edits).unwrap().to_json().to_string();
            let cold = replay_cold(&spec, &edits).unwrap().to_json().to_string();
            assert_eq!(warm, cold, "edits {edits:?} diverged from cold replay");
        }
    }

    #[test]
    fn drop_fault_removes_events_and_speeds_the_run() {
        let spec = find("slow-leak-gpu").unwrap().iters(160);
        let trace = record(&spec, &TraceConfig::default()).unwrap();
        let out = trace.replay(&[Edit::DropFault(0)]).unwrap();
        assert_eq!(out.injected, 0, "the ramp's events must all vanish");
        assert!(
            out.jct_s < trace.outcome.jct_s,
            "dropping the only fault must speed the run: {} vs {}",
            out.jct_s,
            trace.outcome.jct_s
        );
    }

    #[test]
    fn force_level_restart_charges_its_cost() {
        // Forcing S4 on a healthy run pays the checkpoint-restart pause
        // and nothing else: JCT grows by at least the restart cost.
        let spec = ScenarioSpec::new("forced", 2, 4, 1).nodes(1).iters(80).seed(5);
        let trace = record(&spec, &TraceConfig::default()).unwrap();
        let out = trace
            .replay(&[Edit::ForceLevel { strategy: Strategy::CkptRestart, at_frac: 0.5 }])
            .unwrap();
        let restart_s = 20.0 * 60.0; // FalconConfig::default().restart_cost
        assert!(
            out.jct_s >= trace.outcome.jct_s + 0.9 * restart_s,
            "forced S4 must charge the restart: {} vs baseline {}",
            out.jct_s,
            trace.outcome.jct_s
        );
    }

    #[test]
    fn force_at_frac_one_fires_on_the_last_iteration() {
        // @1.0 caps to the final executed iteration instead of silently
        // never firing (the loop is exclusive of `iters`).
        let spec = ScenarioSpec::new("forced-end", 2, 4, 1).nodes(1).iters(60).seed(6);
        let trace = record(&spec, &TraceConfig::default()).unwrap();
        let out = trace
            .replay(&[Edit::ForceLevel { strategy: Strategy::CkptRestart, at_frac: 1.0 }])
            .unwrap();
        assert!(
            out.jct_s > trace.outcome.jct_s + 1000.0,
            "forced S4 at @1.0 must still charge the restart: {} vs {}",
            out.jct_s,
            trace.outcome.jct_s
        );
    }

    #[test]
    fn bad_edits_are_rejected() {
        let spec = find("gpu-thermal").unwrap().iters(60);
        let trace = record(&spec, &TraceConfig::default()).unwrap();
        assert!(matches!(
            trace.replay(&[Edit::DropFault(7)]),
            Err(WhatifError::Unsupported(_))
        ));
        assert!(matches!(
            trace.replay(&[Edit::SwapPolicy(crate::cluster::Policy::Packed)]),
            Err(WhatifError::Unsupported(_))
        ));
        // Fleet records reject per-job mitigation shaping.
        let mut fleet = find("noisy-neighbor").unwrap();
        fleet.run.iters = 20;
        let rec = super::super::record_fleet(&fleet).unwrap();
        assert!(matches!(
            rec.replay(&[Edit::NoMitigation]),
            Err(WhatifError::Unsupported(_))
        ));
        // Swapping the policy re-runs under the new arbiter.
        let swapped = rec
            .replay(&[Edit::SwapPolicy(crate::cluster::Policy::Spread)])
            .unwrap();
        assert_eq!(swapped.fleet.unwrap().policy.as_deref(), Some("spread"));
    }

    #[test]
    fn sweep_matches_serial_replays() {
        let spec = find("cpu-contention").unwrap().iters(120);
        let trace = record(&spec, &TraceConfig::default()).unwrap();
        let sets = vec![
            vec![],
            vec![Edit::DropFault(0)],
            vec![Edit::DropFault(1)],
            vec![Edit::NoMitigation],
        ];
        let fanned = sweep(&trace, &sets, 3);
        for (set, out) in sets.iter().zip(&fanned) {
            let serial = trace.replay(set).unwrap();
            assert_eq!(
                out.as_ref().unwrap().to_json().to_string(),
                serial.to_json().to_string(),
                "sweep diverged on {set:?}"
            );
        }
    }
}
