//! What-if engine: recorded traces, counterfactual replay, and blame
//! attribution.
//!
//! FALCON's evaluation quantifies fail-slow damage only in aggregate;
//! "Understanding Stragglers in Large Model Training Using What-if
//! Analysis" (PAPERS.md) argues the right primitive is **counterfactual
//! simulation**: replay the same run with one fault removed or one
//! decision changed, and attribute the delay to whatever the edit
//! excised. This module builds that primitive on top of the deterministic
//! scenario API:
//!
//! - **Recording** ([`record`] / [`record_fleet`] / [`record_scenario`]):
//!   run a [`ScenarioSpec`] while capturing a compact per-iteration trace
//!   — iteration times, the active fault set, the cluster health epoch —
//!   plus the coordinator's full action log (including arbiter
//!   grants/denials) and periodic **full-state snapshots** (sim +
//!   coordinator, [`TraceConfig::snapshot_every`] iterations apart).
//!   Shared-cluster fleet runs additionally record per-epoch contention
//!   rosters ([`crate::fleet::FleetTrace`]).
//!
//! - **Replay** ([`RunTrace::replay`]): apply typed [`Edit`]s
//!   ([`Edit::DropFault`], [`Edit::NoMitigation`],
//!   [`Edit::DelayMitigation`], [`Edit::ForceLevel`],
//!   [`Edit::SwapPolicy`]) and deterministically re-execute. The engine
//!   computes each edit's **divergence iteration** — the first iteration
//!   the edit can possibly affect — restores the latest snapshot at or
//!   before it (cluster health, RNG stream position, detector posterior,
//!   planner cursor, and the warm [`crate::sim`] caches all come along),
//!   and re-simulates only the tail. A replay therefore costs
//!   O(iterations after divergence) instead of a cold run's O(all
//!   iterations), on unchanged base RNG streams. An empty edit list
//!   restores the final snapshot and reproduces the recorded baseline
//!   bit for bit (pinned over the whole scenario library).
//!
//! - **Attribution** ([`attribute`], [`contention_blame`]): per-fault
//!   delay (baseline JCT minus the fault-removed replay's JCT),
//!   mitigation benefit (the `NoMitigation` replay's excess), the
//!   paper-style aggregate JCT-delay %, and — for shared-cluster fleets —
//!   per-job contention blame (which job slowed which on the leaf
//!   uplinks). Edit sweeps fan out across `std::thread` workers exactly
//!   like the fleet engine ([`sweep`]).
//!
//! `falcon whatif <scenario|file>` is the CLI entry; the `whatif` report
//! id renders the same analysis through `falcon report`. See
//! `docs/WHATIF.md` for the edit grammar and attribution semantics.

mod attribution;
mod replay;
mod trace;

pub use attribution::{attribute, contention_blame, render_blame, BlameEntry};
// Re-exported for back-compat: the attribution result types moved into
// the Outcome shape (`crate::scenario::outcome`) so `scenario` does not
// depend on `whatif`.
pub use crate::scenario::{Attribution, FaultAttribution};
pub use replay::{replay_cold, sweep};
pub use trace::{
    record, record_fleet, FleetRecord, IterRecord, RunTrace, TraceConfig, MAX_SNAPSHOTS,
};

use crate::cluster::Policy;
use crate::mitigate::Strategy;
use crate::scenario::{Outcome, ScenarioError, ScenarioSpec};

/// One typed counterfactual edit to a recorded run.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Edit {
    /// Remove fault `i` (an index into the spec's `[[fault]]` script; every
    /// event the fault expanded to — ramp steps, recurrences — vanishes).
    DropFault(usize),
    /// Run the same trace with FALCON-MITIGATE switched off (detection
    /// still runs — the paper's probe mode).
    NoMitigation,
    /// Hold mitigation back for this many extra iterations after each
    /// episode opens ("what if FALCON had reacted later?").
    DelayMitigation(usize),
    /// Force-execute a strategy at `at_frac` of the horizon, bypassing the
    /// ski-rental planner ("what if S3 had run at t?").
    ForceLevel { strategy: Strategy, at_frac: f64 },
    /// Fleet scenarios: re-run the campaign under a different shared
    /// cluster policy.
    SwapPolicy(Policy),
}

impl std::fmt::Display for Edit {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Edit::DropFault(i) => write!(f, "drop-fault {i}"),
            Edit::NoMitigation => write!(f, "no-mitigation"),
            Edit::DelayMitigation(n) => write!(f, "delay-mitigation {n}"),
            Edit::ForceLevel { strategy, at_frac } => {
                write!(f, "force {} @{at_frac}", strategy.name())
            }
            Edit::SwapPolicy(p) => write!(f, "swap-policy {}", p.name()),
        }
    }
}

/// What-if failure: an edit that does not apply to the recorded scenario,
/// or an invalid scenario underneath.
#[derive(Clone, Debug, PartialEq)]
pub enum WhatifError {
    Scenario(ScenarioError),
    /// The edit cannot apply to this recording (wrong mode or bad index).
    Unsupported(String),
}

impl std::fmt::Display for WhatifError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WhatifError::Scenario(e) => write!(f, "{e}"),
            WhatifError::Unsupported(msg) => write!(f, "unsupported edit: {msg}"),
        }
    }
}

impl std::error::Error for WhatifError {}

impl From<ScenarioError> for WhatifError {
    fn from(e: ScenarioError) -> Self {
        WhatifError::Scenario(e)
    }
}

/// A recorded run of either mode, behind one replay interface.
pub enum Recording {
    Single(Box<RunTrace>),
    Fleet(Box<FleetRecord>),
}

/// Record a scenario in whichever mode it declares: single jobs get the
/// snapshot-backed [`RunTrace`]; fleet scenarios get a [`FleetRecord`]
/// (cold re-runs + contention rosters).
pub fn record_scenario(
    spec: &ScenarioSpec,
    cfg: &TraceConfig,
) -> Result<Recording, ScenarioError> {
    if spec.fleet.is_some() {
        record_fleet(spec).map(|f| Recording::Fleet(Box::new(f)))
    } else {
        record(spec, cfg).map(|t| Recording::Single(Box::new(t)))
    }
}

impl Recording {
    /// The baseline outcome the recording captured.
    pub fn outcome(&self) -> &Outcome {
        match self {
            Recording::Single(t) => &t.outcome,
            Recording::Fleet(f) => &f.outcome,
        }
    }

    /// Replay with the edits applied (see [`RunTrace::replay`] and
    /// [`FleetRecord::replay`] for the per-mode mechanics).
    pub fn replay(&self, edits: &[Edit]) -> Result<Outcome, WhatifError> {
        match self {
            Recording::Single(t) => t.replay(edits),
            Recording::Fleet(f) => f.replay(edits),
        }
    }
}
