//! PJRT runtime: load AOT-compiled HLO text artifacts and execute them.
//!
//! This is the only place Rust touches XLA. Python lowered the Layer-2 JAX
//! train step (with its Layer-1 Pallas kernels) to `artifacts/*.hlo.txt` at
//! build time; here the text parses into an `HloModuleProto` (the parser
//! reassigns instruction ids — why text, not serialized protos, is the
//! interchange format), compiles once per process, and executes on the
//! PJRT CPU client. Nothing on this path imports or spawns Python.
//!
//! Build gating: the module sits behind the `pjrt` cargo feature. The
//! default offline build compiles it out entirely; with the feature on it
//! builds against the in-tree [`crate::xla`]/[`crate::anyhow`] shims (the
//! real external crates are not vendored yet — ROADMAP open item — so
//! executing an artifact reports "XLA backend not vendored" at runtime,
//! but the whole path type-checks in CI). `ModelMeta::load` reads the preset's
//! `model_<preset>.meta.json`, `Runtime::new` owns the PJRT client, and
//! `crate::trainer::LiveTrainer` drives the compiled step function with
//! FALCON attached (the `falcon train` subcommand and `bench_runtime`).
//! Run `make artifacts` first to produce the HLO/meta files.

use crate::anyhow::{self, bail, Context, Result};
use crate::xla;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// Model hyperparameters mirrored from `model_<preset>.meta.json`.
#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub preset: String,
    pub n_params: usize,
    pub param_names: Vec<String>,
    pub param_shapes: Vec<Vec<usize>>,
    pub batch: usize,
    pub vocab: usize,
    pub n_ctx: usize,
    pub lr: f64,
    pub momentum: f64,
}

impl ModelMeta {
    pub fn load(dir: &Path, preset: &str) -> Result<ModelMeta> {
        let path = dir.join(format!("model_{preset}.meta.json"));
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("parse {path:?}: {e}"))?;
        let cfg = j.get("config").context("meta missing config")?;
        let shapes = j
            .get("param_shapes")
            .and_then(|s| s.as_arr())
            .context("meta missing param_shapes")?
            .iter()
            .map(|row| {
                row.as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(|d| d.as_usize())
                    .collect()
            })
            .collect();
        let names = j
            .get("param_names")
            .and_then(|s| s.as_arr())
            .context("meta missing param_names")?
            .iter()
            .filter_map(|n| n.as_str().map(|s| s.to_string()))
            .collect();
        Ok(ModelMeta {
            preset: preset.to_string(),
            n_params: j.get("n_params").and_then(|v| v.as_usize()).context("n_params")?,
            param_names: names,
            param_shapes: shapes,
            batch: j.get("batch").and_then(|v| v.as_usize()).unwrap_or(4),
            vocab: cfg.get("vocab").and_then(|v| v.as_usize()).context("vocab")?,
            n_ctx: cfg.get("n_ctx").and_then(|v| v.as_usize()).context("n_ctx")?,
            lr: cfg.get("lr").and_then(|v| v.as_f64()).unwrap_or(0.1),
            momentum: cfg.get("momentum").and_then(|v| v.as_f64()).unwrap_or(0.9),
        })
    }

    /// Number of elements of parameter i.
    pub fn param_len(&self, i: usize) -> usize {
        self.param_shapes[i].iter().product::<usize>().max(1)
    }
}

/// A compiled executable plus its origin.
pub struct Artifact {
    pub name: String,
    pub exe: xla::PjRtLoadedExecutable,
}

/// The PJRT runtime: one CPU client, many compiled artifacts.
pub struct Runtime {
    pub client: xla::PjRtClient,
    pub dir: PathBuf,
}

impl Runtime {
    /// CPU PJRT client over the artifact directory.
    pub fn new(artifact_dir: impl AsRef<Path>) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Runtime { client, dir: artifact_dir.as_ref().to_path_buf() })
    }

    /// Load + compile `<name>.hlo.txt`.
    pub fn load(&self, name: &str) -> Result<Artifact> {
        let path = self.dir.join(format!("{name}.hlo.txt"));
        if !path.exists() {
            bail!("artifact {path:?} missing — run `make artifacts`");
        }
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .map_err(|e| anyhow::anyhow!("parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {name}: {e:?}"))?;
        Ok(Artifact { name: name.to_string(), exe })
    }

    /// Read the flat f32 initial-parameter dump and split per parameter.
    pub fn load_params(&self, meta: &ModelMeta) -> Result<Vec<Vec<f32>>> {
        let path = self.dir.join(format!("params_{}.bin", meta.preset));
        let bytes = std::fs::read(&path).with_context(|| format!("read {path:?}"))?;
        if bytes.len() != meta.n_params * 4 {
            bail!("{path:?}: {} bytes, expected {}", bytes.len(), meta.n_params * 4);
        }
        let mut flat = vec![0f32; meta.n_params];
        for (i, chunk) in bytes.chunks_exact(4).enumerate() {
            flat[i] = f32::from_le_bytes(chunk.try_into().unwrap());
        }
        let mut out = Vec::with_capacity(meta.param_shapes.len());
        let mut off = 0;
        for i in 0..meta.param_shapes.len() {
            let len = self_param_len(&meta.param_shapes[i]);
            out.push(flat[off..off + len].to_vec());
            off += len;
        }
        debug_assert_eq!(off, meta.n_params);
        Ok(out)
    }
}

fn self_param_len(shape: &[usize]) -> usize {
    shape.iter().product::<usize>().max(1)
}

impl Artifact {
    /// Execute with literal inputs; unpack the (return_tuple=True) tuple
    /// output into per-element f32 vectors.
    pub fn run_f32(&self, inputs: &[xla::Literal]) -> Result<Vec<Vec<f32>>> {
        let bufs = self
            .exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow::anyhow!("execute {}: {e:?}", self.name))?;
        let lit = bufs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch {}: {e:?}", self.name))?;
        let parts = lit
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untuple {}: {e:?}", self.name))?;
        parts
            .into_iter()
            .map(|p| p.to_vec::<f32>().map_err(|e| anyhow::anyhow!("to_vec: {e:?}")))
            .collect()
    }
}

/// Build an f32 literal of the given shape.
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    if n as usize != data.len() {
        bail!("literal_f32: {} elements for shape {dims:?}", data.len());
    }
    xla::Literal::vec1(data)
        .reshape(dims)
        .map_err(|e| anyhow::anyhow!("reshape: {e:?}"))
}

/// Build an i32 literal of the given shape.
pub fn literal_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    if n as usize != data.len() {
        bail!("literal_i32: {} elements for shape {dims:?}", data.len());
    }
    xla::Literal::vec1(data)
        .reshape(dims)
        .map_err(|e| anyhow::anyhow!("reshape: {e:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn art_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        art_dir().join(".stamp").exists()
    }

    #[test]
    fn meta_loads_and_is_consistent() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let meta = ModelMeta::load(&art_dir(), "tiny").unwrap();
        assert_eq!(meta.vocab, 96);
        let total: usize = meta.param_shapes.iter().map(|s| self_param_len(s)).sum();
        assert_eq!(total, meta.n_params);
        assert_eq!(meta.param_names.len(), meta.param_shapes.len());
    }

    #[test]
    fn params_bin_splits_cleanly() {
        if !have_artifacts() {
            return;
        }
        let rt = Runtime::new(art_dir()).unwrap();
        let meta = ModelMeta::load(&art_dir(), "tiny").unwrap();
        let params = rt.load_params(&meta).unwrap();
        assert_eq!(params.len(), meta.param_shapes.len());
        for (i, p) in params.iter().enumerate() {
            assert_eq!(p.len(), meta.param_len(i));
        }
        // LN gains are exactly 1.0 at init — spot-check the layout split.
        for (i, name) in meta.param_names.iter().enumerate() {
            if name.ends_with("_g") {
                assert!(params[i].iter().all(|&x| x == 1.0), "{name}");
            }
        }
    }

    #[test]
    fn literal_shape_validation() {
        assert!(literal_f32(&[1.0, 2.0], &[3, 1]).is_err());
        assert!(literal_f32(&[1.0, 2.0, 3.0], &[3, 1]).is_ok());
        assert!(literal_i32(&[1, 2, 3, 4], &[2, 2]).is_ok());
    }

    #[test]
    fn gemm_bench_artifact_runs() {
        if !have_artifacts() {
            return;
        }
        let rt = Runtime::new(art_dir()).unwrap();
        let art = rt.load("gemm_bench").unwrap();
        let n = 256usize;
        let x: Vec<f32> = (0..n * n).map(|i| ((i % 13) as f32 - 6.0) / 6.0).collect();
        let w: Vec<f32> = (0..n * n).map(|i| ((i % 7) as f32 - 3.0) / 3.0).collect();
        let out = art
            .run_f32(&[
                literal_f32(&x, &[n as i64, n as i64]).unwrap(),
                literal_f32(&w, &[n as i64, n as i64]).unwrap(),
            ])
            .unwrap();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].len(), n * n);
        assert_eq!(out[1].len(), 1);
        assert!(out[1][0].is_finite());
        // Normalization bounds the output.
        assert!(out[0].iter().all(|v| v.abs() <= 1.0 + 1e-4));
    }
}
