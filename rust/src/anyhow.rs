//! In-tree stand-in for the `anyhow` crate (pjrt builds only).
//!
//! The live PJRT path (`runtime/`, `trainer/`) was written against the
//! external `anyhow` crate, which cannot be declared in the offline
//! Cargo.toml. This shim supplies the exact surface those modules use —
//! [`Error`], [`Result`], the [`Context`] extension trait on `Result` and
//! `Option`, and the `anyhow!` / `bail!` / `ensure!` macros — so
//! `cargo build --features pjrt` compiles without network access. It is a
//! faithful-but-minimal substitute: errors are context-joined strings,
//! not chained sources. Swap in the real crate by deleting this module
//! and declaring the dependency once vendoring lands (ROADMAP).

/// String-backed error with `anyhow`-style context joining.
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg(m: impl Into<String>) -> Error {
        Error { msg: m.into() }
    }

    fn wrap(self, ctx: impl std::fmt::Display) -> Error {
        Error { msg: format!("{ctx}: {}", self.msg) }
    }
}

impl std::fmt::Debug for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::msg(e.to_string())
    }
}

impl From<String> for Error {
    fn from(m: String) -> Error {
        Error::msg(m)
    }
}

impl From<&str> for Error {
    fn from(m: &str) -> Error {
        Error::msg(m)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `anyhow::Context`: attach context to the error arm of a `Result` or to
/// a `None`.
pub trait Context<T> {
    fn context<C: std::fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: std::fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: std::fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: std::fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::msg(e.to_string()).wrap(ctx))
    }

    fn with_context<C: std::fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(e.to_string()).wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: std::fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx.to_string()))
    }

    fn with_context<C: std::fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

macro_rules! anyhow {
    ($($t:tt)*) => { $crate::anyhow::Error::msg(format!($($t)*)) };
}

macro_rules! bail {
    ($($t:tt)*) => { return Err($crate::anyhow::anyhow!($($t)*)) };
}

macro_rules! ensure {
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            $crate::anyhow::bail!($($t)*)
        }
    };
}

pub use {anyhow, bail, ensure};

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<u32> {
        Err(anyhow!("base {}", 42))
    }

    #[test]
    fn context_joins_messages() {
        let e = fails().context("outer").unwrap_err();
        assert_eq!(format!("{e}"), "outer: base 42");
        let e = fails().with_context(|| format!("ring {}", 7)).unwrap_err();
        assert_eq!(format!("{e:?}"), "ring 7: base 42");
        let none: Option<u32> = None;
        assert!(none.context("missing").is_err());
    }

    #[test]
    fn bail_and_ensure_short_circuit() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "too big: {x}");
            if x == 0 {
                bail!("zero");
            }
            Ok(x)
        }
        assert!(f(3).is_ok());
        assert_eq!(f(11).unwrap_err().to_string(), "too big: 11");
        assert_eq!(f(0).unwrap_err().to_string(), "zero");
    }
}
