//! Built-in named scenarios: the paper's §3 cases plus beyond-paper
//! fail-slow shapes. `falcon run <name>` executes one; `falcon scenarios`
//! lists them; every entry round-trips through the TOML renderer/parser.

use crate::cluster::Policy;
use crate::inject::{FailSlowKind, Target};

use super::{FaultSpec, FleetSpec, LedgerSpec, ScenarioSpec};

/// Names of the built-in scenarios, in presentation order.
pub const LIBRARY: &[&str] = &[
    "cpu-contention",
    "gpu-thermal",
    "net-congestion",
    "compound-cascade",
    "slow-leak-gpu",
    "flapping-link",
    "transient-spikes",
    "cascading-leaf-congestion",
    "correlated-storm",
    "hang",
    "hang-then-recover",
    "slow-masking-a-hang",
    "multi-tenant-burst",
    "fleet-breathing",
    "noisy-neighbor",
    "stage-straggler-persistent",
    "no-spares-degradation",
    "recurrent-flaky-node",
    "heavy-tailed-fleet",
];

/// Build one library scenario by name (`None` for unknown names).
pub fn find(name: &str) -> Option<ScenarioSpec> {
    use FailSlowKind::{
        CommHang as Hang, CpuContention as Cpu, GpuDegradation as Gpu, NetworkCongestion as Net,
    };
    Some(match name {
        // --- the paper's §3 case studies ---------------------------------
        "cpu-contention" => ScenarioSpec::new(name, 2, 1, 2)
            .describe("paper case 1: two CPU-contention bursts on a 1-node GPT2-11B job")
            .model("gpt2-11b")
            .nodes(1)
            .iters(600)
            .seed(2)
            .fault(FaultSpec::new(Cpu, Target::Node(0), 0.25, 0.12, 0.35))
            .fault(FaultSpec::new(Cpu, Target::Node(0), 0.62, 0.10, 0.45)),
        "gpu-thermal" => ScenarioSpec::new(name, 2, 1, 2)
            .describe("paper case 2: one GPU thermally throttled to 80% for the early run")
            .model("gpt2-11b")
            .nodes(1)
            .iters(500)
            .seed(3)
            .fault(FaultSpec::new(Gpu, Target::Gpu(0), 0.0, 0.3, 0.8)),
        "net-congestion" => ScenarioSpec::new(name, 2, 4, 1)
            .describe("paper case 3: two congestion episodes on a 4-node GPT2-7B job")
            .nodes(4)
            .iters(700)
            .seed(4)
            .fault(FaultSpec::new(Net, Target::Uplink(2), 0.27, 0.20, 0.45))
            .fault(FaultSpec::new(Net, Target::Uplink(2), 0.75, 0.18, 0.25)),
        "compound-cascade" => ScenarioSpec::new(name, 2, 4, 2)
            .describe("compound comm+comp fail-slow: a congested link, then a degraded GPU")
            .nodes(8)
            .iters(400)
            .seed(17)
            .jitter(0.01)
            .fault(FaultSpec::new(Net, Target::Link(0, 1), 0.08, 1.2, 0.25))
            .fault(FaultSpec::new(Gpu, Target::Gpu(2), 0.4, 1.2, 0.45)),
        // --- beyond-paper shapes -----------------------------------------
        "slow-leak-gpu" => ScenarioSpec::new(name, 1, 8, 1)
            .describe("slow leak: one GPU ramps from 90% down to 35% in ten steps")
            .nodes(1)
            .iters(400)
            .seed(5)
            .fault(FaultSpec::new(Gpu, Target::Gpu(3), 0.1, 0.8, 0.9).ramp(0.35, 10)),
        "flapping-link" => ScenarioSpec::new(name, 2, 8, 1)
            .describe("flapping uplink: eight short congestion bursts, evenly spaced")
            .nodes(2)
            .iters(500)
            .seed(6)
            .fault(FaultSpec::new(Net, Target::Uplink(1), 0.1, 0.05, 0.3).recurring(7, 0.11)),
        "transient-spikes" => ScenarioSpec::new(name, 2, 4, 1)
            .describe("six brief CPU bursts that BOCD+V should mostly dismiss as transient")
            .nodes(1)
            .iters(400)
            .seed(7)
            .fault(FaultSpec::new(Cpu, Target::Node(0), 0.2, 0.01, 0.5).recurring(5, 0.15)),
        "cascading-leaf-congestion" => ScenarioSpec::new(name, 1, 16, 1)
            .describe("leaf congestion cascade: four uplinks degrade in a worsening ladder")
            .nodes(4)
            .iters(500)
            .seed(8)
            .fault(FaultSpec::new(Net, Target::Uplink(0), 0.1, 0.25, 0.50))
            .fault(FaultSpec::new(Net, Target::Uplink(1), 0.3, 0.25, 0.42))
            .fault(FaultSpec::new(Net, Target::Uplink(2), 0.5, 0.25, 0.34))
            .fault(FaultSpec::new(Net, Target::Uplink(3), 0.7, 0.25, 0.26)),
        "correlated-storm" => ScenarioSpec::new(name, 2, 8, 1)
            .describe("correlated storm: a leaf uplink jams while two co-located GPUs degrade")
            .nodes(4)
            .iters(500)
            .seed(9)
            .fault(FaultSpec::new(Net, Target::Uplink(1), 0.30, 0.25, 0.40))
            .fault(FaultSpec::new(Gpu, Target::Gpu(4), 0.32, 0.22, 0.55))
            .fault(FaultSpec::new(Gpu, Target::Gpu(5), 0.34, 0.20, 0.60)),
        // --- hang-vs-slow taxonomy (CCL-D, PAPERS.md) --------------------
        "hang" => ScenarioSpec::new(name, 2, 4, 1)
            .describe("permanent comm hang: the node1-node2 path wedges; only S4 clears it")
            .nodes(4)
            .iters(500)
            .seed(21)
            .fault(FaultSpec::new(Hang, Target::Link(1, 2), 0.3, 0.7, 1.0)),
        "hang-then-recover" => ScenarioSpec::new(name, 2, 4, 1)
            .describe("transient uplink hang that un-wedges on its own; no mitigation")
            .nodes(4)
            .iters(500)
            .seed(22)
            .mitigate(false)
            .fault(FaultSpec::new(Hang, Target::Uplink(2), 0.2, 0.65, 1.0)),
        "slow-masking-a-hang" => ScenarioSpec::new(name, 2, 4, 1)
            .describe("a degraded GPU drags iterations, then a link hang hides underneath")
            .nodes(4)
            .iters(500)
            .seed(23)
            .fault(FaultSpec::new(Gpu, Target::Gpu(2), 0.15, 0.75, 0.55))
            .fault(FaultSpec::new(Hang, Target::Link(0, 3), 0.45, 0.45, 1.0)),
        // --- fleet / shared-cluster scenarios ----------------------------
        "multi-tenant-burst" => ScenarioSpec::new(name, 2, 4, 1)
            .describe("24 tenants burst onto one packed shared cluster at heavy injection")
            .iters(80)
            .seed(11)
            .with_fleet(FleetSpec {
                jobs: 24,
                workers: 0,
                boost: 20.0,
                compare: false,
                policy: Some(Policy::Packed),
                spare: 0.1,
                epoch_len: 10,
                stagger: 0.0,
            }),
        "fleet-breathing" => ScenarioSpec::new(name, 2, 4, 1)
            .describe("staggered fleet on a shared cluster: jobs come and go, the pool breathes")
            .iters(60)
            .seed(12)
            .with_fleet(FleetSpec {
                jobs: 16,
                workers: 0,
                boost: 12.0,
                compare: false,
                policy: Some(Policy::StragglerAware),
                spare: 0.25,
                epoch_len: 10,
                stagger: 2.0,
            }),
        "noisy-neighbor" => ScenarioSpec::new(name, 2, 4, 1)
            .describe("shared fleet where a scripted GPU fault strikes exactly job 0")
            .iters(60)
            .seed(13)
            .fault(FaultSpec::new(Gpu, Target::Gpu(0), 0.2, 0.5, 0.5).on_job(0))
            .with_fleet(FleetSpec {
                jobs: 8,
                workers: 0,
                boost: 4.0,
                compare: false,
                policy: Some(Policy::FirstFit),
                spare: 0.2,
                epoch_len: 10,
                stagger: 0.0,
            }),
        // --- S5 malleable-parallelism scenarios --------------------------
        "stage-straggler-persistent" => ScenarioSpec::new(name, 8, 2, 2)
            .describe("one slow pipeline-stage node with spares exhausted; S5 replans in place")
            .nodes(4)
            .iters(400)
            .seed(31)
            .replan(true)
            .fault(FaultSpec::new(Cpu, Target::Node(1), 0.15, 1.2, 0.5)),
        "no-spares-degradation" => ScenarioSpec::new(name, 2, 4, 1)
            .describe("saturated shared pool + persistent GPU degradation: every grant denied")
            .iters(60)
            .seed(32)
            .replan(true)
            .fault(FaultSpec::new(Gpu, Target::Gpu(0), 0.1, 1.5, 0.5).on_job(0))
            .with_fleet(FleetSpec {
                jobs: 8,
                workers: 0,
                boost: 4.0,
                compare: false,
                policy: Some(Policy::Packed),
                spare: 0.0,
                epoch_len: 10,
                stagger: 0.0,
            }),
        // --- node-health ledger scenarios --------------------------------
        "recurrent-flaky-node" => ScenarioSpec::new(name, 2, 4, 1)
            .describe("chronically flaky nodes relapse; predictive quarantine learns them")
            .iters(60)
            .seed(41)
            .with_fleet(FleetSpec {
                jobs: 12,
                workers: 0,
                boost: 4.0,
                compare: false,
                policy: Some(Policy::PredictiveQuarantine),
                spare: 0.25,
                epoch_len: 10,
                stagger: 1.0,
            })
            .with_ledger(LedgerSpec { enabled: true, flaky: 0.15, alpha: 1.2 }),
        "heavy-tailed-fleet" => ScenarioSpec::new(name, 2, 4, 1)
            .describe("a third of the pool flares on Pareto gaps; placement follows health")
            .iters(60)
            .seed(42)
            .with_fleet(FleetSpec {
                jobs: 16,
                workers: 0,
                boost: 2.0,
                compare: false,
                policy: Some(Policy::HealthWeighted),
                spare: 0.3,
                epoch_len: 10,
                stagger: 1.5,
            })
            .with_ledger(LedgerSpec { enabled: true, flaky: 0.3, alpha: 1.1 }),
        _ => return None,
    })
}

/// Build every library scenario.
pub fn all() -> Vec<ScenarioSpec> {
    // audit:allow(panic-budget): LIBRARY and find() are defined side by
    // side in this file; the round-trip is pinned by the tests below.
    LIBRARY.iter().map(|n| find(n).expect("library names build")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_library_scenario_is_valid() {
        for spec in all() {
            spec.validate().unwrap_or_else(|e| panic!("{}: {e}", spec.name));
            assert!(!spec.description.is_empty(), "{} has no description", spec.name);
            assert!(LIBRARY.contains(&spec.name.as_str()));
        }
        assert_eq!(LIBRARY.len(), 19);
        assert!(find("no-such-scenario").is_none());
    }

    #[test]
    fn slow_leak_gpu_runs_end_to_end() {
        // The acceptance scenario: a named library entry executes through
        // ScenarioSpec::run. Shortened horizon keeps the test quick.
        let outcome = find("slow-leak-gpu").unwrap().iters(150).run().unwrap();
        assert_eq!(outcome.scenario, "slow-leak-gpu");
        assert_eq!(outcome.injected, 10, "ramp expands to ten staircase steps");
        assert_eq!(outcome.timeline_thpt.len(), 150);
        assert!(outcome.mean_thpt > 0.0);
        assert!(outcome.mean_thpt < outcome.ideal_thpt, "the leak must cost throughput");
    }

    #[test]
    fn correlated_storm_faults_are_colocated() {
        // The storm's GPUs sit on the node whose uplink jams (node 1 at 4
        // GPUs/node), and the three windows overlap.
        let spec = find("correlated-storm").unwrap();
        assert_eq!(spec.n_nodes(), 4);
        let gpn = spec.topology.gpus_per_node;
        let mut gpu_nodes = Vec::new();
        let mut uplink = None;
        for f in &spec.faults {
            match f.target {
                crate::inject::Target::Gpu(g) => gpu_nodes.push(g / gpn),
                crate::inject::Target::Uplink(u) => uplink = Some(u),
                other => panic!("unexpected target {other:?}"),
            }
        }
        assert_eq!(gpu_nodes, vec![uplink.unwrap(), uplink.unwrap()]);
        let first_end = spec.faults[0].start + spec.faults[0].duration;
        assert!(spec.faults.iter().all(|f| f.start < first_end), "windows must overlap");
        let outcome = spec.iters(150).run().unwrap();
        assert_eq!(outcome.injected, 3);
        assert!(outcome.mean_thpt < outcome.ideal_thpt, "the storm must cost throughput");
    }

    #[test]
    fn noisy_neighbor_scripts_a_job_targeted_fault() {
        let spec = find("noisy-neighbor").unwrap();
        assert_eq!(spec.faults[0].job, Some(0));
        let cfg = spec.fleet_config().expect("fleet scenario");
        assert_eq!(cfg.scripted.len(), 1);
        let (job, events) = &cfg.scripted[0];
        assert_eq!(*job, 0);
        assert_eq!(events.len(), 1, "one-shot fault expands to one event");
    }

    #[test]
    fn ledger_scenarios_lower_onto_the_fleet_engine() {
        let spec = find("recurrent-flaky-node").unwrap();
        let cfg = spec.fleet_config().expect("fleet scenario");
        assert!(cfg.ledger, "[ledger] must lower onto FleetConfig::ledger");
        assert_eq!(cfg.flaky_frac, 0.15);
        assert_eq!(cfg.policy, Some(Policy::PredictiveQuarantine));
        let hw = find("heavy-tailed-fleet").unwrap().fleet_config().unwrap();
        assert!(hw.ledger);
        assert_eq!(hw.policy, Some(Policy::HealthWeighted));
        assert_eq!(hw.flaky_alpha, 1.1);
    }

    #[test]
    fn fleet_breathing_runs_end_to_end() {
        let outcome = find("fleet-breathing").unwrap().run().unwrap();
        let fleet = outcome.fleet.expect("fleet scenario emits fleet results");
        assert_eq!(fleet.jobs, 16);
        assert_eq!(fleet.policy.as_deref(), Some("straggler-aware"));
        assert!(!fleet.digest.is_empty());
        assert_eq!(outcome.label, "fleet");
    }
}
