//! Declarative scenario API: one spec to drive the simulator, the fleet
//! engine, and every report.
//!
//! A [`ScenarioSpec`] captures an entire experiment as *data*: the
//! parallelism/topology of the job, the horizon, a fault **script**
//! (multiple timed / recurring / flapping / ramping
//! [`FailSlowEvent`]s rather than a single hardcoded preset), the
//! detector + mitigation switch, and — for fleet scenarios — shared-cluster
//! settings including per-job staggered start offsets so the node pool
//! breathes.
//!
//! Three frontends produce specs:
//!
//! - the **builder API** ([`ScenarioSpec::new`] + chainable setters), used
//!   by `main.rs` and the report generators;
//! - a hand-rolled **TOML-subset parser** ([`ScenarioSpec::parse`], no
//!   external crates — see `docs/SCENARIOS.md` for the grammar) with typed
//!   [`ScenarioError`] line/field diagnostics, plus the inverse
//!   [`ScenarioSpec::render`] (round-trip: `parse(render(s)) == s`);
//! - the built-in **library** of named scenarios ([`LIBRARY`] /
//!   [`find`]): the paper's §3 cases plus beyond-paper ones
//!   (slow-leak GPU, flapping link, multi-tenant burst, ...).
//!
//! Execution is unified behind [`ScenarioSpec::run`], which returns a
//! structured [`Outcome`] (episodes, detection latencies, mitigation
//! actions, throughput timeline, fleet/arbitration tallies) with a
//! hand-rolled [`Outcome::to_json`] and an ASCII [`Outcome::render`]
//! layered on top. `falcon run <file|name>` is the CLI entry.

pub mod library;
mod outcome;
mod parse;

pub use library::{find, LIBRARY};
pub use outcome::{
    Attribution, FaultAttribution, FleetOutcome, Outcome, OutcomeAction, OutcomeDiagnosis,
};

use crate::cluster::Policy;
use crate::coordinator::{run_with_falcon, FalconConfig};
use crate::fabric::GpuClass;
use crate::fleet::FleetConfig;
use crate::inject::{FailSlowEvent, FailSlowKind, Target};
use crate::pipeline::{ModelDims, ParallelConfig, Workload};
use crate::sim::{JobSpec, TrainingSim};
use crate::simkit::from_secs;

/// Model names [`ScenarioSpec`] accepts (the `ModelDims::gpt2` presets).
pub const MODELS: &[&str] = &["gpt2-7b", "gpt2-11b", "gpt2-13b"];

/// Typed scenario error with line/field diagnostics.
#[derive(Clone, Debug, PartialEq)]
pub enum ScenarioError {
    /// Syntax error while parsing a spec file (1-based line number).
    Parse { line: usize, msg: String },
    /// Semantic error on one field of the spec.
    Field { field: String, msg: String },
}

impl ScenarioError {
    pub(crate) fn field(field: &str, msg: impl Into<String>) -> ScenarioError {
        ScenarioError::Field { field: field.to_string(), msg: msg.into() }
    }
}

impl std::fmt::Display for ScenarioError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ScenarioError::Parse { line, msg } => {
                write!(f, "scenario parse error, line {line}: {msg}")
            }
            ScenarioError::Field { field, msg } => write!(f, "scenario field '{field}': {msg}"),
        }
    }
}

impl std::error::Error for ScenarioError {}

/// Job shape: parallel strategy, hardware, model, and noise profile.
#[derive(Clone, Debug, PartialEq)]
pub struct TopologySpec {
    pub tp: usize,
    pub dp: usize,
    pub pp: usize,
    pub gpus_per_node: usize,
    pub gpu_class: GpuClass,
    /// One of [`MODELS`].
    pub model: String,
    /// Micro-batches per DP replica per iteration (before S2 rebalance).
    pub microbatches: usize,
    pub mfu: f64,
    /// Iteration-time measurement jitter (CoV of healthy iterations).
    pub jitter: f64,
    /// Per-iteration transient stall-spike probability.
    pub spike_p: f64,
}

impl Default for TopologySpec {
    fn default() -> Self {
        TopologySpec {
            tp: 2,
            dp: 4,
            pp: 1,
            gpus_per_node: 8,
            gpu_class: GpuClass::H800,
            model: "gpt2-7b".to_string(),
            microbatches: 8,
            mfu: 0.42,
            jitter: 0.015,
            spike_p: 0.01,
        }
    }
}

/// Horizon and control knobs.
#[derive(Clone, Debug, PartialEq)]
pub struct RunSpec {
    /// Training iterations (the horizon; fault times are fractions of it).
    pub iters: usize,
    pub seed: u64,
    /// Run FALCON-MITIGATE (false = detection-only probe mode).
    pub mitigate: bool,
    /// Enable the S5 malleable-parallelism tier (`mitigate::replan`):
    /// ski-rental escalation gains the replan rung and denied grants fall
    /// back to an in-allocation replan. Off by default — legacy scenarios
    /// stay bit-identical.
    pub replan: bool,
}

impl Default for RunSpec {
    fn default() -> Self {
        RunSpec { iters: 300, seed: 1, mitigate: true, replan: false }
    }
}

/// One scripted fault: a timed fail-slow episode, optionally recurring
/// (flapping) and/or ramping in severity (slow leak).
///
/// Times are **fractions of the horizon** (`ideal_iter_s * iters`), so a
/// scenario keeps its shape when the horizon changes. `start + duration`
/// may exceed 1.0 (the episode outlives the run).
#[derive(Clone, Debug, PartialEq)]
pub struct FaultSpec {
    pub kind: FailSlowKind,
    pub target: Target,
    /// Onset, as a fraction of the horizon.
    pub start: f64,
    /// Duration of one occurrence, as a fraction of the horizon.
    pub duration: f64,
    /// Residual performance scale in (0, 1]; lower = more severe. With a
    /// ramp, the scale of the FIRST step.
    pub scale: f64,
    /// Additional occurrences after the first (0 = one-shot). A short
    /// duration with several repeats models a flapping component.
    pub repeat: usize,
    /// Start-to-start spacing of occurrences (fraction of horizon).
    pub period: f64,
    /// Slow leak: the scale ramps from `scale` to this value across
    /// `ramp_steps` equal steps spanning `duration`.
    pub ramp_to: Option<f64>,
    pub ramp_steps: usize,
    /// Fleet scenarios only: the fleet job this fault strikes. Targets and
    /// horizon fractions are then interpreted against that job's palette
    /// topology, and the events are injected on top of whatever the
    /// §3-calibrated injection model samples for it. Must be `None` for
    /// single-job scenarios.
    pub job: Option<usize>,
}

impl FaultSpec {
    pub fn new(kind: FailSlowKind, target: Target, start: f64, duration: f64, scale: f64) -> Self {
        FaultSpec {
            kind,
            target,
            start,
            duration,
            scale,
            repeat: 0,
            period: 0.0,
            ramp_to: None,
            ramp_steps: 8,
            job: None,
        }
    }

    /// Aim the fault at fleet job `j` (fleet scenarios only): the fault
    /// script rides on top of the calibrated injection model for exactly
    /// that job.
    pub fn on_job(mut self, j: usize) -> Self {
        self.job = Some(j);
        self
    }

    /// Make the fault recur `repeat` more times, `period` apart.
    pub fn recurring(mut self, repeat: usize, period: f64) -> Self {
        self.repeat = repeat;
        self.period = period;
        self
    }

    /// Ramp the severity from `self.scale` to `to` in `steps` steps.
    pub fn ramp(mut self, to: f64, steps: usize) -> Self {
        self.ramp_to = Some(to);
        self.ramp_steps = steps;
        self
    }

    /// Expand into concrete events on a horizon of `horizon_s` seconds.
    ///
    /// Plain faults use the report generators' exact arithmetic
    /// (`from_secs` start, truncated-microsecond duration) so rewired
    /// reports reproduce their historical event streams bit for bit.
    /// Ramps are emitted as a staircase of back-to-back events whose
    /// boundaries share the same microsecond, so each step's revert is
    /// immediately overwritten by the next step's apply.
    pub fn expand(&self, horizon_s: f64) -> Vec<FailSlowEvent> {
        let mut out = Vec::new();
        for o in 0..=self.repeat {
            let start_s = (self.start + o as f64 * self.period) * horizon_s;
            let dur_s = self.duration * horizon_s;
            match self.ramp_to {
                None => out.push(FailSlowEvent {
                    kind: self.kind,
                    target: self.target,
                    start: from_secs(start_s),
                    duration: (dur_s * 1e6) as u64,
                    scale: self.scale,
                }),
                Some(to) => {
                    let steps = self.ramp_steps.max(2);
                    let step_s = dur_s / steps as f64;
                    for i in 0..steps {
                        let b0 = from_secs(start_s + i as f64 * step_s);
                        let b1 = from_secs(start_s + (i + 1) as f64 * step_s);
                        if b1 <= b0 {
                            continue;
                        }
                        // Last step lands exactly on `to` (float-drift-free).
                        let scale = if i + 1 == steps {
                            to
                        } else {
                            let f = i as f64 / (steps - 1) as f64;
                            self.scale + (to - self.scale) * f
                        };
                        out.push(FailSlowEvent {
                            kind: self.kind,
                            target: self.target,
                            start: b0,
                            duration: b1 - b0,
                            scale,
                        });
                    }
                }
            }
        }
        out
    }
}

/// Fleet/shared-cluster settings. When present, the scenario runs the
/// fleet engine (jobs drawn from the fleet palette, faults from the
/// §3-calibrated injection model) instead of one scripted job.
#[derive(Clone, Debug, PartialEq)]
pub struct FleetSpec {
    pub jobs: usize,
    /// Worker threads (0 = one per core).
    pub workers: usize,
    /// Multiplier on the §3 per-job fail-slow probabilities.
    pub boost: f64,
    /// Re-run injected jobs unmitigated for the delta (private mode only).
    pub compare: bool,
    /// `Some(_)` = one shared cluster under this policy; `None` = private
    /// clusters.
    pub policy: Option<Policy>,
    /// Healthy-node headroom above peak demand (0.0 saturates the pool).
    pub spare: f64,
    /// Iterations per arbitration epoch (shared mode).
    pub epoch_len: usize,
    /// Per-job staggered start offsets, as a multiple of the per-job epoch
    /// count: job starts spread over `stagger * ceil(iters / epoch_len)`
    /// epochs, so jobs start/finish at different times and the node pool
    /// breathes (shared mode; 0.0 = everyone starts together).
    pub stagger: f64,
}

impl Default for FleetSpec {
    fn default() -> Self {
        let d = FleetConfig::default();
        FleetSpec {
            jobs: d.jobs,
            workers: d.workers,
            boost: d.failslow_boost,
            compare: d.compare,
            policy: d.policy,
            spare: d.spare_frac,
            epoch_len: d.epoch_len,
            stagger: 0.0,
        }
    }
}

impl FleetSpec {
    /// Lower this spec onto the fleet engine's configuration. The
    /// `[ledger]` section (if any) is layered on by
    /// [`ScenarioSpec::fleet_config`].
    pub fn to_config(&self, iters: usize, seed: u64) -> FleetConfig {
        FleetConfig {
            jobs: self.jobs,
            iters,
            seed,
            workers: self.workers,
            failslow_boost: self.boost,
            compare: self.compare,
            policy: self.policy,
            spare_frac: self.spare,
            epoch_len: self.epoch_len,
            stagger: self.stagger,
            scripted: Vec::new(),
            falcon: FalconConfig::default(),
            ledger: false,
            ledger_init: None,
            flaky_frac: 0.0,
            flaky_alpha: 1.2,
        }
    }
}

/// `[ledger]` — attach the persistent node-health ledger
/// ([`crate::ledger`]) to a shared-cluster fleet campaign, optionally
/// with a chronically flaky slice of the node pool whose flares recur on
/// heavy-tailed (Pareto) gaps.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LedgerSpec {
    /// Attach the ledger (incident records, decaying scores, ledger-driven
    /// quarantine under the predictive policy).
    pub enabled: bool,
    /// Fraction of shared nodes that are chronically flaky (0.0 = none;
    /// flares then degrade whichever job sits on them).
    pub flaky: f64,
    /// Pareto tail index of flare recurrence gaps (smaller = heavier tail,
    /// faster relapses).
    pub alpha: f64,
}

impl Default for LedgerSpec {
    fn default() -> Self {
        LedgerSpec { enabled: true, flaky: 0.0, alpha: 1.2 }
    }
}

/// One declaratively specified experiment. See the module docs.
#[derive(Clone, Debug, PartialEq)]
pub struct ScenarioSpec {
    pub name: String,
    pub description: String,
    pub topology: TopologySpec,
    pub run: RunSpec,
    pub faults: Vec<FaultSpec>,
    pub fleet: Option<FleetSpec>,
    pub ledger: Option<LedgerSpec>,
}

impl ScenarioSpec {
    /// New spec with the given parallel strategy and library defaults
    /// everywhere else (the `demo_spec` profile).
    pub fn new(name: &str, tp: usize, dp: usize, pp: usize) -> Self {
        ScenarioSpec {
            name: name.to_string(),
            description: String::new(),
            topology: TopologySpec { tp, dp, pp, ..TopologySpec::default() },
            run: RunSpec::default(),
            faults: Vec::new(),
            fleet: None,
            ledger: None,
        }
    }

    // --- builder ----------------------------------------------------------

    pub fn describe(mut self, d: &str) -> Self {
        self.description = d.to_string();
        self
    }

    /// Spread the job across `n` nodes (sets `gpus_per_node` to
    /// `ceil(world / n)`, the report generators' convention).
    pub fn nodes(mut self, n: usize) -> Self {
        self.topology.gpus_per_node = self.world().div_ceil(n.max(1)).max(1);
        self
    }

    pub fn gpus_per_node(mut self, g: usize) -> Self {
        self.topology.gpus_per_node = g;
        self
    }

    pub fn model(mut self, m: &str) -> Self {
        self.topology.model = m.to_string();
        self
    }

    pub fn gpu_class(mut self, c: GpuClass) -> Self {
        self.topology.gpu_class = c;
        self
    }

    pub fn microbatches(mut self, m: usize) -> Self {
        self.topology.microbatches = m;
        self
    }

    pub fn mfu(mut self, v: f64) -> Self {
        self.topology.mfu = v;
        self
    }

    pub fn jitter(mut self, v: f64) -> Self {
        self.topology.jitter = v;
        self
    }

    pub fn spike_p(mut self, v: f64) -> Self {
        self.topology.spike_p = v;
        self
    }

    pub fn iters(mut self, n: usize) -> Self {
        self.run.iters = n;
        self
    }

    pub fn seed(mut self, s: u64) -> Self {
        self.run.seed = s;
        self
    }

    pub fn mitigate(mut self, b: bool) -> Self {
        self.run.mitigate = b;
        self
    }

    pub fn replan(mut self, b: bool) -> Self {
        self.run.replan = b;
        self
    }

    pub fn fault(mut self, f: FaultSpec) -> Self {
        self.faults.push(f);
        self
    }

    pub fn with_fleet(mut self, f: FleetSpec) -> Self {
        self.fleet = Some(f);
        self
    }

    pub fn with_ledger(mut self, l: LedgerSpec) -> Self {
        self.ledger = Some(l);
        self
    }

    // --- derived ----------------------------------------------------------

    pub fn cfg(&self) -> ParallelConfig {
        ParallelConfig::new(self.topology.tp, self.topology.dp, self.topology.pp)
    }

    pub fn world(&self) -> usize {
        self.topology.tp * self.topology.dp * self.topology.pp
    }

    pub fn n_nodes(&self) -> usize {
        self.world().div_ceil(self.topology.gpus_per_node.max(1))
    }

    // --- validation -------------------------------------------------------

    /// Check every field; returns the first problem found.
    pub fn validate(&self) -> Result<(), ScenarioError> {
        let t = &self.topology;
        if self.name.is_empty() {
            return Err(ScenarioError::field("name", "must not be empty"));
        }
        for (field, s) in [("name", &self.name), ("description", &self.description)] {
            if s.contains('"') || s.contains('\n') {
                return Err(ScenarioError::field(
                    field,
                    "must not contain quotes or newlines (the TOML renderer \
                     does not escape them)",
                ));
            }
        }
        if t.tp == 0 || t.dp == 0 || t.pp == 0 {
            return Err(ScenarioError::field("topology", "tp/dp/pp must all be >= 1"));
        }
        if t.gpus_per_node == 0 {
            return Err(ScenarioError::field("topology.gpus_per_node", "must be >= 1"));
        }
        if !MODELS.contains(&t.model.as_str()) {
            return Err(ScenarioError::field(
                "topology.model",
                format!("unknown model '{}' (want one of {MODELS:?})", t.model),
            ));
        }
        if t.microbatches == 0 {
            return Err(ScenarioError::field("topology.microbatches", "must be >= 1"));
        }
        if !(t.mfu > 0.0 && t.mfu <= 1.0) {
            return Err(ScenarioError::field("topology.mfu", "must be in (0, 1]"));
        }
        if self.run.iters == 0 {
            return Err(ScenarioError::field("run.iters", "must be >= 1"));
        }
        if let Some(ls) = &self.ledger {
            let shared = self.fleet.as_ref().is_some_and(|fs| fs.policy.is_some());
            if !shared {
                return Err(ScenarioError::field(
                    "ledger",
                    "[ledger] needs a [fleet] section with a shared policy \
                     (the ledger lives on the shared node pool)",
                ));
            }
            if !(0.0..1.0).contains(&ls.flaky) {
                return Err(ScenarioError::field("ledger.flaky", "must be in [0, 1)"));
            }
            if !(ls.alpha > 0.0) {
                return Err(ScenarioError::field("ledger.alpha", "must be > 0"));
            }
        }
        if let Some(fs) = &self.fleet {
            if fs.jobs == 0 {
                return Err(ScenarioError::field("fleet.jobs", "must be >= 1"));
            }
            if fs.epoch_len == 0 {
                return Err(ScenarioError::field("fleet.epoch_len", "must be >= 1"));
            }
            if fs.spare < 0.0 || fs.stagger < 0.0 || fs.boost < 0.0 {
                return Err(ScenarioError::field(
                    "fleet",
                    "spare/stagger/boost must be >= 0",
                ));
            }
            if !self.run.mitigate {
                return Err(ScenarioError::field(
                    "run.mitigate",
                    "fleet scenarios always mitigate (the engine forces the \
                     per-mode behavior); drop mitigate = false",
                ));
            }
            // Fleet fault scripts must name their victim: the calibrated
            // injection model supplies the untargeted background faults,
            // and each [[fault]] rides on one specific palette job.
            for (i, f) in self.faults.iter().enumerate() {
                let field = format!("fault[{i}]");
                let Some(job) = f.job else {
                    return Err(ScenarioError::field(
                        &field,
                        "fleet scenarios need `job = N` on every [[fault]] \
                         (untargeted faults come from the calibrated injection model)",
                    ));
                };
                if job >= fs.jobs {
                    return Err(ScenarioError::field(
                        &field,
                        format!("job {job} out of range for a {}-job fleet", fs.jobs),
                    ));
                }
                let spec = crate::fleet::job_spec(self.run.seed, job);
                validate_fault(f, &field, spec.n_nodes(), spec.gpus_per_node)?;
            }
            return Ok(());
        }
        let nodes = self.n_nodes();
        for (i, f) in self.faults.iter().enumerate() {
            let field = format!("fault[{i}]");
            if f.job.is_some() {
                return Err(ScenarioError::field(
                    &field,
                    "`job = N` targets a fleet job; this is a single-job scenario",
                ));
            }
            validate_fault(f, &field, nodes, t.gpus_per_node)?;
        }
        Ok(())
    }

    // --- execution --------------------------------------------------------

    /// The simulator job spec this scenario describes.
    pub fn job_spec(&self) -> JobSpec {
        let t = &self.topology;
        JobSpec {
            cfg: self.cfg(),
            wl: Workload {
                model: ModelDims::gpt2(&t.model),
                micro_batch: 1,
                microbatches: t.microbatches,
            },
            gpus_per_node: t.gpus_per_node,
            gpu_class: t.gpu_class,
            mfu: t.mfu,
            jitter: t.jitter,
            spike_p: t.spike_p,
            seed: self.run.seed,
        }
    }

    /// Expand the fault script against a horizon of `horizon_s` seconds.
    pub fn events(&self, horizon_s: f64) -> Vec<FailSlowEvent> {
        self.faults.iter().flat_map(|f| f.expand(horizon_s)).collect()
    }

    /// Fault index of each event [`ScenarioSpec::events`] produces, in the
    /// same order — the what-if engine's event → `[[fault]]` attribution
    /// map (a ramp or recurring fault expands to several events that all
    /// blame the same fault).
    pub fn event_fault_indices(&self, horizon_s: f64) -> Vec<usize> {
        let mut out = Vec::new();
        for (i, f) in self.faults.iter().enumerate() {
            out.extend(std::iter::repeat(i).take(f.expand(horizon_s).len()));
        }
        out
    }

    /// Validate, build the simulated job, and inject the fault script.
    pub fn build_sim(&self) -> Result<TrainingSim, ScenarioError> {
        self.validate()?;
        if self.fleet.is_some() {
            return Err(ScenarioError::field(
                "fleet",
                "fleet scenarios run through ScenarioSpec::run, not build_sim",
            ));
        }
        let mut sim = TrainingSim::new(self.job_spec());
        let horizon_s = sim.ideal_iter_s * self.run.iters as f64;
        sim.inject(self.events(horizon_s));
        Ok(sim)
    }

    /// The fleet configuration, when this is a fleet scenario. Job-targeted
    /// faults are expanded here against the target job's own horizon (its
    /// palette topology fixes `ideal_iter_s`) and lowered onto
    /// [`FleetConfig::scripted`] as absolute-time events.
    pub fn fleet_config(&self) -> Option<FleetConfig> {
        self.fleet.as_ref().map(|fs| {
            let mut cfg = fs.to_config(self.run.iters, self.run.seed);
            cfg.falcon.replan = self.run.replan;
            if let Some(ls) = &self.ledger {
                cfg.ledger = ls.enabled;
                cfg.flaky_frac = ls.flaky;
                cfg.flaky_alpha = ls.alpha;
            }
            for f in &self.faults {
                // Validated specs always carry a job id here; tolerate an
                // unvalidated caller by skipping the (invalid) fault
                // rather than aborting the process.
                debug_assert!(f.job.is_some(), "fleet faults carry a job id after validate()");
                let Some(job) = f.job else { continue };
                let spec = crate::fleet::job_spec(cfg.seed, job);
                let ideal = TrainingSim::new(spec).ideal_iter_s;
                // Mirror the engine's horizon clamp so fractions line up.
                let horizon_s = (ideal * cfg.iters as f64).max(60.0);
                cfg.scripted.push((job, f.expand(horizon_s)));
            }
            cfg
        })
    }

    /// Execute the scenario end to end and return the structured outcome.
    ///
    /// Single-job scenarios run [`TrainingSim`] under a
    /// [`crate::coordinator::Falcon`]; fleet scenarios run
    /// [`crate::fleet::run_fleet`]. Both paths land in the same
    /// [`Outcome`].
    pub fn run(&self) -> Result<Outcome, ScenarioError> {
        self.validate()?;
        if let Some(cfg) = self.fleet_config() {
            let report = crate::fleet::run_fleet(&cfg);
            return Ok(Outcome::from_fleet(self, &report));
        }
        let mut sim = self.build_sim()?;
        let injected = sim.events.clone();
        let falcon = run_with_falcon(
            &mut sim,
            FalconConfig {
                mitigate: self.run.mitigate,
                replan: self.run.replan,
                ..FalconConfig::default()
            },
            self.run.iters,
        );
        Ok(Outcome::from_single(self, &sim, &falcon, &injected))
    }

    // --- text frontends ---------------------------------------------------

    /// Parse a spec from the TOML subset described in `docs/SCENARIOS.md`.
    pub fn parse(src: &str) -> Result<ScenarioSpec, ScenarioError> {
        parse::parse(src)
    }

    /// Render back to the TOML subset; `parse(render(spec)) == spec`.
    pub fn render(&self) -> String {
        parse::render(self)
    }
}

/// Shape/range checks for one fault against a topology of `nodes` nodes x
/// `gpus_per_node` GPUs (the scenario's own job, or — for job-targeted
/// fleet faults — the palette topology of the targeted fleet job).
fn validate_fault(
    f: &FaultSpec,
    field: &str,
    nodes: usize,
    gpus_per_node: usize,
) -> Result<(), ScenarioError> {
    let gpus = nodes * gpus_per_node;
    if !(f.scale > 0.0 && f.scale <= 1.0) {
        return Err(ScenarioError::field(field, "scale must be in (0, 1]"));
    }
    if f.start < 0.0 || f.duration <= 0.0 {
        return Err(ScenarioError::field(
            field,
            "start must be >= 0 and duration > 0 (fractions of the horizon)",
        ));
    }
    if f.repeat > 0 && f.period <= 0.0 {
        return Err(ScenarioError::field(field, "recurring faults need period > 0"));
    }
    if f.repeat > 0 && f.period < f.duration {
        // The sim's apply/revert event semantics reset the target to
        // healthy when ANY occurrence ends, so overlapping occurrences
        // would silently truncate the script.
        return Err(ScenarioError::field(
            field,
            "recurring occurrences must not overlap: need period >= duration",
        ));
    }
    if let Some(to) = f.ramp_to {
        if !(to > 0.0 && to <= 1.0) {
            return Err(ScenarioError::field(field, "ramp_to must be in (0, 1]"));
        }
        if f.ramp_steps < 2 {
            return Err(ScenarioError::field(field, "ramp needs ramp_steps >= 2"));
        }
    }
    let ok = match (f.kind, f.target) {
        (FailSlowKind::GpuDegradation, Target::Gpu(g)) => g < gpus,
        (FailSlowKind::CpuContention, Target::Node(n)) => n < nodes,
        (FailSlowKind::NetworkCongestion, Target::Uplink(u)) => u < nodes,
        (FailSlowKind::NetworkCongestion, Target::Link(a, b)) => a < nodes && b < nodes && a != b,
        (FailSlowKind::CommHang, Target::Uplink(u)) => u < nodes,
        (FailSlowKind::CommHang, Target::Link(a, b)) => a < nodes && b < nodes && a != b,
        _ => {
            return Err(ScenarioError::field(
                field,
                format!("kind {:?} cannot target {:?}", f.kind, f.target),
            ))
        }
    };
    if !ok {
        return Err(ScenarioError::field(
            field,
            format!(
                "target {:?} out of range for {nodes} nodes x {gpus_per_node} GPUs/node",
                f.target
            ),
        ));
    }
    Ok(())
}

// --- token helpers shared by the parser, renderer, and outcome -------------

pub(crate) fn kind_token(k: FailSlowKind) -> &'static str {
    match k {
        FailSlowKind::CpuContention => "cpu",
        FailSlowKind::GpuDegradation => "gpu",
        FailSlowKind::NetworkCongestion => "net",
        FailSlowKind::CommHang => "hang",
    }
}

pub(crate) fn parse_kind(s: &str) -> Option<FailSlowKind> {
    match s {
        "cpu" => Some(FailSlowKind::CpuContention),
        "gpu" => Some(FailSlowKind::GpuDegradation),
        "net" => Some(FailSlowKind::NetworkCongestion),
        "hang" => Some(FailSlowKind::CommHang),
        _ => None,
    }
}

pub(crate) fn target_token(t: Target) -> String {
    match t {
        Target::Gpu(g) => format!("gpu:{g}"),
        Target::Node(n) => format!("node:{n}"),
        Target::Uplink(u) => format!("uplink:{u}"),
        Target::Link(a, b) => format!("link:{a}-{b}"),
    }
}

pub(crate) fn parse_target(s: &str) -> Option<Target> {
    let (what, rest) = s.split_once(':')?;
    match what {
        "gpu" => rest.parse().ok().map(Target::Gpu),
        "node" => rest.parse().ok().map(Target::Node),
        "uplink" => rest.parse().ok().map(Target::Uplink),
        "link" => {
            let (a, b) = rest.split_once('-')?;
            Some(Target::Link(a.parse().ok()?, b.parse().ok()?))
        }
        _ => None,
    }
}

pub(crate) fn gpu_class_token(c: GpuClass) -> &'static str {
    match c {
        GpuClass::H800 => "h800",
        GpuClass::A100 => "a100",
    }
}

pub(crate) fn parse_gpu_class(s: &str) -> Option<GpuClass> {
    match s {
        "h800" => Some(GpuClass::H800),
        "a100" => Some(GpuClass::A100),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_produces_valid_specs() {
        let spec = ScenarioSpec::new("t", 2, 4, 1)
            .describe("test")
            .nodes(1)
            .iters(100)
            .seed(9)
            .fault(FaultSpec::new(
                FailSlowKind::GpuDegradation,
                Target::Gpu(0),
                0.2,
                0.3,
                0.5,
            ));
        assert!(spec.validate().is_ok());
        assert_eq!(spec.world(), 8);
        assert_eq!(spec.n_nodes(), 1);
        assert_eq!(spec.topology.gpus_per_node, 8);
    }

    #[test]
    fn validation_rejects_bad_specs() {
        let base = ScenarioSpec::new("t", 1, 4, 1).nodes(1);
        // Mismatched kind/target.
        let bad = base.clone().fault(FaultSpec::new(
            FailSlowKind::GpuDegradation,
            Target::Node(0),
            0.1,
            0.1,
            0.5,
        ));
        assert!(matches!(bad.validate(), Err(ScenarioError::Field { .. })));
        // Out-of-range target.
        let bad = base.clone().fault(FaultSpec::new(
            FailSlowKind::CpuContention,
            Target::Node(5),
            0.1,
            0.1,
            0.5,
        ));
        assert!(bad.validate().is_err());
        // Bad scale.
        let bad = base.clone().fault(FaultSpec::new(
            FailSlowKind::GpuDegradation,
            Target::Gpu(0),
            0.1,
            0.1,
            1.5,
        ));
        assert!(bad.validate().is_err());
        // Unknown model.
        assert!(base.clone().model("gpt5").validate().is_err());
        // Recurring without period.
        let bad = base.fault(
            FaultSpec::new(FailSlowKind::GpuDegradation, Target::Gpu(0), 0.1, 0.1, 0.5)
                .recurring(3, 0.0),
        );
        assert!(bad.validate().is_err());
    }

    #[test]
    fn expansion_matches_adhoc_report_construction() {
        // The fig2 pattern: the scenario expansion must produce the exact
        // events the report generator used to hand-assemble, so rewired
        // reports keep bit-identical traces.
        let iters = 600usize;
        let spec = find("cpu-contention").unwrap().iters(iters);
        let sim = spec.build_sim().unwrap();
        let it = sim.ideal_iter_s;
        let expect = vec![
            FailSlowEvent {
                kind: FailSlowKind::CpuContention,
                target: Target::Node(0),
                start: from_secs(it * iters as f64 * 0.25),
                duration: (it * iters as f64 * 0.12 * 1e6) as u64,
                scale: 0.35,
            },
            FailSlowEvent {
                kind: FailSlowKind::CpuContention,
                target: Target::Node(0),
                start: from_secs(it * iters as f64 * 0.62),
                duration: (it * iters as f64 * 0.10 * 1e6) as u64,
                scale: 0.45,
            },
        ];
        assert_eq!(sim.events, expect);
    }

    #[test]
    fn recurring_fault_expands_to_spaced_events() {
        let f = FaultSpec::new(
            FailSlowKind::NetworkCongestion,
            Target::Uplink(1),
            0.1,
            0.05,
            0.3,
        )
        .recurring(3, 0.2);
        let evs = f.expand(1000.0);
        assert_eq!(evs.len(), 4);
        for (i, ev) in evs.iter().enumerate() {
            assert_eq!(ev.start, from_secs((0.1 + 0.2 * i as f64) * 1000.0));
            assert_eq!(ev.scale, 0.3);
        }
        // Occurrences do not overlap: each ends before the next starts.
        for w in evs.windows(2) {
            assert!(w[0].end() < w[1].start);
        }
    }

    #[test]
    fn ramp_expands_to_contiguous_staircase() {
        let f = FaultSpec::new(
            FailSlowKind::GpuDegradation,
            Target::Gpu(0),
            0.1,
            0.5,
            0.9,
        )
        .ramp(0.3, 5);
        let evs = f.expand(2000.0);
        assert_eq!(evs.len(), 5);
        // Severity strictly worsens, from `scale` to `ramp_to`.
        assert_eq!(evs[0].scale, 0.9);
        assert_eq!(evs[4].scale, 0.3);
        for w in evs.windows(2) {
            assert!(w[1].scale < w[0].scale);
            // Back to back: step i ends exactly where step i+1 starts, so
            // the revert of one is overwritten by the apply of the next.
            assert_eq!(w[0].end(), w[1].start);
        }
    }

    #[test]
    fn single_job_scenario_runs_end_to_end() {
        let spec = ScenarioSpec::new("e2e", 1, 4, 1)
            .nodes(1)
            .iters(120)
            .seed(33)
            .fault(FaultSpec::new(
                FailSlowKind::GpuDegradation,
                Target::Gpu(0),
                0.2,
                0.6,
                0.4,
            ));
        let outcome = spec.run().unwrap();
        assert_eq!(outcome.iters, 120);
        assert_eq!(outcome.injected, 1);
        assert_eq!(outcome.timeline_thpt.len(), 120);
        assert!(outcome.mean_thpt > 0.0);
        // Deterministic: the same spec yields the identical JSON.
        let again = spec.run().unwrap();
        assert_eq!(outcome.to_json().to_string(), again.to_json().to_string());
    }

    #[test]
    fn token_round_trips() {
        for t in [Target::Gpu(3), Target::Node(1), Target::Uplink(7), Target::Link(2, 5)] {
            assert_eq!(parse_target(&target_token(t)), Some(t));
        }
        for k in [
            FailSlowKind::CpuContention,
            FailSlowKind::GpuDegradation,
            FailSlowKind::NetworkCongestion,
            FailSlowKind::CommHang,
        ] {
            assert_eq!(parse_kind(kind_token(k)), Some(k));
        }
        for c in [GpuClass::H800, GpuClass::A100] {
            assert_eq!(parse_gpu_class(gpu_class_token(c)), Some(c));
        }
        assert_eq!(parse_target("disk:0"), None);
        assert_eq!(parse_kind("rain"), None);
    }
}
