//! Hand-rolled TOML-subset parser and renderer for [`ScenarioSpec`]
//! (toml/serde are unavailable offline; follows the `util::cli::Args`
//! philosophy of a small, typed, dependency-free substrate).
//!
//! Supported grammar (see `docs/SCENARIOS.md` for the full reference):
//!
//! ```text
//! # comment (also allowed after a value)
//! name = "flapping-link"          # top-level strings are quoted
//! description = "..."
//!
//! [topology]                      # tables: topology, run, fleet
//! tp = 2
//! mfu = 0.42                      # numbers: integers or floats
//!
//! [run]
//! mitigate = true                 # booleans: true/false
//!
//! [[fault]]                       # array of tables: the fault script
//! kind = "net"                    # cpu | gpu | net | hang
//! target = "uplink:1"             # gpu:N | node:N | uplink:N | link:A-B
//! job = 2                         # fleet scenarios: which job it strikes
//! start = 0.1                     # fractions of the horizon
//! duration = 0.05
//! scale = 0.3
//! ```
//!
//! Errors carry 1-based line numbers ([`ScenarioError::Parse`]); semantic
//! problems surface as [`ScenarioError::Field`] from the final
//! [`ScenarioSpec::validate`] pass.

use crate::cluster::Policy;
use crate::inject::{FailSlowKind, Target};

use super::{
    gpu_class_token, kind_token, parse_gpu_class, parse_kind, parse_target, target_token,
    FaultSpec, FleetSpec, LedgerSpec, ScenarioError, ScenarioSpec,
};

fn perr(line: usize, msg: impl Into<String>) -> ScenarioError {
    ScenarioError::Parse { line, msg: msg.into() }
}

/// Cut a trailing `#` comment, respecting quoted strings.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn p_str(v: &str, line: usize) -> Result<String, ScenarioError> {
    let inner = v
        .strip_prefix('"')
        .and_then(|r| r.strip_suffix('"'))
        .ok_or_else(|| perr(line, format!("expected a quoted string, got '{v}'")))?;
    if inner.contains('"') {
        return Err(perr(line, "nested quotes are not supported"));
    }
    Ok(inner.to_string())
}

fn p_f64(v: &str, line: usize) -> Result<f64, ScenarioError> {
    v.parse().map_err(|_| perr(line, format!("expected a number, got '{v}'")))
}

fn p_usize(v: &str, line: usize) -> Result<usize, ScenarioError> {
    v.parse().map_err(|_| perr(line, format!("expected a non-negative integer, got '{v}'")))
}

fn p_u64(v: &str, line: usize) -> Result<u64, ScenarioError> {
    v.parse().map_err(|_| perr(line, format!("expected a non-negative integer, got '{v}'")))
}

fn p_bool(v: &str, line: usize) -> Result<bool, ScenarioError> {
    match v {
        "true" => Ok(true),
        "false" => Ok(false),
        _ => Err(perr(line, format!("expected true or false, got '{v}'"))),
    }
}

/// A `[[fault]]` under construction: kind/target/scale are required, the
/// rest defaults like [`FaultSpec::new`].
struct FaultDraft {
    header_line: usize,
    kind: Option<FailSlowKind>,
    target: Option<Target>,
    start: f64,
    duration: f64,
    scale: Option<f64>,
    repeat: usize,
    period: f64,
    ramp_to: Option<f64>,
    ramp_steps: usize,
    job: Option<usize>,
}

impl FaultDraft {
    fn new(header_line: usize) -> Self {
        FaultDraft {
            header_line,
            kind: None,
            target: None,
            start: 0.0,
            duration: 1.0,
            scale: None,
            repeat: 0,
            period: 0.0,
            ramp_to: None,
            ramp_steps: 8,
            job: None,
        }
    }

    fn finish(self) -> Result<FaultSpec, ScenarioError> {
        let need = |what: &str| perr(self.header_line, format!("[[fault]] is missing '{what}'"));
        Ok(FaultSpec {
            kind: self.kind.ok_or_else(|| need("kind"))?,
            target: self.target.ok_or_else(|| need("target"))?,
            start: self.start,
            duration: self.duration,
            scale: self.scale.ok_or_else(|| need("scale"))?,
            repeat: self.repeat,
            period: self.period,
            ramp_to: self.ramp_to,
            ramp_steps: self.ramp_steps,
            job: self.job,
        })
    }
}

enum Section {
    Top,
    Topology,
    Run,
    Fleet,
    Ledger,
    Fault,
}

pub(crate) fn parse(src: &str) -> Result<ScenarioSpec, ScenarioError> {
    let mut spec = ScenarioSpec {
        name: String::new(),
        description: String::new(),
        topology: Default::default(),
        run: Default::default(),
        faults: Vec::new(),
        fleet: None,
        ledger: None,
    };
    let mut drafts: Vec<FaultDraft> = Vec::new();
    let mut section = Section::Top;

    for (i, raw) in src.lines().enumerate() {
        let ln = i + 1;
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(h) = line.strip_prefix("[[").and_then(|r| r.strip_suffix("]]")) {
            match h.trim() {
                "fault" => {
                    drafts.push(FaultDraft::new(ln));
                    section = Section::Fault;
                }
                other => return Err(perr(ln, format!("unknown table '[[{other}]]'"))),
            }
            continue;
        }
        if let Some(h) = line.strip_prefix('[').and_then(|r| r.strip_suffix(']')) {
            section = match h.trim() {
                "topology" => Section::Topology,
                "run" => Section::Run,
                "fleet" => {
                    if spec.fleet.is_none() {
                        spec.fleet = Some(FleetSpec::default());
                    }
                    Section::Fleet
                }
                "ledger" => {
                    if spec.ledger.is_none() {
                        spec.ledger = Some(LedgerSpec::default());
                    }
                    Section::Ledger
                }
                other => {
                    return Err(perr(
                        ln,
                        format!(
                            "unknown section '[{other}]' (want topology, run, fleet, or ledger)"
                        ),
                    ))
                }
            };
            continue;
        }
        let (key, val) = line
            .split_once('=')
            .ok_or_else(|| perr(ln, format!("expected 'key = value', got '{line}'")))?;
        let (key, val) = (key.trim(), val.trim());
        match section {
            Section::Top => match key {
                "name" => spec.name = p_str(val, ln)?,
                "description" => spec.description = p_str(val, ln)?,
                _ => return Err(perr(ln, format!("unknown top-level key '{key}'"))),
            },
            Section::Topology => {
                let t = &mut spec.topology;
                match key {
                    "tp" => t.tp = p_usize(val, ln)?,
                    "dp" => t.dp = p_usize(val, ln)?,
                    "pp" => t.pp = p_usize(val, ln)?,
                    "gpus_per_node" => t.gpus_per_node = p_usize(val, ln)?,
                    "gpu_class" => {
                        let s = p_str(val, ln)?;
                        t.gpu_class = parse_gpu_class(&s)
                            .ok_or_else(|| perr(ln, format!("unknown gpu_class '{s}'")))?;
                    }
                    "model" => t.model = p_str(val, ln)?,
                    "microbatches" => t.microbatches = p_usize(val, ln)?,
                    "mfu" => t.mfu = p_f64(val, ln)?,
                    "jitter" => t.jitter = p_f64(val, ln)?,
                    "spike_p" => t.spike_p = p_f64(val, ln)?,
                    _ => return Err(perr(ln, format!("unknown [topology] key '{key}'"))),
                }
            }
            Section::Run => match key {
                "iters" => spec.run.iters = p_usize(val, ln)?,
                "seed" => spec.run.seed = p_u64(val, ln)?,
                "mitigate" => spec.run.mitigate = p_bool(val, ln)?,
                "replan" => spec.run.replan = p_bool(val, ln)?,
                _ => return Err(perr(ln, format!("unknown [run] key '{key}'"))),
            },
            Section::Fleet => {
                let Some(f) = spec.fleet.as_mut() else {
                    return Err(perr(ln, "[fleet] section lost its spec".to_string()));
                };
                match key {
                    "jobs" => f.jobs = p_usize(val, ln)?,
                    "workers" => f.workers = p_usize(val, ln)?,
                    "boost" => f.boost = p_f64(val, ln)?,
                    "compare" => f.compare = p_bool(val, ln)?,
                    "policy" => {
                        let s = p_str(val, ln)?;
                        f.policy = match s.as_str() {
                            "private" | "none" => None,
                            other => Some(Policy::parse(other).ok_or_else(|| {
                                perr(ln, format!("unknown policy '{other}'"))
                            })?),
                        };
                    }
                    "spare" => f.spare = p_f64(val, ln)?,
                    "epoch_len" => f.epoch_len = p_usize(val, ln)?,
                    "stagger" => f.stagger = p_f64(val, ln)?,
                    _ => return Err(perr(ln, format!("unknown [fleet] key '{key}'"))),
                }
            }
            Section::Ledger => {
                let Some(l) = spec.ledger.as_mut() else {
                    return Err(perr(ln, "[ledger] section lost its spec".to_string()));
                };
                match key {
                    "enabled" => l.enabled = p_bool(val, ln)?,
                    "flaky" => l.flaky = p_f64(val, ln)?,
                    "alpha" => l.alpha = p_f64(val, ln)?,
                    _ => return Err(perr(ln, format!("unknown [ledger] key '{key}'"))),
                }
            }
            Section::Fault => {
                let Some(d) = drafts.last_mut() else {
                    return Err(perr(ln, "[[fault]] section lost its draft".to_string()));
                };
                match key {
                    "kind" => {
                        let s = p_str(val, ln)?;
                        d.kind = Some(parse_kind(&s).ok_or_else(|| {
                            perr(ln, format!("unknown kind '{s}' (want cpu, gpu, net, or hang)"))
                        })?);
                    }
                    "target" => {
                        let s = p_str(val, ln)?;
                        d.target = Some(parse_target(&s).ok_or_else(|| {
                            perr(
                                ln,
                                format!(
                                    "bad target '{s}' (want gpu:N, node:N, uplink:N, or \
                                     link:A-B)"
                                ),
                            )
                        })?);
                    }
                    "start" => d.start = p_f64(val, ln)?,
                    "duration" => d.duration = p_f64(val, ln)?,
                    "scale" => d.scale = Some(p_f64(val, ln)?),
                    "repeat" => d.repeat = p_usize(val, ln)?,
                    "period" => d.period = p_f64(val, ln)?,
                    "ramp_to" => d.ramp_to = Some(p_f64(val, ln)?),
                    "ramp_steps" => d.ramp_steps = p_usize(val, ln)?,
                    "job" => d.job = Some(p_usize(val, ln)?),
                    _ => return Err(perr(ln, format!("unknown [[fault]] key '{key}'"))),
                }
            }
        }
    }

    for d in drafts {
        spec.faults.push(d.finish()?);
    }
    spec.validate()?;
    Ok(spec)
}

pub(crate) fn render(spec: &ScenarioSpec) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    let _ = writeln!(out, "name = \"{}\"", spec.name);
    let _ = writeln!(out, "description = \"{}\"", spec.description);

    let t = &spec.topology;
    out.push_str("\n[topology]\n");
    let _ = writeln!(out, "tp = {}", t.tp);
    let _ = writeln!(out, "dp = {}", t.dp);
    let _ = writeln!(out, "pp = {}", t.pp);
    let _ = writeln!(out, "gpus_per_node = {}", t.gpus_per_node);
    let _ = writeln!(out, "gpu_class = \"{}\"", gpu_class_token(t.gpu_class));
    let _ = writeln!(out, "model = \"{}\"", t.model);
    let _ = writeln!(out, "microbatches = {}", t.microbatches);
    let _ = writeln!(out, "mfu = {}", t.mfu);
    let _ = writeln!(out, "jitter = {}", t.jitter);
    let _ = writeln!(out, "spike_p = {}", t.spike_p);

    out.push_str("\n[run]\n");
    let _ = writeln!(out, "iters = {}", spec.run.iters);
    let _ = writeln!(out, "seed = {}", spec.run.seed);
    let _ = writeln!(out, "mitigate = {}", spec.run.mitigate);
    let _ = writeln!(out, "replan = {}", spec.run.replan);

    for f in &spec.faults {
        out.push_str("\n[[fault]]\n");
        let _ = writeln!(out, "kind = \"{}\"", kind_token(f.kind));
        let _ = writeln!(out, "target = \"{}\"", target_token(f.target));
        if let Some(j) = f.job {
            let _ = writeln!(out, "job = {j}");
        }
        let _ = writeln!(out, "start = {}", f.start);
        let _ = writeln!(out, "duration = {}", f.duration);
        let _ = writeln!(out, "scale = {}", f.scale);
        if f.repeat > 0 {
            let _ = writeln!(out, "repeat = {}", f.repeat);
            let _ = writeln!(out, "period = {}", f.period);
        }
        if let Some(to) = f.ramp_to {
            let _ = writeln!(out, "ramp_to = {to}");
            let _ = writeln!(out, "ramp_steps = {}", f.ramp_steps);
        }
    }

    if let Some(f) = &spec.fleet {
        out.push_str("\n[fleet]\n");
        let _ = writeln!(out, "jobs = {}", f.jobs);
        let _ = writeln!(out, "workers = {}", f.workers);
        let _ = writeln!(out, "boost = {}", f.boost);
        let _ = writeln!(out, "compare = {}", f.compare);
        let policy = f.policy.map_or("private", |p| p.name());
        let _ = writeln!(out, "policy = \"{policy}\"");
        let _ = writeln!(out, "spare = {}", f.spare);
        let _ = writeln!(out, "epoch_len = {}", f.epoch_len);
        let _ = writeln!(out, "stagger = {}", f.stagger);
    }

    if let Some(l) = &spec.ledger {
        out.push_str("\n[ledger]\n");
        let _ = writeln!(out, "enabled = {}", l.enabled);
        let _ = writeln!(out, "flaky = {}", l.flaky);
        let _ = writeln!(out, "alpha = {}", l.alpha);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::super::{find, LIBRARY};
    use super::*;

    #[test]
    fn round_trip_pins_every_library_scenario() {
        // The acceptance contract: parse(render(spec)) == spec, for every
        // built-in scenario (covers faults, ramps, repeats, and fleet).
        for &name in LIBRARY {
            let spec = find(name).unwrap();
            let text = spec.render();
            let back = ScenarioSpec::parse(&text)
                .unwrap_or_else(|e| panic!("{name} failed to re-parse: {e}\n{text}"));
            assert_eq!(back, spec, "{name} did not round-trip:\n{text}");
        }
    }

    #[test]
    fn parses_a_hand_written_spec_with_comments() {
        let src = r#"
            # a scenario written by hand
            name = "hand"
            description = "one flapping uplink"   # trailing comment

            [topology]
            tp = 1
            dp = 8
            pp = 1
            gpus_per_node = 4

            [run]
            iters = 50
            seed = 7
            mitigate = false

            [[fault]]
            kind = "net"
            target = "uplink:1"
            start = 0.1
            duration = 0.05
            scale = 0.3
            repeat = 3
            period = 0.2
        "#;
        let spec = ScenarioSpec::parse(src).unwrap();
        assert_eq!(spec.name, "hand");
        assert_eq!(spec.topology.dp, 8);
        assert_eq!(spec.n_nodes(), 2);
        assert!(!spec.run.mitigate);
        assert_eq!(spec.faults.len(), 1);
        assert_eq!(spec.faults[0].target, Target::Uplink(1));
        assert_eq!(spec.faults[0].repeat, 3);
        // Defaults fill what the file leaves out.
        assert_eq!(spec.topology.model, "gpt2-7b");
        assert_eq!(spec.topology.microbatches, 8);
    }

    #[test]
    fn errors_carry_line_numbers() {
        let bad = "name = \"x\"\nbogus_key = 3\n";
        match ScenarioSpec::parse(bad) {
            Err(ScenarioError::Parse { line, msg }) => {
                assert_eq!(line, 2);
                assert!(msg.contains("bogus_key"), "{msg}");
            }
            other => panic!("expected a parse error, got {other:?}"),
        }
        let bad = "name = \"x\"\n[nope]\n";
        assert!(matches!(
            ScenarioSpec::parse(bad),
            Err(ScenarioError::Parse { line: 2, .. })
        ));
        let bad = "name = \"x\"\n\n[[fault]]\nkind = \"gpu\"\nscale = 0.5\n";
        match ScenarioSpec::parse(bad) {
            Err(ScenarioError::Parse { line, msg }) => {
                assert_eq!(line, 3, "points at the [[fault]] header");
                assert!(msg.contains("target"), "{msg}");
            }
            other => panic!("expected a missing-field error, got {other:?}"),
        }
        // Semantic problems surface as typed field errors.
        let bad = "name = \"x\"\n[topology]\nmodel = \"gpt9\"\n";
        assert!(matches!(
            ScenarioSpec::parse(bad),
            Err(ScenarioError::Field { .. })
        ));
    }

    #[test]
    fn ledger_section_parses_and_validates() {
        let src = "name = \"l\"\n[fleet]\npolicy = \"predictive\"\n\
                   [ledger]\nflaky = 0.2\nalpha = 1.1\n";
        let spec = ScenarioSpec::parse(src).unwrap();
        let ls = spec.ledger.unwrap();
        assert!(ls.enabled, "enabled defaults to true");
        assert_eq!(ls.flaky, 0.2);
        assert_eq!(ls.alpha, 1.1);
        assert_eq!(
            spec.fleet.unwrap().policy,
            Some(Policy::PredictiveQuarantine)
        );
        // [ledger] without a shared-cluster fleet is a typed field error.
        let bad = "name = \"l\"\n[ledger]\nflaky = 0.2\n";
        assert!(matches!(
            ScenarioSpec::parse(bad),
            Err(ScenarioError::Field { .. })
        ));
    }

    #[test]
    fn fleet_section_parses_policies() {
        let src = "name = \"f\"\n[fleet]\njobs = 8\npolicy = \"spread\"\nstagger = 1.5\n";
        let spec = ScenarioSpec::parse(src).unwrap();
        let fs = spec.fleet.unwrap();
        assert_eq!(fs.jobs, 8);
        assert_eq!(fs.policy, Some(Policy::Spread));
        assert_eq!(fs.stagger, 1.5);
        let src = "name = \"f\"\n[fleet]\npolicy = \"private\"\n";
        assert_eq!(ScenarioSpec::parse(src).unwrap().fleet.unwrap().policy, None);
    }
}
