//! Structured scenario results: one [`Outcome`] shape for single-job and
//! fleet scenarios, with hand-rolled JSON serialization and the existing
//! ASCII rendering layered on top.

use crate::coordinator::{ActionKind, Falcon};
use crate::fleet::{match_detection_latencies, FleetReport};
use crate::inject::FailSlowEvent;
use crate::mitigate::Strategy;
use crate::sim::TrainingSim;
use crate::util::json::Json;
use crate::util::{plot, stats};

use super::ScenarioSpec;

/// Delay attributed to one `[[fault]]` entry: baseline JCT minus the JCT
/// of the replay with that fault dropped. Positive = the fault cost time.
/// Produced by the what-if engine (`crate::whatif::attribute`); defined
/// here because it is part of the [`Outcome`] shape.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultAttribution {
    /// Index into the spec's fault script.
    pub fault: usize,
    /// Compact description, e.g. `gpu gpu:3 @0.10`.
    pub label: String,
    /// Events the fault expanded to (ramp steps, recurrences).
    pub events: usize,
    pub delay_s: f64,
    /// `delay_s` as a percentage of the ideal JCT.
    pub delay_pct: f64,
}

/// The what-if attribution of one recorded single-job run.
#[derive(Clone, Debug, PartialEq)]
pub struct Attribution {
    pub baseline_jct_s: f64,
    /// Fault-free, pause-free JCT (`iters * ideal_iter_s`).
    pub ideal_jct_s: f64,
    /// Paper-style aggregate: `100 * (baseline - ideal) / ideal`.
    pub jct_delay_pct: f64,
    pub faults: Vec<FaultAttribution>,
    /// JCT excess of the `NoMitigation` replay over the baseline: what
    /// FALCON-MITIGATE saved (negative = mitigation cost more than it
    /// bought on this trace). 0 for detection-only runs.
    pub mitigation_benefit_s: f64,
    pub mitigation_benefit_pct: f64,
    /// `(baseline - ideal) - Σ fault delays`: measurement jitter, stall
    /// spikes, detection/validation pauses, and fault interaction.
    pub unattributed_s: f64,
    /// Counterfactual replays executed to produce this attribution.
    pub replays: usize,
}

impl Attribution {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("baseline_jct_s", Json::Num(self.baseline_jct_s)),
            ("ideal_jct_s", Json::Num(self.ideal_jct_s)),
            ("jct_delay_pct", Json::Num(self.jct_delay_pct)),
            (
                "faults",
                Json::Arr(
                    self.faults
                        .iter()
                        .map(|f| {
                            Json::obj(vec![
                                ("fault", Json::Num(f.fault as f64)),
                                ("label", Json::str(&f.label)),
                                ("events", Json::Num(f.events as f64)),
                                ("delay_s", Json::Num(f.delay_s)),
                                ("delay_pct", Json::Num(f.delay_pct)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("mitigation_benefit_s", Json::Num(self.mitigation_benefit_s)),
            ("mitigation_benefit_pct", Json::Num(self.mitigation_benefit_pct)),
            ("unattributed_s", Json::Num(self.unattributed_s)),
            ("replays", Json::Num(self.replays as f64)),
        ])
    }

    /// Human-readable attribution block (appended to `Outcome::render`).
    pub fn render(&self) -> String {
        let mut out = format!(
            "what-if attribution ({} replays): JCT {:.1} s vs ideal {:.1} s \
             ({:+.2}% delay)\n",
            self.replays, self.baseline_jct_s, self.ideal_jct_s, self.jct_delay_pct
        );
        for f in &self.faults {
            out.push_str(&format!(
                "  fault[{}] {} ({} events): {:+.1} s ({:+.2}%)\n",
                f.fault, f.label, f.events, f.delay_s, f.delay_pct
            ));
        }
        if self.mitigation_benefit_s != 0.0 {
            out.push_str(&format!(
                "  mitigation benefit: {:+.1} s ({:+.2}%)\n",
                self.mitigation_benefit_s, self.mitigation_benefit_pct
            ));
        }
        out.push_str(&format!(
            "  unattributed (jitter/spikes/pauses/interaction): {:+.1} s\n",
            self.unattributed_s
        ));
        out
    }
}

/// One coordinator action, flattened for logs and JSON.
#[derive(Clone, Debug, PartialEq)]
pub struct OutcomeAction {
    pub t_min: f64,
    pub iter: usize,
    /// Compact token, e.g. `episode_opened`, `diagnosed:gpu`,
    /// `applied:S2:AdjustMicrobatch`.
    pub kind: String,
}

/// One op-trace episode verdict (`crate::diagnose`), flattened for JSON:
/// the hang-vs-slow class token, the pinned culprit label, and the
/// evidence behind them.
#[derive(Clone, Debug, PartialEq)]
pub struct OutcomeDiagnosis {
    pub t_min: f64,
    pub iter: usize,
    /// Class token: `compute-slow`, `comm-slow`, `comm-hang`, or
    /// `slow-masking-hang`.
    pub class: String,
    /// Culprit label: `gpu:N`, `node:N`, `link:A-B`, or `uplink:N`.
    pub culprit: String,
    /// Sim-time span (seconds) of the evidence window folded.
    pub window_s: (f64, f64),
    /// Worst ring-edge ratio vs the healthy twin in the window.
    pub comm_ratio: f64,
    /// Worst replica makespan ratio vs the healthy twin in the window.
    pub compute_ratio: f64,
}

/// Fleet-level results (None for single-job scenarios). Wall-clock fields
/// are deliberately excluded so the outcome is deterministic for a fixed
/// spec.
#[derive(Clone, Debug, PartialEq)]
pub struct FleetOutcome {
    pub jobs: usize,
    pub gpus: usize,
    pub jobs_with_failslow: usize,
    pub jobs_flagged: usize,
    pub false_positives: usize,
    pub missed: usize,
    pub mean_slowdown: f64,
    pub mitigated_over_ignored: f64,
    pub compared_jobs: usize,
    /// FNV fingerprint of the per-job results (hex).
    pub digest: String,
    /// Shared-cluster policy name (None = private clusters).
    pub policy: Option<String>,
    pub cluster_nodes: usize,
    pub s3_requests: usize,
    pub s3_granted: usize,
    pub s3_denied: usize,
    pub s4_requests: usize,
    pub s4_granted: usize,
    pub s4_in_place: usize,
    pub queued_decisions: usize,
    pub preempted: usize,
    pub cancelled: usize,
    pub denial_rate: f64,
    pub mean_contention_scale: f64,
    pub grant_wait_p50_s: f64,
    pub grant_wait_p99_s: f64,
}

/// Structured result of [`ScenarioSpec::run`].
#[derive(Clone, Debug, PartialEq)]
pub struct Outcome {
    pub scenario: String,
    /// Parallel strategy label (single-job) or `fleet`.
    pub label: String,
    pub nodes: usize,
    pub world: usize,
    pub iters: usize,
    /// Healthy-cluster throughput, iters/s (fleet: mean across jobs).
    pub ideal_thpt: f64,
    /// Achieved mean throughput, iters/s (fleet: mean across jobs).
    pub mean_thpt: f64,
    /// Job completion time in simulated seconds: the sim clock at the end
    /// of the run, every pause and restart included (fleet: mean over jobs
    /// of `iters / mean_thpt`). The what-if engine's attribution deltas
    /// are differences of this field across counterfactual replays.
    pub jct_s: f64,
    /// Injected fail-slow events (fleet: across all jobs).
    pub injected: usize,
    /// Verified episodes the detector(s) opened.
    pub episodes_detected: usize,
    /// Seconds from injected onset to verified onset, per matched episode.
    pub detection_latency_s: Vec<f64>,
    /// Coordinator action log (empty for fleet scenarios).
    pub actions: Vec<OutcomeAction>,
    /// Applied-mitigation tally per level, `[S1, S2, S3, S4, S5]` (empty
    /// for fleet scenarios — the arbiter counters cover those).
    pub applied_per_level: Vec<usize>,
    pub timeline_mins: Vec<f64>,
    pub timeline_thpt: Vec<f64>,
    /// Op-trace episode verdicts (hang-vs-slow taxonomy; empty for fleet
    /// scenarios — fleet jobs diagnose internally but the report
    /// aggregates counts only).
    pub diagnosis: Vec<OutcomeDiagnosis>,
    pub fleet: Option<FleetOutcome>,
    /// What-if attribution (per-fault delay, mitigation benefit, JCT-delay
    /// %), attached by `falcon whatif` / the what-if engine's `attribute`;
    /// `None` on a plain run.
    pub attribution: Option<Attribution>,
}

fn action_token(what: &ActionKind) -> String {
    match what {
        ActionKind::EpisodeOpened => "episode_opened".to_string(),
        ActionKind::EpisodeClosed => "episode_closed".to_string(),
        ActionKind::Diagnosed(d) => format!("diagnosed:{}", super::kind_token(d.kind)),
        ActionKind::Applied(s) => format!("applied:{}", s.name()),
        ActionKind::Requested(s) => format!("requested:{}", s.name()),
        ActionKind::Granted(s) => format!("granted:{}", s.name()),
        ActionKind::Denied(s, streak) => format!("denied:{}#{streak}", s.name()),
    }
}

/// Slot of a strategy in the `[S1, S2, S3, S4, S5]` tally.
fn level_index(s: Strategy) -> usize {
    match s {
        Strategy::Ignore => 0,
        Strategy::AdjustMicrobatch => 1,
        Strategy::AdjustTopology => 2,
        Strategy::CkptRestart => 3,
        Strategy::ReplanParallelism => 4,
    }
}

impl Outcome {
    pub(crate) fn from_single(
        spec: &ScenarioSpec,
        sim: &TrainingSim,
        falcon: &Falcon,
        injected: &[FailSlowEvent],
    ) -> Outcome {
        let latencies = match_detection_latencies(injected, &falcon.episode_opens());
        let mut applied_per_level = vec![0usize; 5];
        for a in &falcon.actions {
            if let ActionKind::Applied(s) = a.what {
                applied_per_level[level_index(s)] += 1;
            }
        }
        Outcome {
            scenario: spec.name.clone(),
            label: spec.cfg().label(),
            nodes: spec.n_nodes(),
            world: spec.world(),
            iters: spec.run.iters,
            ideal_thpt: 1.0 / sim.ideal_iter_s,
            mean_thpt: sim.timeline.mean_throughput(),
            jct_s: crate::simkit::secs(sim.now),
            injected: injected.len(),
            episodes_detected: falcon.detector.episodes.len(),
            detection_latency_s: latencies,
            actions: falcon
                .actions
                .iter()
                .map(|a| OutcomeAction {
                    t_min: crate::simkit::mins(a.at),
                    iter: a.iter,
                    kind: action_token(&a.what),
                })
                .collect(),
            applied_per_level,
            timeline_mins: sim.timeline.xs_mins(),
            timeline_thpt: sim.timeline.ys(),
            diagnosis: falcon
                .episode_diagnoses
                .iter()
                .map(|d| OutcomeDiagnosis {
                    t_min: crate::simkit::mins(d.at),
                    iter: d.iter,
                    class: d.verdict.class.token().to_string(),
                    culprit: d.verdict.culprit.label(),
                    window_s: (
                        crate::simkit::secs(d.verdict.window.0),
                        crate::simkit::secs(d.verdict.window.1),
                    ),
                    comm_ratio: d.verdict.comm_ratio,
                    compute_ratio: d.verdict.compute_ratio,
                })
                .collect(),
            fleet: None,
            attribution: None,
        }
    }

    pub(crate) fn from_fleet(spec: &ScenarioSpec, report: &FleetReport) -> Outcome {
        let ideals: Vec<f64> = report.results.iter().map(|r| r.ideal_thpt).collect();
        let means: Vec<f64> = report.results.iter().map(|r| r.mean_thpt).collect();
        let pooled: Vec<f64> = report
            .results
            .iter()
            .flat_map(|r| r.detection_latency_s.iter().copied())
            .collect();
        let c = report.cluster.as_ref();
        let fleet = FleetOutcome {
            jobs: report.jobs,
            gpus: report.gpus,
            jobs_with_failslow: report.jobs_with_failslow,
            jobs_flagged: report.jobs_flagged,
            false_positives: report.false_positives,
            missed: report.missed,
            mean_slowdown: report.mean_slowdown,
            mitigated_over_ignored: report.mitigated_over_ignored,
            compared_jobs: report.compared_jobs,
            digest: format!("{:016x}", report.digest()),
            policy: c.map(|c| c.policy.name().to_string()),
            cluster_nodes: c.map_or(0, |c| c.nodes),
            s3_requests: c.map_or(0, |c| c.s3_requests),
            s3_granted: c.map_or(0, |c| c.s3_granted),
            s3_denied: c.map_or(0, |c| c.s3_denied),
            s4_requests: c.map_or(0, |c| c.s4_requests),
            s4_granted: c.map_or(0, |c| c.s4_granted),
            s4_in_place: c.map_or(0, |c| c.s4_in_place),
            queued_decisions: c.map_or(0, |c| c.queued_decisions),
            preempted: c.map_or(0, |c| c.preempted),
            cancelled: c.map_or(0, |c| c.cancelled),
            denial_rate: c.map_or(0.0, |c| c.denial_rate()),
            mean_contention_scale: c.map_or(1.0, |c| c.mean_contention_scale),
            grant_wait_p50_s: c.map_or(0.0, |c| c.grant_wait.p50),
            grant_wait_p99_s: c.map_or(0.0, |c| c.grant_wait.p99),
        };
        let jcts: Vec<f64> = report
            .results
            .iter()
            .filter(|r| r.mean_thpt > 0.0)
            .map(|r| report.iters as f64 / r.mean_thpt)
            .collect();
        Outcome {
            scenario: spec.name.clone(),
            label: "fleet".to_string(),
            nodes: c.map_or(0, |c| c.nodes),
            world: report.gpus,
            iters: report.iters,
            ideal_thpt: stats::mean(&ideals),
            mean_thpt: stats::mean(&means),
            jct_s: stats::mean(&jcts),
            injected: report.episodes_injected,
            episodes_detected: report.episodes_detected,
            detection_latency_s: pooled,
            actions: Vec::new(),
            applied_per_level: Vec::new(),
            timeline_mins: Vec::new(),
            timeline_thpt: Vec::new(),
            diagnosis: Vec::new(),
            fleet: Some(fleet),
            attribution: None,
        }
    }

    /// Serialize with the hand-rolled JSON substrate. Deterministic for a
    /// fixed spec (no wall-clock fields).
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("scenario", Json::str(&self.scenario)),
            ("label", Json::str(&self.label)),
            ("nodes", Json::Num(self.nodes as f64)),
            ("world", Json::Num(self.world as f64)),
            ("iters", Json::Num(self.iters as f64)),
            ("ideal_thpt", Json::Num(self.ideal_thpt)),
            ("mean_thpt", Json::Num(self.mean_thpt)),
            ("jct_s", Json::Num(self.jct_s)),
            ("injected", Json::Num(self.injected as f64)),
            ("episodes_detected", Json::Num(self.episodes_detected as f64)),
            ("detection_latency_s", Json::arr_f64(&self.detection_latency_s)),
            (
                "actions",
                Json::Arr(
                    self.actions
                        .iter()
                        .map(|a| {
                            Json::obj(vec![
                                ("t_min", Json::Num(a.t_min)),
                                ("iter", Json::Num(a.iter as f64)),
                                ("kind", Json::str(&a.kind)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "applied_per_level",
                Json::Arr(self.applied_per_level.iter().map(|&n| Json::Num(n as f64)).collect()),
            ),
            ("timeline_mins", Json::arr_f64(&self.timeline_mins)),
            ("timeline_thpt", Json::arr_f64(&self.timeline_thpt)),
            (
                "diagnosis",
                Json::Arr(
                    self.diagnosis
                        .iter()
                        .map(|d| {
                            Json::obj(vec![
                                ("t_min", Json::Num(d.t_min)),
                                ("iter", Json::Num(d.iter as f64)),
                                ("class", Json::str(&d.class)),
                                ("culprit", Json::str(&d.culprit)),
                                ("window_s", Json::arr_f64(&[d.window_s.0, d.window_s.1])),
                                ("comm_ratio", Json::Num(d.comm_ratio)),
                                ("compute_ratio", Json::Num(d.compute_ratio)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ];
        let fleet = match &self.fleet {
            None => Json::Null,
            Some(f) => Json::obj(vec![
                ("jobs", Json::Num(f.jobs as f64)),
                ("gpus", Json::Num(f.gpus as f64)),
                ("jobs_with_failslow", Json::Num(f.jobs_with_failslow as f64)),
                ("jobs_flagged", Json::Num(f.jobs_flagged as f64)),
                ("false_positives", Json::Num(f.false_positives as f64)),
                ("missed", Json::Num(f.missed as f64)),
                ("mean_slowdown", Json::Num(f.mean_slowdown)),
                ("mitigated_over_ignored", Json::Num(f.mitigated_over_ignored)),
                ("compared_jobs", Json::Num(f.compared_jobs as f64)),
                ("digest", Json::str(&f.digest)),
                (
                    "policy",
                    f.policy.as_ref().map_or(Json::Null, |p| Json::str(p)),
                ),
                ("cluster_nodes", Json::Num(f.cluster_nodes as f64)),
                ("s3_requests", Json::Num(f.s3_requests as f64)),
                ("s3_granted", Json::Num(f.s3_granted as f64)),
                ("s3_denied", Json::Num(f.s3_denied as f64)),
                ("s4_requests", Json::Num(f.s4_requests as f64)),
                ("s4_granted", Json::Num(f.s4_granted as f64)),
                ("s4_in_place", Json::Num(f.s4_in_place as f64)),
                ("queued_decisions", Json::Num(f.queued_decisions as f64)),
                ("preempted", Json::Num(f.preempted as f64)),
                ("cancelled", Json::Num(f.cancelled as f64)),
                ("denial_rate", Json::Num(f.denial_rate)),
                ("mean_contention_scale", Json::Num(f.mean_contention_scale)),
                ("grant_wait_p50_s", Json::Num(f.grant_wait_p50_s)),
                ("grant_wait_p99_s", Json::Num(f.grant_wait_p99_s)),
            ]),
        };
        fields.push(("fleet", fleet));
        fields.push((
            "attribution",
            self.attribution.as_ref().map_or(Json::Null, |a| a.to_json()),
        ));
        Json::obj(fields)
    }

    /// Human-readable rendering (the existing ASCII layer).
    pub fn render(&self) -> String {
        let mut out = format!(
            "scenario '{}' — {} ({} GPUs on {} nodes), {} iters\n",
            self.scenario, self.label, self.world, self.nodes, self.iters
        );
        if !self.timeline_thpt.is_empty() {
            out.push_str(&plot::line_chart(
                &format!("throughput ({} on {} nodes, iters/s)", self.label, self.nodes),
                &self.timeline_mins,
                &self.timeline_thpt,
                70,
                10,
            ));
        }
        if !self.actions.is_empty() {
            out.push_str("actions:\n");
            for a in &self.actions {
                out.push_str(&format!("  t={:.1}min iter={} {}\n", a.t_min, a.iter, a.kind));
            }
        }
        if self.applied_per_level.iter().any(|&n| n > 0) {
            let labels = ["S1", "S2", "S3", "S4", "S5"];
            let parts: Vec<String> = self
                .applied_per_level
                .iter()
                .zip(labels)
                .filter(|(&n, _)| n > 0)
                .map(|(&n, l)| format!("{l} x{n}"))
                .collect();
            out.push_str(&format!("applied per level: {}\n", parts.join(", ")));
        }
        if !self.diagnosis.is_empty() {
            out.push_str("diagnosis:\n");
            for d in &self.diagnosis {
                out.push_str(&format!(
                    "  t={:.1}min iter={} {} culprit={} (comm x{:.2}, compute x{:.2})\n",
                    d.t_min, d.iter, d.class, d.culprit, d.comm_ratio, d.compute_ratio
                ));
            }
        }
        out.push_str(&format!(
            "episodes: injected {}, detected {}",
            self.injected, self.episodes_detected
        ));
        if self.detection_latency_s.is_empty() {
            out.push('\n');
        } else {
            out.push_str(&format!(
                "; detection latency p50 {:.1}s (n={})\n",
                stats::quantile(&self.detection_latency_s, 0.5),
                self.detection_latency_s.len()
            ));
        }
        out.push_str(&format!(
            "mean throughput {:.3} iters/s (ideal {:.3}); JCT {:.1} s\n",
            self.mean_thpt, self.ideal_thpt, self.jct_s
        ));
        if let Some(f) = &self.fleet {
            out.push_str(&format!(
                "fleet: {} jobs ({} GPUs) — {} w/ fail-slow, {} flagged, {} missed, \
                 {} false+\n",
                f.jobs, f.gpus, f.jobs_with_failslow, f.jobs_flagged, f.missed, f.false_positives
            ));
            out.push_str(&format!(
                "fleet slowdown {:.3}x mean; digest {}\n",
                f.mean_slowdown, f.digest
            ));
            if let Some(p) = &f.policy {
                out.push_str(&format!(
                    "shared cluster: policy {}, {} nodes; contention scale {:.3}, \
                     denial rate {:.1}%\n",
                    p,
                    f.cluster_nodes,
                    f.mean_contention_scale,
                    100.0 * f.denial_rate
                ));
                out.push_str(&format!(
                    "arbitration: S3 {}/{}/{} req/granted/denied; S4 {}/{}/{} \
                     req/granted/in-place; queued {}, preempted {}, cancelled {}\n",
                    f.s3_requests,
                    f.s3_granted,
                    f.s3_denied,
                    f.s4_requests,
                    f.s4_granted,
                    f.s4_in_place,
                    f.queued_decisions,
                    f.preempted,
                    f.cancelled
                ));
            }
        }
        if let Some(a) = &self.attribution {
            out.push_str(&a.render());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_outcome() -> Outcome {
        Outcome {
            scenario: "golden".to_string(),
            label: "2T4D1P".to_string(),
            nodes: 1,
            world: 8,
            iters: 4,
            ideal_thpt: 0.5,
            mean_thpt: 0.25,
            jct_s: 16.0,
            injected: 1,
            episodes_detected: 1,
            detection_latency_s: vec![12.5],
            actions: vec![OutcomeAction {
                t_min: 1.5,
                iter: 2,
                kind: "episode_opened".to_string(),
            }],
            applied_per_level: vec![0, 1, 0, 0, 0],
            timeline_mins: vec![0.0, 2.0],
            timeline_thpt: vec![0.5, 0.25],
            diagnosis: vec![OutcomeDiagnosis {
                t_min: 1.6,
                iter: 2,
                class: "comm-hang".to_string(),
                culprit: "link:1-2".to_string(),
                window_s: (90.0, 96.0),
                comm_ratio: 1.0,
                compute_ratio: 1.5,
            }],
            fleet: None,
            attribution: None,
        }
    }

    #[test]
    fn golden_json_single_job() {
        // Pins the Outcome::to_json schema: field names, nesting, and value
        // encoding. Compared as parsed JSON so the pin is on content, not
        // incidental key order or whitespace.
        let expected = r#"{
            "scenario": "golden", "label": "2T4D1P", "nodes": 1, "world": 8,
            "iters": 4, "ideal_thpt": 0.5, "mean_thpt": 0.25, "jct_s": 16,
            "injected": 1, "episodes_detected": 1,
            "detection_latency_s": [12.5],
            "actions": [{"t_min": 1.5, "iter": 2, "kind": "episode_opened"}],
            "applied_per_level": [0, 1, 0, 0, 0],
            "timeline_mins": [0, 2], "timeline_thpt": [0.5, 0.25],
            "diagnosis": [{"t_min": 1.6, "iter": 2, "class": "comm-hang",
                           "culprit": "link:1-2", "window_s": [90, 96],
                           "comm_ratio": 1, "compute_ratio": 1.5}],
            "fleet": null, "attribution": null
        }"#;
        assert_eq!(Json::parse(expected).unwrap(), small_outcome().to_json());
    }

    #[test]
    fn golden_json_round_trips_through_parser() {
        let j = small_outcome().to_json();
        let text = j.to_string();
        assert_eq!(Json::parse(&text).unwrap(), j);
    }

    #[test]
    fn non_finite_outcome_fields_stay_valid_json() {
        // Audit pin: a degenerate run (zero-throughput job, NaN latency)
        // must never emit invalid JSON — non-finite numbers become null.
        let mut o = small_outcome();
        o.mean_thpt = f64::NAN;
        o.jct_s = f64::INFINITY;
        o.detection_latency_s = vec![f64::NEG_INFINITY];
        let text = o.to_json().to_string();
        let back = Json::parse(&text).expect("non-finite outcome must stay parseable");
        assert_eq!(back.get("mean_thpt"), Some(&Json::Null));
        assert_eq!(back.get("jct_s"), Some(&Json::Null));
    }

    #[test]
    fn render_mentions_key_fields() {
        let out = small_outcome().render();
        assert!(out.contains("scenario 'golden'"));
        assert!(out.contains("episodes: injected 1, detected 1"));
        assert!(out.contains("mean throughput 0.250"));
        assert!(out.contains("applied per level: S2 x1"));
        assert!(out.contains("comm-hang culprit=link:1-2"));
    }
}
