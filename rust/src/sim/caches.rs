//! Memoization layer for the simulator hot path.
//!
//! `TrainingSim::step` used to recompute the entire world every iteration:
//! per-replica 1F1B makespans (each walking freshly allocated TP groups and
//! stage-time vectors), per-stage p2p transfers, and brand-new `CommGroup`s
//! for every DP gradient ring — even though cluster health only moves when
//! an injected episode fires or heals, a mitigation lands, or the fleet
//! re-derives contention. This module makes the step O(what-changed):
//!
//! - every memo entry records the **physical nodes** it depends on and a
//!   stamp from [`Cluster::generation_sum`] over them; the per-node
//!   generations are bumped by the fabric's health setters, so a sick link
//!   invalidates only the replicas/rings whose node sets touch it
//!   (per-node granularity: any health change on a node recomputes every
//!   entry reading that node — always correct, occasionally wider than
//!   strictly needed);
//! - DP rings keep a prebuilt [`CommGroup`] plus a frozen
//!   [`AllReducePlan`] (deterministic base × per-call jitter, so the RNG
//!   stream is unchanged from the uncached engine);
//! - node-map permutations ([`RankGrid::generation`]) rebind placement
//!   without reallocating groups;
//! - recomputes reuse one [`StageTimes`] + [`MakespanScratch`] so the
//!   steady-state loop allocates nothing beyond the observation itself.
//!
//! Correctness bar: every value produced through this layer is
//! bit-identical to a from-scratch recompute — pinned by the equivalence
//! tests in `sim` (cached vs naive engine over the scenario library).
//! Mitigations exercise both invalidation paths at once: an S3 swap or an
//! S5 replan permutes the node map (generation bump → full rebind) while
//! the S2/S5 re-split moves per-replica micro-batch counts (per-entry `m`
//! mismatch → targeted recompute); `sim`'s
//! `replan_apply_revert_stays_cache_coherent` pins the combined case.

use crate::collectives::{AllReducePlan, CommGroup, Topology};
use crate::diagnose::{ComputeObs, Culprit, RingObs, TraceEntry, COMM_SLOW_RATIO};
use crate::fabric::Cluster;
use crate::monitor::group_id;
use crate::pipeline::{
    microbatch_time_s, one_f1b_makespan_scratch, MakespanScratch, RankCoord, RankGrid, StageTimes,
    Workload,
};
use crate::simkit::Time;
use crate::util::rng::Rng;

/// Memoized 1F1B makespan of one DP replica.
#[derive(Clone)]
struct ReplicaCache {
    /// Physical nodes hosting this replica's ranks (deduped).
    nodes: Vec<usize>,
    /// [`Cluster::generation_sum`] over `nodes` when `makespan` was cached.
    stamp: u64,
    /// Micro-batch count `makespan` was computed with.
    m: usize,
    makespan: f64,
    valid: bool,
    /// Micro-batch count `healthy_makespan` was computed with (0 = stale;
    /// the healthy twin never mutates, so placement + `m` are the only
    /// invalidators — see [`SimCaches::trace_entry`]).
    healthy_m: usize,
    /// This replica's 1F1B makespan on the pristine healthy twin.
    healthy_makespan: f64,
}

/// Memoized all-reduce plan of one DP gradient ring (the tp = 0 ring of a
/// pipeline stage — the representative ring `TrainingSim::step` samples).
#[derive(Clone)]
struct RingCache {
    group: CommGroup,
    nodes: Vec<usize>,
    stamp: u64,
    plan: AllReducePlan,
    valid: bool,
    /// Per-edge nominal times on the pristine healthy twin, in edge order
    /// (the op-trace's denominator). Invalidated only by rebinds.
    healthy_edges: Vec<f64>,
    healthy_valid: bool,
}

/// Placement- and health-independent op-log constants for one rank: the
/// monitor's communication-group ids depend only on rank sets, so they are
/// computed once at construction instead of once per rank per step.
#[derive(Clone)]
pub(super) struct RankOpLog {
    pub(super) coord: RankCoord,
    pub(super) tp_gid: u64,
    pub(super) pp_gid: u64,
    pub(super) dp_gid: u64,
    pub(super) self_gid: u64,
}

#[derive(Clone)]
pub(super) struct SimCaches {
    /// [`RankGrid::generation`] the node lists / ring GPUs derive from.
    topo_gen: u64,
    /// False until the first rebind (and after `invalidate_all`).
    topo_bound: bool,
    replicas: Vec<ReplicaCache>,
    rings: Vec<RingCache>,
    pub(super) oplog: Vec<RankOpLog>,
    /// Scratch stage times reused across recomputes.
    st: StageTimes,
    scratch: MakespanScratch,
}

impl SimCaches {
    pub(super) fn new(grid: &RankGrid) -> SimCaches {
        let cfg = grid.cfg;
        let world = cfg.world();
        let mut oplog = Vec::with_capacity(world);
        for rank in 0..world {
            let c = grid.coord_of(rank);
            oplog.push(RankOpLog {
                coord: c,
                tp_gid: group_id(&grid.tp_group(c.dp, c.pp)),
                pp_gid: group_id(&grid.pp_group(c.tp, c.dp)),
                dp_gid: group_id(&grid.dp_group(c.tp, c.pp)),
                self_gid: group_id(&[rank]),
            });
        }
        let replicas = (0..cfg.dp)
            .map(|_| ReplicaCache {
                nodes: Vec::new(),
                stamp: 0,
                m: 0,
                makespan: 0.0,
                valid: false,
                healthy_m: 0,
                healthy_makespan: 0.0,
            })
            .collect();
        let rings = if cfg.dp > 1 {
            (0..cfg.pp)
                .map(|pp| {
                    let ranks = grid.dp_group(0, pp);
                    let gpus = ranks.iter().map(|&r| grid.gpu_of(r)).collect();
                    RingCache {
                        group: CommGroup::new(ranks, gpus, Topology::Ring),
                        nodes: Vec::new(),
                        stamp: 0,
                        plan: AllReducePlan::default(),
                        valid: false,
                        healthy_edges: Vec::new(),
                        healthy_valid: false,
                    }
                })
                .collect()
        } else {
            Vec::new()
        };
        SimCaches {
            topo_gen: 0,
            topo_bound: false,
            replicas,
            rings,
            oplog,
            st: StageTimes { fwd: Vec::new(), bwd: Vec::new(), p2p: Vec::new() },
            scratch: MakespanScratch::default(),
        }
    }

    /// Forget every memoized value; the next refresh recomputes from
    /// scratch. The escape hatch after writing cluster health fields
    /// directly, and the benches' "what every step cost before the cache
    /// layer" probe.
    pub(super) fn invalidate_all(&mut self) {
        self.topo_bound = false;
    }

    /// Rebind placement-derived state (replica node lists, ring GPU
    /// positions) after a node-map permutation, invalidating every memo.
    fn rebind(&mut self, grid: &RankGrid) {
        let cfg = grid.cfg;
        for (d, rc) in self.replicas.iter_mut().enumerate() {
            rc.nodes.clear();
            for pp in 0..cfg.pp {
                for tp in 0..cfg.tp {
                    let n = grid.gpu_of(grid.rank_of(RankCoord { tp, dp: d, pp })).node;
                    if !rc.nodes.contains(&n) {
                        rc.nodes.push(n);
                    }
                }
            }
            rc.valid = false;
            rc.healthy_m = 0;
        }
        for ring in &mut self.rings {
            for i in 0..ring.group.ranks.len() {
                ring.group.gpus[i] = grid.gpu_of(ring.group.ranks[i]);
            }
            ring.nodes.clear();
            for g in &ring.group.gpus {
                if !ring.nodes.contains(&g.node) {
                    ring.nodes.push(g.node);
                }
            }
            ring.valid = false;
            ring.healthy_valid = false;
        }
        self.topo_gen = grid.generation();
        self.topo_bound = true;
    }

    /// Bring every memo up to date with the current placement, health, and
    /// micro-batch allocation. When nothing changed this is a stamp sweep
    /// (a few u64 adds per replica/ring); only entries whose stamps moved
    /// recompute, with the exact pre-cache arithmetic.
    pub(super) fn refresh(
        &mut self,
        cluster: &Cluster,
        grid: &RankGrid,
        wl: &Workload,
        mfu: f64,
        alloc: &[usize],
    ) {
        if !self.topo_bound || self.topo_gen != grid.generation() {
            self.rebind(grid);
        }
        for d in 0..self.replicas.len() {
            let m = alloc[d].max(1);
            let stamp = cluster.generation_sum(&self.replicas[d].nodes);
            {
                let rc = &self.replicas[d];
                if rc.valid && rc.stamp == stamp && rc.m == m {
                    continue;
                }
            }
            let makespan = Self::replica_makespan(
                cluster,
                grid,
                wl,
                mfu,
                d,
                m,
                &mut self.st,
                &mut self.scratch,
            );
            let rc = &mut self.replicas[d];
            rc.makespan = makespan;
            rc.stamp = stamp;
            rc.m = m;
            rc.valid = true;
        }
        for ring in &mut self.rings {
            let stamp = cluster.generation_sum(&ring.nodes);
            if ring.valid && ring.stamp == stamp {
                continue;
            }
            ring.plan = ring.group.allreduce_plan(cluster, wl.dp_bytes(grid.cfg));
            ring.stamp = stamp;
            ring.valid = true;
        }
    }

    /// One replica's 1F1B makespan — the exact arithmetic of the uncached
    /// engine, over scratch-backed buffers.
    #[allow(clippy::too_many_arguments)]
    fn replica_makespan(
        cluster: &Cluster,
        grid: &RankGrid,
        wl: &Workload,
        mfu: f64,
        d: usize,
        m: usize,
        st: &mut StageTimes,
        scratch: &mut MakespanScratch,
    ) -> f64 {
        let pp = grid.cfg.pp;
        st.fwd.clear();
        st.bwd.clear();
        st.p2p.clear();
        st.fwd.reserve(pp);
        st.p2p.reserve(pp.saturating_sub(1));
        for s in 0..pp {
            let total = microbatch_time_s(cluster, grid, wl, d, s, mfu);
            st.fwd.push(total / 3.0);
            if s + 1 < pp {
                let a = grid.gpu_of_coord(RankCoord { tp: 0, dp: d, pp: s });
                let b = grid.gpu_of_coord(RankCoord { tp: 0, dp: d, pp: s + 1 });
                st.p2p.push(cluster.transfer_time_nominal_s(a, b, wl.pp_bytes_per_microbatch()));
            }
        }
        for i in 0..st.fwd.len() {
            let f = st.fwd[i];
            st.bwd.push(2.0 * f);
        }
        one_f1b_makespan_scratch(st, m, scratch)
    }

    /// Max over cached replica makespans (call after [`SimCaches::refresh`];
    /// same fold order as the uncached engine).
    pub(super) fn compute_max(&self) -> f64 {
        self.replicas.iter().map(|r| r.makespan).fold(0.0, f64::max)
    }

    /// Per-replica makespans, copied out for the observation.
    pub(super) fn makespans(&self) -> Vec<f64> {
        self.replicas.iter().map(|r| r.makespan).collect()
    }

    /// Slowest DP ring all-reduce over the frozen plans: `Some(rng)` draws
    /// one normal per edge in ring order (the identical stream the uncached
    /// engine consumed); `None` is the nominal planner value and draws
    /// nothing.
    pub(super) fn dp_time(&self, rng: Option<&mut Rng>) -> f64 {
        let mut dp_time = 0.0f64;
        match rng {
            Some(r) => {
                for ring in &self.rings {
                    dp_time = dp_time.max(ring.plan.sample(r));
                }
            }
            None => {
                for ring in &self.rings {
                    dp_time = dp_time.max(ring.plan.nominal());
                }
            }
        }
        dp_time
    }

    /// One iteration's op-trace entry: per-ring edge ratios against the
    /// pristine `healthy` twin (plus which edges are hung) and the worst
    /// replica's compute ratio with a telemetry-scan culprit. Call after
    /// [`SimCaches::refresh`] — every numerator is a cached nominal, so
    /// this draws no RNG and costs O(edges + replicas) per step. The
    /// healthy denominators are memoized: replica baselines recompute only
    /// when `m` moves or placement rebinds; ring baselines only on
    /// rebinds (the twin's health never changes).
    pub(super) fn trace_entry(
        &mut self,
        cluster: &Cluster,
        healthy: &Cluster,
        grid: &RankGrid,
        wl: &Workload,
        mfu: f64,
        iter: usize,
        now: Time,
    ) -> TraceEntry {
        // Compute evidence: worst makespan ratio across replicas.
        let mut best = (0usize, f64::MIN);
        for d in 0..self.replicas.len() {
            let m = self.replicas[d].m.max(1);
            if self.replicas[d].healthy_m != m {
                let mk = Self::replica_makespan(
                    healthy,
                    grid,
                    wl,
                    mfu,
                    d,
                    m,
                    &mut self.st,
                    &mut self.scratch,
                );
                let rc = &mut self.replicas[d];
                rc.healthy_makespan = mk;
                rc.healthy_m = m;
            }
            let rc = &self.replicas[d];
            let ratio =
                if rc.healthy_makespan > 0.0 { rc.makespan / rc.healthy_makespan } else { 1.0 };
            if ratio > best.1 {
                best = (d, ratio);
            }
        }
        let culprit = Self::compute_culprit(cluster, &self.replicas[best.0].nodes);
        let compute = ComputeObs { replica: best.0, ratio: best.1, culprit };

        // Comm evidence: per-edge ratio of each DP ring's frozen plan
        // against the healthy twin, hung edges recorded separately (a
        // hung edge's α–β nominal is unchanged — blocking is orthogonal
        // evidence to stretching).
        let bytes = wl.dp_bytes(grid.cfg);
        let mut rings = Vec::with_capacity(self.rings.len());
        for (stage, ring) in self.rings.iter_mut().enumerate() {
            let n = ring.group.len();
            if n <= 1 {
                continue;
            }
            let chunk = bytes / n as f64;
            if !ring.healthy_valid {
                ring.healthy_edges.clear();
                for i in 0..n {
                    let (a, b) = (ring.group.gpus[i], ring.group.gpus[(i + 1) % n]);
                    ring.healthy_edges.push(healthy.transfer_time_nominal_s(a, b, chunk));
                }
                ring.healthy_valid = true;
            }
            let mut obs =
                RingObs { stage, worst_ratio: 0.0, slow: Vec::new(), blocked: Vec::new() };
            for i in 0..n {
                let (ga, gb) = (ring.group.gpus[i], ring.group.gpus[(i + 1) % n]);
                let t = ring.plan.edges.get(i).map_or(0.0, |e| e.0);
                let h = ring.healthy_edges.get(i).copied().unwrap_or(0.0);
                let ratio = if h > 0.0 { t / h } else { 1.0 };
                obs.worst_ratio = obs.worst_ratio.max(ratio);
                if ga.node == gb.node {
                    continue;
                }
                let pair = (ga.node.min(gb.node), ga.node.max(gb.node));
                if ring.plan.hung_edges.contains(&i) {
                    if !obs.blocked.contains(&pair) {
                        obs.blocked.push(pair);
                    }
                } else if ratio >= COMM_SLOW_RATIO && !obs.slow.contains(&pair) {
                    obs.slow.push(pair);
                }
            }
            rings.push(obs);
        }
        TraceEntry { iter, at: now, rings, compute }
    }

    /// DCGM-style telemetry scan over one replica's nodes: the most
    /// degraded GPU wins, else the most contended host CPU, else the
    /// replica's first node as a neutral placeholder (only reported when
    /// the makespan ratio clears the compute bar, which a healthy replica
    /// never does).
    fn compute_culprit(cluster: &Cluster, nodes: &[usize]) -> Culprit {
        let gpn = cluster.spec.gpus_per_node;
        let mut worst_gpu = (1.0f64, 0usize);
        let mut worst_node = (1.0f64, 0usize);
        for &n in nodes {
            for i in 0..gpn {
                let flat = n * gpn + i;
                let scale = cluster.gpus.get(flat).map_or(1.0, |g| g.compute_scale);
                if scale < worst_gpu.0 {
                    worst_gpu = (scale, flat);
                }
            }
            let sat = cluster.nodes.get(n).map_or(1.0, |s| s.cpu_satisfaction);
            if sat < worst_node.0 {
                worst_node = (sat, n);
            }
        }
        if worst_gpu.0 < 0.9995 {
            Culprit::Gpu(worst_gpu.1)
        } else if worst_node.0 < 0.9995 {
            Culprit::Node(worst_node.1)
        } else {
            Culprit::Node(nodes.first().copied().unwrap_or(0))
        }
    }
}
