//! Simulated hybrid-parallel training job.
//!
//! Composes the fabric, pipeline, collective and injection substrates into
//! an iteration-by-iteration training simulation: each step evaluates
//! per-replica 1F1B makespans and DP all-reduce times at the *current*
//! cluster health, advances the clock, emits the monitor's op log, and
//! exposes the hooks FALCON needs (profiling queries, validation
//! benchmarks, micro-batch reallocation, node swaps, restart).
//!
//! This is the system under test for every at-scale experiment: the
//! characterization campaign (Fig 1/Table 1), the case studies (Fig 2–6),
//! detection accuracy (Fig 12, Tables 4–5) and mitigation effectiveness
//! (Fig 13–17, 20, Table 7).

mod caches;

use caches::SimCaches;

use crate::collectives::{CollOp, CommGroup, Topology};
use crate::diagnose::OpTrace;
use crate::fabric::{Cluster, ClusterSpec, GpuClass};
use crate::inject::{FailSlowEvent, Target};
use crate::metrics::{JobOutcome, Timeline};
use crate::monitor::{group_id, Monitor};
use crate::pipeline::{microbatch_time_s, ParallelConfig, RankGrid, Workload};
use crate::simkit::{from_secs, Time};
use crate::util::rng::Rng;

/// Test-only switch: route `iter_time_s` through a from-scratch naive
/// recompute instead of the [`SimCaches`] layer. The two paths are
/// bit-identical by contract (the equivalence tests below pin it), so
/// flipping this mid-run is semantically invisible — only slower.
#[cfg(test)]
pub(crate) static NAIVE_RECOMPUTE: std::sync::atomic::AtomicBool =
    std::sync::atomic::AtomicBool::new(false);

/// Everything needed to instantiate a simulated job.
#[derive(Clone, Copy, Debug)]
pub struct JobSpec {
    pub cfg: ParallelConfig,
    pub wl: Workload,
    pub gpus_per_node: usize,
    pub gpu_class: GpuClass,
    /// Model FLOPs utilization (fraction of peak the kernels achieve).
    pub mfu: f64,
    /// Iteration-time measurement jitter (CoV of healthy iterations).
    pub jitter: f64,
    /// Probability of a single-iteration stall spike (dataloader hiccup,
    /// GC pause, ...): the transient jitter that raw BOCD mistakes for a
    /// fail-slow and BOCD+V's verification dismisses (Tables 4-5).
    pub spike_p: f64,
    pub seed: u64,
}

impl JobSpec {
    pub fn n_nodes(&self) -> usize {
        self.cfg.world().div_ceil(self.gpus_per_node)
    }
}

/// Communication-group class (profiling compares like with like).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GroupClass {
    Dp,
    Pp,
}

/// Output of the profiling phase for one communication group.
#[derive(Clone, Debug)]
pub struct ProfiledGroup {
    pub id: u64,
    pub ranks: Vec<usize>,
    pub mean_time: f64,
    pub class: GroupClass,
}

/// Per-iteration observation surfaced to FALCON-DETECT.
#[derive(Clone, Debug)]
pub struct IterObs {
    pub iter: usize,
    pub start: Time,
    pub duration: Time,
    /// Compute makespan per DP replica (seconds).
    pub replica_makespan: Vec<f64>,
    /// DP all-reduce time (slowest gradient ring, seconds).
    pub dp_time: f64,
    /// Mean GPU SM utilization proxy (Fig 2/3/4's right panels).
    pub sm_util: f64,
}

impl IterObs {
    /// Iteration duration in seconds — the sample FALCON-DETECT consumes.
    pub fn duration_s(&self) -> f64 {
        crate::simkit::secs(self.duration)
    }
}

/// `Clone` captures the complete run state — cluster health, placement,
/// RNG stream position, scheduled events, allocation, timeline, and the
/// memo layer — so the what-if engine can snapshot a run mid-flight and
/// replay counterfactual tails from the exact recorded state.
#[derive(Clone)]
pub struct TrainingSim {
    pub spec: JobSpec,
    pub cluster: Cluster,
    /// Pristine twin of `cluster`, frozen at construction health and never
    /// mutated: the op-trace's noise-free denominator (a healthy component
    /// reads exactly ratio 1.0 against it, bitwise).
    healthy: Cluster,
    pub grid: RankGrid,
    pub monitor: Monitor,
    pub rng: Rng,
    pub now: Time,
    pub iter: usize,
    /// Scheduled fail-slow episodes (absolute sim time).
    pub events: Vec<FailSlowEvent>,
    applied: Vec<bool>,
    /// Micro-batches currently assigned to each DP replica (S2 mutates).
    pub microbatch_alloc: Vec<usize>,
    /// Healthy-cluster iteration time with even allocation (seconds).
    pub ideal_iter_s: f64,
    /// Whether the monitor shim is attached (adds its overhead — Fig 18).
    pub monitor_attached: bool,
    pub timeline: Timeline,
    /// Per-iteration collective-level evidence for `crate::diagnose`
    /// (bounded ring buffer; `op_trace.enabled` gates recording).
    pub op_trace: OpTrace,
    /// Incremental-engine memos (makespans, ring plans, op-log ids).
    caches: SimCaches,
}

impl TrainingSim {
    pub fn new(spec: JobSpec) -> Self {
        let cluster = Cluster::new(ClusterSpec::new(
            spec.n_nodes(),
            spec.gpus_per_node,
            spec.gpu_class,
        ));
        let grid = RankGrid::new(spec.cfg, spec.gpus_per_node);
        let world = spec.cfg.world();
        // THE root stream — every other stream in the sim (and its
        // replays) forks from this one seed.
        let rng = Rng::new(spec.seed);
        let monitor = Monitor::new(world, 4096);
        let alloc = even_alloc(spec.wl.microbatches * spec.cfg.dp, spec.cfg.dp);
        let caches = SimCaches::new(&grid);
        let mut sim = TrainingSim {
            spec,
            healthy: cluster.clone(),
            cluster,
            grid,
            monitor,
            rng,
            now: 0,
            iter: 0,
            events: Vec::new(),
            applied: Vec::new(),
            microbatch_alloc: alloc,
            ideal_iter_s: 0.0,
            monitor_attached: true,
            timeline: Timeline::default(),
            op_trace: OpTrace::default(),
            caches,
        };
        // Noiseless: touches no RNG, so the measurement stream starts
        // untouched at the first step.
        sim.ideal_iter_s = sim.iter_time_s(false).0;
        sim
    }

    /// Schedule fail-slow episodes (absolute times). Accepts any event
    /// source — fleet jobs pass `events.iter().copied()` so a 256-job
    /// campaign stops cloning its fault scripts.
    pub fn inject<I: IntoIterator<Item = FailSlowEvent>>(&mut self, events: I) {
        let before = self.events.len();
        self.events.extend(events);
        self.applied.extend(std::iter::repeat(false).take(self.events.len() - before));
    }

    /// Remove scheduled fail-slow events matching `pred`, reverting any
    /// that are currently applied, and return how many were removed. The
    /// what-if replay engine uses this to excise one fault's events from a
    /// restored snapshot before re-running the tail (`Edit::DropFault`).
    pub fn remove_events(&mut self, mut pred: impl FnMut(&FailSlowEvent) -> bool) -> usize {
        let mut keep_ev = Vec::with_capacity(self.events.len());
        let mut keep_ap = Vec::with_capacity(self.applied.len());
        let mut removed = 0;
        for i in 0..self.events.len() {
            let ev = self.events[i];
            if pred(&ev) {
                if self.applied[i] {
                    ev.revert(&mut self.cluster);
                }
                removed += 1;
            } else {
                keep_ev.push(ev);
                keep_ap.push(self.applied[i]);
            }
        }
        self.events = keep_ev;
        self.applied = keep_ap;
        removed
    }

    /// Indices (into the current `events` list) of episodes applied to the
    /// cluster right now — the active fault set the what-if trace records
    /// per iteration.
    pub fn active_event_indices(&self) -> Vec<usize> {
        (0..self.events.len()).filter(|&i| self.applied[i]).collect()
    }

    /// Drop every memoized value; the next step recomputes from scratch.
    /// Results are bit-identical either way — this is the escape hatch
    /// after mutating `cluster` health fields directly (bypassing the
    /// generation-bumping setters), and the benches' probe for what every
    /// step cost before the incremental engine.
    pub fn invalidate_caches(&mut self) {
        self.caches.invalidate_all();
    }

    /// Apply/revert episodes whose boundaries we crossed.
    fn update_health(&mut self) {
        for i in 0..self.events.len() {
            let ev = self.events[i];
            if !self.applied[i] && ev.active_at(self.now) {
                ev.apply(&mut self.cluster);
                self.applied[i] = true;
            } else if self.applied[i] && !ev.active_at(self.now) {
                ev.revert(&mut self.cluster);
                self.applied[i] = false;
            }
        }
    }

    /// Compute the current iteration time (seconds) and per-replica detail.
    /// `noisy` adds measurement jitter (off when computing the ideal; the
    /// noiseless path touches no RNG at all).
    ///
    /// Incremental: per-replica makespans and per-ring all-reduce plans
    /// come from [`SimCaches`], revalidated against the cluster's per-node
    /// health generations — O(what-changed) instead of O(world).
    fn iter_time_s(&mut self, noisy: bool) -> (f64, Vec<f64>, f64) {
        #[cfg(test)]
        if NAIVE_RECOMPUTE.load(std::sync::atomic::Ordering::Relaxed) {
            return self.iter_time_naive(noisy);
        }
        let cfg = self.spec.cfg;
        self.caches.refresh(
            &self.cluster,
            &self.grid,
            &self.spec.wl,
            self.spec.mfu,
            &self.microbatch_alloc,
        );
        let makespans = self.caches.makespans();

        // Gradient all-reduce: slowest DP ring paces the sync. One ring per
        // (tp, pp); the tp=0 ring is representative since TP peers sit on
        // the same nodes.
        let mut dp_time = 0.0f64;
        if cfg.dp > 1 {
            let rng = if noisy { Some(&mut self.rng) } else { None };
            dp_time = self.caches.dp_time(rng);
        }

        let compute = self.caches.compute_max();
        let mut total = compute + dp_time;
        if self.monitor_attached {
            total *= 1.0 + self.monitor.overhead_frac;
        }
        if noisy && self.spec.jitter > 0.0 {
            total *= (1.0 + self.spec.jitter * self.rng.normal()).max(0.2);
        }
        if noisy && self.spec.spike_p > 0.0 && self.rng.bernoulli(self.spec.spike_p) {
            total *= self.rng.range_f64(1.2, 1.8);
        }
        (total, makespans, dp_time)
    }

    /// The pre-cache engine: rebuild everything from scratch, per call.
    /// Kept test-only as the oracle the equivalence tests pin [`SimCaches`]
    /// against (identical values AND identical RNG stream).
    #[cfg(test)]
    fn iter_time_naive(&mut self, noisy: bool) -> (f64, Vec<f64>, f64) {
        use crate::pipeline::{one_f1b_makespan, RankCoord, StageTimes};
        let cfg = self.spec.cfg;
        let mfu = self.spec.mfu;

        let mut makespans = Vec::with_capacity(cfg.dp);
        for d in 0..cfg.dp {
            let m = self.microbatch_alloc[d].max(1);
            let mut fwd = Vec::with_capacity(cfg.pp);
            let mut p2p = Vec::with_capacity(cfg.pp.saturating_sub(1));
            for s in 0..cfg.pp {
                let total = microbatch_time_s(&self.cluster, &self.grid, &self.spec.wl, d, s, mfu);
                fwd.push(total / 3.0);
                if s + 1 < cfg.pp {
                    let a = self.grid.gpu_of_coord(RankCoord { tp: 0, dp: d, pp: s });
                    let b = self.grid.gpu_of_coord(RankCoord { tp: 0, dp: d, pp: s + 1 });
                    p2p.push(self.cluster.transfer_time_nominal_s(
                        a,
                        b,
                        self.spec.wl.pp_bytes_per_microbatch(),
                    ));
                }
            }
            let st = StageTimes { bwd: fwd.iter().map(|f| 2.0 * f).collect(), fwd, p2p };
            makespans.push(one_f1b_makespan(&st, m));
        }

        let mut dp_time = 0.0f64;
        if cfg.dp > 1 {
            let bytes = self.spec.wl.dp_bytes(cfg);
            for pp in 0..cfg.pp {
                let plan = self.dp_comm_group(0, pp).allreduce_plan(&self.cluster, bytes);
                let t = if noisy { plan.sample(&mut self.rng) } else { plan.nominal() };
                dp_time = dp_time.max(t);
            }
        }

        let compute = makespans.iter().copied().fold(0.0, f64::max);
        let mut total = compute + dp_time;
        if self.monitor_attached {
            total *= 1.0 + self.monitor.overhead_frac;
        }
        if noisy && self.spec.jitter > 0.0 {
            total *= (1.0 + self.spec.jitter * self.rng.normal()).max(0.2);
        }
        if noisy && self.spec.spike_p > 0.0 && self.rng.bernoulli(self.spec.spike_p) {
            total *= self.rng.range_f64(1.2, 1.8);
        }
        (total, makespans, dp_time)
    }

    /// Noiseless estimate of the current iteration time (seconds) at the
    /// present health and topology — does not advance the clock, log ops,
    /// or touch the RNG (no clone, no draws: the nominal ring plans skip
    /// the per-edge jitter entirely). Planners (S3 swap search) call this
    /// many times per decision.
    pub fn estimate_iter_time_s(&mut self) -> f64 {
        let (t, _, _) = self.iter_time_s(false);
        t
    }

    pub fn dp_comm_group(&self, tp: usize, pp: usize) -> CommGroup {
        let ranks = self.grid.dp_group(tp, pp);
        let gpus = ranks.iter().map(|&r| self.grid.gpu_of(r)).collect();
        CommGroup::new(ranks, gpus, Topology::Ring)
    }

    pub fn pp_comm_group(&self, tp: usize, dp: usize) -> CommGroup {
        let ranks = self.grid.pp_group(tp, dp);
        let gpus = ranks.iter().map(|&r| self.grid.gpu_of(r)).collect();
        CommGroup::new(ranks, gpus, Topology::Ring)
    }

    pub fn tp_comm_group(&self, dp: usize, pp: usize) -> CommGroup {
        let ranks = self.grid.tp_group(dp, pp);
        let gpus = ranks.iter().map(|&r| self.grid.gpu_of(r)).collect();
        CommGroup::new(ranks, gpus, Topology::Ring)
    }

    /// Run one training iteration; returns the observation.
    pub fn step(&mut self) -> IterObs {
        self.update_health();
        let start = self.now;
        let (total_s, makespans, dp_time) = self.iter_time_s(true);
        let duration = from_secs(total_s);

        // SM utilization proxy: healthy iteration time / actual (all GPUs
        // idle-wait on the straggler, so utilization dips cluster-wide —
        // the signature seen in every case-study figure).
        let sm_util = (self.ideal_iter_s / total_s).min(1.0) * 0.95;

        self.record_trace(start);
        self.emit_op_log(start, duration, dp_time);

        self.now += duration;
        let obs = IterObs {
            iter: self.iter,
            start,
            duration,
            replica_makespan: makespans,
            dp_time,
            sm_util,
        };
        self.iter += 1;
        self.timeline.push(start, 1.0 / total_s);
        obs
    }

    /// Record this iteration's op-trace entry: per-ring edge evidence and
    /// the worst replica's compute evidence, each normalized against the
    /// pristine `healthy` twin. Draws no RNG and reads only memoized
    /// nominals, so tracing never perturbs the measurement stream. It
    /// refreshes the memo layer itself — a no-op stamp sweep on the cached
    /// engine, and exactly what makes the naive test engine (which skips
    /// the memo layer) produce the identical trace.
    fn record_trace(&mut self, start: Time) {
        if !self.op_trace.enabled {
            return;
        }
        self.caches.refresh(
            &self.cluster,
            &self.grid,
            &self.spec.wl,
            self.spec.mfu,
            &self.microbatch_alloc,
        );
        let entry = self.caches.trace_entry(
            &self.cluster,
            &self.healthy,
            &self.grid,
            &self.spec.wl,
            self.spec.mfu,
            self.iter,
            start,
        );
        self.op_trace.push(entry);
    }

    /// Emit the per-rank communication-op timeline for this iteration
    /// (the Monitor's view; Fig 8's recurring period). Group ids depend
    /// only on rank sets, so they come from the per-rank cache built at
    /// construction instead of being rehashed per rank per step.
    fn emit_op_log(&mut self, start: Time, duration: Time, dp_time: f64) {
        if !self.monitor_attached {
            return;
        }
        let cfg = self.spec.cfg;
        let compute_end = start + duration - from_secs(dp_time);
        for rank in 0..cfg.world() {
            let ids = &self.caches.oplog[rank];
            let c = ids.coord;
            // TP all-reduce marks within the compute phase.
            if cfg.tp > 1 {
                let g = ids.tp_gid;
                let at = start + (compute_end - start) / 4;
                self.monitor.record(rank, CollOp::AllReduce, g, at, 0);
            }
            // PP boundary send/recv.
            if cfg.pp > 1 {
                let g = ids.pp_gid;
                let at = start + (compute_end - start) / 2;
                let op = if c.pp + 1 < cfg.pp { CollOp::Send } else { CollOp::Recv };
                self.monitor.record(rank, op, g, at, 0);
            }
            // Gradient RS + AG at the iteration boundary.
            if cfg.dp > 1 {
                let g = ids.dp_gid;
                self.monitor.record(rank, CollOp::ReduceScatter, g, compute_end, 0);
                self.monitor
                    .record(rank, CollOp::AllGather, g, start + duration, 0);
            } else {
                // Still an optimizer-boundary op so every config has an
                // iteration marker.
                self.monitor
                    .record(rank, CollOp::AllReduce, ids.self_gid, start + duration, 0);
            }
        }
    }

    /// Run `iters` iterations, returning the outcome.
    pub fn run(&mut self, iters: usize) -> JobOutcome {
        let t0 = self.now;
        for _ in 0..iters {
            self.step();
        }
        JobOutcome {
            iters,
            ideal: from_secs(self.ideal_iter_s * iters as f64),
            actual: self.now - t0,
            timeline: self.timeline.clone(),
        }
    }

    // --- profiling & validation hooks (used by FALCON-DETECT) -------------

    /// Per-group mean transfer time at current health: the profiling phase's
    /// "CUDA event" aggregation.
    pub fn profile_groups(&mut self) -> Vec<ProfiledGroup> {
        let cfg = self.spec.cfg;
        let mut out = Vec::new();
        let mut rng = self.rng.fork(0xA11CE);
        if cfg.dp > 1 {
            let bytes = self.spec.wl.dp_bytes(cfg);
            for pp in 0..cfg.pp {
                for tp in 0..cfg.tp {
                    let g = self.dp_comm_group(tp, pp);
                    let t = g.allreduce_time_s(&self.cluster, bytes, &mut rng);
                    out.push(ProfiledGroup {
                        id: group_id(&g.ranks),
                        ranks: g.ranks.clone(),
                        mean_time: t,
                        class: GroupClass::Dp,
                    });
                }
            }
        }
        if cfg.pp > 1 {
            let bytes = self.spec.wl.pp_bytes_per_microbatch();
            for dp in 0..cfg.dp {
                for tp in 0..cfg.tp {
                    let g = self.pp_comm_group(tp, dp);
                    let mut worst = 0.0f64;
                    for (a, b) in g.edges() {
                        if a < b {
                            // PP is a chain, not a cycle: skip the wrap edge.
                            let t = self
                                .cluster
                                .transfer_time_s(g.gpus[a], g.gpus[b], bytes, &mut rng);
                            worst = worst.max(t);
                        }
                    }
                    out.push(ProfiledGroup {
                        id: group_id(&g.ranks),
                        ranks: g.ranks.clone(),
                        mean_time: worst,
                        class: GroupClass::Pp,
                    });
                }
            }
        }
        out
    }

    /// Dispatch a GEMM benchmark to one GPU (validation phase). Returns
    /// seconds for a fixed-size GEMM at current health + noise.
    pub fn bench_gpu(&mut self, flat_gpu: usize) -> f64 {
        let id = self.cluster.gpu_by_flat(flat_gpu);
        let flops = 2.0 * 4096f64.powi(3); // 4096^3 GEMM
        let t = flops / (self.cluster.gpu_rate(id) * self.spec.mfu);
        t * (1.0 + 0.01 * self.rng.normal()).max(0.5)
    }

    /// Time one P2P validation transfer between two ranks (fixed 256 MiB).
    pub fn bench_edge(&mut self, rank_a: usize, rank_b: usize) -> f64 {
        let a = self.grid.gpu_of(rank_a);
        let b = self.grid.gpu_of(rank_b);
        let bytes = 256.0 * 1024.0 * 1024.0;
        let mut rng = self.rng.fork(0xBE9C);
        self.cluster.transfer_time_s(a, b, bytes, &mut rng)
    }

    // --- mitigation hooks (used by FALCON-MITIGATE) ------------------------

    /// S2: set the per-replica micro-batch allocation.
    pub fn set_microbatch_alloc(&mut self, alloc: Vec<usize>) {
        assert_eq!(alloc.len(), self.spec.cfg.dp);
        assert_eq!(
            alloc.iter().sum::<usize>(),
            self.spec.wl.microbatches * self.spec.cfg.dp,
            "allocation must preserve the global batch"
        );
        self.microbatch_alloc = alloc;
    }

    /// Mean per-microbatch processing time of each DP replica (the t_i of
    /// Eq. 1), profiled at current health.
    pub fn replica_microbatch_times(&self) -> Vec<f64> {
        let cfg = self.spec.cfg;
        let (wl, mfu) = (&self.spec.wl, self.spec.mfu);
        (0..cfg.dp)
            .map(|d| {
                (0..cfg.pp)
                    .map(|s| microbatch_time_s(&self.cluster, &self.grid, wl, d, s, mfu))
                    .fold(0.0, f64::max)
            })
            .collect()
    }

    /// S3: swap two logical nodes and charge the pause overhead.
    pub fn swap_nodes(&mut self, a: usize, b: usize, pause: Time) {
        self.grid.swap_nodes(a, b);
        self.now += pause;
    }

    /// Shared-cluster S3: the job traded logical node `node`'s hardware for
    /// a healthy spare (see `crate::cluster::Arbiter`). Episodes bound to
    /// that hardware stay with the *old* physical node: active ones revert,
    /// scheduled ones are dropped. The caller charges the pause cost.
    pub fn replace_node_hardware(&mut self, node: usize) {
        let gpn = self.spec.gpus_per_node;
        let mut keep_ev = Vec::with_capacity(self.events.len());
        let mut keep_ap = Vec::with_capacity(self.applied.len());
        for i in 0..self.events.len() {
            let ev = self.events[i];
            let touches = match ev.target {
                Target::Node(n) | Target::Uplink(n) => n == node,
                Target::Gpu(g) => g / gpn == node,
                Target::Link(a, b) => a == node || b == node,
            };
            if touches {
                if self.applied[i] {
                    ev.revert(&mut self.cluster);
                }
            } else {
                keep_ev.push(ev);
                keep_ap.push(self.applied[i]);
            }
        }
        self.events = keep_ev;
        self.applied = keep_ap;
    }

    /// S4 granted *in place* (shared cluster, exhausted pool): pay the
    /// restart cost on the SAME hardware. The pause lets time-bounded
    /// episodes lapse on their own (`update_health` reverts them at the
    /// next step), but unlike [`TrainingSim::restart`] nothing is healed by
    /// fiat — persistent degradation on these nodes survives the restart.
    pub fn restart_in_place(&mut self, cost: Time) {
        self.microbatch_alloc =
            even_alloc(self.spec.wl.microbatches * self.spec.cfg.dp, self.spec.cfg.dp);
        self.now += cost;
    }

    /// S4: checkpoint-and-restart onto healthy hardware: all active
    /// episodes end (the job left the degraded components) and the restart
    /// cost is charged.
    pub fn restart(&mut self, cost: Time) {
        for i in 0..self.events.len() {
            if self.applied[i] {
                self.events[i].revert(&mut self.cluster);
                self.applied[i] = false;
            }
        }
        self.events.clear();
        self.applied.clear();
        self.cluster.heal_all();
        self.microbatch_alloc =
            even_alloc(self.spec.wl.microbatches * self.spec.cfg.dp, self.spec.cfg.dp);
        self.now += cost;
    }
}

/// Evenly split `total` micro-batches across `d` replicas.
pub fn even_alloc(total: usize, d: usize) -> Vec<usize> {
    let base = total / d;
    let extra = total % d;
    (0..d).map(|i| base + usize::from(i < extra)).collect()
}

/// Convenience spec for tests and examples: GPT-2 7B-class job.
pub fn demo_spec(cfg: ParallelConfig, seed: u64) -> JobSpec {
    use crate::pipeline::ModelDims;
    JobSpec {
        cfg,
        wl: Workload { model: ModelDims::gpt2("gpt2-7b"), micro_batch: 1, microbatches: 8 },
        gpus_per_node: 8,
        gpu_class: GpuClass::H800,
        mfu: 0.42,
        jitter: 0.015,
        spike_p: 0.01,
        seed,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::inject::{FailSlowKind, Severity, Target};
    use crate::simkit::{MINUTE, SEC};

    fn sim(cfg: ParallelConfig) -> TrainingSim {
        TrainingSim::new(demo_spec(cfg, 42))
    }

    #[test]
    fn healthy_iterations_stable() {
        let mut s = sim(ParallelConfig::new(2, 4, 1));
        let times: Vec<f64> = (0..50).map(|_| s.step().duration as f64 / SEC as f64).collect();
        let cov = crate::util::stats::cov(&times);
        assert!(cov < 0.05, "healthy cov {cov}");
    }

    #[test]
    fn gpu_degradation_slows_iterations() {
        let mut s = sim(ParallelConfig::new(2, 4, 1));
        let healthy = s.step().duration;
        s.inject(vec![FailSlowEvent {
            kind: FailSlowKind::GpuDegradation,
            target: Target::Gpu(0),
            start: s.now,
            duration: 60 * MINUTE,
            scale: Severity::Medium.scale(),
        }]);
        let slow = s.step().duration;
        assert!(slow as f64 > 1.2 * healthy as f64, "{slow} vs {healthy}");
    }

    #[test]
    fn congestion_slows_inter_node_job_only() {
        // 2-node job, DP rings cross nodes.
        let mut s = sim(ParallelConfig::new(2, 8, 1));
        assert!(s.grid.n_nodes() > 1);
        let healthy = s.step().duration;
        s.inject(vec![FailSlowEvent {
            kind: FailSlowKind::NetworkCongestion,
            target: Target::Uplink(1),
            start: s.now,
            duration: 60 * MINUTE,
            scale: 0.2,
        }]);
        let slow = s.step().duration;
        assert!(slow > healthy, "{slow} vs {healthy}");
    }

    #[test]
    fn events_self_revert() {
        let mut s = sim(ParallelConfig::new(2, 4, 1));
        let healthy = s.step().duration as f64;
        let dur = 30 * SEC;
        s.inject(vec![FailSlowEvent {
            kind: FailSlowKind::GpuDegradation,
            target: Target::Gpu(0),
            start: s.now,
            duration: dur,
            scale: 0.3,
        }]);
        // Step until past the episode.
        let mut slow_seen = false;
        for _ in 0..200 {
            let obs = s.step();
            if (obs.duration as f64) > 1.5 * healthy {
                slow_seen = true;
            }
            if s.now > s.events[0].end() + 5 * SEC {
                break;
            }
        }
        assert!(slow_seen, "episode must slow some iterations");
        let recovered = s.step().duration as f64;
        assert!(recovered < 1.15 * healthy, "{recovered} vs {healthy}");
    }

    #[test]
    fn sm_util_dips_during_fail_slow() {
        let mut s = sim(ParallelConfig::new(2, 4, 1));
        let obs_h = s.step();
        s.inject(vec![FailSlowEvent {
            kind: FailSlowKind::GpuDegradation,
            target: Target::Gpu(1),
            start: s.now,
            duration: 60 * MINUTE,
            scale: 0.4,
        }]);
        let obs_s = s.step();
        assert!(obs_s.sm_util < 0.8 * obs_h.sm_util);
    }

    #[test]
    fn op_log_has_periodic_pattern() {
        let mut s = sim(ParallelConfig::new(2, 2, 2));
        for _ in 0..32 {
            s.step();
        }
        let sig = s.monitor.logs[0].op_kinds();
        // Period = ops per iteration for rank 0.
        let per_iter = sig.len() / 32;
        assert!(per_iter >= 2);
        assert!(crate::util::stats::acf(&sig, per_iter) > 0.9);
    }

    #[test]
    fn microbatch_realloc_rebalances_straggler() {
        let mut s = sim(ParallelConfig::new(1, 4, 1));
        s.inject(vec![FailSlowEvent {
            kind: FailSlowKind::GpuDegradation,
            target: Target::Gpu(0),
            start: 0,
            duration: 600 * MINUTE,
            scale: 0.5,
        }]);
        let slow = s.step().duration;
        // Shift work off the degraded replica 0.
        s.set_microbatch_alloc(vec![4, 9, 9, 10]);
        let fixed = s.step().duration;
        assert!(
            (fixed as f64) < 0.85 * slow as f64,
            "rebalance must help: {fixed} vs {slow}"
        );
    }

    #[test]
    fn profile_flags_congested_dp_group() {
        let mut s = sim(ParallelConfig::new(1, 16, 1)); // 2 nodes, dp rings cross
        s.inject(vec![FailSlowEvent {
            kind: FailSlowKind::NetworkCongestion,
            target: Target::Uplink(1),
            start: 0,
            duration: 600 * MINUTE,
            scale: 0.2,
        }]);
        s.step();
        let profile = s.profile_groups();
        assert!(!profile.is_empty());
        // All DP rings cross the congested uplink here; the mean transfer
        // time must far exceed the healthy nominal.
        let healthy = {
            let mut s2 = sim(ParallelConfig::new(1, 16, 1));
            s2.step();
            s2.profile_groups()[0].mean_time
        };
        assert!(profile[0].mean_time > 2.0 * healthy);
    }

    #[test]
    fn bench_gpu_identifies_slow_device() {
        let mut s = sim(ParallelConfig::new(2, 4, 1));
        s.inject(vec![FailSlowEvent {
            kind: FailSlowKind::GpuDegradation,
            target: Target::Gpu(3),
            start: 0,
            duration: 600 * MINUTE,
            scale: 0.5,
        }]);
        s.step();
        let times: Vec<f64> = (0..8).map(|g| s.bench_gpu(g)).collect();
        let med = crate::util::stats::median(&times);
        assert!(times[3] > 1.5 * med, "{times:?}");
        for (i, t) in times.iter().enumerate() {
            if i != 3 {
                assert!(*t < 1.3 * med);
            }
        }
    }

    #[test]
    fn restart_in_place_keeps_persistent_degradation() {
        let mut s = sim(ParallelConfig::new(1, 4, 1));
        let healthy = s.step().duration as f64;
        s.inject(vec![FailSlowEvent {
            kind: FailSlowKind::GpuDegradation,
            target: Target::Gpu(0),
            start: 0,
            duration: 600 * MINUTE,
            scale: 0.4,
        }]);
        s.set_microbatch_alloc(vec![2, 10, 10, 10]);
        s.restart_in_place(2 * MINUTE);
        // Allocation resets, clock advances, but the hardware is the same:
        // the still-active episode keeps slowing iterations.
        assert_eq!(s.microbatch_alloc, vec![8, 8, 8, 8]);
        assert_eq!(s.events.len(), 1);
        let after = s.step().duration as f64;
        assert!(after > 1.3 * healthy, "{after} vs {healthy}");
    }

    #[test]
    fn restart_heals_everything() {
        let mut s = sim(ParallelConfig::new(2, 4, 1));
        let healthy = s.step().duration as f64;
        s.inject(vec![FailSlowEvent {
            kind: FailSlowKind::GpuDegradation,
            target: Target::Gpu(0),
            start: 0,
            duration: 600 * MINUTE,
            scale: 0.3,
        }]);
        s.step();
        s.restart(2 * MINUTE);
        let after = s.step().duration as f64;
        assert!((after - healthy).abs() / healthy < 0.1, "{after} vs {healthy}");
        assert!(s.events.is_empty());
    }

    #[test]
    fn replace_node_hardware_sheds_its_events_only() {
        let mut s = sim(ParallelConfig::new(2, 8, 1)); // 2 nodes
        assert_eq!(s.grid.n_nodes(), 2);
        s.inject(vec![
            FailSlowEvent {
                kind: FailSlowKind::GpuDegradation,
                target: Target::Gpu(1), // node 0
                start: 0,
                duration: 600 * MINUTE,
                scale: 0.5,
            },
            FailSlowEvent {
                kind: FailSlowKind::CpuContention,
                target: Target::Node(1),
                start: 0,
                duration: 600 * MINUTE,
                scale: 0.5,
            },
        ]);
        s.step(); // both active
        assert!(s.cluster.gpus[1].compute_scale < 1.0);
        s.replace_node_hardware(0);
        assert_eq!(s.cluster.gpus[1].compute_scale, 1.0, "node 0's episode reverted");
        assert_eq!(s.events.len(), 1, "node 1's episode stays");
        assert!(matches!(s.events[0].target, Target::Node(1)));
        assert!(s.cluster.nodes[1].cpu_satisfaction < 1.0);
    }

    #[test]
    fn even_alloc_sums() {
        assert_eq!(even_alloc(32, 4), vec![8, 8, 8, 8]);
        assert_eq!(even_alloc(10, 3), vec![4, 3, 3]);
        assert_eq!(even_alloc(10, 3).iter().sum::<usize>(), 10);
    }

    #[test]
    fn outcome_slowdown_accounting() {
        let mut s = sim(ParallelConfig::new(2, 4, 1));
        s.inject(vec![FailSlowEvent {
            kind: FailSlowKind::GpuDegradation,
            target: Target::Gpu(0),
            start: 0,
            duration: 600 * MINUTE,
            scale: 0.5,
        }]);
        let outcome = s.run(20);
        assert!(outcome.slowdown() > 1.1, "slowdown {}", outcome.slowdown());
    }

    #[test]
    fn estimate_is_deterministic_and_rng_free() {
        let mut s = sim(ParallelConfig::new(2, 4, 2));
        let e1 = s.estimate_iter_time_s();
        let e2 = s.estimate_iter_time_s();
        assert_eq!(e1.to_bits(), e2.to_bits(), "nominal estimate must be stable");
        assert_eq!(e1.to_bits(), s.ideal_iter_s.to_bits(), "healthy estimate == ideal");
        // The estimator must not perturb the measurement stream: a sim that
        // estimated 100 times steps bit-identically to one that never did.
        for _ in 0..100 {
            s.estimate_iter_time_s();
        }
        let mut fresh = sim(ParallelConfig::new(2, 4, 2));
        for _ in 0..5 {
            assert_eq!(s.step().duration, fresh.step().duration);
        }
    }

    #[test]
    fn op_trace_records_hang_evidence_without_touching_the_stream() {
        use crate::diagnose::{classify, AnomalyClass};
        let ev = FailSlowEvent {
            kind: FailSlowKind::CommHang,
            target: Target::Link(0, 1),
            start: 5 * SEC,
            duration: 60 * MINUTE,
            scale: 1.0,
        };
        let mut traced = sim(ParallelConfig::new(2, 8, 1)); // 2 nodes, rings cross
        let mut untraced = sim(ParallelConfig::new(2, 8, 1));
        untraced.op_trace.enabled = false;
        traced.inject(vec![ev]);
        untraced.inject(vec![ev]);
        for i in 0..40 {
            let a = traced.step();
            let b = untraced.step();
            assert_eq!(a.duration, b.duration, "iter {i}: tracing must be invisible");
        }
        assert_eq!(traced.op_trace.len(), 40);
        assert_eq!(untraced.op_trace.len(), 0);
        // The healthy prefix reads exactly 1.0 against the pristine twin —
        // bitwise, not approximately: identical arithmetic on identical
        // health produces identical floats.
        let first = traced.op_trace.entries().next().expect("trace populated");
        assert_eq!(first.compute.ratio.to_bits(), 1.0f64.to_bits());
        for r in &first.rings {
            assert_eq!(r.worst_ratio.to_bits(), 1.0f64.to_bits());
            assert!(r.blocked.is_empty() && r.slow.is_empty());
        }
        // Once the hang lands, the wedged node pair shows as blocked and
        // the window classifies as a pure comm-hang on that path.
        let blocked: Vec<(usize, usize)> = traced
            .op_trace
            .entries()
            .flat_map(|e| e.rings.iter().flat_map(|r| r.blocked.iter().copied()))
            .collect();
        assert!(blocked.contains(&(0, 1)), "hung pair recorded: {blocked:?}");
        let c = classify(&traced.op_trace).expect("hang evidence classifies");
        assert_eq!(c.class, AnomalyClass::CommHang);
        assert_eq!(c.culprit.label(), "link:0-1");
    }

    #[test]
    fn forced_invalidation_is_bit_identical() {
        // Recomputing every memo from scratch each step must reproduce the
        // cached engine exactly — health changes mid-run included.
        let ev = FailSlowEvent {
            kind: FailSlowKind::GpuDegradation,
            target: Target::Gpu(2),
            start: 5 * SEC,
            duration: 2 * MINUTE,
            scale: 0.5,
        };
        let mut cached = sim(ParallelConfig::new(2, 4, 2));
        let mut uncached = sim(ParallelConfig::new(2, 4, 2));
        cached.inject(vec![ev]);
        uncached.inject(vec![ev]);
        for i in 0..40 {
            uncached.invalidate_caches();
            let a = cached.step();
            let b = uncached.step();
            assert_eq!(a.duration, b.duration, "iter {i}");
            assert_eq!(a.dp_time.to_bits(), b.dp_time.to_bits(), "iter {i}");
            for (x, y) in a.replica_makespan.iter().zip(&b.replica_makespan) {
                assert_eq!(x.to_bits(), y.to_bits(), "iter {i}");
            }
        }
    }

    #[test]
    fn replan_apply_revert_stays_cache_coherent() {
        // S5 hits the two memo invalidation paths at once: its swaps bump
        // `RankGrid::generation` (full rebind) and its re-split moves each
        // replica's `m` (per-entry recompute). A twin forced to recompute
        // every memo from scratch must step bit-identically through plan,
        // apply, and revert — and revert must land on the nominal layout.
        use crate::mitigate::replan;
        let ev = FailSlowEvent {
            kind: FailSlowKind::NetworkCongestion,
            target: Target::Link(0, 1),
            start: 0,
            duration: 600 * MINUTE,
            scale: 0.15,
        };
        let mut spec = demo_spec(ParallelConfig::new(8, 2, 2), 71);
        spec.jitter = 0.0;
        spec.spike_p = 0.0;
        let mut cached = TrainingSim::new(spec.clone());
        let mut naive = TrainingSim::new(spec);
        cached.inject(vec![ev]);
        naive.inject(vec![ev]);
        fn lockstep(a: &mut TrainingSim, b: &mut TrainingSim, label: &str) {
            for i in 0..10 {
                b.invalidate_caches();
                let x = a.step();
                let y = b.step();
                assert_eq!(x.duration, y.duration, "{label} iter {i}");
                for (p, q) in x.replica_makespan.iter().zip(&y.replica_makespan) {
                    assert_eq!(p.to_bits(), q.to_bits(), "{label} iter {i}");
                }
            }
        }
        lockstep(&mut cached, &mut naive, "congested");
        let plan = replan::plan(&mut cached, 2);
        assert!(plan.is_worthwhile(), "congested layout leaves headroom");
        replan::apply(&mut cached, &plan, 30 * SEC);
        replan::apply(&mut naive, &plan, 30 * SEC);
        lockstep(&mut cached, &mut naive, "replanned");
        replan::revert(&mut cached, &plan);
        replan::revert(&mut naive, &plan);
        lockstep(&mut cached, &mut naive, "reverted");
        let nominal = TrainingSim::new(demo_spec(ParallelConfig::new(8, 2, 2), 71));
        assert_eq!(cached.grid.node_map, nominal.grid.node_map);
        assert_eq!(cached.microbatch_alloc, nominal.microbatch_alloc);
    }
}

#[cfg(test)]
mod equivalence {
    //! The incremental engine's correctness bar: cached vs naive recompute
    //! must be bit-identical — every scenario-library entry's
    //! `Outcome::to_json` and a shared-cluster fleet's
    //! `FleetReport::digest`.

    use std::sync::atomic::Ordering;
    use std::sync::Mutex;

    use super::NAIVE_RECOMPUTE;

    /// Serializes the tests that flip the global naive switch, so each
    /// run is pure cached or pure naive (an interleaved run would still be
    /// bit-identical, but would weaken what the test demonstrates).
    static MODE: Mutex<()> = Mutex::new(());

    fn run_scenario(spec: &crate::scenario::ScenarioSpec, naive: bool) -> String {
        NAIVE_RECOMPUTE.store(naive, Ordering::SeqCst);
        let out = spec.run().expect("library scenario runs");
        NAIVE_RECOMPUTE.store(false, Ordering::SeqCst);
        out.to_json().to_string()
    }

    #[test]
    fn cached_engine_matches_naive_across_scenario_library() {
        let _guard = MODE.lock().unwrap_or_else(|e| e.into_inner());
        for mut spec in crate::scenario::library::all() {
            // Shorter horizons keep the sweep fast; equivalence is checked
            // iteration by iteration, so any prefix is just as binding.
            let cap = if spec.fleet.is_some() { 30 } else { 120 };
            spec.run.iters = spec.run.iters.min(cap);
            let cached = run_scenario(&spec, false);
            let naive = run_scenario(&spec, true);
            assert_eq!(cached, naive, "scenario '{}' diverged", spec.name);
        }
    }

    #[test]
    fn cached_fleet_digest_matches_naive_recompute() {
        use crate::cluster::Policy;
        use crate::fleet::{run_fleet, FleetConfig};
        let _guard = MODE.lock().unwrap_or_else(|e| e.into_inner());
        let cfg = FleetConfig {
            jobs: 4,
            iters: 40,
            seed: 9,
            workers: 2,
            failslow_boost: 20.0,
            compare: false,
            policy: Some(Policy::StragglerAware),
            spare_frac: 0.25,
            epoch_len: 10,
            ..FleetConfig::default()
        };
        NAIVE_RECOMPUTE.store(false, Ordering::SeqCst);
        let cached = run_fleet(&cfg).digest();
        NAIVE_RECOMPUTE.store(true, Ordering::SeqCst);
        let naive = run_fleet(&cfg).digest();
        NAIVE_RECOMPUTE.store(false, Ordering::SeqCst);
        assert_eq!(cached, naive, "cached vs naive shared-cluster digest");
    }
}
