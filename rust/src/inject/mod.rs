//! Fail-slow injection substrate.
//!
//! Reproduces the paper's two injection mechanisms (§7.1) and its measured
//! fail-slow phenomenology (§3): GPU frequency locking -> `GpuDegradation`
//! (compute-rate scale), side-channel traffic -> `NetworkCongestion`
//! (uplink bandwidth scale), plus `CpuContention` for the §3.2 cases.
//! Durations/severities are drawn from distributions fit to Figure 1 and
//! Table 1 so the characterization campaign reproduces the paper's rates.

use crate::fabric::Cluster;
use crate::simkit::{Time, MINUTE, SEC};
use crate::util::rng::Rng;

/// Root cause taxonomy (Table 1).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FailSlowKind {
    CpuContention,
    GpuDegradation,
    NetworkCongestion,
    /// A *hang*, not a slowdown: the targeted inter-node path blocks and
    /// collectives crossing it stall at the watchdog timeout
    /// ([`crate::collectives::HANG_WATCHDOG_S`]) instead of stretching.
    /// "Permanent" vs "until-epoch" is expressed through the event's
    /// `duration` (>= remaining horizon = permanent); the `scale` field is
    /// carried but semantically unused (hangs have no residual rate).
    CommHang,
}

impl FailSlowKind {
    pub fn name(self) -> &'static str {
        match self {
            FailSlowKind::CpuContention => "CPU Contention",
            FailSlowKind::GpuDegradation => "GPU Degradation",
            FailSlowKind::NetworkCongestion => "Network Congestion",
            FailSlowKind::CommHang => "Communication Hang",
        }
    }

    pub fn is_compute(self) -> bool {
        !matches!(self, FailSlowKind::NetworkCongestion | FailSlowKind::CommHang)
    }
}

/// Severity presets used throughout §7.3 (weak/medium/severe).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Severity {
    Weak,
    Medium,
    Severe,
}

impl Severity {
    /// Residual performance scale of the degraded component.
    pub fn scale(self) -> f64 {
        match self {
            Severity::Weak => 0.8,
            Severity::Medium => 0.5,
            Severity::Severe => 0.25,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Severity::Weak => "W",
            Severity::Medium => "M",
            Severity::Severe => "S",
        }
    }

    pub const ALL: [Severity; 3] = [Severity::Weak, Severity::Medium, Severity::Severe];
}

/// Which component is degraded.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Target {
    /// Flat GPU index within the job's cluster.
    Gpu(usize),
    /// Node index (CPU contention affects every rank on the node).
    Node(usize),
    /// Node uplink index (congestion at a leaf port: slows every inter-node
    /// path touching the node).
    Uplink(usize),
    /// Specific inter-node path (congestion on one spine-leaf route: the
    /// granularity of Fig 10's "congested link between nodes 3 and 4").
    Link(usize, usize),
}

/// One injected fail-slow episode.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FailSlowEvent {
    pub kind: FailSlowKind,
    pub target: Target,
    pub start: Time,
    pub duration: Time,
    /// Residual performance scale in (0, 1]; lower = more severe.
    pub scale: f64,
}

impl FailSlowEvent {
    pub fn end(&self) -> Time {
        self.start.saturating_add(self.duration)
    }

    pub fn active_at(&self, t: Time) -> bool {
        t >= self.start && t < self.end()
    }

    /// Apply onset to the cluster. Routed through the health setters so the
    /// cluster's per-node generations (and every cache stamped against
    /// them) see the change.
    pub fn apply(&self, cluster: &mut Cluster) {
        match (self.kind, self.target) {
            (FailSlowKind::GpuDegradation, Target::Gpu(flat)) => {
                // 70 C: the thermal-throttling signature (Fig 3's
                // bottom-right).
                cluster.set_gpu_health(flat, self.scale, 70.0);
            }
            (FailSlowKind::CpuContention, Target::Node(n)) => {
                cluster.set_cpu_health(n, self.scale, ((1.0 - self.scale) * 20.0) as u32);
            }
            (FailSlowKind::NetworkCongestion, Target::Uplink(u)) => {
                cluster.set_uplink_scale(u, self.scale);
            }
            (FailSlowKind::NetworkCongestion, Target::Link(a, b)) => {
                cluster.set_pair_scale(a, b, self.scale);
            }
            (FailSlowKind::CommHang, Target::Link(a, b)) => {
                cluster.set_path_hang(a, b, true);
            }
            (FailSlowKind::CommHang, Target::Uplink(u)) => {
                // Degenerate (u, u) key: wedge every path touching node u.
                cluster.set_path_hang(u, u, true);
            }
            // audit:allow(panic-budget): kind/target pairs are validated
            // when the fault script is parsed; a mismatch here is a bug in
            // event construction, not recoverable state.
            (k, t) => panic!("mismatched injection {k:?} on {t:?}"),
        }
    }

    /// Revert (episode ends / transient self-recovers).
    pub fn revert(&self, cluster: &mut Cluster) {
        match (self.kind, self.target) {
            (FailSlowKind::GpuDegradation, Target::Gpu(flat)) => {
                cluster.set_gpu_health(flat, 1.0, 45.0);
            }
            (FailSlowKind::CpuContention, Target::Node(n)) => {
                cluster.set_cpu_health(n, 1.0, 0);
            }
            (FailSlowKind::NetworkCongestion, Target::Uplink(u)) => {
                cluster.set_uplink_scale(u, 1.0);
            }
            (FailSlowKind::NetworkCongestion, Target::Link(a, b)) => {
                cluster.set_pair_scale(a, b, 1.0);
            }
            (FailSlowKind::CommHang, Target::Link(a, b)) => {
                cluster.set_path_hang(a, b, false);
            }
            (FailSlowKind::CommHang, Target::Uplink(u)) => {
                cluster.set_path_hang(u, u, false);
            }
            // audit:allow(panic-budget): revert sees exactly the pairs
            // apply accepted; any other combination cannot be constructed.
            _ => unreachable!(),
        }
    }
}

/// Campaign-level generator reproducing §3's occurrence statistics.
///
/// Occurrence probabilities are per job; durations are lognormal with the
/// paper's means (10 min computation, 24 min communication at small scale,
/// 72 min at >=512-GPU scale — Fig 1 right).
#[derive(Clone, Debug)]
pub struct InjectionModel {
    /// P[a given job sees CPU contention] at single-node scale (4/392).
    pub p_cpu_1node: f64,
    /// P[GPU degradation] at single-node scale (2/392).
    pub p_gpu_1node: f64,
    /// P[network congestion per inter-node link per job] calibrated so a
    /// 4-node job sees congestion with probability ~42/107.
    pub p_congestion_per_link: f64,
    pub mean_comp_duration: Time,
    pub mean_comm_duration: Time,
}

impl Default for InjectionModel {
    fn default() -> Self {
        InjectionModel {
            p_cpu_1node: 4.0 / 392.0,
            p_gpu_1node: 2.0 / 392.0,
            // 1 - (1-p)^4 = 42/107  =>  p ≈ 0.115 per node-uplink.
            p_congestion_per_link: 0.115,
            mean_comp_duration: 10 * MINUTE,
            mean_comm_duration: 24 * MINUTE,
        }
    }
}

impl InjectionModel {
    /// Sample the fail-slow episodes one job experiences.
    ///
    /// `nodes`/`gpus` describe the job's footprint; `job_duration` bounds
    /// episode starts. Multi-node jobs can accumulate several episodes
    /// (§3.4's compounding at scale).
    pub fn sample_job(
        &self,
        nodes: usize,
        gpus_per_node: usize,
        job_duration: Time,
        rng: &mut Rng,
    ) -> Vec<FailSlowEvent> {
        let mut out = Vec::new();
        let dur_sigma_frac = 0.8; // heavy tail: CDF spans seconds..hours (Fig 1)

        for node in 0..nodes {
            if rng.bernoulli(self.p_cpu_1node) {
                out.push(self.event(
                    FailSlowKind::CpuContention,
                    Target::Node(node),
                    self.mean_comp_duration,
                    dur_sigma_frac,
                    job_duration,
                    rng.range_f64(0.3, 0.7),
                    rng,
                ));
            }
            for g in 0..gpus_per_node {
                if rng.bernoulli(self.p_gpu_1node / gpus_per_node as f64) {
                    out.push(self.event(
                        FailSlowKind::GpuDegradation,
                        Target::Gpu(node * gpus_per_node + g),
                        self.mean_comp_duration,
                        dur_sigma_frac,
                        job_duration,
                        rng.range_f64(0.6, 0.85),
                        rng,
                    ));
                }
            }
            // Congestion only matters when the job spans nodes.
            if nodes > 1 && rng.bernoulli(self.p_congestion_per_link) {
                out.push(self.event(
                    FailSlowKind::NetworkCongestion,
                    Target::Uplink(node),
                    self.mean_comm_duration,
                    dur_sigma_frac,
                    job_duration,
                    rng.range_f64(0.2, 0.6),
                    rng,
                ));
            }
        }
        out.sort_by_key(|e| e.start);
        out
    }

    #[allow(clippy::too_many_arguments)]
    fn event(
        &self,
        kind: FailSlowKind,
        target: Target,
        mean_dur: Time,
        sigma_frac: f64,
        job_duration: Time,
        scale: f64,
        rng: &mut Rng,
    ) -> FailSlowEvent {
        let mean = mean_dur as f64 / SEC as f64;
        let dur_s = rng.lognormal_mean_std(mean, sigma_frac * mean).max(20.0);
        let start = rng.below(job_duration.max(1)) as Time;
        FailSlowEvent {
            kind,
            target,
            start,
            duration: (dur_s * SEC as f64) as Time,
            scale,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::{ClusterSpec, GpuClass};
    use crate::simkit::HOUR;

    #[test]
    fn apply_revert_round_trip() {
        let mut c = Cluster::new(ClusterSpec::new(2, 4, GpuClass::H800));
        let ev = FailSlowEvent {
            kind: FailSlowKind::NetworkCongestion,
            target: Target::Uplink(1),
            start: 0,
            duration: MINUTE,
            scale: 0.3,
        };
        ev.apply(&mut c);
        assert_eq!(c.uplinks[1].bandwidth_scale, 0.3);
        ev.revert(&mut c);
        assert_eq!(c.uplinks[1].bandwidth_scale, 1.0);
    }

    #[test]
    fn gpu_injection_sets_thermal_signature() {
        let mut c = Cluster::new(ClusterSpec::new(1, 4, GpuClass::H800));
        let ev = FailSlowEvent {
            kind: FailSlowKind::GpuDegradation,
            target: Target::Gpu(2),
            start: 0,
            duration: MINUTE,
            scale: 0.8,
        };
        ev.apply(&mut c);
        assert!(c.gpus[2].temp_c > 65.0);
        assert_eq!(c.gpus[2].compute_scale, 0.8);
    }

    #[test]
    fn hang_apply_revert_round_trip() {
        let mut c = Cluster::new(ClusterSpec::new(4, 2, GpuClass::H800));
        let link = FailSlowEvent {
            kind: FailSlowKind::CommHang,
            target: Target::Link(0, 2),
            start: 0,
            duration: MINUTE,
            scale: 1.0,
        };
        link.apply(&mut c);
        assert!(c.hung_paths.contains(&(0, 2)));
        link.revert(&mut c);
        assert!(c.hung_paths.is_empty());
        let uplink = FailSlowEvent { target: Target::Uplink(3), ..link };
        uplink.apply(&mut c);
        assert!(c.hung_paths.contains(&(3, 3)), "uplink hang uses the degenerate key");
        uplink.revert(&mut c);
        assert!(c.hung_paths.is_empty());
        assert!(!FailSlowKind::CommHang.is_compute());
    }

    #[test]
    fn active_window() {
        let ev = FailSlowEvent {
            kind: FailSlowKind::CpuContention,
            target: Target::Node(0),
            start: 10 * SEC,
            duration: 5 * SEC,
            scale: 0.5,
        };
        assert!(!ev.active_at(9 * SEC));
        assert!(ev.active_at(10 * SEC));
        assert!(ev.active_at(14 * SEC));
        assert!(!ev.active_at(15 * SEC));
    }

    #[test]
    fn campaign_rates_match_table1_single_node() {
        // 392 single-node jobs -> expect ~4 CPU + ~2 GPU episodes.
        let model = InjectionModel::default();
        let mut rng = Rng::new(2024);
        let mut cpu = 0;
        let mut gpu = 0;
        let mut net = 0;
        for _ in 0..392 {
            for ev in model.sample_job(1, 4, HOUR, &mut rng) {
                match ev.kind {
                    FailSlowKind::CpuContention => cpu += 1,
                    FailSlowKind::GpuDegradation => gpu += 1,
                    FailSlowKind::NetworkCongestion => net += 1,
                    FailSlowKind::CommHang => panic!("campaign never samples hangs"),
                }
            }
        }
        assert_eq!(net, 0, "single-node jobs see no congestion");
        assert!((1..=10).contains(&cpu), "cpu {cpu}");
        assert!(gpu <= 7, "gpu {gpu}");
    }

    #[test]
    fn campaign_rates_match_table1_four_node() {
        // 107 4-node jobs -> ~40% see congestion.
        let model = InjectionModel::default();
        let mut rng = Rng::new(7);
        let mut jobs_with_congestion = 0;
        for _ in 0..107 {
            let evs = model.sample_job(4, 2, 5 * HOUR, &mut rng);
            if evs.iter().any(|e| e.kind == FailSlowKind::NetworkCongestion) {
                jobs_with_congestion += 1;
            }
        }
        let frac = jobs_with_congestion as f64 / 107.0;
        assert!((0.25..=0.55).contains(&frac), "congestion frac {frac}");
    }

    #[test]
    fn durations_heavy_tailed() {
        let model = InjectionModel::default();
        let mut rng = Rng::new(99);
        let mut durs = Vec::new();
        for _ in 0..4000 {
            for ev in model.sample_job(4, 2, 5 * HOUR, &mut rng) {
                durs.push(ev.duration as f64 / MINUTE as f64);
            }
        }
        assert!(durs.len() > 500);
        let p10 = crate::util::stats::quantile(&durs, 0.1);
        let p95 = crate::util::stats::quantile(&durs, 0.95);
        // Fig 1 right: spans sub-minute to hours.
        assert!(p10 < 10.0, "p10 {p10}");
        assert!(p95 > 45.0, "p95 {p95}");
    }
}
