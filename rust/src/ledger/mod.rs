//! Fleet-wide node-health ledger (Guard, arxiv 2605.17879).
//!
//! FALCON's shared cluster treats nodes as memoryless: a degraded node
//! quarantines for a fixed 4 epochs and re-enters the pool as if nothing
//! happened, even though the paper's §2 characterization (and the
//! homogeneous-GPU recurrence study, arxiv 2512.09685) shows fail-slows
//! recur on the *same* hardware for hours with heavy-tailed intervals.
//! This module gives every shared node a persistent health history that
//! outlives individual jobs and accrues across fleet epochs:
//!
//! - [`NodeLedger`] — per-node incident records (fault kind from the
//!   diagnosis taxonomy, duration, recurrence gap), a blame account fed
//!   from the what-if contention attribution, and an exponentially
//!   decaying health score: every incident multiplies the score by
//!   `1 - penalty`, every clean epoch recovers it toward 1.0 by
//!   `recovery * (1 - score)`.
//! - **Predictive quarantine** — [`NodeLedger::quarantine_epochs`]
//!   replaces the fixed `QUARANTINE_EPOCHS` with a score-driven duration:
//!   repeat offenders (≥ 2 recorded incidents) quarantine for
//!   `floor + round((1 - score) * scale)` epochs (capped), clean and
//!   first-time nodes keep the 4-epoch floor. With `predictive` off the
//!   ledger is a pure shadow observer and always answers the floor, so
//!   memoryless behavior is bit-identical.
//! - **Snapshot persistence** — [`NodeLedger::to_json`] /
//!   [`NodeLedger::parse`] round-trip the full ledger through the house
//!   JSON substrate so a campaign can seed from a prior campaign's ledger
//!   (`--ledger-file`).
//!
//! Determinism contract: the ledger draws no RNG, stores nodes in a
//! `BTreeMap`, and is only ever updated from the fleet's *serial* epoch
//! boundary passes in job-id order, so `FleetReport::digest` stays
//! bit-identical across worker counts (`falcon-audit` pins this module
//! into the digest-determinism scope with a panic budget of 0).

use std::collections::BTreeMap;

use crate::diagnose::AnomalyClass;
use crate::util::json::Json;

/// Minimum quarantine duration in fleet epochs — identical to the
/// memoryless `cluster::QUARANTINE_EPOCHS` so clean nodes behave exactly
/// as they did before the ledger existed.
pub const FLOOR_EPOCHS: usize = 4;

/// Upper bound on a predictive quarantine, no matter how low the score.
pub const MAX_EPOCHS: usize = 32;

/// Scale factor from health deficit to extra quarantine epochs:
/// `extra = round((1 - score) * QUARANTINE_SCALE)`.
pub const QUARANTINE_SCALE: f64 = 16.0;

/// Per-epoch recovery rate toward 1.0 for nodes with no open incident.
pub const RECOVERY_RATE: f64 = 0.02;

/// Multiplicative score penalty applied when an incident opens.
pub const INCIDENT_PENALTY: f64 = 0.35;

/// Tunable decay-model constants. The defaults above are what every
/// fleet run uses; the struct exists so the bench and tests can probe
/// the formulas without re-deriving them.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LedgerConfig {
    /// Quarantine floor in epochs (memoryless behavior).
    pub floor_epochs: usize,
    /// Predictive quarantine cap in epochs.
    pub max_epochs: usize,
    /// Health-deficit → extra-epochs scale.
    pub quarantine_scale: f64,
    /// Per-clean-epoch recovery rate toward 1.0.
    pub recovery: f64,
    /// Multiplicative penalty per incident.
    pub penalty: f64,
}

impl Default for LedgerConfig {
    fn default() -> Self {
        LedgerConfig {
            floor_epochs: FLOOR_EPOCHS,
            max_epochs: MAX_EPOCHS,
            quarantine_scale: QUARANTINE_SCALE,
            recovery: RECOVERY_RATE,
            penalty: INCIDENT_PENALTY,
        }
    }
}

/// One closed incident on a node: when it opened, what the diagnosis
/// taxonomy called it, how long it lasted, and how long after the
/// previous incident it recurred (`None` for a node's first incident).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Incident {
    /// Fleet epoch the incident opened.
    pub epoch: usize,
    /// Fault kind from the hang-vs-slow taxonomy.
    pub kind: AnomalyClass,
    /// Epochs from open to release (≥ 1).
    pub duration_epochs: usize,
    /// Epochs since the previous incident opened; `None` for the first.
    pub gap_epochs: Option<usize>,
}

/// Per-node health state: decaying score, closed incident history, the
/// currently open incident (if any), and the contention-blame account.
#[derive(Clone, Debug, PartialEq)]
pub struct NodeHealth {
    /// Exponentially decaying health in (0, 1]; 1.0 is pristine.
    pub score: f64,
    /// Closed incidents, oldest first.
    pub incidents: Vec<Incident>,
    /// Epoch of the currently open incident, if one is open.
    pub open_since: Option<usize>,
    /// Fault kind of the currently open incident.
    pub open_kind: Option<AnomalyClass>,
    /// Open epoch of the most recent incident (open or closed).
    pub last_incident_epoch: Option<usize>,
    /// Seconds of victim time the what-if attribution blames on jobs
    /// placed on this node (fed by `whatif::attribution::ledger_blame`).
    pub blame_s: f64,
    /// Incidents that opened on a node with ≥ 1 prior *closed* incident
    /// — the repeat-offender count the ledger report pins.
    pub repeats: u32,
}

impl NodeHealth {
    fn pristine() -> Self {
        NodeHealth {
            score: 1.0,
            incidents: Vec::new(),
            open_since: None,
            open_kind: None,
            last_incident_epoch: None,
            blame_s: 0.0,
            repeats: 0,
        }
    }

    /// Mean recurrence gap over closed incidents, if ≥ 1 gap is recorded.
    fn mean_gap(&self) -> Option<f64> {
        let gaps: Vec<f64> = self
            .incidents
            .iter()
            .filter_map(|i| i.gap_epochs.map(|g| g as f64))
            .collect();
        if gaps.is_empty() {
            None
        } else {
            Some(gaps.iter().sum::<f64>() / gaps.len() as f64)
        }
    }
}

/// The fleet-wide ledger: node id → health, plus the clock and mode.
#[derive(Clone, Debug, PartialEq)]
pub struct NodeLedger {
    /// Decay-model constants.
    pub cfg: LedgerConfig,
    /// Per-node health, keyed by shared-pool node id (BTree for
    /// deterministic iteration — audit-pinned).
    pub nodes: BTreeMap<usize, NodeHealth>,
    /// Last fleet epoch the ledger advanced to.
    pub epoch: usize,
    /// When false the ledger is a shadow observer: it records incidents
    /// but `quarantine_epochs` always answers the memoryless floor and
    /// no admission is denied.
    pub predictive: bool,
}

impl Default for NodeLedger {
    fn default() -> Self {
        NodeLedger::new(LedgerConfig::default())
    }
}

impl NodeLedger {
    pub fn new(cfg: LedgerConfig) -> Self {
        NodeLedger { cfg, nodes: BTreeMap::new(), epoch: 0, predictive: false }
    }

    /// Number of nodes with any recorded history.
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Advance the fleet clock one boundary: every node *without* an open
    /// incident recovers toward 1.0. Called once per epoch boundary from
    /// the serial pass.
    pub fn advance_epoch(&mut self, epoch: usize) {
        self.epoch = epoch;
        for health in self.nodes.values_mut() {
            if health.open_since.is_none() {
                health.score += (1.0 - health.score) * self.cfg.recovery;
                health.score = health.score.min(1.0);
            }
        }
    }

    /// A node transitioned healthy → flagged: open an incident and take
    /// the score penalty. Idempotent while the incident stays open.
    pub fn record_flag(&mut self, node: usize, epoch: usize, kind: AnomalyClass) {
        let health = self.nodes.entry(node).or_insert_with(NodeHealth::pristine);
        if health.open_since.is_some() {
            return;
        }
        if !health.incidents.is_empty() {
            health.repeats += 1;
        }
        health.open_since = Some(epoch);
        health.open_kind = Some(kind);
        health.score *= 1.0 - self.cfg.penalty;
    }

    /// A node transitioned flagged → healthy (flare ended or hardware
    /// replaced): close the open incident, recording duration and the
    /// recurrence gap since the previous incident's open epoch.
    pub fn record_release(&mut self, node: usize, epoch: usize) {
        let health = match self.nodes.get_mut(&node) {
            Some(h) => h,
            None => return,
        };
        let start = match health.open_since.take() {
            Some(s) => s,
            None => return,
        };
        let kind = health.open_kind.take().unwrap_or(AnomalyClass::ComputeSlow);
        let gap = health.last_incident_epoch.map(|prev| start.saturating_sub(prev));
        health.incidents.push(Incident {
            epoch: start,
            kind,
            duration_epochs: epoch.saturating_sub(start).max(1),
            gap_epochs: gap,
        });
        health.last_incident_epoch = Some(start);
    }

    /// Credit contention blame (victim-seconds) to a node.
    pub fn add_blame(&mut self, node: usize, lost_s: f64) {
        let health = self.nodes.entry(node).or_insert_with(NodeHealth::pristine);
        health.blame_s += lost_s;
    }

    /// Current health score; nodes with no history are pristine (1.0).
    pub fn score(&self, node: usize) -> f64 {
        self.nodes.get(&node).map_or(1.0, |h| h.score)
    }

    /// Quarantine duration for a node being released while flagged.
    ///
    /// Memoryless mode (`predictive == false`), clean nodes, and
    /// first-time offenders all get the floor (the old fixed 4 epochs).
    /// Repeat offenders (≥ 2 recorded incidents, open or closed) get
    /// `floor + round((1 - score) * scale)`, capped at `max_epochs` —
    /// short recurrence intervals keep the score low (recovery never
    /// catches up), so fast repeaters quarantine longest.
    pub fn quarantine_epochs(&self, node: usize) -> usize {
        if !self.predictive {
            return self.cfg.floor_epochs;
        }
        let health = match self.nodes.get(&node) {
            Some(h) => h,
            None => return self.cfg.floor_epochs,
        };
        let total = health.incidents.len() + usize::from(health.open_since.is_some());
        if total < 2 {
            return self.cfg.floor_epochs;
        }
        let extra = ((1.0 - health.score) * self.cfg.quarantine_scale).round();
        let extra = if extra.is_finite() && extra > 0.0 { extra as usize } else { 0 };
        (self.cfg.floor_epochs + extra).min(self.cfg.max_epochs)
    }

    /// Predicted open epoch of the node's *next* incident: the last
    /// incident's open epoch plus the mean recurrence gap. `None` until
    /// the node has recorded at least one gap (two incidents).
    pub fn predicted_next_incident(&self, node: usize) -> Option<usize> {
        let health = self.nodes.get(&node)?;
        let last = health.last_incident_epoch?;
        let gap = health.mean_gap()?;
        Some(last + gap.round().max(1.0) as usize)
    }

    /// Total closed + open incidents across the fleet.
    pub fn total_incidents(&self) -> usize {
        self.nodes
            .values()
            .map(|h| h.incidents.len() + usize::from(h.open_since.is_some()))
            .sum()
    }

    /// Fleet-wide repeat-offender incident count (the report metric).
    pub fn repeat_incidents(&self) -> u32 {
        self.nodes.values().map(|h| h.repeats).sum()
    }

    // -- persistence -------------------------------------------------------

    /// Serializable snapshot in the house JSON substrate. BTree iteration
    /// order makes the output deterministic; `parse` round-trips it
    /// bit-identically (pinned below).
    pub fn to_json(&self) -> Json {
        let nodes: Vec<Json> = self
            .nodes
            .iter()
            .map(|(&node, h)| {
                let incidents: Vec<Json> = h
                    .incidents
                    .iter()
                    .map(|i| {
                        Json::obj(vec![
                            ("epoch", Json::Num(i.epoch as f64)),
                            ("kind", Json::str(i.kind.token())),
                            ("duration_epochs", Json::Num(i.duration_epochs as f64)),
                            (
                                "gap_epochs",
                                i.gap_epochs.map_or(Json::Null, |g| Json::Num(g as f64)),
                            ),
                        ])
                    })
                    .collect();
                Json::obj(vec![
                    ("node", Json::Num(node as f64)),
                    ("score", Json::Num(h.score)),
                    ("incidents", Json::Arr(incidents)),
                    (
                        "open_since",
                        h.open_since.map_or(Json::Null, |e| Json::Num(e as f64)),
                    ),
                    (
                        "open_kind",
                        h.open_kind.map_or(Json::Null, |k| Json::str(k.token())),
                    ),
                    (
                        "last_incident_epoch",
                        h.last_incident_epoch.map_or(Json::Null, |e| Json::Num(e as f64)),
                    ),
                    ("blame_s", Json::Num(h.blame_s)),
                    ("repeats", Json::Num(h.repeats as f64)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("epoch", Json::Num(self.epoch as f64)),
            ("predictive", Json::Bool(self.predictive)),
            (
                "config",
                Json::obj(vec![
                    ("floor_epochs", Json::Num(self.cfg.floor_epochs as f64)),
                    ("max_epochs", Json::Num(self.cfg.max_epochs as f64)),
                    ("quarantine_scale", Json::Num(self.cfg.quarantine_scale)),
                    ("recovery", Json::Num(self.cfg.recovery)),
                    ("penalty", Json::Num(self.cfg.penalty)),
                ]),
            ),
            ("nodes", Json::Arr(nodes)),
        ])
    }

    /// Parse a snapshot produced by [`to_json`]. Errors name the missing
    /// or malformed field so a corrupt `--ledger-file` fails loudly.
    pub fn parse(s: &str) -> Result<NodeLedger, String> {
        let doc = Json::parse(s).map_err(|e| format!("ledger snapshot: {e}"))?;
        let num = |j: &Json, key: &str| -> Result<f64, String> {
            j.get(key)
                .and_then(Json::as_f64)
                .ok_or_else(|| format!("ledger snapshot: missing number '{key}'"))
        };
        let opt_num = |j: &Json, key: &str| -> Option<usize> {
            j.get(key).and_then(Json::as_f64).map(|n| n as usize)
        };
        let cfg_doc = doc
            .get("config")
            .ok_or_else(|| "ledger snapshot: missing 'config'".to_string())?;
        let cfg = LedgerConfig {
            floor_epochs: num(cfg_doc, "floor_epochs")? as usize,
            max_epochs: num(cfg_doc, "max_epochs")? as usize,
            quarantine_scale: num(cfg_doc, "quarantine_scale")?,
            recovery: num(cfg_doc, "recovery")?,
            penalty: num(cfg_doc, "penalty")?,
        };
        let mut ledger = NodeLedger::new(cfg);
        ledger.epoch = num(&doc, "epoch")? as usize;
        ledger.predictive = doc
            .get("predictive")
            .and_then(Json::as_bool)
            .ok_or_else(|| "ledger snapshot: missing bool 'predictive'".to_string())?;
        let nodes = doc
            .get("nodes")
            .and_then(Json::as_arr)
            .ok_or_else(|| "ledger snapshot: missing array 'nodes'".to_string())?;
        for entry in nodes {
            let node = num(entry, "node")? as usize;
            let mut health = NodeHealth::pristine();
            health.score = num(entry, "score")?;
            health.blame_s = num(entry, "blame_s")?;
            health.repeats = num(entry, "repeats")? as u32;
            health.open_since = opt_num(entry, "open_since");
            health.open_kind = match entry.get("open_kind").and_then(Json::as_str) {
                Some(tok) => Some(parse_kind(tok)?),
                None => None,
            };
            health.last_incident_epoch = opt_num(entry, "last_incident_epoch");
            let incidents = entry
                .get("incidents")
                .and_then(Json::as_arr)
                .ok_or_else(|| "ledger snapshot: node missing 'incidents'".to_string())?;
            for inc in incidents {
                let tok = inc
                    .get("kind")
                    .and_then(Json::as_str)
                    .ok_or_else(|| "ledger snapshot: incident missing 'kind'".to_string())?;
                health.incidents.push(Incident {
                    epoch: num(inc, "epoch")? as usize,
                    kind: parse_kind(tok)?,
                    duration_epochs: num(inc, "duration_epochs")? as usize,
                    gap_epochs: opt_num(inc, "gap_epochs"),
                });
            }
            ledger.nodes.insert(node, health);
        }
        Ok(ledger)
    }
}

/// Inverse of [`AnomalyClass::token`] for snapshot parsing.
fn parse_kind(tok: &str) -> Result<AnomalyClass, String> {
    match tok {
        "compute-slow" => Ok(AnomalyClass::ComputeSlow),
        "comm-slow" => Ok(AnomalyClass::CommSlow),
        "comm-hang" => Ok(AnomalyClass::CommHang),
        "slow-masking-hang" => Ok(AnomalyClass::SlowMaskingHang),
        other => Err(format!("ledger snapshot: unknown fault kind '{other}'")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn exercised_ledger() -> NodeLedger {
        let mut ledger = NodeLedger::default();
        ledger.predictive = true;
        // Node 3: two incidents with a 6-epoch recurrence gap, then blame.
        ledger.record_flag(3, 2, AnomalyClass::ComputeSlow);
        ledger.advance_epoch(3);
        ledger.record_release(3, 4);
        ledger.advance_epoch(5);
        ledger.record_flag(3, 8, AnomalyClass::CommSlow);
        ledger.record_release(3, 9);
        ledger.add_blame(3, 42.5);
        // Node 7: one open incident, never released.
        ledger.record_flag(7, 6, AnomalyClass::CommHang);
        ledger.advance_epoch(10);
        ledger
    }

    #[test]
    fn score_decays_on_incident_and_recovers_when_clean() {
        let mut ledger = NodeLedger::default();
        assert_eq!(ledger.score(0), 1.0);
        ledger.record_flag(0, 1, AnomalyClass::ComputeSlow);
        let hit = ledger.score(0);
        assert!((hit - (1.0 - INCIDENT_PENALTY)).abs() < 1e-12);
        // Open incidents do not recover.
        ledger.advance_epoch(2);
        assert_eq!(ledger.score(0), hit);
        // Released nodes recover toward 1.0 but never pass it.
        ledger.record_release(0, 3);
        ledger.advance_epoch(4);
        assert!(ledger.score(0) > hit);
        for e in 5..5000 {
            ledger.advance_epoch(e);
        }
        assert!(ledger.score(0) <= 1.0 && ledger.score(0) > 0.999);
    }

    #[test]
    fn record_flag_is_idempotent_while_open() {
        let mut ledger = NodeLedger::default();
        ledger.record_flag(0, 1, AnomalyClass::ComputeSlow);
        let once = ledger.score(0);
        ledger.record_flag(0, 2, AnomalyClass::ComputeSlow);
        assert_eq!(ledger.score(0), once);
        assert_eq!(ledger.total_incidents(), 1);
        assert_eq!(ledger.repeat_incidents(), 0);
    }

    #[test]
    fn repeat_incidents_count_reopens_only() {
        let mut ledger = NodeLedger::default();
        ledger.record_flag(0, 1, AnomalyClass::ComputeSlow);
        ledger.record_release(0, 2);
        assert_eq!(ledger.repeat_incidents(), 0);
        ledger.record_flag(0, 6, AnomalyClass::ComputeSlow);
        assert_eq!(ledger.repeat_incidents(), 1);
        let gap = ledger.nodes[&0].incidents[0].gap_epochs;
        assert_eq!(gap, None);
        ledger.record_release(0, 7);
        assert_eq!(ledger.nodes[&0].incidents[1].gap_epochs, Some(5));
    }

    #[test]
    fn memoryless_mode_always_answers_the_floor() {
        let mut ledger = exercised_ledger();
        ledger.predictive = false;
        assert_eq!(ledger.quarantine_epochs(3), FLOOR_EPOCHS);
        assert_eq!(ledger.quarantine_epochs(7), FLOOR_EPOCHS);
        assert_eq!(ledger.quarantine_epochs(99), FLOOR_EPOCHS);
    }

    #[test]
    fn predictive_quarantine_scales_with_health_deficit() {
        let ledger = exercised_ledger();
        // Node 3 is a repeat offender with a battered score: longer than
        // the floor, still under the cap.
        let q = ledger.quarantine_epochs(3);
        assert!(q > FLOOR_EPOCHS && q <= MAX_EPOCHS, "q = {q}");
        // Node 7 has a single (open) incident: floor.
        assert_eq!(ledger.quarantine_epochs(7), FLOOR_EPOCHS);
        // Unknown nodes: floor.
        assert_eq!(ledger.quarantine_epochs(99), FLOOR_EPOCHS);
        // A hammered score pins at the cap.
        let mut worst = ledger.clone();
        for e in 0..60 {
            worst.record_flag(3, 100 + 2 * e, AnomalyClass::ComputeSlow);
            worst.record_release(3, 101 + 2 * e);
        }
        assert_eq!(worst.quarantine_epochs(3), MAX_EPOCHS);
    }

    #[test]
    fn predicted_next_incident_needs_two_incidents() {
        let ledger = exercised_ledger();
        // Node 3: incidents opened at 2 and 8 → mean gap 6 → next at 14.
        assert_eq!(ledger.predicted_next_incident(3), Some(14));
        // Node 7 has no closed gap yet.
        assert_eq!(ledger.predicted_next_incident(7), None);
        assert_eq!(ledger.predicted_next_incident(99), None);
    }

    #[test]
    fn snapshot_round_trip_is_bit_identical() {
        let ledger = exercised_ledger();
        let text = ledger.to_json().to_string();
        let back = NodeLedger::parse(&text).expect("round trip");
        assert_eq!(back, ledger);
        // And the re-serialization is byte-identical (snapshot stability).
        assert_eq!(back.to_json().to_string(), text);
    }

    #[test]
    fn snapshot_format_is_pinned() {
        let mut ledger = NodeLedger::default();
        ledger.predictive = true;
        ledger.record_flag(1, 2, AnomalyClass::CommSlow);
        ledger.record_release(1, 3);
        ledger.epoch = 3;
        assert_eq!(
            ledger.to_json().to_string(),
            concat!(
                "{\"config\":{\"floor_epochs\":4,\"max_epochs\":32,",
                "\"penalty\":0.35,\"quarantine_scale\":16,\"recovery\":0.02},",
                "\"epoch\":3,\"nodes\":[{\"blame_s\":0,\"incidents\":",
                "[{\"duration_epochs\":1,\"epoch\":2,\"gap_epochs\":null,",
                "\"kind\":\"comm-slow\"}],\"last_incident_epoch\":2,",
                "\"node\":1,\"open_kind\":null,\"open_since\":null,",
                "\"repeats\":0,\"score\":0.65}],\"predictive\":true}"
            )
        );
    }

    #[test]
    fn parse_rejects_corrupt_snapshots() {
        assert!(NodeLedger::parse("not json").is_err());
        assert!(NodeLedger::parse("{}").is_err());
        let bad_kind = "{\"config\":{\"floor_epochs\":4,\"max_epochs\":32,\
                        \"penalty\":0.35,\"quarantine_scale\":16,\"recovery\":0.02},\
                        \"epoch\":0,\"nodes\":[{\"blame_s\":0,\"incidents\":\
                        [{\"duration_epochs\":1,\"epoch\":2,\"gap_epochs\":null,\
                        \"kind\":\"gremlins\"}],\"last_incident_epoch\":2,\"node\":1,\
                        \"open_kind\":null,\"open_since\":null,\"repeats\":0,\
                        \"score\":0.65}],\"predictive\":false}";
        let err = NodeLedger::parse(bad_kind).unwrap_err();
        assert!(err.contains("gremlins"), "{err}");
    }
}
