//! ASCII rendering of the paper's figures: throughput timelines, CDFs and
//! bar groups, so every `bench_figures` target prints a terminal-readable
//! analogue of the corresponding plot plus a CSV block for re-plotting.

/// Render a line series as an ASCII chart of the given height.
pub fn line_chart(title: &str, xs: &[f64], ys: &[f64], width: usize, height: usize) -> String {
    assert_eq!(xs.len(), ys.len());
    if ys.is_empty() {
        return format!("{title}\n  (empty)\n");
    }
    let (ymin, ymax) = bounds(ys);
    let span = if (ymax - ymin).abs() < 1e-12 { 1.0 } else { ymax - ymin };
    let mut grid = vec![vec![b' '; width]; height];

    let n = ys.len();
    for col in 0..width {
        // Downsample: average the bucket of samples that map to this column.
        let lo = col * n / width;
        let hi = (((col + 1) * n) / width).max(lo + 1).min(n);
        let v = ys[lo..hi].iter().sum::<f64>() / (hi - lo) as f64;
        let row = ((v - ymin) / span * (height - 1) as f64).round() as usize;
        let row = (height - 1).saturating_sub(row.min(height - 1));
        grid[row][col] = b'*';
    }

    let mut out = String::new();
    out.push_str(title);
    out.push('\n');
    for (i, row) in grid.iter().enumerate() {
        let yval = ymax - span * i as f64 / (height - 1) as f64;
        out.push_str(&format!("{yval:>10.3} |"));
        out.push_str(std::str::from_utf8(row).unwrap_or(""));
        out.push('\n');
    }
    out.push_str(&format!(
        "{:>10} +{}\n{:>12}x: [{:.1} .. {:.1}]\n",
        "",
        "-".repeat(width),
        "",
        xs.first().copied().unwrap_or(0.0),
        xs.last().copied().unwrap_or(0.0)
    ));
    out
}

/// Horizontal bar chart for grouped comparisons (Fig 13/15-style).
pub fn bar_chart(title: &str, labels: &[String], values: &[f64], width: usize) -> String {
    assert_eq!(labels.len(), values.len());
    let vmax = values.iter().cloned().fold(f64::MIN, f64::max).max(1e-12);
    let lw = labels.iter().map(|l| l.len()).max().unwrap_or(0);
    let mut out = format!("{title}\n");
    for (l, &v) in labels.iter().zip(values) {
        let n = ((v / vmax) * width as f64).round().max(0.0) as usize;
        out.push_str(&format!("  {l:<lw$} | {} {v:.3}\n", "#".repeat(n)));
    }
    out
}

/// CSV block with a header row — machine-readable twin of every chart.
pub fn csv(header: &[&str], rows: &[Vec<f64>]) -> String {
    let mut out = header.join(",");
    out.push('\n');
    for row in rows {
        let cells: Vec<String> = row.iter().map(|v| format!("{v}")).collect();
        out.push_str(&cells.join(","));
        out.push('\n');
    }
    out
}

/// Markdown-style table used by bench_tables to mirror the paper's tables.
pub fn table(header: &[&str], rows: &[Vec<String>]) -> String {
    let ncol = header.len();
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate().take(ncol) {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let line = |cells: &[String]| -> String {
        let mut s = String::from("|");
        for (i, c) in cells.iter().enumerate().take(ncol) {
            s.push_str(&format!(" {:<w$} |", c, w = widths[i]));
        }
        s.push('\n');
        s
    };
    let mut out = line(&header.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
    out.push_str(&line(&sep));
    for row in rows {
        out.push_str(&line(row));
    }
    out
}

fn bounds(ys: &[f64]) -> (f64, f64) {
    let mut lo = f64::MAX;
    let mut hi = f64::MIN;
    for &y in ys {
        lo = lo.min(y);
        hi = hi.max(y);
    }
    (lo, hi)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn line_chart_renders() {
        let xs: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| (x / 10.0).sin()).collect();
        let s = line_chart("sine", &xs, &ys, 60, 10);
        assert!(s.contains("sine"));
        assert!(s.matches('*').count() >= 55);
    }

    #[test]
    fn line_chart_constant_series() {
        let s = line_chart("flat", &[0.0, 1.0], &[5.0, 5.0], 10, 4);
        assert!(s.contains('*'));
    }

    #[test]
    fn bar_chart_scales_to_max() {
        let s = bar_chart(
            "t",
            &["a".into(), "b".into()],
            &[1.0, 2.0],
            20,
        );
        let lines: Vec<&str> = s.lines().collect();
        let a = lines[1].matches('#').count();
        let b = lines[2].matches('#').count();
        assert_eq!(b, 20);
        assert_eq!(a, 10);
    }

    #[test]
    fn table_aligns() {
        let t = table(
            &["alg", "acc"],
            &[
                vec!["BOCD+V".into(), "99.1".into()],
                vec!["SlideWindow".into(), "93.5".into()],
            ],
        );
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines.iter().all(|l| l.len() == lines[0].len()));
    }

    #[test]
    fn csv_shape() {
        let c = csv(&["x", "y"], &[vec![1.0, 2.0], vec![3.0, 4.5]]);
        assert_eq!(c, "x,y\n1,2\n3,4.5\n");
    }
}
