//! Utility substrate: deterministic RNG, JSON, CLI parsing, statistics,
//! ASCII plotting, property-test harness, logging. Hand-rolled because the
//! offline build has no serde/clap/rand/proptest.

pub mod cli;
pub mod json;
pub mod logging;
pub mod plot;
pub mod prop;
pub mod rng;
pub mod stats;
