//! Minimal JSON substrate (serde is unavailable offline).
//!
//! Parses the `artifacts/*.meta.json` sidecars emitted by the AOT path and
//! serializes experiment reports/metrics. Supports the full JSON grammar
//! except `\u` surrogate pairs outside the BMP.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing bytes at {}", p.i));
        }
        Ok(v)
    }

    // -- accessors ---------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    // -- constructors ------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    // -- serialization -----------------------------------------------------

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if !n.is_finite() {
                    // JSON has no NaN/Infinity literal; Rust's `{}` would
                    // emit `NaN`/`inf` and corrupt the document. Every
                    // non-finite number serializes as null.
                    out.push_str("null");
                } else if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{}", n);
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Serialization lives behind `Display`, so `.to_string()` keeps working
/// at every call site via the `ToString` blanket impl.
impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut out = String::new();
        self.write(&mut out);
        f.write_str(&out)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at {}", start))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| "bad \\u")?,
                                16,
                            )
                            .map_err(|_| "bad \\u")?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(format!("bad escape at {}", self.i)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Copy one UTF-8 scalar.
                    let s = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf8")?;
                    let c = s.chars().next().ok_or("invalid utf8")?;
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(format!("expected , or ] at {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            out.insert(key, self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(format!("expected , or }} at {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("c")
        );
    }

    #[test]
    fn round_trip() {
        let src = r#"{"shapes":[[2,3],[4]],"name":"wte","n":108288,"ok":true}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn non_finite_numbers_serialize_as_null() {
        // Invalid-JSON audit pin: NaN/inf anywhere in a document must not
        // leak `NaN`/`inf` tokens; they degrade to null and the output
        // stays parseable.
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            assert_eq!(Json::Num(bad).to_string(), "null");
        }
        let doc = Json::obj(vec![
            ("ok", Json::Num(1.5)),
            ("nan", Json::Num(f64::NAN)),
            ("arr", Json::arr_f64(&[0.25, f64::INFINITY])),
        ]);
        let text = doc.to_string();
        let back = Json::parse(&text).expect("non-finite docs must stay valid JSON");
        assert_eq!(back.get("nan"), Some(&Json::Null));
        assert_eq!(back.get("arr").unwrap().as_arr().unwrap()[1], Json::Null);
        assert_eq!(back.get("ok").unwrap().as_f64(), Some(1.5));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"open").is_err());
    }

    #[test]
    fn parses_real_meta_shape() {
        let src = r#"{"preset":"tiny","n_params":108288,
                      "param_shapes":[[96,64],[32,64],[64]],
                      "config":{"lr":0.1,"vocab":96}}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.get("n_params").unwrap().as_usize(), Some(108288));
        let shapes = j.get("param_shapes").unwrap().as_arr().unwrap();
        assert_eq!(shapes[0].as_arr().unwrap()[0].as_usize(), Some(96));
        assert_eq!(j.get("config").unwrap().get("lr").unwrap().as_f64(), Some(0.1));
    }

    #[test]
    fn unicode_and_escapes() {
        let j = Json::parse(r#""é\tA""#).unwrap();
        assert_eq!(j.as_str(), Some("é\tA"));
        let s = Json::Str("a\"b\\c\nd".into()).to_string();
        assert_eq!(Json::parse(&s).unwrap().as_str(), Some("a\"b\\c\nd"));
    }
}
