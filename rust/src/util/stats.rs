//! Statistics substrate: the estimators the paper's analysis relies on
//! (mean/median/percentiles, coefficient of variation, CDFs, autocovariance).

/// Arithmetic mean. Returns 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population variance.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

pub fn std_dev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Coefficient of variation (Table 2's stability metric).
pub fn cov(xs: &[f64]) -> f64 {
    let m = mean(xs);
    if m == 0.0 {
        return 0.0;
    }
    std_dev(xs) / m
}

/// NaN-last total order: any NaN (either sign bit — `f64::total_cmp` alone
/// would put negative NaNs *below* -inf) sorts above every real number, so
/// NaNs surface only in the extreme top quantiles rather than panicking or
/// silently poisoning the low/mid quantiles.
fn nan_last(a: &f64, b: &f64) -> std::cmp::Ordering {
    match (a.is_nan(), b.is_nan()) {
        (true, true) => std::cmp::Ordering::Equal,
        (true, false) => std::cmp::Ordering::Greater,
        (false, true) => std::cmp::Ordering::Less,
        (false, false) => a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal),
    }
}

/// Quantile with linear interpolation, q in [0, 1]. NaN-bearing input
/// cannot panic: NaNs sort last and surface only in the top quantiles.
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut s = xs.to_vec();
    s.sort_by(nan_last);
    quantile_sorted(&s, q)
}

/// Quantile on an already-sorted (ascending) slice — lets callers that need
/// several quantiles sort once instead of once per call.
pub fn quantile_sorted(s: &[f64], q: f64) -> f64 {
    if s.is_empty() {
        return 0.0;
    }
    let pos = q.clamp(0.0, 1.0) * (s.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        s[lo] + (pos - lo as f64) * (s[hi] - s[lo])
    }
}

pub fn median(xs: &[f64]) -> f64 {
    quantile(xs, 0.5)
}

/// Empirical CDF sampled at `points` evenly spaced quantiles: (value, F(value)).
pub fn ecdf(xs: &[f64], points: usize) -> Vec<(f64, f64)> {
    if xs.is_empty() || points == 0 {
        return vec![];
    }
    let mut s = xs.to_vec();
    s.sort_by(nan_last);
    (0..points)
        .map(|i| {
            let q = (i + 1) as f64 / points as f64;
            let idx = ((q * s.len() as f64).ceil() as usize).min(s.len()) - 1;
            (s[idx], q)
        })
        .collect()
}

/// Lag-k autocovariance-based ACF as defined in §4.2 of the paper:
/// ACF(X)_k = sum_{t..L-k} (x_t - mu)(x_{t+k} - mu) / sum_t (x_t - mu)^2.
pub fn acf(xs: &[f64], k: usize) -> f64 {
    let n = xs.len();
    if k >= n {
        return 0.0;
    }
    let mu = mean(xs);
    let denom: f64 = xs.iter().map(|x| (x - mu) * (x - mu)).sum();
    if denom == 0.0 {
        // A perfectly constant series is trivially periodic at every lag.
        return 1.0;
    }
    let num: f64 = (0..n - k).map(|t| (xs[t] - mu) * (xs[t + k] - mu)).sum();
    num / denom
}

/// Online mean/variance accumulator (Welford).
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn std_dev(&self) -> f64 {
        self.variance().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_median_basic() {
        let xs = [1.0, 2.0, 3.0, 4.0, 100.0];
        assert!((mean(&xs) - 22.0).abs() < 1e-12);
        assert_eq!(median(&xs), 3.0);
    }

    #[test]
    fn quantiles_interpolate() {
        let xs = [0.0, 10.0];
        assert!((quantile(&xs, 0.5) - 5.0).abs() < 1e-12);
        assert_eq!(quantile(&xs, 0.0), 0.0);
        assert_eq!(quantile(&xs, 1.0), 10.0);
    }

    #[test]
    fn cov_scale_invariant() {
        let xs = [1.0, 2.0, 3.0];
        let ys = [10.0, 20.0, 30.0];
        assert!((cov(&xs) - cov(&ys)).abs() < 1e-12);
    }

    #[test]
    fn acf_periodic_signal_peaks_at_period() {
        // A period-4 signal must have ACF ~1.0 at lag 4 and low at lag 1.
        // (The finite-series ceiling is (L-k)/L, hence the long series.)
        let xs: Vec<f64> = (0..256).map(|i| [0.0, 5.0, 1.0, 9.0][i % 4]).collect();
        assert!(acf(&xs, 4) > 0.95, "lag4 {}", acf(&xs, 4));
        assert!(acf(&xs, 1) < 0.5, "lag1 {}", acf(&xs, 1));
    }

    #[test]
    fn acf_constant_series_is_one() {
        let xs = [3.0; 32];
        assert_eq!(acf(&xs, 5), 1.0);
    }

    #[test]
    fn acf_lag_zero_is_one() {
        let xs: Vec<f64> = (0..32).map(|i| (i as f64).sin()).collect();
        assert!((acf(&xs, 0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn ecdf_monotone() {
        let xs: Vec<f64> = (0..100).map(|i| (i * 7 % 31) as f64).collect();
        let cdf = ecdf(&xs, 20);
        for w in cdf.windows(2) {
            assert!(w[1].0 >= w[0].0);
            assert!(w[1].1 >= w[0].1);
        }
        assert!((cdf.last().unwrap().1 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn quantile_tolerates_nan_input() {
        // NaNs of EITHER sign sort last: low/mid quantiles stay meaningful
        // and nothing panics. (0.0/0.0 on x86_64 yields a negative-sign
        // QNaN, which f64::total_cmp would sort below -inf.)
        let neg_nan = f64::from_bits(0xFFF8_0000_0000_0000);
        assert!(neg_nan.is_nan() && neg_nan.is_sign_negative());
        for nan in [f64::NAN, neg_nan] {
            let xs = [3.0, nan, 1.0, 2.0];
            assert_eq!(quantile(&xs, 0.0), 1.0);
            assert!((median(&xs) - 2.5).abs() < 1e-12);
            assert!(quantile(&xs, 1.0).is_nan());
        }
        assert!(quantile(&[f64::NAN], 0.5).is_nan());
        // -inf still beats every finite value at the bottom.
        assert_eq!(quantile(&[1.0, f64::NEG_INFINITY, neg_nan], 0.0), f64::NEG_INFINITY);
    }

    #[test]
    fn ecdf_tolerates_nan_input() {
        let xs = [1.0, f64::NAN, 0.0, 2.0];
        let cdf = ecdf(&xs, 4);
        assert_eq!(cdf.len(), 4);
        // Finite prefix is still ordered; only the top bucket sees the NaN.
        assert_eq!(cdf[0].0, 0.0);
        assert_eq!(cdf[1].0, 1.0);
        assert_eq!(cdf[2].0, 2.0);
        assert!(cdf[3].0.is_nan());
    }

    #[test]
    fn welford_matches_batch() {
        let xs: Vec<f64> = (0..500).map(|i| ((i * 37) % 97) as f64).collect();
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - mean(&xs)).abs() < 1e-9);
        assert!((w.variance() - variance(&xs)).abs() < 1e-6);
    }
}
