//! Minimal leveled logger writing to stderr; level set via FALCON_LOG
//! (error|warn|info|debug, default info).

use std::sync::atomic::{AtomicU8, Ordering};

pub const ERROR: u8 = 0;
pub const WARN: u8 = 1;
pub const INFO: u8 = 2;
pub const DEBUG: u8 = 3;

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX);

pub fn level() -> u8 {
    let cur = LEVEL.load(Ordering::Relaxed);
    if cur != u8::MAX {
        return cur;
    }
    let lvl = match std::env::var("FALCON_LOG").as_deref() {
        Ok("error") => ERROR,
        Ok("warn") => WARN,
        Ok("debug") => DEBUG,
        _ => INFO,
    };
    LEVEL.store(lvl, Ordering::Relaxed);
    lvl
}

pub fn set_level(lvl: u8) {
    LEVEL.store(lvl, Ordering::Relaxed);
}

pub fn log(lvl: u8, tag: &str, msg: &str) {
    if lvl <= level() {
        let name = ["ERROR", "WARN", "INFO", "DEBUG"][lvl as usize];
        eprintln!("[{name}] {tag}: {msg}");
    }
}

#[macro_export]
macro_rules! info {
    ($tag:expr, $($fmt:tt)*) => {
        $crate::util::logging::log($crate::util::logging::INFO, $tag, &format!($($fmt)*))
    };
}

#[macro_export]
macro_rules! warn_log {
    ($tag:expr, $($fmt:tt)*) => {
        $crate::util::logging::log($crate::util::logging::WARN, $tag, &format!($($fmt)*))
    };
}

#[macro_export]
macro_rules! debug_log {
    ($tag:expr, $($fmt:tt)*) => {
        $crate::util::logging::log($crate::util::logging::DEBUG, $tag, &format!($($fmt)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        set_level(WARN);
        assert!(ERROR <= level());
        assert!(WARN <= level());
        assert!(INFO > level());
        set_level(INFO);
    }
}
