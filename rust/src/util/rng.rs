//! Deterministic PRNG substrate.
//!
//! The offline build has no `rand` crate, so we implement SplitMix64 (for
//! seeding) and xoshiro256** (the workhorse generator) from the published
//! reference algorithms. Every stochastic component in the simulator takes
//! an explicit `Rng` so campaigns are reproducible from a single seed.

/// xoshiro256** by Blackman & Vigna — fast, 256-bit state, passes BigCrush.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent stream (for per-job / per-rank RNGs).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0xA076_1D64_78BD_642F))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n). Rejection-free Lemire reduction.
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo)
    }

    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn normal_with(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Lognormal with the given *underlying* normal parameters.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Lognormal parameterized by its own mean/std (what §3 reports).
    pub fn lognormal_mean_std(&mut self, mean: f64, std: f64) -> f64 {
        let cv2 = (std / mean).powi(2);
        let sigma2 = (1.0 + cv2).ln();
        let mu = mean.ln() - sigma2 / 2.0;
        self.lognormal(mu, sigma2.sqrt())
    }

    /// Exponential with the given rate.
    pub fn exponential(&mut self, rate: f64) -> f64 {
        -(1.0 - self.f64()).ln() / rate
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick one element by reference.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn lognormal_mean_std_matches_target() {
        let mut r = Rng::new(13);
        let n = 300_000;
        let xs: Vec<f64> = (0..n).map(|_| r.lognormal_mean_std(10.0, 8.0)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        assert!((mean - 10.0).abs() < 0.25, "mean {mean}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(17);
        let n = 200_000;
        let mean = (0..n).map(|_| r.exponential(0.5)).sum::<f64>() / n as f64;
        assert!((mean - 2.0).abs() < 0.05, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(19);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn forked_streams_independent() {
        let mut root = Rng::new(23);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
