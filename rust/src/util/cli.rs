//! Tiny CLI argument substrate (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args, with
//! typed accessors and a generated usage string.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    present: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(rest) = arg.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                    out.present.push(k.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    if let Some(v) = it.next() {
                        out.flags.insert(rest.to_string(), v);
                        out.present.push(rest.to_string());
                    }
                } else {
                    out.flags.insert(rest.to_string(), "true".to_string());
                    out.present.push(rest.to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    /// How many times `--key` appeared. The value map keeps only the last
    /// occurrence, so callers that cannot merge repeats use this to reject
    /// them instead of silently dropping all but one.
    pub fn count(&self, key: &str) -> usize {
        self.present.iter().filter(|k| k.as_str() == key).count()
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        match self.get(key) {
            Some("true") | Some("1") | Some("yes") => true,
            Some("false") | Some("0") | Some("no") => false,
            Some(_) => default,
            None => default,
        }
    }

    /// Comma-separated list.
    pub fn list_or(&self, key: &str, default: &[&str]) -> Vec<String> {
        match self.get(key) {
            Some(v) => v.split(',').map(|s| s.trim().to_string()).collect(),
            None => default.iter().map(|s| s.to_string()).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string()))
    }

    #[test]
    fn key_value_pairs() {
        let a = parse(&["--steps", "100", "--preset=small", "train"]);
        assert_eq!(a.usize_or("steps", 0), 100);
        assert_eq!(a.str_or("preset", "x"), "small");
        assert_eq!(a.positional, vec!["train"]);
    }

    #[test]
    fn bare_flags() {
        let a = parse(&["--verbose", "--dp", "4"]);
        assert!(a.bool_or("verbose", false));
        assert_eq!(a.usize_or("dp", 0), 4);
    }

    #[test]
    fn repeated_flags_keep_last_but_are_countable() {
        let a = parse(&["--drop-fault", "0", "--drop-fault", "1"]);
        assert_eq!(a.usize_or("drop-fault", 9), 1, "value map keeps the last");
        assert_eq!(a.count("drop-fault"), 2);
        assert_eq!(a.count("missing"), 0);
    }

    #[test]
    fn trailing_bare_flag() {
        let a = parse(&["run", "--fast"]);
        assert!(a.bool_or("fast", false));
        assert_eq!(a.positional, vec!["run"]);
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&[]);
        assert_eq!(a.f64_or("sev", 0.5), 0.5);
        assert_eq!(a.str_or("mode", "sim"), "sim");
        assert!(!a.has("anything"));
    }

    #[test]
    fn lists_split() {
        let a = parse(&["--presets", "tiny, small,base"]);
        assert_eq!(a.list_or("presets", &[]), vec!["tiny", "small", "base"]);
        assert_eq!(a.list_or("other", &["x"]), vec!["x"]);
    }

    #[test]
    fn negative_number_values() {
        let a = parse(&["--offset=-3.5"]);
        assert_eq!(a.f64_or("offset", 0.0), -3.5);
    }
}
