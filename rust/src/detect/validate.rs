//! Validation phase (§4.3): pinpoint slow GPUs and congested links inside
//! suspicious groups, in O(1) parallel passes.
//!
//! Communication validation decomposes the collective topology into
//! non-overlapping P2P send/receive passes (Fig 9): even rings take 2
//! passes, odd rings 3, trees 4 — independent of group size, so wall-clock
//! is constant (R2). All transfers within a pass run concurrently; a pass's
//! per-edge times are compared and slow edges flagged.
//!
//! Computation validation dispatches the GEMM benchmark to every candidate
//! GPU in parallel and flags outliers vs the group median. (The live
//! system runs the AOT `gemm_bench.hlo.txt` artifact via PJRT; the
//! simulator models it with `TrainingSim::bench_gpu`.)

use crate::collectives::{CommGroup, Topology};
use crate::util::stats;

/// Outlier multiplier for flagging slow components vs group median.
pub const SLOW_FACTOR: f64 = 1.3;

/// The P2P validation plan: passes of disjoint (from, to) index pairs.
#[derive(Clone, Debug, PartialEq)]
pub struct ValidationPlan {
    pub passes: Vec<Vec<(usize, usize)>>,
}

impl ValidationPlan {
    /// Total edges covered.
    pub fn n_edges(&self) -> usize {
        self.passes.iter().map(|p| p.len()).sum()
    }

    /// No rank appears twice within a pass (concurrency invariant).
    pub fn passes_disjoint(&self) -> bool {
        self.passes.iter().all(|pass| {
            let mut seen = std::collections::BTreeSet::new();
            pass.iter().all(|&(a, b)| seen.insert(a) && seen.insert(b))
        })
    }
}

/// Decompose a ring of `n` members (Fig 9, left & center).
///
/// Even ring: pass 1 covers even->odd edges, pass 2 odd->even. Odd ring
/// needs a third pass for the wrap-around remainder.
pub fn ring_plan(n: usize) -> ValidationPlan {
    assert!(n >= 2);
    let mut p1 = Vec::new();
    let mut p2 = Vec::new();
    let mut p3 = Vec::new();
    for i in 0..n {
        let j = (i + 1) % n;
        let edge = (i, j);
        if i % 2 == 0 && j % 2 == 1 {
            p1.push(edge);
        } else if i % 2 == 1 && j % 2 == 0 && j != 0 {
            p2.push(edge);
        } else {
            // Wrap edges that break parity (odd rings; and the n-1 -> 0
            // edge of even rings falls in p2 naturally).
            if n % 2 == 0 {
                p2.push(edge);
            } else {
                p3.push(edge);
            }
        }
    }
    let mut passes = vec![p1, p2];
    if !p3.is_empty() {
        passes.push(p3);
    }
    ValidationPlan { passes }
}

/// Decompose a binary tree of `n` members (Fig 9, right): four passes —
/// left children at even depth, right children at even depth, then the
/// same from odd depth.
pub fn tree_plan(n: usize) -> ValidationPlan {
    assert!(n >= 2);
    let depth = |mut i: usize| {
        let mut d = 0;
        while i > 0 {
            i = (i - 1) / 2;
            d += 1;
        }
        d
    };
    let mut passes = vec![Vec::new(), Vec::new(), Vec::new(), Vec::new()];
    for c in 1..n {
        let parent = (c - 1) / 2;
        let is_left = c == 2 * parent + 1;
        let even_level = depth(parent) % 2 == 0;
        let idx = match (even_level, is_left) {
            (true, true) => 0,
            (true, false) => 1,
            (false, true) => 2,
            (false, false) => 3,
        };
        passes[idx].push((parent, c));
    }
    passes.retain(|p| !p.is_empty());
    ValidationPlan { passes }
}

/// Plan for a comm group according to its topology.
pub fn plan_for(group: &CommGroup) -> ValidationPlan {
    match group.topology {
        Topology::Ring => ring_plan(group.len()),
        Topology::Tree => tree_plan(group.len()),
    }
}

/// Result of communication validation: flagged slow edges with their
/// measured-vs-median slowdown.
#[derive(Clone, Debug)]
pub struct SlowEdge {
    pub from_rank: usize,
    pub to_rank: usize,
    pub slowdown: f64,
}

/// Execute a plan with a caller-supplied measurement function
/// `measure(member_a, member_b) -> seconds` (simulator benches in tests,
/// real PJRT-timed transfers in the live system). Equal transfer sizes mean
/// slow links simply measure longer (§4.3).
pub fn validate_comm(
    group: &CommGroup,
    measure: &mut dyn FnMut(usize, usize) -> f64,
) -> Vec<SlowEdge> {
    let plan = plan_for(group);
    let mut timings = Vec::new();
    for pass in &plan.passes {
        for &(a, b) in pass {
            timings.push((a, b, measure(a, b)));
        }
    }
    let ts: Vec<f64> = timings.iter().map(|&(_, _, t)| t).collect();
    let med = stats::median(&ts);
    timings
        .into_iter()
        .filter(|&(_, _, t)| t > SLOW_FACTOR * med)
        .map(|(a, b, t)| SlowEdge {
            from_rank: group.ranks[a],
            to_rank: group.ranks[b],
            slowdown: t / med,
        })
        .collect()
}

/// Result of computation validation: flagged slow GPUs (by candidate index).
#[derive(Clone, Debug)]
pub struct SlowGpu {
    pub rank: usize,
    pub slowdown: f64,
}

/// GEMM-validate a set of ranks with a caller-supplied benchmark function.
pub fn validate_compute(
    ranks: &[usize],
    bench: &mut dyn FnMut(usize) -> f64,
) -> Vec<SlowGpu> {
    let times: Vec<(usize, f64)> = ranks.iter().map(|&r| (r, bench(r))).collect();
    let ts: Vec<f64> = times.iter().map(|&(_, t)| t).collect();
    let med = stats::median(&ts);
    times
        .into_iter()
        .filter(|&(_, t)| t > SLOW_FACTOR * med)
        .map(|(rank, t)| SlowGpu { rank, slowdown: t / med })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::GpuId;

    fn ring_group(n: usize) -> CommGroup {
        CommGroup::new(
            (0..n).collect(),
            (0..n).map(|i| GpuId { node: i / 8, index: i % 8 }).collect(),
            Topology::Ring,
        )
    }

    #[test]
    fn even_ring_two_passes() {
        for n in [2, 4, 8, 16, 64] {
            let plan = ring_plan(n);
            assert_eq!(plan.passes.len(), 2, "n={n}");
            assert_eq!(plan.n_edges(), n, "n={n}");
            assert!(plan.passes_disjoint(), "n={n}");
        }
    }

    #[test]
    fn odd_ring_three_passes() {
        for n in [3, 5, 7, 15, 63] {
            let plan = ring_plan(n);
            assert_eq!(plan.passes.len(), 3, "n={n}");
            assert_eq!(plan.n_edges(), n, "n={n}");
            assert!(plan.passes_disjoint(), "n={n}");
        }
    }

    #[test]
    fn tree_at_most_four_passes() {
        for n in [2, 3, 4, 7, 8, 15, 16, 33, 64, 127] {
            let plan = tree_plan(n);
            assert!(plan.passes.len() <= 4, "n={n}: {}", plan.passes.len());
            assert_eq!(plan.n_edges(), n - 1, "n={n}");
            assert!(plan.passes_disjoint(), "n={n}");
        }
    }

    #[test]
    fn plans_are_o1_in_group_size() {
        // Pass count must not grow with n — the O(1) claim.
        assert_eq!(ring_plan(4).passes.len(), ring_plan(1024).passes.len());
        assert!(tree_plan(1024).passes.len() <= 4);
    }

    #[test]
    fn ring_plan_covers_every_ring_edge_exactly_once() {
        for n in [4, 5, 8, 9] {
            let plan = ring_plan(n);
            let mut edges: Vec<(usize, usize)> = plan.passes.concat();
            edges.sort_unstable();
            let mut expect: Vec<(usize, usize)> = (0..n).map(|i| (i, (i + 1) % n)).collect();
            expect.sort_unstable();
            assert_eq!(edges, expect, "n={n}");
        }
    }

    #[test]
    fn validate_comm_flags_slow_edge() {
        let group = ring_group(8);
        let mut measure = |a: usize, b: usize| {
            if (a, b) == (3, 4) {
                5.0
            } else {
                1.0 + 0.01 * (a + b) as f64
            }
        };
        let slow = validate_comm(&group, &mut measure);
        assert_eq!(slow.len(), 1);
        assert_eq!((slow[0].from_rank, slow[0].to_rank), (3, 4));
        assert!(slow[0].slowdown > 4.0);
    }

    #[test]
    fn validate_comm_healthy_is_clean() {
        let group = ring_group(9);
        let mut measure = |a: usize, b: usize| 1.0 + 0.02 * ((a * 7 + b) % 5) as f64;
        assert!(validate_comm(&group, &mut measure).is_empty());
    }

    #[test]
    fn validate_compute_flags_slow_gpu() {
        let ranks = vec![0, 1, 2, 3];
        let mut bench = |r: usize| if r == 2 { 2.0 } else { 1.0 };
        let slow = validate_compute(&ranks, &mut bench);
        assert_eq!(slow.len(), 1);
        assert_eq!(slow[0].rank, 2);
        assert!((slow[0].slowdown - 2.0).abs() < 1e-9);
    }

    #[test]
    fn validate_compute_multiple_stragglers() {
        let ranks: Vec<usize> = (0..8).collect();
        let mut bench = |r: usize| if r < 2 { 3.0 } else { 1.0 };
        let slow = validate_compute(&ranks, &mut bench);
        assert_eq!(slow.len(), 2);
    }
}
