//! ACF-based iteration-time inference (§4.2).
//!
//! The tracking phase sees only a stream of intercepted communication calls
//! per rank — their types and timestamps — and must infer the training
//! iteration time without knowing the framework or model (R1). The op-kind
//! sequence is periodic with period = calls per iteration (Fig 8); the
//! autocorrelation function finds that period, and the iteration time is
//! the timestamp difference between an op and its previous-period twin.

use crate::simkit::Time;
use crate::util::stats;

/// Default ACF acceptance threshold M (paper uses 0.95).
pub const ACF_THRESHOLD: f64 = 0.95;

/// Find the recurring period of a signal: the smallest lag k in
/// [1, max_lag] with ACF(X)_k > threshold. (Paper's argmin_k rule.)
pub fn find_period(signal: &[f64], max_lag: usize, threshold: f64) -> Option<usize> {
    if signal.len() < 8 {
        return None;
    }
    let max_lag = max_lag.min(signal.len() / 2);
    (1..=max_lag).find(|&k| {
        // Compensate the finite-series ceiling (L-k)/L so short windows
        // don't mask true periods.
        let ceiling = (signal.len() - k) as f64 / signal.len() as f64;
        stats::acf(signal, k) > threshold * ceiling
    })
}

/// Infer per-iteration durations from a rank's op log.
///
/// `kinds` encodes op types as small floats (see `RankLog::op_kinds`);
/// `timestamps` are the matching call times. Returns `(period,
/// iteration_times_seconds)` or None if no period is found.
pub fn iteration_times(
    kinds: &[f64],
    timestamps: &[Time],
    max_lag: usize,
) -> Option<(usize, Vec<f64>)> {
    assert_eq!(kinds.len(), timestamps.len());
    // The op-kind sequence alone can be ambiguous — a framework issuing
    // only AllReduce yields a constant signal with "period 1" even when an
    // iteration spans several calls. Cross-check against the inter-arrival
    // rhythm: the true period must also be (a multiple of the kind-period
    // and) a period of the timestamp deltas.
    let deltas: Vec<f64> = timestamps
        .windows(2)
        .map(|w| (w[1] - w[0]) as f64)
        .collect();
    let kind_period = find_period(kinds, max_lag, ACF_THRESHOLD);
    let period = match kind_period {
        Some(kp) => {
            // Smallest multiple of the kind-period that also matches the
            // timing rhythm (kp itself when timings agree).
            let mut best = None;
            let mut m = kp;
            while m <= max_lag.min(deltas.len() / 2) {
                let ceiling = (deltas.len() - m) as f64 / deltas.len() as f64;
                if crate::util::stats::acf(&deltas, m) > 0.8 * ceiling {
                    best = Some(m);
                    break;
                }
                m += kp;
            }
            best.or(Some(kp))
        }
        None => find_period(&deltas, max_lag, 0.8),
    }?;

    let mut times = Vec::with_capacity(timestamps.len() / period);
    // Anchor on one op per period (index 0 mod period): difference between
    // consecutive occurrences is the iteration time.
    let mut i = period;
    while i < timestamps.len() {
        let dt = timestamps[i].saturating_sub(timestamps[i - period]);
        times.push(dt as f64 / 1e6);
        i += period;
    }
    if times.is_empty() {
        None
    } else {
        Some((period, times))
    }
}

/// Relative error between estimated and true mean iteration time (Fig 12).
pub fn relative_error(estimated: &[f64], ground_truth: &[f64]) -> f64 {
    let est = stats::mean(estimated);
    let gt = stats::mean(ground_truth);
    if gt == 0.0 {
        return 0.0;
    }
    (est - gt).abs() / gt
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simkit::SEC;

    fn synth_log(
        n_iters: usize,
        ops_per_iter: usize,
        iter_time: f64,
    ) -> (Vec<f64>, Vec<Time>) {
        let mut kinds = Vec::new();
        let mut ts = Vec::new();
        for it in 0..n_iters {
            let base = (it as f64 * iter_time * SEC as f64) as Time;
            for op in 0..ops_per_iter {
                kinds.push((op % 5 + 1) as f64);
                let frac = op as f64 / ops_per_iter as f64;
                ts.push(base + (frac * 0.8 * iter_time * SEC as f64) as Time);
            }
        }
        (kinds, ts)
    }

    #[test]
    fn finds_period_of_clean_pattern() {
        let (kinds, _) = synth_log(50, 4, 1.0);
        assert_eq!(find_period(&kinds, 16, ACF_THRESHOLD), Some(4));
    }

    #[test]
    fn period_one_pattern() {
        // Single op per iteration: kinds are constant -> ACF = 1 at lag 1.
        let (kinds, _) = synth_log(50, 1, 1.0);
        assert_eq!(find_period(&kinds, 16, ACF_THRESHOLD), Some(1));
    }

    #[test]
    fn no_period_in_noise() {
        let mut rng = crate::util::rng::Rng::new(3);
        let sig: Vec<f64> = (0..128).map(|_| rng.f64() * 10.0).collect();
        assert_eq!(find_period(&sig, 16, ACF_THRESHOLD), None);
    }

    #[test]
    fn iteration_times_recovered() {
        let (kinds, ts) = synth_log(60, 5, 2.5);
        let (period, times) = iteration_times(&kinds, &ts, 32).unwrap();
        assert_eq!(period, 5);
        let mean = stats::mean(&times);
        assert!((mean - 2.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn estimation_error_below_paper_bound() {
        // Fig 12: relative error <= 1.2% across strategies. Jittered log.
        let mut rng = crate::util::rng::Rng::new(5);
        let mut kinds = Vec::new();
        let mut ts: Vec<Time> = Vec::new();
        let mut now = 0f64;
        let iter_time = 3.0;
        let mut truth = Vec::new();
        for _ in 0..100 {
            let this = iter_time * (1.0 + 0.02 * rng.normal());
            truth.push(this);
            for op in 0..6 {
                kinds.push((op + 1) as f64);
                ts.push(((now + this * 0.12 * op as f64) * SEC as f64) as Time);
            }
            now += this;
        }
        let (_, est) = iteration_times(&kinds, &ts, 32).unwrap();
        assert!(relative_error(&est, &truth) < 0.012);
    }

    #[test]
    fn slowdown_visible_in_estimated_series() {
        // Iterations 40.. are 1.5x slower; the estimated series must show it.
        let mut kinds = Vec::new();
        let mut ts: Vec<Time> = Vec::new();
        let mut now = 0f64;
        for it in 0..80 {
            let this = if it < 40 { 1.0 } else { 1.5 };
            for op in 0..4 {
                kinds.push((op + 1) as f64);
                ts.push(((now + 0.1 * op as f64) * SEC as f64) as Time);
            }
            now += this;
        }
        let (_, est) = iteration_times(&kinds, &ts, 16).unwrap();
        let early = stats::mean(&est[..30]);
        let late = stats::mean(&est[45..]);
        assert!(late > 1.4 * early, "{late} vs {early}");
    }
}
