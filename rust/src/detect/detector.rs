//! BOCD + Verification: the paper's slow-iteration detector (§4.2), plus
//! episode bookkeeping (onset/relief) used by the coordinator and the
//! accuracy evaluation of Tables 4–5.
//!
//! Raw BOCD change-points are verified by comparing the mean iteration time
//! in windows before and after the candidate point; differences under 10%
//! are dismissed as jitter. Verified upward changes open a fail-slow
//! episode; verified downward changes (or a return to within 10% of the
//! healthy baseline) close it.

use super::bocd::{Bocd, BocdConfig};
use crate::util::stats::Welford;

/// Verification window length (iterations on each side of the candidate).
pub const VERIFY_WINDOW: usize = 8;
/// Minimum relative mean shift to accept a change-point (paper: 10%).
pub const VERIFY_DELTA: f64 = 0.10;

/// Samples the detector must keep resident. A candidate change-point at
/// index `cp` is verified once the stream reaches `cp + VERIFY_WINDOW - 1`,
/// at which point the verification reads `[cp - VERIFY_WINDOW, cp +
/// VERIFY_WINDOW)` — a span of `2 * VERIFY_WINDOW` ending at the newest
/// sample. Pending candidates are never older than that (they are retained
/// only while their post-window is incomplete), so this capacity is exact;
/// +1 is slack for off-by-one safety.
const RING_CAPACITY: usize = 2 * VERIFY_WINDOW + 1;

/// Fixed-capacity window over the most recent observations, addressed by
/// *absolute* sample index so the verification code reads like it did when
/// history was a `Vec` — but memory is O(VERIFY_WINDOW), not O(iterations).
#[derive(Clone, Debug)]
struct Ring {
    buf: Vec<f64>,
    /// Total samples ever pushed; `buf` holds the last `buf.len()` of them.
    pushed: usize,
}

impl Ring {
    fn new(cap: usize) -> Self {
        Ring { buf: vec![0.0; cap.max(1)], pushed: 0 }
    }

    fn push(&mut self, x: f64) {
        let cap = self.buf.len();
        self.buf[self.pushed % cap] = x;
        self.pushed += 1;
    }

    /// Number of samples pushed so far (absolute stream length).
    fn len(&self) -> usize {
        self.pushed
    }

    fn capacity(&self) -> usize {
        self.buf.len()
    }

    /// Copy of the absolute index range `[lo, hi)`; every index must still
    /// be resident.
    fn range(&self, lo: usize, hi: usize) -> Vec<f64> {
        debug_assert!(hi <= self.pushed, "range beyond stream");
        debug_assert!(
            self.pushed - lo <= self.buf.len(),
            "index {lo} evicted (pushed {}, cap {})",
            self.pushed,
            self.buf.len()
        );
        (lo..hi).map(|i| self.buf[i % self.buf.len()]).collect()
    }
}

/// A detected fail-slow episode in iteration indices.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Episode {
    pub start_iter: usize,
    /// None while ongoing.
    pub end_iter: Option<usize>,
    /// Mean slowdown factor during the episode vs the healthy baseline.
    pub severity: f64,
}

/// Online BOCD+V detector over an iteration-time stream.
///
/// Memory is bounded: observations live in a fixed ring sized to the
/// verification window plus the pending-candidate horizon, and the healthy
/// baseline is a streaming [`Welford`] accumulator — the detector can run
/// always-on over unbounded streams (R2).
#[derive(Clone, Debug)]
pub struct Detector {
    bocd: Bocd,
    history: Ring,
    /// Candidate change-points awaiting enough post-window to verify.
    pending: Vec<usize>,
    /// Healthy-mean estimate (pre-episode baseline), streamed.
    baseline: Welford,
    pub episodes: Vec<Episode>,
    in_episode: bool,
    escalated: bool,
}

impl Detector {
    pub fn new(cfg: BocdConfig) -> Self {
        Detector {
            bocd: Bocd::new(cfg),
            history: Ring::new(RING_CAPACITY),
            pending: Vec::new(),
            baseline: Welford::new(),
            episodes: Vec::new(),
            in_episode: false,
            escalated: false,
        }
    }

    pub fn with_defaults() -> Self {
        Detector::new(BocdConfig::default())
    }

    /// Resident observation capacity — constant, independent of how many
    /// samples have streamed through (exposed for the bounded-memory tests).
    pub fn ring_capacity(&self) -> usize {
        self.history.capacity()
    }

    /// Feed one iteration time. Returns `Some(true)` when an episode opens
    /// at this step, `Some(false)` when one closes, `None` otherwise.
    pub fn push(&mut self, x: f64) -> Option<bool> {
        // A non-finite measurement carries no information about cluster
        // health; dropping it keeps the ring, baseline and BOCD posterior
        // clean. (Bocd::push has the same guard for direct users.)
        if !x.is_finite() {
            return None;
        }
        let idx = self.history.len();
        self.history.push(x);

        // Track the healthy baseline while not inside an episode.
        if !self.in_episode {
            self.baseline.push(x);
        }

        if self.bocd.push(x).is_some() {
            self.pending.push(idx);
        }

        // Verify pending change-points once the post-window is complete.
        let mut result = None;
        let ready: Vec<usize> = self
            .pending
            .iter()
            .cloned()
            .filter(|&cp| idx + 1 >= cp + VERIFY_WINDOW)
            .collect();
        self.pending.retain(|&cp| idx + 1 < cp + VERIFY_WINDOW);

        for cp in ready {
            if let Some(opened) = self.verify(cp) {
                result = Some(opened);
            }
        }
        result
    }

    /// Change-point verification (the "+V"): mean of the windows around cp.
    fn verify(&mut self, cp: usize) -> Option<bool> {
        if cp < 2 {
            return None;
        }
        // Medians, not means: a single 1.2-1.8x jitter spike inside an
        // 8-wide window shifts the mean by >10% and would defeat the
        // verification's purpose; the median is immune to lone spikes while
        // preserving genuine level shifts.
        let lo = cp.saturating_sub(VERIFY_WINDOW);
        let before = crate::util::stats::median(&self.history.range(lo, cp));
        let hi = (cp + VERIFY_WINDOW).min(self.history.len());
        let after = crate::util::stats::median(&self.history.range(cp, hi));
        if before <= 0.0 {
            return None;
        }
        let delta = (after - before) / before;

        if !self.in_episode && delta > VERIFY_DELTA {
            let severity = after / self.baseline.mean().max(1e-12);
            self.episodes.push(Episode { start_iter: cp, end_iter: None, severity });
            self.in_episode = true;
            return Some(true);
        }
        if self.in_episode {
            // Relief closes the episode only when performance RETURNS TO
            // BASELINE. A significant drop that still sits above baseline is
            // *partial* relief (e.g. S3 fixed the congestion but a slow GPU
            // remains — Fig 17's compound case): the episode stays open so
            // the planner keeps escalating.
            let near_baseline = (after - self.baseline.mean()).abs()
                / self.baseline.mean().max(1e-12)
                < VERIFY_DELTA;
            if delta < -VERIFY_DELTA || near_baseline {
                if let Some(ep) = self.episodes.last_mut() {
                    ep.end_iter = Some(cp);
                }
                self.in_episode = false;
                return Some(false);
            }
            // Escalation within an episode: a further *upward* verified
            // shift (compound fail-slows, §3.4). Flag it so the coordinator
            // re-diagnoses the new root cause.
            if delta > VERIFY_DELTA {
                self.escalated = true;
            }
            if let Some(ep) = self.episodes.last_mut() {
                ep.severity = ep.severity.max(after / self.baseline.mean().max(1e-12));
            }
        }
        None
    }

    /// Whether an episode is currently open.
    pub fn slow_now(&self) -> bool {
        self.in_episode
    }

    /// Consume the "episode escalated" flag (set when a further verified
    /// upward shift occurs inside an open episode).
    pub fn take_escalation(&mut self) -> bool {
        std::mem::replace(&mut self.escalated, false)
    }

    pub fn baseline(&self) -> f64 {
        self.baseline.mean()
    }

    /// Job-level verdict: did this job experience any fail-slow?
    pub fn job_flagged(&self) -> bool {
        !self.episodes.is_empty()
    }
}

/// Offline convenience: feed a whole series, get the episodes.
pub fn detect_episodes(xs: &[f64], cfg: BocdConfig) -> Vec<Episode> {
    let mut d = Detector::new(cfg);
    for &x in xs {
        d.push(x);
    }
    d.episodes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn series(segments: &[(usize, f64)], noise: f64, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        let mut out = Vec::new();
        for &(n, m) in segments {
            for _ in 0..n {
                out.push(m * (1.0 + noise * rng.normal()));
            }
        }
        out
    }

    #[test]
    fn detects_episode_with_onset_and_relief() {
        let xs = series(&[(80, 1.0), (60, 1.5), (80, 1.0)], 0.015, 1);
        let eps = detect_episodes(&xs, BocdConfig::default());
        assert_eq!(eps.len(), 1, "{eps:?}");
        let ep = eps[0];
        assert!((75..=90).contains(&ep.start_iter), "{ep:?}");
        let end = ep.end_iter.expect("episode must close");
        assert!((135..=150).contains(&end), "{ep:?}");
        assert!((ep.severity - 1.5).abs() < 0.1, "{ep:?}");
    }

    #[test]
    fn jitter_spikes_are_verified_away() {
        // The false positives that kill raw BOCD (Tables 4–5) are dismissed.
        let mut xs = series(&[(250, 1.0)], 0.015, 2);
        for i in [50usize, 120, 180] {
            xs[i] = 1.6;
        }
        let eps = detect_episodes(&xs, BocdConfig::default());
        assert!(eps.is_empty(), "jitter flagged as episode: {eps:?}");
    }

    #[test]
    fn sub_threshold_shift_dismissed() {
        let xs = series(&[(100, 1.0), (100, 1.07)], 0.01, 3);
        let eps = detect_episodes(&xs, BocdConfig::default());
        assert!(eps.is_empty(), "{eps:?}");
    }

    #[test]
    fn compound_escalation_tracked() {
        // Fig 6's pattern: congestion then added GPU throttling.
        let xs = series(&[(80, 1.0), (60, 1.4), (60, 2.2), (60, 1.0)], 0.015, 4);
        let eps = detect_episodes(&xs, BocdConfig::default());
        assert!(!eps.is_empty());
        let max_sev = eps.iter().map(|e| e.severity).fold(0.0, f64::max);
        assert!(max_sev > 1.9, "escalation missed: {eps:?}");
    }

    #[test]
    fn healthy_job_not_flagged() {
        let xs = series(&[(500, 2.0)], 0.02, 5);
        let eps = detect_episodes(&xs, BocdConfig::default());
        assert!(eps.is_empty(), "{eps:?}");
    }

    #[test]
    fn two_separate_episodes() {
        let xs = series(
            &[(80, 1.0), (50, 1.5), (80, 1.0), (50, 1.8), (80, 1.0)],
            0.015,
            6,
        );
        let eps = detect_episodes(&xs, BocdConfig::default());
        assert_eq!(eps.len(), 2, "{eps:?}");
        assert!(eps[0].end_iter.is_some() && eps[1].end_iter.is_some());
    }

    #[test]
    fn bounded_memory_over_100k_iteration_stream() {
        // The R2 requirement: memory is O(VERIFY_WINDOW), not O(iterations).
        // Stream >=100k samples (with embedded fail-slow episodes so the
        // whole verify path runs) through one detector; the resident ring
        // stays at its fixed capacity throughout, and BOCD's hypothesis set
        // stays under its cap. A small cap keeps the debug-mode test quick
        // without changing the detection semantics exercised here.
        let cfg = BocdConfig { max_hypotheses: 128, trunc_eps: 1e-4, ..BocdConfig::default() };
        let mut d = Detector::new(cfg);
        let cap = d.ring_capacity();
        assert_eq!(cap, 2 * VERIFY_WINDOW + 1);

        let mut rng = Rng::new(99);
        let mut n = 0usize;
        // 25 blocks of (3600 healthy, 400 slow) = 100_000 samples.
        for _ in 0..25 {
            for i in 0..4000 {
                let level = if i >= 3600 { 1.5 } else { 1.0 };
                d.push(level * (1.0 + 0.015 * rng.normal()));
                n += 1;
                if n % 10_000 == 0 {
                    assert_eq!(d.ring_capacity(), cap, "ring grew at sample {n}");
                }
            }
        }
        assert!(n >= 100_000);
        assert_eq!(d.ring_capacity(), cap);
        // The detector still works at the far end of the stream.
        assert!(d.episodes.len() >= 20, "episodes: {}", d.episodes.len());
        assert!((d.baseline() - 1.0).abs() < 0.1, "baseline {}", d.baseline());
    }

    #[test]
    fn non_finite_samples_are_dropped() {
        // NaN/inf iteration times must not open bogus episodes, corrupt the
        // baseline, or prevent later real detections.
        let mut xs = series(&[(80, 1.0), (60, 1.5), (80, 1.0)], 0.015, 8);
        xs[10] = f64::NAN;
        xs[30] = f64::INFINITY;
        let mut d = Detector::with_defaults();
        for &x in &xs {
            d.push(x);
        }
        assert!(d.baseline().is_finite());
        assert_eq!(d.episodes.len(), 1, "{:?}", d.episodes);
        assert!(d.episodes[0].severity.is_finite());
    }

    #[test]
    fn baseline_tracks_healthy_mean() {
        let xs = series(&[(100, 2.0)], 0.01, 7);
        let mut d = Detector::with_defaults();
        for &x in &xs {
            d.push(x);
        }
        assert!((d.baseline() - 2.0).abs() < 0.05);
    }
}
