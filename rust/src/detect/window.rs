//! Sliding-window baseline detector (Tables 4–5's "SlideWindow").
//!
//! Reports a fail-slow when the current observation deviates from the
//! window median by more than 10% — simple, cheap, but it misses gradual
//! or compound degradations and re-baselines itself onto long-lived
//! fail-slows (the source of its FNR in the paper).

use std::collections::VecDeque;

use crate::util::stats;

#[derive(Clone, Debug)]
pub struct SlideWindow {
    window: VecDeque<f64>,
    cap: usize,
    threshold: f64,
}

impl SlideWindow {
    pub fn new(cap: usize, threshold: f64) -> Self {
        SlideWindow { window: VecDeque::with_capacity(cap), cap, threshold }
    }

    /// Feed one observation; returns true when it deviates >threshold from
    /// the current window median.
    pub fn push(&mut self, x: f64) -> bool {
        let slow = if self.window.len() >= self.cap / 2 {
            let med = stats::median(&self.window.iter().cloned().collect::<Vec<_>>());
            med > 0.0 && (x - med).abs() / med > self.threshold
        } else {
            false
        };
        self.window.push_back(x);
        if self.window.len() > self.cap {
            self.window.pop_front();
        }
        slow
    }
}

/// Offline run over a series: indices flagged as deviating.
pub fn detect_slow_points(xs: &[f64], cap: usize, threshold: f64) -> Vec<usize> {
    let mut w = SlideWindow::new(cap, threshold);
    xs.iter()
        .enumerate()
        .filter_map(|(i, &x)| if w.push(x) { Some(i) } else { None })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flags_step_change_onset() {
        let xs: Vec<f64> = (0..60).map(|i| if i < 30 { 1.0 } else { 1.3 }).collect();
        let flagged = detect_slow_points(&xs, 20, 0.1);
        assert!(flagged.contains(&30));
    }

    #[test]
    fn rebaselines_onto_long_failslow() {
        // After the window fills with slow iterations, flags stop — the
        // baseline's documented weakness.
        let xs: Vec<f64> = (0..100).map(|i| if i < 30 { 1.0 } else { 1.3 }).collect();
        let flagged = detect_slow_points(&xs, 20, 0.1);
        assert!(flagged.iter().all(|&i| i < 55), "{flagged:?}");
    }

    #[test]
    fn quiet_series_clean() {
        let xs = vec![1.0; 100];
        assert!(detect_slow_points(&xs, 20, 0.1).is_empty());
    }

    #[test]
    fn small_drift_missed() {
        // 8% shift stays under the 10% rule -> FNR source.
        let xs: Vec<f64> = (0..80).map(|i| if i < 40 { 1.0 } else { 1.08 }).collect();
        assert!(detect_slow_points(&xs, 20, 0.1).is_empty());
    }
}
