//! Bayesian Online Change-point Detection (Adams & MacKay; paper §4.2 and
//! Appendix 9.1).
//!
//! Observations are iteration times. The underlying predictive model (UPM)
//! is a Normal with unknown mean and precision under a Normal-Gamma
//! conjugate prior, giving a Student-t predictive — the standard choice for
//! scalar performance series. The run-length posterior is maintained online
//! in O(T) per step with truncation, i.e. linear overall as the paper
//! requires (R2).

/// Normal-Gamma posterior hyperparameters for one run-length hypothesis.
#[derive(Clone, Copy, Debug)]
struct NormalGamma {
    mu: f64,
    kappa: f64,
    alpha: f64,
    beta: f64,
}

impl NormalGamma {
    fn prior(mu0: f64, kappa0: f64, alpha0: f64, beta0: f64) -> Self {
        NormalGamma { mu: mu0, kappa: kappa0, alpha: alpha0, beta: beta0 }
    }

    /// Student-t predictive log-density of x under this posterior.
    fn log_pred(&self, x: f64) -> f64 {
        let df = 2.0 * self.alpha;
        let scale2 = self.beta * (self.kappa + 1.0) / (self.alpha * self.kappa);
        let z2 = (x - self.mu) * (x - self.mu) / scale2;
        ln_gamma((df + 1.0) / 2.0)
            - ln_gamma(df / 2.0)
            - 0.5 * (df * std::f64::consts::PI * scale2).ln()
            - (df + 1.0) / 2.0 * (1.0 + z2 / df).ln()
    }

    /// Posterior update with one observation.
    fn update(&self, x: f64) -> Self {
        let kappa1 = self.kappa + 1.0;
        NormalGamma {
            mu: (self.kappa * self.mu + x) / kappa1,
            kappa: kappa1,
            alpha: self.alpha + 0.5,
            beta: self.beta + self.kappa * (x - self.mu) * (x - self.mu) / (2.0 * kappa1),
        }
    }
}

/// Lanczos log-gamma (g=7, n=9) — standard coefficients.
fn ln_gamma(x: f64) -> f64 {
    const G: [f64; 9] = [
        0.999_999_999_999_809_93,
        676.520_368_121_885_1,
        -1259.139_216_722_402_8,
        771.323_428_777_653_13,
        -176.615_029_162_140_6,
        12.507_343_278_686_905,
        -0.138_571_095_265_720_12,
        9.984_369_578_019_571_6e-6,
        1.505_632_735_149_311_6e-7,
    ];
    if x < 0.5 {
        // Reflection.
        return (std::f64::consts::PI / (std::f64::consts::PI * x).sin()).ln() - ln_gamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut a = G[0];
    let t = x + 7.5;
    for (i, &g) in G.iter().enumerate().skip(1) {
        a += g / (x + i as f64);
    }
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
}

/// Configuration of the BOCD detector.
#[derive(Clone, Copy, Debug)]
pub struct BocdConfig {
    /// Constant hazard: expected run length between change-points.
    pub hazard_lambda: f64,
    /// Report a change-point when the posterior mass of "run just reset"
    /// (r_t <= reset_width) exceeds this (paper threshold: 0.9).
    pub threshold: f64,
    /// Run lengths counted as "just reset".
    pub reset_width: usize,
    /// Truncate run-length hypotheses below this posterior mass.
    pub trunc_eps: f64,
    /// Hard cap on retained run-length hypotheses: after eps-truncation the
    /// lowest-mass survivors are dropped until at most this many remain, so
    /// per-step cost and memory are O(max_hypotheses) regardless of stream
    /// length (the R2 always-on requirement). The default sits above the
    /// eps-truncation tail (~2000 at the default hazard), so it only binds
    /// on adversarial configurations.
    pub max_hypotheses: usize,
    /// Prior scale: expected observation magnitude (set from first samples).
    pub prior_mu: f64,
    pub prior_kappa: f64,
    pub prior_alpha: f64,
    pub prior_beta: f64,
}

impl Default for BocdConfig {
    fn default() -> Self {
        BocdConfig {
            hazard_lambda: 250.0,
            threshold: 0.9,
            reset_width: 1,
            trunc_eps: 1e-6,
            max_hypotheses: 4096,
            prior_mu: 0.0, // 0 => auto-set from the first observation
            prior_kappa: 1.0,
            prior_alpha: 1.0,
            prior_beta: 0.01,
        }
    }
}

/// Online BOCD state.
#[derive(Clone, Debug)]
pub struct Bocd {
    cfg: BocdConfig,
    /// Run-length posterior (index = run length), aligned with `models`.
    probs: Vec<f64>,
    models: Vec<NormalGamma>,
    t: usize,
    initialized: bool,
    prev_map_rl: usize,
}

impl Bocd {
    pub fn new(cfg: BocdConfig) -> Self {
        Bocd {
            cfg,
            probs: vec![1.0],
            models: Vec::new(),
            t: 0,
            initialized: false,
            prev_map_rl: 0,
        }
    }

    /// Feed one observation; returns `Some(p_reset)` when a change-point is
    /// declared at this step.
    pub fn push(&mut self, x: f64) -> Option<f64> {
        // A NaN/infinite iteration time (clock glitch, dropped measurement)
        // must not enter the posterior: one such sample would turn every
        // run-length probability into NaN permanently. Drop it.
        if !x.is_finite() {
            return None;
        }
        if !self.initialized {
            let mu0 = if self.cfg.prior_mu != 0.0 { self.cfg.prior_mu } else { x };
            let beta0 = (self.cfg.prior_beta * mu0 * mu0).max(1e-12);
            self.models =
                vec![NormalGamma::prior(mu0, self.cfg.prior_kappa, self.cfg.prior_alpha, beta0)];
            self.initialized = true;
        }

        let h = 1.0 / self.cfg.hazard_lambda;
        let n = self.probs.len();

        // Growth + changepoint probabilities.
        let mut new_probs = vec![0.0; n + 1];
        let mut cp_mass = 0.0;
        for r in 0..n {
            let pred = self.models[r].log_pred(x).exp().max(1e-300);
            let joint = self.probs[r] * pred;
            new_probs[r + 1] = joint * (1.0 - h);
            cp_mass += joint * h;
        }
        new_probs[0] = cp_mass;

        // Normalize.
        let z: f64 = new_probs.iter().sum();
        if z > 0.0 {
            for p in &mut new_probs {
                *p /= z;
            }
        }

        // Update posteriors: run r+1 extends model r; run 0 restarts from
        // the prior re-anchored at the previous posterior mean of the MAP
        // run (keeps scale adaptive without peeking at x).
        let map_r = argmax(&self.probs);
        let anchor = self.models[map_r].mu;
        let beta0 = (self.cfg.prior_beta * anchor * anchor).max(1e-12);
        let mut new_models = Vec::with_capacity(n + 1);
        new_models.push(NormalGamma::prior(x, self.cfg.prior_kappa, self.cfg.prior_alpha, beta0));
        for r in 0..n {
            new_models.push(self.models[r].update(x));
        }

        // Truncate negligible hypotheses (linear-time guarantee).
        let mut keep: Vec<usize> = (0..new_probs.len())
            .filter(|&i| new_probs[i] > self.cfg.trunc_eps || i == 0)
            .collect();
        // Hard cap: drop the lowest-mass survivors (never index 0) until the
        // hypothesis set fits, keeping memory O(max_hypotheses).
        let cap = self.cfg.max_hypotheses.max(1);
        if keep.len() > cap {
            let mut rest: Vec<usize> = keep.iter().copied().filter(|&i| i != 0).collect();
            rest.sort_by(|&a, &b| new_probs[b].total_cmp(&new_probs[a]));
            rest.truncate(cap.saturating_sub(1));
            rest.push(0);
            rest.sort_unstable();
            keep = rest;
        }
        self.probs = keep.iter().map(|&i| new_probs[i]).collect();
        self.models = keep.iter().map(|&i| new_models[i]).collect();
        // Renormalize, guarding the degenerate case (all retained mass
        // underflowed to zero): without the guard a 0/0 poisons every
        // subsequent step with NaN. Fall back to a uniform posterior over
        // the retained hypotheses instead.
        let z: f64 = self.probs.iter().sum();
        if z > 0.0 && z.is_finite() {
            for p in &mut self.probs {
                *p /= z;
            }
        } else {
            let n = self.probs.len() as f64;
            for p in &mut self.probs {
                *p = 1.0 / n;
            }
        }

        self.t += 1;
        let p_reset: f64 = self
            .probs
            .iter()
            .take(self.cfg.reset_width + 1)
            .sum();
        // Change-point criteria: the paper's posterior-mass rule, OR the
        // standard MAP run-length collapse (the posterior mode jumping back
        // to ~0 after a long run) — the latter catches changes whose reset
        // mass is spread over r in {0, 1, 2}.
        let map_rl = self.map_run_length();
        let collapsed = self.prev_map_rl >= 8
            && map_rl + 4 < self.prev_map_rl
            && map_rl <= self.cfg.reset_width + 2;
        self.prev_map_rl = map_rl;
        if self.t > 2 && (p_reset > self.cfg.threshold || collapsed) {
            Some(p_reset.max(self.cfg.threshold))
        } else {
            None
        }
    }

    /// Posterior-mode run length (diagnostic).
    pub fn map_run_length(&self) -> usize {
        argmax(&self.probs)
    }

    /// Retained run-length hypotheses (diagnostic; bounded by
    /// `max_hypotheses`).
    pub fn n_hypotheses(&self) -> usize {
        self.probs.len()
    }

    /// All run-length probabilities are finite and sum to ~1 (invariant
    /// check used by the NaN-robustness tests).
    pub fn posterior_healthy(&self) -> bool {
        let z: f64 = self.probs.iter().sum();
        self.probs.iter().all(|p| p.is_finite() && *p >= 0.0) && (z - 1.0).abs() < 1e-6
    }
}

fn argmax(xs: &[f64]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

/// Offline convenience: run BOCD over a series, returning change-point
/// indices (the raw-BOCD baseline of Tables 4–5).
pub fn detect_changepoints(xs: &[f64], cfg: BocdConfig) -> Vec<usize> {
    let mut bocd = Bocd::new(cfg);
    let mut out = Vec::new();
    for (i, &x) in xs.iter().enumerate() {
        if bocd.push(x).is_some() {
            out.push(i);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn series(segments: &[(usize, f64)], noise: f64, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        let mut out = Vec::new();
        for &(n, mean) in segments {
            for _ in 0..n {
                out.push(mean * (1.0 + noise * rng.normal()));
            }
        }
        out
    }

    #[test]
    fn ln_gamma_matches_known_values() {
        // Γ(1)=1, Γ(2)=1, Γ(5)=24, Γ(0.5)=sqrt(pi)
        assert!((ln_gamma(1.0)).abs() < 1e-9);
        assert!((ln_gamma(2.0)).abs() < 1e-9);
        assert!((ln_gamma(5.0) - 24f64.ln()).abs() < 1e-9);
        assert!((ln_gamma(0.5) - 0.5 * std::f64::consts::PI.ln()).abs() < 1e-9);
    }

    #[test]
    fn detects_step_change() {
        let xs = series(&[(80, 1.0), (80, 1.5)], 0.02, 1);
        let cps = detect_changepoints(&xs, BocdConfig::default());
        assert!(
            cps.iter().any(|&c| (78..=86).contains(&c)),
            "change at ~80 not found: {cps:?}"
        );
    }

    #[test]
    fn detects_relief_too() {
        let xs = series(&[(60, 1.5), (60, 1.0)], 0.02, 2);
        let cps = detect_changepoints(&xs, BocdConfig::default());
        assert!(cps.iter().any(|&c| (58..=66).contains(&c)), "{cps:?}");
    }

    #[test]
    fn quiet_series_has_no_changepoints() {
        let xs = series(&[(300, 2.0)], 0.02, 3);
        let cps = detect_changepoints(&xs, BocdConfig::default());
        assert!(cps.len() <= 1, "stable series flagged: {cps:?}");
    }

    #[test]
    fn raw_bocd_fires_on_jitter_spikes() {
        // The paper's motivation for verification: transient spikes make raw
        // BOCD produce (false) change-points.
        let mut xs = series(&[(200, 1.0)], 0.015, 4);
        for i in [50usize, 120, 180] {
            xs[i] = 1.6; // single-iteration jitter spikes
        }
        let cps = detect_changepoints(&xs, BocdConfig::default());
        assert!(!cps.is_empty(), "spikes should trigger raw BOCD");
    }

    #[test]
    fn small_shift_below_10pct_still_detectable() {
        // BOCD itself is sensitive; the 10% rule lives in the verifier.
        let xs = series(&[(100, 1.0), (100, 1.08)], 0.01, 5);
        let cps = detect_changepoints(&xs, BocdConfig::default());
        assert!(cps.iter().any(|&c| (95..=115).contains(&c)), "{cps:?}");
    }

    #[test]
    fn non_finite_observations_do_not_corrupt_state() {
        // One NaN/infinite iteration time must neither panic nor poison the
        // run-length posterior: detection still works on the samples around
        // it.
        let mut xs = series(&[(80, 1.0), (80, 1.5)], 0.02, 11);
        xs[20] = f64::NAN;
        xs[40] = f64::INFINITY;
        xs[60] = f64::NEG_INFINITY;
        let mut bocd = Bocd::new(BocdConfig::default());
        let mut cps = Vec::new();
        for (i, &x) in xs.iter().enumerate() {
            if bocd.push(x).is_some() {
                cps.push(i);
            }
            assert!(bocd.posterior_healthy(), "posterior corrupted at obs {i}");
        }
        assert!(
            cps.iter().any(|&c| (78..=90).contains(&c)),
            "step change missed after non-finite samples: {cps:?}"
        );
    }

    #[test]
    fn leading_nan_rejected_before_initialization() {
        // A NaN as the *first* sample must not seed the prior.
        let mut bocd = Bocd::new(BocdConfig::default());
        assert!(bocd.push(f64::NAN).is_none());
        for x in [1.0, 1.01, 0.99, 1.02] {
            bocd.push(x);
            assert!(bocd.posterior_healthy());
        }
    }

    #[test]
    fn hypothesis_cap_bounds_memory() {
        let cfg = BocdConfig { max_hypotheses: 64, trunc_eps: 0.0, ..BocdConfig::default() };
        let xs = series(&[(2000, 1.0)], 0.02, 12);
        let mut bocd = Bocd::new(cfg);
        for &x in &xs {
            bocd.push(x);
            assert!(bocd.n_hypotheses() <= 64);
            assert!(bocd.posterior_healthy());
        }
    }

    #[test]
    fn linear_time_truncation() {
        // Posterior vector stays bounded (truncation) over a long stream.
        let xs = series(&[(5000, 1.0)], 0.02, 6);
        let mut bocd = Bocd::new(BocdConfig::default());
        for &x in &xs {
            bocd.push(x);
        }
        assert!(bocd.probs.len() < 2000, "run-length vector grew unbounded");
    }
}
