//! Profiling phase (§4.3): narrow the search space to suspicious groups.
//!
//! The GlobalAnalyzer aggregates per-group transfer times (gathered by the
//! monitor's injected CUDA events) and classifies a communication group as
//! *suspicious* when its mean transfer time exceeds `1.1x` the median
//! across comparable groups — prolonged transfer indicates degradation,
//! eager idling indicates health.

use crate::util::stats;

/// Suspicion multiplier over the median (paper: 1.1x).
pub const SUSPICION_FACTOR: f64 = 1.1;

/// One profiled group: opaque id, member ranks, mean seconds per op.
#[derive(Clone, Debug)]
pub struct GroupProfile {
    pub id: u64,
    pub ranks: Vec<usize>,
    pub mean_time: f64,
}

/// Groups whose transfer time exceeds `factor` x median. Compares within
/// the given set, which callers keep homogeneous (DP rings with DP rings,
/// PP chains with PP chains) since their nominal volumes differ.
pub fn suspicious_groups(profiles: &[GroupProfile], factor: f64) -> Vec<GroupProfile> {
    if profiles.is_empty() {
        return vec![];
    }
    let times: Vec<f64> = profiles.iter().map(|p| p.mean_time).collect();
    let med = stats::median(&times);
    profiles
        .iter()
        .filter(|p| p.mean_time > factor * med)
        .cloned()
        .collect()
}

/// Partition raw (id, ranks, time) tuples into profiles.
pub fn to_profiles(raw: &[(u64, Vec<usize>, f64)]) -> Vec<GroupProfile> {
    raw.iter()
        .map(|(id, ranks, t)| GroupProfile { id: *id, ranks: ranks.clone(), mean_time: *t })
        .collect()
}

/// Union of ranks across suspicious groups — the validation phase's scope.
pub fn candidate_ranks(suspicious: &[GroupProfile]) -> Vec<usize> {
    let mut out: Vec<usize> = suspicious.iter().flat_map(|g| g.ranks.iter().cloned()).collect();
    out.sort_unstable();
    out.dedup();
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn prof(id: u64, ranks: &[usize], t: f64) -> GroupProfile {
        GroupProfile { id, ranks: ranks.to_vec(), mean_time: t }
    }

    #[test]
    fn flags_only_outliers() {
        let groups = vec![
            prof(1, &[0, 1], 1.0),
            prof(2, &[2, 3], 1.02),
            prof(3, &[4, 5], 2.5),
            prof(4, &[6, 7], 0.98),
        ];
        let sus = suspicious_groups(&groups, SUSPICION_FACTOR);
        assert_eq!(sus.len(), 1);
        assert_eq!(sus[0].id, 3);
    }

    #[test]
    fn healthy_cluster_yields_none() {
        let groups: Vec<GroupProfile> =
            (0..8).map(|i| prof(i, &[i as usize], 1.0 + 0.01 * i as f64)).collect();
        assert!(suspicious_groups(&groups, SUSPICION_FACTOR).is_empty());
    }

    #[test]
    fn all_slow_is_relative() {
        // If EVERY group is equally slow (e.g. model change) nothing stands
        // out — profiling is a relative filter, by design.
        let groups: Vec<GroupProfile> = (0..4).map(|i| prof(i, &[i as usize], 5.0)).collect();
        assert!(suspicious_groups(&groups, SUSPICION_FACTOR).is_empty());
    }

    #[test]
    fn candidate_ranks_dedup() {
        let sus = vec![prof(1, &[4, 2, 0], 2.0), prof(2, &[2, 6], 2.0)];
        assert_eq!(candidate_ranks(&sus), vec![0, 2, 4, 6]);
    }

    #[test]
    fn search_space_reduction() {
        // 64 groups, one degraded: validation scope shrinks from 128 ranks
        // to 2 — the R4 "lightweight" claim quantified.
        let mut groups: Vec<GroupProfile> = (0..64)
            .map(|i| prof(i, &[2 * i as usize, 2 * i as usize + 1], 1.0))
            .collect();
        groups[17].mean_time = 3.0;
        let sus = suspicious_groups(&groups, SUSPICION_FACTOR);
        let ranks = candidate_ranks(&sus);
        assert_eq!(ranks, vec![34, 35]);
    }
}
