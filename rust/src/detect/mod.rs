//! FALCON-DETECT (§4): non-intrusive, framework-agnostic fail-slow
//! detection in three phases — tracking (ACF iteration-time inference +
//! BOCD+V slow-iteration detection), profiling (suspicious-group
//! identification), and validation (O(1) P2P pass decomposition + GEMM
//! dispatch). Baselines for Tables 4–5 live in `window` (SlideWindow) and
//! `bocd::detect_changepoints` (raw BOCD).

pub mod acf;
pub mod bocd;
pub mod detector;
pub mod profiler;
pub mod validate;
pub mod window;

pub use bocd::{Bocd, BocdConfig};
pub use detector::{detect_episodes, Detector, Episode};
pub use profiler::{suspicious_groups, GroupProfile, SUSPICION_FACTOR};
pub use validate::{ring_plan, tree_plan, validate_comm, validate_compute, SlowEdge, SlowGpu};
