//! Collective-communication substrate (the "NCCL" of the simulator).
//!
//! Implements ring and tree collective topologies over rank groups, an
//! α–β cost model evaluated against live cluster health (so congested
//! uplinks slow exactly the collectives whose rings cross them), and the
//! edge enumeration shared with FALCON-DETECT's O(1) validator (§4.3).
//!
//! The *live* trainer uses `reduce_inplace`/`tree_allreduce_live` for real
//! f32 gradient reductions between DP worker threads.

use crate::fabric::{Cluster, GpuId};
use crate::util::rng::Rng;

/// Watchdog timeout (seconds) a *hung* collective charges before the
/// runtime declares it wedged. A hang blocks instead of stretching, so
/// its observable cost is this fixed timeout — large against a healthy
/// iteration (~1 s) yet finite, so the sim progresses, BOCD fires fast,
/// and the op-trace records the blocked edge for `crate::diagnose`.
pub const HANG_WATCHDOG_S: f64 = 30.0;

/// Collective op kinds logged by the monitor shim (Fig 8's vocabulary).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CollOp {
    AllReduce,
    ReduceScatter,
    AllGather,
    Send,
    Recv,
    Broadcast,
}

impl CollOp {
    pub fn name(self) -> &'static str {
        match self {
            CollOp::AllReduce => "AR",
            CollOp::ReduceScatter => "RS",
            CollOp::AllGather => "AG",
            CollOp::Send => "SEND",
            CollOp::Recv => "RECV",
            CollOp::Broadcast => "BC",
        }
    }
}

/// Communicator topology used by a group.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Topology {
    Ring,
    Tree,
}

/// A communication group: ordered ranks plus their physical GPUs.
#[derive(Clone, Debug)]
pub struct CommGroup {
    pub ranks: Vec<usize>,
    pub gpus: Vec<GpuId>,
    pub topology: Topology,
}

impl CommGroup {
    pub fn new(ranks: Vec<usize>, gpus: Vec<GpuId>, topology: Topology) -> Self {
        assert_eq!(ranks.len(), gpus.len());
        CommGroup { ranks, gpus, topology }
    }

    pub fn len(&self) -> usize {
        self.ranks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ranks.is_empty()
    }

    /// Directed edges the collective traverses.
    ///
    /// Ring: i -> i+1 (mod n). Tree: parent<->child edges of the binary
    /// tree rooted at index 0 (NCCL-style rank-order tree).
    pub fn edges(&self) -> Vec<(usize, usize)> {
        match self.topology {
            Topology::Ring => {
                let n = self.len();
                (0..n).map(|i| (i, (i + 1) % n)).collect()
            }
            Topology::Tree => {
                let n = self.len();
                let mut out = Vec::new();
                for i in 0..n {
                    for c in [2 * i + 1, 2 * i + 2] {
                        if c < n {
                            out.push((i, c));
                        }
                    }
                }
                out
            }
        }
    }

    /// Ring all-reduce time (seconds): 2(n-1) steps moving `bytes`/n each,
    /// paced by the slowest edge at current health.
    ///
    /// Equivalent to `self.allreduce_plan(cluster, bytes).sample(rng)` —
    /// the deterministic per-edge base is recomputed here on every call;
    /// hot paths cache the [`AllReducePlan`] instead.
    pub fn allreduce_time_s(&self, cluster: &Cluster, bytes: f64, rng: &mut Rng) -> f64 {
        self.allreduce_plan(cluster, bytes).sample(rng)
    }

    /// The cacheable deterministic half of [`CommGroup::allreduce_time_s`]:
    /// per-edge nominal transfer times and jitter CoVs frozen at the
    /// current cluster health. Valid until the health of a node the group
    /// touches changes (see `fabric::Cluster::generation_sum`).
    pub fn allreduce_plan(&self, cluster: &Cluster, bytes: f64) -> AllReducePlan {
        let n = self.len();
        if n <= 1 {
            return AllReducePlan::default();
        }
        match self.topology {
            Topology::Ring => {
                let chunk = bytes / n as f64;
                let mut edges = Vec::with_capacity(n);
                let mut hung_edges = Vec::new();
                for (i, (a, b)) in self.edges().into_iter().enumerate() {
                    let t = cluster.transfer_time_nominal_s(self.gpus[a], self.gpus[b], chunk);
                    let cov = cluster.link_class(self.gpus[a], self.gpus[b]).base_cov();
                    if cluster.path_hung(self.gpus[a], self.gpus[b]) {
                        hung_edges.push(i);
                    }
                    edges.push((t, cov));
                }
                AllReducePlan { edges, rounds: 2.0 * (n - 1) as f64, hung_edges }
            }
            Topology::Tree => {
                // Reduce up + broadcast down: 2 * depth rounds of `bytes`.
                let depth = (usize::BITS - n.leading_zeros()) as f64;
                let mut edges = Vec::with_capacity(n - 1);
                let mut hung_edges = Vec::new();
                for (i, (a, b)) in self.edges().into_iter().enumerate() {
                    let t = cluster.transfer_time_nominal_s(self.gpus[a], self.gpus[b], bytes);
                    let cov = cluster.link_class(self.gpus[a], self.gpus[b]).base_cov();
                    if cluster.path_hung(self.gpus[a], self.gpus[b]) {
                        hung_edges.push(i);
                    }
                    edges.push((t, cov));
                }
                AllReducePlan { edges, rounds: 2.0 * depth, hung_edges }
            }
        }
    }

    /// Point-to-point transfer time between two member indices.
    pub fn p2p_time_s(
        &self,
        cluster: &mut Cluster,
        from: usize,
        to: usize,
        bytes: f64,
        rng: &mut Rng,
    ) -> f64 {
        cluster.transfer_time_s(self.gpus[from], self.gpus[to], bytes, rng)
    }
}

/// Deterministic base of one all-reduce, memoizable across iterations:
/// `(nominal edge seconds, link CoV)` in [`CommGroup::edges`] order plus
/// the synchronous round count (2(n-1) ring steps, 2·depth tree rounds).
/// [`AllReducePlan::sample`] layers the per-call measurement noise on top
/// with the exact RNG stream and arithmetic of the uncached path;
/// [`AllReducePlan::nominal`] is the noise-free planner value and draws
/// nothing.
#[derive(Clone, Debug, Default)]
pub struct AllReducePlan {
    /// (nominal edge time seconds, link CoV), one entry per edge.
    pub edges: Vec<(f64, f64)>,
    /// Synchronous rounds each edge is traversed.
    pub rounds: f64,
    /// Edge indices (into `edges`) whose path is hung: the collective
    /// blocks on them and both `sample` and `nominal` return the
    /// [`HANG_WATCHDOG_S`] timeout instead of the α–β estimate.
    pub hung_edges: Vec<usize>,
}

impl AllReducePlan {
    /// Apply per-call measurement noise: one `rng.normal()` per edge, in
    /// edge order, slowest noisy edge paces every round. A hung edge
    /// overrides the result with the watchdog timeout — every per-edge
    /// normal is still drawn first, so the RNG stream position never
    /// depends on hang state (the cached-vs-naive bit-equality contract).
    pub fn sample(&self, rng: &mut Rng) -> f64 {
        let mut worst = 0.0f64;
        for &(t, cov) in &self.edges {
            let t = t * (1.0 + cov * rng.normal()).max(0.05);
            worst = worst.max(t);
        }
        if !self.hung_edges.is_empty() {
            return HANG_WATCHDOG_S;
        }
        self.rounds * worst
    }

    /// Noise-free value at the frozen health; touches no RNG.
    pub fn nominal(&self) -> f64 {
        if !self.hung_edges.is_empty() {
            return HANG_WATCHDOG_S;
        }
        let mut worst = 0.0f64;
        for &(t, _) in &self.edges {
            worst = worst.max(t);
        }
        self.rounds * worst
    }
}

// ---------------------------------------------------------------------------
// Live (real-data) reductions for the in-process DP trainer.
// ---------------------------------------------------------------------------

/// Sum `src` into `dst` elementwise (the core of a real all-reduce).
pub fn reduce_inplace(dst: &mut [f32], src: &[f32]) {
    assert_eq!(dst.len(), src.len());
    for (d, s) in dst.iter_mut().zip(src) {
        *d += *s;
    }
}

/// Real tree all-reduce over per-worker gradient buffers: pairwise sums up
/// a binary tree then averages. Returns the averaged buffer.
pub fn tree_allreduce_live(mut bufs: Vec<Vec<f32>>) -> Vec<f32> {
    assert!(!bufs.is_empty());
    let n = bufs.len();
    let mut stride = 1;
    while stride < n {
        let mut i = 0;
        while i + stride < n {
            // Split borrow: sum bufs[i+stride] into bufs[i].
            let (left, right) = bufs.split_at_mut(i + stride);
            reduce_inplace(&mut left[i], &right[0]);
            i += 2 * stride;
        }
        stride *= 2;
    }
    let inv = 1.0 / n as f32;
    let mut out = std::mem::take(&mut bufs[0]);
    for x in &mut out {
        *x *= inv;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::{ClusterSpec, GpuClass};

    fn group(cluster: &Cluster, ranks: &[usize], topo: Topology) -> CommGroup {
        let gpus = ranks.iter().map(|&r| cluster.gpu_by_flat(r)).collect();
        CommGroup::new(ranks.to_vec(), gpus, topo)
    }

    #[test]
    fn ring_edges_close_cycle() {
        let c = Cluster::new(ClusterSpec::new(2, 4, GpuClass::A100));
        let g = group(&c, &[0, 1, 2, 3], Topology::Ring);
        assert_eq!(g.edges(), vec![(0, 1), (1, 2), (2, 3), (3, 0)]);
    }

    #[test]
    fn tree_edges_cover_all_non_roots() {
        let c = Cluster::new(ClusterSpec::new(2, 4, GpuClass::A100));
        let g = group(&c, &[0, 1, 2, 3, 4, 5, 6], Topology::Tree);
        let edges = g.edges();
        assert_eq!(edges.len(), 6); // n-1 edges
        let mut has_parent = vec![false; 7];
        for (_, b) in edges {
            assert!(!has_parent[b], "single parent");
            has_parent[b] = true;
        }
        assert!(!has_parent[0], "root has no parent");
        assert!(has_parent[1..].iter().all(|&x| x));
    }

    #[test]
    fn congested_edge_slows_ring_allreduce() {
        let mut c = Cluster::new(ClusterSpec::new(4, 2, GpuClass::H800));
        let mut rng = Rng::new(3);
        // DP ring across nodes: GPUs 0,2,4,6 (one per node).
        let g = group(&c, &[0, 2, 4, 6], Topology::Ring);
        let healthy = g.allreduce_time_s(&c, 1e9, &mut rng);
        c.uplinks[2].bandwidth_scale = 0.2;
        let congested = g.allreduce_time_s(&c, 1e9, &mut rng);
        assert!(congested > 3.0 * healthy, "{congested} vs {healthy}");
    }

    #[test]
    fn cross_job_contention_slows_inter_node_allreduce() {
        // A co-resident job's contention share (LinkState::external_scale,
        // set by the shared-cluster fleet driver) slows exactly the
        // collectives whose rings cross the contended uplink.
        let mut c = Cluster::new(ClusterSpec::new(4, 2, GpuClass::H800));
        let mut rng = Rng::new(11);
        let g = group(&c, &[0, 2, 4, 6], Topology::Ring);
        let alone = g.allreduce_time_s(&c, 1e9, &mut rng);
        for n in 0..4 {
            c.set_external_scale(n, 0.5);
        }
        let contended = g.allreduce_time_s(&c, 1e9, &mut rng);
        assert!(contended > 1.6 * alone, "{contended} vs {alone}");
    }

    #[test]
    fn intra_node_ring_immune_to_uplink_congestion() {
        let mut c = Cluster::new(ClusterSpec::new(2, 4, GpuClass::H800));
        let mut rng = Rng::new(4);
        let g = group(&c, &[0, 1, 2, 3], Topology::Ring); // all on node 0
        let before = g.allreduce_time_s(&c, 1e8, &mut rng);
        c.uplinks[0].bandwidth_scale = 0.1;
        let after = g.allreduce_time_s(&c, 1e8, &mut rng);
        assert!((after - before).abs() / before < 0.2, "{after} vs {before}");
    }

    #[test]
    fn allreduce_scales_with_bytes() {
        let c = Cluster::new(ClusterSpec::new(4, 2, GpuClass::H800));
        let mut rng = Rng::new(5);
        let g = group(&c, &[0, 2, 4, 6], Topology::Ring);
        let t1 = g.allreduce_time_s(&c, 1e8, &mut rng);
        let t10 = g.allreduce_time_s(&c, 1e9, &mut rng);
        assert!(t10 > 5.0 * t1, "{t10} vs {t1}");
    }

    #[test]
    fn plan_split_preserves_stream_and_value() {
        // The cached plan must reproduce the one-shot path bit for bit and
        // leave the RNG at the same position; nominal() must draw nothing.
        let mut c = Cluster::new(ClusterSpec::new(4, 2, GpuClass::H800));
        c.uplinks[2].bandwidth_scale = 0.4;
        for topo in [Topology::Ring, Topology::Tree] {
            let g = group(&c, &[0, 2, 4, 6], topo);
            let plan = g.allreduce_plan(&c, 1e9);
            let mut r1 = Rng::new(21);
            let mut r2 = Rng::new(21);
            let direct = g.allreduce_time_s(&c, 1e9, &mut r1);
            let cached = plan.sample(&mut r2);
            assert_eq!(direct.to_bits(), cached.to_bits());
            assert_eq!(r1.next_u64(), r2.next_u64(), "stream diverged");
            assert!(plan.nominal() > 0.0);
        }
        let solo = group(&c, &[0], Topology::Ring);
        assert!(solo.allreduce_plan(&c, 1e9).edges.is_empty());
        assert_eq!(solo.allreduce_plan(&c, 1e9).nominal(), 0.0);
    }

    #[test]
    fn hung_edge_blocks_at_watchdog_and_preserves_stream() {
        let mut c = Cluster::new(ClusterSpec::new(4, 2, GpuClass::H800));
        let g = group(&c, &[0, 2, 4, 6], Topology::Ring);
        let healthy = g.allreduce_plan(&c, 1e9);
        assert!(healthy.hung_edges.is_empty());
        c.set_path_hang(1, 2, true);
        let hung = g.allreduce_plan(&c, 1e9);
        assert_eq!(hung.hung_edges, vec![1], "ring edge node1->node2");
        assert_eq!(hung.nominal(), HANG_WATCHDOG_S);
        assert!(hung.nominal() > 20.0 * healthy.nominal(), "a hang dwarfs a healthy step");
        // The hang must not move the RNG stream: sample() draws exactly
        // one normal per edge whether or not an edge is hung.
        let mut r1 = Rng::new(33);
        let mut r2 = Rng::new(33);
        assert_eq!(hung.sample(&mut r1), HANG_WATCHDOG_S);
        let _ = healthy.sample(&mut r2);
        assert_eq!(r1.next_u64(), r2.next_u64(), "stream diverged on hang");
        // Uplink-wide hang ((u, u) key) wedges every edge touching node 3.
        c.set_path_hang(1, 2, false);
        c.set_path_hang(3, 3, true);
        let wedged = g.allreduce_plan(&c, 1e9);
        assert_eq!(wedged.hung_edges, vec![2, 3], "both edges at node 3");
    }

    #[test]
    fn singleton_group_is_free() {
        let c = Cluster::new(ClusterSpec::new(1, 8, GpuClass::A100));
        let mut rng = Rng::new(6);
        let g = group(&c, &[0], Topology::Ring);
        assert_eq!(g.allreduce_time_s(&c, 1e9, &mut rng), 0.0);
    }

    #[test]
    fn reduce_inplace_sums() {
        let mut a = vec![1.0, 2.0, 3.0];
        reduce_inplace(&mut a, &[10.0, 20.0, 30.0]);
        assert_eq!(a, vec![11.0, 22.0, 33.0]);
    }

    #[test]
    fn tree_allreduce_live_averages() {
        for n in 1..=9 {
            let bufs: Vec<Vec<f32>> = (0..n).map(|i| vec![i as f32, 2.0 * i as f32]).collect();
            let out = tree_allreduce_live(bufs);
            let expect0 = (0..n).map(|i| i as f32).sum::<f32>() / n as f32;
            assert!((out[0] - expect0).abs() < 1e-5, "n={n}: {} vs {expect0}", out[0]);
            assert!((out[1] - 2.0 * expect0).abs() < 1e-4);
        }
    }
}
