//! Checkpoint subsystem: parameter dump/restore over memory and disk.
//!
//! Backs two paper mechanisms: S3's lightweight parameter swap ("temporally
//! dumping parameters into main memory ... via RDMA", §5.3) and S4's
//! checkpoint-and-restart. Fig 19 compares the memory path (M) against the
//! disk baseline (D) across GPU-memory-utilization levels — here measured
//! on *real* buffers so the ratio is an honest measurement on this host —
//! and a calibrated cost model extrapolates to paper-scale jobs (a
//! GPT2-100B dump is ~100 minutes, §5.1).

use std::io::{Error, ErrorKind, Read, Result, Write};
use std::path::{Path, PathBuf};
// audit:allow(clock-hygiene): this module times *real* dump/restore I/O
// on the host (Fig 19 is an honest measurement, not simulated time).
use std::time::Instant;

/// In-memory checkpoint store (S3's fast path).
#[derive(Default)]
pub struct MemoryStore {
    slots: std::collections::BTreeMap<String, Vec<u8>>,
}

impl MemoryStore {
    pub fn new() -> Self {
        Self::default()
    }

    /// Dump a buffer; returns elapsed seconds.
    pub fn dump(&mut self, key: &str, data: &[u8]) -> f64 {
        let t0 = Instant::now();
        self.slots.insert(key.to_string(), data.to_vec());
        t0.elapsed().as_secs_f64()
    }

    /// Restore into a caller buffer; returns elapsed seconds.
    pub fn load(&self, key: &str, out: &mut Vec<u8>) -> Result<f64> {
        let t0 = Instant::now();
        let src = self
            .slots
            .get(key)
            .ok_or_else(|| {
                Error::new(ErrorKind::NotFound, format!("missing checkpoint slot {key}"))
            })?;
        out.clear();
        out.extend_from_slice(src);
        Ok(t0.elapsed().as_secs_f64())
    }

    pub fn contains(&self, key: &str) -> bool {
        self.slots.contains_key(key)
    }

    pub fn bytes(&self) -> usize {
        self.slots.values().map(|v| v.len()).sum()
    }
}

/// Disk checkpoint store (S4 / Fig 19's baseline).
pub struct DiskStore {
    dir: PathBuf,
}

impl DiskStore {
    pub fn new(dir: impl AsRef<Path>) -> Result<Self> {
        std::fs::create_dir_all(dir.as_ref())?;
        Ok(DiskStore { dir: dir.as_ref().to_path_buf() })
    }

    fn path(&self, key: &str) -> PathBuf {
        self.dir.join(format!("{key}.ckpt"))
    }

    /// Dump with fsync (a checkpoint that can be lost is not a checkpoint);
    /// returns elapsed seconds.
    pub fn dump(&self, key: &str, data: &[u8]) -> Result<f64> {
        let t0 = Instant::now();
        let mut f = std::fs::File::create(self.path(key))?;
        f.write_all(data)?;
        f.sync_all()?;
        Ok(t0.elapsed().as_secs_f64())
    }

    pub fn load(&self, key: &str, out: &mut Vec<u8>) -> Result<f64> {
        let t0 = Instant::now();
        let mut f = std::fs::File::open(self.path(key))
            .map_err(|e| Error::new(e.kind(), format!("open checkpoint {key}: {e}")))?;
        out.clear();
        f.read_to_end(out)?;
        Ok(t0.elapsed().as_secs_f64())
    }
}

/// Cost model for paper-scale checkpoints, calibrated by two effective
/// bandwidths (bytes/sec). Defaults: host-memory dump over NVLink+PCIe
/// ~20 GB/s; shared-filesystem dump ~3 GB/s (both per the ratios in
/// Fig 19's ~6.7x gap once load+dump are combined).
#[derive(Clone, Copy, Debug)]
pub struct CkptCostModel {
    pub mem_bw: f64,
    pub disk_bw: f64,
    /// Fixed orchestration cost per dump/restore (seconds).
    pub fixed_s: f64,
}

impl Default for CkptCostModel {
    fn default() -> Self {
        CkptCostModel { mem_bw: 20e9, disk_bw: 3e9, fixed_s: 4.0 }
    }
}

impl CkptCostModel {
    pub fn mem_roundtrip_s(&self, bytes: f64) -> f64 {
        2.0 * (bytes / self.mem_bw) + self.fixed_s
    }

    pub fn disk_roundtrip_s(&self, bytes: f64) -> f64 {
        2.0 * (bytes / self.disk_bw) + self.fixed_s
    }

    /// Full checkpoint-restart cost for S4: dump + reschedule + restore.
    pub fn restart_cost_s(&self, bytes: f64, reschedule_s: f64) -> f64 {
        self.disk_roundtrip_s(bytes) + reschedule_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn payload(n: usize, seed: u8) -> Vec<u8> {
        (0..n).map(|i| (i as u8).wrapping_mul(31).wrapping_add(seed)).collect()
    }

    #[test]
    fn memory_round_trip_exact() {
        let mut store = MemoryStore::new();
        let data = payload(1 << 20, 7);
        store.dump("params", &data);
        let mut out = Vec::new();
        store.load("params", &mut out).unwrap();
        assert_eq!(out, data);
    }

    #[test]
    fn disk_round_trip_exact() {
        let dir = std::env::temp_dir().join("falcon_ckpt_test");
        let store = DiskStore::new(&dir).unwrap();
        let data = payload(1 << 20, 9);
        store.dump("params", &data).unwrap();
        let mut out = Vec::new();
        store.load("params", &mut out).unwrap();
        assert_eq!(out, data);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn missing_key_errors() {
        let store = MemoryStore::new();
        let mut out = Vec::new();
        assert!(store.load("nope", &mut out).is_err());
    }

    #[test]
    fn memory_faster_than_disk_on_real_buffers() {
        // The Fig 19 direction on this host: memory round-trip beats
        // fsync'd disk for a multi-MB buffer.
        let dir = std::env::temp_dir().join("falcon_ckpt_bench_test");
        let disk = DiskStore::new(&dir).unwrap();
        let mut mem = MemoryStore::new();
        let data = payload(8 << 20, 3);

        let mut out = Vec::new();
        let t_mem = mem.dump("p", &data) + mem.load("p", &mut out).unwrap();
        let t_disk = disk.dump("p", &data).unwrap() + {
            let mut o2 = Vec::new();
            disk.load("p", &mut o2).unwrap()
        };
        assert!(t_mem < t_disk, "mem {t_mem} vs disk {t_disk}");
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn cost_model_paper_anchors() {
        let m = CkptCostModel::default();
        // GPT2-100B-class checkpoint (params+optimizer ~ 1.2 TB): disk
        // round-trip lands in the tens-of-minutes band (§5.1 cites ~100 min
        // for dump infrastructure; our default bw is optimistic-modern).
        let bytes = 1.2e12;
        let t = m.disk_roundtrip_s(bytes) / 60.0;
        assert!(t > 10.0 && t < 120.0, "{t} min");
        // Memory path is several times faster (Fig 19: up to 6.7x).
        let ratio = m.disk_roundtrip_s(bytes) / m.mem_roundtrip_s(bytes);
        assert!(ratio > 4.0 && ratio < 8.0, "ratio {ratio}");
    }

    #[test]
    fn ratio_grows_with_size() {
        // Fig 19: gains more pronounced at higher memory utilization
        // (fixed costs amortize away).
        let m = CkptCostModel::default();
        let small = m.disk_roundtrip_s(1e9) / m.mem_roundtrip_s(1e9);
        let large = m.disk_roundtrip_s(1e12) / m.mem_roundtrip_s(1e12);
        assert!(large > small);
    }
}
