//! Live data-parallel trainer: real training through the AOT artifacts.
//!
//! This is the end-to-end validation path: D data-parallel workers each run
//! the compiled `grad_step` HLO (JAX fwd/bwd with the Pallas kernels) on
//! their micro-batches, the coordinator performs a *real* f32 tree
//! all-reduce over the gradients (weighted by micro-batch counts — the
//! paper's weighted aggregation, so S2's uneven allocations keep the loss
//! trajectory consistent), and `apply_update` advances the parameters.
//!
//! Substitution note (DESIGN.md): the paper's workers are GPUs on separate
//! nodes; here they are logical workers multiplexed onto one CPU PJRT
//! client. Worker compute times are *measured* per worker and the
//! iteration time uses max-over-workers semantics (synchronous DP), with
//! fail-slow injection scaling each worker's effective time — identical
//! observable behaviour to parallel workers for everything FALCON sees.

use crate::anyhow::{self, Context, Result};
use crate::xla;
use std::path::Path;
// audit:allow(clock-hygiene): the live trainer times *real* XLA
// executions on the host; these wall-clock reads are the measurement
// itself, not simulated time.
use std::time::Instant;

use crate::ckpt::{DiskStore, MemoryStore};
use crate::collectives::reduce_inplace;
use crate::runtime::{literal_f32, literal_i32, Artifact, ModelMeta, Runtime};
use crate::sim::even_alloc;
use crate::util::rng::Rng;

/// Live-trainer configuration.
#[derive(Clone, Debug)]
pub struct TrainerConfig {
    pub preset: String,
    /// Data-parallel width (logical workers).
    pub dp: usize,
    /// Micro-batches per worker per iteration (before S2 rebalancing).
    pub microbatches: usize,
    pub seed: u64,
}

/// One iteration's observation.
#[derive(Clone, Debug)]
pub struct LiveIterObs {
    pub iter: usize,
    pub loss: f64,
    /// Virtual iteration time (max over workers + comm), seconds.
    pub iter_time_s: f64,
    /// Effective per-worker compute seconds (incl. injected slowdown).
    pub worker_time_s: Vec<f64>,
    /// All-reduce seconds (incl. injected congestion).
    pub comm_time_s: f64,
}

pub struct LiveTrainer {
    pub meta: ModelMeta,
    grad: Artifact,
    apply: Artifact,
    pub params: Vec<Vec<f32>>,
    pub momenta: Vec<Vec<f32>>,
    /// Micro-batches per worker (S2 mutates; sum is conserved).
    pub alloc: Vec<usize>,
    /// Injected per-worker compute health (1.0 = nominal).
    pub compute_scale: Vec<f64>,
    /// Injected all-reduce health (1.0 = nominal).
    pub comm_scale: f64,
    corpus: Vec<i32>,
    rng: Rng,
    pub iter: usize,
    pub dp: usize,
    microbatches_total: usize,
}

impl LiveTrainer {
    pub fn new(rt: &Runtime, cfg: &TrainerConfig) -> Result<LiveTrainer> {
        let meta = ModelMeta::load(&rt.dir, &cfg.preset)?;
        let grad = rt.load(&format!("grad_step_{}", cfg.preset))?;
        let apply = rt.load(&format!("apply_update_{}", cfg.preset))?;
        let params = rt.load_params(&meta)?;
        let momenta = params.iter().map(|p| vec![0f32; p.len()]).collect();
        let corpus = synth_corpus(meta.vocab, 64 * 1024, cfg.seed);
        Ok(LiveTrainer {
            meta,
            grad,
            apply,
            params,
            momenta,
            alloc: even_alloc(cfg.microbatches * cfg.dp, cfg.dp),
            compute_scale: vec![1.0; cfg.dp],
            comm_scale: 1.0,
            corpus,
            rng: Rng::new(cfg.seed ^ 0x7A11),
            iter: 0,
            dp: cfg.dp,
            microbatches_total: cfg.microbatches * cfg.dp,
        })
    }

    /// Sample one (tokens, targets) micro-batch from the synthetic corpus.
    fn sample_batch(&mut self) -> (Vec<i32>, Vec<i32>) {
        let b = self.meta.batch;
        let t = self.meta.n_ctx;
        let mut tokens = Vec::with_capacity(b * t);
        let mut targets = Vec::with_capacity(b * t);
        for _ in 0..b {
            let start = self.rng.below((self.corpus.len() - t - 1) as u64) as usize;
            tokens.extend_from_slice(&self.corpus[start..start + t]);
            targets.extend_from_slice(&self.corpus[start + 1..start + t + 1]);
        }
        (tokens, targets)
    }

    fn param_literals(&self) -> Result<Vec<xla::Literal>> {
        self.params
            .iter()
            .zip(&self.meta.param_shapes)
            .map(|(p, shape)| {
                let dims: Vec<i64> = if shape.is_empty() {
                    vec![]
                } else {
                    shape.iter().map(|&d| d as i64).collect()
                };
                literal_f32(p, &dims)
            })
            .collect()
    }

    /// Run one synchronous DP iteration.
    pub fn step(&mut self) -> Result<LiveIterObs> {
        let n_params = self.params.len();
        let b = self.meta.batch as i64;
        let t = self.meta.n_ctx as i64;
        let total_mb: usize = self.alloc.iter().sum();

        // --- per-worker gradient computation (real HLO execution) --------
        let mut worker_grads: Vec<Vec<Vec<f32>>> = Vec::with_capacity(self.dp);
        let mut worker_time = vec![0f64; self.dp];
        let mut loss_acc = 0f64;
        for d in 0..self.dp {
            let mut acc: Option<Vec<Vec<f32>>> = None;
            // audit:allow(clock-hygiene): real per-worker step timing.
            let t0 = Instant::now();
            for _ in 0..self.alloc[d] {
                let (tokens, targets) = self.sample_batch();
                let mut inputs = self.param_literals()?;
                inputs.push(literal_i32(&tokens, &[b, t])?);
                inputs.push(literal_i32(&targets, &[b, t])?);
                let out = self.grad.run_f32(&inputs)?;
                anyhow::ensure!(out.len() == n_params + 1, "grad_step arity");
                loss_acc += out[0][0] as f64;
                match &mut acc {
                    None => acc = Some(out[1..].to_vec()),
                    Some(a) => {
                        for (dst, src) in a.iter_mut().zip(&out[1..]) {
                            reduce_inplace(dst, src);
                        }
                    }
                }
            }
            let mut grads = acc.unwrap_or_else(|| {
                self.params.iter().map(|p| vec![0f32; p.len()]).collect()
            });
            // Mean over this worker's micro-batches.
            let inv = 1.0 / self.alloc[d].max(1) as f32;
            for g in &mut grads {
                for x in g.iter_mut() {
                    *x *= inv;
                }
            }
            worker_grads.push(grads);
            // Effective time: measured / injected health (a 0.5-scale GPU
            // takes 2x as long for the same work).
            worker_time[d] = t0.elapsed().as_secs_f64() / self.compute_scale[d].max(1e-3);
        }

        // --- weighted all-reduce (real summation) -------------------------
        // audit:allow(clock-hygiene): real all-reduce timing.
        let t0 = Instant::now();
        let weights: Vec<f32> = self
            .alloc
            .iter()
            .map(|&m| m as f32 / total_mb.max(1) as f32)
            .collect();
        let mut global: Vec<Vec<f32>> =
            self.params.iter().map(|p| vec![0f32; p.len()]).collect();
        for (d, grads) in worker_grads.iter().enumerate() {
            for (dst, src) in global.iter_mut().zip(grads) {
                for (x, &s) in dst.iter_mut().zip(src) {
                    *x += weights[d] * s;
                }
            }
        }
        let comm_time = t0.elapsed().as_secs_f64() / self.comm_scale.max(1e-3);

        // --- optimizer update (real HLO execution) ------------------------
        let mut inputs = self.param_literals()?;
        for (m, shape) in self.momenta.iter().zip(&self.meta.param_shapes) {
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            inputs.push(literal_f32(m, &dims)?);
        }
        for (g, shape) in global.iter().zip(&self.meta.param_shapes) {
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            inputs.push(literal_f32(g, &dims)?);
        }
        let out = self.apply.run_f32(&inputs)?;
        anyhow::ensure!(out.len() == 2 * n_params, "apply_update arity");
        self.params = out[..n_params].to_vec();
        self.momenta = out[n_params..].to_vec();

        let obs = LiveIterObs {
            iter: self.iter,
            loss: loss_acc / total_mb.max(1) as f64,
            iter_time_s: worker_time.iter().cloned().fold(0.0, f64::max) + comm_time,
            worker_time_s: worker_time,
            comm_time_s: comm_time,
        };
        self.iter += 1;
        Ok(obs)
    }

    /// S2 on the live job: reassign micro-batches (global batch conserved).
    pub fn set_alloc(&mut self, alloc: Vec<usize>) {
        assert_eq!(alloc.len(), self.dp);
        assert_eq!(alloc.iter().sum::<usize>(), self.microbatches_total);
        self.alloc = alloc;
    }

    /// Per-worker per-micro-batch times (Eq. 1's t_i) from an observation.
    pub fn microbatch_times(&self, obs: &LiveIterObs) -> Vec<f64> {
        obs.worker_time_s
            .iter()
            .zip(&self.alloc)
            .map(|(&t, &m)| t / m.max(1) as f64)
            .collect()
    }

    /// Serialize parameters+momenta (checkpoint payload).
    pub fn checkpoint_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        for v in self.params.iter().chain(&self.momenta) {
            for x in v {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
        out
    }

    /// Restore from a checkpoint payload.
    pub fn restore_bytes(&mut self, bytes: &[u8]) -> Result<()> {
        let want: usize = self
            .params
            .iter()
            .chain(&self.momenta)
            .map(|v| v.len() * 4)
            .sum();
        anyhow::ensure!(bytes.len() == want, "checkpoint size {} != {want}", bytes.len());
        let mut off = 0;
        for v in self.params.iter_mut().chain(self.momenta.iter_mut()) {
            for x in v.iter_mut() {
                *x = f32::from_le_bytes(bytes[off..off + 4].try_into().unwrap());
                off += 4;
            }
        }
        Ok(())
    }

    /// S4 on the live job: checkpoint to memory, "reschedule" (heal all
    /// injections), restore. Returns the measured restart seconds.
    pub fn restart_via_memory(&mut self, store: &mut MemoryStore) -> Result<f64> {
        let payload = self.checkpoint_bytes();
        let t_dump = store.dump("restart", &payload);
        // audit:allow(generation-discipline): LiveTrainer's own per-worker
        // scale vector, not a fabric::Cluster health field.
        self.compute_scale = vec![1.0; self.dp];
        self.comm_scale = 1.0;
        self.alloc = even_alloc(self.microbatches_total, self.dp);
        let mut buf = Vec::new();
        let t_load = store.load("restart", &mut buf)?;
        self.restore_bytes(&buf)?;
        Ok(t_dump + t_load)
    }

    /// Disk-based checkpoint round trip (the Fig 19 baseline path).
    pub fn ckpt_roundtrip_disk(&mut self, dir: &Path) -> Result<f64> {
        let store = DiskStore::new(dir)?;
        let payload = self.checkpoint_bytes();
        let t_dump = store.dump("restart", &payload).context("disk dump")?;
        let mut buf = Vec::new();
        let t_load = store.load("restart", &mut buf)?;
        self.restore_bytes(&buf)?;
        Ok(t_dump + t_load)
    }
}

/// Synthetic char-level corpus with Markov structure: loss has real
/// learnable signal (word bank + punctuation rhythm), entropy well below
/// uniform.
pub fn synth_corpus(vocab: usize, len: usize, seed: u64) -> Vec<i32> {
    const WORDS: [&str; 12] = [
        "gradient", "straggler", "pipeline", "allreduce", "tensor", "falcon",
        "detects", "mitigates", "congestion", "iteration", "training", "cluster",
    ];
    let mut rng = Rng::new(seed);
    let mut text = String::with_capacity(len + 16);
    while text.len() < len {
        text.push_str(WORDS[rng.below(WORDS.len() as u64) as usize]);
        text.push(if rng.bernoulli(0.15) { '.' } else { ' ' });
    }
    text.bytes().take(len).map(|b| (b as usize % vocab) as i32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn art_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        art_dir().join(".stamp").exists()
    }

    fn trainer(dp: usize, mb: usize) -> Option<(Runtime, LiveTrainer)> {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts`");
            return None;
        }
        let rt = Runtime::new(art_dir()).unwrap();
        let t = LiveTrainer::new(
            &rt,
            &TrainerConfig { preset: "tiny".into(), dp, microbatches: mb, seed: 7 },
        )
        .unwrap();
        Some((rt, t))
    }

    #[test]
    fn corpus_in_vocab_range() {
        let c = synth_corpus(96, 10_000, 3);
        assert_eq!(c.len(), 10_000);
        assert!(c.iter().all(|&x| (0..96).contains(&x)));
        // Non-trivial structure: far fewer distinct symbols than vocab.
        let distinct: std::collections::HashSet<i32> = c.iter().cloned().collect();
        assert!(distinct.len() < 40);
    }

    #[test]
    fn live_training_reduces_loss() {
        let Some((_rt, mut t)) = trainer(2, 1) else { return };
        let first = t.step().unwrap();
        let mut last = first.clone();
        for _ in 0..12 {
            last = t.step().unwrap();
        }
        assert!(
            last.loss < 0.9 * first.loss,
            "loss must drop: {} -> {}",
            first.loss,
            last.loss
        );
        assert!(first.loss < (t.meta.vocab as f64).ln() * 1.2);
    }

    #[test]
    fn injected_slowdown_visible_in_iteration_time() {
        let Some((_rt, mut t)) = trainer(2, 1) else { return };
        t.step().unwrap(); // warm-up (compile caches etc.)
        let healthy: f64 = (0..3).map(|_| t.step().unwrap().iter_time_s).sum::<f64>() / 3.0;
        t.compute_scale[0] = 0.4;
        let slow: f64 = (0..3).map(|_| t.step().unwrap().iter_time_s).sum::<f64>() / 3.0;
        assert!(slow > 1.5 * healthy, "slow {slow} vs healthy {healthy}");
    }

    #[test]
    fn s2_rebalance_reduces_live_iteration_time() {
        let Some((_rt, mut t)) = trainer(2, 4) else { return };
        t.step().unwrap();
        t.compute_scale[0] = 0.34; // worker 0 is ~3x slower
        let slow: f64 = (0..2).map(|_| t.step().unwrap().iter_time_s).sum::<f64>() / 2.0;
        // Shift work: 8 total micro-batches, give the slow worker 2.
        t.set_alloc(vec![2, 6]);
        let fixed: f64 = (0..2).map(|_| t.step().unwrap().iter_time_s).sum::<f64>() / 2.0;
        assert!(fixed < 0.8 * slow, "rebalance: {fixed} vs {slow}");
    }

    #[test]
    fn weighted_aggregation_keeps_training_consistent() {
        // Uneven allocation must still reduce loss (paper's consistency
        // claim for S2 via weighted gradients).
        let Some((_rt, mut t)) = trainer(2, 2) else { return };
        t.set_alloc(vec![1, 3]);
        let first = t.step().unwrap();
        let mut last = first.clone();
        for _ in 0..10 {
            last = t.step().unwrap();
        }
        assert!(last.loss < 0.95 * first.loss, "{} -> {}", first.loss, last.loss);
    }

    #[test]
    fn checkpoint_restore_round_trip() {
        let Some((_rt, mut t)) = trainer(2, 1) else { return };
        t.step().unwrap();
        let snap = t.checkpoint_bytes();
        let params_before = t.params.clone();
        t.step().unwrap();
        assert!(t.params != params_before, "params must move");
        t.restore_bytes(&snap).unwrap();
        assert_eq!(t.params, params_before);
    }

    #[test]
    fn restart_heals_injections() {
        let Some((_rt, mut t)) = trainer(2, 1) else { return };
        t.compute_scale[1] = 0.3;
        t.comm_scale = 0.5;
        t.set_alloc(vec![0, 2]);
        let mut store = MemoryStore::new();
        let secs = t.restart_via_memory(&mut store).unwrap();
        assert!(secs >= 0.0);
        assert_eq!(t.compute_scale, vec![1.0, 1.0]);
        assert_eq!(t.comm_scale, 1.0);
        assert_eq!(t.alloc, vec![1, 1]);
    }
}
