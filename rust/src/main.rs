//! `falcon` — CLI for the FALCON reproduction.
//!
//! Subcommands:
//!
//! ```text
//! report <id|all> [--iters N] [--seed S] [--fast true|false]
//!     Regenerate a paper table/figure (fig1..fig20, tab1..tab7), or a
//!     beyond-paper report (fleet, fleet_cluster).
//! train [--preset tiny|small|base] [--dp D] [--steps N] [--inject true]
//!     Live data-parallel training through the AOT PJRT artifacts with
//!     FALCON detection + mitigation in the loop.
//! sim [--tp T] [--dp D] [--pp P] [--iters N] [--inject gpu|cpu|net]
//!     One simulated hybrid-parallel job with FALCON attached.
//! fleet [--jobs N] [--iters I] [--seed S] [--workers W] [--boost B]
//!       [--compare true|false] [--spare F] [--epoch-len L]
//!       [--policy first-fit|packed|spread|straggler-aware|private]
//!     Fleet campaign: N concurrent simulated jobs sharded across worker
//!     threads, with a deterministic cross-job aggregate report.
//!     --policy moves the fleet onto ONE shared cluster: jobs contend
//!     for spine-leaf uplink bandwidth and every S3/S4 mitigation must
//!     win a grant from the cluster arbiter (--spare sizes the healthy
//!     spare pool; 0.0 saturates it).
//! campaign [--fast true|false]
//!     The §3 characterization campaign (Fig 1 + Table 1).
//! list
//!     List available report ids.
//! ```

use falcon::coordinator::{run_with_falcon, FalconConfig};
use falcon::inject::{FailSlowEvent, FailSlowKind, Target};
use falcon::pipeline::ParallelConfig;
use falcon::sim::{demo_spec, TrainingSim};
use falcon::simkit::from_secs;
use falcon::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "report" => {
            let id = args.positional.get(1).map(|s| s.as_str()).unwrap_or("all");
            if id == "all" {
                for id in falcon::reports::ALL {
                    println!("{}", falcon::reports::generate(id, &args));
                }
            } else {
                println!("{}", falcon::reports::generate(id, &args));
            }
        }
        "list" => {
            for id in falcon::reports::ALL {
                println!("{id}");
            }
        }
        "sim" => run_sim(&args),
        "fleet" => run_fleet_cmd(&args),
        "campaign" => {
            println!("{}", falcon::reports::generate("fig1", &args));
            println!("{}", falcon::reports::generate("tab1", &args));
        }
        #[cfg(feature = "pjrt")]
        "train" => run_train(&args),
        #[cfg(not(feature = "pjrt"))]
        "train" => {
            println!(
                "the live PJRT trainer is compiled out: it needs the external \
                 `xla`/`anyhow` crates, which are not yet vendored (see \
                 ROADMAP open items). Once they are declared in rust/Cargo.toml, \
                 build with `--features pjrt`."
            );
        }
        _ => {
            println!(
                "usage: falcon <report|train|sim|fleet|campaign|list> [flags]\n\
                 see `falcon list` for report ids; DESIGN.md for the experiment index"
            );
        }
    }
}

fn run_sim(args: &Args) {
    let cfg = ParallelConfig::new(
        args.usize_or("tp", 2),
        args.usize_or("dp", 4),
        args.usize_or("pp", 1),
    );
    let iters = args.usize_or("iters", 300);
    let mut sim = TrainingSim::new(demo_spec(cfg, args.u64_or("seed", 1)));
    let onset = sim.ideal_iter_s * iters as f64 * 0.25;
    let dur = sim.ideal_iter_s * iters as f64 * 0.4;
    match args.get("inject") {
        Some("gpu") => sim.inject(vec![FailSlowEvent {
            kind: FailSlowKind::GpuDegradation,
            target: Target::Gpu(0),
            start: from_secs(onset),
            duration: (dur * 1e6) as u64,
            scale: args.f64_or("scale", 0.5),
        }]),
        Some("cpu") => sim.inject(vec![FailSlowEvent {
            kind: FailSlowKind::CpuContention,
            target: Target::Node(0),
            start: from_secs(onset),
            duration: (dur * 1e6) as u64,
            scale: args.f64_or("scale", 0.4),
        }]),
        Some("net") => sim.inject(vec![FailSlowEvent {
            kind: FailSlowKind::NetworkCongestion,
            target: Target::Link(0, 1),
            start: from_secs(onset),
            duration: (dur * 1e6) as u64,
            scale: args.f64_or("scale", 0.25),
        }]),
        _ => {}
    }
    let falcon = run_with_falcon(
        &mut sim,
        FalconConfig { mitigate: args.bool_or("mitigate", true), ..FalconConfig::default() },
        iters,
    );
    println!(
        "{}",
        falcon::util::plot::line_chart(
            &format!("throughput ({} on {} nodes, iters/s)", cfg.label(), sim.grid.n_nodes()),
            &sim.timeline.xs_mins(),
            &sim.timeline.ys(),
            70,
            10,
        )
    );
    for a in &falcon.actions {
        println!("  t={:.1}min iter={} {:?}", falcon::simkit::mins(a.at), a.iter, a.what);
    }
    println!(
        "mean throughput {:.3} iters/s (ideal {:.3})",
        sim.timeline.mean_throughput(),
        1.0 / sim.ideal_iter_s
    );
}

fn run_fleet_cmd(args: &Args) {
    let cfg = falcon::reports::fleet::config_from_args(args);
    eprintln!(
        "[fleet] {} jobs x {} iters, seed {}, workers {} (0 = auto), compare {}, cluster {}",
        cfg.jobs,
        cfg.iters,
        cfg.seed,
        cfg.workers,
        cfg.compare,
        cfg.policy.map(|p| p.name()).unwrap_or("private"),
    );
    let report = falcon::fleet::run_fleet(&cfg);
    println!("{}", report.render());
}

#[cfg(feature = "pjrt")]
fn run_train(args: &Args) {
    use falcon::detect::{BocdConfig, Detector};
    use falcon::mitigate::microbatch;
    use falcon::runtime::Runtime;
    use falcon::trainer::{LiveTrainer, TrainerConfig};

    let preset = args.str_or("preset", "tiny");
    let dp = args.usize_or("dp", 2);
    let steps = args.usize_or("steps", 40);
    let rt = Runtime::new(args.str_or("artifacts", "artifacts")).expect("runtime");
    let mut t = LiveTrainer::new(
        &rt,
        &TrainerConfig {
            preset,
            dp,
            microbatches: args.usize_or("microbatches", 2),
            seed: args.u64_or("seed", 0),
        },
    )
    .expect("trainer (run `make artifacts` first)");

    // Optional injected compute fail-slow on worker 0 mid-run.
    let inject_at = args.usize_or("inject-at", steps / 3);
    let inject_scale = args.f64_or("scale", 0.4);
    let inject = args.bool_or("inject", false);

    let mut detector = Detector::new(BocdConfig::default());
    println!("step, loss, iter_time_s, alloc");
    for step in 0..steps {
        if inject && step == inject_at {
            t.compute_scale[0] = inject_scale;
            eprintln!("[inject] worker 0 compute scale -> {inject_scale}");
        }
        let obs = t.step().expect("step");
        if let Some(true) = detector.push(obs.iter_time_s) {
            // Fail-slow confirmed: rebalance micro-batches (S2) live.
            let times = t.microbatch_times(&obs);
            let total: usize = t.alloc.iter().sum();
            let alloc = microbatch::solve(&times, total).m;
            eprintln!("[falcon] fail-slow verified at step {step}; S2 realloc {alloc:?}");
            t.set_alloc(alloc);
        }
        println!(
            "{}, {:.4}, {:.3}, {:?}",
            obs.iter, obs.loss, obs.iter_time_s, t.alloc
        );
    }
}
