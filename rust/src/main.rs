//! `falcon` — CLI for the FALCON reproduction.
//!
//! Subcommands:
//!
//! ```text
//! report <id|all> [--iters N] [--seed S] [--fast true|false]
//!     Regenerate a paper table/figure (fig1..fig20, tab1..tab7), or a
//!     beyond-paper report (fleet, fleet_cluster).
//! train [--preset tiny|small|base] [--dp D] [--steps N] [--inject true]
//!     Live data-parallel training through the AOT PJRT artifacts with
//!     FALCON detection + mitigation in the loop.
//! run <file|name> [--iters N] [--seed S] [--json true]
//!     Execute a declarative scenario: either a built-in library name
//!     (`falcon scenarios` lists them) or a TOML spec file (format:
//!     docs/SCENARIOS.md). Prints the structured Outcome as ASCII, or as
//!     JSON with --json.
//! scenarios
//!     List the built-in scenario library with descriptions.
//! sim [--tp T] [--dp D] [--pp P] [--iters N] [--inject gpu|cpu|net]
//!     One simulated hybrid-parallel job with FALCON attached (a thin
//!     builder-API shortcut over `falcon run`).
//! fleet [--jobs N] [--iters I] [--seed S] [--workers W] [--boost B]
//!       [--compare true|false] [--spare F] [--epoch-len L] [--stagger G]
//!       [--policy first-fit|packed|spread|straggler-aware|private]
//!     Fleet campaign: N concurrent simulated jobs sharded across worker
//!     threads, with a deterministic cross-job aggregate report.
//!     --policy moves the fleet onto ONE shared cluster: jobs contend
//!     for spine-leaf uplink bandwidth and every S3/S4 mitigation must
//!     win a grant from the cluster arbiter (--spare sizes the healthy
//!     spare pool; 0.0 saturates it; --stagger spreads job start epochs so
//!     the pool breathes).
//! campaign [--fast true|false]
//!     The §3 characterization campaign (Fig 1 + Table 1).
//! list
//!     List available report ids (paper set plus beyond-paper reports).
//! ```

use falcon::inject::{FailSlowKind, Target};
use falcon::scenario::{FaultSpec, ScenarioSpec};
use falcon::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "report" => {
            let id = args.positional.get(1).map(|s| s.as_str()).unwrap_or("all");
            if id == "all" {
                for id in falcon::reports::ALL {
                    println!("{}", falcon::reports::generate(id, &args));
                }
            } else {
                println!("{}", falcon::reports::generate(id, &args));
            }
        }
        "list" => {
            for id in falcon::reports::ALL {
                println!("{id}");
            }
            println!("beyond paper:");
            for id in falcon::reports::BEYOND_PAPER {
                println!("{id}");
            }
        }
        "run" => run_scenario(&args),
        "scenarios" => {
            for &name in falcon::scenario::LIBRARY {
                let spec = falcon::scenario::find(name).expect("library names build");
                let tag = if spec.fleet.is_some() { " [fleet]" } else { "" };
                println!("{name:<26} {}{tag}", spec.description);
            }
        }
        "sim" => run_sim(&args),
        "fleet" => run_fleet_cmd(&args),
        "campaign" => {
            println!("{}", falcon::reports::generate("fig1", &args));
            println!("{}", falcon::reports::generate("tab1", &args));
        }
        #[cfg(feature = "pjrt")]
        "train" => run_train(&args),
        #[cfg(not(feature = "pjrt"))]
        "train" => {
            println!(
                "the live PJRT trainer is compiled out: it needs the external \
                 `xla`/`anyhow` crates, which are not yet vendored (see \
                 ROADMAP open items). Once they are declared in rust/Cargo.toml, \
                 build with `--features pjrt`."
            );
        }
        _ => {
            println!(
                "usage: falcon <report|run|scenarios|train|sim|fleet|campaign|list> [flags]\n\
                 see `falcon list` for report ids, `falcon scenarios` for the scenario\n\
                 library, README.md for the quickstart, and docs/SCENARIOS.md for the\n\
                 scenario spec format"
            );
        }
    }
}

/// `falcon run <library-name|path/to/spec.toml>`: one declarative scenario,
/// end to end, through `ScenarioSpec::run`.
fn run_scenario(args: &Args) {
    let Some(what) = args.positional.get(1) else {
        eprintln!("usage: falcon run <library-name|path/to/spec.toml> [--json true]");
        eprintln!("library scenarios (details: `falcon scenarios`):");
        for &name in falcon::scenario::LIBRARY {
            eprintln!("  {name}");
        }
        return;
    };
    let mut spec = if let Some(spec) = falcon::scenario::find(what) {
        spec
    } else {
        match std::fs::read_to_string(what) {
            Ok(text) => match ScenarioSpec::parse(&text) {
                Ok(spec) => spec,
                Err(e) => {
                    eprintln!("{what}: {e}");
                    return;
                }
            },
            Err(io) => {
                eprintln!("'{what}' is neither a library scenario nor a readable file ({io})");
                eprintln!("library names: {:?}", falcon::scenario::LIBRARY);
                return;
            }
        }
    };
    // CLI overrides for quick sweeps over the same scenario.
    if args.has("iters") {
        spec = spec.iters(args.usize_or("iters", spec.run.iters));
    }
    if args.has("seed") {
        spec = spec.seed(args.u64_or("seed", spec.run.seed));
    }
    if args.has("mitigate") {
        spec = spec.mitigate(args.bool_or("mitigate", spec.run.mitigate));
    }
    match spec.run() {
        Ok(outcome) => {
            if args.bool_or("json", false) {
                println!("{}", outcome.to_json().to_string());
            } else {
                println!("{}", outcome.render());
            }
        }
        Err(e) => eprintln!("scenario '{}' failed: {e}", spec.name),
    }
}

/// `falcon sim`: a builder-API shortcut — assembles a [`ScenarioSpec`] from
/// flags and runs it through the same unified entry as `falcon run`.
fn run_sim(args: &Args) {
    let mut spec = ScenarioSpec::new(
        "sim",
        args.usize_or("tp", 2),
        args.usize_or("dp", 4),
        args.usize_or("pp", 1),
    )
    .iters(args.usize_or("iters", 300))
    .seed(args.u64_or("seed", 1))
    .mitigate(args.bool_or("mitigate", true));
    spec = match args.get("inject") {
        Some("gpu") => spec.fault(FaultSpec::new(
            FailSlowKind::GpuDegradation,
            Target::Gpu(0),
            0.25,
            0.4,
            args.f64_or("scale", 0.5),
        )),
        Some("cpu") => spec.fault(FaultSpec::new(
            FailSlowKind::CpuContention,
            Target::Node(0),
            0.25,
            0.4,
            args.f64_or("scale", 0.4),
        )),
        Some("net") => spec.fault(FaultSpec::new(
            FailSlowKind::NetworkCongestion,
            Target::Link(0, 1),
            0.25,
            0.4,
            args.f64_or("scale", 0.25),
        )),
        _ => spec,
    };
    match spec.run() {
        Ok(outcome) => println!("{}", outcome.render()),
        Err(e) => eprintln!(
            "sim scenario invalid: {e}\n(hint: --inject net needs a job spanning \
             at least 2 nodes, e.g. --dp 16)"
        ),
    }
}

fn run_fleet_cmd(args: &Args) {
    let cfg = falcon::reports::fleet::config_from_args(args);
    eprintln!(
        "[fleet] {} jobs x {} iters, seed {}, workers {} (0 = auto), compare {}, cluster {}",
        cfg.jobs,
        cfg.iters,
        cfg.seed,
        cfg.workers,
        cfg.compare,
        cfg.policy.map(|p| p.name()).unwrap_or("private"),
    );
    let report = falcon::fleet::run_fleet(&cfg);
    println!("{}", report.render());
}

#[cfg(feature = "pjrt")]
fn run_train(args: &Args) {
    use falcon::detect::{BocdConfig, Detector};
    use falcon::mitigate::microbatch;
    use falcon::runtime::Runtime;
    use falcon::trainer::{LiveTrainer, TrainerConfig};

    let preset = args.str_or("preset", "tiny");
    let dp = args.usize_or("dp", 2);
    let steps = args.usize_or("steps", 40);
    let rt = Runtime::new(args.str_or("artifacts", "artifacts")).expect("runtime");
    let mut t = LiveTrainer::new(
        &rt,
        &TrainerConfig {
            preset,
            dp,
            microbatches: args.usize_or("microbatches", 2),
            seed: args.u64_or("seed", 0),
        },
    )
    .expect("trainer (run `make artifacts` first)");

    // Optional injected compute fail-slow on worker 0 mid-run.
    let inject_at = args.usize_or("inject-at", steps / 3);
    let inject_scale = args.f64_or("scale", 0.4);
    let inject = args.bool_or("inject", false);

    let mut detector = Detector::new(BocdConfig::default());
    println!("step, loss, iter_time_s, alloc");
    for step in 0..steps {
        if inject && step == inject_at {
            t.compute_scale[0] = inject_scale;
            eprintln!("[inject] worker 0 compute scale -> {inject_scale}");
        }
        let obs = t.step().expect("step");
        if let Some(true) = detector.push(obs.iter_time_s) {
            // Fail-slow confirmed: rebalance micro-batches (S2) live.
            let times = t.microbatch_times(&obs);
            let total: usize = t.alloc.iter().sum();
            let alloc = microbatch::solve(&times, total).m;
            eprintln!("[falcon] fail-slow verified at step {step}; S2 realloc {alloc:?}");
            t.set_alloc(alloc);
        }
        println!(
            "{}, {:.4}, {:.3}, {:?}",
            obs.iter, obs.loss, obs.iter_time_s, t.alloc
        );
    }
}
