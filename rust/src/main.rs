//! `falcon` — CLI for the FALCON reproduction.
//!
//! Subcommands:
//!
//! ```text
//! report <id|all> [--iters N] [--seed S] [--fast true|false]
//!     Regenerate a paper table/figure (fig1..fig20, tab1..tab7), or a
//!     beyond-paper report (fleet, fleet_cluster, whatif, diagnosis,
//!     ledger — diagnosis scores the hang-vs-slow taxonomy against
//!     scripted ground truth, see docs/DIAGNOSIS.md; ledger compares
//!     memoryless vs health-aware policies on a chronically flaky
//!     fleet, see docs/LEDGER.md).
//! train [--preset tiny|small|base] [--dp D] [--steps N] [--inject true]
//!     Live data-parallel training through the AOT PJRT artifacts with
//!     FALCON detection + mitigation in the loop.
//! run <file|name> [--iters N] [--seed S] [--replan true] [--json true]
//!     Execute a declarative scenario: either a built-in library name
//!     (`falcon scenarios` lists them) or a TOML spec file (format:
//!     docs/SCENARIOS.md). Prints the structured Outcome as ASCII, or as
//!     JSON with --json.
//! whatif <file|name> [--drop-fault i[,j..] | --no-mitigation
//!        | --delay-mitigation N | --force S2@0.5 | --swap-policy P
//!        | --sweep] [--iters N] [--seed S] [--json true]
//!     Counterfactual analysis: record the scenario, replay it with the
//!     given edit, and report the attributed JCT delta. With --sweep (or
//!     no edit at all) runs the full attribution — one fault-removed
//!     replay per [[fault]] plus a no-mitigation replay — fanned across
//!     worker threads; fleet scenarios report contention blame instead.
//! scenarios
//!     List the built-in scenario library with descriptions.
//! sim [--tp T] [--dp D] [--pp P] [--iters N] [--inject gpu|cpu|net|hang]
//!     One simulated hybrid-parallel job with FALCON attached (a thin
//!     builder-API shortcut over `falcon run`).
//! fleet [--jobs N] [--iters I] [--seed S] [--workers W] [--boost B]
//!       [--compare true|false] [--spare F] [--epoch-len L] [--stagger G]
//!       [--policy first-fit|packed|spread|straggler-aware|
//!                 health-weighted|predictive-quarantine|private]
//!       [--ledger true] [--flaky F] [--alpha A] [--ledger-file PATH]
//!     Fleet campaign: N concurrent simulated jobs sharded across worker
//!     threads, with a deterministic cross-job aggregate report.
//!     --policy moves the fleet onto ONE shared cluster: jobs contend
//!     for spine-leaf uplink bandwidth and every S3/S4 mitigation must
//!     win a grant from the cluster arbiter (--spare sizes the healthy
//!     spare pool; 0.0 saturates it; --stagger spreads job start epochs so
//!     the pool breathes). --ledger attaches the persistent node-health
//!     ledger (docs/LEDGER.md); --flaky/--alpha make a slice of the pool
//!     chronically flaky on heavy-tailed gaps; --ledger-file seeds the
//!     campaign from a prior snapshot and writes the evolved ledger back.
//! campaign [--fast true|false]
//!     The §3 characterization campaign (Fig 1 + Table 1).
//! audit [--src DIR] [--json true] [--graph [--dot|--json]]
//!     Run the in-tree invariant lint (determinism, RNG-taint,
//!     lock-order, module-layering, and cache-coherence discipline)
//!     over the crate's own source; exits non-zero on any violation.
//!     Rule scope is derived from a crate-wide call graph; --graph
//!     emits that graph (human summary, Graphviz with --dot, or JSON
//!     with --json). Rule catalog: docs/AUDIT.md.
//! list
//!     List available report ids (paper set plus beyond-paper reports).
//! ```

use falcon::inject::{FailSlowKind, Target};
use falcon::scenario::{FaultSpec, ScenarioSpec};
use falcon::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "report" => {
            let id = args.positional.get(1).map(|s| s.as_str()).unwrap_or("all");
            if id == "all" {
                for id in falcon::reports::ALL {
                    println!("{}", falcon::reports::generate(id, &args));
                }
            } else {
                println!("{}", falcon::reports::generate(id, &args));
            }
        }
        "list" => {
            for id in falcon::reports::ALL {
                println!("{id}");
            }
            println!("beyond paper:");
            for id in falcon::reports::BEYOND_PAPER {
                println!("{id}");
            }
        }
        "run" => run_scenario(&args),
        "whatif" => run_whatif(&args),
        "scenarios" => {
            for &name in falcon::scenario::LIBRARY {
                let Some(spec) = falcon::scenario::find(name) else {
                    continue;
                };
                let tag = if spec.fleet.is_some() { " [fleet]" } else { "" };
                println!("{name:<26} {}{tag}", spec.description);
            }
        }
        "sim" => run_sim(&args),
        "fleet" => run_fleet_cmd(&args),
        "audit" => run_audit(&args),
        "campaign" => {
            println!("{}", falcon::reports::generate("fig1", &args));
            println!("{}", falcon::reports::generate("tab1", &args));
        }
        #[cfg(feature = "pjrt")]
        "train" => run_train(&args),
        #[cfg(not(feature = "pjrt"))]
        "train" => {
            println!(
                "the live PJRT trainer is compiled out of this binary; rebuild \
                 with `--features pjrt`. That build compiles against the \
                 in-tree xla/anyhow stubs (rust/src/xla.rs), so real artifact \
                 execution still needs the vendored crates — see the ROADMAP \
                 open item."
            );
        }
        _ => {
            println!(
                "usage: falcon <report|run|whatif|scenarios|train|sim|fleet|campaign|audit|list> \
                 [flags]\n\
                 see `falcon list` for report ids, `falcon scenarios` for the scenario\n\
                 library, README.md for the quickstart, docs/SCENARIOS.md for the\n\
                 scenario spec format, docs/WHATIF.md for counterfactual edits, and\n\
                 docs/AUDIT.md for the `falcon audit` invariant-lint rules"
            );
        }
    }
}

/// Resolve the `<library-name|path/to/spec.toml>` positional into a spec
/// and apply the common CLI overrides (shared by `run` and `whatif`).
fn load_spec(args: &Args, usage: &str) -> Option<ScenarioSpec> {
    let Some(what) = args.positional.get(1) else {
        eprintln!("usage: {usage}");
        eprintln!("library scenarios (details: `falcon scenarios`):");
        for &name in falcon::scenario::LIBRARY {
            eprintln!("  {name}");
        }
        return None;
    };
    let mut spec = if let Some(spec) = falcon::scenario::find(what) {
        spec
    } else {
        match std::fs::read_to_string(what) {
            Ok(text) => match ScenarioSpec::parse(&text) {
                Ok(spec) => spec,
                Err(e) => {
                    eprintln!("{what}: {e}");
                    return None;
                }
            },
            Err(io) => {
                eprintln!("'{what}' is neither a library scenario nor a readable file ({io})");
                eprintln!("library names: {:?}", falcon::scenario::LIBRARY);
                return None;
            }
        }
    };
    // CLI overrides for quick sweeps over the same scenario.
    if args.has("iters") {
        spec = spec.iters(args.usize_or("iters", spec.run.iters));
    }
    if args.has("seed") {
        spec = spec.seed(args.u64_or("seed", spec.run.seed));
    }
    if args.has("mitigate") {
        spec = spec.mitigate(args.bool_or("mitigate", spec.run.mitigate));
    }
    if args.has("replan") {
        spec = spec.replan(args.bool_or("replan", spec.run.replan));
    }
    Some(spec)
}

/// `falcon run <library-name|path/to/spec.toml>`: one declarative scenario,
/// end to end, through `ScenarioSpec::run`.
fn run_scenario(args: &Args) {
    let Some(spec) =
        load_spec(args, "falcon run <library-name|path/to/spec.toml> [--json true]")
    else {
        return;
    };
    match spec.run() {
        Ok(outcome) => {
            if args.bool_or("json", false) {
                println!("{}", outcome.to_json());
            } else {
                println!("{}", outcome.render());
            }
        }
        Err(e) => eprintln!("scenario '{}' failed: {e}", spec.name),
    }
}

/// `falcon whatif <scenario|file>`: record, counterfactually replay, and
/// attribute (see `docs/WHATIF.md`).
fn run_whatif(args: &Args) {
    use falcon::whatif::{self, Edit, Recording, TraceConfig};

    let Some(spec) = load_spec(
        args,
        "falcon whatif <library-name|path/to/spec.toml> [--drop-fault i[,j..] | \
         --no-mitigation | --delay-mitigation N | --force S2@0.5 | \
         --swap-policy P | --sweep] [--json true]",
    ) else {
        return;
    };

    // --- collect edits -----------------------------------------------------
    // The flag map keeps only the last occurrence of a repeated flag, so
    // repeats would silently drop edits; reject them (drop-fault merges
    // several faults via a comma list instead).
    for flag in ["drop-fault", "force", "swap-policy", "delay-mitigation"] {
        if args.count(flag) > 1 {
            eprintln!(
                "--{flag} was passed {} times; pass it once{}",
                args.count(flag),
                if flag == "drop-fault" { " (it accepts a comma list: 0,2)" } else { "" }
            );
            return;
        }
    }
    let mut edits: Vec<Edit> = Vec::new();
    if let Some(v) = args.get("drop-fault") {
        for part in v.split(',') {
            match part.trim().parse() {
                Ok(i) => edits.push(Edit::DropFault(i)),
                Err(_) => {
                    eprintln!("--drop-fault wants a fault index or comma list, got '{v}'");
                    return;
                }
            }
        }
    }
    if args.bool_or("no-mitigation", false) {
        edits.push(Edit::NoMitigation);
    }
    if let Some(v) = args.get("delay-mitigation") {
        match v.parse() {
            Ok(n) => edits.push(Edit::DelayMitigation(n)),
            Err(_) => {
                eprintln!("--delay-mitigation wants an iteration count, got '{v}'");
                return;
            }
        }
    }
    if let Some(v) = args.get("force") {
        let (s, at) = match v.split_once('@') {
            Some((s, at)) => match at.parse::<f64>() {
                Ok(frac) if (0.0..=1.0).contains(&frac) => (s, frac),
                _ => {
                    eprintln!(
                        "--force wants a fraction in [0, 1] after '@', got '{at}' in '{v}'"
                    );
                    return;
                }
            },
            None => (v, 0.5),
        };
        let Some(strategy) = parse_strategy(s) else {
            eprintln!("--force wants S1|S2|S3|S4|S5[@frac], got '{v}'");
            return;
        };
        edits.push(Edit::ForceLevel { strategy, at_frac: at });
    }
    if let Some(v) = args.get("swap-policy") {
        let Some(p) = falcon::cluster::Policy::parse(v) else {
            eprintln!("--swap-policy wants first-fit|packed|spread|straggler-aware, got '{v}'");
            return;
        };
        edits.push(Edit::SwapPolicy(p));
    }
    if args.bool_or("sweep", false) && !edits.is_empty() {
        eprintln!(
            "--sweep runs the full attribution and cannot be combined with an \
             explicit edit flag; drop one of them"
        );
        return;
    }
    let sweep_mode = args.bool_or("sweep", false) || edits.is_empty();
    let json = args.bool_or("json", false);

    // --- record ------------------------------------------------------------
    let tcfg = TraceConfig { snapshot_every: args.usize_or("snapshot-every", 64) };
    let recording = match whatif::record_scenario(&spec, &tcfg) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("whatif '{}' failed to record: {e}", spec.name);
            return;
        }
    };

    match &recording {
        Recording::Single(trace) => {
            if sweep_mode {
                // Full attribution: one replay per fault + no-mitigation.
                match whatif::attribute(trace, args.usize_or("workers", 0)) {
                    Ok(attr) => {
                        let mut outcome = trace.outcome.clone();
                        outcome.attribution = Some(attr);
                        if json {
                            println!("{}", outcome.to_json());
                        } else {
                            println!("{}", outcome.render());
                        }
                    }
                    Err(e) => eprintln!("attribution failed: {e}"),
                }
                return;
            }
            let edited = match recording.replay(&edits) {
                Ok(o) => o,
                Err(e) => {
                    eprintln!("replay failed: {e}");
                    return;
                }
            };
            let baseline = &trace.outcome;
            let delta = edited.jct_s - baseline.jct_s;
            if json {
                let doc = falcon::util::json::Json::obj(vec![
                    ("baseline", baseline.to_json()),
                    ("edited", edited.to_json()),
                    ("jct_delta_s", falcon::util::json::Json::Num(delta)),
                ]);
                println!("{doc}");
                return;
            }
            println!(
                "whatif '{}' — edits: {}",
                spec.name,
                edits.iter().map(|e| e.to_string()).collect::<Vec<_>>().join(", ")
            );
            println!(
                "baseline: JCT {:.1} s (mean {:.3} iters/s, {} episodes detected)",
                baseline.jct_s, baseline.mean_thpt, baseline.episodes_detected
            );
            println!(
                "edited:   JCT {:.1} s (mean {:.3} iters/s, {} episodes detected)",
                edited.jct_s, edited.mean_thpt, edited.episodes_detected
            );
            println!("JCT delta (edited - baseline): {delta:+.1} s");
            if edits.iter().any(|e| matches!(e, Edit::DropFault(_))) {
                println!("attributed delay of the dropped fault(s): {:+.1} s", -delta);
            }
            if edits.contains(&Edit::NoMitigation) {
                println!("mitigation benefit on this trace: {delta:+.1} s");
            }
        }
        Recording::Fleet(rec) => {
            use falcon::util::json::Json;
            let blame = whatif::contention_blame(&rec.trace);
            // Replay first so --json can carry the edited outcome too.
            let edited = if edits.is_empty() {
                None
            } else {
                match recording.replay(&edits) {
                    Ok(out) => Some(out),
                    Err(e) => {
                        eprintln!("fleet replay failed: {e}");
                        return;
                    }
                }
            };
            if json {
                let blame_json = Json::Arr(
                    blame
                        .iter()
                        .map(|b| {
                            Json::obj(vec![
                                ("victim", Json::Num(b.victim as f64)),
                                ("culprit", Json::Num(b.culprit as f64)),
                                ("lost_s", Json::Num(b.lost_s)),
                            ])
                        })
                        .collect(),
                );
                let doc = Json::obj(vec![
                    ("baseline", rec.outcome.to_json()),
                    ("blame", blame_json),
                    ("edited", edited.as_ref().map_or(Json::Null, |o| o.to_json())),
                ]);
                println!("{doc}");
                return;
            }
            println!("{}", rec.outcome.render());
            println!("contention blame (top 10):");
            print!("{}", whatif::render_blame(&blame, 10));
            if let Some(out) = edited {
                println!(
                    "\nedited fleet ({}): mean slowdown {:.3}x (baseline {:.3}x), \
                     JCT {:.1} s (baseline {:.1} s)",
                    edits.iter().map(|e| e.to_string()).collect::<Vec<_>>().join(", "),
                    out.ideal_thpt / out.mean_thpt.max(1e-9),
                    rec.outcome.ideal_thpt / rec.outcome.mean_thpt.max(1e-9),
                    out.jct_s,
                    rec.outcome.jct_s
                );
            }
        }
    }
}

/// Parse a mitigation-level token (`S1`..`S5`, case-insensitive).
fn parse_strategy(s: &str) -> Option<falcon::mitigate::Strategy> {
    use falcon::mitigate::Strategy;
    match s.to_ascii_lowercase().as_str() {
        "s1" | "ignore" => Some(Strategy::Ignore),
        "s2" | "microbatch" => Some(Strategy::AdjustMicrobatch),
        "s3" | "topology" => Some(Strategy::AdjustTopology),
        "s4" | "restart" => Some(Strategy::CkptRestart),
        "s5" | "replan" => Some(Strategy::ReplanParallelism),
        _ => None,
    }
}

/// `falcon sim`: a builder-API shortcut — assembles a [`ScenarioSpec`] from
/// flags and runs it through the same unified entry as `falcon run`.
fn run_sim(args: &Args) {
    let mut spec = ScenarioSpec::new(
        "sim",
        args.usize_or("tp", 2),
        args.usize_or("dp", 4),
        args.usize_or("pp", 1),
    )
    .iters(args.usize_or("iters", 300))
    .seed(args.u64_or("seed", 1))
    .mitigate(args.bool_or("mitigate", true))
    .replan(args.bool_or("replan", false));
    spec = match args.get("inject") {
        Some("gpu") => spec.fault(FaultSpec::new(
            FailSlowKind::GpuDegradation,
            Target::Gpu(0),
            0.25,
            0.4,
            args.f64_or("scale", 0.5),
        )),
        Some("cpu") => spec.fault(FaultSpec::new(
            FailSlowKind::CpuContention,
            Target::Node(0),
            0.25,
            0.4,
            args.f64_or("scale", 0.4),
        )),
        Some("net") => spec.fault(FaultSpec::new(
            FailSlowKind::NetworkCongestion,
            Target::Link(0, 1),
            0.25,
            0.4,
            args.f64_or("scale", 0.25),
        )),
        // A hang blocks the path outright (scale is carried but unused).
        Some("hang") => spec.fault(FaultSpec::new(
            FailSlowKind::CommHang,
            Target::Link(0, 1),
            0.25,
            0.4,
            1.0,
        )),
        _ => spec,
    };
    match spec.run() {
        Ok(outcome) => println!("{}", outcome.render()),
        Err(e) => eprintln!(
            "sim scenario invalid: {e}\n(hint: --inject net or hang needs a job \
             spanning at least 2 nodes, e.g. --dp 16)"
        ),
    }
}

fn run_fleet_cmd(args: &Args) {
    let mut cfg = falcon::reports::fleet::config_from_args(args);
    // --ledger-file seeds the campaign from a prior snapshot and writes
    // the evolved ledger back afterwards, so fleet health persists across
    // `falcon fleet` invocations (implies --ledger).
    let ledger_file = args.get("ledger-file").map(str::to_string);
    if let Some(path) = &ledger_file {
        match std::fs::read_to_string(path) {
            Ok(text) => match falcon::ledger::NodeLedger::parse(&text) {
                Ok(l) => {
                    eprintln!(
                        "[fleet] seeding ledger from {path}: {} tracked nodes, {} incidents",
                        l.len(),
                        l.total_incidents()
                    );
                    cfg.ledger_init = Some(l);
                }
                Err(e) => {
                    eprintln!("[fleet] ignoring corrupt ledger snapshot {path}: {e}");
                    cfg.ledger = true;
                }
            },
            // A missing file just starts a fresh ledger (first campaign).
            Err(_) => cfg.ledger = true,
        }
    }
    eprintln!(
        "[fleet] {} jobs x {} iters, seed {}, workers {} (0 = auto), compare {}, cluster {}",
        cfg.jobs,
        cfg.iters,
        cfg.seed,
        cfg.workers,
        cfg.compare,
        cfg.policy.map(|p| p.name()).unwrap_or("private"),
    );
    let report = falcon::fleet::run_fleet(&cfg);
    println!("{}", report.render());
    if let (Some(path), Some(ledger)) = (&ledger_file, &report.ledger) {
        match std::fs::write(path, ledger.to_json().to_string()) {
            Ok(()) => eprintln!("[fleet] ledger snapshot written to {path}"),
            Err(e) => eprintln!("[fleet] failed to write ledger snapshot {path}: {e}"),
        }
    }
}

/// `falcon audit`: run the invariant lint over the crate source (or
/// `--src DIR`) and exit non-zero unless the tree is clean.
fn run_audit(args: &Args) {
    let src = args.str_or("src", "");
    let root = if src.is_empty() {
        // Works from the repo root and from rust/.
        ["rust/src", "src"]
            .iter()
            .find(|p| std::path::Path::new(p).is_dir())
            .map(|p| p.to_string())
            .unwrap_or_else(|| "src".to_string())
    } else {
        src
    };
    let t0 = std::time::Instant::now();
    match falcon::audit::audit_dir_graph(std::path::Path::new(&root)) {
        Ok(audit) => {
            let ms = t0.elapsed().as_secs_f64() * 1000.0;
            if args.bool_or("graph", false) {
                if args.bool_or("dot", false) {
                    print!("{}", audit.graph.to_dot());
                } else if args.bool_or("json", false) {
                    println!("{}", audit.graph.to_json(&audit.flow));
                } else {
                    print!("{}", audit.graph.render(&audit.flow));
                }
                return;
            }
            if args.bool_or("json", false) {
                println!("{}", audit.report.to_json());
            } else {
                print!("{}", audit.report.render());
                let fps = if ms > 0.0 {
                    audit.report.files as f64 / (ms / 1000.0)
                } else {
                    0.0
                };
                println!(
                    "scan: {} files in {ms:.1} ms ({fps:.0} files/sec)",
                    audit.report.files
                );
            }
            if !audit.report.clean() {
                std::process::exit(1);
            }
        }
        Err(e) => {
            eprintln!("audit: cannot scan '{root}': {e}");
            std::process::exit(2);
        }
    }
}

#[cfg(feature = "pjrt")]
fn run_train(args: &Args) {
    use falcon::detect::{BocdConfig, Detector};
    use falcon::mitigate::microbatch;
    use falcon::runtime::Runtime;
    use falcon::trainer::{LiveTrainer, TrainerConfig};

    let preset = args.str_or("preset", "tiny");
    let dp = args.usize_or("dp", 2);
    let steps = args.usize_or("steps", 40);
    let rt = Runtime::new(args.str_or("artifacts", "artifacts")).expect("runtime");
    let mut t = LiveTrainer::new(
        &rt,
        &TrainerConfig {
            preset,
            dp,
            microbatches: args.usize_or("microbatches", 2),
            seed: args.u64_or("seed", 0),
        },
    )
    .expect("trainer (run `make artifacts` first)");

    // Optional injected compute fail-slow on worker 0 mid-run.
    let inject_at = args.usize_or("inject-at", steps / 3);
    let inject_scale = args.f64_or("scale", 0.4);
    let inject = args.bool_or("inject", false);

    let mut detector = Detector::new(BocdConfig::default());
    println!("step, loss, iter_time_s, alloc");
    for step in 0..steps {
        if inject && step == inject_at {
            // audit:allow(generation-discipline): LiveTrainer's own per-worker
            // scale vector, not a fabric::Cluster health field.
            t.compute_scale[0] = inject_scale;
            eprintln!("[inject] worker 0 compute scale -> {inject_scale}");
        }
        let obs = t.step().expect("step");
        if let Some(true) = detector.push(obs.iter_time_s) {
            // Fail-slow confirmed: rebalance micro-batches (S2) live.
            let times = t.microbatch_times(&obs);
            let total: usize = t.alloc.iter().sum();
            let alloc = microbatch::solve(&times, total).m;
            eprintln!("[falcon] fail-slow verified at step {step}; S2 realloc {alloc:?}");
            t.set_alloc(alloc);
        }
        println!(
            "{}, {:.4}, {:.3}, {:?}",
            obs.iter, obs.loss, obs.iter_time_s, t.alloc
        );
    }
}
