//! Shared-cluster substrate: one node pool, contended uplinks, and an
//! arbiter every mitigation action must go through.
//!
//! The paper's characterization (§3) is of a *shared* production cluster:
//! fail-slows propagate because jobs compete for the same nodes and
//! spine-leaf uplinks, and mitigation actions (node swaps, restarts) draw
//! from one finite healthy-node pool. This module supplies the three pieces
//! the fleet engine (see [`crate::fleet`]) composes into that setting:
//!
//! - [`ClusterState`] — the global node inventory. Every node has a
//!   [`GpuClass`](crate::fabric::GpuClass), an owner (the fleet job it is
//!   allocated to), a fail-slow flag synced from the owning job's injected
//!   events, and a quarantine epoch (released degraded hardware is repaired
//!   off-pool before it may be granted again). Nodes are grouped into
//!   *leaves* of [`ClusterState::leaf_size`] nodes; each leaf shares one
//!   spine uplink, and the effective per-job bandwidth on that uplink
//!   degrades with the number of co-resident jobs
//!   ([`ClusterState::contention_scale`]) — one job's traffic is another
//!   job's congestion.
//!
//! - [`Policy`] — pluggable admission/placement policies (`first-fit`,
//!   `packed`, `spread`, `straggler-aware`, `health-weighted`,
//!   `predictive-quarantine`) deciding which leaves a job's nodes land on
//!   and which spares a mitigation grant hands out. The last two consume
//!   the persistent node-health ledger ([`crate::ledger`]) when the fleet
//!   attaches one via [`ClusterState::ledger`]: `health-weighted` prefers
//!   high-score nodes (ties break by node id), `predictive-quarantine`
//!   additionally refuses to place onto nodes whose predicted
//!   next-incident epoch falls inside the requesting job's horizon.
//!   Without a ledger every score reads 1.0 and both reduce to
//!   `first-fit`.
//!
//! - [`Arbiter`] — the gate all S3/S4 mitigation requests pass through.
//!   Requests compete for the same spare pool and can be **granted**,
//!   **denied** (S3: the planner must escalate on accumulated impact
//!   alone), **queued** (S4: retried every epoch, granted in place after
//!   [`S4_MAX_WAIT_EPOCHS`]), or **preempted** by a higher-priority
//!   request taking the last spares.
//!
//! Determinism contract: none of these types contain randomness or clocks.
//! Arbitration outcomes depend only on the request set and the order the
//! fleet driver files them in (job-id order at each epoch boundary), so a
//! fixed fleet seed yields bit-identical outcomes across worker counts.

use std::collections::BTreeMap;

use crate::fabric::GpuClass;
use crate::ledger::NodeLedger;
use crate::mitigate::Strategy;

/// Nodes per leaf switch (spine-leaf: one shared uplink per leaf).
pub const DEFAULT_LEAF_SIZE: usize = 8;

/// Epochs a released degraded node spends in repair before rejoining the
/// healthy pool.
pub const QUARANTINE_EPOCHS: usize = 4;

/// Epochs an S4 (checkpoint-restart) request may queue before the arbiter
/// grants it *in place* (restart onto the same nodes once their contending
/// episodes clear) rather than starving the job forever.
pub const S4_MAX_WAIT_EPOCHS: usize = 3;

/// Bandwidth-sharing aggressiveness: with `k` co-resident jobs on a leaf
/// uplink each job sees `1 / (1 + alpha * (k - 1))` of the bandwidth.
pub const CONTENTION_ALPHA: f64 = 0.3;

/// Admission/placement policy for the shared cluster.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    /// Lowest-index free nodes, ignoring leaf structure.
    FirstFit,
    /// Fill the fullest leaves first (locality, high co-residency).
    Packed,
    /// Fill the least-loaded leaves first (balance, low co-residency).
    Spread,
    /// Avoid leaves with degraded/quarantined hardware, then balance.
    StragglerAware,
    /// Prefer nodes with the highest ledger health score (ties break by
    /// node id). Needs [`ClusterState::ledger`]; without one it reduces
    /// to [`Policy::FirstFit`].
    HealthWeighted,
    /// Health-weighted placement plus predictive admission: refuse nodes
    /// whose ledger-predicted next-incident epoch falls inside the
    /// requesting job's registered horizon.
    PredictiveQuarantine,
}

impl Policy {
    pub const ALL: [Policy; 6] = [
        Policy::FirstFit,
        Policy::Packed,
        Policy::Spread,
        Policy::StragglerAware,
        Policy::HealthWeighted,
        Policy::PredictiveQuarantine,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Policy::FirstFit => "first-fit",
            Policy::Packed => "packed",
            Policy::Spread => "spread",
            Policy::StragglerAware => "straggler-aware",
            Policy::HealthWeighted => "health-weighted",
            Policy::PredictiveQuarantine => "predictive-quarantine",
        }
    }

    /// Parse a CLI spelling (`--policy first-fit`). `None` for unknown.
    pub fn parse(s: &str) -> Option<Policy> {
        match s {
            "first-fit" | "firstfit" | "ff" => Some(Policy::FirstFit),
            "packed" | "pack" => Some(Policy::Packed),
            "spread" => Some(Policy::Spread),
            "straggler-aware" | "straggler" | "sa" => Some(Policy::StragglerAware),
            "health-weighted" | "health" | "hw" => Some(Policy::HealthWeighted),
            "predictive-quarantine" | "predictive" | "pq" => {
                Some(Policy::PredictiveQuarantine)
            }
            _ => None,
        }
    }
}

/// One node of the shared inventory.
#[derive(Clone, Debug)]
pub struct SharedNode {
    pub gpu_class: GpuClass,
    /// Fleet job currently occupying the node (`None` = free).
    pub owner: Option<usize>,
    /// An injected fail-slow episode is currently active on this node.
    pub flagged: bool,
    /// Node is in repair until this epoch (exclusive); 0 = healthy.
    pub quarantined_until: usize,
}

impl SharedNode {
    fn new(gpu_class: GpuClass) -> Self {
        SharedNode { gpu_class, owner: None, flagged: false, quarantined_until: 0 }
    }

    /// Usable as a healthy spare at `epoch`?
    pub fn spare_at(&self, epoch: usize) -> bool {
        self.owner.is_none() && !self.flagged && epoch >= self.quarantined_until
    }
}

/// The global node inventory plus the spine-leaf sharing model.
#[derive(Clone, Debug)]
pub struct ClusterState {
    pub nodes: Vec<SharedNode>,
    pub leaf_size: usize,
    pub contention_alpha: f64,
    /// Per-job inter-node communication volume (any consistent rate unit,
    /// e.g. bytes/s), used to weight uplink contention: a chatty job takes
    /// a proportionally larger bandwidth share, a silent one none. Jobs
    /// without an entry weigh 1.0, which reduces
    /// [`ClusterState::contention_scale_for`] to the flat co-residency
    /// formula.
    job_volume: BTreeMap<usize, f64>,
    /// Persistent node-health ledger, when the fleet attaches one. Drives
    /// quarantine durations in [`ClusterState::release`] and the
    /// health-aware policies; `None` keeps the memoryless behavior
    /// bit-identical.
    pub ledger: Option<NodeLedger>,
    /// Job → expected final fleet epoch, registered at admission so
    /// [`Policy::PredictiveQuarantine`] can test predicted incidents
    /// against the job's remaining horizon.
    job_horizon: BTreeMap<usize, usize>,
}

impl ClusterState {
    pub fn new(n_nodes: usize) -> Self {
        Self::with_leaf_size(n_nodes, DEFAULT_LEAF_SIZE)
    }

    pub fn with_leaf_size(n_nodes: usize, leaf_size: usize) -> Self {
        ClusterState {
            nodes: (0..n_nodes).map(|_| SharedNode::new(GpuClass::H800)).collect(),
            leaf_size: leaf_size.max(1),
            contention_alpha: CONTENTION_ALPHA,
            job_volume: BTreeMap::new(),
            ledger: None,
            job_horizon: BTreeMap::new(),
        }
    }

    /// Register the fleet epoch a job is expected to finish by, for
    /// predictive-quarantine admission. Cleared on job completion.
    pub fn set_job_horizon(&mut self, job: usize, end_epoch: usize) {
        self.job_horizon.insert(job, end_epoch);
    }

    /// Forget a finished job's horizon.
    pub fn clear_job_horizon(&mut self, job: usize) {
        self.job_horizon.remove(&job);
    }

    /// Ledger health score of a node; 1.0 without a ledger or history.
    pub fn health_score(&self, node: usize) -> f64 {
        self.ledger.as_ref().map_or(1.0, |l| l.score(node))
    }

    /// Register a job's inter-node communication volume for contention
    /// weighting (0.0 = the job never touches the uplinks).
    pub fn set_job_volume(&mut self, job: usize, rate: f64) {
        self.job_volume.insert(job, rate.max(0.0));
    }

    /// Forget a finished job's volume.
    pub fn clear_job_volume(&mut self, job: usize) {
        self.job_volume.remove(&job);
    }

    fn volume_of(&self, job: usize) -> f64 {
        self.job_volume.get(&job).copied().unwrap_or(1.0)
    }

    pub fn n_leaves(&self) -> usize {
        self.nodes.len().div_ceil(self.leaf_size)
    }

    pub fn leaf_of(&self, node: usize) -> usize {
        node / self.leaf_size
    }

    /// Node indices of one leaf.
    pub fn leaf_nodes(&self, leaf: usize) -> std::ops::Range<usize> {
        let lo = leaf * self.leaf_size;
        lo..((leaf + 1) * self.leaf_size).min(self.nodes.len())
    }

    /// Distinct jobs with at least one node in the leaf.
    pub fn co_resident_jobs(&self, leaf: usize) -> usize {
        let mut owners: Vec<usize> =
            self.leaf_nodes(leaf).filter_map(|n| self.nodes[n].owner).collect();
        owners.sort_unstable();
        owners.dedup();
        owners.len()
    }

    /// Degraded or in-repair nodes in the leaf (straggler-aware avoids
    /// these leaves; `epoch` resolves quarantine expiry).
    pub fn degraded_in_leaf(&self, leaf: usize, epoch: usize) -> usize {
        self.leaf_nodes(leaf)
            .filter(|&n| self.nodes[n].flagged || epoch < self.nodes[n].quarantined_until)
            .count()
    }

    /// Unweighted per-job bandwidth share on the leaf's uplink: `k`
    /// co-resident jobs each see `1 / (1 + alpha * (k - 1))`. This is the
    /// equal-volume special case of
    /// [`ClusterState::contention_scale_for`]; the fleet driver uses the
    /// volume-weighted form.
    pub fn contention_scale(&self, leaf: usize) -> f64 {
        let k = self.co_resident_jobs(leaf);
        if k <= 1 {
            1.0
        } else {
            1.0 / (1.0 + self.contention_alpha * (k - 1) as f64)
        }
    }

    /// Total registered communication volume of the distinct jobs resident
    /// on `leaf` (each co-resident job counted once). Precompute this once
    /// per leaf per epoch and feed it to
    /// [`ClusterState::contention_share`] for the O(1) per-job path.
    pub fn leaf_volume(&self, leaf: usize) -> f64 {
        let mut owners: Vec<usize> =
            self.leaf_nodes(leaf).filter_map(|n| self.nodes[n].owner).collect();
        owners.sort_unstable();
        owners.dedup();
        owners.iter().map(|&o| self.volume_of(o)).sum()
    }

    /// Volume-weighted bandwidth share a job RESIDENT on a leaf with total
    /// volume `leaf_volume` sees: `1 / (1 + alpha * V_others / v_job)`
    /// with `V_others = leaf_volume - v_job`.
    pub fn contention_share(&self, leaf_volume: f64, job: usize) -> f64 {
        let v = self.volume_of(job);
        if v <= 0.0 {
            return 1.0;
        }
        let others = (leaf_volume - v).max(0.0);
        if others <= 0.0 {
            1.0
        } else {
            1.0 / (1.0 + self.contention_alpha * others / v)
        }
    }

    /// Volume-weighted bandwidth share `job` (resident on `leaf`) sees on
    /// the leaf's uplink: `1 / (1 + alpha * V_others / v_job)`, where
    /// `V_others` sums the registered communication volumes of the other
    /// co-resident jobs.
    ///
    /// With equal volumes this reduces exactly to the flat
    /// `1 / (1 + alpha * (k - 1))` of
    /// [`ClusterState::contention_scale`]; a chattier neighbor squeezes
    /// the job harder, a silent neighbor not at all. A job with zero
    /// volume of its own sends nothing over the uplink, so it sees (and
    /// causes) no contention.
    pub fn contention_scale_for(&self, leaf: usize, job: usize) -> f64 {
        self.contention_share(self.leaf_volume(leaf), job)
    }

    /// Healthy free nodes at `epoch`, in index order.
    pub fn spares(&self, epoch: usize) -> Vec<usize> {
        (0..self.nodes.len()).filter(|&n| self.nodes[n].spare_at(epoch)).collect()
    }

    /// Allocate specific nodes to a job (panics if any is taken).
    pub fn claim(&mut self, job: usize, nodes: &[usize]) {
        for &n in nodes {
            assert!(self.nodes[n].owner.is_none(), "node {n} already owned");
            self.nodes[n].owner = Some(job);
        }
    }

    /// Release a node; degraded hardware goes to repair until
    /// `epoch + QUARANTINE_EPOCHS` — or, with a ledger attached, for the
    /// ledger's score-driven duration ([`NodeLedger::quarantine_epochs`],
    /// which still answers the same 4-epoch floor for clean nodes and in
    /// non-predictive mode). The ledger also closes the node's open
    /// incident here.
    pub fn release(&mut self, node: usize, epoch: usize) {
        let quarantine =
            self.ledger.as_ref().map_or(QUARANTINE_EPOCHS, |l| l.quarantine_epochs(node));
        let n = &mut self.nodes[node];
        n.owner = None;
        if n.flagged {
            n.flagged = false;
            n.quarantined_until = epoch + quarantine;
            if let Some(ledger) = self.ledger.as_mut() {
                ledger.record_release(node, epoch);
            }
        }
    }

    /// Leaves ordered by the policy's placement preference for `job`
    /// (deterministic: ties break by leaf index).
    fn leaf_order(&self, policy: Policy, job: usize, epoch: usize) -> Vec<usize> {
        let mut leaves: Vec<usize> = (0..self.n_leaves()).collect();
        let allocated =
            |l: usize| self.leaf_nodes(l).filter(|&n| self.nodes[n].owner.is_some()).count();
        let mine = |l: usize| {
            self.leaf_nodes(l).filter(|&n| self.nodes[n].owner == Some(job)).count()
        };
        match policy {
            Policy::FirstFit => {}
            Policy::Packed => {
                // Fullest first; leaves the job already occupies win ties.
                leaves.sort_by_key(|&l| {
                    (std::cmp::Reverse(mine(l)), std::cmp::Reverse(allocated(l)), l)
                });
            }
            Policy::Spread => {
                leaves.sort_by_key(|&l| (self.co_resident_jobs(l), allocated(l), l));
            }
            Policy::StragglerAware => {
                leaves.sort_by_key(|&l| {
                    (self.degraded_in_leaf(l, epoch), self.co_resident_jobs(l), allocated(l), l)
                });
            }
            // Node-level (not leaf-level) policies: picked by health in
            // `pick_spares_by_health`, so the leaf order is immaterial.
            Policy::HealthWeighted | Policy::PredictiveQuarantine => {}
        }
        leaves
    }

    /// Would placing `job` on `node` land inside the node's predicted
    /// next incident? Only predictive ledgers with a registered job
    /// horizon ever say yes.
    fn predicted_risky(&self, node: usize, job: usize, epoch: usize) -> bool {
        let ledger = match &self.ledger {
            Some(l) if l.predictive => l,
            _ => return false,
        };
        let horizon = match self.job_horizon.get(&job) {
            Some(&h) => h,
            None => return false,
        };
        match ledger.predicted_next_incident(node) {
            Some(next) => next >= epoch && next < horizon,
            None => false,
        }
    }

    /// Spare pick for the ledger-consuming policies: every eligible node
    /// ranked by (health score desc, node id) — the deterministic
    /// tie-break the ledger docs pin. [`Policy::PredictiveQuarantine`]
    /// additionally filters out predicted-risky nodes, so a too-small
    /// surviving pool surfaces as a `Denied`/`Queued` decision upstream.
    fn pick_spares_by_health(
        &self,
        policy: Policy,
        job: usize,
        n: usize,
        epoch: usize,
    ) -> Option<Vec<usize>> {
        let mut candidates: Vec<usize> = (0..self.nodes.len())
            .filter(|&node| self.nodes[node].spare_at(epoch))
            .filter(|&node| {
                policy != Policy::PredictiveQuarantine
                    || !self.predicted_risky(node, job, epoch)
            })
            .collect();
        candidates.sort_by(|&a, &b| {
            self.health_score(b).total_cmp(&self.health_score(a)).then(a.cmp(&b))
        });
        candidates.truncate(n);
        (candidates.len() == n).then_some(candidates)
    }

    /// Pick `n` healthy spare nodes for `job` per the policy; `None` when
    /// the pool cannot supply them.
    pub fn pick_spares(
        &self,
        policy: Policy,
        job: usize,
        n: usize,
        epoch: usize,
    ) -> Option<Vec<usize>> {
        if matches!(policy, Policy::HealthWeighted | Policy::PredictiveQuarantine) {
            return self.pick_spares_by_health(policy, job, n, epoch);
        }
        let mut picked = Vec::with_capacity(n);
        for leaf in self.leaf_order(policy, job, epoch) {
            for node in self.leaf_nodes(leaf) {
                if picked.len() == n {
                    break;
                }
                if self.nodes[node].spare_at(epoch) {
                    picked.push(node);
                }
            }
            if picked.len() == n {
                break;
            }
        }
        (picked.len() == n).then_some(picked)
    }
}

/// Why a request could not be granted this epoch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Decision {
    /// Fresh healthy nodes were allocated.
    Granted,
    /// Pool exhausted; the requester must escalate without this strategy.
    Denied,
    /// Pool exhausted but the request stays queued for a later epoch.
    Queued,
    /// Queued past [`S4_MAX_WAIT_EPOCHS`]: restart granted onto the same
    /// nodes (no fresh hardware — the pool never freed up).
    GrantedInPlace,
}

/// A pending S3/S4 resource request.
#[derive(Clone, Debug)]
pub struct GrantRequest {
    pub job: usize,
    pub strategy: Strategy,
    pub nodes_wanted: usize,
    pub filed_epoch: usize,
}

impl GrantRequest {
    /// Arbitration priority: restarts outrank swaps (the job asking for S4
    /// has accumulated strictly more impact under the ski-rental planner).
    fn priority(&self) -> u32 {
        match self.strategy {
            Strategy::CkptRestart => 2,
            _ => 1,
        }
    }
}

/// One arbitration outcome, for the fleet log and report.
#[derive(Clone, Debug)]
pub struct ArbOutcome {
    pub epoch: usize,
    pub job: usize,
    pub strategy: Strategy,
    pub decision: Decision,
    /// Epochs between filing and this decision.
    pub waited_epochs: usize,
    /// Fresh nodes handed out (empty for deny/queue/in-place).
    pub granted_nodes: Vec<usize>,
}

/// Cluster-wide mitigation arbitration: one queue, one spare pool, one
/// policy. All S3/S4 requests pass through [`Arbiter::arbitrate`], which
/// the fleet driver calls once per epoch with the requests filed in job-id
/// order — the determinism hinge.
#[derive(Clone, Debug)]
pub struct Arbiter {
    pub policy: Policy,
    queue: Vec<GrantRequest>,
    /// Arbitration rounds in which at least one request went unserved
    /// after a higher-priority grant had consumed spares (priority-induced
    /// starvation; counted per round so a queue of losers is not
    /// multi-counted).
    pub preempted: usize,
}

impl Arbiter {
    pub fn new(policy: Policy) -> Self {
        Arbiter { policy, queue: Vec::new(), preempted: 0 }
    }

    /// Admit a new job at `epoch`: allocate `n` nodes per the policy (for
    /// [`Policy::FirstFit`] the unsorted leaf order makes this the lowest
    /// free indices). `None` when the cluster cannot host the job right
    /// now — with staggered fleet starts the driver retries next epoch.
    pub fn admit(
        &mut self,
        cluster: &mut ClusterState,
        job: usize,
        n: usize,
        epoch: usize,
    ) -> Option<Vec<usize>> {
        let picked = cluster.pick_spares(self.policy, job, n, epoch)?;
        cluster.claim(job, &picked);
        Some(picked)
    }

    /// File a mitigation request. One outstanding request per job: a
    /// higher-strategy request replaces a queued lower one (S4 supersedes a
    /// starving S3), anything else is dropped.
    pub fn file(&mut self, req: GrantRequest) {
        if let Some(existing) = self.queue.iter_mut().find(|r| r.job == req.job) {
            if req.strategy > existing.strategy {
                *existing = req;
            }
            return;
        }
        self.queue.push(req);
    }

    /// Drop a job's queued request (episode healed before a grant arrived).
    /// Returns whether anything was queued.
    pub fn cancel(&mut self, job: usize) -> bool {
        let before = self.queue.len();
        self.queue.retain(|r| r.job != job);
        before != self.queue.len()
    }

    pub fn has_queued(&self, job: usize) -> bool {
        self.queue.iter().any(|r| r.job == job)
    }

    /// Decide every pending request against the current spare pool.
    ///
    /// Requests are served in (priority desc, filed epoch asc, job asc)
    /// order. Granted nodes are claimed immediately, so a high-priority
    /// request can take the spares a lower-priority one was waiting for —
    /// that starvation is counted as a preemption. S3 requests that find
    /// the pool empty are **denied** (cheap strategy, the planner escalates
    /// on impact); S4 requests **queue** and are granted in place after
    /// [`S4_MAX_WAIT_EPOCHS`].
    pub fn arbitrate(&mut self, cluster: &mut ClusterState, epoch: usize) -> Vec<ArbOutcome> {
        let mut pending = std::mem::take(&mut self.queue);
        pending.sort_by_key(|r| (std::cmp::Reverse(r.priority()), r.filed_epoch, r.job));

        let mut out = Vec::with_capacity(pending.len());
        let mut pool_exhausted_by_higher = false;
        let mut round_preempted = false;
        for req in pending {
            let waited = epoch.saturating_sub(req.filed_epoch);
            let grant = cluster.pick_spares(self.policy, req.job, req.nodes_wanted, epoch);
            match grant {
                Some(nodes) => {
                    cluster.claim(req.job, &nodes);
                    out.push(ArbOutcome {
                        epoch,
                        job: req.job,
                        strategy: req.strategy,
                        decision: Decision::Granted,
                        waited_epochs: waited,
                        granted_nodes: nodes,
                    });
                }
                None => {
                    if pool_exhausted_by_higher {
                        round_preempted = true;
                    }
                    let decision = match req.strategy {
                        Strategy::CkptRestart if waited < S4_MAX_WAIT_EPOCHS => {
                            self.queue.push(req.clone());
                            Decision::Queued
                        }
                        Strategy::CkptRestart => Decision::GrantedInPlace,
                        _ => Decision::Denied,
                    };
                    out.push(ArbOutcome {
                        epoch,
                        job: req.job,
                        strategy: req.strategy,
                        decision,
                        waited_epochs: waited,
                        granted_nodes: Vec::new(),
                    });
                }
            }
            // Once anything was granted this epoch, later shortfalls may be
            // due to that grab rather than a genuinely empty pool.
            if out.last().map(|o| o.decision == Decision::Granted).unwrap_or(false) {
                pool_exhausted_by_higher = true;
            }
        }
        if round_preempted {
            self.preempted += 1;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_leaf_cluster() -> ClusterState {
        ClusterState::with_leaf_size(8, 4)
    }

    #[test]
    fn leaf_math() {
        let c = two_leaf_cluster();
        assert_eq!(c.n_leaves(), 2);
        assert_eq!(c.leaf_of(3), 0);
        assert_eq!(c.leaf_of(4), 1);
        assert_eq!(c.leaf_nodes(1), 4..8);
    }

    #[test]
    fn contention_scale_degrades_with_co_residency() {
        let mut c = two_leaf_cluster();
        assert_eq!(c.contention_scale(0), 1.0);
        c.nodes[0].owner = Some(0);
        assert_eq!(c.contention_scale(0), 1.0, "a lone job sees full bandwidth");
        c.nodes[1].owner = Some(1);
        let two = c.contention_scale(0);
        c.nodes[2].owner = Some(2);
        let three = c.contention_scale(0);
        assert!(two < 1.0 && three < two, "{two} then {three}");
        assert_eq!(c.contention_scale(1), 1.0, "other leaf unaffected");
    }

    #[test]
    fn packed_fills_one_leaf_spread_fans_out() {
        let mut c = two_leaf_cluster();
        let mut packed = Arbiter::new(Policy::Packed);
        let a = packed.admit(&mut c, 0, 2, 0).unwrap();
        let b = packed.admit(&mut c, 1, 2, 0).unwrap();
        let leaves: Vec<usize> =
            a.iter().chain(&b).map(|&n| c.leaf_of(n)).collect();
        assert!(leaves.iter().all(|&l| l == leaves[0]), "packed spans leaves: {leaves:?}");

        let mut c = two_leaf_cluster();
        let mut spread = Arbiter::new(Policy::Spread);
        let a = spread.admit(&mut c, 0, 2, 0).unwrap();
        let b = spread.admit(&mut c, 1, 2, 0).unwrap();
        assert_ne!(
            c.leaf_of(a[0]),
            c.leaf_of(b[0]),
            "spread must use both leaves: {a:?} {b:?}"
        );
    }

    #[test]
    fn straggler_aware_avoids_degraded_leaves() {
        let mut c = two_leaf_cluster();
        c.nodes[1].flagged = true;
        let mut arb = Arbiter::new(Policy::StragglerAware);
        let placement = arb.admit(&mut c, 0, 2, 0).unwrap();
        for &n in &placement {
            assert_eq!(c.leaf_of(n), 1, "placed next to a straggler: {placement:?}");
        }
    }

    #[test]
    fn first_fit_takes_lowest_indices() {
        let mut c = two_leaf_cluster();
        c.nodes[0].owner = Some(9);
        let mut arb = Arbiter::new(Policy::FirstFit);
        assert_eq!(arb.admit(&mut c, 0, 2, 0).unwrap(), vec![1, 2]);
    }

    #[test]
    fn admit_fails_when_pool_too_small() {
        let mut c = ClusterState::with_leaf_size(2, 4);
        let mut arb = Arbiter::new(Policy::FirstFit);
        assert!(arb.admit(&mut c, 0, 3, 0).is_none());
        assert!(c.nodes.iter().all(|n| n.owner.is_none()), "failed admit must not leak");
    }

    #[test]
    fn s3_denied_on_empty_pool_s4_queues_then_in_place() {
        let mut c = ClusterState::with_leaf_size(2, 4);
        let mut arb = Arbiter::new(Policy::FirstFit);
        arb.admit(&mut c, 0, 1, 0).unwrap();
        arb.admit(&mut c, 1, 1, 0).unwrap(); // pool now empty

        arb.file(GrantRequest {
            job: 0,
            strategy: Strategy::AdjustTopology,
            nodes_wanted: 1,
            filed_epoch: 0,
        });
        arb.file(GrantRequest {
            job: 1,
            strategy: Strategy::CkptRestart,
            nodes_wanted: 1,
            filed_epoch: 0,
        });
        let out = arb.arbitrate(&mut c, 0);
        let d0 = out.iter().find(|o| o.job == 0).unwrap();
        let d1 = out.iter().find(|o| o.job == 1).unwrap();
        assert_eq!(d0.decision, Decision::Denied);
        assert_eq!(d1.decision, Decision::Queued);
        assert!(arb.has_queued(1) && !arb.has_queued(0));

        // Still starved S4_MAX_WAIT_EPOCHS later: granted in place.
        let mut last = Vec::new();
        for e in 1..=S4_MAX_WAIT_EPOCHS {
            last = arb.arbitrate(&mut c, e);
        }
        assert_eq!(last.len(), 1);
        assert_eq!(last[0].decision, Decision::GrantedInPlace);
        assert!(last[0].granted_nodes.is_empty());
        assert!(!arb.has_queued(1));
    }

    #[test]
    fn s4_outranks_earlier_s3_and_counts_preemption() {
        let mut c = ClusterState::with_leaf_size(4, 4);
        let mut arb = Arbiter::new(Policy::FirstFit);
        arb.admit(&mut c, 0, 1, 0).unwrap();
        arb.admit(&mut c, 1, 2, 0).unwrap(); // one spare left

        arb.file(GrantRequest {
            job: 0,
            strategy: Strategy::AdjustTopology,
            nodes_wanted: 1,
            filed_epoch: 0,
        });
        arb.file(GrantRequest {
            job: 1,
            strategy: Strategy::CkptRestart,
            nodes_wanted: 1,
            filed_epoch: 1,
        });
        let out = arb.arbitrate(&mut c, 1);
        let s4 = out.iter().find(|o| o.strategy == Strategy::CkptRestart).unwrap();
        let s3 = out.iter().find(|o| o.strategy == Strategy::AdjustTopology).unwrap();
        assert_eq!(s4.decision, Decision::Granted, "restart outranks the older swap");
        assert_eq!(s3.decision, Decision::Denied);
        assert_eq!(arb.preempted, 1);
    }

    #[test]
    fn release_quarantines_degraded_hardware() {
        let mut c = two_leaf_cluster();
        c.nodes[3].owner = Some(0);
        c.nodes[3].flagged = true;
        c.release(3, 5);
        assert!(!c.nodes[3].spare_at(5));
        assert!(!c.nodes[3].spare_at(5 + QUARANTINE_EPOCHS - 1));
        assert!(c.nodes[3].spare_at(5 + QUARANTINE_EPOCHS));
        // Healthy release returns straight to the pool.
        c.nodes[2].owner = Some(0);
        c.release(2, 5);
        assert!(c.nodes[2].spare_at(5));
    }

    #[test]
    fn file_dedupes_per_job_keeping_higher_strategy() {
        let mut arb = Arbiter::new(Policy::FirstFit);
        arb.file(GrantRequest {
            job: 0,
            strategy: Strategy::AdjustTopology,
            nodes_wanted: 1,
            filed_epoch: 0,
        });
        arb.file(GrantRequest {
            job: 0,
            strategy: Strategy::CkptRestart,
            nodes_wanted: 2,
            filed_epoch: 1,
        });
        arb.file(GrantRequest {
            job: 0,
            strategy: Strategy::AdjustTopology,
            nodes_wanted: 1,
            filed_epoch: 2,
        });
        assert_eq!(arb.queue.len(), 1);
        assert_eq!(arb.queue[0].strategy, Strategy::CkptRestart);
        assert!(arb.cancel(0));
        assert!(!arb.cancel(0));
    }

    #[test]
    fn health_weighted_prefers_high_score_nodes() {
        use crate::diagnose::AnomalyClass;
        let mut c = two_leaf_cluster();
        let mut ledger = NodeLedger::default();
        ledger.record_flag(0, 1, AnomalyClass::ComputeSlow);
        ledger.record_flag(1, 1, AnomalyClass::ComputeSlow);
        c.ledger = Some(ledger);
        // The battered nodes 0/1 rank behind every pristine node.
        let picked = c.pick_spares(Policy::HealthWeighted, 0, 2, 0).unwrap();
        assert_eq!(picked, vec![2, 3]);
        // Without a ledger every score is 1.0: exactly first-fit.
        let plain = two_leaf_cluster();
        assert_eq!(
            plain.pick_spares(Policy::HealthWeighted, 0, 3, 0),
            plain.pick_spares(Policy::FirstFit, 0, 3, 0),
        );
    }

    #[test]
    fn predictive_quarantine_denies_risky_nodes_inside_horizon() {
        use crate::diagnose::AnomalyClass;
        let mut c = ClusterState::with_leaf_size(3, 4);
        let mut ledger = NodeLedger::default();
        ledger.predictive = true;
        // Node 0: incidents open at 2 and 8 → predicted next at 14.
        ledger.record_flag(0, 2, AnomalyClass::ComputeSlow);
        ledger.record_release(0, 3);
        ledger.record_flag(0, 8, AnomalyClass::ComputeSlow);
        ledger.record_release(0, 9);
        c.ledger = Some(ledger);
        // Job 7 runs through epoch 20 — the predicted incident at 14 is
        // inside its horizon, so node 0 is refused and 3 nodes can't be
        // supplied from the 2 survivors.
        c.set_job_horizon(7, 20);
        assert!(c.pick_spares(Policy::PredictiveQuarantine, 7, 3, 10).is_none());
        assert_eq!(c.pick_spares(Policy::PredictiveQuarantine, 7, 2, 10), Some(vec![1, 2]));
        // A job ending before the predicted incident may still use node 0
        // (last: its score is battered).
        c.set_job_horizon(8, 12);
        assert_eq!(
            c.pick_spares(Policy::PredictiveQuarantine, 8, 3, 10),
            Some(vec![1, 2, 0])
        );
    }

    #[test]
    fn ledger_driven_release_extends_quarantine_for_repeat_offenders() {
        use crate::diagnose::AnomalyClass;
        let mut c = two_leaf_cluster();
        let mut ledger = NodeLedger::default();
        ledger.predictive = true;
        ledger.record_flag(3, 0, AnomalyClass::ComputeSlow);
        ledger.record_release(3, 1);
        ledger.record_flag(3, 5, AnomalyClass::ComputeSlow);
        c.ledger = Some(ledger);
        c.nodes[3].owner = Some(0);
        c.nodes[3].flagged = true;
        c.release(3, 6);
        assert!(
            !c.nodes[3].spare_at(6 + QUARANTINE_EPOCHS),
            "repeat offender must quarantine past the memoryless floor"
        );
        // The release also closed the open incident in the ledger.
        assert_eq!(c.ledger.as_ref().unwrap().total_incidents(), 2);

        // A non-predictive (shadow) ledger keeps the memoryless floor.
        let mut shadow = two_leaf_cluster();
        let mut obs = NodeLedger::default();
        obs.record_flag(2, 0, AnomalyClass::ComputeSlow);
        obs.record_release(2, 1);
        obs.record_flag(2, 5, AnomalyClass::ComputeSlow);
        shadow.ledger = Some(obs);
        shadow.nodes[2].owner = Some(0);
        shadow.nodes[2].flagged = true;
        shadow.release(2, 6);
        assert!(!shadow.nodes[2].spare_at(6 + QUARANTINE_EPOCHS - 1));
        assert!(shadow.nodes[2].spare_at(6 + QUARANTINE_EPOCHS));
    }

    #[test]
    fn policy_names_round_trip() {
        for p in Policy::ALL {
            assert_eq!(Policy::parse(p.name()), Some(p));
        }
        assert_eq!(Policy::parse("nonsense"), None);
    }

    #[test]
    fn volume_weighted_contention_reduces_to_flat_when_equal() {
        let mut c = two_leaf_cluster();
        c.nodes[0].owner = Some(0);
        c.nodes[1].owner = Some(1);
        c.nodes[2].owner = Some(2);
        // No volumes registered: every job defaults to weight 1.0.
        for j in 0..3 {
            assert!((c.contention_scale_for(0, j) - c.contention_scale(0)).abs() < 1e-12);
        }
        // Registering equal volumes changes nothing.
        for j in 0..3 {
            c.set_job_volume(j, 5e9);
        }
        for j in 0..3 {
            assert!((c.contention_scale_for(0, j) - c.contention_scale(0)).abs() < 1e-12);
        }
    }

    #[test]
    fn chatty_jobs_take_a_larger_share_and_silent_jobs_none() {
        let mut c = two_leaf_cluster();
        c.nodes[0].owner = Some(0);
        c.nodes[1].owner = Some(1);
        c.nodes[2].owner = Some(2);
        c.set_job_volume(0, 9.0);
        c.set_job_volume(1, 1.0);
        c.set_job_volume(2, 0.0);
        let big = c.contention_scale_for(0, 0);
        let small = c.contention_scale_for(0, 1);
        assert!(big > small, "chatty job must keep more bandwidth: {big} vs {small}");
        // The silent job neither suffers nor causes contention.
        assert_eq!(c.contention_scale_for(0, 2), 1.0);
        let with_silent = c.contention_scale_for(0, 0);
        c.clear_job_volume(2); // back to the default weight of 1.0
        assert!(c.contention_scale_for(0, 0) < with_silent);
    }

    #[test]
    fn contention_share_properties() {
        // Property: for random co-resident volume mixes, every share is in
        // (0, 1), chattier jobs never get a smaller share than quieter
        // ones, and adding a neighbor never increases anyone's share.
        crate::util::prop::check(
            "volume-weighted-contention",
            2024,
            200,
            |rng| {
                let k = 2 + rng.below(6) as usize; // 2..=7 jobs on one leaf
                (0..k).map(|_| rng.range_f64(0.1, 50.0)).collect::<Vec<f64>>()
            },
            |vols| {
                let k = vols.len();
                let mut c = ClusterState::with_leaf_size(8, 8);
                for j in 0..k {
                    c.nodes[j].owner = Some(j);
                    c.set_job_volume(j, vols[j]);
                }
                let shares: Vec<f64> = (0..k).map(|j| c.contention_scale_for(0, j)).collect();
                let leaf_vol = c.leaf_volume(0);
                for (j, &s) in shares.iter().enumerate() {
                    if !(s > 0.0 && s < 1.0) {
                        return Err(format!("share {s} out of (0, 1) for job {j}"));
                    }
                    // The O(1) precomputed path agrees with the direct one.
                    if (c.contention_share(leaf_vol, j) - s).abs() > 1e-12 {
                        return Err(format!("fast path disagrees for job {j}"));
                    }
                }
                for a in 0..k {
                    for b in 0..k {
                        if vols[a] > vols[b] && shares[a] < shares[b] - 1e-12 {
                            return Err(format!(
                                "chattier job {a} got a smaller share: {} vs {}",
                                shares[a], shares[b]
                            ));
                        }
                    }
                }
                let mut c2 = c.clone();
                c2.nodes[7].owner = Some(99);
                c2.set_job_volume(99, 10.0);
                for j in 0..k {
                    if c2.contention_scale_for(0, j) > shares[j] + 1e-12 {
                        return Err(format!("a new neighbor increased job {j}'s share"));
                    }
                }
                Ok(())
            },
        );
    }
}
