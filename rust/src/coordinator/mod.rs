//! The FALCON coordinator: GlobalController + GlobalAnalyzer (§4.1's
//! master) driving the three-phase detection workflow and the mitigation
//! planner against a running job.
//!
//! Per iteration the coordinator:
//!  1. (tracking) feeds the measured iteration time to BOCD+V;
//!  2. on a verified onset, runs the profiling phase (suspicious groups via
//!     the 1.1x-median rule) and the validation phase (GEMM dispatch +
//!     O(1) P2P passes) under a lightweight training suspension, yielding a
//!     root cause;
//!  3. while the episode persists, advances the ski-rental planner and
//!     executes whatever strategy it escalates to (S1–S4) on the job.
//!
//! The same coordinator drives the simulator (`TrainingSim`) and, through
//! the `Job` trait, the live PJRT trainer — the paper's R1 framework
//! independence realized as an interface.

use crate::detect::bocd::BocdConfig;
use crate::detect::detector::Detector;
use crate::detect::profiler::{self, GroupProfile};
use crate::detect::validate::{self, SlowEdge, SlowGpu};
use crate::diagnose::{self, EpisodeDiagnosis};
use crate::inject::FailSlowKind;
use crate::mitigate::microbatch;
use crate::mitigate::planner::{MitigationPlanner, Overheads, Strategy};
use crate::mitigate::replan::{self, ReplanPlan};
use crate::mitigate::topology;
use crate::sim::TrainingSim;
use crate::simkit::{from_secs, Time};

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct FalconConfig {
    pub bocd: BocdConfig,
    pub overheads: Overheads,
    /// Run FALCON-MITIGATE (off = detection-only, the §3 probe mode).
    pub mitigate: bool,
    /// Hold mitigation back for this many iterations after an episode
    /// opens (detection and diagnosis still run on time). 0 = react
    /// immediately, the normal behavior; the what-if engine's
    /// `DelayMitigation` counterfactual raises it to ask "what if FALCON
    /// had reacted N iterations later?".
    pub mitigation_delay_iters: usize,
    /// Shared-cluster mode: S3/S4 need hardware from a finite healthy-node
    /// pool, so instead of executing immediately they file a request (see
    /// [`Falcon::take_request`]) that the fleet's `cluster::Arbiter` may
    /// grant, queue, or deny. Off (the default) = the job owns its cluster
    /// and every escalation executes immediately.
    pub defer_heavy: bool,
    /// Cost of the brief validation suspension (trap NCCL calls, run
    /// benches, §4.3's "lightweight training suspension").
    pub validation_pause: Time,
    /// Cost of an S3 topology adjustment pause (§5.3: under a minute).
    pub topology_pause: Time,
    /// Cost of an S4 checkpoint-restart.
    pub restart_cost: Time,
    /// Enable the S5 malleable-parallelism tier (beyond the paper): the
    /// ski-rental ladder gains `Strategy::ReplanParallelism` at its own
    /// overhead slot, and a denied S3/S4 grant triggers an immediate
    /// re-plan within the existing allocation — graceful degradation when
    /// the healthy-node pool is exhausted. Off (the default) leaves every
    /// run bit-identical to the four-tier ladder.
    pub replan: bool,
    /// Cost of an S5 re-plan pause (dump to memory, migrate the affected
    /// stages in place, re-split, restore — a few minutes).
    pub replan_pause: Time,
}

impl Default for FalconConfig {
    fn default() -> Self {
        FalconConfig {
            bocd: BocdConfig::default(),
            overheads: Overheads::default(),
            mitigate: true,
            mitigation_delay_iters: 0,
            defer_heavy: false,
            validation_pause: from_secs(5.0),
            topology_pause: from_secs(45.0),
            restart_cost: from_secs(20.0 * 60.0),
            replan: false,
            replan_pause: from_secs(3.0 * 60.0),
        }
    }
}

/// Diagnosis produced by the profiling + validation phases.
#[derive(Clone, Debug)]
pub struct Diagnosis {
    pub kind: FailSlowKind,
    pub slow_gpus: Vec<SlowGpu>,
    pub slow_edges: Vec<SlowEdge>,
    pub suspicious_groups: usize,
}

/// One coordinator action, for logs and figure annotations.
#[derive(Clone, Debug)]
pub struct Action {
    pub at: Time,
    pub iter: usize,
    pub what: ActionKind,
}

#[derive(Clone, Debug)]
pub enum ActionKind {
    EpisodeOpened,
    Diagnosed(Diagnosis),
    Applied(Strategy),
    /// Shared-cluster mode: the strategy escalated but needs a resource
    /// grant from the cluster arbiter before it can execute.
    Requested(Strategy),
    /// The arbiter granted the request (fresh nodes or in-place).
    Granted(Strategy),
    /// The arbiter denied the request — the healthy-node pool was
    /// exhausted; escalation continues on accumulated impact. The second
    /// field is the episode's consecutive-denial streak at this denial
    /// (1-based), the dead-end hysteresis the S5 fallback keys off.
    Denied(Strategy, usize),
    EpisodeClosed,
}

/// The coordinator state machine.
///
/// `Clone` captures the complete coordinator state (detector posterior,
/// planner escalation cursor, action log) so the what-if engine can
/// snapshot a supervised run and replay counterfactual tails.
#[derive(Clone)]
pub struct Falcon {
    pub cfg: FalconConfig,
    pub detector: Detector,
    planner: Option<MitigationPlanner>,
    pub diagnosis: Option<Diagnosis>,
    pub actions: Vec<Action>,
    restarts: usize,
    /// Strategy awaiting a cluster grant (shared-cluster mode only).
    pending_grant: Option<Strategy>,
    /// Iteration at which the currently open episode was verified (drives
    /// the `mitigation_delay_iters` counterfactual gate).
    episode_open_iter: Option<usize>,
    /// Op-trace verdicts (`crate::diagnose`): one per episode open or
    /// compound re-diagnosis. Surfaced as `Outcome::diagnosis`.
    pub episode_diagnoses: Vec<EpisodeDiagnosis>,
    /// A hang verdict's S4 held back by `mitigation_delay_iters`: the
    /// iteration at which the restart fires (`None` = nothing pending).
    hang_restart_due: Option<usize>,
    /// The S5 re-plan currently applied to the job, if any — the job is in
    /// the malleable degradation mode and [`replan::revert`] restores the
    /// nominal layout bit-for-bit once the hardware heals.
    replan_active: Option<ReplanPlan>,
    /// Whether S5 was already attempted this episode (a failed attempt
    /// still pays a partial pause; retrying every denial would pay it over
    /// and over for the same verdict).
    replan_tried: bool,
}

impl Falcon {
    pub fn new(cfg: FalconConfig) -> Self {
        Falcon {
            detector: Detector::new(cfg.bocd),
            cfg,
            planner: None,
            diagnosis: None,
            actions: Vec::new(),
            restarts: 0,
            pending_grant: None,
            episode_open_iter: None,
            episode_diagnoses: Vec::new(),
            hang_restart_due: None,
            replan_active: None,
            replan_tried: false,
        }
    }

    /// Episode planner for a fresh diagnosis: the four-tier ladder, or the
    /// five-tier one when the S5 malleable tier is enabled.
    fn make_planner(&self, kind: FailSlowKind) -> MitigationPlanner {
        if self.cfg.replan {
            MitigationPlanner::with_replan(kind, self.cfg.overheads)
        } else {
            MitigationPlanner::new(kind, self.cfg.overheads)
        }
    }

    /// Process one finished iteration of the simulated job.
    pub fn on_iteration(&mut self, sim: &mut TrainingSim, iter: usize, iter_time_s: f64) {
        let verdict = self.detector.push(iter_time_s);

        match verdict {
            Some(true) => {
                self.actions.push(Action { at: sim.now, iter, what: ActionKind::EpisodeOpened });
                self.episode_open_iter = Some(iter);
                self.replan_tried = false;
                let diag = self.diagnose(sim);
                self.planner = Some(self.make_planner(diag.kind));
                self.actions.push(Action {
                    at: sim.now,
                    iter,
                    what: ActionKind::Diagnosed(diag.clone()),
                });
                self.diagnosis = Some(diag);
                self.classify_episode(sim, iter);
            }
            Some(false) => {
                self.actions.push(Action { at: sim.now, iter, what: ActionKind::EpisodeClosed });
                self.planner = None;
                self.diagnosis = None;
                self.episode_open_iter = None;
                self.hang_restart_due = None;
                self.replan_tried = false;
                // S5 exit check first: if the hardware healed, the nominal
                // layout comes back bit-for-bit; if the relief came from
                // the re-plan itself, the plan stays (no oscillation).
                self.maybe_exit_replan(sim);
                if self.cfg.mitigate && self.replan_active.is_none() {
                    // Re-solve the allocation for the *current* replica
                    // speeds: if the underlying degradation healed this is
                    // even again; if the relief came from S2 itself, the
                    // skew is preserved (no oscillation).
                    let times = sim.replica_microbatch_times();
                    let total = sim.spec.wl.microbatches * sim.spec.cfg.dp;
                    sim.set_microbatch_alloc(microbatch::solve(&times, total).m);
                }
            }
            None => {}
        }

        // Counterfactual delay gate: with `mitigation_delay_iters > 0` the
        // planner sits out the first N iterations after the episode opens
        // (impact accumulation included — FALCON "reacts later", it does
        // not pre-accumulate). 0 leaves behavior bit-identical.
        let delay_passed = self
            .episode_open_iter
            .map_or(true, |o| iter >= o + self.cfg.mitigation_delay_iters);
        if self.detector.slow_now() && self.cfg.mitigate && delay_passed {
            // A hang verdict whose S4 was held back by the delay gate
            // fires as soon as the gate opens (and the episode persists).
            if self.hang_restart_due.is_some_and(|due| iter >= due) {
                self.hang_restart_due = None;
                self.apply(sim, iter, Strategy::CkptRestart);
            }
            // Compound escalation (Fig 17): a further verified upward shift
            // inside the episode means a NEW root cause arrived — re-run
            // profiling + validation and retarget the planner, carrying the
            // accumulated impact forward.
            if self.detector.take_escalation() {
                let diag = self.diagnose(sim);
                if self.diagnosis.as_ref().map(|d| d.kind) != Some(diag.kind) {
                    self.planner = Some(self.make_planner(diag.kind));
                    // A new root cause may be re-plannable even though the
                    // first was not (or vice versa): give S5 a fresh shot.
                    self.replan_tried = false;
                }
                self.actions.push(Action {
                    at: sim.now,
                    iter,
                    what: ActionKind::Diagnosed(diag.clone()),
                });
                self.diagnosis = Some(diag);
                self.classify_episode(sim, iter);
            }
            let healthy = self.detector.baseline();
            let escalate = self
                .planner
                .as_mut()
                .and_then(|p| p.on_slow_iter(iter_time_s, healthy));
            if let Some(strategy) = escalate {
                self.apply(sim, iter, strategy);
            }
        } else if self.cfg.mitigate && !self.detector.slow_now() && iter % 20 == 19 {
            // Housekeeping while healthy: first give a kept S5 plan its
            // periodic exit check (an episode can close while the fault
            // persists, then the fault expires without re-opening one)...
            self.maybe_exit_replan(sim);
            if self.replan_active.is_none() {
                // ...then drop stale S2 skew once the replicas are
                // homogeneous again (episodes can close while a
                // later-expiring event still held the skew in place).
                let times = sim.replica_microbatch_times();
                let total = sim.spec.wl.microbatches * sim.spec.cfg.dp;
                let solved = microbatch::solve(&times, total).m;
                if solved != sim.microbatch_alloc {
                    sim.set_microbatch_alloc(solved);
                }
            }
        }
    }

    /// Exit check for the S5 degradation mode: tentatively revert to the
    /// nominal layout and keep the reversion only if nominal is no slower —
    /// i.e. the hardware actually healed. If the relief is coming from the
    /// plan itself (the fault persists), re-enter the mode unchanged so the
    /// close/re-open cycle cannot oscillate. Noise-free estimates only; a
    /// disabled S5 (`replan_active == None`) makes this a strict no-op.
    fn maybe_exit_replan(&mut self, sim: &mut TrainingSim) {
        let Some(p) = self.replan_active.take() else { return };
        let with_plan = sim.estimate_iter_time_s();
        replan::revert(sim, &p);
        let nominal = sim.estimate_iter_time_s();
        if nominal > with_plan * 1.02 {
            // Still degraded without the plan: stay in the mode.
            for &(a, b) in &p.swaps {
                sim.grid.swap_nodes(a, b);
            }
            let total = sim.spec.wl.microbatches * sim.spec.cfg.dp;
            if p.alloc.len() == sim.spec.cfg.dp && p.alloc.iter().sum::<usize>() == total {
                sim.set_microbatch_alloc(p.alloc.clone());
            }
            self.replan_active = Some(p);
        }
    }

    /// Op-trace classification (the hang-vs-slow taxonomy of
    /// `crate::diagnose`): fold the recent trace window into a class +
    /// culprit verdict. Hang verdicts route STRAIGHT to S4 — the paper's
    /// bench-driven diagnosis above cannot see a hang (its probes run on
    /// nominal health, where a wedged path still times healthy) and the
    /// S1–S3 ladder cannot unwedge a blocked collective; every iteration
    /// spent escalating is priced at the watchdog timeout. Slow verdicts
    /// change nothing: the ski-rental escalation already handles them.
    fn classify_episode(&mut self, sim: &mut TrainingSim, iter: usize) {
        let Some(verdict) = diagnose::classify(&sim.op_trace) else {
            return; // below every evidence bar: transient, let it close
        };
        let hang = verdict.class.is_hang();
        self.episode_diagnoses.push(EpisodeDiagnosis { iter, at: sim.now, verdict });
        if hang && self.cfg.mitigate {
            if self.cfg.mitigation_delay_iters == 0 {
                self.apply(sim, iter, Strategy::CkptRestart);
            } else {
                self.hang_restart_due = Some(iter + self.cfg.mitigation_delay_iters);
            }
        }
    }

    /// Profiling + validation under a lightweight suspension (§4.3).
    fn diagnose(&mut self, sim: &mut TrainingSim) -> Diagnosis {
        sim.now += self.cfg.validation_pause;

        // --- profiling: find suspicious groups, per class so medians
        // compare like with like (DP rings vs DP rings, PP chains vs PP).
        let raw = sim.profile_groups();
        let mut suspicious: Vec<GroupProfile> = Vec::new();
        for class in [crate::sim::GroupClass::Dp, crate::sim::GroupClass::Pp] {
            let set: Vec<(u64, Vec<usize>, f64)> = raw
                .iter()
                .filter(|g| g.class == class)
                .map(|g| (g.id, g.ranks.clone(), g.mean_time))
                .collect();
            let profs = profiler::to_profiles(&set);
            suspicious.extend(profiler::suspicious_groups(&profs, profiler::SUSPICION_FACTOR));
        }
        let n_suspicious = suspicious.len();

        // --- validation: GEMM per candidate GPU, P2P passes per group ----
        // When profiling finds nothing (e.g. pure computation fail-slow in a
        // dp=1 job, or uniform degradation), validate all ranks.
        let candidates = if suspicious.is_empty() {
            (0..sim.spec.cfg.world()).collect()
        } else {
            profiler::candidate_ranks(&suspicious)
        };
        let mut slow_gpus = validate::validate_compute(&candidates, &mut |r| sim.bench_gpu(r));

        // Communication validation: run the O(1) P2P passes over every
        // suspicious group, pooling edge timings with the *healthy* groups
        // of the same class as reference (a 2-member ring has no internal
        // healthy edge to compare against — the pooled median supplies it).
        let mut slow_edges: Vec<SlowEdge> = Vec::new();
        if !suspicious.is_empty() {
            let suspicious_ids: std::collections::BTreeSet<u64> =
                suspicious.iter().map(|g| g.id).collect();
            let mut measurements: Vec<(u64, usize, usize, f64)> = Vec::new();
            for g in &raw {
                let group = crate::collectives::CommGroup::new(
                    g.ranks.clone(),
                    g.ranks.iter().map(|&r| sim.grid.gpu_of(r)).collect(),
                    crate::collectives::Topology::Ring,
                );
                let plan = validate::plan_for(&group);
                for pass in &plan.passes {
                    for &(a, b) in pass {
                        let t = sim.bench_edge(group.ranks[a], group.ranks[b]);
                        measurements.push((g.id, group.ranks[a], group.ranks[b], t));
                    }
                }
            }
            let all_times: Vec<f64> = measurements.iter().map(|m| m.3).collect();
            let med = crate::util::stats::median(&all_times);
            for (gid, from, to, t) in measurements {
                if suspicious_ids.contains(&gid) && t > validate::SLOW_FACTOR * med {
                    slow_edges.push(SlowEdge { from_rank: from, to_rank: to, slowdown: t / med });
                }
            }
        }

        // Profiling is a noisy, *relative* filter: if the narrowed
        // validation confirmed nothing, widen to a full-job GEMM sweep
        // before concluding (otherwise a jitter-flagged group would mask a
        // real slow GPU elsewhere).
        if slow_gpus.is_empty() && slow_edges.is_empty() && !suspicious.is_empty() {
            let all: Vec<usize> = (0..sim.spec.cfg.world()).collect();
            slow_gpus = validate::validate_compute(&all, &mut |r| sim.bench_gpu(r));
        }

        // Root cause: slow links beat slow GPUs when both appear (comm
        // affects the whole ring); GEMM-clean + link-clean slow iterations
        // with suspicious compute point to host (CPU) contention — exactly
        // the paper's Case-1 reasoning.
        let kind = if !slow_edges.is_empty() {
            FailSlowKind::NetworkCongestion
        } else if !slow_gpus.is_empty() {
            FailSlowKind::GpuDegradation
        } else {
            FailSlowKind::CpuContention
        };

        Diagnosis { kind, slow_gpus, slow_edges, suspicious_groups: n_suspicious }
    }

    /// Route an escalated strategy: execute directly, or (shared-cluster
    /// mode) file a resource request for S3/S4 and wait for the arbiter.
    fn apply(&mut self, sim: &mut TrainingSim, iter: usize, strategy: Strategy) {
        if self.cfg.defer_heavy
            && matches!(strategy, Strategy::AdjustTopology | Strategy::CkptRestart)
        {
            self.pending_grant = Some(strategy);
            self.actions.push(Action { at: sim.now, iter, what: ActionKind::Requested(strategy) });
            return;
        }
        self.execute(sim, iter, strategy);
    }

    /// Take the strategy waiting on a cluster grant, if any (the fleet
    /// driver files it with the arbiter at the next epoch boundary).
    pub fn take_request(&mut self) -> Option<Strategy> {
        self.pending_grant.take()
    }

    /// The arbiter granted fresh hardware: execute the strategy now.
    pub fn execute_granted(&mut self, sim: &mut TrainingSim, strategy: Strategy) {
        let iter = sim.iter;
        self.actions.push(Action { at: sim.now, iter, what: ActionKind::Granted(strategy) });
        self.execute(sim, iter, strategy);
    }

    /// S4 granted *in place* after queue starvation: the pool never freed
    /// up, so the restart reschedules onto the SAME nodes. The pause is
    /// paid and transient episodes may lapse during it, but persistent
    /// degradation on this hardware survives — the honest cost of a
    /// saturated healthy-node pool.
    pub fn execute_granted_in_place(&mut self, sim: &mut TrainingSim) {
        let (iter, s) = (sim.iter, Strategy::CkptRestart);
        self.actions.push(Action { at: sim.now, iter, what: ActionKind::Granted(s) });
        if let Some(p) = self.replan_active.take() {
            // A restart reschedules from the nominal plan: unwind S5 first.
            replan::revert(sim, &p);
        }
        sim.restart_in_place(self.cfg.restart_cost);
        self.restarts += 1;
        self.planner = None;
        self.diagnosis = None;
        self.actions.push(Action { at: sim.now, iter, what: ActionKind::Applied(s) });
    }

    /// Record a grant outcome the fleet driver executed (or refused)
    /// itself: `granted = true` logs grant + application (the driver
    /// already mutated the sim, e.g. swapped the degraded node's hardware
    /// for a spare); `false` logs a denial — with the episode's
    /// consecutive-denial streak — and tells the planner so escalation
    /// proceeds on accumulated impact without assuming S3 ever succeeds.
    /// With the S5 tier enabled, the first denial of an episode is the
    /// dead-end signal: the pool is exhausted, so re-plan the
    /// parallelization within the existing allocation right away instead
    /// of waiting for the next impact threshold.
    pub fn note_grant(&mut self, sim: &mut TrainingSim, strategy: Strategy, granted: bool) {
        let (at, iter) = (sim.now, sim.iter);
        if granted {
            self.actions.push(Action { at, iter, what: ActionKind::Granted(strategy) });
            self.actions.push(Action { at, iter, what: ActionKind::Applied(strategy) });
            if let Some(p) = self.planner.as_mut() {
                p.on_granted();
            }
        } else {
            let streak = match self.planner.as_mut() {
                Some(p) => {
                    p.on_denied(strategy);
                    p.denied_streak()
                }
                None => 1,
            };
            self.actions.push(Action { at, iter, what: ActionKind::Denied(strategy, streak) });
            if self.cfg.mitigate && self.cfg.replan && !self.replan_tried {
                self.execute(sim, iter, Strategy::ReplanParallelism);
            }
        }
    }

    /// Force-execute a strategy right now, bypassing the ski-rental
    /// planner and any cluster arbitration — the what-if engine's
    /// `ForceLevel` counterfactual ("what if S3 had run at t?"). The
    /// action is logged like a planner-driven application.
    pub fn force(&mut self, sim: &mut TrainingSim, strategy: Strategy) {
        let iter = sim.iter;
        self.execute(sim, iter, strategy);
    }

    /// Execute a strategy on the job.
    fn execute(&mut self, sim: &mut TrainingSim, iter: usize, strategy: Strategy) {
        match strategy {
            Strategy::Ignore => {}
            Strategy::AdjustMicrobatch => {
                let times = sim.replica_microbatch_times();
                let total = sim.spec.wl.microbatches * sim.spec.cfg.dp;
                let alloc = microbatch::solve(&times, total);
                sim.set_microbatch_alloc(alloc.m);
            }
            Strategy::AdjustTopology => {
                let plan = topology::plan(sim, 2);
                if !plan.swaps.is_empty() {
                    topology::apply(sim, &plan, self.cfg.topology_pause);
                } else {
                    sim.now += self.cfg.topology_pause / 4; // aborted pause
                }
            }
            Strategy::CkptRestart => {
                if let Some(p) = self.replan_active.take() {
                    // A restart reschedules from the nominal plan: unwind S5 first.
                    replan::revert(sim, &p);
                }
                sim.restart(self.cfg.restart_cost);
                self.restarts += 1;
                self.planner = None;
                self.diagnosis = None;
            }
            Strategy::ReplanParallelism => {
                self.replan_tried = true;
                let plan = replan::plan(sim, 2);
                if plan.is_worthwhile() {
                    replan::apply(sim, &plan, self.cfg.replan_pause);
                    self.replan_active = Some(match self.replan_active.take() {
                        Some(prev) => prev.merge(plan),
                        None => plan,
                    });
                } else {
                    sim.now += self.cfg.replan_pause / 4; // aborted pause
                }
            }
        }
        self.actions.push(Action { at: sim.now, iter, what: ActionKind::Applied(strategy) });
    }

    pub fn restarts(&self) -> usize {
        self.restarts
    }

    /// Times at which verified episodes opened (fleet-level detection-
    /// latency accounting matches these against the injected trace).
    pub fn episode_opens(&self) -> Vec<Time> {
        self.actions
            .iter()
            .filter(|a| matches!(a.what, ActionKind::EpisodeOpened))
            .map(|a| a.at)
            .collect()
    }

    /// Strategies applied so far (for assertions and figure annotations).
    pub fn applied_strategies(&self) -> Vec<Strategy> {
        self.actions
            .iter()
            .filter_map(|a| match a.what {
                ActionKind::Applied(s) => Some(s),
                _ => None,
            })
            .collect()
    }
}

/// Run a simulated job for `iters` iterations under FALCON control,
/// returning (outcome, coordinator).
pub fn run_with_falcon(
    sim: &mut TrainingSim,
    cfg: FalconConfig,
    iters: usize,
) -> Falcon {
    let mut falcon = Falcon::new(cfg);
    for _ in 0..iters {
        let obs = sim.step();
        falcon.on_iteration(sim, obs.iter, obs.duration_s());
    }
    falcon
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::inject::{FailSlowEvent, Severity, Target};
    use crate::pipeline::ParallelConfig;
    use crate::sim::{demo_spec, TrainingSim};
    use crate::simkit::MINUTE;

    fn gpu_event(start_iter_s: f64, dur_min: u64, scale: f64, gpu: usize) -> FailSlowEvent {
        FailSlowEvent {
            kind: FailSlowKind::GpuDegradation,
            target: Target::Gpu(gpu),
            start: from_secs(start_iter_s),
            duration: dur_min * MINUTE,
            scale,
        }
    }

    #[test]
    fn detects_and_diagnoses_gpu_degradation() {
        let mut sim = TrainingSim::new(demo_spec(ParallelConfig::new(1, 8, 1), 21));
        let onset = sim.ideal_iter_s * 60.0;
        sim.inject(vec![gpu_event(onset, 120, Severity::Medium.scale(), 2)]);
        let falcon = run_with_falcon(&mut sim, FalconConfig::default(), 160);
        let diag = falcon
            .actions
            .iter()
            .find_map(|a| match &a.what {
                ActionKind::Diagnosed(d) => Some(d.clone()),
                _ => None,
            })
            .expect("episode must be diagnosed");
        assert_eq!(diag.kind, FailSlowKind::GpuDegradation);
        assert!(diag.slow_gpus.iter().any(|g| g.rank == 2), "{:?}", diag.slow_gpus);
    }

    #[test]
    fn mitigation_improves_throughput_vs_ignore() {
        let mk = |mitigate: bool| {
            let mut sim = TrainingSim::new(demo_spec(ParallelConfig::new(1, 8, 1), 33));
            let onset = sim.ideal_iter_s * 40.0;
            sim.inject(vec![gpu_event(onset, 600, Severity::Severe.scale(), 0)]);
            let cfg = FalconConfig { mitigate, ..FalconConfig::default() };
            run_with_falcon(&mut sim, cfg, 300);
            sim.timeline.mean_throughput()
        };
        let with = mk(true);
        let without = mk(false);
        assert!(with > 1.1 * without, "with {with} vs without {without}");
    }

    #[test]
    fn s2_applied_for_compute_failslow() {
        let mut sim = TrainingSim::new(demo_spec(ParallelConfig::new(1, 8, 1), 35));
        let onset = sim.ideal_iter_s * 30.0;
        sim.inject(vec![gpu_event(onset, 600, Severity::Medium.scale(), 1)]);
        let falcon = run_with_falcon(&mut sim, FalconConfig::default(), 200);
        let applied = falcon.applied_strategies();
        assert!(applied.contains(&Strategy::AdjustMicrobatch), "{applied:?}");
        // Allocation actually skewed away from replica 1.
        assert!(sim.microbatch_alloc[1] < sim.microbatch_alloc[0]);
    }

    #[test]
    fn congestion_diagnosed_and_topology_adjusted() {
        // 4-node job with a congested DP path.
        let mut spec = demo_spec(ParallelConfig::new(8, 2, 2), 37);
        spec.jitter = 0.01;
        let mut sim = TrainingSim::new(spec);
        let onset = sim.ideal_iter_s * 30.0;
        sim.inject(vec![FailSlowEvent {
            kind: FailSlowKind::NetworkCongestion,
            target: Target::Link(0, 1),
            start: from_secs(onset),
            duration: 600 * MINUTE,
            scale: 0.15,
        }]);
        // Ski-rental: S3 escalates only once the accumulated impact matches
        // its ~45 s overhead, hence the longer horizon.
        let falcon = run_with_falcon(&mut sim, FalconConfig::default(), 700);
        let diag = falcon
            .actions
            .iter()
            .find_map(|a| match &a.what {
                ActionKind::Diagnosed(d) => Some(d.clone()),
                _ => None,
            })
            .expect("diagnosed");
        assert_eq!(diag.kind, FailSlowKind::NetworkCongestion);
        let applied = falcon.applied_strategies();
        assert!(applied.contains(&Strategy::AdjustTopology), "{applied:?}");
        // S2 must NOT be applied for pure congestion (Table 3).
        assert!(!applied.contains(&Strategy::AdjustMicrobatch), "{applied:?}");
    }

    #[test]
    fn healthy_job_triggers_nothing() {
        let mut sim = TrainingSim::new(demo_spec(ParallelConfig::new(2, 4, 1), 39));
        let falcon = run_with_falcon(&mut sim, FalconConfig::default(), 150);
        assert!(falcon.actions.is_empty(), "{:?}", falcon.actions);
    }

    #[test]
    fn defer_heavy_waits_for_grant_then_executes() {
        // Shared-cluster mode: a brutal fleet-wide slowdown escalates to
        // S4, but the restart must wait for the arbiter's grant.
        let mut sim = TrainingSim::new(demo_spec(ParallelConfig::new(1, 4, 1), 43));
        let onset = sim.ideal_iter_s * 20.0;
        sim.inject((0..4).map(|g| gpu_event(onset, 100_000, 0.2, g)));
        let mut cfg = FalconConfig::default();
        cfg.defer_heavy = true;
        cfg.overheads.ckpt_restart_s = 120.0;
        cfg.restart_cost = from_secs(120.0);
        let mut falcon = Falcon::new(cfg);
        for _ in 0..400 {
            let obs = sim.step();
            falcon.on_iteration(&mut sim, obs.iter, obs.duration_s());
        }
        assert_eq!(falcon.restarts(), 0, "S4 must wait for a grant");
        let kinds = falcon.applied_strategies();
        assert!(
            !kinds.contains(&Strategy::AdjustTopology) && !kinds.contains(&Strategy::CkptRestart),
            "heavy strategies executed without a grant: {kinds:?}"
        );
        let req = falcon.take_request().expect("an S3/S4 request must be pending");
        assert_eq!(req, Strategy::CkptRestart, "escalation reached S4");
        assert!(falcon.take_request().is_none(), "requests are taken once");
        falcon.execute_granted(&mut sim, req);
        assert_eq!(falcon.restarts(), 1);
        assert!(falcon.applied_strategies().contains(&Strategy::CkptRestart));
    }

    fn hang_event(start_s: f64) -> FailSlowEvent {
        FailSlowEvent {
            kind: FailSlowKind::CommHang,
            target: Target::Link(0, 1),
            start: from_secs(start_s),
            duration: 600 * MINUTE,
            scale: 1.0,
        }
    }

    #[test]
    fn hang_routes_straight_to_restart() {
        let mut sim = TrainingSim::new(demo_spec(ParallelConfig::new(2, 8, 1), 47)); // 2 nodes
        let onset = sim.ideal_iter_s * 30.0;
        sim.inject(vec![hang_event(onset)]);
        let falcon = run_with_falcon(&mut sim, FalconConfig::default(), 120);
        let d = falcon.episode_diagnoses.first().expect("hang episode classified");
        assert!(d.verdict.class.is_hang(), "{:?}", d.verdict.class);
        assert_eq!(d.verdict.culprit.label(), "link:0-1");
        assert!(falcon.restarts() >= 1, "{:?}", falcon.applied_strategies());
        // S4 fires at classification time — not after the ski-rental
        // ladder: the restart lands the very iteration the episode opens.
        let open = falcon
            .actions
            .iter()
            .find(|a| matches!(a.what, ActionKind::EpisodeOpened))
            .expect("episode opened")
            .iter;
        let applied = falcon
            .actions
            .iter()
            .find(|a| matches!(a.what, ActionKind::Applied(Strategy::CkptRestart)))
            .expect("restart applied")
            .iter;
        assert_eq!(applied, open, "hang bypasses S1–S3");
    }

    #[test]
    fn hang_restart_honors_mitigation_delay() {
        let restart_iter = |delay: usize| {
            let mut sim = TrainingSim::new(demo_spec(ParallelConfig::new(2, 8, 1), 47));
            let onset = sim.ideal_iter_s * 30.0;
            sim.inject(vec![hang_event(onset)]);
            let cfg = FalconConfig { mitigation_delay_iters: delay, ..FalconConfig::default() };
            let falcon = run_with_falcon(&mut sim, cfg, 120);
            falcon
                .actions
                .iter()
                .find_map(|a| match a.what {
                    ActionKind::Applied(Strategy::CkptRestart) => Some(a.iter),
                    _ => None,
                })
                .expect("hang restart fires")
        };
        let now = restart_iter(0);
        let later = restart_iter(6);
        assert!(later >= now + 6, "delayed {later} vs immediate {now}");
    }

    #[test]
    fn persistent_failslow_escalates_to_restart() {
        let mut sim = TrainingSim::new(demo_spec(ParallelConfig::new(1, 4, 1), 41));
        let onset = sim.ideal_iter_s * 20.0;
        // Brutal, unmitigable-by-rebalancing slowdown on ALL replicas.
        sim.inject((0..4).map(|g| gpu_event(onset, 100_000, 0.2, g)));
        let mut cfg = FalconConfig::default();
        cfg.overheads.ckpt_restart_s = 120.0; // cheap restart for the test
        cfg.restart_cost = from_secs(120.0);
        let falcon = run_with_falcon(&mut sim, cfg, 400);
        assert!(falcon.restarts() >= 1, "{:?}", falcon.applied_strategies());
    }

    fn congestion_event(start_s: f64, dur_min: u64) -> FailSlowEvent {
        FailSlowEvent {
            kind: FailSlowKind::NetworkCongestion,
            target: Target::Link(0, 1),
            start: from_secs(start_s),
            duration: dur_min * MINUTE,
            scale: 0.15,
        }
    }

    #[test]
    fn saturated_pool_reaches_s5_and_recovers_throughput() {
        // Shared-cluster dead end: every S3/S4 grant is denied (healthy-node
        // pool exhausted), so the only relief left is the S5 replan within
        // the existing allocation. The malleable tier must recover a large
        // fraction of the congestion-induced slowdown without any grant.
        let run = |mitigate: bool, replan: bool| {
            let mut spec = demo_spec(ParallelConfig::new(8, 2, 2), 51);
            spec.jitter = 0.0;
            spec.spike_p = 0.0;
            let mut sim = TrainingSim::new(spec);
            let ideal = sim.ideal_iter_s;
            sim.inject(vec![congestion_event(ideal * 20.0, 600)]);
            let mut cfg = FalconConfig::default();
            cfg.mitigate = mitigate;
            cfg.defer_heavy = true;
            cfg.replan = replan;
            cfg.overheads.adjust_topology_s = 10.0;
            cfg.overheads.replan_s = 30.0;
            cfg.overheads.ckpt_restart_s = 50_000.0;
            cfg.replan_pause = from_secs(30.0);
            let mut falcon = Falcon::new(cfg);
            for _ in 0..400 {
                let obs = sim.step();
                falcon.on_iteration(&mut sim, obs.iter, obs.duration_s());
                if let Some(req) = falcon.take_request() {
                    falcon.note_grant(&mut sim, req, false); // pool exhausted
                }
            }
            (falcon, sim.timeline.mean_throughput(), ideal)
        };
        let (off, thpt_off, ideal) = run(false, false);
        let (s5, thpt_s5, _) = run(true, true);
        assert_eq!(off.restarts(), 0);
        assert_eq!(s5.restarts(), 0, "denied S4 must not restart");
        let applied = s5.applied_strategies();
        assert!(applied.contains(&Strategy::ReplanParallelism), "{applied:?}");
        assert!(
            s5.actions.iter().any(|a| matches!(a.what, ActionKind::Denied(_, _))),
            "the dead end must be on record"
        );
        assert!(
            !s5.actions.iter().any(|a| matches!(a.what, ActionKind::Granted(_))),
            "no grants in a saturated pool"
        );
        // Recover at least 40% of the slowdown relative to the healthy rate.
        let healthy = 1.0 / ideal;
        let recovery = (thpt_s5 - thpt_off) / (healthy - thpt_off);
        assert!(recovery >= 0.40, "recovered {recovery:.2} ({thpt_off} -> {thpt_s5}, healthy {healthy})");
    }

    #[test]
    fn s5_reverts_to_nominal_layout_after_heal() {
        let mut spec = demo_spec(ParallelConfig::new(8, 2, 2), 53);
        spec.jitter = 0.0;
        spec.spike_p = 0.0;
        let mut sim = TrainingSim::new(spec);
        let ideal = sim.ideal_iter_s;
        // Finite congestion: S5 enters via the ski-rental ladder, then the
        // fault heals and the nominal layout must come back bit-identical.
        sim.inject(vec![FailSlowEvent {
            kind: FailSlowKind::NetworkCongestion,
            target: Target::Link(0, 1),
            start: from_secs(ideal * 20.0),
            duration: from_secs(ideal * 150.0),
            scale: 0.15,
        }]);
        let nominal_map = sim.grid.node_map.clone();
        let nominal_alloc = sim.microbatch_alloc.clone();
        let mut cfg = FalconConfig::default();
        cfg.replan = true;
        // S3 priced out so the grid is only ever permuted by S5; S4 priced
        // out so no restart resets the comparison.
        cfg.overheads.adjust_topology_s = 5_000.0;
        cfg.overheads.replan_s = 20.0;
        cfg.overheads.ckpt_restart_s = 500_000.0;
        cfg.replan_pause = from_secs(20.0);
        let falcon = run_with_falcon(&mut sim, cfg, 500);
        let applied = falcon.applied_strategies();
        assert!(applied.contains(&Strategy::ReplanParallelism), "{applied:?}");
        assert_eq!(falcon.restarts(), 0);
        assert_eq!(sim.grid.node_map, nominal_map, "swap not unwound after heal");
        assert_eq!(sim.microbatch_alloc, nominal_alloc, "alloc not evened after heal");
    }

    #[test]
    fn denied_streak_surfaces_in_action_log() {
        // Uniform node-wide contention: S5 has nothing to rebalance, so the
        // episode persists and escalation keeps filing requests. Each
        // consecutive denial must carry its 1-based streak count.
        let mut sim = TrainingSim::new(demo_spec(ParallelConfig::new(1, 4, 1), 57));
        let onset = sim.ideal_iter_s * 20.0;
        sim.inject(vec![FailSlowEvent {
            kind: FailSlowKind::CpuContention,
            target: Target::Node(0),
            start: from_secs(onset),
            duration: 100_000 * MINUTE,
            scale: 0.5,
        }]);
        let mut cfg = FalconConfig::default();
        cfg.defer_heavy = true;
        cfg.replan = true;
        cfg.overheads.adjust_microbatch_s = 2.0;
        cfg.overheads.adjust_topology_s = 10.0;
        cfg.overheads.replan_s = 30.0;
        cfg.overheads.ckpt_restart_s = 100.0;
        let mut falcon = Falcon::new(cfg);
        for _ in 0..300 {
            let obs = sim.step();
            falcon.on_iteration(&mut sim, obs.iter, obs.duration_s());
            if let Some(req) = falcon.take_request() {
                falcon.note_grant(&mut sim, req, false);
            }
        }
        assert_eq!(falcon.restarts(), 0, "denied S4 must not restart");
        let denied: Vec<(Strategy, usize)> = falcon
            .actions
            .iter()
            .filter_map(|a| match a.what {
                ActionKind::Denied(s, n) => Some((s, n)),
                _ => None,
            })
            .collect();
        assert!(denied.contains(&(Strategy::AdjustTopology, 1)), "{denied:?}");
        assert!(denied.contains(&(Strategy::CkptRestart, 2)), "{denied:?}");
        // The dead-end fallback fired (even if the replan found no gain,
        // the attempt is on the record).
        assert!(falcon.applied_strategies().contains(&Strategy::ReplanParallelism));
    }
}
