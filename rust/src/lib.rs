//! FALCON: Pinpointing and Mitigating Stragglers for Large-Scale
//! Hybrid-Parallel Training — full reproduction.
//!
//! Layer 3 (this crate) hosts the paper's contribution — FALCON-DETECT and
//! FALCON-MITIGATE — plus every substrate they run on: a deterministic
//! cluster/fabric/collective/pipeline simulator for at-scale experiments and
//! a live PJRT trainer that executes the AOT-compiled JAX/Pallas train step
//! for end-to-end validation. Beyond the paper, [`fleet`] runs many
//! concurrent FALCON-supervised jobs — optionally on one *shared* cluster
//! ([`cluster`]) with contended spine-leaf uplinks and cluster-wide
//! arbitration of S3/S4 mitigation resources — and [`scenario`] makes
//! every experiment a declarative spec: `falcon run <file|name>` executes
//! a fault script (or a whole fleet campaign) from one TOML document or
//! the built-in library. [`whatif`] adds counterfactual analysis on top:
//! record a run, replay it with one fault removed or one decision
//! changed, and attribute the delay (`falcon whatif <scenario>`).
//! [`diagnose`] closes the hang-vs-slow gap: scripted `hang` faults block
//! collectives at a watchdog instead of stretching them, and an op-trace
//! taxonomy pins the culprit and routes hangs straight to restart
//! (`falcon report diagnosis`, docs/DIAGNOSIS.md). [`ledger`] gives the
//! shared pool memory across jobs: a persistent per-node health ledger
//! with decaying scores, predictive quarantine, and health-aware
//! placement/admission policies (`falcon report ledger`, docs/LEDGER.md).
//! The
//! determinism conventions all of this rests on are machine-checked by
//! [`audit`] (`falcon audit`), a dependency-free static-analysis pass
//! over this crate's own source. See the top-level README.md for the
//! architecture map and quickstart.

#![forbid(unsafe_code)]

/// In-tree `anyhow` stand-in for the pjrt feature (see the module docs).
#[cfg(feature = "pjrt")]
pub mod anyhow;
pub mod audit;
pub mod cluster;
pub mod collectives;
pub mod coordinator;
pub mod detect;
pub mod diagnose;
pub mod fabric;
pub mod fleet;
pub mod inject;
pub mod ckpt;
pub mod ledger;
pub mod metrics;
pub mod mitigate;
pub mod monitor;
pub mod pipeline;
pub mod reports;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod scenario;
pub mod sim;
pub mod simkit;
#[cfg(feature = "pjrt")]
pub mod trainer;
pub mod util;
pub mod whatif;
/// In-tree `xla` PJRT stub for the pjrt feature (see the module docs).
#[cfg(feature = "pjrt")]
pub mod xla;
