//! FALCON: Pinpointing and Mitigating Stragglers for Large-Scale
//! Hybrid-Parallel Training — full reproduction.
//!
//! Layer 3 (this crate) hosts the paper's contribution — FALCON-DETECT and
//! FALCON-MITIGATE — plus every substrate they run on: a deterministic
//! cluster/fabric/collective/pipeline simulator for at-scale experiments and
//! a live PJRT trainer that executes the AOT-compiled JAX/Pallas train step
//! for end-to-end validation. See DESIGN.md for the system inventory.

pub mod collectives;
pub mod coordinator;
pub mod detect;
pub mod fabric;
pub mod fleet;
pub mod inject;
pub mod ckpt;
pub mod metrics;
pub mod mitigate;
pub mod monitor;
pub mod pipeline;
pub mod reports;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod sim;
pub mod simkit;
#[cfg(feature = "pjrt")]
pub mod trainer;
pub mod util;
