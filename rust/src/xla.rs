//! In-tree stub of the `xla` crate's PJRT surface (pjrt builds only).
//!
//! The live-trainer path (`runtime/`, `trainer/`) targets the external
//! `xla` crate (PJRT CPU client + HLO text loading), which cannot be
//! vendored into this offline tree yet. This stub mirrors exactly the
//! types and signatures those modules call so `cargo build --features
//! pjrt` type-checks end to end; every entry point that would touch real
//! XLA returns [`XlaError`] at runtime ("XLA backend not vendored").
//! `falcon train` therefore compiles everywhere and fails with a clear
//! message instead of a missing-crate build break. Replacing this module
//! with the real dependency requires no call-site changes (ROADMAP).

/// Error type standing in for `xla::Error` (call sites only format it).
#[derive(Debug)]
pub struct XlaError(pub String);

impl std::fmt::Display for XlaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

fn unavailable() -> XlaError {
    XlaError(
        "XLA backend not vendored: this build uses the in-tree pjrt stub \
         (see rust/src/xla.rs and the ROADMAP open item)"
            .to_string(),
    )
}

/// Host literal (stub: carries no data).
pub struct Literal;

impl Literal {
    /// Build a rank-1 literal (stub: shape/data are discarded).
    pub fn vec1<T>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, XlaError> {
        Err(unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, XlaError> {
        Err(unavailable())
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>, XlaError> {
        Err(unavailable())
    }
}

/// Device buffer handle.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        Err(unavailable())
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        Err(unavailable())
    }
}

/// PJRT client (stub: construction itself reports the missing backend, so
/// nothing downstream ever holds a half-working handle).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        Err(unavailable())
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        Err(unavailable())
    }
}

/// Parsed HLO module.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, XlaError> {
        Err(unavailable())
    }
}

/// XLA computation wrapper.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_surfaces_report_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        assert!(Literal::vec1(&[1.0f32]).reshape(&[1]).is_err());
        let err = format!("{:?}", unavailable());
        assert!(err.contains("not vendored"));
    }
}
