//! Interprocedural analyses over the [`super::graph::CrateGraph`]:
//! digest-reachability, RNG taint, lock-order discipline, and the
//! module-layering DAG.
//!
//! **Reachability** replaces the old hand-maintained path-exemption
//! lists: `digest-determinism` and `clock-hygiene` fire exactly in
//! functions transitively reachable from the determinism roots —
//! `digest()`, `to_json()`, the whatif record/replay entry points, and
//! `scenario::run` — plus module-scope lines of files that define at
//! least one reachable fn. Resolution is over-approximate (see
//! `graph.rs`), so the scope errs toward checking too much, never too
//! little.
//!
//! **RNG taint** proves each `Rng::new(arg)` root derives from a run
//! seed: the argument must carry a seed-bearing identifier (a token
//! containing `seed`) or a parameter that *every* resolved call site
//! proves seed-derived (greatest fixed point, so laundering a literal
//! through a helper is caught). `Rng`'s own impl is the substrate and
//! exempt; `.fork` is the blessed derivation and needs no proof.
//!
//! **Lock order** tracks `let`-bound guards of named `Mutex`es through
//! brace depth and `drop()`, records pairwise acquisition-order edges,
//! and flags inversions plus guards held across calls that (directly or
//! transitively) reach the cluster arbiter's serialization points
//! (`admit`/`arbitrate`/`file`). Limits: guards bound through `if let`
//! or held in cycles longer than two locks are not modeled.
//!
//! **Module layering** checks every non-test `crate::X` edge against
//! [`LAYERS`], the explicit allowed-dependency DAG (`util` and `simkit`
//! are substrate, allowed everywhere).

use super::graph::{tokens, CallKind, CrateGraph};
use super::lexer::SourceModel;
use super::Diagnostic;
use std::collections::{BTreeMap, BTreeSet};

/// Fn names that root the digest/replay determinism surface anywhere.
const ROOT_NAMES: &[&str] = &["digest", "to_json"];
/// Whatif entry points (module-scoped roots).
const WHATIF_ROOTS: &[&str] = &["record", "record_fleet", "replay", "replay_cold", "sweep"];
/// Arbiter serialization points: fns by these names in `cluster`.
const ARBITER_NAMES: &[&str] = &["admit", "arbitrate", "file"];

/// The allowed module-dependency DAG. `util` and `simkit` are implicit
/// everywhere; every other edge must be listed. Kept acyclic (unit
/// tested) so the layering rule enforces a true hierarchy.
pub const LAYERS: &[(&str, &[&str])] = &[
    ("anyhow", &[]),
    ("audit", &[]),
    ("ckpt", &[]),
    ("cluster", &["diagnose", "fabric", "ledger", "mitigate"]),
    ("collectives", &["fabric"]),
    (
        "coordinator",
        &["collectives", "detect", "diagnose", "inject", "mitigate", "pipeline", "sim"],
    ),
    ("detect", &["collectives", "fabric"]),
    ("diagnose", &[]),
    ("fabric", &[]),
    (
        "fleet",
        &[
            "cluster", "coordinator", "diagnose", "fabric", "inject", "ledger", "metrics",
            "mitigate", "pipeline", "sim",
        ],
    ),
    ("inject", &["fabric"]),
    ("ledger", &["diagnose"]),
    ("lib", &[]),
    (
        "main",
        &[
            "audit", "cluster", "coordinator", "detect", "fleet", "inject", "ledger",
            "mitigate", "reports", "runtime", "scenario", "trainer", "whatif",
        ],
    ),
    ("metrics", &[]),
    ("mitigate", &["inject", "pipeline", "sim"]),
    ("monitor", &["collectives"]),
    ("pipeline", &["fabric"]),
    (
        "reports",
        &[
            "ckpt", "cluster", "coordinator", "detect", "diagnose", "fabric", "fleet", "inject",
            "ledger", "metrics", "mitigate", "pipeline", "scenario", "sim", "whatif",
        ],
    ),
    ("runtime", &["anyhow", "xla"]),
    (
        "scenario",
        &["cluster", "coordinator", "fabric", "fleet", "inject", "ledger", "pipeline", "sim"],
    ),
    (
        "sim",
        &["collectives", "diagnose", "fabric", "inject", "metrics", "monitor", "pipeline"],
    ),
    ("simkit", &[]),
    ("trainer", &["anyhow", "ckpt", "collectives", "runtime", "sim", "xla"]),
    ("util", &[]),
    (
        "whatif",
        &["cluster", "coordinator", "fleet", "inject", "ledger", "mitigate", "scenario", "sim"],
    ),
    ("xla", &[]),
];

fn layer_allows(from: &str) -> Option<&'static [&'static str]> {
    LAYERS.iter().find(|(m, _)| *m == from).map(|(_, d)| *d)
}

fn layer_known(m: &str) -> bool {
    LAYERS.iter().any(|(k, _)| *k == m)
}

/// Whether the allowed-dependency graph in [`LAYERS`] is acyclic
/// (Kahn's algorithm); pinned by a unit test so an edit that introduces
/// a cycle fails fast.
pub fn layers_acyclic() -> bool {
    let mut indeg: BTreeMap<&str, usize> = LAYERS.iter().map(|(m, _)| (*m, 0)).collect();
    for (_, deps) in LAYERS {
        for d in *deps {
            if let Some(n) = indeg.get_mut(d) {
                *n += 1;
            }
        }
    }
    let mut ready: Vec<&str> = indeg
        .iter()
        .filter(|(_, n)| **n == 0)
        .map(|(m, _)| *m)
        .collect();
    let mut seen = 0usize;
    while let Some(m) = ready.pop() {
        seen += 1;
        if let Some(deps) = layer_allows(m) {
            for d in deps {
                if let Some(n) = indeg.get_mut(d) {
                    *n -= 1;
                    if *n == 0 {
                        ready.push(d);
                    }
                }
            }
        }
    }
    seen == LAYERS.len()
}

/// The flow-analysis result the scoped rules and `--graph` consume.
#[derive(Debug, Default)]
pub struct FlowInfo {
    /// Root fn indices (by name/module match, non-test).
    pub roots: BTreeSet<usize>,
    /// Fns transitively reachable from the roots.
    pub reachable: BTreeSet<usize>,
    /// Files defining at least one reachable fn (module-scope lines of
    /// these files are in digest/clock scope).
    pub reachable_files: BTreeSet<String>,
    /// Pairwise lock acquisition-order edges: `(first, second) -> site`.
    pub order_edges: BTreeMap<(String, String), (String, usize)>,
}

/// Run every interprocedural analysis. Returns the flow info plus raw
/// diagnostics (suppression happens in the engine).
pub fn analyze(graph: &CrateGraph, files: &[(String, SourceModel)]) -> (FlowInfo, Vec<Diagnostic>) {
    let mut flow = FlowInfo::default();
    let mut diags = Vec::new();
    reachability(graph, &mut flow);
    rng_taint(graph, &mut diags);
    lock_order(graph, files, &mut flow, &mut diags);
    layering(graph, &mut diags);
    (flow, diags)
}

fn reachability(graph: &CrateGraph, flow: &mut FlowInfo) {
    for (id, f) in graph.fns.iter().enumerate() {
        if f.in_test {
            continue;
        }
        let is_root = ROOT_NAMES.contains(&f.name.as_str())
            || (f.module == "whatif" && WHATIF_ROOTS.contains(&f.name.as_str()))
            || (f.module == "scenario" && f.name == "run");
        if is_root {
            flow.roots.insert(id);
        }
    }
    let mut out_edges: BTreeMap<usize, BTreeSet<usize>> = BTreeMap::new();
    for c in &graph.calls {
        if let Some(caller) = c.caller {
            for &r in &c.resolved {
                out_edges.entry(caller).or_default().insert(r);
            }
        }
    }
    flow.reachable = flow.roots.clone();
    let mut work: Vec<usize> = flow.roots.iter().copied().collect();
    while let Some(f) = work.pop() {
        if let Some(tos) = out_edges.get(&f) {
            for &t in tos {
                if flow.reachable.insert(t) {
                    work.push(t);
                }
            }
        }
    }
    for &id in &flow.reachable {
        if let Some(f) = graph.fns.get(id) {
            flow.reachable_files.insert(f.path.clone());
        }
    }
}

fn seedlike(tok: &str) -> bool {
    tok.to_ascii_lowercase().contains("seed")
}

fn rng_taint(graph: &CrateGraph, diags: &mut Vec<Diagnostic>) {
    // callers_of[f] = call sites resolving to f.
    let mut callers_of: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
    for (ci, c) in graph.calls.iter().enumerate() {
        for &r in &c.resolved {
            callers_of.entry(r).or_default().push(ci);
        }
    }
    let param_idx: Vec<BTreeMap<&str, usize>> = graph
        .fns
        .iter()
        .map(|f| {
            f.params
                .iter()
                .enumerate()
                .map(|(i, p)| (p.as_str(), i))
                .collect()
        })
        .collect();
    // Greatest fixed point: a param is seed-tainted unless some call site
    // fails to prove it. Fns with no known callers start untainted.
    let mut tainted: BTreeMap<(usize, usize), bool> = BTreeMap::new();
    for (fid, f) in graph.fns.iter().enumerate() {
        let has_callers = callers_of.get(&fid).is_some_and(|v| !v.is_empty());
        for i in 0..f.params.len() {
            tainted.insert((fid, i), has_callers);
        }
    }
    let arg_proven = |c: &super::graph::CallSite,
                      ai: usize,
                      tainted: &BTreeMap<(usize, usize), bool>|
     -> bool {
        let Some(atoks) = c.args.get(ai) else {
            return false;
        };
        if atoks.iter().any(|t| seedlike(t)) {
            return true;
        }
        if let Some(caller) = c.caller {
            if let Some(pi) = param_idx.get(caller) {
                return atoks.iter().any(|t| {
                    pi.get(t.as_str())
                        .is_some_and(|&i| tainted.get(&(caller, i)).copied().unwrap_or(false))
                });
            }
        }
        false
    };
    let mut changed = true;
    while changed {
        changed = false;
        for (fid, f) in graph.fns.iter().enumerate() {
            for i in 0..f.params.len() {
                if !tainted.get(&(fid, i)).copied().unwrap_or(false) {
                    continue;
                }
                let ok = callers_of.get(&fid).is_some_and(|sites| {
                    sites.iter().all(|&ci| {
                        let c = &graph.calls[ci];
                        // `Type::method(self_expr, ...)` shifts args by 1.
                        let ai = if f.is_method
                            && c.kind == CallKind::TypeQualified
                            && c.args.len() == f.params.len() + 1
                        {
                            i + 1
                        } else {
                            i
                        };
                        arg_proven(c, ai, &tainted)
                    })
                });
                if !ok {
                    tainted.insert((fid, i), false);
                    changed = true;
                }
            }
        }
    }
    for c in &graph.calls {
        let is_rng_new = c.kind == CallKind::TypeQualified
            && c.qualifier.as_deref() == Some("Rng")
            && c.callee == "new";
        if !is_rng_new || c.impl_type.as_deref() == Some("Rng") {
            continue;
        }
        if arg_proven(c, 0, &tainted) {
            continue;
        }
        diags.push(Diagnostic {
            rule: "rng-taint",
            path: c.path.clone(),
            line: c.line,
            msg: "RNG root not provably seed-derived: no seed-bearing token in the \
                  argument and no call site proves the parameter seed-derived; derive \
                  via .fork(tag) or thread the run's root seed through"
                .to_string(),
            snippet: String::new(),
        });
    }
}

fn lock_order(
    graph: &CrateGraph,
    files: &[(String, SourceModel)],
    flow: &mut FlowInfo,
    diags: &mut Vec<Diagnostic>,
) {
    // Fns that can (transitively) reach an arbiter serialization point.
    let arbiter_fns: BTreeSet<usize> = graph
        .fns
        .iter()
        .enumerate()
        .filter(|(_, f)| {
            !f.in_test && f.module == "cluster" && ARBITER_NAMES.contains(&f.name.as_str())
        })
        .map(|(id, _)| id)
        .collect();
    let mut rev: BTreeMap<usize, BTreeSet<usize>> = BTreeMap::new();
    for c in &graph.calls {
        if let Some(caller) = c.caller {
            for &r in &c.resolved {
                rev.entry(r).or_default().insert(caller);
            }
        }
    }
    let mut reaches_arbiter = arbiter_fns.clone();
    let mut work: Vec<usize> = arbiter_fns.iter().copied().collect();
    while let Some(f) = work.pop() {
        if let Some(parents) = rev.get(&f) {
            for &p in parents {
                if reaches_arbiter.insert(p) {
                    work.push(p);
                }
            }
        }
    }

    // Index calls by (path, line) for the guard walk.
    let mut calls_at: BTreeMap<(&str, usize), Vec<&super::graph::CallSite>> = BTreeMap::new();
    for c in &graph.calls {
        calls_at.entry((c.path.as_str(), c.line)).or_default().push(c);
    }

    for (path, model) in files {
        let module = super::graph::top_module(path);
        let mut depth = 0usize;
        // Live guards: (var name, lock id, depth at binding).
        let mut guards: Vec<(String, String, usize)> = Vec::new();
        for (li, info) in model.lines.iter().enumerate() {
            let line = li + 1;
            if info.in_test {
                for ch in info.code.chars() {
                    match ch {
                        '{' => depth += 1,
                        '}' => {
                            guards.retain(|g| g.2 < depth);
                            depth = depth.saturating_sub(1);
                        }
                        _ => {}
                    }
                }
                continue;
            }
            // Acquisitions on this line.
            let mut acquired: Vec<(Option<String>, String)> = Vec::new();
            let mut from = 0usize;
            while let Some(off) = info.code[from..].find(".lock(") {
                let p = from + off;
                if let Some(name) = receiver_base(&info.code, p) {
                    let lockid = format!("{module}::{name}");
                    for g in &guards {
                        if g.1 != lockid {
                            let key = (g.1.clone(), lockid.clone());
                            flow.order_edges
                                .entry(key)
                                .or_insert_with(|| (path.clone(), line));
                        }
                    }
                    let stripped = info.code.trim_start();
                    let var = stripped.strip_prefix("let ").and_then(|rest| {
                        let toks = tokens(rest);
                        match toks.first() {
                            Some((_, t)) if t == "mut" => toks.get(1).map(|(_, t)| t.clone()),
                            Some((_, t)) => Some(t.clone()),
                            None => None,
                        }
                    });
                    acquired.push((var, lockid));
                }
                from = p + 6;
            }
            // `drop(var)` releases early.
            for (pos, word) in tokens(&info.code) {
                if word == "drop"
                    && info.code[pos + 4..].starts_with('(')
                {
                    let rest = &info.code[pos + 5..];
                    let inner = match rest.find(')') {
                        Some(close) => &rest[..close],
                        None => rest,
                    };
                    let dropped: BTreeSet<String> =
                        tokens(inner).into_iter().map(|(_, t)| t).collect();
                    guards.retain(|g| !dropped.contains(&g.0));
                }
            }
            // Calls under a live guard that reach an arbiter point.
            if !guards.is_empty() {
                if let Some(cs) = calls_at.get(&(path.as_str(), line)) {
                    for c in cs {
                        let direct = ARBITER_NAMES.contains(&c.callee.as_str())
                            && (c.resolved.iter().any(|r| arbiter_fns.contains(r))
                                || (c.kind == CallKind::Method && c.resolved.is_empty()));
                        let transitive =
                            c.resolved.iter().any(|r| reaches_arbiter.contains(r));
                        if direct || transitive {
                            let held: Vec<&str> =
                                guards.iter().map(|g| g.1.as_str()).collect();
                            diags.push(Diagnostic {
                                rule: "lock-order",
                                path: path.clone(),
                                line,
                                msg: format!(
                                    "guard on {} held across a call into the arbiter \
                                     serialization path (`{}`): file grants outside the lock",
                                    held.join(", "),
                                    c.callee
                                ),
                                snippet: info.code.trim().to_string(),
                            });
                        }
                    }
                }
            }
            for (var, lockid) in acquired {
                if let Some(var) = var {
                    guards.push((var, lockid, depth));
                }
            }
            for ch in info.code.chars() {
                match ch {
                    '{' => depth += 1,
                    '}' => {
                        guards.retain(|g| g.2 < depth);
                        depth = depth.saturating_sub(1);
                    }
                    _ => {}
                }
            }
        }
    }

    // Inversions: both (a, b) and (b, a) recorded.
    let edges: Vec<((String, String), (String, usize))> = flow
        .order_edges
        .iter()
        .map(|(k, v)| (k.clone(), v.clone()))
        .collect();
    for ((a, b), (path, line)) in &edges {
        if let Some((opath, oline)) = flow.order_edges.get(&(b.clone(), a.clone())) {
            diags.push(Diagnostic {
                rule: "lock-order",
                path: path.clone(),
                line: *line,
                msg: format!(
                    "lock-order inversion: {a} is held while acquiring {b} here, but \
                     {b} is held while acquiring {a} at {opath}:{oline} — deadlock risk"
                ),
                snippet: String::new(),
            });
        }
    }
}

/// Receiver base identifier of a `.lock(` at byte offset `dot`: skip
/// back over one `[...]` index, then take the identifier.
fn receiver_base(code: &str, dot: usize) -> Option<String> {
    let cs: Vec<char> = code[..dot].chars().collect();
    let mut k = cs.len();
    while k > 0 && (cs[k - 1] == ' ' || cs[k - 1] == '\t') {
        k -= 1;
    }
    if k > 0 && cs[k - 1] == ']' {
        let mut d = 1usize;
        k -= 1;
        while k > 0 && d > 0 {
            k -= 1;
            match cs[k] {
                ']' => d += 1,
                '[' => d -= 1,
                _ => {}
            }
        }
    }
    let end = k;
    while k > 0 && (cs[k - 1].is_ascii_alphanumeric() || cs[k - 1] == '_') {
        k -= 1;
    }
    if end > k {
        Some(cs[k..end].iter().collect())
    } else {
        None
    }
}

fn layering(graph: &CrateGraph, diags: &mut Vec<Diagnostic>) {
    for ((from, to), (path, line)) in &graph.mod_edges {
        if !layer_known(from) || !layer_known(to) {
            continue;
        }
        if to == "util" || to == "simkit" {
            continue;
        }
        let allowed = layer_allows(from).is_some_and(|deps| deps.contains(&to.as_str()));
        if !allowed {
            diags.push(Diagnostic {
                rule: "module-layering",
                path: path.clone(),
                line: *line,
                msg: format!(
                    "module `{from}` may not depend on `{to}` (allowed: {})",
                    layer_allows(from)
                        .map(|d| d.join(", "))
                        .unwrap_or_default()
                ),
                snippet: String::new(),
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::audit::graph;

    fn analyze_src(files: &[(&str, &str)]) -> (FlowInfo, Vec<Diagnostic>) {
        let parsed: Vec<(String, SourceModel)> = files
            .iter()
            .map(|(p, s)| (p.to_string(), SourceModel::parse(s)))
            .collect();
        let g = graph::build(&parsed);
        analyze(&g, &parsed)
    }

    #[test]
    fn layers_dag_is_acyclic() {
        assert!(layers_acyclic());
    }

    #[test]
    fn layers_cover_every_crate_module() {
        // Every module lib.rs declares (plus the two crate roots) must
        // have a layering entry, or the DAG silently stops constraining
        // new code.
        for m in [
            "anyhow", "audit", "ckpt", "cluster", "collectives", "coordinator", "detect",
            "diagnose", "fabric", "fleet", "inject", "ledger", "lib", "main", "metrics",
            "mitigate",
            "monitor", "pipeline", "reports", "runtime", "scenario", "sim", "simkit", "trainer",
            "util", "whatif", "xla",
        ] {
            assert!(layer_known(m), "module {m} missing from LAYERS");
        }
    }

    #[test]
    fn reachability_follows_calls_from_roots() {
        let (flow, _) = analyze_src(&[(
            "m/a.rs",
            "pub fn to_json() -> u64 {\n    helper()\n}\nfn helper() -> u64 {\n    1\n}\n\
             fn unrelated() -> u64 {\n    2\n}\n",
        )]);
        assert_eq!(flow.roots.len(), 1);
        assert_eq!(flow.reachable.len(), 2, "root + helper, not unrelated");
    }

    #[test]
    fn rng_taint_flags_laundered_literal() {
        let (_, diags) = analyze_src(&[(
            "sim/a.rs",
            "fn helper(tag: u64) -> u64 {\n    let r = Rng::new(tag);\n    tag\n}\n\
             pub fn go(seed: u64) -> u64 {\n    helper(41) + Rng::new(seed).fork(1)\n}\n",
        )]);
        let taints: Vec<usize> = diags
            .iter()
            .filter(|d| d.rule == "rng-taint")
            .map(|d| d.line)
            .collect();
        assert_eq!(taints, vec![2], "literal laundered through helper param");
    }

    #[test]
    fn lock_inversion_is_flagged_both_ways() {
        let src = "struct P {\n    a: std::sync::Mutex<u32>,\n    b: std::sync::Mutex<u32>,\n}\n\
                   impl P {\n    fn ab(&self) {\n        let ga = self.a.lock();\n        \
                   let gb = self.b.lock();\n    }\n    fn ba(&self) {\n        \
                   let gb = self.b.lock();\n        let ga = self.a.lock();\n    }\n}\n";
        let (flow, diags) = analyze_src(&[("fleet/l.rs", src)]);
        assert_eq!(flow.order_edges.len(), 2);
        assert_eq!(diags.iter().filter(|d| d.rule == "lock-order").count(), 2);
    }

    #[test]
    fn layering_violation_is_flagged() {
        let (_, diags) =
            analyze_src(&[("diagnose/bad.rs", "use crate::whatif::Attribution;\n")]);
        assert_eq!(diags.iter().filter(|d| d.rule == "module-layering").count(), 1);
    }
}
