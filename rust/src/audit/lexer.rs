//! AST-lite source model for the audit scanner.
//!
//! Rules never see raw source. [`SourceModel::parse`] runs two passes:
//!
//! 1. **Blanking** — a char-level state machine replaces comments, string
//!    literals (plain, byte, raw, any `#` depth), and char literals with
//!    spaces, preserving line structure, so `"HashMap"` inside a string or
//!    a doc comment can never trip a rule. Line comments are captured on
//!    the way out because they may carry `audit:allow` directives.
//! 2. **Structure** — a brace-depth walk over the blanked text marks
//!    `#[cfg(test)]` / `#[test]` regions (rules skip test code), and
//!    tracks the innermost enclosing `fn` name so rules can bless specific
//!    functions (e.g. the `Cluster` setters).
//!
//! The output is one [`LineInfo`] per source line: blanked code, test
//! flag, enclosing function, and any allow directives attached to it.

/// One parsed `// audit:allow(rule-id): reason` directive.
#[derive(Clone, Debug)]
pub struct Allow {
    /// Rule id inside the parentheses (not yet validated against the
    /// registry — the `allow-grammar` meta-rule does that).
    pub rule: String,
    /// Whether a non-empty reason followed the closing paren.
    pub has_reason: bool,
    /// Line the directive comment itself sits on (1-based), for
    /// diagnostics about the directive.
    pub at_line: usize,
}

/// Everything a rule may know about one source line.
#[derive(Clone, Debug, Default)]
pub struct LineInfo {
    /// The line with comments/strings/chars blanked to spaces.
    pub code: String,
    /// Inside a `#[cfg(test)]` module or `#[test]` function.
    pub in_test: bool,
    /// Innermost enclosing function name, if any.
    pub fn_name: Option<String>,
    /// Allow directives that apply to this line (trailing comments attach
    /// to their own line; standalone comment lines attach to the next
    /// code line).
    pub allows: Vec<Allow>,
}

/// Parsed model of one `.rs` file.
#[derive(Debug, Default)]
pub struct SourceModel {
    pub lines: Vec<LineInfo>,
}

impl SourceModel {
    pub fn parse(text: &str) -> SourceModel {
        let (blanked, comments) = blank(text);
        let mut lines = structure(&blanked);
        attach_allows(&mut lines, &comments);
        SourceModel { lines }
    }
}

fn is_ident(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Pass 1: blank comments, strings, and char literals; collect line
/// comments as `(0-based line, text)`.
fn blank(text: &str) -> (String, Vec<(usize, String)>) {
    let chars: Vec<char> = text.chars().collect();
    let n = chars.len();
    let mut out = String::with_capacity(text.len());
    let mut comments: Vec<(usize, String)> = Vec::new();
    let mut line = 0usize;
    let mut i = 0usize;
    while i < n {
        let c = chars[i];
        // Line comment: capture, blank to end of line.
        if c == '/' && i + 1 < n && chars[i + 1] == '/' {
            let start = i;
            while i < n && chars[i] != '\n' {
                out.push(' ');
                i += 1;
            }
            comments.push((line, chars[start..i].iter().collect()));
            continue;
        }
        // Block comment: blank, honoring nesting.
        if c == '/' && i + 1 < n && chars[i + 1] == '*' {
            let mut depth = 1usize;
            out.push_str("  ");
            i += 2;
            while i < n && depth > 0 {
                if chars[i] == '/' && i + 1 < n && chars[i + 1] == '*' {
                    depth += 1;
                    out.push_str("  ");
                    i += 2;
                } else if chars[i] == '*' && i + 1 < n && chars[i + 1] == '/' {
                    depth -= 1;
                    out.push_str("  ");
                    i += 2;
                } else {
                    if chars[i] == '\n' {
                        out.push('\n');
                        line += 1;
                    } else {
                        out.push(' ');
                    }
                    i += 1;
                }
            }
            continue;
        }
        // Raw / byte-raw string: r"..", r#".."#, br".." — no escapes.
        if (c == 'r' || c == 'b') && !(i > 0 && is_ident(chars[i - 1])) {
            let mut j = i + 1;
            if c == 'b' && j < n && chars[j] == 'r' {
                j += 1;
            }
            let raw = c == 'r' || j > i + 1;
            let mut hashes = 0usize;
            while raw && j < n && chars[j] == '#' {
                hashes += 1;
                j += 1;
            }
            if raw && j < n && chars[j] == '"' {
                for _ in i..=j {
                    out.push(' ');
                }
                i = j + 1;
                'raw: while i < n {
                    if chars[i] == '"' {
                        let mut k = 0usize;
                        while k < hashes && i + 1 + k < n && chars[i + 1 + k] == '#' {
                            k += 1;
                        }
                        if k == hashes {
                            for _ in 0..=hashes {
                                out.push(' ');
                            }
                            i += 1 + hashes;
                            break 'raw;
                        }
                    }
                    if chars[i] == '\n' {
                        out.push('\n');
                        line += 1;
                    } else {
                        out.push(' ');
                    }
                    i += 1;
                }
                continue;
            }
            // `b"..."` (non-raw byte string) falls through to the string
            // arm below via its `"`; a lone identifier starting with r/b
            // falls through to the default arm.
        }
        // Plain string literal (escapes honored).
        if c == '"' {
            out.push(' ');
            i += 1;
            while i < n {
                if chars[i] == '\\' {
                    out.push(' ');
                    i += 1;
                    if i < n {
                        if chars[i] == '\n' {
                            out.push('\n');
                            line += 1;
                        } else {
                            out.push(' ');
                        }
                        i += 1;
                    }
                } else if chars[i] == '"' {
                    out.push(' ');
                    i += 1;
                    break;
                } else {
                    if chars[i] == '\n' {
                        out.push('\n');
                        line += 1;
                    } else {
                        out.push(' ');
                    }
                    i += 1;
                }
            }
            continue;
        }
        // Char literal vs lifetime: 'x' / '\n' are literals; 'a in
        // `<'a>` is a lifetime (left alone).
        if c == '\'' {
            let lit = (i + 1 < n && chars[i + 1] == '\\')
                || (i + 2 < n && chars[i + 2] == '\'' && chars[i + 1] != '\'');
            if lit {
                out.push(' ');
                i += 1;
                while i < n {
                    if chars[i] == '\\' {
                        out.push(' ');
                        i += 1;
                        if i < n {
                            out.push(' ');
                            i += 1;
                        }
                    } else if chars[i] == '\'' {
                        out.push(' ');
                        i += 1;
                        break;
                    } else {
                        out.push(' ');
                        i += 1;
                    }
                }
                continue;
            }
        }
        if c == '\n' {
            line += 1;
        }
        out.push(c);
        i += 1;
    }
    (out, comments)
}

/// Pass 2: walk the blanked text line by line, tracking brace depth,
/// test regions, and the enclosing-function stack.
fn structure(blanked: &str) -> Vec<LineInfo> {
    let mut lines: Vec<LineInfo> = Vec::new();
    let mut depth = 0usize;
    // Depth at which the active `#[cfg(test)]` / `#[test]` region's brace
    // opened; the region ends when that brace closes.
    let mut test_until: Option<usize> = None;
    // A test attribute was seen; latches onto the next `{` (cleared by a
    // `;` first — bodyless items like `#[cfg(test)] use x;`).
    let mut pending_test = false;
    // (fn name, depth its body opened at).
    let mut fn_stack: Vec<(String, usize)> = Vec::new();
    let mut pending_fn: Option<String> = None;

    for raw in blanked.split('\n') {
        if raw.contains("#[cfg(test)]") || raw.contains("#[test]") || raw.contains("#[cfg(all(test")
        {
            pending_test = true;
        }
        let in_test = test_until.is_some() || pending_test;
        let fn_at_start = fn_stack.last().map(|(name, _)| name.clone());

        let cs: Vec<char> = raw.chars().collect();
        let mut k = 0usize;
        let mut after_fn_kw = false;
        while k < cs.len() {
            let ch = cs[k];
            if is_ident(ch) && !ch.is_ascii_digit() {
                let start = k;
                while k < cs.len() && is_ident(cs[k]) {
                    k += 1;
                }
                let word: String = cs[start..k].iter().collect();
                if word == "fn" {
                    after_fn_kw = true;
                } else if after_fn_kw {
                    pending_fn = Some(word);
                    after_fn_kw = false;
                }
                continue;
            }
            match ch {
                '{' => {
                    depth += 1;
                    if pending_test && test_until.is_none() {
                        test_until = Some(depth);
                    }
                    pending_test = false;
                    if let Some(name) = pending_fn.take() {
                        fn_stack.push((name, depth));
                    }
                }
                '}' => {
                    if test_until == Some(depth) {
                        test_until = None;
                    }
                    while fn_stack.last().is_some_and(|&(_, d)| d == depth) {
                        fn_stack.pop();
                    }
                    depth = depth.saturating_sub(1);
                }
                ';' => {
                    // A `;` before any `{` means the pending item was
                    // bodyless (trait method, cfg'd use/const).
                    if pending_test && test_until.is_none() {
                        pending_test = false;
                    }
                    if pending_fn.is_some() {
                        pending_fn = None;
                    }
                }
                _ => {}
            }
            k += 1;
        }

        let fn_at_end = fn_stack.last().map(|(name, _)| name.clone());
        lines.push(LineInfo {
            code: raw.to_string(),
            in_test,
            // A fn signature line belongs to the fn it opens; a closing
            // `}` line still belongs to the fn it closes.
            fn_name: fn_at_start.or(fn_at_end),
            allows: Vec::new(),
        });
    }
    lines
}

/// Parse allow directives out of captured line comments and attach each
/// to the line it governs.
fn attach_allows(lines: &mut [LineInfo], comments: &[(usize, String)]) {
    for &(line0, ref text) in comments {
        let Some(allow) = parse_allow(text, line0 + 1) else {
            continue;
        };
        // Trailing comment: the line has code of its own. Standalone
        // comment line: attach to the next non-blank code line.
        let mut target = line0;
        if lines[line0].code.trim().is_empty() {
            let mut j = line0 + 1;
            while j < lines.len() && lines[j].code.trim().is_empty() {
                j += 1;
            }
            if j < lines.len() {
                target = j;
            }
        }
        lines[target].allows.push(allow);
    }
}

/// Parse one comment's text as an allow directive. The directive must be
/// the comment's first payload — `audit:allow` right after the `//`(`/`,
/// `!`) markers — so prose that merely *mentions* the grammar (like this
/// sentence) is not a directive. Returns `None` for non-directives; a
/// directive with a bad tail comes back with an empty rule id so the
/// `allow-grammar` rule can report it.
fn parse_allow(comment: &str, at_line: usize) -> Option<Allow> {
    // Strip exactly one comment marker (`//`, `///`, `//!`), not any
    // nested one — a doc example quoting a directive stays prose.
    let payload = comment.trim_start().trim_start_matches('/');
    let payload = payload.strip_prefix('!').unwrap_or(payload).trim_start();
    let rest = payload.strip_prefix("audit:allow")?;
    let rest = rest.trim_start();
    let Some(rest) = rest.strip_prefix('(') else {
        return Some(Allow { rule: String::new(), has_reason: false, at_line });
    };
    let Some(close) = rest.find(')') else {
        return Some(Allow { rule: String::new(), has_reason: false, at_line });
    };
    let rule = rest[..close].trim().to_string();
    let tail = rest[close + 1..].trim_start();
    let has_reason = match tail.strip_prefix(':') {
        Some(reason) => !reason.trim().is_empty(),
        None => false,
    };
    Some(Allow { rule, has_reason, at_line })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strings_and_comments_are_blanked() {
        let m = SourceModel::parse("let x = \"HashMap\"; // HashMap too\nlet y = 1;\n");
        assert!(!m.lines[0].code.contains("HashMap"));
        assert!(m.lines[1].code.contains("let y"));
    }

    #[test]
    fn raw_strings_and_char_literals() {
        let src = "let r = r#\"Instant \"quoted\" inside\"#;\nlet c = '\\n';\n\
                   let l: &'static str = s;\n";
        let m = SourceModel::parse(src);
        assert!(!m.lines[0].code.contains("Instant"));
        assert!(m.lines[1].code.contains("let c"));
        assert!(m.lines[2].code.contains("static"), "lifetime must survive");
    }

    #[test]
    fn nested_block_comments() {
        let m = SourceModel::parse("/* outer /* inner */ still comment */ let z = 2;\n");
        assert!(!m.lines[0].code.contains("comment"));
        assert!(m.lines[0].code.contains("let z"));
    }

    #[test]
    fn test_regions_are_marked() {
        let src = "fn lib() {}\n#[cfg(test)]\nmod tests {\n    fn t() {}\n}\nfn after() {}\n";
        let m = SourceModel::parse(src);
        assert!(!m.lines[0].in_test);
        assert!(m.lines[3].in_test);
        assert!(!m.lines[5].in_test);
    }

    #[test]
    fn bodyless_cfg_test_item_does_not_latch() {
        let src = "#[cfg(test)]\nuse foo::Bar;\nfn real() {\n    work();\n}\n";
        let m = SourceModel::parse(src);
        assert!(m.lines[1].in_test, "the cfg'd use itself is test-only");
        assert!(!m.lines[3].in_test, "the next fn must not inherit it");
    }

    #[test]
    fn enclosing_fn_names() {
        let src = "impl Foo {\n    pub fn set_x(&mut self) {\n        self.x = 1;\n    }\n}\n";
        let m = SourceModel::parse(src);
        assert_eq!(m.lines[2].fn_name.as_deref(), Some("set_x"));
        assert_eq!(m.lines[1].fn_name.as_deref(), Some("set_x"));
    }

    #[test]
    fn allow_directives_attach() {
        let src = "// audit:allow(clock-hygiene): measured overhead\nlet t = now();\n\
                   let u = later(); // audit:allow(rng-stream): root stream\n";
        let m = SourceModel::parse(src);
        assert_eq!(m.lines[1].allows.len(), 1);
        assert_eq!(m.lines[1].allows[0].rule, "clock-hygiene");
        assert!(m.lines[1].allows[0].has_reason);
        assert_eq!(m.lines[2].allows[0].rule, "rng-stream");
    }

    #[test]
    fn malformed_allow_is_surfaced_not_dropped() {
        let src = "// audit:allow(panic-budget)\nfoo();\n";
        let m = SourceModel::parse(src);
        assert_eq!(m.lines[1].allows.len(), 1);
        assert!(!m.lines[1].allows[0].has_reason);
    }
}
