//! The audit rule registry.
//!
//! Each rule is a pure function over one [`LineInfo`] (plus the file's
//! repo-relative path); the engine in `mod.rs` handles allow suppression
//! and the panic budget. Rules are scoped by *exclusion* — a file is in
//! scope unless its path is listed — so fixture files under arbitrary
//! paths still fire.

use super::lexer::{LineInfo, SourceModel};
use super::{Diagnostic, RULES};

/// `fabric::Cluster` health/scale fields whose writes must route through
/// the generation-bumping setters (the PR 4 cache-coherence contract).
const CLUSTER_FIELDS: &[&str] = &[
    "compute_scale",
    "temp_c",
    "cpu_satisfaction",
    "high_cpu_jobs",
    "bandwidth_scale",
    "external_scale",
];

/// Methods that mutate a map/set in place (for `pair_scale`/`hung_paths`).
const MAP_MUTATORS: &[&str] = &["insert", "remove", "clear", "entry", "get_mut", "retain"];

/// The only functions allowed to write `Cluster` fields directly: the
/// setters themselves, all in `fabric/mod.rs`.
const BLESSED_SETTERS: &[&str] = &[
    "set_gpu_health",
    "set_cpu_health",
    "set_uplink_scale",
    "set_pair_scale",
    "set_path_hang",
    "set_external_scale",
    "heal_all",
];

/// Paths exempt from `digest-determinism` (no digest/replay-reachable
/// state): the substrate, this scanner, the CLI shell, and the
/// pjrt-gated live path.
const DIGEST_EXEMPT: &[&str] =
    &["util/", "audit/", "trainer/", "runtime/", "main.rs", "xla.rs", "anyhow.rs"];

/// Paths allowed to construct RNG roots freely: the RNG substrate and
/// everything outside the deterministic sim/replay surface.
const RNG_EXEMPT: &[&str] =
    &["util/", "audit/", "reports/", "trainer/", "runtime/", "main.rs", "xla.rs", "anyhow.rs"];

/// Ambient / ad-hoc RNG constructors that break replayability anywhere.
const RNG_AMBIENT: &[&str] = &["thread_rng", "from_entropy", "seed_from_u64"];

fn exempt(path: &str, list: &[&str]) -> bool {
    list.iter().any(|p| {
        if p.ends_with('/') {
            path.starts_with(p)
        } else {
            path == *p
        }
    })
}

/// Whole-word identifier tokens of a blanked line, with char positions.
fn tokens(code: &str) -> Vec<(usize, String)> {
    let cs: Vec<char> = code.chars().collect();
    let mut out = Vec::new();
    let mut k = 0usize;
    while k < cs.len() {
        let c = cs[k];
        if (c.is_ascii_alphabetic() || c == '_') && !(k > 0 && cs[k - 1].is_ascii_digit()) {
            let start = k;
            while k < cs.len() && (cs[k].is_ascii_alphanumeric() || cs[k] == '_') {
                k += 1;
            }
            out.push((start, cs[start..k].iter().collect()));
        } else {
            k += 1;
        }
    }
    out
}

fn prev_nonspace(cs: &[char], mut k: usize) -> Option<char> {
    while k > 0 {
        k -= 1;
        if cs[k] != ' ' && cs[k] != '\t' {
            return Some(cs[k]);
        }
    }
    None
}

fn next_nonspace(cs: &[char], mut k: usize) -> Option<(usize, char)> {
    while k < cs.len() {
        if cs[k] != ' ' && cs[k] != '\t' {
            return Some((k, cs[k]));
        }
        k += 1;
    }
    None
}

/// After a field token (and optional `[index]`), is the next operator a
/// plain or compound assignment?
fn is_assignment(cs: &[char], mut k: usize) -> bool {
    // Skip one bracketed index expression, line-locally.
    if let Some((p, '[')) = next_nonspace(cs, k) {
        let mut depth = 1usize;
        k = p + 1;
        while k < cs.len() && depth > 0 {
            match cs[k] {
                '[' => depth += 1,
                ']' => depth -= 1,
                _ => {}
            }
            k += 1;
        }
        if depth > 0 {
            return false;
        }
    }
    match next_nonspace(cs, k) {
        Some((p, '=')) => cs.get(p + 1) != Some(&'='),
        Some((p, c)) if matches!(c, '+' | '-' | '*' | '/') => cs.get(p + 1) == Some(&'='),
        _ => false,
    }
}

fn diag(rule: &'static str, path: &str, line: usize, msg: String, code: &str) -> Diagnostic {
    Diagnostic {
        rule,
        path: path.to_string(),
        line,
        msg,
        snippet: code.trim().to_string(),
    }
}

/// Run every rule over one parsed file. Allow suppression happens in the
/// engine; this returns raw findings (including `allow-grammar` ones).
pub fn check(path: &str, model: &SourceModel) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    for (idx, info) in model.lines.iter().enumerate() {
        let line = idx + 1;
        check_allow_grammar(path, info, &mut out);
        if info.in_test {
            continue;
        }
        let toks = tokens(&info.code);
        if toks.is_empty() {
            continue;
        }
        let cs: Vec<char> = info.code.chars().collect();
        check_generation(path, line, info, &toks, &cs, &mut out);
        check_digest(path, line, info, &toks, &mut out);
        check_clock(path, line, info, &toks, &mut out);
        check_rng(path, line, info, &toks, &cs, &mut out);
        check_panic(path, line, info, &toks, &cs, &mut out);
    }
    out
}

fn check_allow_grammar(path: &str, info: &LineInfo, out: &mut Vec<Diagnostic>) {
    for allow in &info.allows {
        let known = RULES.iter().any(|r| r.id == allow.rule) && allow.rule != "allow-grammar";
        if !known {
            out.push(diag(
                "allow-grammar",
                path,
                allow.at_line,
                format!("allow names unknown rule `{}`", allow.rule),
                &info.code,
            ));
        } else if !allow.has_reason {
            out.push(diag(
                "allow-grammar",
                path,
                allow.at_line,
                format!("allow({}) missing a `: reason`", allow.rule),
                &info.code,
            ));
        }
    }
}

fn check_generation(
    path: &str,
    line: usize,
    info: &LineInfo,
    toks: &[(usize, String)],
    cs: &[char],
    out: &mut Vec<Diagnostic>,
) {
    let blessed = path == "fabric/mod.rs"
        && info
            .fn_name
            .as_deref()
            .is_some_and(|f| BLESSED_SETTERS.contains(&f));
    if blessed {
        return;
    }
    for &(pos, ref word) in toks {
        let field_write = CLUSTER_FIELDS.contains(&word.as_str())
            && prev_nonspace(cs, pos) == Some('.')
            && is_assignment(cs, pos + word.len());
        let pair_mutation = (word == "pair_scale" || word == "hung_paths")
            && prev_nonspace(cs, pos) == Some('.')
            && {
            let after = pos + word.len();
            is_assignment(cs, after)
                || match next_nonspace(cs, after) {
                    Some((p, '.')) => toks
                        .iter()
                        .any(|&(tp, ref t)| tp == p + 1 && MAP_MUTATORS.contains(&t.as_str())),
                    _ => false,
                }
        };
        if field_write || pair_mutation {
            out.push(diag(
                "generation-discipline",
                path,
                line,
                format!(
                    "direct write to Cluster::{word} outside a generation-bumping setter \
                     (stale caches: route through set_*/heal_all)"
                ),
                &info.code,
            ));
        }
    }
}

fn check_digest(
    path: &str,
    line: usize,
    info: &LineInfo,
    toks: &[(usize, String)],
    out: &mut Vec<Diagnostic>,
) {
    if exempt(path, DIGEST_EXEMPT) {
        return;
    }
    for &(_, ref word) in toks {
        if word == "HashMap" || word == "HashSet" {
            out.push(diag(
                "digest-determinism",
                path,
                line,
                format!(
                    "{word} in digest/replay-reachable code: iteration order is \
                     nondeterministic; use BTreeMap/BTreeSet or sort before use"
                ),
                &info.code,
            ));
        }
    }
}

fn check_clock(
    path: &str,
    line: usize,
    info: &LineInfo,
    toks: &[(usize, String)],
    out: &mut Vec<Diagnostic>,
) {
    for &(_, ref word) in toks {
        if word == "Instant" || word == "SystemTime" {
            out.push(diag(
                "clock-hygiene",
                path,
                line,
                format!(
                    "{word} (wall clock) in library code: sim time must come from \
                     simkit::Time; annotate real overhead-measurement sites"
                ),
                &info.code,
            ));
        }
    }
}

fn check_rng(
    path: &str,
    line: usize,
    info: &LineInfo,
    toks: &[(usize, String)],
    cs: &[char],
    out: &mut Vec<Diagnostic>,
) {
    for (i, &(pos, ref word)) in toks.iter().enumerate() {
        if RNG_AMBIENT.contains(&word.as_str()) {
            out.push(diag(
                "rng-stream",
                path,
                line,
                format!("ambient/ad-hoc RNG `{word}` breaks replay determinism"),
                &info.code,
            ));
            continue;
        }
        // `rand::` — the external crate's ambient entry points.
        if word == "rand" && cs.get(pos + word.len()) == Some(&':') {
            out.push(diag(
                "rng-stream",
                path,
                line,
                "external `rand::` usage: the tree's RNG substrate is util::rng".to_string(),
                &info.code,
            ));
            continue;
        }
        // `Rng::new(...)` — a fresh root stream. Forking (`.fork(n)`) is
        // the blessed derivation; new roots need an allow outside the
        // exempt paths.
        if word == "Rng"
            && !exempt(path, RNG_EXEMPT)
            && toks.get(i + 1).is_some_and(|&(p, ref t)| {
                t == "new" && p == pos + word.len() + 2 && cs.get(pos + word.len()) == Some(&':')
            })
        {
            out.push(diag(
                "rng-stream",
                path,
                line,
                "new RNG root stream: derive via .fork(tag) from the run's root seed, \
                 or annotate the blessed root-derivation site"
                    .to_string(),
                &info.code,
            ));
        }
    }
}

fn check_panic(
    path: &str,
    line: usize,
    info: &LineInfo,
    toks: &[(usize, String)],
    cs: &[char],
    out: &mut Vec<Diagnostic>,
) {
    for &(pos, ref word) in toks {
        let after = pos + word.len();
        let hit = match word.as_str() {
            "unwrap" | "expect" => {
                prev_nonspace(cs, pos) == Some('.') && cs.get(after) == Some(&'(')
            }
            "panic" | "unreachable" | "todo" | "unimplemented" => cs.get(after) == Some(&'!'),
            _ => false,
        };
        if hit {
            out.push(diag(
                "panic-budget",
                path,
                line,
                format!("`{word}` in non-test library code: return a Result or annotate why \
                     the invariant holds"),
                &info.code,
            ));
        }
    }
}
