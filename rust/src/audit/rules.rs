//! The per-line rule registry.
//!
//! Each rule here is a pure function over one [`LineInfo`] plus the
//! crate-wide [`Scope`] computed by `graph.rs`/`flow.rs`. There are no
//! path-exemption lists: `digest-determinism` and `clock-hygiene` are
//! scoped by *reachability from the determinism roots* (see
//! `flow.rs`), so fixture files under arbitrary paths fire whenever
//! their own call structure makes them reachable, and nothing is
//! silently exempted by a stale prefix. The interprocedural rules
//! (`rng-taint`, `lock-order`, `module-layering`) live in `flow.rs`;
//! this module holds the lexical ones.

use super::flow::FlowInfo;
use super::graph::{next_nonspace, prev_nonspace, tokens, CrateGraph, LineCtx};
use super::lexer::{LineInfo, SourceModel};
use super::{Diagnostic, RULES};

/// `fabric::Cluster` health/scale fields whose writes must route through
/// the generation-bumping setters (the PR 4 cache-coherence contract).
const CLUSTER_FIELDS: &[&str] = &[
    "compute_scale",
    "temp_c",
    "cpu_satisfaction",
    "high_cpu_jobs",
    "bandwidth_scale",
    "external_scale",
];

/// Methods that mutate a map/set in place (for `pair_scale`/`hung_paths`).
const MAP_MUTATORS: &[&str] = &["insert", "remove", "clear", "entry", "get_mut", "retain"];

/// The only functions allowed to write `Cluster` fields directly: the
/// setters themselves, all in `fabric/mod.rs`.
const BLESSED_SETTERS: &[&str] = &[
    "set_gpu_health",
    "set_cpu_health",
    "set_uplink_scale",
    "set_pair_scale",
    "set_path_hang",
    "set_external_scale",
    "heal_all",
];

/// Ambient / ad-hoc RNG constructors that break replayability anywhere.
const RNG_AMBIENT: &[&str] = &["thread_rng", "from_entropy", "seed_from_u64"];

/// Crate-wide context the lexical rules consult: the call graph (for
/// per-line fn attribution and the panic-budget self-method check) and
/// the flow result (for reachability scoping).
pub struct Scope<'a> {
    pub graph: &'a CrateGraph,
    pub flow: &'a FlowInfo,
}

impl Scope<'_> {
    fn line_ctx(&self, path: &str, line: usize) -> Option<&LineCtx> {
        self.graph.line_ctx.get(path).and_then(|v| v.get(line - 1))
    }

    /// Whether a line is in digest/clock scope: inside a fn reachable
    /// from the determinism roots, or at module scope in a file that
    /// defines at least one reachable fn.
    fn in_reach_scope(&self, path: &str, line: usize) -> bool {
        match self.line_ctx(path, line).and_then(|c| c.fn_id) {
            Some(id) => self.flow.reachable.contains(&id),
            None => self.flow.reachable_files.contains(path),
        }
    }

    /// Whether `self.<method>(...)` at this line resolves to a method the
    /// enclosing impl defines — an in-crate call, not `Option::expect` /
    /// `Result::unwrap`.
    fn self_method(&self, path: &str, line: usize, name: &str) -> bool {
        self.line_ctx(path, line)
            .and_then(|c| c.impl_type.as_deref())
            .and_then(|ty| self.graph.impl_methods.get(ty))
            .is_some_and(|methods| methods.contains(name))
    }
}

/// After a field token (and optional `[index]`), is the next operator a
/// plain or compound assignment?
fn is_assignment(cs: &[char], mut k: usize) -> bool {
    // Skip one bracketed index expression, line-locally.
    if let Some((p, '[')) = next_nonspace(cs, k) {
        let mut depth = 1usize;
        k = p + 1;
        while k < cs.len() && depth > 0 {
            match cs[k] {
                '[' => depth += 1,
                ']' => depth -= 1,
                _ => {}
            }
            k += 1;
        }
        if depth > 0 {
            return false;
        }
    }
    match next_nonspace(cs, k) {
        Some((p, '=')) => cs.get(p + 1) != Some(&'='),
        Some((p, c)) if matches!(c, '+' | '-' | '*' | '/') => cs.get(p + 1) == Some(&'='),
        _ => false,
    }
}

fn diag(rule: &'static str, path: &str, line: usize, msg: String, code: &str) -> Diagnostic {
    Diagnostic {
        rule,
        path: path.to_string(),
        line,
        msg,
        snippet: code.trim().to_string(),
    }
}

/// Run every lexical rule over one parsed file. Allow suppression
/// happens in the engine; this returns raw findings (including
/// `allow-grammar` ones).
pub fn check(path: &str, model: &SourceModel, scope: &Scope) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    for (idx, info) in model.lines.iter().enumerate() {
        let line = idx + 1;
        check_allow_grammar(path, info, &mut out);
        if info.in_test {
            continue;
        }
        let toks = tokens(&info.code);
        if toks.is_empty() {
            continue;
        }
        let cs: Vec<char> = info.code.chars().collect();
        check_generation(path, line, info, &toks, &cs, &mut out);
        if scope.in_reach_scope(path, line) {
            check_digest(path, line, info, &toks, &mut out);
            check_clock(path, line, info, &toks, &mut out);
        }
        check_rng(path, line, info, &toks, &cs, &mut out);
        check_panic(path, line, info, &toks, &cs, scope, &mut out);
    }
    out
}

fn check_allow_grammar(path: &str, info: &LineInfo, out: &mut Vec<Diagnostic>) {
    for allow in &info.allows {
        let known = RULES.iter().any(|r| r.id == allow.rule) && allow.rule != "allow-grammar";
        if !known {
            out.push(diag(
                "allow-grammar",
                path,
                allow.at_line,
                format!("allow names unknown rule `{}`", allow.rule),
                &info.code,
            ));
        } else if !allow.has_reason {
            out.push(diag(
                "allow-grammar",
                path,
                allow.at_line,
                format!("allow({}) missing a `: reason`", allow.rule),
                &info.code,
            ));
        }
    }
}

fn check_generation(
    path: &str,
    line: usize,
    info: &LineInfo,
    toks: &[(usize, String)],
    cs: &[char],
    out: &mut Vec<Diagnostic>,
) {
    let blessed = path == "fabric/mod.rs"
        && info
            .fn_name
            .as_deref()
            .is_some_and(|f| BLESSED_SETTERS.contains(&f));
    if blessed {
        return;
    }
    for &(pos, ref word) in toks {
        let field_write = CLUSTER_FIELDS.contains(&word.as_str())
            && prev_nonspace(cs, pos) == Some('.')
            && is_assignment(cs, pos + word.len());
        let pair_mutation = (word == "pair_scale" || word == "hung_paths")
            && prev_nonspace(cs, pos) == Some('.')
            && {
            let after = pos + word.len();
            is_assignment(cs, after)
                || match next_nonspace(cs, after) {
                    Some((p, '.')) => toks
                        .iter()
                        .any(|&(tp, ref t)| tp == p + 1 && MAP_MUTATORS.contains(&t.as_str())),
                    _ => false,
                }
        };
        if field_write || pair_mutation {
            out.push(diag(
                "generation-discipline",
                path,
                line,
                format!(
                    "direct write to Cluster::{word} outside a generation-bumping setter \
                     (stale caches: route through set_*/heal_all)"
                ),
                &info.code,
            ));
        }
    }
}

fn check_digest(
    path: &str,
    line: usize,
    info: &LineInfo,
    toks: &[(usize, String)],
    out: &mut Vec<Diagnostic>,
) {
    for &(_, ref word) in toks {
        if word == "HashMap" || word == "HashSet" {
            out.push(diag(
                "digest-determinism",
                path,
                line,
                format!(
                    "{word} in digest/replay-reachable code: iteration order is \
                     nondeterministic; use BTreeMap/BTreeSet or sort before use"
                ),
                &info.code,
            ));
        }
    }
}

fn check_clock(
    path: &str,
    line: usize,
    info: &LineInfo,
    toks: &[(usize, String)],
    out: &mut Vec<Diagnostic>,
) {
    for &(_, ref word) in toks {
        if word == "Instant" || word == "SystemTime" {
            out.push(diag(
                "clock-hygiene",
                path,
                line,
                format!(
                    "{word} (wall clock) in digest/replay-reachable code: sim time must \
                     come from simkit::Time; annotate real overhead-measurement sites"
                ),
                &info.code,
            ));
        }
    }
}

fn check_rng(
    path: &str,
    line: usize,
    info: &LineInfo,
    toks: &[(usize, String)],
    cs: &[char],
    out: &mut Vec<Diagnostic>,
) {
    for &(pos, ref word) in toks {
        if RNG_AMBIENT.contains(&word.as_str()) {
            out.push(diag(
                "rng-stream",
                path,
                line,
                format!("ambient/ad-hoc RNG `{word}` breaks replay determinism"),
                &info.code,
            ));
            continue;
        }
        // `rand::` — the external crate's ambient entry points. Fresh
        // `Rng::new` roots are the `rng-taint` rule's job now: it proves
        // seed derivation interprocedurally instead of flagging the
        // constructor textually.
        if word == "rand" && cs.get(pos + word.len()) == Some(&':') {
            out.push(diag(
                "rng-stream",
                path,
                line,
                "external `rand::` usage: the tree's RNG substrate is util::rng".to_string(),
                &info.code,
            ));
        }
    }
}

fn check_panic(
    path: &str,
    line: usize,
    info: &LineInfo,
    toks: &[(usize, String)],
    cs: &[char],
    scope: &Scope,
    out: &mut Vec<Diagnostic>,
) {
    for &(pos, ref word) in toks {
        let after = pos + word.len();
        let hit = match word.as_str() {
            "unwrap" | "expect" => {
                let call = prev_nonspace(cs, pos) == Some('.') && cs.get(after) == Some(&'(');
                // `self.expect(...)` where the enclosing impl defines
                // `expect` is an in-crate method call (e.g. the JSON
                // parser), proven by the call graph — not a panic site.
                call && !(receiver_is_self(cs, pos) && scope.self_method(path, line, word))
            }
            "panic" | "unreachable" | "todo" | "unimplemented" => cs.get(after) == Some(&'!'),
            _ => false,
        };
        if hit {
            out.push(diag(
                "panic-budget",
                path,
                line,
                format!("`{word}` in non-test library code: return a Result or annotate why \
                     the invariant holds"),
                &info.code,
            ));
        }
    }
}

/// Whether the receiver chain immediately before `.word(` is literally
/// the token `self`.
fn receiver_is_self(cs: &[char], pos: usize) -> bool {
    let mut k = pos;
    while k > 0 && (cs[k - 1] == ' ' || cs[k - 1] == '\t') {
        k -= 1;
    }
    if k == 0 || cs[k - 1] != '.' {
        return false;
    }
    k -= 1;
    while k > 0 && (cs[k - 1] == ' ' || cs[k - 1] == '\t') {
        k -= 1;
    }
    let end = k;
    while k > 0 && (cs[k - 1].is_ascii_alphanumeric() || cs[k - 1] == '_') {
        k -= 1;
    }
    let recv: String = cs[k..end].iter().collect();
    recv == "self" && (k == 0 || cs[k - 1] != '.')
}
