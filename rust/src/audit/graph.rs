//! Crate-wide item extraction and call-graph construction.
//!
//! Built on the blanked line model from [`super::lexer`], this walks every
//! file once and extracts the items the interprocedural rules in
//! [`super::flow`] need: `fn` definitions (with enclosing `impl` type and
//! parameter names), call sites (with a best-effort qualifier and
//! line-local argument tokens), `crate::` module-dependency edges, and
//! named `Mutex` declarations. Resolution is name-based and deliberately
//! over-approximate — `.step(` resolves to *every* method named `step` —
//! which is sound for reachability scoping (a function is only exempted
//! from a scoped rule when *no* resolution path reaches it) but must not
//! be read as a proof that a specific dynamic call occurs.
//!
//! Everything here is itself digest-reachable (the `--graph --json`
//! surface makes [`CrateGraph::to_json`] a root), so this module obeys
//! the rules it powers: `BTreeMap`/`BTreeSet` only, no wall clock, no
//! panicking calls.

use super::lexer::SourceModel;
use crate::util::json::Json;
use std::collections::{BTreeMap, BTreeSet};

/// Whole-word identifier tokens of a blanked line, with char positions.
pub(super) fn tokens(code: &str) -> Vec<(usize, String)> {
    let cs: Vec<char> = code.chars().collect();
    let mut out = Vec::new();
    let mut k = 0usize;
    while k < cs.len() {
        let c = cs[k];
        if (c.is_ascii_alphabetic() || c == '_') && !(k > 0 && cs[k - 1].is_ascii_digit()) {
            let start = k;
            while k < cs.len() && (cs[k].is_ascii_alphanumeric() || cs[k] == '_') {
                k += 1;
            }
            out.push((start, cs[start..k].iter().collect()));
        } else {
            k += 1;
        }
    }
    out
}

pub(super) fn prev_nonspace(cs: &[char], mut k: usize) -> Option<char> {
    while k > 0 {
        k -= 1;
        if cs[k] != ' ' && cs[k] != '\t' {
            return Some(cs[k]);
        }
    }
    None
}

pub(super) fn next_nonspace(cs: &[char], mut k: usize) -> Option<(usize, char)> {
    while k < cs.len() {
        if cs[k] != ' ' && cs[k] != '\t' {
            return Some((k, cs[k]));
        }
        k += 1;
    }
    None
}

/// Keywords that look like calls when followed by `(`.
const KEYWORDS: &[&str] = &[
    "if", "while", "for", "match", "return", "loop", "fn", "let", "in", "as", "move", "ref",
    "mut", "else", "break", "continue", "where", "impl", "pub", "use", "mod", "struct", "enum",
    "trait", "type", "const", "static", "dyn", "crate", "super", "self", "true", "false",
];

/// One extracted function definition.
#[derive(Clone, Debug)]
pub struct FnItem {
    pub name: String,
    /// Top-level module (first path segment; `main.rs` -> `main`).
    pub module: String,
    pub path: String,
    /// Enclosing `impl` type, if inside one.
    pub impl_type: Option<String>,
    /// Takes `self` in some form.
    pub is_method: bool,
    /// Non-`self` parameter names, in order.
    pub params: Vec<String>,
    /// 1-based line of the `fn` keyword.
    pub line: usize,
    pub in_test: bool,
}

/// How a call site names its callee.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CallKind {
    /// `name(...)` with no qualifier.
    Bare,
    /// `.name(...)` on a receiver.
    Method,
    /// `Type::name(...)` (including `Self::`).
    TypeQualified,
    /// `module::name(...)` (lowercase path segment).
    ModQualified,
}

/// One extracted call site (non-test lines only).
#[derive(Clone, Debug)]
pub struct CallSite {
    /// Index into [`CrateGraph::fns`] of the enclosing fn, if any.
    pub caller: Option<usize>,
    pub path: String,
    pub line: usize,
    pub callee: String,
    pub kind: CallKind,
    /// The `Type`/`module` segment immediately before `::`, if any.
    pub qualifier: Option<String>,
    /// Enclosing `impl` type at the call site (resolves `Self::`).
    pub impl_type: Option<String>,
    /// Method call whose receiver token is literally `self`.
    pub receiver_self: bool,
    /// Identifier tokens per argument, line-local.
    pub args: Vec<Vec<String>>,
    /// Candidate callee fn indices after name-based resolution.
    pub resolved: Vec<usize>,
}

/// One named `Mutex` field/static declaration (non-test lines only).
#[derive(Clone, Debug)]
pub struct LockDecl {
    pub module: String,
    pub name: String,
    pub path: String,
    pub line: usize,
}

/// Per-line context the scoped rules need.
#[derive(Clone, Debug, Default)]
pub struct LineCtx {
    /// Enclosing fn (index into [`CrateGraph::fns`]); `None` at module
    /// scope.
    pub fn_id: Option<usize>,
    /// Enclosing `impl` type.
    pub impl_type: Option<String>,
}

/// The crate-wide item graph.
#[derive(Debug, Default)]
pub struct CrateGraph {
    pub fns: Vec<FnItem>,
    pub calls: Vec<CallSite>,
    /// `(from module, to module) -> first site`, non-test lines only.
    pub mod_edges: BTreeMap<(String, String), (String, usize)>,
    pub locks: Vec<LockDecl>,
    /// Per file: one [`LineCtx`] per line.
    pub line_ctx: BTreeMap<String, Vec<LineCtx>>,
    /// Top-level modules seen across the scanned files.
    pub modules: BTreeSet<String>,
    /// `impl` type -> method names it defines (for the panic-budget
    /// self-method resolution).
    pub impl_methods: BTreeMap<String, BTreeSet<String>>,
}

/// Top-level module of a root-relative path: `fleet/mod.rs` -> `fleet`,
/// `main.rs` -> `main`.
pub fn top_module(path: &str) -> String {
    match path.split_once('/') {
        Some((head, _)) => head.to_string(),
        None => path.strip_suffix(".rs").unwrap_or(path).to_string(),
    }
}

/// Parse an `impl` header's remainder-of-line into the implemented type:
/// the first capitalized token, or the first after `for` in
/// `impl Trait for Type`.
fn impl_type_of(rest: &str) -> Option<String> {
    let toks = tokens(rest);
    let names: Vec<&str> = toks.iter().map(|(_, t)| t.as_str()).collect();
    let from = match names.iter().position(|t| *t == "for") {
        Some(i) => i + 1,
        None => 0,
    };
    names[from..]
        .iter()
        .find(|t| t.starts_with(|c: char| c.is_ascii_uppercase()))
        .map(|t| t.to_string())
}

/// Parse a signature buffer (everything between the fn name and its body
/// `{` / terminating `;`) into parameter names and method-ness.
fn parse_sig(sig: &str) -> (Vec<String>, bool) {
    let cs: Vec<char> = sig.chars().collect();
    let mut k = 0usize;
    // Skip a leading generics group, ignoring `->` arrowheads inside it.
    if let Some((p, '<')) = next_nonspace(&cs, 0) {
        let mut depth = 1usize;
        k = p + 1;
        while k < cs.len() && depth > 0 {
            match cs[k] {
                '<' => depth += 1,
                '>' if k > 0 && cs[k - 1] != '-' => depth -= 1,
                _ => {}
            }
            k += 1;
        }
    }
    // The parameter list is the first balanced (...) group after that.
    let mut start = None;
    while k < cs.len() {
        if cs[k] == '(' {
            start = Some(k + 1);
            break;
        }
        k += 1;
    }
    let Some(start) = start else {
        return (Vec::new(), false);
    };
    let mut depth = 1usize;
    let mut end = cs.len();
    let mut j = start;
    while j < cs.len() {
        match cs[j] {
            '(' | '<' | '[' => depth += 1,
            ')' | ']' => {
                depth -= 1;
                if depth == 0 {
                    end = j;
                    break;
                }
            }
            '>' if j > 0 && cs[j - 1] != '-' => depth = depth.saturating_sub(1),
            _ => {}
        }
        j += 1;
    }
    let params_text: String = cs[start..end].iter().collect();
    let mut params = Vec::new();
    let mut is_method = false;
    let mut part = String::new();
    let mut d = 0usize;
    let mut parts = Vec::new();
    for ch in params_text.chars() {
        match ch {
            '(' | '<' | '[' | '{' => d += 1,
            ')' | '>' | ']' | '}' => d = d.saturating_sub(1),
            ',' if d == 0 => {
                parts.push(std::mem::take(&mut part));
                continue;
            }
            _ => {}
        }
        part.push(ch);
    }
    if !part.trim().is_empty() {
        parts.push(part);
    }
    for p in parts {
        let toks = tokens(&p);
        let names: Vec<&str> = toks.iter().map(|(_, t)| t.as_str()).collect();
        if names.iter().take(2).any(|t| *t == "self") {
            is_method = true;
            continue;
        }
        if let Some(name) = names.iter().find(|t| **t != "mut") {
            if p.contains(':') {
                params.push(name.to_string());
            }
        }
    }
    (params, is_method)
}

/// A pending fn definition whose signature is still being accumulated.
struct PendingFn {
    name: String,
    line: usize,
    sig: String,
}

/// Build the crate graph from parsed sources. `files` must be
/// root-relative paths with `/` separators, sorted (the caller's walk
/// already guarantees this).
pub fn build(files: &[(String, SourceModel)]) -> CrateGraph {
    let mut g = CrateGraph::default();
    for (path, model) in files {
        extract_file(&mut g, path, model);
    }
    for f in &g.fns {
        if let Some(ty) = &f.impl_type {
            g.impl_methods
                .entry(ty.clone())
                .or_default()
                .insert(f.name.clone());
        }
    }
    resolve(&mut g);
    g
}

fn extract_file(g: &mut CrateGraph, path: &str, model: &SourceModel) {
    let module = top_module(path);
    g.modules.insert(module.clone());
    let mut depth = 0usize;
    let mut impl_stack: Vec<(String, usize)> = Vec::new();
    let mut fn_stack: Vec<(usize, usize)> = Vec::new();
    let mut pending_impl: Option<String> = None;
    let mut pending_fn: Option<PendingFn> = None;
    let mut ctxs: Vec<LineCtx> = Vec::with_capacity(model.lines.len());

    for (li, info) in model.lines.iter().enumerate() {
        let line = li + 1;
        let start_fn = fn_stack.last().map(|&(id, _)| id);
        let start_impl = impl_stack.last().map(|(t, _)| t.clone());
        let mut pushed_fn: Option<usize> = None;
        let mut pushed_impl: Option<String> = None;

        let cs: Vec<char> = info.code.chars().collect();
        let mut k = 0usize;
        let mut after_fn_kw = false;
        while k < cs.len() {
            let ch = cs[k];
            if (ch.is_ascii_alphanumeric() || ch == '_') && !ch.is_ascii_digit() {
                let start = k;
                while k < cs.len() && (cs[k].is_ascii_alphanumeric() || cs[k] == '_') {
                    k += 1;
                }
                let word: String = cs[start..k].iter().collect();
                if pending_fn.is_some() {
                    // Signature accumulation swallows everything below.
                } else if word == "fn" {
                    after_fn_kw = true;
                    continue;
                } else if after_fn_kw {
                    after_fn_kw = false;
                    pending_fn = Some(PendingFn { name: word, line, sig: String::new() });
                    continue;
                } else if word == "impl" {
                    let rest: String = cs[k..].iter().collect();
                    pending_impl = impl_type_of(&rest);
                    continue;
                }
                if let Some(p) = pending_fn.as_mut() {
                    p.sig.push_str(&word);
                }
                continue;
            }
            match ch {
                '{' => {
                    depth += 1;
                    if let Some(p) = pending_fn.take() {
                        let (params, is_method) = parse_sig(&p.sig);
                        let id = g.fns.len();
                        g.fns.push(FnItem {
                            name: p.name,
                            module: module.clone(),
                            path: path.to_string(),
                            impl_type: impl_stack.last().map(|(t, _)| t.clone()),
                            is_method,
                            params,
                            line: p.line,
                            in_test: info.in_test,
                        });
                        fn_stack.push((id, depth));
                        pushed_fn = Some(id);
                    } else if let Some(ty) = pending_impl.take() {
                        impl_stack.push((ty.clone(), depth));
                        pushed_impl = Some(ty);
                    }
                }
                '}' => {
                    while fn_stack.last().is_some_and(|&(_, d)| d == depth) {
                        fn_stack.pop();
                    }
                    while impl_stack.last().is_some_and(|&(_, d)| d == depth) {
                        impl_stack.pop();
                    }
                    depth = depth.saturating_sub(1);
                }
                ';' => {
                    // Bodyless fn (trait decl) or non-impl item; a `;`
                    // inside the signature's parens stays part of it.
                    let done = pending_fn
                        .as_ref()
                        .is_some_and(|p| !p.sig.contains('(') || balanced(&p.sig));
                    if done {
                        pending_fn = None;
                    } else if let Some(p) = pending_fn.as_mut() {
                        p.sig.push(ch);
                    }
                    pending_impl = None;
                }
                _ => {
                    if let Some(p) = pending_fn.as_mut() {
                        p.sig.push(ch);
                    }
                }
            }
            k += 1;
        }

        let line_fn = pushed_fn.or(start_fn);
        let line_impl = pushed_impl.or(start_impl);
        ctxs.push(LineCtx { fn_id: line_fn, impl_type: line_impl.clone() });

        if !info.in_test {
            extract_line(g, path, &module, line, &info.code, line_fn, line_impl.as_deref());
        }
    }
    g.line_ctx.insert(path.to_string(), ctxs);
}

/// Whether a signature buffer's parens are balanced (so a `;` terminates
/// the item rather than sitting inside a default expression).
fn balanced(sig: &str) -> bool {
    let mut d = 0i64;
    for ch in sig.chars() {
        match ch {
            '(' => d += 1,
            ')' => d -= 1,
            _ => {}
        }
    }
    d <= 0
}

/// Extract call sites, module edges, and lock declarations from one
/// non-test line.
fn extract_line(
    g: &mut CrateGraph,
    path: &str,
    module: &str,
    line: usize,
    code: &str,
    line_fn: Option<usize>,
    line_impl: Option<&str>,
) {
    let cs: Vec<char> = code.chars().collect();
    let toks = tokens(code);
    for (i, &(pos, ref word)) in toks.iter().enumerate() {
        let after = pos + word.len();
        // Module-dependency edge: `crate::X` (or `falcon::X` from the
        // binary crate), plus grouped `use crate::{a, b::c}`.
        if (word == "crate" || (word == "falcon" && module == "main"))
            && cs.get(after) == Some(&':')
            && cs.get(after + 1) == Some(&':')
        {
            if let Some(&(np, ref next)) = toks.get(i + 1) {
                if np == after + 2 {
                    record_mod_edge(g, module, next, path, line);
                }
            }
            if cs.get(after + 2) == Some(&'{') {
                let rest: String = cs[after + 3..].iter().collect();
                let inner = match rest.find('}') {
                    Some(close) => &rest[..close],
                    None => rest.as_str(),
                };
                for part in inner.split(',') {
                    if let Some((_, first)) = tokens(part).first() {
                        record_mod_edge(g, module, first, path, line);
                    }
                }
            }
        }
        // Lock declaration: `name: Mutex<...>` (possibly wrapped, possibly
        // `std::sync::`-qualified). The field name sits before the last
        // *type* colon — a `:` that is not part of a `::` path separator.
        if word == "Mutex" && cs.get(after) == Some(&'<') {
            let mut colon = None;
            for j in 0..pos {
                if cs[j] == ':'
                    && cs.get(j + 1) != Some(&':')
                    && (j == 0 || cs[j - 1] != ':')
                {
                    colon = Some(j);
                }
            }
            if let Some(colon) = colon {
                let head: String = cs[..colon].iter().collect();
                if let Some(&(_, ref name)) = tokens(&head).last() {
                    g.locks.push(LockDecl {
                        module: module.to_string(),
                        name: name.clone(),
                        path: path.to_string(),
                        line,
                    });
                }
            }
        }
        // Call site: ident immediately followed by `(`, lowercase-initial,
        // not a keyword, not a definition.
        if cs.get(after) != Some(&'(')
            || word.starts_with(|c: char| c.is_ascii_uppercase())
            || KEYWORDS.contains(&word.as_str())
        {
            continue;
        }
        if i > 0 && toks[i - 1].1 == "fn" {
            continue;
        }
        let before: String = cs[..pos].iter().collect();
        let trimmed = before.trim_end();
        let (kind, qualifier, receiver_self) = if trimmed.ends_with("::") {
            let head = &trimmed[..trimmed.len() - 2];
            match tokens(head).last() {
                Some(&(qp, ref q)) if qp + q.len() == head.len() => {
                    if q == "crate" || q == "super" || q == "falcon" {
                        (CallKind::Bare, None, false)
                    } else if q.starts_with(|c: char| c.is_ascii_uppercase()) {
                        (CallKind::TypeQualified, Some(q.clone()), false)
                    } else {
                        (CallKind::ModQualified, Some(q.clone()), false)
                    }
                }
                _ => (CallKind::Bare, None, false),
            }
        } else if trimmed.ends_with('.') {
            let recv = trimmed[..trimmed.len() - 1].trim_end();
            let is_self = tokens(recv)
                .last()
                .is_some_and(|&(rp, ref r)| r == "self" && rp + r.len() == recv.len());
            (CallKind::Method, None, is_self)
        } else {
            (CallKind::Bare, None, false)
        };
        // Line-local argument token lists.
        let mut d = 0usize;
        let mut j = after;
        let mut close = cs.len();
        while j < cs.len() {
            match cs[j] {
                '(' => d += 1,
                ')' => {
                    d -= 1;
                    if d == 0 {
                        close = j;
                        break;
                    }
                }
                _ => {}
            }
            j += 1;
        }
        let argtext: String = cs[after + 1..close.min(cs.len())].iter().collect();
        let mut args = Vec::new();
        let mut cur = String::new();
        let mut d2 = 0i64;
        for ch in argtext.chars() {
            match ch {
                '(' | '[' | '{' | '<' => d2 += 1,
                ')' | ']' | '}' | '>' => d2 -= 1,
                ',' if d2 <= 0 => {
                    args.push(std::mem::take(&mut cur));
                    continue;
                }
                _ => {}
            }
            cur.push(ch);
        }
        if !cur.trim().is_empty() {
            args.push(cur);
        }
        g.calls.push(CallSite {
            caller: line_fn,
            path: path.to_string(),
            line,
            callee: word.clone(),
            kind,
            qualifier,
            impl_type: line_impl.map(|s| s.to_string()),
            receiver_self,
            args: args
                .iter()
                .map(|a| tokens(a).into_iter().map(|(_, t)| t).collect())
                .collect(),
            resolved: Vec::new(),
        });
    }
}

fn record_mod_edge(g: &mut CrateGraph, module: &str, target: &str, path: &str, line: usize) {
    if target == module || target.is_empty() {
        return;
    }
    g.mod_edges
        .entry((module.to_string(), target.to_string()))
        .or_insert_with(|| (path.to_string(), line));
}

/// Name-based resolution: fill each call site's candidate list.
fn resolve(g: &mut CrateGraph) {
    let mut by_impl: BTreeMap<(String, String), Vec<usize>> = BTreeMap::new();
    let mut by_mod: BTreeMap<(String, String), Vec<usize>> = BTreeMap::new();
    let mut by_file: BTreeMap<(String, String), Vec<usize>> = BTreeMap::new();
    let mut by_name: BTreeMap<String, Vec<usize>> = BTreeMap::new();
    let mut methods: BTreeMap<String, Vec<usize>> = BTreeMap::new();
    for (id, f) in g.fns.iter().enumerate() {
        if f.in_test {
            continue;
        }
        if let Some(ty) = &f.impl_type {
            by_impl.entry((ty.clone(), f.name.clone())).or_default().push(id);
        }
        by_mod.entry((f.module.clone(), f.name.clone())).or_default().push(id);
        by_file.entry((f.path.clone(), f.name.clone())).or_default().push(id);
        by_name.entry(f.name.clone()).or_default().push(id);
        if f.is_method {
            methods.entry(f.name.clone()).or_default().push(id);
        }
    }
    let empty: Vec<usize> = Vec::new();
    // Collect (index, resolved) first: the file-match fallback needs
    // immutable access to `g.fns`.
    let mut resolutions: Vec<Vec<usize>> = Vec::with_capacity(g.calls.len());
    for c in &g.calls {
        let res: &Vec<usize> = match c.kind {
            CallKind::TypeQualified => {
                let ty = match c.qualifier.as_deref() {
                    Some("Self") => c.impl_type.clone().unwrap_or_default(),
                    Some(q) => q.to_string(),
                    None => String::new(),
                };
                by_impl.get(&(ty, c.callee.clone())).unwrap_or(&empty)
            }
            CallKind::ModQualified => {
                let q = c.qualifier.clone().unwrap_or_default();
                match by_mod.get(&(q.clone(), c.callee.clone())) {
                    Some(v) => v,
                    None => {
                        // Submodule path segment: match by file name.
                        let file_rs = format!("{q}.rs");
                        let slash_rs = format!("/{q}.rs");
                        let dir = format!("{q}/");
                        let in_dir = format!("/{q}/");
                        resolutions.push(
                            g.fns
                                .iter()
                                .enumerate()
                                .filter(|(_, f)| {
                                    !f.in_test
                                        && f.name == c.callee
                                        && (f.path == file_rs
                                            || f.path.ends_with(&slash_rs)
                                            || f.path.starts_with(&dir)
                                            || f.path.contains(&in_dir))
                                })
                                .map(|(id, _)| id)
                                .collect(),
                        );
                        continue;
                    }
                }
            }
            CallKind::Method => methods.get(&c.callee).unwrap_or(&empty),
            CallKind::Bare => {
                let in_file = by_file.get(&(c.path.clone(), c.callee.clone()));
                match in_file {
                    Some(v) if !v.is_empty() => v,
                    _ => {
                        let m = top_module(&c.path);
                        match by_mod.get(&(m, c.callee.clone())) {
                            Some(v) if !v.is_empty() => v,
                            _ => by_name.get(&c.callee).unwrap_or(&empty),
                        }
                    }
                }
            }
        };
        resolutions.push(res.clone());
    }
    for (c, res) in g.calls.iter_mut().zip(resolutions) {
        c.resolved = res;
    }
}

impl CrateGraph {
    /// Non-test fn count.
    pub fn live_fns(&self) -> usize {
        self.fns.iter().filter(|f| !f.in_test).count()
    }

    /// Distinct resolved caller->callee edges.
    pub fn call_edges(&self) -> BTreeSet<(usize, usize)> {
        let mut out = BTreeSet::new();
        for c in &self.calls {
            if let Some(caller) = c.caller {
                for &r in &c.resolved {
                    out.insert((caller, r));
                }
            }
        }
        out
    }

    /// JSON form of the call graph + module DAG (`falcon audit --graph
    /// --json`). Takes the flow result so reachability is included.
    pub fn to_json(&self, flow: &super::flow::FlowInfo) -> Json {
        let mut per_module: BTreeMap<&str, usize> = BTreeMap::new();
        for f in &self.fns {
            if !f.in_test {
                *per_module.entry(f.module.as_str()).or_default() += 1;
            }
        }
        Json::obj(vec![
            ("files", Json::Num(self.line_ctx.len() as f64)),
            ("fns", Json::Num(self.live_fns() as f64)),
            ("call_sites", Json::Num(self.calls.len() as f64)),
            ("call_edges", Json::Num(self.call_edges().len() as f64)),
            ("roots", Json::Num(flow.roots.len() as f64)),
            ("reachable", Json::Num(flow.reachable.len() as f64)),
            (
                "modules",
                Json::Arr(
                    per_module
                        .iter()
                        .map(|(m, n)| {
                            Json::obj(vec![("name", Json::str(m)), ("fns", Json::Num(*n as f64))])
                        })
                        .collect(),
                ),
            ),
            (
                "module_edges",
                Json::Arr(
                    self.mod_edges
                        .iter()
                        .map(|((a, b), (p, l))| {
                            Json::obj(vec![
                                ("from", Json::str(a)),
                                ("to", Json::str(b)),
                                ("site", Json::str(&format!("{p}:{l}"))),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "locks",
                Json::Arr(
                    self.locks
                        .iter()
                        .map(|l| {
                            Json::obj(vec![
                                ("id", Json::str(&format!("{}::{}", l.module, l.name))),
                                ("site", Json::str(&format!("{}:{}", l.path, l.line))),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Graphviz form of the module-dependency DAG (`--graph --dot`).
    pub fn to_dot(&self) -> String {
        let mut s = String::from("digraph falcon_modules {\n  rankdir=LR;\n");
        for m in &self.modules {
            s.push_str(&format!("  \"{m}\";\n"));
        }
        for (a, b) in self.mod_edges.keys() {
            s.push_str(&format!("  \"{a}\" -> \"{b}\";\n"));
        }
        s.push_str("}\n");
        s
    }

    /// Human summary for `--graph` without a format flag.
    pub fn render(&self, flow: &super::flow::FlowInfo) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "crate graph: {} files, {} fns ({} non-test), {} call sites, {} resolved edges\n",
            self.line_ctx.len(),
            self.fns.len(),
            self.live_fns(),
            self.calls.len(),
            self.call_edges().len(),
        ));
        s.push_str(&format!(
            "reachability: {} roots -> {} reachable fns across {} files\n",
            flow.roots.len(),
            flow.reachable.len(),
            flow.reachable_files.len(),
        ));
        s.push_str(&format!(
            "module DAG: {} modules, {} edges; locks: {}\n",
            self.modules.len(),
            self.mod_edges.len(),
            self.locks.len(),
        ));
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model(src: &str) -> SourceModel {
        SourceModel::parse(src)
    }

    #[test]
    fn extracts_fns_with_impl_and_params() {
        let src = "impl Foo {\n    pub fn bar(&self, seed: u64, n: usize) -> u64 {\n        \
                   helper(seed)\n    }\n}\nfn helper(x: u64) -> u64 {\n    x\n}\n";
        let g = build(&[("m/a.rs".to_string(), model(src))]);
        assert_eq!(g.fns.len(), 2);
        assert_eq!(g.fns[0].name, "bar");
        assert_eq!(g.fns[0].impl_type.as_deref(), Some("Foo"));
        assert!(g.fns[0].is_method);
        assert_eq!(g.fns[0].params, vec!["seed", "n"]);
        assert_eq!(g.fns[1].name, "helper");
        assert!(!g.fns[1].is_method);
    }

    #[test]
    fn resolves_bare_calls_in_file_first() {
        let src = "fn a() {\n    b();\n}\nfn b() {}\n";
        let g = build(&[("m/a.rs".to_string(), model(src))]);
        let call = g.calls.iter().find(|c| c.callee == "b");
        assert!(call.is_some_and(|c| c.resolved == vec![1]));
    }

    #[test]
    fn resolves_type_qualified_and_self() {
        let src = "impl Foo {\n    fn new() -> Foo {\n        Foo\n    }\n    fn dup(&self) {\n        \
                   let _ = Self::new();\n    }\n}\n";
        let g = build(&[("m/a.rs".to_string(), model(src))]);
        let call = g.calls.iter().find(|c| c.callee == "new");
        assert!(call.is_some_and(|c| c.kind == CallKind::TypeQualified && c.resolved == vec![0]));
    }

    #[test]
    fn method_calls_resolve_by_name_over_approx() {
        let a = "impl A {\n    pub fn step(&self) {}\n}\n";
        let b = "impl B {\n    pub fn step(&self) {}\n}\nfn go(x: &B) {\n    x.step();\n}\n";
        let g = build(&[("m/a.rs".to_string(), model(a)), ("n/b.rs".to_string(), model(b))]);
        let call = g.calls.iter().find(|c| c.callee == "step");
        assert!(call.is_some_and(|c| c.resolved.len() == 2), "both impls are candidates");
    }

    #[test]
    fn module_edges_and_grouped_use() {
        let src = "use crate::fabric::Cluster;\nuse crate::{inject, sim::TrainingSim};\n\
                   fn f() {\n    crate::util::stats::mean(&[]);\n}\n";
        let g = build(&[("fleet/mod.rs".to_string(), model(src))]);
        let tos: Vec<&str> = g.mod_edges.keys().map(|(_, b)| b.as_str()).collect();
        assert_eq!(tos, vec!["fabric", "inject", "sim", "util"]);
    }

    #[test]
    fn test_lines_are_excluded() {
        let src = "fn live() {\n    x();\n}\n#[cfg(test)]\nmod tests {\n    fn t() {\n        \
                   y();\n    }\n}\n";
        let g = build(&[("m/a.rs".to_string(), model(src))]);
        assert!(g.calls.iter().all(|c| c.callee != "y"));
        assert_eq!(g.fns.iter().filter(|f| f.in_test).count(), 1);
    }

    #[test]
    fn lock_decls_are_named() {
        let src = "struct S {\n    slots: std::sync::Mutex<Vec<u32>>,\n    jobs: Vec<std::sync::Mutex<u8>>,\n}\n";
        let g = build(&[("fleet/mod.rs".to_string(), model(src))]);
        let names: Vec<&str> = g.locks.iter().map(|l| l.name.as_str()).collect();
        assert_eq!(names, vec!["slots", "jobs"]);
    }

    #[test]
    fn multi_line_signatures_parse_params() {
        let src = "fn spawn(\n    cfg: &Cfg,\n    seed: u64,\n) -> u64 {\n    seed\n}\n";
        let g = build(&[("m/a.rs".to_string(), model(src))]);
        assert_eq!(g.fns[0].params, vec!["cfg", "seed"]);
    }
}
