//! `falcon audit` — an in-tree invariant lint for the determinism
//! contracts everything else promises.
//!
//! The reproduction's headline guarantees are conventions, not types:
//! bit-identical fleet digests across worker counts, empty-edit what-if
//! replays byte-equal to their baselines, cached-vs-naive sim
//! equivalence. Each holds only while every module (a) mutates cluster
//! health exclusively through the generation-bumping `Cluster::set_*`
//! setters, (b) never lets `HashMap`/`HashSet` iteration order reach a
//! digest or serialized report, (c) keeps wall-clock time out of sim
//! paths, and (d) derives every RNG stream from the run's root seed via
//! [`crate::util::rng::Rng::fork`]. This module is the checker that
//! makes those conventions enforceable — and since v2 the *scope* of
//! each check is derived from the crate's own call structure, not from
//! hand-maintained path lists: a dependency-free AST-lite scanner (same
//! style as the TOML/JSON code) over `src/**/*.rs` feeds an item/graph
//! layer ([`graph`]) and interprocedural analyses ([`flow`]) —
//! digest-reachability, RNG taint, lock-order discipline, and an
//! enforced module-layering DAG — on top of the nine-rule registry and
//! the inline allow grammar
//!
//! ```text
//! // audit:allow(rule-id): reason the invariant still holds here
//! ```
//!
//! where the reason is mandatory — a bare allow is itself a violation
//! (`allow-grammar`), and an allow that no longer suppresses anything
//! is flagged as stale so the list of exceptions can only shrink.
//! `unwrap`/`expect`/`panic!` sites are additionally metered by
//! [`PANIC_BUDGET`], a per-module ratchet: entry-point and substrate
//! modules get a fixed allowance that CI fails on exceeding, so the
//! count can only go down. See `docs/AUDIT.md` for the rule catalog and
//! `tests/audit.rs` for the fixture suite; the self-audit test keeps
//! `src/` violation-free.

pub mod flow;
pub mod graph;
mod lexer;
mod rules;

pub use lexer::SourceModel;

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One registry entry: a stable rule id plus the invariant it protects.
pub struct RuleInfo {
    pub id: &'static str,
    pub summary: &'static str,
}

/// The rule registry. Ids are the vocabulary of the allow grammar.
pub const RULES: &[RuleInfo] = &[
    RuleInfo {
        id: "generation-discipline",
        summary: "Cluster health/scale fields change only through the \
                  generation-bumping set_* setters (cache coherence)",
    },
    RuleInfo {
        id: "digest-determinism",
        summary: "no HashMap/HashSet in code reachable from a digest, \
                  serialized report, or replay entry point",
    },
    RuleInfo {
        id: "clock-hygiene",
        summary: "no wall-clock (Instant/SystemTime) in digest/replay- \
                  reachable code; sim time is simkit::Time",
    },
    RuleInfo {
        id: "rng-stream",
        summary: "no ambient or external-crate RNG construction; the \
                  tree's substrate is util::rng",
    },
    RuleInfo {
        id: "rng-taint",
        summary: "every Rng::new root provably derives from a run seed \
                  (interprocedural taint through params)",
    },
    RuleInfo {
        id: "lock-order",
        summary: "named Mutex guards follow one global acquisition order \
                  and are never held across arbiter serialization points",
    },
    RuleInfo {
        id: "module-layering",
        summary: "crate:: dependencies respect the explicit module DAG \
                  in audit::flow::LAYERS",
    },
    RuleInfo {
        id: "panic-budget",
        summary: "unwrap/expect/panic! in library code are metered per \
                  module and annotated or fixed elsewhere",
    },
    RuleInfo {
        id: "allow-grammar",
        summary: "every audit:allow names a known rule, carries a written \
                  reason, and still suppresses a real finding",
    },
];

/// Per-module `panic-budget` allowances: `(path prefix, max sites,
/// rationale)`. A prefix ending in `/` matches a directory; otherwise an
/// exact file. Counts above the allowance fail the audit — lower the
/// number as sites are burned down, never raise it without cause.
pub const PANIC_BUDGET: &[(&str, usize, &str)] = &[
    (
        "main.rs",
        3,
        "CLI entry point: fail-fast with a message is the intended UX",
    ),
    (
        "util/",
        1,
        "dependency substrate: one pinned invariant in prop.rs; \
         everything else degrades gracefully",
    ),
    (
        "reports/",
        6,
        "rendering layer over already-validated outcomes",
    ),
    (
        "diagnose/",
        0,
        "classification layer over op-trace evidence: every input is \
         already validated, so any panic is a bug — the budget is zero",
    ),
    (
        "ledger/",
        0,
        "bookkeeping over already-validated fleet state; snapshot parsing \
         returns Result — any panic is a bug, the budget is zero",
    ),
    (
        "mitigate/",
        0,
        "mitigation planners and the S5 replan solver run inside the \
         coordinator loop on degraded clusters: they must degrade \
         gracefully (guards and let-else), never panic",
    ),
    (
        "trainer/",
        1,
        "pjrt-gated live-training path; not part of the deterministic sim",
    ),
    ("runtime/", 2, "pjrt-gated device runtime; not part of the sim"),
];

/// One finding: where, which rule, why, and the offending line.
#[derive(Clone, Debug)]
pub struct Diagnostic {
    pub rule: &'static str,
    /// Path relative to the scanned root, `/`-separated.
    pub path: String,
    /// 1-based line number.
    pub line: usize,
    pub msg: String,
    pub snippet: String,
}

impl Diagnostic {
    fn render(&self) -> String {
        format!(
            "  {}:{} [{}] {}\n      > {}",
            self.path, self.line, self.rule, self.msg, self.snippet
        )
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("rule", Json::str(self.rule)),
            ("path", Json::str(&self.path)),
            ("line", Json::Num(self.line as f64)),
            ("msg", Json::str(&self.msg)),
            ("snippet", Json::str(&self.snippet)),
        ])
    }
}

/// Findings for one file, before directory-level budget accounting.
#[derive(Debug, Default)]
pub struct FileFindings {
    /// Hard violations (everything but in-budget panic sites).
    pub violations: Vec<Diagnostic>,
    /// `panic-budget` sites, to be metered against [`PANIC_BUDGET`].
    pub panic_sites: Vec<Diagnostic>,
    /// Findings suppressed by a well-formed allow directive.
    pub allowed: usize,
}

/// Run the full analysis over a set of parsed sources: build the crate
/// graph, run the interprocedural rules, then the per-line rules with
/// graph-derived scope, then the stale-allow pass and suppression.
fn analyze_parsed(
    parsed: &[(String, SourceModel)],
) -> (graph::CrateGraph, flow::FlowInfo, Vec<(String, FileFindings)>) {
    let g = graph::build(parsed);
    let (fl, inter) = flow::analyze(&g, parsed);
    let scope = rules::Scope { graph: &g, flow: &fl };
    let mut inter_by_path: BTreeMap<String, Vec<Diagnostic>> = BTreeMap::new();
    for d in inter {
        inter_by_path.entry(d.path.clone()).or_default().push(d);
    }
    let mut out = Vec::with_capacity(parsed.len());
    for (path, model) in parsed {
        let mut raw = rules::check(path, model, &scope);
        if let Some(extra) = inter_by_path.remove(path) {
            raw.extend(extra);
        }
        // Stale-allow pass: a well-formed allow on a non-test line that
        // suppresses no finding is itself a finding, so the exception
        // list can only shrink.
        let mut stale = Vec::new();
        for (idx, info) in model.lines.iter().enumerate() {
            if info.in_test {
                continue;
            }
            let line = idx + 1;
            for allow in &info.allows {
                let well_formed = allow.has_reason
                    && allow.rule != "allow-grammar"
                    && RULES.iter().any(|r| r.id == allow.rule);
                if !well_formed {
                    continue; // already an allow-grammar finding
                }
                let hits = raw
                    .iter()
                    .any(|d| d.rule == allow.rule && d.line == line);
                if !hits {
                    stale.push(Diagnostic {
                        rule: "allow-grammar",
                        path: path.clone(),
                        line: allow.at_line,
                        msg: format!(
                            "stale allow({}): no {} finding on its target line — \
                             delete the annotation",
                            allow.rule, allow.rule
                        ),
                        snippet: info.code.trim().to_string(),
                    });
                }
            }
        }
        raw.extend(stale);
        let mut found = FileFindings::default();
        for d in raw {
            let suppressed = d.rule != "allow-grammar"
                && model
                    .lines
                    .get(d.line - 1)
                    .is_some_and(|l| l.allows.iter().any(|a| a.rule == d.rule && a.has_reason));
            if suppressed {
                found.allowed += 1;
            } else if d.rule == "panic-budget" {
                found.panic_sites.push(d);
            } else {
                found.violations.push(d);
            }
        }
        found
            .violations
            .sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
        found.panic_sites.sort_by_key(|d| d.line);
        out.push((path.clone(), found));
    }
    (g, fl, out)
}

/// Scan one file's source in isolation. `path` is the root-relative path
/// rules use for scoping (fixtures pass virtual paths like
/// `fleet/bad.rs`); reachability is computed over this file alone.
pub fn audit_source(path: &str, text: &str) -> FileFindings {
    let parsed = vec![(path.to_string(), SourceModel::parse(text))];
    let (_, _, mut files) = analyze_parsed(&parsed);
    match files.pop() {
        Some((_, found)) => found,
        None => FileFindings::default(),
    }
}

/// The whole-tree audit result.
#[derive(Debug, Default)]
pub struct AuditReport {
    pub files: usize,
    pub violations: Vec<Diagnostic>,
    pub allowed: usize,
    /// `(prefix, sites used, allowance)` for each [`PANIC_BUDGET`] entry
    /// with at least one site.
    pub budget_used: Vec<(String, usize, usize)>,
}

/// An [`AuditReport`] plus the crate graph and flow analysis it was
/// scoped by (the `--graph` surface).
#[derive(Debug, Default)]
pub struct CrateAudit {
    pub report: AuditReport,
    pub graph: graph::CrateGraph,
    pub flow: flow::FlowInfo,
}

impl AuditReport {
    pub fn clean(&self) -> bool {
        self.violations.is_empty()
    }

    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!("falcon audit: {} files scanned\n", self.files));
        for r in RULES {
            let n = self.violations.iter().filter(|d| d.rule == r.id).count();
            s.push_str(&format!("  {:<22} {:>3} violation(s)\n", r.id, n));
        }
        if !self.violations.is_empty() {
            s.push('\n');
            for d in &self.violations {
                s.push_str(&d.render());
                s.push('\n');
            }
        }
        if !self.budget_used.is_empty() {
            s.push_str("\npanic budget (sites used / allowance):\n");
            for (prefix, used, budget) in &self.budget_used {
                s.push_str(&format!("  {prefix:<12} {used:>3} / {budget}\n"));
            }
        }
        s.push_str(&format!("\n{} finding(s) suppressed by audit:allow\n", self.allowed));
        s.push_str(if self.clean() {
            "audit: CLEAN\n"
        } else {
            "audit: FAIL\n"
        });
        s
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("files", Json::Num(self.files as f64)),
            ("clean", Json::Bool(self.clean())),
            ("allowed", Json::Num(self.allowed as f64)),
            (
                "violations",
                Json::Arr(self.violations.iter().map(|d| d.to_json()).collect()),
            ),
            (
                "panic_budget",
                Json::Arr(
                    self.budget_used
                        .iter()
                        .map(|(p, u, b)| {
                            Json::obj(vec![
                                ("prefix", Json::str(p)),
                                ("used", Json::Num(*u as f64)),
                                ("allowance", Json::Num(*b as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "rules",
                Json::Arr(
                    RULES
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("id", Json::str(r.id)),
                                ("summary", Json::str(r.summary)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    let mut entries: Vec<PathBuf> = std::fs::read_dir(dir)?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            collect_rs(&p, out)?;
        } else if p.extension().and_then(|e| e.to_str()) == Some("rs") {
            out.push(p);
        }
    }
    Ok(())
}

fn budget_for(path: &str) -> Option<usize> {
    PANIC_BUDGET.iter().position(|(prefix, _, _)| {
        if prefix.ends_with('/') {
            path.starts_with(prefix)
        } else {
            path == *prefix
        }
    })
}

/// Audit a set of in-memory sources as one crate: the whole-graph
/// equivalent of [`audit_source`], with panic-budget metering.
pub fn audit_sources(sources: &[(String, String)]) -> CrateAudit {
    let parsed: Vec<(String, SourceModel)> = sources
        .iter()
        .map(|(p, t)| (p.clone(), SourceModel::parse(t)))
        .collect();
    let (g, fl, files) = analyze_parsed(&parsed);
    let mut report = AuditReport::default();
    let mut metered: Vec<Vec<Diagnostic>> = PANIC_BUDGET.iter().map(|_| Vec::new()).collect();
    for (path, found) in files {
        report.files += 1;
        report.allowed += found.allowed;
        report.violations.extend(found.violations);
        for site in found.panic_sites {
            match budget_for(&path) {
                Some(i) => metered[i].push(site),
                // Outside every budgeted module: a hard violation.
                None => report.violations.push(site),
            }
        }
    }
    for (i, sites) in metered.into_iter().enumerate() {
        if sites.is_empty() {
            continue;
        }
        let (prefix, allowance, _) = PANIC_BUDGET[i];
        let used = sites.len();
        report.budget_used.push((prefix.to_string(), used, allowance));
        if used > allowance {
            for mut site in sites {
                site.msg = format!(
                    "{} (module budget for {prefix} exceeded: {used} sites, allowance {allowance})",
                    site.msg
                );
                report.violations.push(site);
            }
        }
    }
    report
        .violations
        .sort_by(|a, b| (&a.path, a.line, a.rule).cmp(&(&b.path, b.line, b.rule)));
    CrateAudit { report, graph: g, flow: fl }
}

/// Audit every `.rs` file under `root` (recursively, sorted walk) as one
/// crate, returning the report plus the graph/flow surfaces.
pub fn audit_dir_graph(root: &Path) -> std::io::Result<CrateAudit> {
    let mut files = Vec::new();
    collect_rs(root, &mut files)?;
    let mut sources = Vec::with_capacity(files.len());
    for f in &files {
        let text = std::fs::read_to_string(f)?;
        let rel = f
            .strip_prefix(root)
            .unwrap_or(f.as_path())
            .to_string_lossy()
            .replace('\\', "/");
        sources.push((rel, text));
    }
    Ok(audit_sources(&sources))
}

/// Audit every `.rs` file under `root`, report only.
pub fn audit_dir(root: &Path) -> std::io::Result<AuditReport> {
    Ok(audit_dir_graph(root)?.report)
}
